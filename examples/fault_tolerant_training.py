"""End-to-end fault-tolerant training: the paper's full loop on real JAX.

    REPRO_HOST_DEVICES=8 PYTHONPATH=src python examples/fault_tolerant_training.py

Runs a dp=2 x pp=2 x tp=2 pipeline on 8 emulated host devices, checkpoints
every 5 steps, injects a fail-stop at step 8 and a fail-slow at step 12, and
lets the ResiHP stack detect -> adapt (selective TP exclusion + layer
repartition) -> recover -> resume. Watch the plan summaries change.
"""
import os
import sys

if "REPRO_HOST_DEVICES" not in os.environ:
    os.environ["REPRO_HOST_DEVICES"] = "8"
    os.execv(sys.executable, [sys.executable] + sys.argv)  # re-exec pre-jax

from repro.launch.train import main  # noqa: E402


if __name__ == "__main__":
    result = main([
        "--arch", "qwen3-8b", "--reduced",
        "--mode", "pipeline",
        "--dp", "2", "--pp", "2", "--tp", "2",
        "--steps", "20", "--seq-len", "64", "--batch", "8",
        "--ckpt-dir", "/tmp/resihp_example_ckpt", "--ckpt-interval", "5",
        "--inject-failstop", "8:5",
        "--inject-failslow", "12:2@0.5",
    ])
    print(f"\nsurvived {len(result['losses'])} steps; "
          f"reconfigurations at steps {result['reconfigs']}")
