"""The paper's core Detector idea in isolation: sequence-length variability
vs real fail-slow, and how the Eq. 1 workload filter separates them.

    PYTHONPATH=src python examples/detector_filter.py
"""
import numpy as np

from repro.core.detector.changepoint import CusumDetector
from repro.core.detector.detector import Detector
from repro.core.detector.heartbeat import HeartbeatMonitor
from repro.data.packing import pack_documents, quadratic_cost
from repro.data.synth import sample_doc_lengths

ALPHA, BETA = 2.0e-7, 1.2e-11  # Eq. 1 ground truth (per layer)
SEQ, LAYERS = 8192, 40


def iteration_time(rng, slow=1.0):
    # two packed rows per iteration: sum(l^2) genuinely swings iteration to
    # iteration (one 8K document costs ~4x four 2K documents — §2.2)
    lens = sample_doc_lengths(rng, 6, SEQ, sigma=1.4)
    rows = pack_documents(lens, SEQ)[:2]
    t = sum(ALPHA * sum(r) + BETA * quadratic_cost(r) for r in rows) * LAYERS * 3
    return t * slow * float(rng.normal(1.0, 0.01)), rows


def healthy_time(workload):
    return sum(ALPHA * sum(r) + BETA * quadratic_cost(r) for r in workload) * LAYERS * 3


def run(workload_filter: bool):
    rng = np.random.default_rng(0)
    det = Detector(
        healthy_time_fn=healthy_time,
        validate_fn=lambda it: [(5, 0.5)] if it >= 60 else [],
        heartbeat=HeartbeatMonitor(),
        workload_filter=workload_filter,
        changepoint_factory=lambda: CusumDetector(warmup=10),
    )
    detected_at = None
    for it in range(90):
        slow = 2.0 if it >= 60 else 1.0  # true fail-slow from iteration 60
        t, rows = iteration_time(rng, slow)
        rep = det.observe_iteration(it, t, rows)
        if rep and detected_at is None:
            detected_at = it
            break  # a real deployment reconfigures here
    return det, detected_at


def main():
    for mode, name in ((True, "ResiHP (workload-aware)"),
                       (False, "Greyhound-style (no filter)")):
        det, at = run(mode)
        s = det.stats
        print(f"{name}:")
        print(f"  change points seen      {s.change_points}")
        print(f"  benign filtered         {s.filtered_benign}")
        print(f"  validations paid        {s.validations}")
        print(f"  false alarms            {s.false_alarms}")
        print(f"  detection overhead      {det.overhead_s*1e3:.0f} ms")
        print(f"  fail-slow detected at   iter {at}\n")


if __name__ == "__main__":
    main()
