"""Quickstart: train a reduced Qwen3-family model for a few steps on CPU.

    PYTHONPATH=src python examples/quickstart.py

Walks the public API surface: config registry -> data pipeline (sequence
packing) -> pjit train step -> Eq. 1 predictor fitting on measured times.
"""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_arch, list_archs, reduced
from repro.core.detector.predictor import MicroBatchTimePredictor
from repro.data.packing import pack_stats
from repro.data.synth import SyntheticPackedDataset
from repro.parallel.sharding import NULL_POLICY
from repro.train.optimizer import optimizer_for
from repro.train.train_step import build_train_step, init_train_state


def main():
    print("registered architectures:", ", ".join(list_archs()))
    cfg = reduced(get_arch("qwen3-8b"))
    print(f"training {cfg.arch_id}: {cfg.param_count()/1e6:.2f}M params")

    opt = optimizer_for(cfg, lr=1e-3)
    state, _ = init_train_state(jax.random.PRNGKey(0), cfg, opt)
    step = jax.jit(build_train_step(cfg, NULL_POLICY, opt, microbatches=2,
                                    remat=False, flash_chunk=32))
    ds = SyntheticPackedDataset(cfg, seq_len=128, global_batch=8, seed=0)

    pred = MicroBatchTimePredictor()
    for it in range(12):
        batch = {k: jnp.asarray(v) for k, v in ds.batch_at(it).items()}
        t0 = time.perf_counter()
        state, metrics = step(state, batch)
        loss = float(metrics["loss"])
        dt = time.perf_counter() - t0
        stats = pack_stats(np.asarray(batch["segment_ids"]))
        n, l2 = sum(s[0] for s in stats), sum(s[1] for s in stats)
        if it >= 2:  # skip compile steps, then feed the Eq. 1 predictor
            pred.observe(n, l2, dt)
        print(f"step {it:2d}  loss {loss:.4f}  {dt*1e3:6.1f} ms  "
              f"tokens={n}  sum_l2={l2}")
    pred.fit()
    print(f"\nEq.1 fit: alpha={pred.alpha:.3e} s/token  "
          f"beta={pred.beta:.3e} s/token^2  gamma={pred.gamma:.3e} s")


if __name__ == "__main__":
    main()
