"""256-GPU failure scenarios in the discrete-event simulator: ResiHP vs the
paper's baselines, mixed fail-stop + fail-slow (Fig. 10/14 style).

    PYTHONPATH=src python examples/cluster_failures.py
"""
from repro.cluster.simulator import SimConfig, TrainingSim


def run(policy: str) -> TrainingSim:
    cfg = SimConfig(dp=4, pp=16, tp=4, n_layers=80, n_microbatches=6,
                    seq_len=8192, seed=0)  # llama2-70b scale: 256 devices
    sim = TrainingSim(policy, cfg)
    # recurring mixed failures across distinct TP groups (Fig. 14 style)
    events = [(15.0, "stop", 37), (35.0, "slow", 101, 0.45), (55.0, "stop", 5),
              (75.0, "slow", 182, 0.3), (95.0, "stop", 201), (115.0, "slow", 66, 0.5)]
    for ev in events:
        if ev[1] == "stop":
            sim.inject_at(ev[0], lambda c, now, d=ev[2]: c.fail_stop(d, now))
        else:
            sim.inject_at(ev[0], lambda c, now, d=ev[2], f=ev[3]: c.fail_slow(d, f, now))
    sim.run(160, stop_on_abort=False)
    return sim


def main():
    print(f"{'system':12s} {'samples/s':>10s} {'vs resihp':>10s} "
          f"{'false alarms':>13s} {'aborted':>8s}")
    results = {p: run(p) for p in ("resihp", "recycle+", "oobleck+", "recycle")}
    resi = results["resihp"].avg_throughput(skip=2)
    for p, sim in results.items():
        th = sim.avg_throughput(skip=2)
        print(f"{p:12s} {th:10.2f} {resi/max(th,1e-9):9.2f}x "
              f"{sim.detector.stats.false_alarms:13d} {str(sim.aborted):>8s}")
    print("\nreconfiguration events (resihp):")
    for rec in results["resihp"].trace:
        interesting = [e for e in rec.events if e[0] != "migrations"]
        if interesting:
            print(f"  iter {rec.iteration:3d} t={rec.t_start:7.1f}s  {interesting}")


if __name__ == "__main__":
    main()
