"""256-GPU failure scenarios in the discrete-event simulator: ResiHP vs the
paper's baselines, mixed fail-stop + fail-slow (Fig. 10/14 style).

    PYTHONPATH=src python examples/cluster_failures.py
"""
from repro.cluster import scenarios
from repro.cluster.simulator import SimConfig, TrainingSim


def run(policy: str) -> TrainingSim:
    cfg = SimConfig(dp=4, pp=16, tp=4, n_layers=80, n_microbatches=6,
                    seq_len=8192, seed=0)  # llama2-70b scale: 256 devices
    sim = TrainingSim(policy, cfg)
    # recurring mixed failures across distinct TP groups (Fig. 14 style)
    sim.apply_scenario(scenarios.get("example_mixed"))
    sim.run(160, stop_on_abort=False)
    return sim


def main():
    print(f"{'system':12s} {'samples/s':>10s} {'vs resihp':>10s} "
          f"{'false alarms':>13s} {'aborted':>8s}")
    results = {p: run(p) for p in ("resihp", "recycle+", "oobleck+", "recycle")}
    resi = results["resihp"].avg_throughput(skip=2)
    for p, sim in results.items():
        th = sim.avg_throughput(skip=2)
        print(f"{p:12s} {th:10.2f} {resi/max(th,1e-9):9.2f}x "
              f"{sim.detector.stats.false_alarms:13d} {str(sim.aborted):>8s}")
    print("\nreconfiguration events (resihp):")
    for rec in results["resihp"].trace:
        interesting = [e for e in rec.events if e[0] != "migrations"]
        if interesting:
            print(f"  iter {rec.iteration:3d} t={rec.t_start:7.1f}s  {interesting}")


if __name__ == "__main__":
    main()
