"""Fig. 14: 256-GPU large-scale run — LLaMA2-70B, (TP,DP,PP)=(4,4,16),
recurring fail-stop + fail-slow failures and re-joins; ResiHP vs strengthened
ReCycle vs strengthened Oobleck. Produces the timeline trace (throughput per
iteration + event markers).

Beyond the paper's 256-GPU point, ``--devices`` sweeps the same protocol at
1024/2048/4096 devices (Table-3 ``1k``/``2k``/``4k`` presets); ``--engine``
picks the simulator core (the ``fast`` default is the only practical choice
at 1k+ — see ``BENCH_simcore.json``):

    PYTHONPATH=src python -m benchmarks.bench_fig14_largescale \
        --engine fast --devices 1024,2048 [--quick]
"""
from __future__ import annotations

from benchmarks.common import Timer, sim_config, write_result
from repro.cluster import scenarios
from repro.cluster.simulator import TrainingSim

# device count -> Table-3 scale preset (all share llama2-70b layer costs)
SCALES = {256: "xlarge", 1024: "1k", 2048: "2k", 4096: "4k",
          8192: "8k", 16384: "16k", 32768: "32k", 102400: "100k"}


def run(policy: str, kw=None, *, iters=160, seed=0, engine="fast",
        devices=256):
    scale = SCALES[devices]
    cfg = sim_config("llama2-70b", n_mb=6, seed=seed, scale=scale)
    assert cfg.n_devices == devices, (cfg.n_devices, devices)
    sim = TrainingSim(policy, cfg, policy_kwargs=kw or {}, engine=engine)
    sim.apply_scenario(scenarios.get("fig14_largescale", span=iters * 1.2))
    with Timer() as t:
        sim.run(iters, stop_on_abort=False)
    trace = [
        {"iter": r.iteration, "t": round(r.t_start, 1),
         "thpt": round(r.throughput, 3),
         "events": [e[0] for e in r.events if e[0] != "migrations"]}
        for r in sim.trace
    ]
    return {
        "avg_throughput": sim.avg_throughput(skip=2),
        "aborted": sim.aborted,
        "engine": engine,
        "devices": devices,
        "wall_s": round(t.seconds, 2),
        "trace": trace,
        "detector": sim.detector.stats.as_dict(),
    }


def main(quick=False, engine="fast", devices=(256,)):
    iters = 60 if quick else 160
    out, rows = {}, []
    for dv in devices:
        tag = "fig14" if dv == 256 else f"fig14@{dv}"
        per_policy = {}
        for policy in ("resihp", "recycle+", "oobleck+"):
            r = run(policy, iters=iters, engine=engine, devices=dv)
            per_policy[policy] = r
            out[f"{tag}/{policy}" if dv != 256 else policy] = r
            rows.append((f"{tag}/{policy}/avg_throughput",
                         round(r["avg_throughput"], 2),
                         f"aborted={r['aborted']} wall={r['wall_s']}s"))
        resi = per_policy["resihp"]["avg_throughput"]
        for p in ("recycle+", "oobleck+"):
            rows.append((f"{tag}/speedup_over_{p}",
                         round(resi / max(per_policy[p]["avg_throughput"], 1e-9), 2),
                         ""))
    write_result("fig14_largescale", out)
    return rows


if __name__ == "__main__":
    import argparse

    from benchmarks.common import emit

    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--engine", choices=("python", "fast"), default="fast")
    ap.add_argument("--devices", default="256",
                    help=f"comma-separated subset of {sorted(SCALES)}")
    args = ap.parse_args()
    devices = tuple(int(d) for d in args.devices.split(","))
    emit(main(quick=args.quick, engine=args.engine, devices=devices))
