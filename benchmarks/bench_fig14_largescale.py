"""Fig. 14: 256-GPU large-scale run — LLaMA2-70B, (TP,DP,PP)=(4,4,16),
recurring fail-stop + fail-slow failures and re-joins; ResiHP vs strengthened
ReCycle vs strengthened Oobleck. Produces the timeline trace (throughput per
iteration + event markers)."""
from __future__ import annotations

from benchmarks.common import sim_config, write_result
from repro.cluster import scenarios
from repro.cluster.simulator import TrainingSim


def run(policy: str, kw=None, *, iters=160, seed=0):
    cfg = sim_config("llama2-70b", n_mb=6, seed=seed)  # (4, 4, 16) = 256
    sim = TrainingSim(policy, cfg, policy_kwargs=kw or {})
    sim.apply_scenario(scenarios.get("fig14_largescale", span=iters * 1.2))
    sim.run(iters, stop_on_abort=False)
    trace = [
        {"iter": r.iteration, "t": round(r.t_start, 1),
         "thpt": round(r.throughput, 3),
         "events": [e[0] for e in r.events if e[0] != "migrations"]}
        for r in sim.trace
    ]
    return {
        "avg_throughput": sim.avg_throughput(skip=2),
        "aborted": sim.aborted,
        "trace": trace,
        "detector": sim.detector.stats.as_dict(),
    }


def main(quick=False):
    iters = 60 if quick else 160
    out, rows = {}, []
    for policy in ("resihp", "recycle+", "oobleck+"):
        r = run(policy, iters=iters)
        out[policy] = r
        rows.append((f"fig14/{policy}/avg_throughput",
                     round(r["avg_throughput"], 2),
                     f"aborted={r['aborted']}"))
    resi = out["resihp"]["avg_throughput"]
    for p in ("recycle+", "oobleck+"):
        rows.append((f"fig14/speedup_over_{p}",
                     round(resi / max(out[p]["avg_throughput"], 1e-9), 2), ""))
    write_result("fig14_largescale", out)
    return rows


if __name__ == "__main__":
    from benchmarks.common import emit

    emit(main())
