"""Fig. 12: training-loss trajectory — fault-free baseline vs ResiHP with
injected fail-stop failures (real PipelineEngine execution: kill devices,
reconfigure, reshard, resume). Curves must tightly overlap."""
from __future__ import annotations

import numpy as np

from benchmarks.common import write_result
from repro.configs import get_arch, reduced
from repro.core.scheduler.plan import initial_plan
from repro.core.scheduler.repartition import costs_for_arch
from repro.core.scheduler.scheduler import Scheduler
from repro.data.synth import SyntheticPackedDataset
from repro.engine.pipeline import PipelineEngine
from repro.train.optimizer import make_optimizer


def run(steps, fail_steps=(), seed=0):
    cfg = reduced(get_arch("qwen3-8b"), n_layers=4)  # llama-family reduced
    ds = SyntheticPackedDataset(cfg, 64, 8, seed=seed)
    opt = make_optimizer("adamw", lr=3e-3)
    plan = initial_plan(4, dp=2, pp=2, tp=2, microbatches=2)
    eng = PipelineEngine(cfg, plan, optimizer=opt, seed=seed)
    sch = Scheduler(layer_costs=costs_for_arch(cfg, 64))
    speeds = {d: 1.0 for d in plan.devices}
    losses, reconfigs = [], []
    import jax.numpy as jnp

    for it in range(steps):
        if it in fail_steps:
            # kill a device from the currently-largest TP group so no stage
            # dies entirely (a dead stage needs DP migration, not this engine)
            groups = [(len(st.devices), st.devices)
                      for rep in eng.plan.replicas for st in rep.stages
                      if len(st.devices) > 1]
            victim = max(groups)[1][-1]
            speeds[victim] = 0.0
            ad = sch.adapt(eng.plan, speeds)
            if not ad.restore_required:
                eng.apply_plan(ad.plan)
                reconfigs.append(it)
        batch = {k: jnp.asarray(v) for k, v in ds.batch_at(it).items()}
        loss, _ = eng.run_iteration(batch)
        losses.append(loss)
    return losses, reconfigs


def main(quick=False):
    steps = 20 if quick else 50
    base, _ = run(steps)
    resi, reconfigs = run(steps, fail_steps=(steps // 4, steps // 2))
    base, resi = np.asarray(base), np.asarray(resi)
    gap = float(np.abs(base - resi).max())
    final_gap = float(abs(base[-1] - resi[-1]))
    out = {
        "steps": steps,
        "fault_free": base.tolist(),
        "resihp_with_failures": resi.tolist(),
        "reconfig_steps": reconfigs,
        "max_gap": gap,
        "final_gap": final_gap,
    }
    write_result("fig12_convergence", out)
    return [
        ("fig12/max_loss_gap", round(gap, 5), f"reconfigs at {reconfigs}"),
        ("fig12/final_loss_gap", round(final_gap, 5),
         f"ff={base[-1]:.4f} resihp={resi[-1]:.4f}"),
    ]


if __name__ == "__main__":
    from benchmarks.common import emit

    emit(main())
