"""Simulator-core engine benchmark: python (reference) vs fast execution
engine on the Fig. 14 protocol.

Measures wall-clock for the 256-device Fig. 14 config under both engines,
asserts they produce identical results (throughput parity is a live canary
on top of the golden/parity test suites), and adds fast-engine-only points
at 1024/2048 devices — the sweep sizes the ROADMAP "Scale" item asks for.

Writes ``results/bench_simcore.json`` and the repo-root
``BENCH_simcore.json`` cited by the README.

    PYTHONPATH=src python -m benchmarks.bench_simcore [--quick]
"""
from __future__ import annotations

import json
from pathlib import Path

from benchmarks.bench_fig14_largescale import run
from benchmarks.common import RESULTS, write_result

REPO_ROOT_JSON = RESULTS.parent / "BENCH_simcore.json"


def main(quick=False):
    iters = 40 if quick else 160
    points = [("python", 256), ("fast", 256), ("fast", 1024)]
    if not quick:
        points.append(("fast", 2048))
    results = {}
    for engine, devices in points:
        r = run("resihp", iters=iters, engine=engine, devices=devices)
        results[f"{engine}@{devices}"] = {
            "engine": engine,
            "devices": devices,
            "iters": iters,
            "wall_s": r["wall_s"],
            "avg_throughput": r["avg_throughput"],
            "aborted": r["aborted"],
        }
    # the two engines must agree exactly — bit-for-bit is the contract
    assert (results["python@256"]["avg_throughput"]
            == results["fast@256"]["avg_throughput"]), "engine parity broken"

    py, fa = results["python@256"], results["fast@256"]
    speedup = py["wall_s"] / max(fa["wall_s"], 1e-9)
    payload = {
        "config": "fig14_largescale protocol, llama2-70b layer costs, "
                  "resihp policy, n_mb=6, seed=0",
        "iters": iters,
        "results": results,
        "speedup_fast_vs_python_at_256": round(speedup, 1),
        "fast_1024_faster_than_python_256": (
            results["fast@1024"]["wall_s"] < py["wall_s"]),
    }
    write_result("bench_simcore", payload)
    if not quick:
        # the repo-root file is the checked-in 160-iteration measurement the
        # README cites; don't clobber it with quick-mode numbers
        REPO_ROOT_JSON.write_text(json.dumps(payload, indent=2) + "\n")

    rows = [(f"simcore/{k}/wall_s", v["wall_s"],
             f"thpt={v['avg_throughput']:.2f}") for k, v in results.items()]
    rows.append(("simcore/speedup_fast_vs_python@256", round(speedup, 1), ""))
    return rows


if __name__ == "__main__":
    import argparse

    from benchmarks.common import emit

    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    args = ap.parse_args()
    emit(main(quick=args.quick))
