"""Simulator-core engine benchmark: python (reference) vs fast execution
engine on the Fig. 14 protocol.

Measures wall-clock for the 256-device Fig. 14 config under both engines,
asserts they produce identical results (throughput parity is a live canary
on top of the golden/parity test suites), and adds fast-engine-only points
at 1024/2048/8192/16384 devices — the fleet scales the ROADMAP "Scale" item
asks for. Per-row ``wall_ms_per_device`` plus the headline
``per_device_scaling_16384_vs_2048`` ratio make superlinear growth visible
at a glance (the array-native cluster core targets ratio <= ~1.5, i.e.
near-linear).

Writes ``results/bench_simcore.json`` and the repo-root
``BENCH_simcore.json`` cited by the README.

    PYTHONPATH=src python -m benchmarks.bench_simcore [--quick] [--check]

``--check`` is the nightly perf-regression gate: measured wall times are
compared against the checked-in reference values
(``benchmarks/simcore_reference.json``) with a generous 2x tolerance —
loose enough to absorb runner-speed variance, tight enough that a
superlinear regression (which costs 4-8x on the large-device rows) fails
loudly.
"""
from __future__ import annotations

import json
from pathlib import Path

from benchmarks.bench_fig14_largescale import run
from benchmarks.common import RESULTS, peak_rss_mb, write_result

REPO_ROOT_JSON = RESULTS.parent / "BENCH_simcore.json"
REFERENCE_JSON = Path(__file__).resolve().parent / "simcore_reference.json"

QUICK_POINTS = [("python", 256), ("fast", 256), ("fast", 1024),
                ("fast", 4096), ("fast", 32768)]
FULL_POINTS = [("python", 256), ("fast", 256), ("fast", 1024),
               ("fast", 2048), ("fast", 8192), ("fast", 16384),
               ("fast", 32768)]


def check_against_reference(results: dict, iters: int, *,
                            tolerance: float = 2.0) -> list:
    """Compare this run against the checked-in reference; return a list of
    human-readable violations (empty = pass). Two layers:

    * absolute wall times at ``tolerance`` (generous, absorbs moderate
      runner-speed differences);
    * the **scaling ratio** between the largest and smallest fast-engine
      points — runner speed cancels out of a same-run ratio, so this stays
      meaningful even on hosts much faster or slower than the reference
      machine (where the absolute check loses its teeth or cries wolf)."""
    ref = json.loads(REFERENCE_JSON.read_text())
    if ref["iters"] != iters:
        return [f"reference measured at iters={ref['iters']}, got {iters} "
                f"(run with the matching --quick mode)"]
    violations = []
    for key, ref_wall in ref["wall_s"].items():
        got = results.get(key)
        if got is None:
            violations.append(f"{key}: missing from this run")
            continue
        if got["wall_s"] > tolerance * ref_wall:
            violations.append(
                f"{key}: wall_s {got['wall_s']:.2f} > {tolerance:g}x "
                f"reference {ref_wall:.2f} — superlinear regression?")
    fast = sorted((k for k in ref["wall_s"] if k.startswith("fast@")),
                  key=lambda k: int(k.split("@")[1]))
    if len(fast) >= 2 and all(k in results for k in (fast[0], fast[-1])):
        lo, hi = fast[0], fast[-1]
        got_ratio = results[hi]["wall_s"] / max(results[lo]["wall_s"], 1e-9)
        ref_ratio = ref["wall_s"][hi] / max(ref["wall_s"][lo], 1e-9)
        if got_ratio > tolerance * ref_ratio:
            violations.append(
                f"{hi}/{lo} wall ratio {got_ratio:.1f} > {tolerance:g}x "
                f"reference ratio {ref_ratio:.1f} — per-device scaling "
                f"regressed (machine-speed-independent check)")
    return violations


def main(quick=False, check=False):
    iters = 40 if quick else 160
    points = QUICK_POINTS if quick else FULL_POINTS
    results = {}
    for engine, devices in points:
        r = run("resihp", iters=iters, engine=engine, devices=devices)
        results[f"{engine}@{devices}"] = {
            "engine": engine,
            "devices": devices,
            "iters": iters,
            "wall_s": r["wall_s"],
            "wall_ms_per_device": round(1000.0 * r["wall_s"] / devices, 4),
            "avg_throughput": r["avg_throughput"],
            "aborted": r["aborted"],
            # ru_maxrss is a process-wide high-water mark: the reading on
            # each row (points run smallest-to-largest) bounds that row's
            # footprint from above
            "peak_rss_mb": peak_rss_mb(),
        }
    # the two engines must agree exactly — bit-for-bit is the contract
    assert (results["python@256"]["avg_throughput"]
            == results["fast@256"]["avg_throughput"]), "engine parity broken"

    py, fa = results["python@256"], results["fast@256"]
    speedup = py["wall_s"] / max(fa["wall_s"], 1e-9)
    payload = {
        "config": "fig14_largescale protocol, llama2-70b layer costs, "
                  "resihp policy, n_mb=6, seed=0",
        "iters": iters,
        "results": results,
        "speedup_fast_vs_python_at_256": round(speedup, 1),
        "fast_1024_faster_than_python_256": (
            results["fast@1024"]["wall_s"] < py["wall_s"]),
    }
    if "fast@16384" in results and "fast@2048" in results:
        payload["per_device_scaling_16384_vs_2048"] = round(
            results["fast@16384"]["wall_ms_per_device"]
            / max(results["fast@2048"]["wall_ms_per_device"], 1e-9), 3)
    write_result("bench_simcore", payload)
    if not quick:
        # the repo-root file is the checked-in 160-iteration measurement the
        # README cites; don't clobber it with quick-mode numbers
        REPO_ROOT_JSON.write_text(json.dumps(payload, indent=2) + "\n")

    rows = [(f"simcore/{k}/wall_s", v["wall_s"],
             f"thpt={v['avg_throughput']:.2f} "
             f"per_dev_ms={v['wall_ms_per_device']} "
             f"peak_rss_mb={v['peak_rss_mb']}")
            for k, v in results.items()]
    rows.append(("simcore/speedup_fast_vs_python@256", round(speedup, 1), ""))
    if "per_device_scaling_16384_vs_2048" in payload:
        rows.append(("simcore/per_device_scaling_16384_vs_2048",
                     payload["per_device_scaling_16384_vs_2048"],
                     "target <= ~1.5 (near-linear)"))
    if check:
        violations = check_against_reference(results, iters)
        for v in violations:
            rows.append(("simcore/REGRESSION", "-", v))
        if violations:
            raise SystemExit(
                "bench_simcore --check failed:\n  " + "\n  ".join(violations))
    return rows


if __name__ == "__main__":
    import argparse

    from benchmarks.common import emit

    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--check", action="store_true",
                    help="fail (exit 1) if wall times exceed 2x the "
                         "checked-in reference (nightly perf gate)")
    args = ap.parse_args()
    emit(main(quick=args.quick, check=args.check))
