"""Table 4: prediction accuracy (MAPE) of the micro-batch time predictor
(MTP, Eq. 1) and the iteration-time predictor (ITP, Eq. 2) against *measured*
wall times of the real JAX engine on this host.

Adaptation note (CPU container): pipeline stages here execute on one host, so
a real multi-stage iteration serializes — the honest measurable iteration is
the SPMD microbatched step, predicted as sum-of-chunks + fitted constant.
The DAG critical-path machinery itself is validated analytically in
tests/test_dag_sim.py. On real TPUs ITP = DAG critical path, same code path.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import write_result
from repro.configs import get_arch, reduced
from repro.core.detector.predictor import MicroBatchTimePredictor
from repro.data.packing import pack_documents, pack_stats, row_to_arrays
from repro.models.model import loss_fn, stacked_init
from repro.parallel.sharding import NULL_POLICY, split_annotations


def _mb_batch(cfg, S, rng, n_docs):
    lens = np.clip(rng.lognormal(np.log(S / max(n_docs, 1)), 0.6, n_docs),
                   8, S).astype(int)
    rows = pack_documents(lens, S)[:1] or [[S]]
    tokens, seg, pos, labels = row_to_arrays(rows[0], S, rng, cfg.vocab_size)
    return {k: jnp.asarray(v[None]) for k, v in
            {"tokens": tokens, "segment_ids": seg, "positions": pos,
             "labels": labels}.items()}


def measure_mtp(*, S=512, n_train=14, n_test=10, seed=0):
    cfg = reduced(get_arch("qwen3-8b"), n_layers=4, d_model=128, n_heads=4,
                  head_dim=32, d_ff=256)
    params, _ = split_annotations(stacked_init(jax.random.PRNGKey(0), cfg))
    rng = np.random.default_rng(seed)

    fwd_bwd = jax.jit(jax.value_and_grad(
        lambda p, b: loss_fn(cfg, p, b, NULL_POLICY, use_scan=False,
                             remat=False, flash_chunk=64)[0]))

    def timed(batch):
        out = fwd_bwd(params, batch)
        jax.block_until_ready(out)
        ts = []
        for _ in range(3):
            t0 = time.perf_counter()
            out = fwd_bwd(params, batch)
            jax.block_until_ready(out)
            ts.append(time.perf_counter() - t0)
        return min(ts)

    pred = MicroBatchTimePredictor()
    samples = []
    for i in range(n_train + n_test):
        n_docs = int(rng.integers(1, 12))
        batch = _mb_batch(cfg, S, rng, n_docs)
        (n, l2), = pack_stats(np.asarray(batch["segment_ids"]))
        t = timed(batch)
        samples.append((n, l2, t))
    for n, l2, t in samples[:n_train]:
        pred.observe(n, l2, t)
    pred.fit()
    test = [(n, l2, 1, t) for n, l2, t in samples[n_train:]]
    return pred, pred.mape(test)


def measure_itp(pred, *, S=512, n_mb=4, n_iters=8, seed=1):
    """Iteration = n_mb micro-batches accumulated; predict as sum of Eq. 1
    chunk times (+ fitted constant from one calibration iteration)."""
    cfg = reduced(get_arch("qwen3-8b"), n_layers=4, d_model=128, n_heads=4,
                  head_dim=32, d_ff=256)
    params, _ = split_annotations(stacked_init(jax.random.PRNGKey(0), cfg))
    rng = np.random.default_rng(seed)

    fwd_bwd = jax.jit(jax.value_and_grad(
        lambda p, b: loss_fn(cfg, p, b, NULL_POLICY, use_scan=False,
                             remat=False, flash_chunk=64)[0]))

    def run_iteration(batches):
        t0 = time.perf_counter()
        for b in batches:
            jax.block_until_ready(fwd_bwd(params, b))
        return time.perf_counter() - t0

    errs, bias = [], None
    for it in range(n_iters + 1):
        batches, predicted = [], 0.0
        for m in range(n_mb):
            b = _mb_batch(cfg, S, rng, int(rng.integers(1, 12)))
            (n, l2), = pack_stats(np.asarray(b["segment_ids"]))
            predicted += pred.predict(n, l2)
            batches.append(b)
        measured = min(run_iteration(batches) for _ in range(2))
        if it == 0:
            bias = measured - predicted  # dispatch/update constant
            continue
        errs.append(abs(predicted + bias - measured) / measured)
    return float(np.mean(errs))


def main(quick=False):
    pred, mtp = measure_mtp(n_train=10 if quick else 14,
                            n_test=6 if quick else 10)
    itp = measure_itp(pred, n_iters=4 if quick else 8)
    out = {
        "mtp_mape": mtp, "itp_mape": itp,
        "alpha": pred.alpha, "beta": pred.beta, "gamma": pred.gamma,
        "paper_mtp_range": [0.0119, 0.0158],
        "paper_itp_range": [0.0281, 0.0506],
    }
    write_result("table4_mape", out)
    return [
        ("table4/MTP_mape", round(mtp, 4), "paper: 1.19-1.58%"),
        ("table4/ITP_mape", round(itp, 4), "paper: 2.81-5.06%"),
    ]


if __name__ == "__main__":
    from benchmarks.common import emit

    emit(main())
