"""Fig. 11: component ablation — incrementally enable selective device
exclusion (§6.1), adaptive layer repartition (§6.2), and progress-aware
workload migration (§6.3) on top of a ReCycle-style baseline, under mixed
failures. Throughput normalized to ReCycle."""
from __future__ import annotations

from benchmarks.common import sim_config, write_result
from repro.cluster import scenarios
from repro.cluster.simulator import TrainingSim

VARIANTS = {
    "recycle": ("recycle", {}),
    "+selective": ("resihp", dict(enable_selective=True, enable_repartition=False,
                                  migration_mode="recycle")),
    "+repartition": ("resihp", dict(enable_selective=True, enable_repartition=True,
                                    migration_mode="recycle")),
    "+migration(full)": ("resihp", dict(enable_selective=True,
                                        enable_repartition=True,
                                        migration_mode="resihp")),
}


def run(model: str, variant: str, *, iters=250, seed=0):
    name, kw = VARIANTS[variant]
    cfg = sim_config(model, seed=seed)
    sim = TrainingSim(name, cfg, policy_kwargs=kw)
    sim.apply_scenario(scenarios.get("fig11_mixed", span=iters * 0.8))
    sim.run(iters)
    return sim.avg_throughput(skip=2)


def main(quick=False):
    models = ["llama2-13b"] if quick else ["llama2-7b", "llama2-13b", "llama2-30b"]
    iters = 120 if quick else 250
    out, rows = {}, []
    for model in models:
        rs = {v: run(model, v, iters=iters) for v in VARIANTS}
        base = rs["recycle"] or 1e-9
        out[model] = {v: {"throughput": t, "normalized": t / base}
                      for v, t in rs.items()}
        for v, t in rs.items():
            rows.append((f"fig11/{model}/{v}", round(t, 2),
                         f"norm={t/base:.2f}"))
    write_result("fig11_ablation", out)
    return rows


if __name__ == "__main__":
    from benchmarks.common import emit

    emit(main())
