"""Table 5: false alarms, per-alarm overhead, fail-slow detection accuracy —
ResiHP (workload filter) vs Greyhound (no filter), over many short jobs with
fail-slow injected in ~half of them."""
from __future__ import annotations

from benchmarks.common import sim_config, write_result
from repro.cluster import scenarios
from repro.cluster.simulator import TrainingSim


def run_jobs(policy: str, *, n_jobs=12, iters=110, model="qwen2.5-7b", seed=0):
    fa = vals = hits = injected = filtered = 0
    overhead = 0.0
    for j in range(n_jobs):
        cfg = sim_config(model, seed=seed * 100 + j)
        sim = TrainingSim(policy, cfg,
                          detector_kwargs={"workload_filter": policy == "resihp"})
        inject = j % 2 == 0
        if inject:
            injected += 1
            # random time in the mid-session window (leave warm-up + response
            # room), random device/severity — seeded per job (~0.8 s/iter)
            sim.apply_scenario(scenarios.get(
                "table5_failslow", window=(iters * 0.35 * 0.8, iters * 0.65 * 0.8)))
        sim.run(iters)
        st = sim.detector.stats
        fa += st.false_alarms
        vals += st.validations
        filtered += st.filtered_benign
        overhead += st.validation_overhead_s + st.filter_overhead_s
        if inject and any(r.kind == "fail-slow" for r in sim.detector.reports):
            hits += 1
    return {
        "policy": policy,
        "jobs": n_jobs,
        "injected": injected,
        "avg_false_alarms": fa / n_jobs,
        "validations": vals,
        "filtered_benign": filtered,
        "overhead_per_false_alarm_s": (overhead / fa) if fa else 0.0,
        "total_detection_overhead_s": overhead,
        "detection_accuracy": hits / max(injected, 1),
    }


def main(quick=False):
    n = 6 if quick else 12
    iters = 90 if quick else 110
    rows = []
    out = {}
    for model in (["qwen2.5-7b"] if quick else ["qwen2.5-7b", "qwen2.5-14b"]):
        for policy in ("resihp", "greyhound"):
            r = run_jobs(policy, n_jobs=n, iters=iters, model=model)
            out[f"{model}/{policy}"] = r
            rows.append((f"table5/{model}/{policy}/false_alarms",
                         round(r["avg_false_alarms"], 2),
                         f"acc={r['detection_accuracy']:.2f} ovh={r['total_detection_overhead_s']:.2f}s"))
    write_result("table5_false_alarms", out)
    return rows


if __name__ == "__main__":
    from benchmarks.common import emit

    emit(main())
