"""Table 5: false alarms, per-alarm overhead, fail-slow detection accuracy —
ResiHP (workload filter) vs Greyhound (no filter), over many short jobs with
fail-slow injected in ~half of them."""
from __future__ import annotations

import numpy as np

from benchmarks.common import sim_config, write_result
from repro.cluster.simulator import TrainingSim


def run_jobs(policy: str, *, n_jobs=12, iters=110, model="qwen2.5-7b", seed=0):
    rng = np.random.default_rng(seed)
    fa = vals = hits = injected = filtered = 0
    overhead = 0.0
    for j in range(n_jobs):
        cfg = sim_config(model, seed=seed * 100 + j)
        sim = TrainingSim(policy, cfg,
                          detector_kwargs={"workload_filter": policy == "resihp"})
        inject = j % 2 == 0
        if inject:
            injected += 1
            lo, hi = int(iters * 0.35), int(iters * 0.65)  # leave warm-up + response room
            it_at = int(rng.integers(lo, max(hi, lo + 1)))
            t_at = it_at * 0.8  # ~iteration period
            dev = int(rng.integers(0, cfg.n_devices))
            sev = float(rng.choice([0.3, 0.45, 0.6]))
            sim.inject_at(t_at, lambda c, now, d=dev, s=sev: c.fail_slow(d, s, now))
        sim.run(iters)
        st = sim.detector.stats
        fa += st.false_alarms
        vals += st.validations
        filtered += st.filtered_benign
        overhead += st.validation_overhead_s + st.filter_overhead_s
        if inject and any(r.kind == "fail-slow" for r in sim.detector.reports):
            hits += 1
    return {
        "policy": policy,
        "jobs": n_jobs,
        "injected": injected,
        "avg_false_alarms": fa / n_jobs,
        "validations": vals,
        "filtered_benign": filtered,
        "overhead_per_false_alarm_s": (overhead / fa) if fa else 0.0,
        "total_detection_overhead_s": overhead,
        "detection_accuracy": hits / max(injected, 1),
    }


def main(quick=False):
    n = 6 if quick else 12
    iters = 90 if quick else 110
    rows = []
    out = {}
    for model in (["qwen2.5-7b"] if quick else ["qwen2.5-7b", "qwen2.5-14b"]):
        for policy in ("resihp", "greyhound"):
            r = run_jobs(policy, n_jobs=n, iters=iters, model=model)
            out[f"{model}/{policy}"] = r
            rows.append((f"table5/{model}/{policy}/false_alarms",
                         round(r["avg_false_alarms"], 2),
                         f"acc={r['detection_accuracy']:.2f} ovh={r['total_detection_overhead_s']:.2f}s"))
    write_result("table5_false_alarms", out)
    return rows


if __name__ == "__main__":
    from benchmarks.common import emit

    emit(main())
