"""Table 5: false alarms, per-alarm overhead, fail-slow detection accuracy —
ResiHP (workload filter) vs Greyhound (no filter), over many short jobs with
fail-slow injected in ~half of them.

Extended with a ``resihp+lc`` row (the failure-lifecycle subsystem: slope
drift + carried baselines + debounced validation) and detection-latency
columns, so detector changes show up per-night in CI as false-alarm or
latency regressions (run with ``--quick`` in the nightly workflow)."""
from __future__ import annotations

from benchmarks.common import sim_config, write_result
from repro.cluster import scenarios
from repro.cluster.simulator import TrainingSim

# label -> (policy, policy kwargs, detector workload filter)
VARIANTS = {
    "resihp": ("resihp", {}, True),
    "resihp+lc": ("resihp", {"lifecycle": True}, True),
    "greyhound": ("greyhound", {}, False),
}


def run_jobs(variant: str, *, n_jobs=12, iters=110, model="qwen2.5-7b",
             seed=0):
    policy, policy_kwargs, filt = VARIANTS[variant]
    fa = vals = hits = injected = filtered = drift = 0
    overhead = 0.0
    latencies = []
    for j in range(n_jobs):
        cfg = sim_config(model, seed=seed * 100 + j)
        sim = TrainingSim(policy, cfg, policy_kwargs=policy_kwargs,
                          detector_kwargs={"workload_filter": filt})
        inject = j % 2 == 0
        inj_t = None
        if inject:
            injected += 1
            # random time in the mid-session window (leave warm-up + response
            # room), random device/severity — seeded per job (~0.8 s/iter)
            trace = sim.apply_scenario(scenarios.get(
                "table5_failslow",
                window=(iters * 0.35 * 0.8, iters * 0.65 * 0.8)))
            inj_t = trace[0].t
        sim.run(iters)
        st = sim.detector.stats
        fa += st.false_alarms
        vals += st.validations
        filtered += st.filtered_benign
        drift += st.drift_alarms
        overhead += st.validation_overhead_s + st.filter_overhead_s
        reports = [r for r in sim.detector.reports if r.kind == "fail-slow"]
        if inject and reports:
            hits += 1
            latencies.append(max(reports[0].time - inj_t, 0.0))
    return {
        "policy": variant,
        "jobs": n_jobs,
        "injected": injected,
        "avg_false_alarms": fa / n_jobs,
        "validations": vals,
        "filtered_benign": filtered,
        "drift_alarms": drift,
        "overhead_per_false_alarm_s": (overhead / fa) if fa else 0.0,
        "total_detection_overhead_s": overhead,
        "detection_accuracy": hits / max(injected, 1),
        "avg_detect_latency_s": (sum(latencies) / len(latencies)
                                 if latencies else None),
    }


def main(quick=False):
    n = 6 if quick else 12
    iters = 90 if quick else 110
    rows = []
    out = {}
    for model in (["qwen2.5-7b"] if quick else ["qwen2.5-7b", "qwen2.5-14b"]):
        for variant in VARIANTS:
            r = run_jobs(variant, n_jobs=n, iters=iters, model=model)
            out[f"{model}/{variant}"] = r
            lat = r["avg_detect_latency_s"]
            rows.append((f"table5/{model}/{variant}/false_alarms",
                         round(r["avg_false_alarms"], 2),
                         f"acc={r['detection_accuracy']:.2f}"
                         f" ovh={r['total_detection_overhead_s']:.2f}s"
                         + (f" lat={lat:.1f}s" if lat is not None else "")))
    write_result("table5_false_alarms", out)
    return rows


if __name__ == "__main__":
    import argparse

    from benchmarks.common import emit

    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    args = ap.parse_args()
    emit(main(quick=args.quick))
