"""Generate the EXPERIMENTS.md §Dry-run/§Roofline tables from results/.

    PYTHONPATH=src python -m benchmarks.make_roofline_tables [--dir results/dryrun2]
"""
from __future__ import annotations

import argparse
import glob
import json
from pathlib import Path

ARCH_ORDER = [
    "jamba-1.5-large-398b", "xlstm-1.3b", "qwen3-8b", "gemma3-1b", "gemma3-4b",
    "h2o-danube-1.8b", "qwen2-vl-7b", "whisper-medium", "grok-1-314b",
    "qwen3-moe-30b-a3b",
]
SHAPE_ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def fmt_s(x):
    return f"{x:.3f}" if x >= 0.001 else f"{x:.1e}"


def load(dirpath, pod="pod1"):
    recs = {}
    for f in glob.glob(f"{dirpath}/*__{pod}__baseline.json"):
        d = json.load(open(f))
        recs[(d["arch"], d["shape"])] = d
    return recs


def roofline_table(recs):
    lines = [
        "| arch | shape | compute s | memory s | collective s | bound | "
        "MODEL/HLO flops | roofline frac (base) | frac (optimized) |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    for a in ARCH_ORDER:
        for s in SHAPE_ORDER:
            d = recs.get((a, s))
            if d is None:
                continue
            if d.get("status") == "skipped":
                lines.append(f"| {a} | {s} | — | — | — | skip | — | — | — |")
                continue
            r = d["roofline"]
            o = d.get("roofline_optimized", {})
            lines.append(
                f"| {a} | {s} | {fmt_s(r['compute_s'])} | {fmt_s(r['memory_s'])} | "
                f"{fmt_s(r['collective_s'])} | {r['bound']} | "
                f"{r.get('useful_flops_ratio', 0):.2f} | "
                f"{r.get('roofline_fraction', 0):.4f} | "
                f"{o.get('roofline_fraction', 0):.4f} |")
    return "\n".join(lines)


def dryrun_table(recs, recs2):
    lines = [
        "| arch | shape | 1-pod compile | bytes/device (args+temps) | "
        "2-pod compile | collectives (1-pod, GB ring/device) |",
        "|---|---|---|---|---|---|",
    ]
    for a in ARCH_ORDER:
        for s in SHAPE_ORDER:
            d = recs.get((a, s))
            d2 = recs2.get((a, s))
            if d is None:
                continue
            if d.get("status") == "skipped":
                lines.append(f"| {a} | {s} | skip (per spec) | — | skip | — |")
                continue
            ma = d["memory_analysis"]
            per_dev = (ma["argument_bytes"] + ma["temp_bytes"]) / 1e9
            coll = d["walker"]["total_collective_bytes"] / 1e9
            ok2 = "OK" if (d2 or {}).get("status") == "ok" else (
                "skip" if (d2 or {}).get("status") == "skipped" else "?")
            lines.append(
                f"| {a} | {s} | OK ({d['compile_s']:.0f}s) | {per_dev:.2f} GB | "
                f"{ok2} | {coll:.1f} |")
    return "\n".join(lines)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="results/dryrun2")
    ap.add_argument("--pod2-dir", default="results/dryrun")
    args = ap.parse_args()
    recs = load(args.dir, "pod1")
    recs2 = load(args.pod2_dir, "pod2")
    print("## Roofline (single pod, 256 chips, v5e)\n")
    print(roofline_table(recs))
    print("\n## Dry-run\n")
    print(dryrun_table(recs, recs2))


if __name__ == "__main__":
    main()
