"""Table 6: average throughput (samples/s) under increasing fail-stop
frequency — ResiHP vs ReCycle vs Oobleck, six models, three frequencies.

Time-scaled: sessions of ~400 iterations with monotonic worker terminations
every {1/8, 1/12, 1/16} of the session (the paper's 2h/1h/30m over 4-16h
sessions => ~2-16 failures; the '30m' setting terminates workers until ~50%
of the cluster is gone)."""
from __future__ import annotations

from benchmarks.common import MODELS, sim_config, write_result
from repro.cluster import scenarios
from repro.cluster.simulator import TrainingSim

FREQS = {"2h": 8, "1h": 12, "30m": 16}  # failures per session


def run(model: str, policy: str, n_failures: int, *, iters=400, seed=0):
    cfg = sim_config(model, seed=seed)
    sim = TrainingSim(policy, cfg)
    if n_failures:
        # monotonic terminations over the session (1 iter ~ 0.8 s sim-time)
        sim.apply_scenario(scenarios.get(
            "table6_failstop", span=iters * 0.8, n_failures=n_failures))
    sim.run(iters)
    return {
        "throughput": sim.avg_throughput(skip=2),
        "aborted": sim.aborted,
        "iters_done": len(sim.trace),
    }


def main(quick=False):
    models = ["llama2-7b", "llama2-13b"] if quick else [
        "llama2-7b", "llama2-13b", "llama2-30b",
        "qwen2.5-7b", "qwen2.5-14b", "qwen2.5-32b",
    ]
    iters = 200 if quick else 400
    out, rows = {}, []
    for model in models:
        ff = run(model, "resihp", 0, iters=iters)["throughput"]
        out[f"{model}/fault-free"] = ff
        rows.append((f"table6/{model}/fault-free", round(ff, 2), ""))
        for freq, n_fail in FREQS.items():
            for policy in ("oobleck", "recycle", "resihp"):
                r = run(model, policy, n_fail, iters=iters)
                key = f"{model}/{policy}/{freq}"
                out[key] = r
                val = "-" if r["aborted"] else round(r["throughput"], 2)
                rows.append((f"table6/{key}", val,
                             f"frac_of_ff={0 if r['aborted'] else r['throughput']/ff:.2f}"))
    write_result("table6_failstop", out)
    return rows


if __name__ == "__main__":
    from benchmarks.common import emit

    emit(main())
