"""Fig. 13: system overhead — Detector per-iteration tax, Scheduler planning
time (measured, real code), communication-group reconstruction (measured
engine apply_plan), layer-transfer volume/time during reconfiguration.

Also refits the :class:`~repro.core.scheduler.scheduler.PlanOverheadModel`
planning-cost curve (the deterministic replacement for charging measured
wall-clock into simulated time, ``ResiHPPolicy(plan_overhead_model=...)``)
against the fresh measurements and reports both the fit error and the drift
of the checked-in default coefficients — so the model cannot silently rot as
the Scheduler changes."""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import MODELS, sim_config, write_result
from repro.core.recovery import transfer_plan
from repro.core.scheduler.plan import initial_plan
from repro.core.scheduler.scheduler import PlanOverheadModel, Scheduler


def planning_overhead(model: str, *, n=20, seed=0):
    """Measured Scheduler.adapt wall time at the paper's scale."""
    scale, n_layers = MODELS[model]
    cfg = sim_config(model, seed=seed)
    plan = initial_plan(cfg.n_layers, cfg.dp, cfg.pp, cfg.tp,
                        microbatches=cfg.n_microbatches)
    # plan cache off: at the small scales the random failure signatures
    # collide often, and a cache hit would put a ~microsecond sample into
    # the medians this benchmark exists to measure honestly
    sch = Scheduler(layer_costs=[1.0] * cfg.n_layers, plan_cache_size=0)
    rng = np.random.default_rng(seed)
    times = []
    for i in range(n):
        speeds = {d: 1.0 for d in plan.devices}
        speeds[int(rng.integers(0, cfg.n_devices))] = 0.0
        speeds[int(rng.integers(0, cfg.n_devices))] = 0.5
        t0 = time.perf_counter()
        sch.adapt(plan, speeds)
        times.append(time.perf_counter() - t0)
    return float(np.median(times))


def layer_transfer(model_arch_id: str, *, seed=0):
    """Fig. 13 right: bytes/seconds of layer movement on reconfiguration,
    using the real arch configs (full size — byte math only)."""
    from repro.configs import get_arch

    cfg = get_arch(model_arch_id)
    plan = initial_plan(cfg.n_layers, 2, 4, 4)
    sch = Scheduler(layer_costs=[1.0] * cfg.n_layers)
    speeds = {d: 1.0 for d in plan.devices}
    speeds[plan.replicas[0].stages[1].devices[0]] = 0.0
    ad = sch.adapt(plan, speeds)
    tp = transfer_plan(cfg, plan, ad.plan, dead_stages=ad.dead_stages)
    return {"moves": len(tp.moves), "bytes": tp.total_bytes,
            "seconds_at_25GBps": tp.seconds()}


def group_reconstruction(*, seed=0):
    """Measured PipelineEngine.apply_plan (mesh + placement rebuild)."""
    import jax

    from repro.configs import get_arch, reduced
    from repro.engine.pipeline import PipelineEngine

    cfg = reduced(get_arch("qwen3-8b"), n_layers=4)
    plan = initial_plan(4, dp=2, pp=2, tp=1, microbatches=2)
    eng = PipelineEngine(cfg, plan, optimizer=None, seed=seed)
    sch = Scheduler(layer_costs=[1.0] * 4)
    speeds = {d: 1.0 for d in plan.devices}
    speeds[1] = 0.0
    ad = sch.adapt(plan, speeds)
    t0 = time.perf_counter()
    eng.apply_plan(ad.plan)
    return time.perf_counter() - t0


def main(quick=False):
    out, rows = {}, []
    models = ["qwen2.5-7b", "qwen2.5-14b", "qwen2.5-32b"]
    samples = []
    for m in models:
        t = planning_overhead(m, n=8 if quick else 20)
        out[f"planning/{m}"] = t
        cfg = sim_config(m)
        samples.append((cfg.n_devices, cfg.n_layers, t))
        rows.append((f"fig13/planning_s/{m}", round(t, 4), "measured"))
    # modeled planning-cost curve: refit on the fresh measurements and report
    # the drift of the checked-in default coefficients
    fitted = PlanOverheadModel.fit(samples)
    default = PlanOverheadModel()
    drift = max(abs(fitted.predict(d, layers) - default.predict(d, layers))
                / max(fitted.predict(d, layers), 1e-12)
                for d, layers, _ in samples)
    out["plan_overhead_model"] = {
        "coef": fitted.coef, "intercept": fitted.intercept,
        "fit_mape": fitted.fit_mape, "default_drift": drift,
    }
    rows.append(("fig13/plan_overhead_model",
                 f"{fitted.coef:.3f}",
                 f"intercept={fitted.intercept:.3f} "
                 f"mape={fitted.fit_mape:.1%} default_drift={drift:.1%}"))
    for arch in ["qwen3-8b", "qwen3-moe-30b-a3b"] + ([] if quick else ["grok-1-314b"]):
        r = layer_transfer(arch)
        out[f"layer_transfer/{arch}"] = r
        rows.append((f"fig13/layer_transfer_s/{arch}",
                     round(r["seconds_at_25GBps"], 2),
                     f"{r['bytes']/1e9:.2f} GB {r['moves']} moves"))
    g = group_reconstruction()
    out["group_reconstruction_s"] = g
    rows.append(("fig13/group_reconstruction_s", round(g, 4), "measured engine"))
    out["detector_tax"] = 0.013
    rows.append(("fig13/detector_tax", 0.013, "per-iteration fraction (cfg)"))
    write_result("fig13_overhead", out)
    return rows


if __name__ == "__main__":
    from benchmarks.common import emit

    emit(main())
