"""Kernel-level counterpart of the Eq. 1 cost model: the packed flash
attention kernel's executed-tile fraction tracks sum(l_i^2)/N^2 across
packing mixes — the mechanism that makes attention cost proportional to
sum(l^2) rather than N^2 on TPU."""
from __future__ import annotations

import numpy as np

from benchmarks.common import write_result
from repro.data.packing import pack_documents, quadratic_cost
from repro.kernels.packed_flash_attn import skipped_block_fraction

import jax.numpy as jnp


def _pack(S, doc_lens):
    seg = np.zeros((1, S), np.int32)
    pos = np.zeros((1, S), np.int32)
    off = 0
    for i, l in enumerate(doc_lens):
        seg[0, off: off + l] = i + 1
        pos[0, off: off + l] = np.arange(l)
        off += l
    return jnp.asarray(seg), jnp.asarray(pos)


def main(quick=False):
    S = 2048 if quick else 4096
    bq = bk = 128
    mixes = {
        "one_doc": [S],
        "two_docs": [S // 2] * 2,
        "four_docs": [S // 4] * 4,
        "eight_docs": [S // 8] * 8,
        "long_tail": [S // 2] + [S // 8] * 3 + [S // 16] * 2,
    }
    out, rows = {}, []
    for name, lens in mixes.items():
        seg, pos = _pack(S, lens)
        skipped = skipped_block_fraction(seg, pos, bq, bk, causal=True)
        executed = 1.0 - skipped
        l2_ratio = quadratic_cost(lens) / (S * S)
        # causal lower triangle of each doc: visible work ~ l2/2 of full grid
        out[name] = {"executed_tile_fraction": executed,
                     "sum_l2_over_N2": l2_ratio,
                     "ideal_causal_fraction": l2_ratio / 2}
        rows.append((f"kernel/exec_tiles/{name}", round(executed, 4),
                     f"sum_l2/N^2={l2_ratio:.4f} ideal={l2_ratio/2:.4f}"))
    # monotonicity: executed fraction tracks sum l^2
    execs = [out[n]["executed_tile_fraction"] for n in
             ("one_doc", "two_docs", "four_docs", "eight_docs")]
    assert execs == sorted(execs, reverse=True), execs
    write_result("kernel_blockskip", out)
    return rows


if __name__ == "__main__":
    from benchmarks.common import emit

    emit(main())
