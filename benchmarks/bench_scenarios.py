"""Scenario sweep: failure families no paper figure covers — correlated rack
storms, transient flap-then-recover cycles, slow-ramp straggler mixes, a
Poisson background storm and degraded rejoins — ResiHP (with and without the
failure-lifecycle subsystem) vs the strengthened baselines.

These stress exactly the behaviors the fleet literature reports (ByteDance's
correlated infra faults, ElasWave's elastic rejoin) and that the Fig. 9-14
protocols never exercise: co-located simultaneous fail-stops, devices that
bounce between dead and healthy, degradations that creep in over minutes
instead of arriving as a step, and repaired devices that return below peak.

``resihp+lc`` is ResiHP with ``ResiHPPolicy(lifecycle=...)`` enabled (flap
quarantine + ramp-aware drift + rejoin admission — default-off elsewhere);
its rows carry the lifecycle columns (validations, false alarms, quarantines,
probes) so detector regressions are visible next to throughput.
"""
from __future__ import annotations

from benchmarks.common import sim_config, write_result
from repro.cluster import scenarios
from repro.cluster.simulator import TrainingSim

SWEEP = {
    # name -> overrides factory(span) applied at run time
    "rack_storm": lambda span: scenarios.get(
        "rack_storm", at=0.15 * span, recover_after=0.5 * span),
    "flapping_stragglers": lambda span: scenarios.get(
        "flapping_stragglers", span=span),
    "slow_ramp_mix": lambda span: scenarios.get("slow_ramp_mix", span=span),
    "poisson_storm": lambda span: scenarios.get(
        "poisson_storm", rate=4.0 / span, t_end=span, mttr=0.25 * span),
    "degraded_rejoins": lambda span: scenarios.get(
        "degraded_rejoins", span=span),
}

# policy label -> (policy name, policy kwargs); the lifecycle runs are the
# only place the default-off ResiHPPolicy(lifecycle=...) switch is on
POLICIES = {
    "resihp": ("resihp", {}),
    "resihp+lc": ("resihp", {"lifecycle": True}),
    "recycle+": ("recycle+", {}),
    "oobleck+": ("oobleck+", {}),
}


def run(model: str, scenario_name: str, policy: str, *, iters=160, seed=0,
        engine="fast", scale=None):
    cfg = sim_config(model, seed=seed, scale=scale)
    name, policy_kwargs = POLICIES[policy]
    sim = TrainingSim(name, cfg, engine=engine, policy_kwargs=policy_kwargs)
    span = iters * 0.8
    trace = sim.apply_scenario(SWEEP[scenario_name](span))
    sim.run(iters, stop_on_abort=False)
    st = sim.detector.stats
    out = {
        "throughput": sim.avg_throughput(skip=2),
        "aborted": sim.aborted,
        "n_events": len(trace),
        "events": trace.as_tuples(),
        "detector": st.as_dict(),
    }
    if sim.lifecycle is not None:
        out["lifecycle"] = sim.lifecycle.stats.as_dict()
    return out


def main(quick=False, engine="fast"):
    models = ["llama2-13b"] if quick else ["llama2-13b", "llama2-30b"]
    iters = 80 if quick else 160
    out, rows = {}, []
    for model in models:
        for sc in SWEEP:
            rs = {p: run(model, sc, p, iters=iters, engine=engine)
                  for p in POLICIES}
            out[f"{model}/{sc}"] = rs
            resi = rs["resihp"]["throughput"]
            for p, r in rs.items():
                t = r["throughput"]
                det = r["detector"]
                if p == "resihp+lc":
                    lc = r.get("lifecycle", {})
                    derived = (f"vals={det['validations']}"
                               f" fa={det['false_alarms']}"
                               f" quar={lc.get('quarantines', 0)}"
                               f" probes={lc.get('probes', 0)}")
                elif p == "resihp":
                    derived = (f"n_events={r['n_events']}"
                               f" vals={det['validations']}"
                               f" fa={det['false_alarms']}")
                else:
                    derived = f"resihp_speedup={resi / max(t, 1e-9):.2f}x"
                rows.append((
                    f"scenarios/{model}/{sc}/{p}",
                    "-" if r["aborted"] else round(t, 2),
                    derived))
    write_result("scenarios_sweep", out)
    return rows


if __name__ == "__main__":
    import argparse

    from benchmarks.common import emit

    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--engine", choices=("python", "fast"), default="fast")
    args = ap.parse_args()
    emit(main(quick=args.quick, engine=args.engine))
