"""Scenario sweep: failure families no paper figure covers — correlated rack
storms, transient flap-then-recover cycles, slow-ramp straggler mixes, a
Poisson background storm, degraded rejoins and the per-device hazard
families (aging fleets, lemon tails, infant mortality) — ResiHP (with and
without the failure-lifecycle / hazard subsystems) vs the strengthened
baselines.

These stress exactly the behaviors the fleet literature reports (ByteDance's
correlated infra faults, ElasWave's elastic rejoin, per-device age-dependent
MTTF) and that the Fig. 9-14 protocols never exercise: co-located
simultaneous fail-stops, devices that bounce between dead and healthy,
degradations that creep in over minutes instead of arriving as a step,
repaired devices that return below peak, and failures that *recur* on the
same worn parts.

``resihp+lc`` is ResiHP with ``ResiHPPolicy(lifecycle=...)`` enabled (flap
quarantine + ramp-aware drift + rejoin admission — default-off elsewhere);
``resihp+hz`` adds ``ResiHPPolicy(hazard=...)`` on top (hazard-keyed
quarantine + risk-aware placement): the risk-aware planner, against
``resihp+lc`` as the hazard-blind reference. ``resihp+ntp`` is ResiHP with
``ResiHPPolicy(ntp=...)`` enabled (nonuniform TP shard widths): shrink-shard
competes with Eq. 4 exclusion per affected group, against plain ``resihp``
as the exclusion-only reference — its signature win is the
``thermal_throttle_fleet`` many-mild-stragglers family. ``resihp+dom`` is
ResiHP with ``ResiHPPolicy(domains=...)`` enabled (pooled domain-level
quarantine + domain-spread placement + checkpoint/restart economics),
against ``resihp+hz`` as the domain-blind reference — its signature win is
the ``pdu_brownout`` correlated-rack family. Rows carry the lifecycle /
detector columns (validations, false alarms, quarantines, probes) plus the
session throughput (samples per second of *elapsed* time, reconfiguration
and stall charges included) — the metric a repeat-offender's
reconfiguration storm actually hurts, and the one the hazard policies win
on ``aging_fleet``.
"""
from __future__ import annotations

from benchmarks.common import sim_config, write_result
from repro.cluster import scenarios
from repro.cluster.simulator import TrainingSim

SWEEP = {
    # name -> overrides factory(span) applied at run time
    "rack_storm": lambda span: scenarios.get(
        "rack_storm", at=0.15 * span, recover_after=0.5 * span),
    "flapping_stragglers": lambda span: scenarios.get(
        "flapping_stragglers", span=span),
    "slow_ramp_mix": lambda span: scenarios.get("slow_ramp_mix", span=span),
    "poisson_storm": lambda span: scenarios.get(
        "poisson_storm", rate=4.0 / span, t_end=span, mttr=0.25 * span),
    "degraded_rejoins": lambda span: scenarios.get(
        "degraded_rejoins", span=span),
    # many-mild-stragglers family (fleet thermal/power capping): the NTP
    # shrink-shard vs exclusion stress case — every group keeps running, so
    # planning k*min(p) vs efficiency*sum(p) is the whole difference
    "thermal_throttle_fleet": lambda span: scenarios.get(
        "thermal_throttle_fleet", span=span),
    # per-device hazard families (PR 4): age-dependent MTTF, repeat offenders
    "aging_fleet": lambda span: scenarios.get("aging_fleet", span=span),
    "lemon_devices": lambda span: scenarios.get("lemon_devices", span=span),
    "infant_mortality": lambda span: scenarios.get(
        "infant_mortality", span=span),
    # mined adversarial family (tools/mine_scenarios.py): the worst found
    # cases become permanent sweep rows so policy changes can't silently
    # regress on them (timelines rescale to the cell's span and remap to
    # the cell's topology — see AdversarialScenario)
    "adversarial_1": lambda span: scenarios.get("adversarial_1", span=span),
    "adversarial_2": lambda span: scenarios.get("adversarial_2", span=span),
    "adversarial_3": lambda span: scenarios.get("adversarial_3", span=span),
    # correlated failure-domain families (PR 9): a browned-out PDU whose
    # residents fail-stop again and again (the pooled DomainEstimator's
    # signature win), a leaf switch dragging every attached node's links,
    # and an orchestrator restart wave marching through the fleet
    "pdu_brownout": lambda span: scenarios.get("pdu_brownout", span=span),
    "switch_degrade": lambda span: scenarios.get("switch_degrade", span=span),
    "restart_storm": lambda span: scenarios.get("restart_storm", span=span),
}

# policy label -> (policy name, policy kwargs); the lifecycle/hazard runs are
# the only place the default-off ResiHPPolicy(lifecycle=/hazard=) switches
# are on. The resihp rows pin the planning charge to the deterministic
# PlanOverheadModel (instead of measured wall clock) so every sweep cell is
# a pure function of its (model, scenario, policy, seed) coordinates — the
# property the parallel orchestrator's byte-identical merge contract
# (benchmarks/sweep.py) rests on.
POLICIES = {
    "resihp": ("resihp", {"plan_overhead_model": True}),
    "resihp+lc": ("resihp", {"lifecycle": True, "plan_overhead_model": True}),
    "resihp+hz": ("resihp", {"hazard": True, "plan_overhead_model": True}),
    # nonuniform TP shard widths (default-off ResiHPPolicy(ntp=) switch):
    # shrink-shard competes with Eq. 4 exclusion per affected group
    "resihp+ntp": ("resihp", {"ntp": True, "plan_overhead_model": True}),
    # correlated-failure-domain awareness (default-off ResiHPPolicy(domains=)
    # switch): pooled domain quarantine + domain-spread placement + restart
    # economics, against resihp+hz as the domain-blind risk-aware reference
    "resihp+dom": ("resihp", {"domains": True, "plan_overhead_model": True}),
    # unified credit score (default-off ResiHPPolicy(credit=) switch): one
    # fitted health scalar behind quarantine bands, banded/async admission,
    # credit-gated NTP shrink retention, credit-aware placement and
    # restart weighting — the fitted policy measured against *every*
    # hand-tuned resihp column above (vs_best in derive_rows)
    "resihp+credit": ("resihp", {"credit": True, "ntp": True,
                                 "plan_overhead_model": True}),
    "recycle+": ("recycle+", {}),
    "oobleck+": ("oobleck+", {}),
}

# the hand-tuned resihp policy columns the fitted credit row must dominate
# (tools/fit_credit.py's per-family baseline = the best of these)
CREDIT_BASELINES = ("resihp", "resihp+lc", "resihp+hz", "resihp+ntp",
                    "resihp+dom")


def run(model: str, scenario_name: str, policy: str, *, iters=160, seed=0,
        engine="fast", scale=None, full=False):
    """One sweep cell. ``full=True`` keeps the per-cell event timeline in the
    result (16k+ lines of JSON across the grid — debugging/replay payload);
    the default keeps only the summary rows the tests and docs consume."""
    cfg = sim_config(model, seed=seed, scale=scale)
    name, policy_kwargs = POLICIES[policy]
    sim = TrainingSim(name, cfg, engine=engine, policy_kwargs=policy_kwargs)
    span = iters * 0.8
    trace = sim.apply_scenario(SWEEP[scenario_name](span))
    sim.run(iters, stop_on_abort=False)
    st = sim.detector.stats
    out = {
        "throughput": sim.avg_throughput(skip=2),
        "session_throughput": sim.session_throughput(skip=2),
        "aborted": sim.aborted,
        "n_events": len(trace),
        "detector": st.as_dict(),
    }
    if full:
        out["events"] = trace.as_tuples()
    if sim.lifecycle is not None:
        out["lifecycle"] = sim.lifecycle.stats.as_dict()
    if getattr(sim, "credit_model", None) is not None:
        # separate from the lifecycle dict: LifecycleStats feeds every
        # pre-credit sweep cell's JSON and must not grow fields
        out["credit"] = sim.credit_model.stats.as_dict()
    return out


def derive_rows(key_prefix: str, rs: dict) -> list:
    """CSV rows for one scenario cell's policy->result dict (shared with the
    parallel orchestrator so both emit identical summaries)."""
    rows = []
    resi = rs.get("resihp", {}).get("throughput", 0.0)
    for p, r in rs.items():
        t = r["throughput"]
        det = r["detector"]
        sess = f"sess={r['session_throughput']:.2f}"
        if p == "resihp+lc":
            lc = r.get("lifecycle", {})
            derived = (f"vals={det['validations']}"
                       f" fa={det['false_alarms']}"
                       f" quar={lc.get('quarantines', 0)}"
                       f" probes={lc.get('probes', 0)} {sess}")
        elif p == "resihp+hz":
            lc = r.get("lifecycle", {})
            blind = rs.get("resihp+lc", {}).get("session_throughput", 0.0)
            vs = (f"{r['session_throughput'] / blind:.2f}x" if blind > 0
                  else "n/a")  # reference row absent in a sub-sweep
            derived = (f"quar={lc.get('quarantines', 0)}"
                       f" deferred={lc.get('rejoins_deferred', 0)}"
                       f" {sess}"
                       f" vs_blind={vs}")
        elif p == "resihp+dom":
            # the domain-awareness comparison: pooled rack benching +
            # restart economics vs per-device risk only (>1.00x = domain
            # pooling wins; its signature family is pdu_brownout)
            lc = r.get("lifecycle", {})
            hz = rs.get("resihp+hz", {}).get("session_throughput", 0.0)
            vs = (f"{r['session_throughput'] / hz:.2f}x" if hz > 0
                  else "n/a")
            derived = (f"quar={lc.get('quarantines', 0)}"
                       f" {sess}"
                       f" vs_hz={vs}")
        elif p == "resihp+credit":
            # the unified-scalar comparison: the fitted credit policy vs the
            # best hand-tuned resihp column on this scenario (>=1.00x = one
            # fitted scalar matches per-family threshold tuning)
            cr = r.get("credit", {})
            best = max((rs[b]["session_throughput"]
                        for b in CREDIT_BASELINES if b in rs), default=0.0)
            vs = (f"{r['session_throughput'] / best:.2f}x" if best > 0
                  else "n/a")
            derived = (f"direct={cr.get('direct_admits', 0)}"
                       f" async={cr.get('async_admissions', 0)}"
                       f" quar={cr.get('quarantines', 0)}"
                       f" {sess}"
                       f" vs_best={vs}")
        elif p == "resihp+ntp":
            # the adaptation-axis comparison: shrink-shard vs exclusion-only
            # planning on the same scenario (>1.00x = NTP wins)
            derived = (f"{sess}"
                       f" vs_excl={t / max(resi, 1e-9):.2f}x")
        elif p == "resihp":
            derived = (f"n_events={r['n_events']}"
                       f" vals={det['validations']}"
                       f" fa={det['false_alarms']} {sess}")
        else:
            derived = f"resihp_speedup={resi / max(t, 1e-9):.2f}x"
        rows.append((f"{key_prefix}/{p}",
                     "-" if r["aborted"] else round(t, 2),
                     derived))
    return rows


# the hazard families model slow per-device renewal dynamics (lemon repair/
# re-fail cycles, quarantine backoffs): they need the full 160-iteration
# session to play out, so they keep it even in --quick mode (still seconds
# of wall clock on the fast engine). pdu_brownout rides with them: its
# bench-the-rack-then-hold arc needs the same full session.
HAZARD_SCENARIOS = ("aging_fleet", "lemon_devices", "infant_mortality",
                    "pdu_brownout")


def main(quick=False, engine="fast", full=False, scales=None, iters=None):
    """Serial scenario sweep. ``scales`` is an optional list of Table-3
    parallelism presets (``None`` = the model's native one) reusing the
    parallel orchestrator's plumbing: cells run via ``run(scale=...)`` and
    keys gain an ``@scale`` level (``@native`` for None) only when the grid
    actually spans more than one scale — a single-scale sweep's keys stay
    byte-identical to the pre-axis artifact. ``iters`` overrides the
    quick/full iteration count (hazard families included)."""
    from benchmarks.common import TABLE3

    for s in scales or ():
        assert s is None or s in TABLE3, (s, sorted(TABLE3))
    scales = tuple(scales) if scales else (None,)
    multi_scale = len(set(scales)) > 1
    models = ["llama2-13b"] if quick else ["llama2-13b", "llama2-30b"]
    default_iters = 80 if quick else 160
    out, rows = {}, []
    for model in models:
        for scale in scales:
            for sc in SWEEP:
                sc_iters = iters if iters is not None else (
                    160 if sc in HAZARD_SCENARIOS else default_iters)
                rs = {p: run(model, sc, p, iters=sc_iters, engine=engine,
                             scale=scale, full=full)
                      for p in POLICIES}
                key = f"{model}/{sc}"
                if multi_scale:
                    key = f"{key}@{scale or 'native'}"
                out[key] = rs
                rows += derive_rows(f"scenarios/{key}", rs)
    write_result("scenarios_sweep", out)
    return rows


if __name__ == "__main__":
    import argparse

    from benchmarks.common import emit

    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--engine", choices=("python", "fast"), default="fast")
    ap.add_argument("--full", action="store_true",
                    help="keep per-cell event timelines in the JSON "
                         "(large); default keeps summary rows only")
    ap.add_argument("--scales", type=str, default=None,
                    help="comma-separated Table-3 scale presets, e.g. "
                         "'native,1k,16k' — same plumbing as sweep.py "
                         "(default: native only, no @scale key level)")
    ap.add_argument("--iters", type=int, default=None,
                    help="override the per-cell iteration count "
                         "(hazard families included)")
    args = ap.parse_args()
    scales = None
    if args.scales:
        scales = [None if s == "native" else s
                  for s in args.scales.split(",")]
    emit(main(quick=args.quick, engine=args.engine, full=args.full,
              scales=scales, iters=args.iters))
