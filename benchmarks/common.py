"""Shared benchmark scaffolding: paper Table 3 model/parallelism settings
(time-scaled for the CPU container), result I/O, CSV emission.

Time scaling: the paper injects failures every 2h/1h/30m over 4-16h sessions
(~8-16 failures per run). Simulated time is virtual, so we preserve the
*ratios*: sessions of N iterations with failures every N/8 .. N/16 iterations.
"""
from __future__ import annotations

import json
import time
from pathlib import Path

from repro.cluster.simulator import SimConfig

RESULTS = Path(__file__).resolve().parent.parent / "results"

# paper Table 3: scale -> (TP, DP, PP); layer counts per model family.
# The 1k/2k/4k/8k/16k rows extend the paper's 256-GPU Fig. 14 point to the
# fleet scales the related literature reports (ByteDance, SPARe, Meta's
# 100k+-GPU HSDP runs); they are reachable in reasonable wall-clock only
# with the fast simulator engine + array-native cluster core.
TABLE3 = {
    "small": (4, 2, 2),
    "medium": (4, 2, 4),
    "large": (4, 2, 8),
    "xlarge": (4, 4, 16),
    "1k": (4, 8, 32),     # 1024 devices
    "2k": (4, 16, 32),    # 2048 devices
    "4k": (8, 16, 32),    # 4096 devices
    "8k": (8, 32, 32),    # 8192 devices
    "16k": (8, 64, 32),   # 16384 devices
    "32k": (8, 128, 32),  # 32768 devices
    "100k": (8, 400, 32),  # 102400 devices — the Meta/SPARe production regime
}
MODELS = {
    "llama2-7b": ("small", 32),
    "llama2-13b": ("medium", 40),
    "llama2-30b": ("large", 60),
    "qwen2.5-7b": ("small", 28),
    "qwen2.5-14b": ("medium", 48),
    "qwen2.5-32b": ("large", 64),
    "llama2-70b": ("xlarge", 80),
}


def sim_config(model: str, *, seq_len=8192, n_mb=8, noise=0.01, seed=0,
               scale=None) -> SimConfig:
    """Table-3 SimConfig for ``model``; ``scale`` overrides the model's
    native parallelism preset (e.g. ``"1k"`` to run llama2-70b layer costs
    on a 1024-device cluster)."""
    native_scale, n_layers = MODELS[model]
    tp, dp, pp = TABLE3[scale or native_scale]
    return SimConfig(dp=dp, pp=pp, tp=tp, n_layers=n_layers,
                     n_microbatches=n_mb, seq_len=seq_len, noise=noise,
                     seed=seed)


def peak_rss_mb() -> float:
    """Process peak resident set in MiB (``ru_maxrss``) — a monotone
    high-water mark over the whole process, so per-row readings in a multi-
    row benchmark bound each row's footprint from above (the first row that
    *raises* the reading is the one that needed the memory)."""
    import resource
    kb = resource.getrusage(resource.RUSAGE_SELF).ru_maxrss
    return round(kb / 1024.0, 1)


def write_result(name: str, payload: dict):
    RESULTS.mkdir(parents=True, exist_ok=True)
    out = RESULTS / f"{name}.json"
    out.write_text(json.dumps(payload, indent=2, default=str))
    return out


class Timer:
    def __enter__(self):
        self.t0 = time.perf_counter()
        return self

    def __exit__(self, *a):
        self.seconds = time.perf_counter() - self.t0


def emit(rows, header=("name", "value", "derived")):
    print(",".join(header))
    for r in rows:
        print(",".join(str(x) for x in r))
