"""Fig. 9: Scheduler effectiveness under weak/medium/severe fail-slow —
ResiHP vs Greyhound vs Adaptra vs unmitigated, two pipeline scales."""
from __future__ import annotations

from benchmarks.common import sim_config, write_result
from repro.cluster import scenarios
from repro.cluster.simulator import TrainingSim

# severities tuned so the *unmitigated* drop matches the paper's ~35/55/70%
SEVERITY = {"weak": 0.62, "medium": 0.42, "severe": 0.28}


def run(model: str, policy: str, factor: float, *, iters=140, seed=0):
    cfg = sim_config(model, seed=seed)
    sim = TrainingSim(policy, cfg)
    if factor < 1.0:
        sim.apply_scenario(scenarios.get("fig9_failslow", factor=factor))
    sim.run(iters)
    return sim.avg_throughput(skip=2)


def main(quick=False):
    models = ["llama2-13b"] if quick else ["llama2-13b", "qwen2.5-32b"]
    iters = 90 if quick else 140
    out, rows = {}, []
    for model in models:
        ff = run(model, "resihp", 1.0, iters=iters)
        out[f"{model}/fault-free"] = ff
        for sev, factor in SEVERITY.items():
            base = run(model, "recycle", factor, iters=iters)  # no mitigation
            out[f"{model}/{sev}/unmitigated"] = base
            for policy in ("adaptra", "greyhound", "resihp"):
                th = run(model, policy, factor, iters=iters)
                out[f"{model}/{sev}/{policy}"] = th
                rows.append((
                    f"fig9/{model}/{sev}/{policy}", round(th, 2),
                    f"x_over_unmitigated={th/base:.2f} frac_ff={th/ff:.2f}"))
    write_result("fig9_failslow", out)
    return rows


if __name__ == "__main__":
    from benchmarks.common import emit

    emit(main())
