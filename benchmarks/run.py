"""Benchmark driver: one module per paper table/figure. Prints a
``name,value,derived`` CSV and writes JSON per benchmark to results/.

  PYTHONPATH=src python -m benchmarks.run [--quick] [--only fig9,fig10]
"""
from __future__ import annotations

import argparse
import importlib
import time
import traceback

BENCHES = [
    ("table4", "benchmarks.bench_table4_mape"),
    ("table5", "benchmarks.bench_table5_false_alarms"),
    ("table6", "benchmarks.bench_table6_failstop"),
    ("fig2", "benchmarks.bench_fig2_amplification"),
    ("fig9", "benchmarks.bench_fig9_failslow"),
    ("fig10", "benchmarks.bench_fig10_mixed"),
    ("fig11", "benchmarks.bench_fig11_ablation"),
    ("fig12", "benchmarks.bench_fig12_convergence"),
    ("fig13", "benchmarks.bench_fig13_overhead"),
    ("fig14", "benchmarks.bench_fig14_largescale"),
    ("kernel", "benchmarks.bench_kernel_blockskip"),
    ("scenarios", "benchmarks.bench_scenarios"),
    ("simcore", "benchmarks.bench_simcore"),
]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--only", default=None, help="comma-separated bench keys")
    ap.add_argument("--skip", default=None,
                    help="comma-separated bench keys to leave out (e.g. "
                         "when a dedicated CI step runs them separately)")
    args = ap.parse_args()
    only = set(args.only.split(",")) if args.only else None
    skip = set(args.skip.split(",")) if args.skip else set()

    print("name,value,derived")
    failures = []
    for key, module in BENCHES:
        if (only and key not in only) or key in skip:
            continue
        t0 = time.time()
        try:
            mod = importlib.import_module(module)
            rows = mod.main(quick=args.quick)
            for r in rows:
                print(",".join(str(x) for x in r), flush=True)
            print(f"# {key} done in {time.time()-t0:.0f}s", flush=True)
        except Exception as e:  # noqa: BLE001
            failures.append((key, e))
            traceback.print_exc()
            print(f"# {key} FAILED: {e}", flush=True)
    if failures:
        raise SystemExit(f"{len(failures)} benchmark(s) failed: "
                         f"{[k for k, _ in failures]}")


if __name__ == "__main__":
    main()
