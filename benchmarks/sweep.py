"""Parallel scenario-sweep orchestrator: fan the scenario x policy x seed
grid across worker processes.

``bench_scenarios`` runs its grid strictly serially — fine for one model at
80 iterations, a wall-clock throttle for the ROADMAP's "as many scenarios as
you can imagine" goal. Every sweep cell is embarrassingly parallel and, with
the resihp rows pinned to the deterministic :class:`PlanOverheadModel`
planning charge, a pure function of its coordinates — so the orchestrator
can schedule cells on any worker in any order and still merge the exact
bytes the serial path produces:

* **deterministic per-cell seeding** — each cell builds its own ``SimConfig``
  from the cell's ``seed`` coordinate; no RNG state is shared between cells,
  so worker assignment and completion order cannot leak into results;
* **byte-identical merge** — results are keyed by cell coordinates and
  assembled in canonical grid order (models, then scenarios, then seeds,
  then policies) regardless of which worker finished first;
  ``--workers 1`` / ``--serial`` is the in-process reference path, and
  ``tests/test_sweep.py`` pins parallel == serial byte-for-byte and
  worker-count invariance.

Usage:

    PYTHONPATH=src python -m benchmarks.sweep [--workers N] [--serial]
        [--quick] [--full] [--seeds K] [--engine fast|python]
        [--scenarios a,b] [--policies x,y] [--out NAME]

Writes ``results/scenarios_sweep.json`` (the same artifact the serial bench
produces; with ``--seeds K`` > 1, cells are keyed ``model/scenario/sK``).
``--scenarios`` / ``--policies`` restrict the grid to a sub-sweep (e.g. the
nightly ``resihp+ntp`` vs ``resihp`` quick row) and ``--out`` renames the
artifact so a sub-sweep never clobbers the full one.
"""
from __future__ import annotations

import os
from concurrent.futures import ProcessPoolExecutor
from dataclasses import dataclass

from benchmarks import bench_scenarios
from benchmarks.common import TABLE3, write_result


@dataclass(frozen=True)
class Cell:
    """One grid cell — the complete, self-contained recipe for one run.

    ``scale`` is an optional Table-3 parallelism preset (``"1k"``/``"16k"``/
    ``"32k"``...) overriding the model's native one — the multi-scale axis.
    ``None`` (the default) keeps the model's native preset AND the cell key,
    so single-scale sweeps stay byte-identical to the pre-axis artifact."""

    model: str
    scenario: str
    policy: str
    seed: int
    iters: int
    scale: str | None = None


def build_grid(*, models, scenarios=None, policies=None, seeds=(0,),
               iters=160, hazard_iters=160, scales=(None,)) -> list:
    """Canonical cell order: models > scales > scenarios > seeds > policies
    (the serial bench's iteration order, extended by the seed and scale
    axes)."""
    scenarios = list(scenarios or bench_scenarios.SWEEP)
    policies = list(policies or bench_scenarios.POLICIES)
    cells = []
    for model in models:
        for scale in scales:
            for sc in scenarios:
                sc_iters = (hazard_iters
                            if sc in bench_scenarios.HAZARD_SCENARIOS
                            else iters)
                for seed in seeds:
                    for p in policies:
                        cells.append(Cell(model, sc, p, seed, sc_iters, scale))
    return cells


def run_cell(cell: Cell, engine: str = "fast", full: bool = False) -> dict:
    return bench_scenarios.run(cell.model, cell.scenario, cell.policy,
                               iters=cell.iters, seed=cell.seed,
                               engine=engine, scale=cell.scale, full=full)


def pmap(fn, items, *, workers: int = 0, fn_args: tuple = ()) -> list:
    """Order-preserving parallel map over pure, picklable jobs.

    ``workers <= 1`` is the in-process serial reference; otherwise a process
    pool runs the calls concurrently and results come back in input order,
    so a deterministic ``fn`` makes the output worker-count invariant. Both
    the scenario sweep below and the adversarial miner
    (``tools/mine_scenarios.py`` -> :func:`repro.cluster.mining.mine`) fan
    out through this."""
    items = list(items)
    if workers <= 1:
        return [fn(x, *fn_args) for x in items]
    with ProcessPoolExecutor(max_workers=workers) as ex:
        futures = [ex.submit(fn, x, *fn_args) for x in items]
    return [f.result() for f in futures]


def _cell_key(cell: Cell, multi_seed: bool, multi_scale: bool = False) -> str:
    base = f"{cell.model}/{cell.scenario}"
    if multi_scale:
        # native scale keeps a stable name so a multi-scale sweep's keys are
        # self-describing without looking up each model's preset
        base = f"{base}@{cell.scale or 'native'}"
    return f"{base}/s{cell.seed}" if multi_seed else base


def sweep(cells, *, workers: int = 0, engine: str = "fast",
          full: bool = False) -> dict:
    """Run every cell and merge into the serial path's nested dict layout.
    ``workers <= 1`` runs in-process (the reference serial path); otherwise a
    process pool executes cells concurrently and the merge reassembles them
    in canonical grid order, byte-identical to serial. The ``@scale`` key
    level appears only when the grid actually spans more than one scale, so
    default sweeps keep their historical keys."""
    cells = list(cells)
    results = dict(zip(cells, pmap(run_cell, cells, workers=workers,
                                   fn_args=(engine, full))))
    multi_seed = len({c.seed for c in cells}) > 1
    multi_scale = len({c.scale for c in cells}) > 1
    out: dict = {}
    for cell in cells:
        out.setdefault(_cell_key(cell, multi_seed, multi_scale),
                       {})[cell.policy] = results[cell]
    return out


def main(quick=False, engine="fast", full=False, workers=0, seeds=1,
         scenarios=None, policies=None, scales=None,
         out_name="scenarios_sweep"):
    models = ["llama2-13b"] if quick else ["llama2-13b", "llama2-30b"]
    iters = 80 if quick else 160
    for sc in scenarios or ():
        assert sc in bench_scenarios.SWEEP, (sc, sorted(bench_scenarios.SWEEP))
    for p in policies or ():
        assert p in bench_scenarios.POLICIES, (p, sorted(bench_scenarios.POLICIES))
    for s in scales or ():
        assert s is None or s in TABLE3, (s, sorted(TABLE3))
    # the hazard families keep the full 160-iteration session even in
    # --quick mode, exactly like the serial bench (slow renewal dynamics)
    cells = build_grid(models=models, scenarios=scenarios, policies=policies,
                       seeds=range(seeds), iters=iters,
                       scales=tuple(scales) if scales else (None,))
    if workers <= 0:
        workers = min(len(cells), os.cpu_count() or 1)
    out = sweep(cells, workers=workers, engine=engine, full=full)
    write_result(out_name, out)
    rows = []
    for key, rs in out.items():
        rows += bench_scenarios.derive_rows(f"scenarios/{key}", rs)
    return rows


if __name__ == "__main__":
    import argparse

    from benchmarks.common import emit

    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--engine", choices=("python", "fast"), default="fast")
    ap.add_argument("--full", action="store_true",
                    help="keep per-cell event timelines in the JSON")
    ap.add_argument("--workers", type=int, default=0,
                    help="worker processes (0 = one per core; 1 = serial)")
    ap.add_argument("--serial", action="store_true",
                    help="force the in-process serial reference path")
    ap.add_argument("--seeds", type=int, default=1,
                    help="seeds per cell (adds a /sK key level when > 1)")
    ap.add_argument("--scenarios", type=str, default=None,
                    help="comma-separated scenario subset (default: all)")
    ap.add_argument("--policies", type=str, default=None,
                    help="comma-separated policy subset (default: all)")
    ap.add_argument("--scales", type=str, default=None,
                    help="comma-separated Table-3 scale presets, e.g. "
                         "1k,16k,32k; 'native' keeps the model's own preset "
                         "(default: native only, no @scale key level)")
    ap.add_argument("--out", type=str, default="scenarios_sweep",
                    help="results/<out>.json artifact name")
    args = ap.parse_args()
    scales = None
    if args.scales:
        scales = [None if s == "native" else s
                  for s in args.scales.split(",")]
    emit(main(quick=args.quick, engine=args.engine, full=args.full,
              workers=1 if args.serial else args.workers, seeds=args.seeds,
              scenarios=args.scenarios.split(",") if args.scenarios else None,
              policies=args.policies.split(",") if args.policies else None,
              scales=scales, out_name=args.out))
