"""Fig. 2: failure amplification across TP/PP/DP — inject a half-speed
fail-slow on one GPU of LLaMA2-13B (TP,DP,PP)=(4,2,4); count additionally
affected devices and additional idle GPU time per dimension, unmitigated vs
ResiHP (the Fig. 11 mitigation-at-each-level numbers)."""
from __future__ import annotations

from benchmarks.common import sim_config, write_result
from repro.cluster.simulator import TrainingSim
from repro.core.scheduler.migration import ProgressAwareMigrator


def _idle_per_executor(cfg, policy, slow_exec, factor):
    mult = {"F": 1.0, "B": 2.0, "W": 0.0}

    def cost(cid, e):
        c = mult[cid.kind]
        if e == slow_exec:
            c /= factor
        return c

    m = ProgressAwareMigrator(
        n_stages=cfg.pp, n_replicas=cfg.dp, n_microbatches=cfg.n_microbatches,
        chunk_cost=cost, policy=policy, delta=1)
    res = m.run()
    return res, m


def main(quick=False):
    cfg = sim_config("llama2-13b")  # (4, 2, 4)
    slow = (0, 1)
    out = {}
    # healthy baseline idle
    res_h, _ = _idle_per_executor(cfg, "none", slow, 1.0)
    for policy in ("none", "resihp"):
        res, m = _idle_per_executor(cfg, policy, slow, 0.5)
        # slowdown duration: extra busy time on the slow executor
        busy_slow = sum(m.chunk_cost(c, slow) for c in m.done
                        if m._executor_of(c) == slow)
        healthy_equiv = busy_slow * 0.5
        slowdown = busy_slow - healthy_equiv
        d_idle = {e: res.idle[e] - res_h.idle[e] for e in res.idle}
        tp_peers = (cfg.tp - 1)  # same-group devices locked to the slow member
        idle_tp = slowdown * tp_peers
        idle_pp = sum(max(v, 0) for e, v in d_idle.items()
                      if e[0] == slow[0] and e != slow) * cfg.tp
        idle_dp = sum(max(v, 0) for e, v in d_idle.items()
                      if e[0] != slow[0]) * cfg.tp
        affected_tp = tp_peers
        affected_pp = (cfg.pp - 1) * cfg.tp
        affected_dp = (cfg.dp - 1) * cfg.pp * cfg.tp
        out[policy] = {
            "slowdown_duration_s": slowdown,
            "makespan": res.makespan,
            "healthy_makespan": res_h.makespan,
            "affected_devices": {"tp": affected_tp, "pp": affected_pp,
                                 "dp": affected_dp},
            "additional_idle_s": {"tp": idle_tp, "pp": idle_pp, "dp": idle_dp},
            "idle_over_slowdown": {
                "tp": idle_tp / max(slowdown, 1e-9),
                "pp": idle_pp / max(slowdown, 1e-9),
                "dp": idle_dp / max(slowdown, 1e-9),
            },
            "migrations": len(res.migrations),
        }
    write_result("fig2_amplification", out)
    rows = []
    for policy, r in out.items():
        for dim in ("tp", "pp", "dp"):
            rows.append((f"fig2/{policy}/idle_over_slowdown/{dim}",
                         round(r["idle_over_slowdown"][dim], 2),
                         f"affected={r['affected_devices'][dim]}"))
    return rows


if __name__ == "__main__":
    from benchmarks.common import emit

    emit(main())
