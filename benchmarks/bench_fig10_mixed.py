"""Fig. 10: mixed failures (alternating fail-stop / medium fail-slow) —
ResiHP vs ReCycle, strengthened ReCycle, strengthened Oobleck."""
from __future__ import annotations

from benchmarks.common import sim_config, write_result
from repro.cluster import scenarios
from repro.cluster.simulator import TrainingSim


def run(model: str, policy: str, *, iters=300, n_events=6, seed=0):
    cfg = sim_config(model, seed=seed)
    sim = TrainingSim(policy, cfg)
    sim.apply_scenario(
        scenarios.get("fig10_mixed", span=iters * 0.8, n_events=n_events))
    sim.run(iters)
    return {"throughput": sim.avg_throughput(skip=2), "aborted": sim.aborted}


def main(quick=False):
    models = ["llama2-13b"] if quick else ["llama2-7b", "llama2-13b", "llama2-30b"]
    iters = 150 if quick else 300
    out, rows = {}, []
    for model in models:
        rs = {p: run(model, p, iters=iters)
              for p in ("recycle", "recycle+", "oobleck+", "resihp")}
        out[model] = rs
        resi = rs["resihp"]["throughput"]
        for p, r in rs.items():
            t = r["throughput"]
            rows.append((
                f"fig10/{model}/{p}",
                "-" if r["aborted"] else round(t, 2),
                f"resihp_speedup={resi/max(t,1e-9):.2f}x" if p != "resihp" else ""))
    write_result("fig10_mixed", out)
    return rows


if __name__ == "__main__":
    from benchmarks.common import emit

    emit(main())
