#!/usr/bin/env python3
"""Smoke-run the benchmark commands quoted in a docs page (no dependencies).

Extracts every ``PYTHONPATH=src python -m benchmarks.…`` (and
``python tools/…``) command from the page — fenced code blocks and
backtick-quoted table cells alike — appends ``--quick`` where the command
does not already carry it, and executes each from the repo root. Any
non-zero exit fails the run, so a renamed module, flag or scenario breaks
the nightly build instead of silently rotting the docs.

    python tools/docs_smoke.py docs/benchmarks.md [--list] [--timeout 1200]
"""
from __future__ import annotations

import argparse
import os
import re
import shlex
import subprocess
import sys
import time
from pathlib import Path

REPO_ROOT = Path(__file__).resolve().parent.parent

# a command starts at `PYTHONPATH=src python -m benchmarks.` or
# `python tools/` and runs until a backtick, table pipe, bracket or end of
# line — matches both `code spans` and fenced blocks
CMD_RE = re.compile(
    r"(?:PYTHONPATH=src )?python (?:-m benchmarks\.|tools/)[^`|\]\n]+")
QUICKLESS = ("tools/",)  # scripts that have no --quick flag
SELF = "tools/docs_smoke.py"  # validated with --list to avoid recursion


def extract(page: Path) -> list:
    cmds = []
    for m in CMD_RE.finditer(page.read_text()):
        cmd = m.group(0).strip().rstrip("\\").strip()
        if any(ch in cmd for ch in "…<>"):
            continue  # prose placeholder, not a runnable command
        # strip placeholder option syntax from usage lines: `[--quick] ...`
        cmd = re.sub(r"\s*\[[^\]]*\]", "", cmd).strip()
        if SELF in cmd:
            # the page quotes this very tool: running it for real would
            # recurse through the whole command list again — validate the
            # CLI with --list instead
            cmd = f"python {SELF} --list"
        elif "--quick" not in cmd and not any(q in cmd for q in QUICKLESS):
            cmd += " --quick"
        if cmd not in cmds:
            cmds.append(cmd)
    return cmds


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("page", nargs="?", default="docs/benchmarks.md")
    ap.add_argument("--list", action="store_true",
                    help="print the extracted commands and exit")
    ap.add_argument("--timeout", type=int, default=1200,
                    help="per-command timeout in seconds")
    args = ap.parse_args(argv)

    page = (REPO_ROOT / args.page).resolve()
    cmds = extract(page)
    if not cmds:
        print(f"no benchmark commands found in {page}")
        return 1
    if args.list:
        for c in cmds:
            print(c)
        return 0

    env = dict(os.environ)
    failures = []
    for cmd in cmds:
        words = shlex.split(cmd)
        if words[0].startswith("PYTHONPATH="):
            env["PYTHONPATH"] = words[0].split("=", 1)[1] + (
                os.pathsep + os.environ["PYTHONPATH"]
                if os.environ.get("PYTHONPATH") else "")
            words = words[1:]
        assert words[0] == "python", cmd
        argv_cmd = [sys.executable] + words[1:]  # replace bare `python`
        print(f"\n== {cmd}", flush=True)
        t0 = time.time()
        try:
            r = subprocess.run(argv_cmd, cwd=REPO_ROOT, env=env,
                               timeout=args.timeout)
            ok = r.returncode == 0
        except subprocess.TimeoutExpired:
            ok = False
        print(f"== {'ok' if ok else 'FAILED'} in {time.time() - t0:.0f}s",
              flush=True)
        if not ok:
            failures.append(cmd)
    print(f"\n{len(cmds) - len(failures)}/{len(cmds)} documented "
          f"command(s) ran clean")
    for f in failures:
        print(f"FAILED  {f}")
    return 1 if failures else 0


if __name__ == "__main__":
    raise SystemExit(main())
