#!/usr/bin/env python3
"""Fit the unified credit model's weights and band edges against sweep
outcomes (driver for the ``ResiHPPolicy(credit=...)`` switch).

The credit score (:mod:`repro.core.detector.credit`) collapses the policy
stack's hand-tuned per-signal thresholds into one scalar; this tool fits the
four signal weights, the three decision bands, the evidence window and the
two retired lifecycle constants (``drift_filter_threshold``,
``validation_debounce_s``) **offline** so no threshold in the credit path is
hand-tuned. The search is a seeded two-round coordinate descent over a small
discrete surface: for each field in a fixed order, every candidate value is
scored by running the full ``resihp+credit`` scenario catalog
(``benchmarks.bench_scenarios.SWEEP`` on llama2-13b) and comparing each
family's session throughput against the *best hand-tuned resihp policy
column* on that family (``CREDIT_BASELINES``, computed at fit time at the
same iteration count). The objective rewards matching every baseline and
punishes losing to any::

    score = sum_f g(sess_f / best_f),   g(r) = 1 + min(r - 1, cap)  (r >= 1)
                                        g(r) = 1 - loss_mult * (1 - r)  (r < 1)

so a 1% loss on one family costs ``loss_mult`` times what a 1% (capped) win
buys — the fit prefers dominating every column over maximizing any one.

Deterministic by construction: fixed seeds everywhere, a fixed coordinate
order, strictly-greater acceptance (ties keep the incumbent), and
order-preserving fan-out through :func:`benchmarks.sweep.pmap` — the output
is byte-identical for a fixed recipe and invariant to ``--workers``
(pinned in ``tests/test_credit.py``).

Artifacts:

* ``src/repro/configs/credit_fitted.json`` — the fitted surface the runtime
  loads (:func:`repro.core.detector.credit.fitted_credit_config`), written
  by **full** runs only; carries the full-fit ``fitted`` block, a ``quick``
  block (the ``--quick`` recipe's result, the nightly drift guard) and
  provenance (recipe, per-family baselines and ratios);
* ``results/credit_fit.json`` — the search trace (every candidate scored,
  baselines, ratios), written by every run.

Modes:

    PYTHONPATH=src python tools/fit_credit.py              # full fit (slow)
    PYTHONPATH=src python tools/fit_credit.py --quick        # quick recipe
    PYTHONPATH=src python tools/fit_credit.py --quick --check  # nightly
    PYTHONPATH=src python tools/fit_credit.py --priors       # MTTF priors

``--quick --check`` re-runs the fixed quick recipe and verifies it still
reproduces the pinned ``quick`` block (fit-pipeline drift guard); it never
rewrites the fitted config. ``--priors`` fits per-device MTTF priors for
``HazardPolicyConfig(priors=...)`` from the hazard families' observed
failure histories (shrunk toward the fleet prior) and writes
``results/hazard_priors.json``.
"""
from __future__ import annotations

import argparse
import functools
import json
import math
import sys
from pathlib import Path

REPO_ROOT = Path(__file__).resolve().parent.parent
for p in (str(REPO_ROOT / "src"), str(REPO_ROOT)):
    if p not in sys.path:
        sys.path.insert(0, p)

from benchmarks.bench_scenarios import (CREDIT_BASELINES, POLICIES,  # noqa: E402
                                        SWEEP)
from benchmarks.common import sim_config  # noqa: E402
from benchmarks.sweep import pmap  # noqa: E402
from repro.core.detector.credit import (FIT_FIELDS,  # noqa: E402
                                        FITTED_CONFIG_PATH, CreditConfig)

MODEL = "llama2-13b"  # the acceptance model (medium preset, 32 devices)

# the discrete fit surface: coordinate descent visits fields in this order
# (dict order is the seeded coordinate order — do not reorder casually, the
# checked-in artifact pins the search trajectory)
SPACE = {
    "alpha": (0.0, 0.02, 0.05, 0.1, 0.2),
    "beta": (0.0, 0.1, 0.25, 0.5, 1.0),
    "gamma": (0.0, 0.15, 0.3, 0.45),
    "delta": (0.0, 0.05, 0.15, 0.3),
    "quarantine_band": (0.0, 0.05, 0.15, 0.3),
    "probe_band": (0.0, 0.5, 0.7, 0.85, 0.95),
    "ntp_band": (0.0, 0.45, 0.6, 0.75, 0.9),
    # 1.0 retires the slope/carry drift stack outright (see CreditConfig)
    "drift_filter_threshold": (0.05, 0.10, 0.25, 1.0),
    # storm families reward sub-second validation, ramp families the legacy
    # 4s hold — the axis is sharp, hence the fine grid around 2s
    "validation_debounce_s": (0.0, 1.0, 1.5, 2.0, 3.0, 4.0, 8.0),
    # evidence window = the veto's memory: staggered storms need it short
    # (the veto must not outlive the storm), mass bursts need it to cover
    # the pivotal shrink decision
    "window_s": (15.0, 25.0, 60.0),
}
assert tuple(SPACE) == FIT_FIELDS

# descent starting points, scored first and the best taken as the incumbent:
# the config defaults, plus the hand-found corner — drift stack retired
# (dft=1.0), free async probes for every sub-full rejoiner (probe_band
# 0.95), domain-burst NTP veto (delta/ntp_band) on a short evidence window,
# storm-speed debounce (every value below sits on the SPACE grid so the
# descent can walk back out of it)
SEEDS = (
    {},
    {"alpha": 0.0, "beta": 0.25, "gamma": 0.0, "delta": 0.3,
     "quarantine_band": 0.0, "probe_band": 0.95, "ntp_band": 0.45,
     "drift_filter_threshold": 1.0, "validation_debounce_s": 1.5,
     "window_s": 25.0},
)

CAP = 0.05       # per-family win credited at most this far above parity
LOSS_MULT = 5.0  # a loss costs this many times an equal-size win

QUICK = dict(iters=40, rounds=1)   # the pinned nightly drift-guard recipe
FULL = dict(iters=160, rounds=2)   # the checked-in fitted surface's recipe

HAZARD_FAMILIES = ("aging_fleet", "lemon_devices", "infant_mortality")


# ------------------------------------------------------------------- cells
def eval_cell(job, iters: int, engine: str) -> float:
    """One fit cell: session throughput of one policy on one family.

    ``job`` is ``(scenario, params | None)`` — params as a sorted tuple of
    ``(field, value)`` pairs selects the candidate credit surface; ``None``
    plus a policy label in the scenario slot is not used here (baselines go
    through :func:`baseline_cell`). Top-level so the process pool can pick
    it (fork start method)."""
    from repro.cluster.simulator import TrainingSim

    scenario, params = job
    kwargs = {"credit": CreditConfig(**dict(params)), "ntp": True,
              "plan_overhead_model": True}
    cfg = sim_config(MODEL, seed=0)
    sim = TrainingSim("resihp", cfg, engine=engine, policy_kwargs=kwargs)
    sim.apply_scenario(SWEEP[scenario](iters * 0.8))
    sim.run(iters, stop_on_abort=False)
    return sim.session_throughput(skip=2)


def baseline_cell(job, iters: int, engine: str) -> float:
    """Session throughput of one hand-tuned policy column on one family."""
    from repro.cluster.simulator import TrainingSim

    scenario, policy = job
    name, kwargs = POLICIES[policy]
    cfg = sim_config(MODEL, seed=0)
    sim = TrainingSim(name, cfg, engine=engine, policy_kwargs=kwargs)
    sim.apply_scenario(SWEEP[scenario](iters * 0.8))
    sim.run(iters, stop_on_abort=False)
    return sim.session_throughput(skip=2)


def fit_baselines(*, iters: int, engine: str, pool) -> dict:
    """Per-family best over the hand-tuned resihp columns, at fit iters."""
    jobs = [(sc, p) for sc in SWEEP for p in CREDIT_BASELINES]
    vals = pool(functools.partial(baseline_cell, iters=iters, engine=engine),
                jobs)
    best: dict = {}
    for (sc, _p), v in zip(jobs, vals):
        best[sc] = max(best.get(sc, 0.0), v)
    return best


# ------------------------------------------------------------------ search
def objective(ratios) -> float:
    s = 0.0
    for r in ratios:
        s += 1.0 + min(r - 1.0, CAP) if r >= 1.0 else 1.0 - LOSS_MULT * (1.0 - r)
    return s


def score_params(params: dict, best: dict, memo: dict, *,
                 iters: int, engine: str, pool):
    """Score one candidate surface: (objective, {family: ratio})."""
    key = tuple(sorted(params.items()))
    todo = [sc for sc in SWEEP if (key, sc) not in memo]
    if todo:
        vals = pool(functools.partial(eval_cell, iters=iters, engine=engine),
                    [(sc, key) for sc in todo])
        for sc, v in zip(todo, vals):
            memo[(key, sc)] = v
    # a family whose every baseline aborted (possible at tiny --iters) is
    # vacuous: parity by definition rather than a divide-by-zero
    ratios = {sc: (memo[(key, sc)] / best[sc] if best[sc] > 0 else 1.0)
              for sc in SWEEP}
    return objective(ratios.values()), ratios


def fit(*, iters: int, rounds: int, engine: str = "fast",
        workers: int = 1) -> dict:
    """The seeded coordinate descent. Deterministic for a fixed recipe and
    invariant to ``workers`` (order-preserving pool, fixed visit order,
    strictly-greater acceptance)."""
    pool = functools.partial(pmap, workers=workers)
    best = fit_baselines(iters=iters, engine=engine, pool=pool)
    memo: dict = {}
    defaults = {f: getattr(CreditConfig(), f) for f in FIT_FIELDS}
    history = []
    current, cur_score, cur_ratios = None, -math.inf, None
    for i, seed in enumerate(SEEDS):
        cand = dict(defaults, **seed)
        s, ratios = score_params(cand, best, memo, iters=iters,
                                 engine=engine, pool=pool)
        accepted = s > cur_score  # first seed always wins its own tie
        history.append({"params": dict(cand), "objective": s,
                        "accepted": accepted, "note": f"seed {i}"})
        if accepted:
            current, cur_score, cur_ratios = cand, s, ratios
    for rnd in range(rounds):
        for field in FIT_FIELDS:
            for value in SPACE[field]:
                if value == current[field]:
                    continue
                cand = dict(current, **{field: value})
                if cand["quarantine_band"] > cand["probe_band"]:
                    continue  # CreditConfig invariant
                s, ratios = score_params(cand, best, memo, iters=iters,
                                         engine=engine, pool=pool)
                accepted = s > cur_score  # ties keep the incumbent
                history.append({"params": dict(cand), "objective": s,
                                "accepted": accepted,
                                "note": f"round {rnd} {field}={value}"})
                if accepted:
                    current, cur_score, cur_ratios = cand, s, ratios
    cur_key = tuple(sorted(current.items()))
    return {
        "fitted": dict(current),
        "objective": cur_score,
        "ratios": {sc: round(r, 6) for sc, r in cur_ratios.items()},
        # unrounded: tests re-run single cells and pin exact equality
        "sessions": {sc: memo[(cur_key, sc)] for sc in SWEEP},
        "baselines": {sc: best[sc] for sc in SWEEP},
        "recipe": {"model": MODEL, "iters": iters, "rounds": rounds,
                   "engine": engine, "cap": CAP, "loss_mult": LOSS_MULT},
        "history": history,
        "cells_evaluated": len(memo),
    }


# ------------------------------------------------------------------ priors
def fit_priors(*, iters: int = 160, engine: str = "fast") -> dict:
    """Per-device MTTF priors for ``HazardPolicyConfig(priors=...)``: run
    the hazard families under ``resihp+hz``, pool each device's observed
    failure count and exposure across families, and shrink toward the fleet
    prior — ``mttf_d = (prior_time_s + exposure_d) / (prior_failures +
    n_d)`` — so a device that never failed stays near the fleet prior while
    a repeat offender's fitted MTTF drops in proportion to the evidence."""
    from repro.cluster.hazard import HazardPolicyConfig
    from repro.cluster.simulator import TrainingSim

    hz = HazardPolicyConfig()
    counts: dict = {}
    exposure = 0.0
    per_family = {}
    for sc in HAZARD_FAMILIES:
        cfg = sim_config(MODEL, seed=0)
        name, kwargs = POLICIES["resihp+hz"]
        sim = TrainingSim(name, cfg, engine=engine, policy_kwargs=kwargs)
        sim.apply_scenario(SWEEP[sc](iters * 0.8))
        sim.run(iters, stop_on_abort=False)
        fam = {}
        for d, h in sim.lifecycle.histories.items():
            n = len(h.fail_stops) + len(h.fail_slows)
            counts[d] = counts.get(d, 0) + n
            fam[d] = n
        exposure += sim.now
        per_family[sc] = {str(d): n for d, n in sorted(fam.items())}
    n_dev = sim_config(MODEL, seed=0).n_devices
    priors = [
        (d, round((hz.prior_time_s + exposure)
                  / (hz.prior_failures + counts.get(d, 0)), 3))
        for d in range(n_dev)
    ]
    return {
        "priors": priors,
        "recipe": {"model": MODEL, "iters": iters, "engine": engine,
                   "families": list(HAZARD_FAMILIES),
                   "prior_failures": hz.prior_failures,
                   "prior_time_s": hz.prior_time_s},
        "exposure_s": round(exposure, 3),
        "per_family_counts": per_family,
    }


# ------------------------------------------------------------------- check
def check(report: dict, pinned: dict) -> list:
    """The --quick --check contract; returns a list of failure strings."""
    errors = []
    quick = pinned.get("quick")
    if not quick:
        return ["pinned credit_fitted.json has no 'quick' block"]
    if report["fitted"] != quick["fitted"]:
        errors.append(f"quick fit drifted: {report['fitted']} != "
                      f"{quick['fitted']}")
    if abs(report["objective"] - quick["objective"]) > 1e-6:
        errors.append(f"quick objective drifted: {report['objective']:.6f} "
                      f"!= {quick['objective']:.6f}")
    bad = set(pinned.get("fitted", {})) - set(FIT_FIELDS)
    if bad:
        errors.append(f"pinned fitted block carries non-fit keys: "
                      f"{sorted(bad)}")
    return errors


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description="fit the unified credit surface against sweep outcomes")
    ap.add_argument("--quick", action="store_true",
                    help=f"the fixed nightly recipe {QUICK} (does not "
                         "rewrite the fitted config)")
    ap.add_argument("--iters", type=int, default=None,
                    help="override the per-cell iteration count")
    ap.add_argument("--rounds", type=int, default=None,
                    help="override the coordinate-descent round count")
    ap.add_argument("--workers", type=int, default=1,
                    help="worker processes (1 = serial); never changes "
                         "the output bytes")
    ap.add_argument("--engine", choices=("fast", "python"), default="fast")
    ap.add_argument("--check", action="store_true",
                    help="verify the quick recipe against the pinned "
                         "quick block in credit_fitted.json (nightly)")
    ap.add_argument("--priors", action="store_true",
                    help="fit per-device MTTF priors instead "
                         "(results/hazard_priors.json)")
    ap.add_argument("--out", type=str, default=None,
                    help="results/ artifact stem (default credit_fit, or "
                         "hazard_priors with --priors) — lets smoke runs "
                         "keep their trace off the committed artifacts")
    args = ap.parse_args(argv)

    results_dir = REPO_ROOT / "results"
    results_dir.mkdir(exist_ok=True)

    if args.priors:
        report = fit_priors(iters=args.iters or FULL["iters"],
                            engine=args.engine)
        out = results_dir / f"{args.out or 'hazard_priors'}.json"
        out.write_text(json.dumps(report, indent=1, sort_keys=True) + "\n")
        worst = min(report["priors"], key=lambda p: (p[1], p[0]))
        print(f"fitted {len(report['priors'])} device priors "
              f"(worst d{worst[0]}: mttf {worst[1]}s)")
        print(f"wrote {out.relative_to(REPO_ROOT)}")
        return 0

    recipe = dict(QUICK) if args.quick else dict(FULL)
    if args.iters is not None:
        recipe["iters"] = args.iters
    if args.rounds is not None:
        recipe["rounds"] = args.rounds

    # snapshot the pinned config BEFORE any write (mine_scenarios contract)
    pinned = (json.loads(FITTED_CONFIG_PATH.read_text())
              if args.check and FITTED_CONFIG_PATH.exists() else None)

    report = fit(iters=recipe["iters"], rounds=recipe["rounds"],
                 engine=args.engine, workers=args.workers)

    trace = dict(report, quick=bool(args.quick), space=SPACE)
    trace_name = args.out or "credit_fit"
    (results_dir / f"{trace_name}.json").write_text(
        json.dumps(trace, indent=1, sort_keys=True) + "\n")

    print(f"fitted surface ({'quick' if args.quick else 'full'} recipe, "
          f"{report['cells_evaluated']} cells): {report['fitted']}")
    print(f"objective {report['objective']:.4f} "
          f"(parity = {len(SWEEP)}.0000)")
    for sc, r in sorted(report["ratios"].items(), key=lambda kv: kv[1]):
        mark = "==" if abs(r - 1.0) < 5e-4 else (">=" if r > 1 else "LOSS")
        print(f"  {sc:24s} {r:6.3f}x vs best {report['baselines'][sc]:8.3f}"
              f"  {mark}")
    print(f"wrote results/{trace_name}.json")

    if args.check:
        errors = check(report, pinned or {})
        for e in errors:
            print(f"CHECK FAILED: {e}", file=sys.stderr)
        if errors:
            return 1
        print("check passed: quick fit reproduces the pinned surface")
        return 0

    if not args.quick:
        # full run owns the runtime config: full fitted block + a fresh
        # quick block so the nightly guard pins today's pipeline
        q = fit(iters=QUICK["iters"], rounds=QUICK["rounds"],
                engine=args.engine, workers=args.workers)
        payload = {
            "fitted": report["fitted"],
            "objective": report["objective"],
            "ratios": report["ratios"],
            "sessions": report["sessions"],
            "baselines": report["baselines"],
            "provenance": {"tool": "tools/fit_credit.py",
                           "recipe": report["recipe"]},
            "quick": {"fitted": q["fitted"], "objective": q["objective"],
                      "recipe": q["recipe"]},
        }
        FITTED_CONFIG_PATH.write_text(
            json.dumps(payload, indent=1, sort_keys=True) + "\n")
        print(f"wrote {FITTED_CONFIG_PATH.relative_to(REPO_ROOT)}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
