#!/usr/bin/env python3
"""Markdown link checker for the repo docs (no dependencies).

Walks the given files/directories, extracts every markdown link and image
(``[text](target)``), and verifies that each *relative* target resolves to an
existing file — including ``#anchor`` links, whose heading must exist in the
target (or current) file. External ``http(s)://`` and ``mailto:`` targets are
not fetched (CI must not depend on the network); they are only checked for
obvious malformation.

    python tools/check_md_links.py README.md docs

Exit code 1 with a per-link report when anything dangles — wired into the
nightly workflow so a renamed doc or module breaks the night's build, not a
future reader.
"""
from __future__ import annotations

import re
import sys
from pathlib import Path

LINK_RE = re.compile(r"!?\[([^\]]*)\]\(([^)\s]+)(?:\s+\"[^\"]*\")?\)")
HEADING_RE = re.compile(r"^#{1,6}\s+(.*)$", re.MULTILINE)


def slugify(heading: str) -> str:
    """GitHub-style anchor slug: lowercase, drop punctuation, dashes."""
    text = re.sub(r"[`*_~]", "", heading.strip().lower())
    text = re.sub(r"[^\w\- ]", "", text)
    return text.replace(" ", "-")


def anchors_of(path: Path) -> set:
    return {slugify(h) for h in HEADING_RE.findall(path.read_text())}


def md_files(args) -> list:
    out = []
    for a in args:
        p = Path(a)
        if p.is_dir():
            out.extend(sorted(p.rglob("*.md")))
        elif p.suffix == ".md":
            out.append(p)
        else:
            raise SystemExit(f"not a markdown file or directory: {a}")
    return out


def check(files) -> list:
    errors = []
    for md in files:
        text = md.read_text()
        for m in LINK_RE.finditer(text):
            target = m.group(2)
            if target.startswith(("http://", "https://", "mailto:")):
                continue
            if target.startswith("#"):
                if slugify(target[1:]) not in anchors_of(md):
                    errors.append(f"{md}: dangling anchor {target!r}")
                continue
            rel, _, frag = target.partition("#")
            dest = (md.parent / rel).resolve()
            if not dest.exists():
                errors.append(f"{md}: dangling link {target!r} -> {dest}")
            elif frag and dest.suffix == ".md" \
                    and slugify(frag) not in anchors_of(dest):
                errors.append(f"{md}: dangling anchor {target!r} in {dest.name}")
    return errors


def main(argv) -> int:
    files = md_files(argv or ["README.md", "docs"])
    errors = check(files)
    for e in errors:
        print(f"BROKEN  {e}")
    print(f"checked {len(files)} file(s): "
          f"{'%d broken link(s)' % len(errors) if errors else 'all links ok'}")
    return 1 if errors else 0


if __name__ == "__main__":
    raise SystemExit(main(sys.argv[1:]))
