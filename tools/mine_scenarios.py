#!/usr/bin/env python3
"""Mine adversarial failure scenarios (driver for repro.cluster.mining).

Runs the coverage-guided search at the 256-device mining scale, writes the
canonical report to ``results/<out>.json`` and prints the ranked clusters.
Deterministic for a fixed ``(--seed, --budget)`` and invariant to
``--workers`` (see the determinism contract in
:mod:`repro.cluster.mining`), so the checked-in artifact regenerates
byte-identically:

    PYTHONPATH=src python tools/mine_scenarios.py --quick        # regenerate
    PYTHONPATH=src python tools/mine_scenarios.py --quick --check  # CI smoke

``--check`` re-verifies the checked-in ``results/adversarial_mined.json``
against this run: the top-ranked cluster's signature (and timeline) must be
re-found, and the quick run must beat the worst hand-authored catalog
scenario — the nightly regression that keeps the ``adversarial_*`` family
honest. Deeper local searches: raise ``--budget`` (and ``--workers``).
"""
from __future__ import annotations

import argparse
import functools
import json
import sys
from pathlib import Path

REPO_ROOT = Path(__file__).resolve().parent.parent
for p in (str(REPO_ROOT / "src"), str(REPO_ROOT)):
    if p not in sys.path:
        sys.path.insert(0, p)

from benchmarks.sweep import pmap  # noqa: E402
from repro.cluster import mining  # noqa: E402

QUICK = dict(seed=0, budget=128, iters=30)  # the checked-in artifact's recipe


def check(report: dict, pinned: dict) -> list:
    """The --check contract; returns a list of failure strings."""
    errors = []
    for mine_e, pin_e in zip(report["family"], pinned["family"]):
        if mine_e["signature"] != pin_e["signature"]:
            errors.append(
                f"family[{pin_e['rank']}] ({pin_e['objective']}) signature "
                f"changed: {mine_e['signature']} != {pin_e['signature']}")
        elif mine_e["timeline"] != pin_e["timeline"]:
            errors.append(f"family[{pin_e['rank']}] timeline changed")
    if report["n_clusters"] < 3 or len(report["family"]) < 3:
        errors.append(f"only {report['n_clusters']} distinct clusters / "
                      f"{len(report['family'])} family members (need >= 3)")
    worst = report["worst_catalog"]["session_throughput"]["resihp"]
    mined = min(c["session_throughput"]["resihp"] for c in report["family"])
    if not mined < worst:
        errors.append(f"no mined family scenario ({mined:.6g}) beats the "
                      f"worst catalog scenario ({worst:.6g}) on resihp "
                      "session throughput")
    return errors


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description="coverage-guided adversarial scenario mining")
    ap.add_argument("--quick", action="store_true",
                    help=f"the fixed CI recipe {QUICK} (the checked-in "
                         "artifact's exact parameters)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--budget", type=int, default=256,
                    help="candidate evaluations, catalog seeds included")
    ap.add_argument("--iters", type=int, default=30,
                    help="training iterations per candidate run")
    ap.add_argument("--workers", type=int, default=0,
                    help="worker processes (0 = one per core; 1 = serial); "
                         "never changes the output bytes")
    ap.add_argument("--engine", choices=("fast", "python"), default="fast")
    ap.add_argument("--out", type=str, default="adversarial_mined",
                    help="results/<out>.json artifact name")
    ap.add_argument("--check", action="store_true",
                    help="verify this run against the checked-in "
                         "results/adversarial_mined.json (nightly smoke)")
    args = ap.parse_args(argv)
    if args.quick:
        args.seed, args.budget, args.iters = (
            QUICK["seed"], QUICK["budget"], QUICK["iters"])

    # snapshot the pinned artifact BEFORE writing: with the default --out the
    # run overwrites results/adversarial_mined.json, and a post-write load
    # would compare the report against itself
    pinned_path = REPO_ROOT / "results" / "adversarial_mined.json"
    pinned = json.loads(pinned_path.read_text()) if args.check else None

    import os
    workers = args.workers if args.workers > 0 else (os.cpu_count() or 1)
    report = mining.mine(
        seed=args.seed, budget=args.budget, iters=args.iters,
        engine=args.engine,
        pool_map=functools.partial(pmap, workers=workers))

    out_path = REPO_ROOT / "results" / f"{args.out}.json"
    out_path.parent.mkdir(exist_ok=True)
    out_path.write_text(mining.to_json(report) + "\n")

    print(f"healthy resihp session: {report['healthy']['resihp']:.6g}")
    wc = report["worst_catalog"]
    print(f"worst catalog: {wc['name']} "
          f"(resihp {wc['session_throughput']['resihp']:.6g})")
    print(f"{report['n_clusters']} distinct clusters "
          f"({report['config']['budget']} candidates evaluated); top:")
    for c in report["clusters"]:
        flag = " FLIP" if c["flip"] else ""
        print(f"  #{c['rank']} score={c['score']:.4f} "
              f"loss={c['resihp_loss']:.4f}{flag} events={c['n_events']} "
              f"sig={tuple(c['signature'])} [{c['label']}]")
    print("family (-> adversarial_1/2/3):")
    for c in report["family"]:
        print(f"  adversarial_{c['rank']} [{c['objective']}] "
              f"loss={c['resihp_loss']:.4f} "
              f"resihp={c['session_throughput']['resihp']:.6g} "
              f"events={c['n_events']} [{c['label']}]")
    print(f"wrote {out_path.relative_to(REPO_ROOT)}")

    if args.check:
        errors = check(report, pinned)
        for e in errors:
            print(f"CHECK FAILED: {e}", file=sys.stderr)
        if errors:
            return 1
        print("check passed: pinned top pattern re-found")
    return 0


if __name__ == "__main__":
    sys.exit(main())
