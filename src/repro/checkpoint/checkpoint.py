"""Fault-tolerant checkpointing: tensor-level save/restore with resharding on
load, double-buffered step directories, and an atomic commit marker.

Layout:
    <root>/step_000123/
        MANIFEST.json        # treedef + per-leaf dtype/shape + extra payload
        leaf_00000.npy ...   # flattened leaves in treedef order
        COMMIT               # written last; restore ignores dirs without it

A write goes to `step_N.tmp/` and is atomically renamed after COMMIT exists,
so a crash mid-save never corrupts the latest restorable state (Fig. 8b's
"persistent states from the last completed iteration"). Restore accepts a
target sharding tree: leaves are `jax.device_put` straight into the *new*
plan's shardings, which is how recovery restores into a different parallel
layout than the one that saved.
"""
from __future__ import annotations

import json
import os
import shutil
from pathlib import Path
from typing import Any, Optional

import jax
import numpy as np


def _flatten_with_paths(tree):
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    return leaves, treedef


def save_checkpoint(root, state, step: int, *, extra: Optional[dict] = None,
                    keep: int = 2) -> Path:
    root = Path(root)
    root.mkdir(parents=True, exist_ok=True)
    final = root / f"step_{step:09d}"
    tmp = root / f"step_{step:09d}.tmp"
    if tmp.exists():
        shutil.rmtree(tmp)
    tmp.mkdir()
    leaves, treedef = _flatten_with_paths(state)
    manifest = {
        "step": step,
        "treedef": jax.tree_util.tree_structure(state).serialize_using_proto().hex(),
        "n_leaves": len(leaves),
        "leaves": [],
        "extra": extra or {},
    }
    for i, leaf in enumerate(leaves):
        arr = np.asarray(jax.device_get(leaf))
        np.save(tmp / f"leaf_{i:05d}.npy", arr)
        manifest["leaves"].append({"dtype": str(arr.dtype), "shape": list(arr.shape)})
    (tmp / "MANIFEST.json").write_text(json.dumps(manifest))
    (tmp / "COMMIT").write_text("ok")
    if final.exists():
        shutil.rmtree(final)
    os.replace(tmp, final)
    _gc(root, keep)
    return final


def _gc(root: Path, keep: int):
    steps = sorted(
        (p for p in root.glob("step_*") if (p / "COMMIT").exists()),
        key=lambda p: p.name,
    )
    for p in steps[:-keep]:
        shutil.rmtree(p, ignore_errors=True)


def latest_step(root) -> Optional[int]:
    root = Path(root)
    if not root.exists():
        return None
    steps = [
        int(p.name.split("_")[1])
        for p in root.glob("step_*")
        if (p / "COMMIT").exists() and not p.name.endswith(".tmp")
    ]
    return max(steps) if steps else None


def restore_checkpoint(root, *, step: Optional[int] = None, target=None,
                       shardings=None) -> tuple:
    """-> (state, step, extra). `target` (a pytree of the same structure)
    and/or `shardings` (tree of NamedSharding or None) control placement:
    leaves go straight into the new plan's shardings (reshard-on-load)."""
    root = Path(root)
    if step is None:
        step = latest_step(root)
        if step is None:
            raise FileNotFoundError(f"no committed checkpoint under {root}")
    d = root / f"step_{step:09d}"
    manifest = json.loads((d / "MANIFEST.json").read_text())
    from jax.tree_util import tree_unflatten

    # rebuild treedef: prefer the target's structure (robust across jax
    # versions); fall back to the serialized one
    leaves = [np.load(d / f"leaf_{i:05d}.npy") for i in range(manifest["n_leaves"])]
    if target is not None:
        tdef = jax.tree_util.tree_structure(target)
    else:
        from jax.tree_util import PyTreeDef

        tdef = PyTreeDef.deserialize_using_proto(
            bytes.fromhex(manifest["treedef"])
        )
    assert tdef.num_leaves == len(leaves), (tdef.num_leaves, len(leaves))
    if shardings is not None:
        shard_leaves = tdef.flatten_up_to(shardings)
        leaves = [
            jax.device_put(l, s) if s is not None else jax.numpy.asarray(l)
            for l, s in zip(leaves, shard_leaves)
        ]
    else:
        leaves = [jax.numpy.asarray(l) for l in leaves]
    return tree_unflatten(tdef, leaves), step, manifest["extra"]


class CheckpointManager:
    """Every-N-steps checkpointing with restart support for the train loop."""

    def __init__(self, root, *, interval: int = 50, keep: int = 2):
        self.root = Path(root)
        self.interval = interval
        self.keep = keep

    def maybe_save(self, state, step: int, extra=None) -> Optional[Path]:
        if step % self.interval == 0 and step > 0:
            return save_checkpoint(self.root, state, step, extra=extra, keep=self.keep)
        return None

    def restore_latest(self, *, target=None, shardings=None):
        return restore_checkpoint(self.root, target=target, shardings=shardings)

    def has_checkpoint(self) -> bool:
        return latest_step(self.root) is not None
