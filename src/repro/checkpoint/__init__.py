"""Checkpoint subsystem.

``RestartCostModel`` (the jax-free economics side) imports eagerly; the
tensor save/restore API lives in ``repro.checkpoint.checkpoint``, which
imports jax, and is loaded lazily so the cluster simulator can price
restart-from-checkpoint without dragging an accelerator runtime into the
event loop.
"""
from repro.checkpoint.economics import RestartCostModel  # noqa: F401

_LAZY = ("CheckpointManager", "latest_step", "restore_checkpoint",
         "save_checkpoint")


def __getattr__(name):
    if name in _LAZY:
        from repro.checkpoint import checkpoint as _ckpt
        return getattr(_ckpt, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")


def __dir__():
    return sorted(list(globals()) + list(_LAZY))
