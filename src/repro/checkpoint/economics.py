"""Checkpoint/restart economics: a deterministic price for the *other*
recovery path.

Live adaptation (replan + TP group rebuild + layer migration) is not always
the cheapest way out of a failure — at fleet scale the baseline trade is
restart-from-checkpoint: tear the job down, relaunch on the surviving (or
re-provisioned) devices, read the last committed checkpoint back, and replay
the lost iterations. :class:`RestartCostModel` prices that path the same way
:class:`~repro.core.scheduler.scheduler.PlanOverheadModel` prices planning —
a small frozen dataclass whose prediction is a pure function of its fields,
so both simulator engines charge identical floats and every sweep cell stays
a pure function of its coordinates.

The model is intentionally jax-free (this module never imports
``repro.checkpoint.checkpoint``, which pulls in jax) so the cluster
simulator can price restarts without dragging an accelerator runtime into
the event loop. :meth:`RestartCostModel.from_manifest` reads a
``repro.checkpoint`` ``MANIFEST.json`` directly and prices the state size
from the recorded per-leaf dtype/shape — the real bytes a restore would
read.

Cost decomposition (seconds)::

    save_cost_s    = state_gb / write_gbps
    restart_cost_s = relaunch_s                      # teardown + scheduler
                   + state_gb / read_gbps            # restore read
                   + lost_work_frac * checkpoint_interval_s   # replayed work

The defaults price a 13B-class state (weights + optimizer moments, ~26 GB)
against aggregate distributed-filesystem bandwidth; with a 20 s checkpoint
cadence they put the restart path at exactly 15 s — above routine
single-failure adaptations (a couple of seconds) but *below* a
mass-repartition that migrates most of the model, which is precisely the
regime where real systems restart instead of adapting.
"""
from __future__ import annotations

import json
import math
from dataclasses import dataclass, replace
from pathlib import Path
from typing import Optional

import numpy as np

__all__ = ["RestartCostModel"]


@dataclass(frozen=True)
class RestartCostModel:
    state_gb: float = 26.0  # total checkpoint payload across all shards
    write_gbps: float = 13.0  # aggregate checkpoint-write bandwidth (GB/s)
    read_gbps: float = 26.0  # aggregate restore-read bandwidth (GB/s)
    relaunch_s: float = 4.0  # teardown + scheduler relaunch + process init
    checkpoint_interval_s: float = 20.0  # commit cadence of the train loop
    lost_work_frac: float = 0.5  # expected replay: half an interval

    def __post_init__(self):
        if self.state_gb < 0:
            raise ValueError("state_gb must be >= 0")
        if self.write_gbps <= 0 or self.read_gbps <= 0:
            raise ValueError("write/read bandwidth must be > 0")
        if self.relaunch_s < 0 or self.checkpoint_interval_s < 0:
            raise ValueError("relaunch_s / checkpoint_interval_s must be >= 0")
        if not (0.0 <= self.lost_work_frac <= 1.0):
            raise ValueError("lost_work_frac must be in [0, 1]")

    # ------------------------------------------------------------- pricing
    def save_cost_s(self) -> float:
        """Seconds one checkpoint commit steals from training."""
        return self.state_gb / self.write_gbps

    def restore_read_s(self) -> float:
        return self.state_gb / self.read_gbps

    def lost_work_s(self) -> float:
        """Expected training progress discarded by rolling back to the last
        committed step (uniform failure time within the commit cadence)."""
        return self.lost_work_frac * self.checkpoint_interval_s

    def restart_cost_s(self) -> float:
        """Total modeled cost of restart-from-checkpoint, in the same units
        ``ResiHPPolicy`` charges live adaptation (seconds of stalled
        session)."""
        return self.relaunch_s + self.restore_read_s() + self.lost_work_s()

    # -------------------------------------------------------- construction
    @classmethod
    def from_manifest(cls, root, *, step: Optional[int] = None,
                      **overrides) -> "RestartCostModel":
        """Price ``state_gb`` from a ``repro.checkpoint`` step directory's
        ``MANIFEST.json`` (per-leaf dtype × shape — the exact bytes a
        restore reads back). ``step=None`` picks the latest *committed*
        step, same rule as ``repro.checkpoint.latest_step`` (COMMIT marker
        present, ``.tmp`` staging dirs ignored)."""
        root = Path(root)
        if step is None:
            steps = [int(p.name.split("_")[1]) for p in root.glob("step_*")
                     if (p / "COMMIT").exists() and not p.name.endswith(".tmp")]
            if not steps:
                raise FileNotFoundError(f"no committed checkpoint under {root}")
            step = max(steps)
        manifest = json.loads(
            (root / f"step_{step:09d}" / "MANIFEST.json").read_text())
        n_bytes = sum(np.dtype(leaf["dtype"]).itemsize
                      * math.prod(leaf["shape"])
                      for leaf in manifest["leaves"])
        return replace(cls(state_gb=n_bytes / 1e9), **overrides)
