from repro.kernels.ops import (  # noqa: F401
    block_metadata,
    packed_attention,
    packed_attention_ref,
    packed_flash_attention,
    skipped_block_fraction,
)
