"""Segment-aware (packed) flash attention — Pallas TPU kernel.

The compute hot spot behind the paper's Eq. 1 cost model: with sequence
packing, attention cost is proportional to sum(l_i^2), not N^2 — *if* the
kernel skips (q-block, k-block) tiles that the block-diagonal packing mask
rules out. This kernel makes the paper's cost model physically true on TPU:

  * grid (B, H, nQ, nK) with the KV dimension innermost ("arbitrary"
    semantics) so flash accumulators live in VMEM scratch across KV steps;
  * per-tile skip predicate from precomputed block metadata (segment-id and
    position ranges): tiles with no segment overlap, or entirely above the
    causal diagonal / outside the sliding window, execute no MXU work;
  * BlockSpec tiling: q (1,1,bq,dh), k/v (1,1,bk,dh) in VMEM; bq=bk=128 by
    default — MXU-aligned (128x128) and small enough that q,k,v,acc tiles
    (~4 x 128 x head_dim x 4B) stay well under the ~16 MB v5e VMEM budget;
  * fp32 accumulation with the standard running-max/sum correction;
  * GQA via index-map head folding (kv head = h * K // H).

Validated in interpret mode against `repro.kernels.ref.packed_attention_ref`
across shape/dtype/window sweeps in tests/test_kernels.py.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

try:  # TPU scratch/compiler params (available in interpret mode too)
    from jax.experimental.pallas import tpu as pltpu
except ImportError:  # pragma: no cover
    pltpu = None

NEG_INF = -1e30


def _attn_kernel(
    # inputs (per BlockSpec tile)
    blk_ok_ref, q_ref, k_ref, v_ref, segq_ref, segk_ref, posq_ref, posk_ref,
    # output
    o_ref,
    # scratch
    acc_ref, m_ref, l_ref,
    *, scale, causal, window, n_k_blocks,
):
    ik = pl.program_id(3)

    @pl.when(ik == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    @pl.when(blk_ok_ref[0, 0, 0] != 0)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)  # (bq, dh)
        k = k_ref[0, 0].astype(jnp.float32)  # (bk, dh)
        v = v_ref[0, 0].astype(jnp.float32)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        ) * scale  # (bq, bk)

        seg_q = segq_ref[0]  # (bq,)
        seg_k = segk_ref[0]  # (bk,)
        pos_q = posq_ref[0]
        pos_k = posk_ref[0]
        mask = (seg_q[:, None] == seg_k[None, :]) & (seg_q[:, None] != 0)
        if causal:
            mask &= pos_q[:, None] >= pos_k[None, :]
        if window is not None:
            mask &= (pos_q[:, None] - pos_k[None, :]) < window
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, s.max(axis=-1))
        p = jnp.exp(s - m_new[:, None])
        p = jnp.where(mask, p, 0.0)
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * corr + p.sum(axis=-1)
        acc_ref[...] = acc_ref[...] * corr[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
        )
        m_ref[...] = m_new

    @pl.when(ik == n_k_blocks - 1)
    def _finalize():
        l = l_ref[...]
        safe = jnp.maximum(l, 1e-30)
        out = jnp.where(l[:, None] > 0, acc_ref[...] / safe[:, None], 0.0)
        o_ref[0, 0] = out.astype(o_ref.dtype)


def block_metadata(seg_q, seg_k, pos_q, pos_k, bq, bk, *, causal, window):
    """(B, nQ, nK) int8: 1 iff the tile can contain a visible (q, k) pair.

    Range tests on per-block (min, max) of segment ids and positions: a tile
    is skipped when segment ranges cannot intersect (all-q-max < all-k-min or
    vice versa — exact when ids are sorted, which packing guarantees), when
    it is entirely above the causal diagonal, or entirely left of the window.
    """
    B, Sq = seg_q.shape
    Sk = seg_k.shape[1]
    nq, nk = Sq // bq, Sk // bk
    sq = seg_q.reshape(B, nq, bq)
    sk = seg_k.reshape(B, nk, bk)
    pq = pos_q.reshape(B, nq, bq)
    pk = pos_k.reshape(B, nk, bk)
    # ignore padding (seg==0) in q-range mins via masking with large value
    big = jnp.int32(1 << 30)
    sq_min = jnp.where(sq != 0, sq, big).min(-1)
    sq_max = sq.max(-1)
    sk_min = jnp.where(sk != 0, sk, big).min(-1)
    sk_max = sk.max(-1)
    overlap = (sq_min[:, :, None] <= sk_max[:, None, :]) & (
        sk_min[:, None, :] <= sq_max[:, :, None]
    ) & (sq_max[:, :, None] != 0) & (sk_max[:, None, :] != 0)
    ok = overlap
    if causal:
        ok &= pq.max(-1)[:, :, None] >= pk.min(-1)[:, None, :]
    if window is not None:
        ok &= (pq.max(-1)[:, :, None] - pk.min(-1)[:, None, :]) < window + bq + bk
    return ok.astype(jnp.int8)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "scale", "block_q", "block_k", "interpret"),
)
def packed_flash_attention(q, k, v, seg_q, seg_k, pos_q, pos_k, *,
                           causal=True, window=None, scale=None,
                           block_q=128, block_k=128, interpret=False):
    """q (B,Sq,H,dh); k/v (B,Sk,K,dh) -> (B,Sq,H,dh). See module docstring."""
    B, Sq, H, dh = q.shape
    Sk, K = k.shape[1], k.shape[2]
    if scale is None:
        scale = dh ** -0.5
    bq, bk = min(block_q, Sq), min(block_k, Sk)

    # pad sequence dims to block multiples (padding has seg id 0 => masked)
    def pad_to(x, axis, mult):
        pad = (-x.shape[axis]) % mult
        if pad == 0:
            return x
        widths = [(0, 0)] * x.ndim
        widths[axis] = (0, pad)
        return jnp.pad(x, widths)

    q_p = pad_to(q, 1, bq)
    k_p = pad_to(k, 1, bk)
    v_p = pad_to(v, 1, bk)
    seg_q_p = pad_to(seg_q, 1, bq)
    seg_k_p = pad_to(seg_k, 1, bk)
    pos_q_p = pad_to(pos_q, 1, bq)
    pos_k_p = pad_to(pos_k, 1, bk)
    Sq_p, Sk_p = q_p.shape[1], k_p.shape[1]
    nq, nk = Sq_p // bq, Sk_p // bk

    blk_ok = block_metadata(seg_q_p, seg_k_p, pos_q_p, pos_k_p, bq, bk,
                            causal=causal, window=window)

    # (B, H, S, dh) layout for clean tiles
    qt = q_p.transpose(0, 2, 1, 3)
    kt = k_p.transpose(0, 2, 1, 3)
    vt = v_p.transpose(0, 2, 1, 3)

    kernel = functools.partial(
        _attn_kernel, scale=scale, causal=causal, window=window, n_k_blocks=nk)

    grid = (B, H, nq, nk)
    kv_head = lambda h: h * K // H
    in_specs = [
        pl.BlockSpec((1, 1, 1), lambda b, h, iq, ik: (b, iq, ik)),  # blk_ok
        pl.BlockSpec((1, 1, bq, dh), lambda b, h, iq, ik: (b, h, iq, 0)),  # q
        pl.BlockSpec((1, 1, bk, dh), lambda b, h, iq, ik: (b, kv_head(h), ik, 0)),
        pl.BlockSpec((1, 1, bk, dh), lambda b, h, iq, ik: (b, kv_head(h), ik, 0)),
        pl.BlockSpec((1, bq), lambda b, h, iq, ik: (b, iq)),  # seg_q
        pl.BlockSpec((1, bk), lambda b, h, iq, ik: (b, ik)),  # seg_k
        pl.BlockSpec((1, bq), lambda b, h, iq, ik: (b, iq)),  # pos_q
        pl.BlockSpec((1, bk), lambda b, h, iq, ik: (b, ik)),  # pos_k
    ]
    out_spec = pl.BlockSpec((1, 1, bq, dh), lambda b, h, iq, ik: (b, h, iq, 0))
    scratch = []
    compiler_params = None
    if pltpu is not None:
        scratch = [
            pltpu.VMEM((bq, dh), jnp.float32),
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq,), jnp.float32),
        ]
        try:
            compiler_params = pltpu.CompilerParams(
                dimension_semantics=("parallel", "parallel", "parallel", "arbitrary"))
        except (AttributeError, TypeError):
            try:
                compiler_params = pltpu.TPUCompilerParams(
                    dimension_semantics=("parallel", "parallel", "parallel", "arbitrary"))
            except AttributeError:
                compiler_params = None

    kw = {}
    if compiler_params is not None:
        kw["compiler_params"] = compiler_params
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=in_specs,
        out_specs=out_spec,
        out_shape=jax.ShapeDtypeStruct((B, H, Sq_p, dh), q.dtype),
        scratch_shapes=scratch,
        interpret=interpret,
        **kw,
    )(blk_ok, qt, kt, vt, seg_q_p, seg_k_p, pos_q_p, pos_k_p)
    out = out.transpose(0, 2, 1, 3)
    return out[:, :Sq]


def skipped_block_fraction(seg, pos, bq, bk, *, causal=True, window=None):
    """Fraction of (q,k) tiles skipped for a packed batch — the measured
    counterpart of the paper's sum(l^2)/N^2 ratio."""
    meta = block_metadata(seg, seg, pos, pos, bq, bk, causal=causal, window=window)
    return 1.0 - float(meta.mean())
