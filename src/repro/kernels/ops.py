"""Public jit'd wrapper over the Pallas packed flash attention kernel.

Dispatch: on TPU backends the compiled kernel runs natively; elsewhere
(this CPU container) it executes in interpret mode — same kernel body,
Python evaluation — so correctness is validated end to end.
"""
from __future__ import annotations

import jax

from repro.kernels.packed_flash_attn import (  # noqa: F401
    block_metadata,
    packed_flash_attention,
    skipped_block_fraction,
)
from repro.kernels.ref import packed_attention_ref  # noqa: F401


def _on_tpu() -> bool:
    try:
        return jax.default_backend() == "tpu"
    except Exception:  # pragma: no cover
        return False


def packed_attention(q, k, v, seg_q, seg_k, pos_q, pos_k, *, causal=True,
                     window=None, scale=None, block_q=128, block_k=128,
                     interpret=None):
    """Segment-aware flash attention; auto-selects native vs interpret."""
    if interpret is None:
        interpret = not _on_tpu()
    return packed_flash_attention(
        q, k, v, seg_q, seg_k, pos_q, pos_k,
        causal=causal, window=window, scale=scale,
        block_q=block_q, block_k=block_k, interpret=interpret,
    )
