"""Pure-jnp oracle for the packed flash attention kernel.

Dense masked softmax with exactly the kernel's semantics:
  * block-diagonal packing mask (same nonzero segment id),
  * causal mask on *positions* (packed per-document positions),
  * optional sliding window (pos_q - pos_k < window),
  * GQA (kv heads repeated to query heads),
  * rows with no visible key return 0 (matches the kernel's safe divide).
"""
from __future__ import annotations

import jax.numpy as jnp

NEG_INF = -1e30


def packed_attention_ref(q, k, v, seg_q, seg_k, pos_q, pos_k, *,
                         causal=True, window=None, scale=None):
    """q (B,Sq,H,dh); k/v (B,Sk,K,dh); seg/pos (B,S) int32 -> (B,Sq,H,dh)."""
    B, Sq, H, dh = q.shape
    K = k.shape[2]
    assert H % K == 0
    if scale is None:
        scale = dh ** -0.5
    if K != H:
        k = jnp.repeat(k, H // K, axis=2)
        v = jnp.repeat(v, H // K, axis=2)
    mask = (seg_q[:, :, None] == seg_k[:, None, :]) & (seg_q[:, :, None] != 0)
    if causal:
        mask &= pos_q[:, :, None] >= pos_k[:, None, :]
    if window is not None:
        mask &= (pos_q[:, :, None] - pos_k[:, None, :]) < window
    s = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32), k.astype(jnp.float32))
    s = s * scale
    s = jnp.where(mask[:, None], s, NEG_INF)
    m = s.max(axis=-1, keepdims=True)
    p = jnp.exp(s - m)
    p = jnp.where(mask[:, None], p, 0.0)
    l = p.sum(axis=-1, keepdims=True)
    o = jnp.einsum("bhqk,bkhd->bqhd", p, v.astype(jnp.float32))
    l_q = jnp.swapaxes(l[..., 0], 1, 2)[..., None]  # (B,Sq,H,1)
    o = jnp.where(l_q > 0, o / jnp.maximum(l_q, 1e-30), 0.0)
    return o.astype(q.dtype)
