"""Gradient compression for the slow (DCN / pod-axis) reduction path:
int8 block quantization with error feedback.

At multi-pod scale the inter-pod gradient reduce crosses DCN (~25 GB/s/host
vs 50+ GB/s ICI links); quantizing the pod-axis payload 4x (f32 -> int8 with
per-block scales) cuts that term. Error feedback accumulates the
quantization residual locally and re-injects it next step, which keeps SGD
convergence (Karimireddy et al., "Error Feedback Fixes SignSGD").

Usage inside a train step (pure-jax, shard_map/pjit compatible):

    comp = Int8Compressor(block=256)
    q, scales = comp.compress(grad + state.residual)
    # ... all-reduce / psum the int8 payload + f32 scales over 'pod' ...
    deq = comp.decompress(q, scales)
    new_residual = (grad + state.residual) - deq
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class Int8Compressor:
    block: int = 256

    def _pad(self, flat):
        pad = (-flat.shape[0]) % self.block
        if pad:
            flat = jnp.pad(flat, (0, pad))
        return flat, pad

    def compress(self, x):
        """x: any-shape f32/bf16 -> (int8 codes (n_blocks, block),
        f32 scales (n_blocks,), static meta)."""
        shape = x.shape
        flat = x.astype(jnp.float32).reshape(-1)
        flat, pad = self._pad(flat)
        blocks = flat.reshape(-1, self.block)
        scale = jnp.max(jnp.abs(blocks), axis=1) / 127.0
        safe = jnp.maximum(scale, 1e-30)
        q = jnp.clip(jnp.round(blocks / safe[:, None]), -127, 127).astype(jnp.int8)
        return q, scale, (shape, pad)

    def decompress(self, q, scale, meta):
        shape, pad = meta
        flat = (q.astype(jnp.float32) * scale[:, None]).reshape(-1)
        if pad:
            flat = flat[:-pad]
        return flat.reshape(shape)

    def roundtrip_with_feedback(self, grad, residual):
        """One error-feedback step: returns (dequantized, new_residual)."""
        target = grad.astype(jnp.float32) + residual
        q, s, meta = self.compress(target)
        deq = self.decompress(q, s, meta)
        return deq, target - deq

    def compressed_bytes(self, x) -> int:
        n = x.size
        n_blocks = -(-n // self.block)
        return n_blocks * self.block + 4 * n_blocks  # int8 codes + f32 scales

    def ratio(self, x) -> float:
        return (x.size * x.dtype.itemsize) / self.compressed_bytes(x)


def init_feedback(params):
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def compress_tree(comp: Int8Compressor, grads, residuals):
    """Error-feedback compression over a gradient pytree. Returns
    (dequantized grads, new residuals) — the dequantized values are what the
    slow-fabric all-reduce would carry (int8 + scales on the wire)."""
    flat_g, treedef = jax.tree.flatten(grads)
    flat_r = treedef.flatten_up_to(residuals)
    out_g, out_r = [], []
    for g, r in zip(flat_g, flat_r):
        dq, nr = comp.roundtrip_with_feedback(g, r)
        out_g.append(dq.astype(g.dtype))
        out_r.append(nr)
    return treedef.unflatten(out_g), treedef.unflatten(out_r)
