"""pjit train/serve/prefill step builders.

train_step: microbatched gradient accumulation via lax.scan (comm/compute
overlap falls out of the scan structure under XLA's latency-hiding scheduler),
global-norm clipping, optimizer update. Mixed precision: fp32 master params,
bf16 compute, configurable accumulation dtype.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.models.model import (
    forward_train,
    loss_fn,
    prefill_forward,
    serve_forward,
    stacked_init,
)
from repro.parallel.sharding import ShardingPolicy, split_annotations


@dataclass
class TrainState:
    params: Any
    opt: Any
    step: Any

    def tree(self):
        return {"params": self.params, "opt": self.opt, "step": self.step}


def init_train_state(key, cfg, optimizer):
    annotated = stacked_init(key, cfg)
    params, axes = split_annotations(annotated)
    opt = optimizer.init(params)
    return {"params": params, "opt": opt, "step": jnp.zeros((), jnp.int32)}, axes


def state_axes(cfg, optimizer):
    """Logical-axes tree for the full train state (for sharding without init)."""
    key = jax.random.PRNGKey(0)
    annotated = jax.eval_shape(lambda k: stacked_init(k, cfg), key)
    # eval_shape maps Annot -> Annot with ShapeDtypeStruct values
    params_s, axes = split_annotations(annotated)
    opt_s = jax.eval_shape(optimizer.init, params_s)
    return params_s, opt_s, axes


def sharding_for_state(policy: ShardingPolicy, cfg, optimizer):
    """NamedSharding trees for (params, opt, step) + the state ShapeDtypeStructs."""
    params_s, opt_s, axes = state_axes(cfg, optimizer)

    def pspec(ax, sds):
        return policy.sharding_for(ax, sds.shape)

    params_sh = jax.tree.map(
        lambda ax, s: pspec(ax, s), axes, params_s,
        is_leaf=lambda x: isinstance(x, tuple) and all(a is None or isinstance(a, str) for a in x),
    )

    # Optimizer state mirrors param sharding; factored Adafactor leaves drop
    # the corresponding logical axis (vr drops the last dim, vc the -2nd).
    def map_state(sub):
        def per(ax, s_param, st):
            if isinstance(st, dict):  # adafactor v
                out = {}
                for k, leaf in st.items():
                    if k == "vr":
                        out[k] = pspec(ax[:-1], leaf)
                    elif k == "vc":
                        out[k] = pspec(ax[:-2] + ax[-1:], leaf)
                    else:
                        out[k] = pspec(ax, leaf)
                return out
            return pspec(ax, st)

        return jax.tree.map(
            per, axes, params_s, sub,
            is_leaf=lambda x: isinstance(x, tuple) and all(a is None or isinstance(a, str) for a in x),
        )

    opt_sh = {k: map_state(v) for k, v in opt_s.items()}
    step_sh = policy.sharding_for((), ()) if policy.mesh else None
    state_sh = {"params": params_sh, "opt": opt_sh, "step": step_sh}
    state_s = {"params": params_s, "opt": opt_s, "step": jax.ShapeDtypeStruct((), jnp.int32)}
    return state_sh, state_s, axes


def global_norm(tree):
    return jnp.sqrt(
        sum(jnp.sum(jnp.square(g.astype(jnp.float32))) for g in jax.tree.leaves(tree))
    )


def build_train_step(cfg, policy: ShardingPolicy, optimizer, *, microbatches=1,
                     remat=True, flash_chunk=1024, use_scan=True, clip_norm=1.0,
                     accum_dtype=jnp.float32):
    """Returns train_step(state, batch) -> (state, metrics)."""

    def mb_loss(params, mb):
        return loss_fn(cfg, params, mb, policy, use_scan=use_scan, remat=remat,
                       flash_chunk=flash_chunk)

    grad_fn = jax.value_and_grad(mb_loss, has_aux=True)

    def train_step(state, batch):
        params = state["params"]

        def split_mb(x):
            x = x.reshape((microbatches, x.shape[0] // microbatches) + x.shape[1:])
            return x

        mbs = jax.tree.map(split_mb, batch)

        def accum(carry, mb):
            gacc, lacc = carry
            (loss, metrics), grads = grad_fn(params, mb)
            grads = jax.tree.map(lambda a, g: a + g.astype(accum_dtype), gacc, grads)
            return (grads, lacc + loss), metrics

        zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, accum_dtype), params)
        (grads, loss_sum), metrics = jax.lax.scan(accum, (zeros, jnp.zeros((), jnp.float32)), mbs)
        grads = jax.tree.map(lambda g: (g / microbatches).astype(jnp.float32), grads)
        gnorm = global_norm(grads)
        scale = jnp.minimum(1.0, clip_norm / jnp.maximum(gnorm, 1e-9))
        grads = jax.tree.map(lambda g: g * scale, grads)
        new_params, new_opt = optimizer.update(grads, state["opt"], params, state["step"])
        new_state = {"params": new_params, "opt": new_opt, "step": state["step"] + 1}
        out_metrics = {
            "loss": loss_sum / microbatches,
            "grad_norm": gnorm,
            "ntokens": metrics["ntokens"].sum(),
        }
        return new_state, out_metrics

    return train_step


def build_serve_step(cfg, policy: ShardingPolicy, *, sample="greedy"):
    """serve_step(params, cache, batch) -> (next_tokens, logits, cache)."""

    def serve_step(params, cache, batch):
        logits, cache = serve_forward(cfg, params, cache, batch, policy)
        next_tokens = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
        return next_tokens, logits, cache

    return serve_step


def build_prefill_step(cfg, policy: ShardingPolicy, *, flash_chunk=1024, use_scan=True):
    """prefill_step(params, batch) -> (last_logits, caches)."""

    def prefill_step(params, batch):
        return prefill_forward(cfg, params, batch, policy, use_scan=use_scan,
                               flash_chunk=flash_chunk)

    return prefill_step
