"""Optimizers: AdamW and Adafactor(+momentum).

Adafactor (factored second moment, bf16 momentum) is the default above 20B
parameters: on v5e (16 GB HBM) fp32 Adam moments for a 398B model exceed the
whole pod's HBM; factored-v + bf16-m is the standard TPU answer (T5X/MaxText).
Optimizer state inherits each parameter's sharding (ZeRO-1 comes free: the
FSDP axis of the param spec shards the moments too).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class Optimizer:
    name: str
    init: Callable[[Any], Any]
    update: Callable[[Any, Any, Any, Any], tuple]  # (grads, state, params, step) -> (params, state)
    lr: float


def _tree_map(f, *trees, **kw):
    return jax.tree.map(f, *trees, **kw)


def make_optimizer(name="adamw", lr=3e-4, b1=0.9, b2=0.95, eps=1e-8,
                   weight_decay=0.01, momentum_dtype=jnp.float32):
    if name == "adamw":
        def init(params):
            z = _tree_map(jnp.zeros_like, params)
            return {"m": z, "v": _tree_map(jnp.zeros_like, params)}

        def update(grads, state, params, step):
            stepf = step.astype(jnp.float32) + 1.0
            bc1 = 1.0 - b1 ** stepf
            bc2 = 1.0 - b2 ** stepf
            m = _tree_map(lambda m, g: b1 * m + (1 - b1) * g, state["m"], grads)
            v = _tree_map(lambda v, g: b2 * v + (1 - b2) * jnp.square(g), state["v"], grads)
            def upd(p, m_, v_):
                u = (m_ / bc1) / (jnp.sqrt(v_ / bc2) + eps)
                return (p - lr * (u + weight_decay * p)).astype(p.dtype)
            params = _tree_map(upd, params, m, v)
            return params, {"m": m, "v": v}

        return Optimizer("adamw", init, update, lr)

    if name == "adafactor":
        def _factored(shape):
            return len(shape) >= 2

        def init(params):
            def vstate(p):
                if _factored(p.shape):
                    return {
                        "vr": jnp.zeros(p.shape[:-1], jnp.float32),
                        "vc": jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32),
                    }
                return {"v": jnp.zeros_like(p, dtype=jnp.float32)}

            return {
                "m": _tree_map(lambda p: jnp.zeros_like(p, dtype=momentum_dtype), params),
                "v": _tree_map(vstate, params, is_leaf=lambda x: hasattr(x, "shape")),
            }

        def update(grads, state, params, step):
            stepf = step.astype(jnp.float32) + 1.0
            decay = 1.0 - stepf ** -0.8  # t^-0.8 schedule (Adafactor paper)

            def upd(p, g, m, v):
                g = g.astype(jnp.float32)
                g2 = jnp.square(g) + 1e-30
                if _factored(p.shape):
                    vr = decay * v["vr"] + (1 - decay) * g2.mean(axis=-1)
                    vc = decay * v["vc"] + (1 - decay) * g2.mean(axis=-2)
                    vhat = vr[..., None] * vc[..., None, :] / jnp.maximum(
                        vr.mean(axis=-1)[..., None, None], 1e-30
                    )
                    new_v = {"vr": vr, "vc": vc}
                else:
                    vhat = decay * v["v"] + (1 - decay) * g2
                    new_v = {"v": vhat}
                u = g * jax.lax.rsqrt(vhat + 1e-30)
                # update clipping (RMS <= 1)
                rms = jnp.sqrt(jnp.mean(jnp.square(u)) + 1e-30)
                u = u / jnp.maximum(1.0, rms)
                new_m = (b1 * m.astype(jnp.float32) + (1 - b1) * u).astype(m.dtype)
                new_p = (p - lr * (new_m.astype(jnp.float32) + weight_decay * p)).astype(p.dtype)
                return new_p, new_m, new_v

            flat_p, treedef = jax.tree.flatten(params)
            flat_g = treedef.flatten_up_to(grads)
            flat_m = treedef.flatten_up_to(state["m"])
            flat_v = treedef.flatten_up_to(state["v"])
            out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
            params = treedef.unflatten([o[0] for o in out])
            m = treedef.unflatten([o[1] for o in out])
            v = treedef.unflatten([o[2] for o in out])
            return params, {"m": m, "v": v}

        return Optimizer("adafactor", init, update, lr)

    raise ValueError(name)


def optimizer_for(cfg, lr=3e-4):
    """Pick the optimizer by model scale (HBM-driven)."""
    big = cfg.param_count() > 20_000_000_000
    return make_optimizer(
        "adafactor" if big else "adamw",
        lr=lr,
        momentum_dtype=jnp.bfloat16 if big else jnp.float32,
    )
