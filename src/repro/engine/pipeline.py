"""Host-orchestrated pipeline-parallel engine (paper §7's runtime, in JAX).

The Scheduler emits per-stage instruction streams (Forward / Backward /
SendAct / RecvAct / reduce); a lightweight interpreter executes them against
per-stage meshes. This is the engine that *actually runs* ParallelPlans —
reduced configs on the CPU container's host devices, the same code on a TPU
slice — and is what the fault-injection integration tests drive end to end
(kill a device, Scheduler re-plans, recovery reshards, training resumes).

Key properties:
  * per-stage meshes over explicit device sets -> heterogeneous TP degrees
    across stages/replicas are first-class (§6.1);
  * stage boundaries move tensors with `jax.device_put` (resharding-on-
    transfer = the §7 scatter/gather rule in XLA terms);
  * backward recomputes the stage forward under `jax.vjp` (activation
    recomputation — only boundary activations are stored);
  * DP gradient reduction is exact averaging across replica groups;
  * micro-batch migration executes a chunk on a peer replica's stage params
    (replicas are synchronized, so the math is identical — Fig. 6b).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.scheduler.plan import ParallelPlan
from repro.engine.schedules import make_schedule
from repro.launch.mesh import make_stage_mesh
from repro.models.layers import rms_norm
from repro.models.model import apply_layer, embed_tokens, init_params, lm_logits
from repro.parallel.sharding import (
    NULL_POLICY,
    ShardingPolicy,
    policy_for_mesh,
    split_annotations,
)


def _mb_loss(cfg, logits, labels):
    """-> (nll_sum, n_tokens): summed so the host can form the exact global
    token-weighted mean across micro-batches and replicas."""
    mask = (labels >= 0).astype(jnp.float32)
    labels_c = jnp.maximum(labels, 0)
    logits = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(logits, labels_c[..., None], axis=-1)[..., 0]
    nll = (lse - ll) * mask
    return nll.sum(), mask.sum()


class PipelineEngine:
    """Executes one ParallelPlan with real per-stage computation."""

    def __init__(self, cfg, plan: ParallelPlan, *, optimizer=None, seed=0,
                 devices=None, flash_chunk=None):
        self.cfg = cfg
        self.optimizer = optimizer
        self.devices = devices if devices is not None else jax.devices()
        self.flash_chunk = flash_chunk
        # full list-layout params (fp32 master), replicated across replicas
        annotated = init_params(jax.random.PRNGKey(seed), cfg)
        self.params_full, self.axes_full = split_annotations(annotated)
        self.opt_state = optimizer.init(self.params_full) if optimizer else None
        self.step = 0
        self.plan = None
        self.meshes: dict = {}
        self.policies: dict = {}
        self.apply_plan(plan)

    # ----------------------------------------------------------- plan mgmt
    def _mesh_for(self, stage_plan):
        devs = [self.devices[d % len(self.devices)] for d in stage_plan.devices]
        # fewer physical devices than the plan's TP degree (CPU smoke runs):
        # degrade to the unique device set — semantics preserved, TP emulated
        uniq = list(dict.fromkeys(devs))
        if len(uniq) < len(devs):
            devs = uniq[:1]
        return make_stage_mesh(devs, 1, len(devs))

    def apply_plan(self, plan: ParallelPlan):
        """(Re)build meshes + per-stage placements for a plan — the JAX
        analogue of 'destroy and rebuild communication groups'."""
        self.plan = plan
        self.meshes, self.policies = {}, {}
        self._jit_cache = {}  # stage fns close over plan/policies: invalidate
        for r, rep in enumerate(plan.replicas):
            for s, st in enumerate(rep.stages):
                if not st.devices:
                    continue
                mesh = self._mesh_for(st)
                self.meshes[(r, s)] = mesh
                pol = policy_for_mesh(mesh, shard_batch=False)
                tp = pol.tp
                if tp and self.cfg.n_heads % tp == 0:
                    pol = pol.replace(attn_shard="heads")
                elif tp and self.cfg.head_dim % tp == 0:
                    pol = pol.replace(attn_shard="head_dim")
                else:
                    pol = pol.replace(attn_shard=None)
                self.policies[(r, s)] = pol

    def stage_params(self, r: int, s: int):
        """Stage layer params + (first/last extras), placed on the stage mesh."""
        st = self.plan.replicas[r].stages[s]
        pol = self.policies[(r, s)]
        layers = [self.params_full["layers"][l] for l in st.layers]
        ax_layers = [self.axes_full["layers"][l] for l in st.layers]
        p = {"layers": layers}
        ax = {"layers": ax_layers}
        if s == 0:
            p["embed"] = self.params_full["embed"]
            ax["embed"] = self.axes_full["embed"]
        if s == self.plan.replicas[r].pp - 1:
            p["final_norm"] = self.params_full["final_norm"]
            ax["final_norm"] = self.axes_full["final_norm"]
            if "lm_head" in self.params_full:
                p["lm_head"] = self.params_full["lm_head"]
                ax["lm_head"] = self.axes_full["lm_head"]
        shardings = jax.tree.map(
            lambda a, v: pol.sharding_for(a, v.shape), ax, p,
            is_leaf=lambda x: isinstance(x, tuple)
            and all(isinstance(e, str) or e is None for e in x),
        )
        placed = jax.tree.map(
            lambda v, sh: jax.device_put(v, sh) if sh is not None else v, p, shardings)
        return placed, ax

    # ----------------------------------------------------- stage functions
    def _md(self, batch_mb):
        seg = batch_mb["segment_ids"]
        B, S = seg.shape
        return {
            "segment_ids": seg,
            "positions": batch_mb["positions"],
            "abs_positions": jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S)),
        }

    def _stage_apply(self, r, s, p, x, md, *, tokens=None, labels=None):
        cfg, pol = self.cfg, self.policies[(r, s)]
        st = self.plan.replicas[r].stages[s]
        md = dict(md)  # static fields stay out of the traced arguments
        md["flash_chunk"] = self.flash_chunk or max(int(md["segment_ids"].shape[1]) // 2, 16)
        md["causal"] = True
        if s == 0:
            x = embed_tokens(cfg, p, tokens)
        for i, l in enumerate(st.layers):
            spec = cfg.layer_spec(l)
            x, _ = apply_layer(cfg, spec, p["layers"][i], x, md, pol)
        if s == self.plan.replicas[r].pp - 1:
            x = rms_norm(x, p["final_norm"], cfg.norm_eps)
            logits = lm_logits(cfg, p, x, pol)
            return _mb_loss(cfg, logits, labels)
        return x

    # one forward and one forward+vjp per (replica, stage); jit-cached
    def _get_fns(self, r, s):
        key = (r, s)
        if not hasattr(self, "_jit_cache"):
            self._jit_cache = {}
        if key not in self._jit_cache:
            def fwd(p, x, md, tokens, labels):
                return self._stage_apply(r, s, p, x, md, tokens=tokens, labels=labels)

            def bwd(p, x, md, g, tokens, labels):
                _, vjp = jax.vjp(
                    lambda p, x: self._stage_apply(
                        r, s, p, x, md, tokens=tokens, labels=labels),
                    p, x)
                return vjp(g)

            self._jit_cache[key] = (jax.jit(fwd), jax.jit(bwd))
        return self._jit_cache[key]

    def _fwd(self, r, s, p, x, md, tokens=None, labels=None):
        return self._get_fns(r, s)[0](p, x, md, tokens, labels)

    def _bwd(self, r, s, p, x, md, g, tokens=None, labels=None):
        return self._get_fns(r, s)[1](p, x, md, g, tokens, labels)

    # -------------------------------------------------------- interpreter
    def run_iteration(self, batch, *, placement: Optional[dict] = None):
        """One training iteration: interpret the schedule's instruction
        streams per (replica, stage). Returns (mean_loss, grads_applied).

        placement: optional {ChunkId -> (replica, stage)} micro-batch
        migration overrides from the Scheduler (Fig. 6b).
        """
        cfg, plan = self.cfg, self.plan
        placement = placement or {}
        dp, pp, n_mb = plan.dp, plan.replicas[0].pp, plan.microbatches
        B = batch["tokens"].shape[0]
        assert B % (dp * n_mb) == 0, (B, dp, n_mb)
        mb_size = B // (dp * n_mb)

        def mb_slice(r, m):
            lo = (r * n_mb + m) * mb_size
            return {k: v[lo: lo + mb_size] for k, v in batch.items()}

        params = {}
        for r in range(dp):
            for s in range(pp):
                params[(r, s)], _ = self.stage_params(r, s)

        acts: dict = {}  # (r, m, s) -> boundary activation into stage s
        grads_in: dict = {}  # (r, m, s) -> gradient flowing into stage s's output
        losses = []
        grad_acc: dict = {}

        schedules = {}
        for r in range(dp):
            schedules.update(make_schedule(plan.schedule, pp, n_mb, replica=r))

        # topological interpretation: round-robin over executors, running the
        # head instruction when its inputs are available (host = orchestrator)
        queues = {e: list(order) for e, order in schedules.items()}
        done: set = set()
        progress = True
        while any(queues.values()):
            if not progress:
                raise RuntimeError("pipeline interpreter deadlock")
            progress = False
            for e, q in queues.items():
                if not q:
                    continue
                cid = q[0]
                r, s, m = cid.replica, cid.stage, cid.mb
                exec_rs = placement.get(cid, (r, s))
                mb = mb_slice(r, m)
                md = self._md(mb)
                if cid.kind == "F":
                    if s > 0 and (r, m, s) not in acts:
                        continue
                    p = params[exec_rs]
                    x_in = acts.get((r, m, s))
                    if s == 0:
                        x_in = jnp.zeros((mb_size, 1), jnp.float32)  # unused
                    out = self._fwd(exec_rs[0], s, p, x_in, md,
                                    tokens=mb["tokens"] if s == 0 else None,
                                    labels=mb["labels"] if s == pp - 1 else None)
                    if s == pp - 1:
                        losses.append(out)  # (nll_sum, n_tokens)
                        grads_in[(r, m, s)] = (
                            jnp.ones((), jnp.float32), jnp.zeros((), jnp.float32))
                    else:
                        nxt = (r, s + 1)
                        tgt_pol = self.policies.get(placement.get(
                            type(cid)("F", m, s + 1, r), nxt))
                        y = out
                        if tgt_pol is not None and tgt_pol.mesh is not None:
                            y = jax.device_put(
                                y, tgt_pol.sharding_for(("batch", "seq", None), y.shape))
                        acts[(r, m, s + 1)] = y  # SendAct -> RecvAct
                    done.add(cid)
                    q.pop(0)
                    progress = True
                elif cid.kind == "B":
                    if (r, m, s) not in grads_in:
                        continue
                    p = params[exec_rs]
                    x_in = acts.get((r, m, s))
                    if s == 0:
                        x_in = jnp.zeros((mb_size, 1), jnp.float32)
                    g = grads_in.pop((r, m, s))
                    p_grad, x_grad = self._bwd(
                        exec_rs[0], s, p, x_in, md, g,
                        tokens=mb["tokens"] if s == 0 else None,
                        labels=mb["labels"] if s == pp - 1 else None)
                    key = (r, s)
                    if key not in grad_acc:
                        grad_acc[key] = p_grad
                    else:
                        grad_acc[key] = jax.tree.map(jnp.add, grad_acc[key], p_grad)
                    if s > 0:
                        prev_pol = self.policies[(r, s - 1)]
                        gx = jax.device_put(
                            x_grad,
                            prev_pol.sharding_for(("batch", "seq", None), x_grad.shape))
                        grads_in[(r, m, s - 1)] = gx
                    acts.pop((r, m, s), None)
                    done.add(cid)
                    q.pop(0)
                    progress = True
                else:  # W chunks: weight grads were folded into B here
                    done.add(cid)
                    q.pop(0)
                    progress = True

        nll_total = sum(float(l[0]) for l in losses)
        ntok_total = sum(float(l[1]) for l in losses)
        loss = nll_total / max(ntok_total, 1.0)
        self._apply_grads(grad_acc, ntok_total)
        return float(loss), grad_acc

    # ------------------------------------------------------------- update
    def _apply_grads(self, grad_acc, total_tokens):
        """DP-reduce per-stage grads, scatter into the full tree, update."""
        if self.optimizer is None:
            return
        cfg, plan = self.cfg, self.plan
        dp, pp = plan.dp, plan.replicas[0].pp
        full_grads = jax.tree.map(jnp.zeros_like, self.params_full)
        for s in range(pp):
            st = plan.replicas[0].stages[s]
            reduced = None
            for r in range(dp):
                g = grad_acc.get((r, s))
                if g is None:
                    continue
                g = jax.device_get(g)
                reduced = g if reduced is None else jax.tree.map(np.add, reduced, g)
            if reduced is None:
                continue
            scale = 1.0 / max(total_tokens, 1.0)
            reduced = jax.tree.map(lambda a: jnp.asarray(a, jnp.float32) * scale, reduced)
            for i, l in enumerate(st.layers):
                full_grads["layers"][l] = reduced["layers"][i]
            if s == 0:
                full_grads["embed"] = reduced["embed"]
            if s == pp - 1:
                full_grads["final_norm"] = reduced["final_norm"]
                if "lm_head" in reduced:
                    full_grads["lm_head"] = reduced["lm_head"]
        self.params_full, self.opt_state = self.optimizer.update(
            full_grads, self.opt_state, self.params_full, jnp.asarray(self.step))
        self.step += 1
