"""Pipeline schedules: GPipe, 1F1B [PipeDream, 33], ZB-H1 [zero-bubble, 37].

A schedule is a dict: executor (replica, stage) -> ordered list of ChunkIds.
ZB-H1 splits the backward into B (activation grad, on the critical path) and
W (weight grad, fills bubbles) — the same F/B/W decomposition the paper's
Detector and Scheduler use (§5.2, §6.3).
"""
from __future__ import annotations

from repro.core.detector.dag_sim import ChunkId


def gpipe(n_stages, n_mb, replica=0):
    out = {}
    for s in range(n_stages):
        order = [ChunkId("F", m, s, replica) for m in range(n_mb)]
        order += [ChunkId("B", m, s, replica) for m in reversed(range(n_mb))]
        out[(replica, s)] = order
    return out


def one_f_one_b(n_stages, n_mb, replica=0):
    """Standard 1F1B: stage s runs (n_stages - s) warm-up forwards, then
    alternates 1B/1F, then drains. B here is the full backward (B+W fused)."""
    out = {}
    for s in range(n_stages):
        warmup = min(n_stages - s, n_mb)
        order = [ChunkId("F", m, s, replica) for m in range(warmup)]
        nf, nb = warmup, 0
        while nb < n_mb:
            order.append(ChunkId("B", nb, s, replica))
            nb += 1
            if nf < n_mb:
                order.append(ChunkId("F", nf, s, replica))
                nf += 1
        out[(replica, s)] = order
    return out


def zb_h1(n_stages, n_mb, replica=0):
    """ZB-H1 (zero-bubble, handcrafted schedule 1): like 1F1B but backward is
    split; W chunks are deferred to fill the drain bubble."""
    out = {}
    for s in range(n_stages):
        warmup = min(n_stages - s, n_mb)
        order = [ChunkId("F", m, s, replica) for m in range(warmup)]
        nf, nb, nw = warmup, 0, 0
        while nb < n_mb:
            order.append(ChunkId("B", nb, s, replica))
            nb += 1
            if nf < n_mb:
                order.append(ChunkId("F", nf, s, replica))
                nf += 1
            else:
                # drain phase: interleave deferred W chunks
                if nw < nb - 1:
                    order.append(ChunkId("W", nw, s, replica))
                    nw += 1
        while nw < n_mb:
            order.append(ChunkId("W", nw, s, replica))
            nw += 1
        out[(replica, s)] = order
    return out


def make_schedule(name, n_stages, n_mb, replica=0):
    if name in ("1f1b", "1F1B"):
        return one_f_one_b(n_stages, n_mb, replica)
    if name.lower() in ("zb", "zbh1", "zb-h1"):
        return zb_h1(n_stages, n_mb, replica)
    if name.lower() == "gpipe":
        return gpipe(n_stages, n_mb, replica)
    raise ValueError(name)


def has_w_chunks(name):
    return name.lower() in ("zb", "zbh1", "zb-h1")
