"""Mesh construction. Functions, not module-level constants, so importing
never touches jax device state."""
from __future__ import annotations

import jax
import numpy as np
from jax.sharding import Mesh


def make_production_mesh(*, multi_pod: bool = False):
    """The production grid: one v5e pod (16x16) or two pods (2x16x16)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes, axis_types=(jax.sharding.AxisType.Auto,) * len(axes))


def make_mesh(shape, axes, devices=None):
    """Arbitrary mesh over an explicit device list (the ResiHP Scheduler uses
    this to build stage meshes over the surviving-device set)."""
    if devices is None:
        return jax.make_mesh(shape, axes, axis_types=(jax.sharding.AxisType.Auto,) * len(axes))
    arr = np.asarray(devices).reshape(shape)
    return Mesh(arr, axes)


def make_stage_mesh(devices, dp, tp):
    """A (data, model) mesh for one pipeline stage from an explicit device list."""
    return make_mesh((dp, tp), ("data", "model"), devices=devices)
