import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell from
ShapeDtypeStructs only, record memory/cost analysis + the HLO-walker roofline
terms. The two lines above MUST stay first: jax locks the device count on
first init.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3-8b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod] [--out results/dryrun]
"""
import argparse
import json
import time
import traceback
from pathlib import Path

import jax

from repro.configs import ASSIGNED_ARCHS, SHAPES_BY_NAME, get_arch
from repro.launch.mesh import make_production_mesh
from repro.launch.specs import input_specs
from repro.parallel.sharding import policy_for_mesh
from repro.roofline.analysis import V5E, roofline_terms
from repro.roofline.hlo import analyze_hlo_text
from repro.train.train_step import build_prefill_step, build_serve_step, build_train_step


def policy_for_cell(mesh, cfg, shape):
    dp = 1
    for a in ("pod", "data"):
        if a in mesh.axis_names:
            dp *= mesh.shape[a]
    policy = policy_for_mesh(mesh, shard_batch=shape.global_batch >= dp)
    tp = policy.tp
    if tp and cfg.n_heads % tp == 0:
        attn = "heads"
    elif tp and cfg.head_dim % tp == 0:
        attn = "head_dim"
    else:
        attn = None
    return policy.replace(attn_shard=attn)


def step_fn_for_cell(cfg, shape, policy, opt, *, microbatches=None, flash_chunk=1024,
                     remat=True):
    if shape.kind == "train":
        if microbatches is None:
            microbatches = max(1, shape.global_batch // max(policy.dp, 1))
        return build_train_step(
            cfg, policy, opt, microbatches=microbatches, remat=remat,
            flash_chunk=flash_chunk,
            accum_dtype=jax.numpy.bfloat16 if cfg.param_count() > 5e10 else jax.numpy.float32,
        )
    if shape.kind == "prefill":
        return build_prefill_step(cfg, policy, flash_chunk=flash_chunk)
    return build_serve_step(cfg, policy)


def run_cell(arch_id, shape_name, *, multi_pod=False, out_dir=None, save_hlo=False,
             policy_overrides=None, tag="baseline", cfg_overrides=None,
             microbatches=None, remat=True, flash_chunk=1024):
    cfg = get_arch(arch_id)
    if cfg_overrides:
        import dataclasses
        cfg = dataclasses.replace(cfg, **cfg_overrides)
    shape = SHAPES_BY_NAME[shape_name]
    rec = {
        "arch": arch_id, "shape": shape_name, "multi_pod": multi_pod, "tag": tag,
        "status": "ok",
    }
    if shape_name in cfg.shape_skips:
        rec["status"] = "skipped"
        rec["reason"] = cfg.shape_skips[shape_name]
        if out_dir:
            out = Path(out_dir)
            out.mkdir(parents=True, exist_ok=True)
            name = f"{arch_id}__{shape_name}__{'pod2' if multi_pod else 'pod1'}__{tag}"
            (out / f"{name}.json").write_text(json.dumps(rec, indent=2))
        return rec

    t0 = time.time()
    mesh = make_production_mesh(multi_pod=multi_pod)
    n_dev = mesh.size
    policy = policy_for_cell(mesh, cfg, shape)
    if policy_overrides:
        policy = policy.replace(**policy_overrides)
    args_s, shardings, opt = input_specs(cfg, shape, policy)
    step = step_fn_for_cell(cfg, shape, policy, opt, microbatches=microbatches,
                            remat=remat, flash_chunk=flash_chunk)

    with mesh:
        jitted = jax.jit(step, in_shardings=shardings,
                         out_shardings=None, donate_argnums=(0,) if shape.kind != "prefill" else ())
        lowered = jitted.lower(*args_s)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

    ma = compiled.memory_analysis()
    ca = compiled.cost_analysis() or {}
    text = compiled.as_text()
    cost = analyze_hlo_text(text)
    terms = roofline_terms(cost, n_dev, cfg, shape)
    from repro.roofline.analysis import optimized_roofline

    opt_terms = optimized_roofline(cost, n_dev, cfg, shape, tp=policy.tp or 1)

    rec.update({
        "n_devices": n_dev,
        "mesh": dict(zip(mesh.axis_names, [int(mesh.shape[a]) for a in mesh.axis_names])),
        "lower_s": round(t_lower, 2),
        "compile_s": round(t_compile, 2),
        "memory_analysis": {
            "argument_bytes": int(getattr(ma, "argument_size_in_bytes", 0)),
            "output_bytes": int(getattr(ma, "output_size_in_bytes", 0)),
            "temp_bytes": int(getattr(ma, "temp_size_in_bytes", 0)),
            "alias_bytes": int(getattr(ma, "alias_size_in_bytes", 0)),
        },
        "xla_cost_analysis": {
            "flops": float(ca.get("flops", 0.0)),
            "bytes_accessed": float(ca.get("bytes accessed", 0.0)),
        },
        "walker": cost.as_dict(),
        "roofline": terms,
        "roofline_optimized": opt_terms,
    })
    # HBM fit check: params+opt+temps must fit per device
    per_dev_state = rec["memory_analysis"]["argument_bytes"]
    per_dev_temp = rec["memory_analysis"]["temp_bytes"]
    rec["hbm_model"] = {
        "per_device_bytes": per_dev_state + per_dev_temp,
        "capacity_bytes": int(V5E.hbm_bytes),
        "fits": bool(per_dev_state + per_dev_temp <= V5E.hbm_bytes),
    }

    if out_dir:
        out = Path(out_dir)
        out.mkdir(parents=True, exist_ok=True)
        name = f"{arch_id}__{shape_name}__{'pod2' if multi_pod else 'pod1'}__{tag}"
        (out / f"{name}.json").write_text(json.dumps(rec, indent=2, default=str))
        if save_hlo:
            import gzip
            with gzip.open(out / f"{name}.hlo.gz", "wt") as f:
                f.write(text)
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--out", default="results/dryrun")
    ap.add_argument("--save-hlo", action="store_true")
    ap.add_argument("--skip-existing", action="store_true")
    args = ap.parse_args()

    cells = []
    archs = list(ASSIGNED_ARCHS) if (args.all or not args.arch) else [args.arch]
    shapes = list(SHAPES_BY_NAME) if (args.all or not args.shape) else [args.shape]
    meshes = [False, True] if args.both_meshes else [args.multi_pod]
    for a in archs:
        for s in shapes:
            for mp in meshes:
                cells.append((a, s, mp))

    n_ok = n_skip = n_fail = 0
    for a, s, mp in cells:
        name = f"{a}__{s}__{'pod2' if mp else 'pod1'}__baseline"
        if args.skip_existing and (Path(args.out) / f"{name}.json").exists():
            print(f"[dryrun] {name}: exists, skipping")
            continue
        t0 = time.time()
        try:
            rec = run_cell(a, s, multi_pod=mp, out_dir=args.out, save_hlo=args.save_hlo)
            if rec["status"] == "skipped":
                n_skip += 1
                print(f"[dryrun] {name}: SKIP ({rec['reason']})")
            else:
                n_ok += 1
                r = rec["roofline"]
                print(
                    f"[dryrun] {name}: OK {time.time()-t0:.0f}s "
                    f"bound={r['bound']} compute={r['compute_s']:.4f}s "
                    f"mem={r['memory_s']:.4f}s coll={r['collective_s']:.4f}s "
                    f"frac={r.get('roofline_fraction', 0):.3f} "
                    f"fits={rec['hbm_model']['fits']}"
                )
        except Exception as e:  # noqa: BLE001 — record failures, keep sweeping
            n_fail += 1
            err = {"arch": a, "shape": s, "multi_pod": mp, "status": "error",
                   "error": str(e), "traceback": traceback.format_exc()}
            Path(args.out).mkdir(parents=True, exist_ok=True)
            (Path(args.out) / f"{name}.json").write_text(json.dumps(err, indent=2))
            print(f"[dryrun] {name}: FAIL {e}")
    print(f"[dryrun] done ok={n_ok} skip={n_skip} fail={n_fail}")


if __name__ == "__main__":
    main()
