"""ShapeDtypeStruct input stand-ins + shardings for every (arch x shape) cell.

No device allocation: everything here is shapes. Used by the dry-run, the
roofline harness, and the benchmarks.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ArchConfig, ShapeSpec
from repro.models.model import init_cache, stacked_init
from repro.parallel.sharding import ShardingPolicy, split_annotations

S32 = jnp.int32


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def train_batch_specs(cfg: ArchConfig, shape: ShapeSpec, *, with_labels=True):
    GB, S = shape.global_batch, shape.seq_len
    if cfg.enc_dec:
        Sd = max(S // cfg.dec_ratio, 16)
        b = {
            "frame_embeds": _sds((GB, S, cfg.d_model), jnp.bfloat16),
            "enc_segment_ids": _sds((GB, S), S32),
            "enc_positions": _sds((GB, S), S32),
            "dec_tokens": _sds((GB, Sd), S32),
            "dec_segment_ids": _sds((GB, Sd), S32),
            "dec_positions": _sds((GB, Sd), S32),
        }
        if with_labels:
            b["labels"] = _sds((GB, Sd), S32)
        return b
    b = {
        "tokens": _sds((GB, S), S32),
        "segment_ids": _sds((GB, S), S32),
        "positions": _sds((GB, S, 3), S32) if cfg.mrope_sections else _sds((GB, S), S32),
    }
    if cfg.vlm:
        b["vision_embeds"] = _sds((GB, S // 4, cfg.d_model), jnp.bfloat16)
    if with_labels:
        b["labels"] = _sds((GB, S), S32)
    return b


def decode_batch_specs(cfg: ArchConfig, shape: ShapeSpec):
    GB, S = shape.global_batch, shape.seq_len
    b = {"tokens": _sds((GB, 1), S32), "lengths": _sds((GB,), S32)}
    if cfg.enc_dec:
        b["cross_segment_ids"] = _sds((GB, S), S32)
        b["cross_positions"] = _sds((GB, S), S32)
    return b


def batch_shardings(policy: ShardingPolicy, batch_specs):
    """Shard dim 0 (global batch) over the DP axes."""
    if policy.mesh is None:
        return None
    bspec = policy.batch_spec()

    def one(s):
        return NamedSharding(policy.mesh, P(*(bspec + (None,) * (len(s.shape) - len(bspec)))))

    return jax.tree.map(one, batch_specs)


# ------------------------------------------------------------------ caches
_CACHE_AXES = {
    "k": ("layers", "batch", "kv_seq", "kv_heads", "head_dim"),
    "v": ("layers", "batch", "kv_seq", "kv_heads", "head_dim"),
    "pos": ("layers", "batch", "kv_seq"),
    "k_const": ("layers", "batch", "kv_seq", "kv_heads", "head_dim"),
    "v_const": ("layers", "batch", "kv_seq", "kv_heads", "head_dim"),
    "conv": ("layers", "batch", None, "dinner"),
    "ssm": ("layers", "batch", "dinner", None),
    "C": ("layers", "batch", "heads", None, "head_dim"),
    "n": ("layers", "batch", "heads", "head_dim"),
    "m": ("layers", "batch", "heads"),
    "c": ("layers", "batch", "heads", "head_dim"),
    "h": ("layers", "batch", "heads", "head_dim"),
}


def cache_specs(cfg: ArchConfig, shape: ShapeSpec, cache_dtype=jnp.bfloat16):
    cross = shape.seq_len if cfg.enc_dec else 0
    return jax.eval_shape(
        lambda: init_cache(cfg, shape.global_batch, shape.seq_len, cache_dtype, cross_len=cross)
    )


def _cache_leaf_sharding(policy, path, leaf):
    key = path[-1].key if hasattr(path[-1], "key") else str(path[-1])
    axes = _CACHE_AXES.get(key, (None,) * len(leaf.shape))
    axes = axes[: len(leaf.shape)]
    if len(axes) < len(leaf.shape):
        axes = axes + (None,) * (len(leaf.shape) - len(axes))
    # cache batch dim follows the batch sharding
    return policy.sharding_for(axes, leaf.shape)


def cache_shardings(policy: ShardingPolicy, cache_s):
    if policy.mesh is None:
        return None
    return jax.tree_util.tree_map_with_path(
        lambda path, leaf: _cache_leaf_sharding(policy, path, leaf), cache_s
    )


def serve_param_specs(cfg: ArchConfig, dtype=jnp.bfloat16):
    """Inference params (bf16) as ShapeDtypeStructs + logical axes."""
    annotated = jax.eval_shape(lambda k: stacked_init(k, cfg), jax.random.PRNGKey(0))
    params_s, axes = split_annotations(annotated)
    params_s = jax.tree.map(lambda s: jax.ShapeDtypeStruct(s.shape, dtype), params_s)
    return params_s, axes


def param_shardings(policy: ShardingPolicy, params_s, axes):
    if policy.mesh is None:
        return None
    return jax.tree.map(
        lambda ax, s: policy.sharding_for(ax, s.shape),
        axes,
        params_s,
        is_leaf=lambda x: isinstance(x, tuple) and all(a is None or isinstance(a, str) for a in x),
    )


def input_specs(cfg: ArchConfig, shape: ShapeSpec, policy: ShardingPolicy):
    """Everything the dry-run needs for one cell: (args, in_shardings) for the
    step function the cell lowers (train_step / prefill_step / serve_step)."""
    from repro.train.optimizer import optimizer_for
    from repro.train.train_step import sharding_for_state

    if shape.kind == "train":
        opt = optimizer_for(cfg)
        state_sh, state_s, _ = sharding_for_state(policy, cfg, opt)
        batch_s = train_batch_specs(cfg, shape)
        batch_sh = batch_shardings(policy, batch_s)
        return (state_s, batch_s), (state_sh, batch_sh), opt
    if shape.kind == "prefill":
        params_s, axes = serve_param_specs(cfg)
        params_sh = param_shardings(policy, params_s, axes)
        batch_s = train_batch_specs(cfg, shape, with_labels=False)
        batch_sh = batch_shardings(policy, batch_s)
        return (params_s, batch_s), (params_sh, batch_sh), None
    # decode
    params_s, axes = serve_param_specs(cfg)
    params_sh = param_shardings(policy, params_s, axes)
    cache_s = cache_specs(cfg, shape)
    cache_sh = cache_shardings(policy, cache_s)
    batch_s = decode_batch_specs(cfg, shape)
    batch_sh = batch_shardings(policy, batch_s)
    return (params_s, cache_s, batch_s), (params_sh, cache_sh, batch_sh), None
