"""End-to-end fault-tolerant training driver.

Two execution modes share the data pipeline, optimizer, checkpointing, and
the ResiHP stack:

  * spmd     — single-mesh pjit training (the production path the dry-run
               compiles at (16,16)/(2,16,16); here it runs on the host's
               devices). Iteration times + pack stats stream to the Detector.
  * pipeline — the ResiHP runtime: ParallelPlan executed by PipelineEngine
               with per-stage meshes; failure injection triggers the full
               detect -> adapt -> recover -> resume path in-process.

Examples:
  PYTHONPATH=src python -m repro.launch.train --arch qwen3-8b --reduced \
      --steps 40 --mode spmd
  PYTHONPATH=src python -m repro.launch.train --arch qwen3-8b --reduced \
      --mode pipeline --dp 2 --pp 2 --tp 1 --steps 30 \
      --inject-failstop 10:5 --ckpt-dir /tmp/ck
"""
from __future__ import annotations

import os

if os.environ.get("REPRO_HOST_DEVICES"):  # must precede any jax import
    os.environ["XLA_FLAGS"] = (
        os.environ.get("XLA_FLAGS", "")
        + " --xla_force_host_platform_device_count="
        + os.environ["REPRO_HOST_DEVICES"]
    ).strip()

import argparse
import json
import time
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import CheckpointManager
from repro.configs import get_arch, reduced as reduce_cfg
from repro.core.detector.changepoint import CusumDetector
from repro.core.detector.detector import Detector
from repro.core.detector.heartbeat import HeartbeatMonitor
from repro.core.detector.predictor import MicroBatchTimePredictor
from repro.core.recovery import recover_state, transfer_plan
from repro.core.resihp import ResiHPController
from repro.core.scheduler.plan import initial_plan
from repro.core.scheduler.repartition import costs_for_arch
from repro.core.scheduler.scheduler import Scheduler
from repro.data.packing import pack_stats
from repro.data.synth import SyntheticPackedDataset
from repro.engine.pipeline import PipelineEngine
from repro.parallel.sharding import NULL_POLICY, policy_for_mesh
from repro.train.optimizer import optimizer_for
from repro.train.train_step import build_train_step, init_train_state, sharding_for_state


def _parse_inject(spec):
    """'step:device[,step:device...]' -> [(step, device)]."""
    out = []
    if spec:
        for part in spec.split(","):
            s, d = part.split(":")
            out.append((int(s), int(d)))
    return out


# ---------------------------------------------------------------- spmd mode
def run_spmd(cfg, args):
    n_dev = len(jax.devices())
    opt = optimizer_for(cfg, lr=args.lr)
    if n_dev > 1:
        dp = max(1, n_dev // args.tp)
        mesh = jax.make_mesh((dp, args.tp), ("data", "model"))
        policy = policy_for_mesh(mesh)
    else:
        mesh, policy = None, NULL_POLICY

    state, axes = init_train_state(jax.random.PRNGKey(args.seed), cfg, opt)
    if policy.mesh is not None:
        state_sh, _, _ = sharding_for_state(policy, cfg, opt)
        state = jax.tree.map(
            lambda x, s: jax.device_put(x, s) if s is not None else x, state, state_sh)
    step_fn = jax.jit(build_train_step(
        cfg, policy, opt, microbatches=args.microbatches, remat=True,
        flash_chunk=max(args.seq_len // 4, 16)))

    ckpt = CheckpointManager(args.ckpt_dir, interval=args.ckpt_interval) if args.ckpt_dir else None
    start = 0
    if ckpt and ckpt.has_checkpoint() and args.resume:
        state, start, extra = ckpt.restore_latest(target=state)
        print(f"[train] resumed from step {start}")

    ds = SyntheticPackedDataset(cfg, args.seq_len, args.batch, seed=args.seed)
    pred = MicroBatchTimePredictor()
    detector = Detector(
        healthy_time_fn=lambda w: pred.predict(*w) if pred.fitted else float("inf"),
        validate_fn=lambda it: [],
        heartbeat=HeartbeatMonitor(),
        changepoint_factory=lambda: CusumDetector(warmup=8),
    )
    losses, times = [], []
    for it in range(start, args.steps):
        batch = {k: jnp.asarray(v) for k, v in ds.batch_at(it).items()}
        t0 = time.perf_counter()
        state, metrics = step_fn(state, batch)
        loss = float(metrics["loss"])
        dt = time.perf_counter() - t0
        stats = pack_stats(np.asarray(batch["segment_ids"]))
        n, l2 = sum(s[0] for s in stats), sum(s[1] for s in stats)
        if it - start >= 2:  # skip compile iterations
            pred.observe(n, l2, dt)
            if len(pred._obs) >= 4 and not pred.fitted:
                pred.fit()
            detector.observe_iteration(it, dt, (n, l2))
        losses.append(loss)
        times.append(dt)
        if ckpt:
            ckpt.maybe_save(state, it + 1, extra={"loss": loss})
        if it % max(args.steps // 10, 1) == 0 or it == args.steps - 1:
            print(f"[train] step {it} loss {loss:.4f} {dt*1e3:.0f} ms")
    return {"losses": losses, "times": times,
            "detector": detector.stats.as_dict()}


# ------------------------------------------------------------ pipeline mode
def run_pipeline(cfg, args):
    opt = optimizer_for(cfg, lr=args.lr)
    plan = initial_plan(cfg.n_layers, args.dp, args.pp, args.tp,
                        microbatches=args.microbatches)
    layer_costs = costs_for_arch(cfg, args.seq_len)
    scheduler = Scheduler(layer_costs=layer_costs, k_min=1, delta=1)
    hb = HeartbeatMonitor()
    node_devs = {}
    for d in plan.devices:
        node_devs.setdefault(d // 8, []).append(d)
    for n, devs in node_devs.items():
        hb.register_node(n, devs)
    detector = Detector(healthy_time_fn=lambda w: float("inf"),
                        validate_fn=lambda it: [], heartbeat=hb)
    controller = ResiHPController(
        scheduler=scheduler, detector=detector, plan=plan,
        speeds={d: 1.0 for d in plan.devices})

    engine = PipelineEngine(cfg, plan, optimizer=opt, seed=args.seed)
    ckpt = CheckpointManager(args.ckpt_dir, interval=args.ckpt_interval) if args.ckpt_dir else None
    ds = SyntheticPackedDataset(cfg, args.seq_len, args.batch, seed=args.seed)
    injections = dict(_parse_inject(args.inject_failstop))
    slow_inj = {}
    if args.inject_failslow:
        for part in args.inject_failslow.split(","):
            s, rest = part.split(":")
            d, f = rest.split("@")
            slow_inj[int(s)] = (int(d), float(f))

    start = 0
    if ckpt and ckpt.has_checkpoint() and args.resume:
        full, start, _ = ckpt.restore_latest(
            target={"params": engine.params_full, "opt": engine.opt_state,
                    "step": engine.step})
        engine.params_full, engine.opt_state = full["params"], full["opt"]
        engine.step = int(full["step"]) if not isinstance(full["step"], int) else full["step"]
        print(f"[train] resumed from step {start}")

    losses = []
    reconfigs = []
    for it in range(start, args.steps):
        now = float(it)
        from repro.core.detector.detector import FailureReport

        if it in injections:
            dev = injections[it]
            print(f"[inject] fail-stop device {dev} at step {it}")
            controller.speeds[dev] = 0.0
            controller.pending.append(FailureReport("fail-stop", (dev,), it, now))
        if it in slow_inj:
            dev, f = slow_inj[it]
            print(f"[inject] fail-slow device {dev} -> {f} at step {it}")
            controller.speeds[dev] = f
            controller.pending.append(FailureReport("fail-slow", ((dev, f),), it, now))

        adaptation = controller.adapt(now)
        if adaptation is not None:
            old_plan = engine.plan
            print(f"[adapt] {adaptation.plan.summary()}")
            for note in adaptation.notes:
                print(f"        {note}")
            tp_ = transfer_plan(cfg, old_plan, adaptation.plan,
                                dead_stages=adaptation.dead_stages)
            print(f"[recover] {len(tp_.moves)} layer moves, "
                  f"{tp_.total_bytes/1e6:.1f} MB, est {tp_.seconds():.2f}s on IB")
            if tp_.restore_required:
                if ckpt is None or not ckpt.has_checkpoint():
                    raise RuntimeError("stage lost all replicas and no checkpoint")
                full, step0, _ = ckpt.restore_latest(
                    target={"params": engine.params_full, "opt": engine.opt_state,
                            "step": engine.step})
                engine.params_full, engine.opt_state = full["params"], full["opt"]
                print(f"[recover] restored checkpoint step {step0} (Fig. 8b)")
            engine.apply_plan(adaptation.plan)
            reconfigs.append(it)

        batch = {k: jnp.asarray(v) for k, v in ds.batch_at(it).items()}
        t0 = time.perf_counter()
        loss, _ = engine.run_iteration(batch)
        dt = time.perf_counter() - t0
        losses.append(loss)
        if ckpt:
            ckpt.maybe_save(
                {"params": engine.params_full, "opt": engine.opt_state,
                 "step": engine.step}, it + 1, extra={"loss": loss})
        if it % max(args.steps // 10, 1) == 0 or it == args.steps - 1:
            print(f"[train] step {it} loss {loss:.4f} {dt*1e3:.0f} ms "
                  f"plan={engine.plan.summary()}")
    return {"losses": losses, "reconfigs": reconfigs}


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-8b")
    ap.add_argument("--reduced", action="store_true",
                    help="CPU-sized same-family config")
    ap.add_argument("--mode", choices=("spmd", "pipeline"), default="spmd")
    ap.add_argument("--steps", type=int, default=30)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--microbatches", type=int, default=2)
    ap.add_argument("--dp", type=int, default=2)
    ap.add_argument("--pp", type=int, default=2)
    ap.add_argument("--tp", type=int, default=1)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-interval", type=int, default=10)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--inject-failstop", default=None,
                    help="step:device[,step:device]")
    ap.add_argument("--inject-failslow", default=None,
                    help="step:device@factor[,...]")
    ap.add_argument("--out", default=None)
    args = ap.parse_args(argv)

    cfg = get_arch(args.arch)
    if args.reduced:
        cfg = reduce_cfg(cfg)
        if args.mode == "pipeline":
            import dataclasses
            need = max(args.pp * len(cfg.period) * 2, 4)
            cfg = reduce_cfg(get_arch(args.arch), n_layers=need)
    print(f"[train] arch={cfg.arch_id} params={cfg.param_count()/1e6:.1f}M "
          f"mode={args.mode}")
    result = run_spmd(cfg, args) if args.mode == "spmd" else run_pipeline(cfg, args)
    if args.out:
        Path(args.out).parent.mkdir(parents=True, exist_ok=True)
        Path(args.out).write_text(json.dumps(result, default=float))
    print(f"[train] done; final loss {result['losses'][-1]:.4f}")
    return result


if __name__ == "__main__":
    main()
