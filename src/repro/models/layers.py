"""Common layers: norms, rotary embeddings (incl. M-RoPE), initializers."""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp
import numpy as np

from repro.parallel.sharding import annotate


def dense_init(key, shape, in_axis=0, dtype=jnp.float32):
    """LeCun-normal-ish fan-in init (traceable for eval_shape)."""
    fan_in = shape[in_axis] if isinstance(in_axis, int) else int(np.prod([shape[a] for a in in_axis]))
    scale = 1.0 / math.sqrt(max(fan_in, 1))
    return jax.random.normal(key, shape, dtype) * scale


def rms_norm(x, weight, eps=1e-6):
    dtype = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    x = x * jax.lax.rsqrt(var + eps)
    return (x * (1.0 + weight.astype(jnp.float32))).astype(dtype)


def head_rms_norm(x, weight, eps=1e-6):
    """qk-norm: RMS over the head_dim of (..., H, dh)."""
    dtype = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    x = x * jax.lax.rsqrt(var + eps)
    return (x * (1.0 + weight.astype(jnp.float32))).astype(dtype)


# ------------------------------------------------------------------ rotary
def rope_angles(positions, head_dim, theta, sections=None):
    """Rotary angles.

    positions: (..., ) int32 for standard RoPE, or (..., 3) for M-RoPE with
    `sections` (t, h, w) partitioning the head_dim//2 frequency slots.
    Returns (..., head_dim//2) float32 angles.
    """
    half = head_dim // 2
    inv_freq = 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))
    if sections is None:
        pos = positions.astype(jnp.float32)
        return pos[..., None] * inv_freq
    assert sum(sections) == half, (sections, half)
    # map each frequency slot to one of the 3 position axes
    sec_ids = np.concatenate([np.full(s, i) for i, s in enumerate(sections)])
    sec_ids = jnp.asarray(sec_ids)  # (half,)
    pos = positions.astype(jnp.float32)  # (..., 3)
    pos_per_slot = jnp.take(pos, sec_ids, axis=-1)  # (..., half)
    return pos_per_slot * inv_freq


def apply_rope(x, angles):
    """x: (..., H, dh); angles: broadcastable to (..., dh//2)."""
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half], x[..., half:]
    cos = jnp.cos(angles)[..., None, :].astype(x.dtype)
    sin = jnp.sin(angles)[..., None, :].astype(x.dtype)
    return jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)


# ------------------------------------------------------------ param helpers
def norm_param(key, d):
    return annotate(jnp.zeros((d,), jnp.float32), "dmodel")


def causal_conv1d(x, w, b, segment_ids=None):
    """Depthwise causal conv over seq: x (B,S,C), w (C,K), b (C,).

    Implemented as K shifted multiply-adds (K<=4), masked so the receptive
    field never crosses packed-document boundaries.
    """
    K = w.shape[-1]
    out = x * w[:, -1]
    for j in range(1, K):
        shifted = jnp.pad(x, ((0, 0), (j, 0), (0, 0)))[:, : x.shape[1]]
        if segment_ids is not None:
            seg_shift = jnp.pad(segment_ids, ((0, 0), (j, 0)))[:, : x.shape[1]]
            same = (seg_shift == segment_ids)[..., None]
            shifted = jnp.where(same, shifted, 0)
        out = out + shifted * w[:, -1 - j]
    return out + b
