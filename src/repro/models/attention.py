"""GQA attention: packed-segment masks, SWA, qk-norm, M-RoPE, flash-chunked
training path, KV-cache decode path.

The jnp flash-chunked path (lax.scan over KV chunks with running max/sum) is
the lowering reference; `repro.kernels.packed_flash_attn` is the Pallas TPU
kernel with the same semantics (and block skipping on the segment mask).
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

from repro.models.layers import apply_rope, dense_init, head_rms_norm, rope_angles
from repro.parallel.sharding import annotate

NEG_INF = -1e30


def init_attention(key, cfg):
    D, H, K, dh = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    ks = jax.random.split(key, 4)
    p = {
        "wq": annotate(dense_init(ks[0], (D, H, dh)), "dmodel", "heads", "head_dim"),
        "wk": annotate(dense_init(ks[1], (D, K, dh)), "dmodel", "kv_heads", "head_dim"),
        "wv": annotate(dense_init(ks[2], (D, K, dh)), "dmodel", "kv_heads", "head_dim"),
        "wo": annotate(dense_init(ks[3], (H, dh, D), in_axis=(0, 1)), "heads", "head_dim", "dmodel"),
    }
    if cfg.qk_norm:
        p["q_norm"] = annotate(jnp.zeros((dh,), jnp.float32), None)
        p["k_norm"] = annotate(jnp.zeros((dh,), jnp.float32), None)
    return p


def _mask(seg_q, seg_k, pos_q, pos_k, *, causal, window):
    """(B, Sq, Sk) bool mask from segment ids + absolute positions."""
    same = (seg_q[:, :, None] == seg_k[:, None, :]) & (seg_q[:, :, None] != 0)
    if causal:
        same &= pos_q[:, :, None] >= pos_k[:, None, :]
    if window is not None:
        same &= (pos_q[:, :, None] - pos_k[:, None, :]) < window
    return same


def _sdpa_dense(q, k, v, mask, scale):
    # q (B,Sq,H,dh) k/v (B,Sk,H,dh) mask (B,Sq,Sk)
    with jax.named_scope("attn_core"):
        scores = jnp.einsum("bqhd,bkhd->bhqk", q, k).astype(jnp.float32) * scale
        scores = jnp.where(mask[:, None], scores, NEG_INF)
        probs = jax.nn.softmax(scores, axis=-1)
        return jnp.einsum("bhqk,bkhd->bqhd", probs.astype(v.dtype), v)


def _sdpa_flash_chunked(q, k, v, seg_q, seg_k, pos_q, pos_k, *, causal, window, scale, chunk):
    """lax.scan over KV chunks with running (m, l, acc) — flash semantics."""
    B, Sq, H, dh = q.shape
    Sk = k.shape[1]
    n_chunks = Sk // chunk
    assert Sk % chunk == 0, (Sk, chunk)

    k_c = k.reshape(B, n_chunks, chunk, H, dh).transpose(1, 0, 2, 3, 4)
    v_c = v.reshape(B, n_chunks, chunk, H, dh).transpose(1, 0, 2, 3, 4)
    segk_c = seg_k.reshape(B, n_chunks, chunk).transpose(1, 0, 2)
    posk_c = pos_k.reshape(B, n_chunks, chunk).transpose(1, 0, 2)

    def body(carry, xs):
        m, l, acc = carry
        kc, vc, sc, pc = xs
        with jax.named_scope("attn_core"):
            mask = _mask(seg_q, sc, pos_q, pc, causal=causal, window=window)
            s = jnp.einsum("bqhd,bkhd->bhqk", q, kc).astype(jnp.float32) * scale
            s = jnp.where(mask[:, None], s, NEG_INF)
            m_new = jnp.maximum(m, s.max(axis=-1))
            alpha = jnp.exp(m - m_new)
            p = jnp.exp(s - m_new[..., None])
            l_new = l * alpha + p.sum(axis=-1)
            acc_new = acc * alpha[..., None] + jnp.einsum(
                "bhqk,bkhd->bhqd", p.astype(vc.dtype), vc
            ).astype(jnp.float32)
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((B, H, Sq), NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, H, Sq), jnp.float32)
    acc0 = jnp.zeros((B, H, Sq, dh), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(body, (m0, l0, acc0), (k_c, v_c, segk_c, posk_c))
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    return out.transpose(0, 2, 1, 3).astype(q.dtype)  # (B,Sq,H,dh)


def attention(cfg, spec, p, x, md, policy, cache=None):
    """Full attention layer.

    md: dict with 'positions' (B,S) or (B,S,3) for M-RoPE, 'segment_ids' (B,S),
        and for decode: 'lengths' (B,) current KV fill.
    cache: None for train/prefill, else {'k': (B,T,K,dh), 'v': ...}.
    Returns (out (B,S,D), new_cache).
    """
    D, H, K, dh = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    B, S = x.shape[:2]
    scale = 1.0 / math.sqrt(dh)
    window = cfg.window if spec.attn_kind == "swa" else None
    causal = md.get("causal", True)

    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"].astype(x.dtype))
    kx = md.get("cross_x")  # encoder output for cross attention
    src = kx if kx is not None else x
    if cache is not None and "k_const" in cache:
        k_all, v_all = cache["k_const"], cache["v_const"]  # precomputed cross KV
        new_cache = cache
        seg_k = md["cross_segment_ids"]
        pos_k = md["cross_positions"]
        causal, window = False, None
    else:
        k = jnp.einsum("bsd,dkh->bskh", src, p["wk"].astype(x.dtype))
        v = jnp.einsum("bsd,dkh->bskh", src, p["wv"].astype(x.dtype))
        if cfg.qk_norm:
            q = head_rms_norm(q, p["q_norm"])
            k = head_rms_norm(k, p["k_norm"])
        if md.get("rope", True) and kx is None:
            ang = rope_angles(md["positions"], dh, cfg.rope_theta, cfg.mrope_sections)
            q = apply_rope(q, ang)
            k = apply_rope(k, ang)
        elif cfg.qk_norm is False and kx is not None:
            pass
        if cache is None:
            k_all, v_all, new_cache = k, v, None
            if kx is not None:  # cross attention over encoder output
                seg_k = md["cross_segment_ids"]
                pos_k = md["cross_positions"]
                causal, window = False, None
                if md.get("collect_state"):
                    new_cache = {"k_const": k, "v_const": v}
            else:
                seg_k, pos_k = md["segment_ids"], md["abs_positions"]
                if md.get("collect_state"):  # prefill: emit the filled KV cache
                    new_cache = {"k": k, "v": v, "pos": pos_k.astype(jnp.int32)}
        else:
            # decode: ring-buffer insert at (position % T). For full-attention
            # layers T == max_len so slot == position; for SWA layers T is
            # 2*window and old slots are overwritten once out of the window.
            idx = md["lengths"]  # (B,)
            rows = jnp.arange(B)
            T = cache["k"].shape[1]
            slot = idx % T
            k_all = cache["k"].at[rows, slot].set(k[:, 0].astype(cache["k"].dtype))
            v_all = cache["v"].at[rows, slot].set(v[:, 0].astype(cache["v"].dtype))
            pos_arr = cache["pos"].at[rows, slot].set(idx.astype(jnp.int32))
            new_cache = {"k": k_all, "v": v_all, "pos": pos_arr}
            pos_k = jnp.maximum(pos_arr, 0)
            seg_k = (pos_arr >= 0).astype(jnp.int32)  # valid cache entries

    # expand KV heads to H query heads (GQA)
    if k_all.shape[2] != H:
        rep = H // k_all.shape[2]
        k_all = jnp.repeat(k_all, rep, axis=2)
        v_all = jnp.repeat(v_all, rep, axis=2)

    if cache is not None:
        # decode path: queries are length-1 (or small); dense masked attention
        pos_q = md["lengths"][:, None] + jnp.arange(S)[None]
        seg_q = jnp.ones((B, S), jnp.int32)
        mask = _mask(seg_q, seg_k, pos_q, pos_k, causal=causal, window=window)
        out = _sdpa_dense(q, k_all, v_all, mask, scale)
    else:
        pos_q = md["abs_positions"] if kx is None else md["abs_positions"]
        seg_q = md["segment_ids"]
        Sk = k_all.shape[1]
        chunk = md.get("flash_chunk", 1024)
        if md.get("use_pallas_kernel"):
            # Pallas packed flash attention (block-skipping on the packing
            # mask): native on TPU, interpret mode elsewhere.
            from repro.kernels.ops import packed_attention

            out = packed_attention(
                q, k_all, v_all, seg_q, seg_k, pos_q, pos_k,
                causal=causal, window=window, scale=scale,
                block_q=md.get("kernel_block_q", 128),
                block_k=md.get("kernel_block_k", 128),
            )
        elif Sk <= 2 * chunk:
            mask = _mask(seg_q, seg_k, pos_q, pos_k, causal=causal, window=window)
            out = _sdpa_dense(q, k_all, v_all, mask, scale)
        else:
            out = _sdpa_flash_chunked(
                q, k_all, v_all, seg_q, seg_k, pos_q, pos_k,
                causal=causal, window=window, scale=scale, chunk=chunk,
            )

    out = policy.constrain(out, "batch", "seq", "heads", "head_dim")
    y = jnp.einsum("bshk,hkd->bsd", out, p["wo"].astype(x.dtype))
    return y, new_cache


def init_cross_attention(key, cfg):
    return init_attention(key, cfg)


def precompute_cross_kv(cfg, p, enc_out):
    """Cross-attention K/V from encoder output (computed once per request)."""
    k = jnp.einsum("bsd,dkh->bskh", enc_out, p["wk"].astype(enc_out.dtype))
    v = jnp.einsum("bsd,dkh->bskh", enc_out, p["wv"].astype(enc_out.dtype))
    return {"k_const": k, "v_const": v}
