"""Mamba-1 selective SSM block (jamba hybrid layers).

Training/prefill runs a time-step `lax.scan` carrying h (B, d_inner, N): the
projections (the FLOPs-dominant part) are batched matmuls outside the scan, so
only elementwise recurrence work is sequential. Packing-aware: the recurrent
state and the causal conv reset at packed-document boundaries. Decode keeps a
(conv_state, ssm_state) cache and costs O(1) per token — this is why the
hybrid/ssm archs run the long_500k cell.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.models.layers import causal_conv1d, dense_init
from repro.parallel.sharding import annotate


def dt_rank(cfg):
    return math.ceil(cfg.d_model / 16)


def init_mamba(key, cfg):
    D, di, N, K = cfg.d_model, cfg.mamba_d_inner, cfg.mamba_d_state, cfg.mamba_d_conv
    R = dt_rank(cfg)
    ks = jax.random.split(key, 9)
    A = jnp.broadcast_to(jnp.arange(1, N + 1, dtype=jnp.float32), (di, N))
    return {
        "w_x": annotate(dense_init(ks[0], (D, di)), "dmodel", "dinner"),
        "w_z": annotate(dense_init(ks[1], (D, di)), "dmodel", "dinner"),
        "conv_w": annotate(dense_init(ks[2], (di, K)), "dinner", None),
        "conv_b": annotate(jnp.zeros((di,), jnp.float32), "dinner"),
        "w_dt": annotate(dense_init(ks[3], (di, R)), "dinner", None),
        "dt_proj": annotate(dense_init(ks[4], (R, di)), None, "dinner"),
        "dt_bias": annotate(jnp.full((di,), -4.6, jnp.float32), "dinner"),  # softplus ~0.01
        "w_B": annotate(dense_init(ks[5], (di, N)), "dinner", None),
        "w_C": annotate(dense_init(ks[6], (di, N)), "dinner", None),
        "A_log": annotate(jnp.log(A), "dinner", None),
        "D_skip": annotate(jnp.ones((di,), jnp.float32), "dinner"),
        "w_out": annotate(dense_init(ks[7], (di, D)), "dinner", "dmodel"),
    }


def _projections(cfg, p, x, segment_ids):
    xin = jnp.einsum("bsd,di->bsi", x, p["w_x"].astype(x.dtype))
    z = jnp.einsum("bsd,di->bsi", x, p["w_z"].astype(x.dtype))
    xc = causal_conv1d(xin, p["conv_w"].astype(x.dtype), p["conv_b"].astype(x.dtype), segment_ids)
    xc = jax.nn.silu(xc)
    dt_low = jnp.einsum("bsi,ir->bsr", xc, p["w_dt"].astype(x.dtype))
    dt = jax.nn.softplus(
        jnp.einsum("bsr,ri->bsi", dt_low, p["dt_proj"].astype(x.dtype)).astype(jnp.float32)
        + p["dt_bias"]
    )
    Bm = jnp.einsum("bsi,in->bsn", xc, p["w_B"].astype(x.dtype)).astype(jnp.float32)
    Cm = jnp.einsum("bsi,in->bsn", xc, p["w_C"].astype(x.dtype)).astype(jnp.float32)
    return xin, z, xc, dt, Bm, Cm


def mamba(cfg, spec, p, x, md, policy, cache=None):
    """Returns (out (B,S,D), new_cache)."""
    B, S, D = x.shape
    di, N, K = cfg.mamba_d_inner, cfg.mamba_d_state, cfg.mamba_d_conv
    A = -jnp.exp(p["A_log"])  # (di, N)
    seg = md.get("segment_ids")

    if cache is not None:
        # single-token decode with cached conv window + ssm state
        conv_st, h = cache["conv"], cache["ssm"]  # (B, K-1, di), (B, di, N)
        xin = jnp.einsum("bsd,di->bsi", x, p["w_x"].astype(x.dtype))
        z = jnp.einsum("bsd,di->bsi", x, p["w_z"].astype(x.dtype))
        window = jnp.concatenate([conv_st, xin], axis=1)  # (B, K, di)
        conv_w = p["conv_w"].astype(x.dtype)  # (di, K); kernel tap K-1 = current step
        xc = jnp.einsum("bki,ik->bi", window, conv_w) + p["conv_b"].astype(x.dtype)
        xc = jax.nn.silu(xc)[:, None]  # (B,1,di)
        dt_low = jnp.einsum("bsi,ir->bsr", xc, p["w_dt"].astype(x.dtype))
        dt = jax.nn.softplus(
            jnp.einsum("bsr,ri->bsi", dt_low, p["dt_proj"].astype(x.dtype)).astype(jnp.float32)
            + p["dt_bias"]
        )[:, 0]
        Bm = jnp.einsum("bsi,in->bsn", xc, p["w_B"].astype(x.dtype)).astype(jnp.float32)[:, 0]
        Cm = jnp.einsum("bsi,in->bsn", xc, p["w_C"].astype(x.dtype)).astype(jnp.float32)[:, 0]
        decay = jnp.exp(dt[..., None] * A)  # (B, di, N)
        h = h * decay + (dt * xc[:, 0].astype(jnp.float32))[..., None] * Bm[:, None, :]
        y = jnp.einsum("bin,bn->bi", h, Cm) + p["D_skip"] * xc[:, 0].astype(jnp.float32)
        y = (y.astype(x.dtype) * jax.nn.silu(z[:, 0]))[:, None]
        out = jnp.einsum("bsi,id->bsd", y, p["w_out"].astype(x.dtype))
        new_cache = {"conv": window[:, 1:], "ssm": h}
        return out, new_cache

    xin, z, xc, dt, Bm, Cm = _projections(cfg, p, x, seg)

    # recurrence: h_t = exp(dt_t*A) h_{t-1} + (dt_t * xc_t) B_t ; reset at doc starts
    if seg is not None:
        prev_seg = jnp.pad(seg, ((0, 0), (1, 0)), constant_values=-1)[:, :S]
        keep_prev = (seg == prev_seg).astype(jnp.float32)  # (B,S)
    else:
        keep_prev = jnp.ones((B, S), jnp.float32)

    def step(h, xs):
        dt_t, b_t, c_t, xc_t, kp_t = xs  # (B,di),(B,N),(B,N),(B,di),(B,)
        decay = jnp.exp(dt_t[..., None] * A)
        h = h * decay * kp_t[:, None, None] + (dt_t * xc_t)[..., None] * b_t[:, None, :]
        y_t = jnp.einsum("bin,bn->bi", h, c_t)
        return h, y_t

    h0 = jnp.zeros((B, di, N), jnp.float32)
    xs = (
        dt.transpose(1, 0, 2),
        Bm.transpose(1, 0, 2),
        Cm.transpose(1, 0, 2),
        xc.astype(jnp.float32).transpose(1, 0, 2),
        keep_prev.T,
    )
    h_last, ys = jax.lax.scan(step, h0, xs)
    y = ys.transpose(1, 0, 2) + p["D_skip"] * xc.astype(jnp.float32)  # (B,S,di)
    y = y.astype(x.dtype) * jax.nn.silu(z)
    y = policy.constrain(y, "batch", "seq", "dinner")
    out = jnp.einsum("bsi,id->bsd", y, p["w_out"].astype(x.dtype))
    new_cache = None
    if md.get("collect_state"):  # prefill: emit decode-ready state
        new_cache = {"conv": xin[:, -(K - 1):], "ssm": h_last}
    return out, new_cache


def init_mamba_cache(cfg, batch, dtype=jnp.float32):
    di, N, K = cfg.mamba_d_inner, cfg.mamba_d_state, cfg.mamba_d_conv
    return {
        "conv": jnp.zeros((batch, K - 1, di), dtype),
        "ssm": jnp.zeros((batch, di, N), jnp.float32),
    }
