"""Dense gated FFN (SwiGLU)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import dense_init
from repro.parallel.sharding import annotate


def init_mlp(key, cfg):
    D, F = cfg.d_model, cfg.d_ff
    ks = jax.random.split(key, 3)
    return {
        "w_gate": annotate(dense_init(ks[0], (D, F)), "dmodel", "ffn"),
        "w_up": annotate(dense_init(ks[1], (D, F)), "dmodel", "ffn"),
        "w_down": annotate(dense_init(ks[2], (F, D)), "ffn", "dmodel"),
    }


def mlp(cfg, p, x, policy):
    h = jnp.einsum("bsd,df->bsf", x, p["w_gate"].astype(x.dtype))
    u = jnp.einsum("bsd,df->bsf", x, p["w_up"].astype(x.dtype))
    h = jax.nn.silu(h) * u
    h = policy.constrain(h, "batch", "seq", "ffn")
    return jnp.einsum("bsf,fd->bsd", h, p["w_down"].astype(x.dtype))
