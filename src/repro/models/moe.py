"""Top-k MoE FFN with capacity-buffer dispatch.

Parallelization: the layer runs under shard_map — tokens sharded over the DP
axes, expert FFN width sharded over the TP axis (per-expert tensor
parallelism; works for any expert count). With `policy.expert_parallel` and
E % tp == 0, the expert dim is sharded instead (classic EP; each TP rank
hosts E/tp full experts and contributes their outputs to the final psum).
Dispatch is sort-free (cumsum-ranked scatter into capacity buffers) so the
expert compute is dense batched GEMM — MXU-friendly and exactly countable
for the roofline walker. The router aux (load-balance) loss is computed
outside the shard_map region from a replicated router matmul (negligible
FLOPs) to keep shard_map out_specs trivial.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models.layers import dense_init
from repro.parallel.sharding import annotate


def init_moe(key, cfg):
    D, F, E = cfg.d_model, cfg.moe_d_ff, cfg.n_experts
    ks = jax.random.split(key, 4)
    return {
        "router": annotate(dense_init(ks[0], (D, E)), "dmodel", None),
        "w_gate": annotate(dense_init(ks[1], (E, D, F), in_axis=1), "expert", "dmodel", "ffn"),
        "w_up": annotate(dense_init(ks[2], (E, D, F), in_axis=1), "expert", "dmodel", "ffn"),
        "w_down": annotate(dense_init(ks[3], (E, F, D), in_axis=1), "expert", "ffn", "dmodel"),
    }


def _capacity(cfg, n_tokens):
    c = int(n_tokens * cfg.moe_top_k * cfg.capacity_factor / cfg.n_experts)
    c = max(8, ((c + 7) // 8) * 8)
    return min(c, n_tokens)


def _moe_math(cfg, router, w_gate, w_up, w_down, x):
    """Device-local MoE math. x: (B, S, D) local tokens; weights local slices
    of shape (E_local, D, F_local). Returns the (possibly partial) output that
    the caller psums over TP."""
    B, S, D = x.shape
    E_local = w_gate.shape[0]
    T = B * S
    k = cfg.moe_top_k
    xt = x.reshape(T, D)

    logits = (xt.astype(jnp.float32) @ router.astype(jnp.float32))  # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_idx = jax.lax.top_k(probs, k)  # (T, k)
    gate_vals = gate_vals / jnp.maximum(gate_vals.sum(-1, keepdims=True), 1e-9)

    C = _capacity(cfg, T)
    flat_expert = expert_idx.reshape(-1)  # (T*k,) in [0, E)
    onehot = jax.nn.one_hot(flat_expert, cfg.n_experts, dtype=jnp.int32)
    pos = (jnp.cumsum(onehot, axis=0) - onehot)  # (T*k, E)
    pos = jnp.take_along_axis(pos, flat_expert[:, None], axis=1)[:, 0]  # (T*k,)
    keep = pos < C

    # EP: this shard owns experts [e0, e0 + E_local)
    e_offset = 0
    if E_local != cfg.n_experts:
        e_offset = jax.lax.axis_index(_EP_AXIS_SENTINEL[0]) * E_local
    local_expert = flat_expert - e_offset
    on_shard = (local_expert >= 0) & (local_expert < E_local) & keep
    local_expert = jnp.clip(local_expert, 0, E_local - 1)

    src = jnp.repeat(xt, k, axis=0)  # (T*k, D)
    buf = jnp.zeros((E_local, C, D), x.dtype)
    buf = buf.at[local_expert, pos].add(jnp.where(on_shard[:, None], src, 0))

    h = jnp.einsum("ecd,edf->ecf", buf, w_gate.astype(x.dtype))
    u = jnp.einsum("ecd,edf->ecf", buf, w_up.astype(x.dtype))
    h = jax.nn.silu(h) * u
    out_buf = jnp.einsum("ecf,efd->ecd", h, w_down.astype(x.dtype))

    y = out_buf[local_expert, pos]  # (T*k, D)
    y = jnp.where(on_shard[:, None], y, 0) * gate_vals.reshape(-1, 1).astype(x.dtype)
    y = y.reshape(T, k, D).sum(axis=1)
    return y.reshape(B, S, D)


_EP_AXIS_SENTINEL = [None]  # set inside shard_map wrapper when EP is active


def moe_ffn(cfg, p, x, policy):
    """MoE layer. Single-device fallback when no mesh is present."""
    router, w_gate, w_up, w_down = p["router"], p["w_gate"], p["w_up"], p["w_down"]
    if policy.mesh is None:
        return _moe_math(cfg, router, w_gate, w_up, w_down, x)

    mesh, tp, dp = policy.mesh, policy.tp_axis, policy.dp_axes
    dp_entry = dp if len(dp) > 1 else (dp[0] if dp else None)
    x_spec = P(dp_entry, None, None) if (dp and policy.shard_batch) else P(None, None, None)
    ep = policy.expert_parallel and tp and cfg.n_experts % policy.tp == 0
    fsdp_ok = policy.fsdp and dp and cfg.d_model % policy.dp == 0

    if ep:
        w_spec = P(tp, dp_entry if fsdp_ok else None, None)
    else:
        tp_ok = tp and cfg.moe_d_ff % policy.tp == 0
        w_spec = P(None, dp_entry if fsdp_ok else None, tp if tp_ok else None)
    wd_spec = P(w_spec[0], w_spec[2], w_spec[1])
    r_spec = P(None, None)

    def body(router_l, wg_l, wu_l, wd_l, x_l):
        if fsdp_ok:  # gather the FSDP-sharded dmodel dim of expert weights
            ax = dp if len(dp) > 1 else dp[0]
            wg_l = jax.lax.all_gather(wg_l, ax, axis=1, tiled=True)
            wu_l = jax.lax.all_gather(wu_l, ax, axis=1, tiled=True)
            wd_l = jax.lax.all_gather(wd_l, ax, axis=2, tiled=True)
        _EP_AXIS_SENTINEL[0] = tp if ep else None
        y = _moe_math(cfg, router_l, wg_l, wu_l, wd_l, x_l)
        _EP_AXIS_SENTINEL[0] = None
        if tp:
            y = jax.lax.psum(y, tp)  # combine F-partial (TP) or expert-partial (EP)
        return y

    return jax.shard_map(
        body,
        mesh=mesh,
        in_specs=(r_spec, w_spec, w_spec, wd_spec, x_spec),
        out_specs=x_spec,
        check_vma=False,
    )(router, w_gate, w_up, w_down, x)


def router_aux_loss(cfg, p, x):
    """Load-balance auxiliary loss (computed in the GSPMD region)."""
    logits = jnp.einsum("bsd,de->bse", x.astype(jnp.float32), p["router"].astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)
    # fraction routed (top-1 proxy) x mean gate prob, scaled by E (Switch-style)
    top1 = jnp.argmax(logits, axis=-1)
    frac = jnp.mean(jax.nn.one_hot(top1, cfg.n_experts, dtype=jnp.float32), axis=(0, 1))
    mean_prob = jnp.mean(probs, axis=(0, 1))
    return cfg.n_experts * jnp.sum(frac * mean_prob)
