"""xLSTM blocks: mLSTM (matrix-memory, chunkwise-parallel) and sLSTM
(scalar-memory, time-step recurrent with block-diagonal recurrence).

The mLSTM training path uses the chunkwise form (intra-chunk quadratic +
inter-chunk matrix-state carry, stabilized in log space per the xLSTM paper
[arXiv:2405.04517]) — the TPU-native adaptation: chunk-local quadratic work
maps to the MXU, the carried state is (B, H, dh, dh). Decode is O(1)/token
with (C, n, m) cache, which is why xlstm-1.3b runs the long_500k cell.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.models.layers import causal_conv1d, dense_init
from repro.parallel.sharding import annotate

NEG = -1e30


def _di(cfg):
    return 2 * cfg.d_model


def init_mlstm(key, cfg):
    D, H = cfg.d_model, cfg.n_heads
    di = _di(cfg)
    dh = di // H
    K = cfg.xlstm_conv
    ks = jax.random.split(key, 9)
    return {
        "w_m": annotate(dense_init(ks[0], (D, di)), "dmodel", "dinner"),
        "w_z": annotate(dense_init(ks[1], (D, di)), "dmodel", "dinner"),
        "conv_w": annotate(dense_init(ks[2], (di, K)), "dinner", None),
        "conv_b": annotate(jnp.zeros((di,), jnp.float32), "dinner"),
        # block-diagonal per-head q/k/v
        "wq": annotate(dense_init(ks[3], (H, dh, dh), in_axis=1), "heads", None, None),
        "wk": annotate(dense_init(ks[4], (H, dh, dh), in_axis=1), "heads", None, None),
        "wv": annotate(dense_init(ks[5], (H, dh, dh), in_axis=1), "heads", None, None),
        "wi": annotate(dense_init(ks[6], (di, H)), "dinner", None),
        "wf": annotate(dense_init(ks[7], (di, H)), "dinner", None),
        "bi": annotate(jnp.zeros((H,), jnp.float32), None),
        "bf": annotate(jnp.full((H,), 3.0, jnp.float32), None),  # forget ~ sigmoid(3)
        "gn": annotate(jnp.ones((di,), jnp.float32), "dinner"),
        "w_out": annotate(dense_init(ks[8], (di, D)), "dinner", "dmodel"),
    }


def _mlstm_inputs(cfg, p, x, segment_ids):
    H = cfg.n_heads
    di = _di(cfg)
    dh = di // H
    B, S, _ = x.shape
    xm = jnp.einsum("bsd,di->bsi", x, p["w_m"].astype(x.dtype))
    z = jnp.einsum("bsd,di->bsi", x, p["w_z"].astype(x.dtype))
    xc = causal_conv1d(xm, p["conv_w"].astype(x.dtype), p["conv_b"].astype(x.dtype), segment_ids)
    xc = jax.nn.silu(xc)
    xh = xc.reshape(B, S, H, dh)
    q = jnp.einsum("bshk,hkl->bshl", xh, p["wq"].astype(x.dtype))
    k = jnp.einsum("bshk,hkl->bshl", xh, p["wk"].astype(x.dtype)) / math.sqrt(dh)
    v = jnp.einsum("bshk,hkl->bshl", xm.reshape(B, S, H, dh), p["wv"].astype(x.dtype))
    li = (jnp.einsum("bsi,ih->bsh", xc, p["wi"].astype(x.dtype)).astype(jnp.float32) + p["bi"])
    lf = jax.nn.log_sigmoid(
        jnp.einsum("bsi,ih->bsh", xc, p["wf"].astype(x.dtype)).astype(jnp.float32) + p["bf"]
    )
    return q, k, v, li, lf, z


def mlstm(cfg, spec, p, x, md, policy, cache=None, chunk=None):
    chunk = chunk if chunk is not None else getattr(cfg, "mlstm_chunk", 256)
    B, S, D = x.shape
    H = cfg.n_heads
    di = _di(cfg)
    dh = di // H
    seg = md.get("segment_ids")

    if cache is not None:
        # O(1) recurrent decode step
        q, k, v, li, lf, z = _mlstm_inputs(cfg, p, x, None)
        C, n, m = cache["C"], cache["n"], cache["m"]  # (B,H,dh,dh),(B,H,dh),(B,H)
        li, lf = li[:, 0], lf[:, 0]  # (B,H)
        m_new = jnp.maximum(lf + m, li)
        fe = jnp.exp(lf + m - m_new)[..., None]
        ie = jnp.exp(li - m_new)[..., None]
        kv = k[:, 0].astype(jnp.float32), v[:, 0].astype(jnp.float32)
        C = fe[..., None] * C + ie[..., None] * kv[0][..., :, None] * kv[1][..., None, :]
        n = fe * n + ie * kv[0]
        qf = q[:, 0].astype(jnp.float32)  # (B,H,dh)
        num = jnp.einsum("bhkl,bhk->bhl", C, qf)
        den = jnp.abs(jnp.einsum("bhk,bhk->bh", n, qf))
        h = num / jnp.maximum(den, jnp.exp(-m_new))[..., None]
        hflat = (h.reshape(B, 1, di) * p["gn"]).astype(x.dtype)
        out = jnp.einsum("bsi,id->bsd", hflat * jax.nn.silu(z), p["w_out"].astype(x.dtype))
        return out, {"C": C, "n": n, "m": m_new}

    q, k, v, li, lf, z = _mlstm_inputs(cfg, p, x, seg)
    L = min(chunk, S)
    assert S % L == 0, (S, L)
    nc = S // L

    def resh(t, extra=()):  # (B,S,...) -> (nc, B, H, L, ...)
        t = t.reshape((B, nc, L) + t.shape[2:])
        if t.ndim == 5:  # (B,nc,L,H,dh)
            return t.transpose(1, 0, 3, 2, 4)
        return t.transpose(1, 0, 3, 2)  # gates (B,nc,L,H) -> (nc,B,H,L)

    qc, kc, vc = resh(q.astype(jnp.float32)), resh(k.astype(jnp.float32)), resh(v.astype(jnp.float32))
    lic, lfc = resh(li), resh(lf)
    if seg is not None:
        segc = seg.reshape(B, nc, L).transpose(1, 0, 2)  # (nc, B, L)
        prev = jnp.pad(seg, ((0, 0), (1, 0)), constant_values=-1)[:, :S]
        keepc = (seg == prev).reshape(B, nc, L).transpose(1, 0, 2)
    else:
        segc = jnp.ones((nc, B, L), jnp.int32)
        keepc = jnp.ones((nc, B, L), jnp.bool_)

    tri = jnp.tril(jnp.ones((L, L), jnp.bool_))

    def body(carry, xs):
        C, n, m = carry  # (B,H,dh,dh), (B,H,dh), (B,H)
        qb, kb, vb, lib, lfb, sb, kpb = xs
        kpf = kpb.astype(jnp.float32)  # (B,L): 0 where a new doc starts
        # prod(kp[:i]) -> positions that may still see the inter-chunk carry
        carry_ok = jnp.cumprod(kpf, axis=-1)[:, None, :]  # (B,1,L)
        # prod(kp[j+1:]) -> steps whose contribution survives to chunk end
        kp_next = jnp.concatenate([kpf[:, 1:], jnp.ones((kpf.shape[0], 1))], axis=-1)
        suffix_ok = jnp.flip(jnp.cumprod(jnp.flip(kp_next, -1), -1), -1)[:, None, :]

        b = jnp.cumsum(lfb, axis=-1)  # (B,H,L) inclusive log-decay
        m_inter = b + m[..., None]  # (B,H,L)
        dmat = b[..., :, None] - b[..., None, :] + lib[..., None, :]  # (B,H,L,L)
        smask = (sb[:, None, :, None] == sb[:, None, None, :]) & tri
        dmat = jnp.where(smask, dmat, NEG)
        m_intra = dmat.max(axis=-1)
        m_new = jnp.maximum(m_inter, m_intra)  # (B,H,L)
        sc = jnp.einsum("bhlk,bhmk->bhlm", qb, kb) * jnp.exp(dmat - m_new[..., None])
        num = jnp.einsum("bhlm,bhmk->bhlk", sc, vb)
        inter_w = carry_ok * jnp.exp(m_inter - m_new)  # (B,H,L)
        num += inter_w[..., None] * jnp.einsum("bhlk,bhkm->bhlm", qb, C)
        den = sc.sum(-1) + inter_w * jnp.einsum("bhk,bhlk->bhl", n, qb)
        h = num / jnp.maximum(jnp.abs(den), jnp.exp(-m_new))[..., None]  # (B,H,L,dh)
        # chunk-end state (drop contributions preceding the last doc boundary)
        total = b[..., -1]  # (B,H)
        dk = total[..., None] - b + lib  # (B,H,L) decay from step j to chunk end
        m_c = jnp.maximum(total + m, dk.max(-1))
        scale_old = carry_ok[:, :, -1] * jnp.exp(total + m - m_c)  # (B,H)
        w = suffix_ok * jnp.exp(dk - m_c[..., None])
        C = scale_old[..., None, None] * C + jnp.einsum("bhl,bhlk,bhlm->bhkm", w, kb, vb)
        n = scale_old[..., None] * n + jnp.einsum("bhl,bhlk->bhk", w, kb)
        return (C, n, m_c), h

    C0 = jnp.zeros((B, H, dh, dh), jnp.float32)
    n0 = jnp.zeros((B, H, dh), jnp.float32)
    m0 = jnp.zeros((B, H), jnp.float32)
    (Cf, nf, mf), hs = jax.lax.scan(body, (C0, n0, m0), (qc, kc, vc, lic, lfc, segc, keepc))
    # hs: (nc, B, H, L, dh) -> (B, S, di)
    h = hs.transpose(1, 0, 3, 2, 4).reshape(B, S, di)
    h = (h * p["gn"]).astype(x.dtype)
    h = policy.constrain(h, "batch", "seq", "dinner")
    out = jnp.einsum("bsi,id->bsd", h * jax.nn.silu(z), p["w_out"].astype(x.dtype))
    new_cache = {"C": Cf, "n": nf, "m": mf} if md.get("collect_state") else None
    return out, new_cache


def init_mlstm_cache(cfg, batch):
    H = cfg.n_heads
    dh = _di(cfg) // H
    return {
        "C": jnp.zeros((batch, H, dh, dh), jnp.float32),
        "n": jnp.zeros((batch, H, dh), jnp.float32),
        "m": jnp.zeros((batch, H), jnp.float32),
    }


# --------------------------------------------------------------------- sLSTM
def init_slstm(key, cfg):
    D, H = cfg.d_model, cfg.n_heads
    dh = D // H
    ks = jax.random.split(key, 3)
    return {
        "w_g": annotate(dense_init(ks[0], (D, 4, H, dh)), "dmodel", None, "heads", None),
        "r_g": annotate(dense_init(ks[1], (4, H, dh, dh), in_axis=2) * 0.5, None, "heads", None, None),
        "b_g": annotate(jnp.concatenate([
            jnp.zeros((2, H, dh)), jnp.zeros((1, H, dh)), jnp.zeros((1, H, dh))
        ]).reshape(4, H, dh).at[1].set(3.0), None, "heads", None),
        "w_out": annotate(dense_init(ks[2], (D, D)), "dmodel", "dmodel"),
    }


def slstm(cfg, spec, p, x, md, policy, cache=None):
    """Time-step recurrent sLSTM with per-head block-diagonal recurrence.

    Gates: i (exp), f (exp/sigmoid stabilized), z (tanh cell input), o (sigmoid).
    """
    B, S, D = x.shape
    H = cfg.n_heads
    dh = D // H
    gates_x = jnp.einsum("bsd,dghk->bsghk", x, p["w_g"].astype(x.dtype))  # (B,S,4,H,dh)
    seg = md.get("segment_ids")
    if seg is not None and cache is None:
        prev = jnp.pad(seg, ((0, 0), (1, 0)), constant_values=-1)[:, :S]
        keep = (seg == prev).astype(jnp.float32).T  # (S,B)
    else:
        keep = jnp.ones((S, B), jnp.float32)

    r_g = p["r_g"].astype(jnp.float32)
    b_g = p["b_g"]

    def step(carry, xs):
        c, n, m, h = carry  # all (B,H,dh) fp32; h is the output state
        gx, kp = xs  # (B,4,H,dh), (B,)
        c, n, m, h = (t * kp[:, None, None] for t in (c, n, m, h))
        gr = jnp.einsum("bhk,ghkl->bghl", h, r_g)  # (B,4,H,dh)
        pre = gx.astype(jnp.float32) + gr + b_g
        it, ft, zt, ot = pre[:, 0], pre[:, 1], pre[:, 2], pre[:, 3]
        m_new = jnp.maximum(ft + m, it)
        i_e = jnp.exp(it - m_new)
        f_e = jnp.exp(ft + m - m_new)
        c = f_e * c + i_e * jnp.tanh(zt)
        n = f_e * n + i_e
        h_new = jax.nn.sigmoid(ot) * c / jnp.maximum(n, 1.0)
        return (c, n, m_new, h_new), h_new

    z0 = jnp.zeros((B, H, dh), jnp.float32)
    if cache is not None:
        carry0 = (cache["c"], cache["n"], cache["m"], cache["h"])
    else:
        carry0 = (z0, z0, z0, z0)
    carry, hs = jax.lax.scan(step, carry0, (gates_x.transpose(1, 0, 2, 3, 4), keep))
    h = hs.transpose(1, 0, 2, 3).reshape(B, S, D).astype(x.dtype)
    out = jnp.einsum("bsd,de->bse", h, p["w_out"].astype(x.dtype))
    new_cache = None
    if cache is not None or md.get("collect_state"):
        new_cache = dict(zip(("c", "n", "m", "h"), carry))
    return out, new_cache


def init_slstm_cache(cfg, batch):
    H = cfg.n_heads
    dh = cfg.d_model // H
    z = jnp.zeros((batch, H, dh), jnp.float32)
    return {"c": z, "n": z, "m": z, "h": z}
