"""Model assembly: init, train/prefill forward, decode step, cache management.

Two execution layouts share the same per-layer code:
  * scan layout — per-period-position stacked parameters, `lax.scan` over
    periods (fast compiles at 70+ layers; what train_step/serve_step lower);
  * list layout — per-layer parameter list (what the ResiHP pipeline engine
    partitions across stages and migrates during reconfiguration).
"""
from __future__ import annotations

import math
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.models import attention as attn_mod
from repro.models.attention import attention, init_attention, precompute_cross_kv
from repro.models.layers import norm_param, rms_norm
from repro.models.mlp import init_mlp, mlp
from repro.models.moe import init_moe, moe_ffn, router_aux_loss
from repro.models.ssm import init_mamba, init_mamba_cache, mamba
from repro.models.xlstm import (
    init_mlstm,
    init_mlstm_cache,
    init_slstm,
    init_slstm_cache,
    mlstm,
    slstm,
)
from repro.parallel.sharding import Annot, annotate, split_annotations

MIXER_INIT = {"attn": init_attention, "mamba": init_mamba, "mlstm": init_mlstm, "slstm": init_slstm}
MIXER_FN = {"attn": attention, "mamba": mamba, "mlstm": mlstm, "slstm": slstm}


# ------------------------------------------------------------------- init
def init_layer(key, cfg, spec, cross=False):
    ks = jax.random.split(key, 4)
    p = {"norm1": norm_param(ks[0], cfg.d_model), "mixer": MIXER_INIT[spec.mixer](ks[0], cfg)}
    if cross:
        p["norm_cross"] = norm_param(ks[1], cfg.d_model)
        p["cross"] = init_attention(ks[1], cfg)
    if spec.ffn == "dense":
        p["norm2"] = norm_param(ks[2], cfg.d_model)
        p["ffn"] = init_mlp(ks[2], cfg)
    elif spec.ffn == "moe":
        p["norm2"] = norm_param(ks[2], cfg.d_model)
        p["ffn"] = init_moe(ks[2], cfg)
    return p


def init_params(key, cfg):
    """Annotated parameter tree, list layout."""
    ks = jax.random.split(key, cfg.n_layers + 4)
    V, D = cfg.padded_vocab, cfg.d_model
    params: dict[str, Any] = {
        "embed": annotate(
            jax.random.normal(ks[0], (V, D), jnp.float32) * (1.0 / math.sqrt(D)),
            "vocab", "dmodel",
        ),
        "final_norm": norm_param(ks[1], D),
        "layers": [
            init_layer(ks[3 + i], cfg, cfg.layer_spec(i), cross=cfg.enc_dec)
            for i in range(cfg.n_layers)
        ],
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = annotate(
            jax.random.normal(ks[2], (D, V), jnp.float32) * (1.0 / math.sqrt(D)),
            "dmodel", "vocab",
        )
    if cfg.enc_dec:
        eks = jax.random.split(ks[2], cfg.n_enc_layers + 1)
        enc_spec = cfg.period[0]
        params["enc_layers"] = [
            init_layer(eks[i], cfg, enc_spec, cross=False) for i in range(cfg.n_enc_layers)
        ]
        params["enc_norm"] = norm_param(eks[-1], D)
    return params


def stack_for_scan(cfg, layers, n_layers=None, period=None):
    """Group per-layer trees by period position and stack across periods."""
    period = period if period is not None else cfg.period
    n_layers = n_layers if n_layers is not None else len(layers)
    P = len(period)
    assert n_layers % P == 0
    stacked = []
    for pos in range(P):
        group = [layers[j * P + pos] for j in range(n_layers // P)]
        stacked.append(
            jax.tree.map(
                lambda *xs: Annot(jnp.stack([x.value for x in xs]), ("layers",) + xs[0].axes)
                if isinstance(xs[0], Annot)
                else jnp.stack(xs),
                *group,
                is_leaf=lambda x: isinstance(x, Annot),
            )
        )
    return tuple(stacked)


def unstack_from_scan(stacked, n_layers):
    """Inverse of stack_for_scan (plain arrays, no annotations)."""
    P = len(stacked)
    layers = [None] * n_layers
    for pos in range(P):
        n = n_layers // P
        for j in range(n):
            layers[j * P + pos] = jax.tree.map(lambda a: a[j], stacked[pos])
    return layers


def stacked_init(key, cfg):
    """Annotated params with layers in scan layout (the train-state layout)."""
    p = init_params(key, cfg)
    p["layers"] = stack_for_scan(cfg, p["layers"])
    if cfg.enc_dec:
        p["enc_layers"] = stack_for_scan(cfg, p["enc_layers"], period=(cfg.period[0],))
    return p


# ----------------------------------------------------------------- layers
def apply_layer(cfg, spec, p, x, md, policy, cache=None):
    mix_cache = cache.get("mixer") if cache else None
    h, new_mix = MIXER_FN[spec.mixer](
        cfg, spec, p["mixer"], rms_norm(x, p["norm1"], cfg.norm_eps), md, policy, cache=mix_cache
    )
    x = x + h
    new_cache = {"mixer": new_mix} if new_mix is not None else None
    if "cross" in p:
        cmd = dict(md)
        cmd["cross_x"] = md.get("enc_out")
        ccache = cache.get("cross") if cache else None
        h, new_cross = attention(
            cfg, spec, p["cross"], rms_norm(x, p["norm_cross"], cfg.norm_eps), cmd, policy,
            cache=ccache,
        )
        x = x + h
        if new_cross is not None:  # prefill collect
            new_cache = dict(new_cache or {})
            new_cache["cross"] = new_cross
        elif new_cache is not None and ccache is not None:
            new_cache["cross"] = ccache  # cross KV is constant during decode
    if spec.ffn == "dense":
        x = x + mlp(cfg, p["ffn"], rms_norm(x, p["norm2"], cfg.norm_eps), policy)
    elif spec.ffn == "moe":
        x = x + moe_ffn(cfg, p["ffn"], rms_norm(x, p["norm2"], cfg.norm_eps), policy)
    x = policy.constrain(x, "batch", "seq", None)
    return x, new_cache


def _run_layers(cfg, stacked_layers, x, md, policy, caches=None, *, period=None,
                use_scan=True, remat=False):
    """Run the stacked (scan-layout) layers; returns (x, new_caches)."""
    period = period if period is not None else cfg.period
    P = len(period)

    def block(x, xs):
        p_slices, c_slices = xs
        new_cs = []
        for pos in range(P):
            c = c_slices[pos] if c_slices is not None else None
            x, nc = apply_layer(cfg, period[pos], p_slices[pos], x, md, policy, cache=c)
            new_cs.append(nc if nc is not None else 0)
        return x, tuple(new_cs)

    if remat:
        block = jax.checkpoint(block, prevent_cse=False)

    if use_scan:
        xs = (stacked_layers, caches)
        x, new_caches = jax.lax.scan(block, x, xs)
    else:
        n = jax.tree.leaves(stacked_layers[0])[0].shape[0]
        new_list = []
        for j in range(n):
            p_slices = jax.tree.map(lambda a: a[j], stacked_layers)
            c_slices = jax.tree.map(lambda a: a[j], caches) if caches is not None else None
            x, ncs = block(x, (p_slices, c_slices))
            new_list.append(ncs)
        new_caches = (
            jax.tree.map(lambda *xs: jnp.stack(xs), *new_list) if new_list and caches is not None else None
        )
    return x, new_caches


# ----------------------------------------------------------------- embed
def embed_tokens(cfg, params, tokens, compute_dtype=jnp.bfloat16):
    e = jnp.take(params["embed"], tokens, axis=0)
    return e.astype(compute_dtype)


def lm_logits(cfg, params, x, policy):
    w = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = jnp.einsum("bsd,dv->bsv", x, w.astype(x.dtype))
    return policy.constrain(logits, "batch", "seq", "vocab")


# ------------------------------------------------------------------ train
def _default_md(cfg, batch, flash_chunk):
    seg = batch["segment_ids"]
    B, S = seg.shape
    md = {
        "segment_ids": seg,
        "positions": batch["positions"],
        "abs_positions": jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S)),
        "flash_chunk": flash_chunk,
        "causal": True,
    }
    return md


def forward_train(cfg, params, batch, policy, *, use_scan=True, remat=True,
                  flash_chunk=1024, compute_dtype=jnp.bfloat16, _collect=None):
    """Returns logits (B, S, V) and aux dict. batch fields depend on family:

    LM:      tokens (B,S), segment_ids, positions
    VLM:     + vision_embeds (B,S_vis,D) replacing the first S_vis embeddings,
               positions (B,S,3) M-RoPE
    Audio:   frame_embeds (B,S_enc,D), dec_tokens (B,S_dec), (enc|dec)_segment_ids ...
    """
    aux = {"moe_aux": jnp.zeros((), jnp.float32)}
    if cfg.enc_dec:
        enc_x = batch["frame_embeds"].astype(compute_dtype)
        B, S_enc = enc_x.shape[:2]
        enc_md = {
            "segment_ids": batch["enc_segment_ids"],
            "positions": batch["enc_positions"],
            "abs_positions": jnp.broadcast_to(jnp.arange(S_enc, dtype=jnp.int32), (B, S_enc)),
            "flash_chunk": flash_chunk,
            "causal": False,
        }
        enc_x = policy.constrain(enc_x, "batch", "seq", None)
        enc_out, _ = _run_layers(
            cfg, params["enc_layers"], enc_x, enc_md, policy,
            period=(cfg.period[0],), use_scan=use_scan, remat=remat,
        )
        enc_out = rms_norm(enc_out, params["enc_norm"], cfg.norm_eps)
        tokens = batch["dec_tokens"]
        S_dec = tokens.shape[1]
        md = {
            "segment_ids": batch["dec_segment_ids"],
            "positions": batch["dec_positions"],
            "abs_positions": jnp.broadcast_to(jnp.arange(S_dec, dtype=jnp.int32), (B, S_dec)),
            "flash_chunk": flash_chunk,
            "causal": True,
            "enc_out": enc_out,
            "cross_segment_ids": batch["enc_segment_ids"],
            "cross_positions": enc_md["abs_positions"],
        }
        x = embed_tokens(cfg, params, tokens, compute_dtype)
    else:
        md = _default_md(cfg, batch, flash_chunk)
        x = embed_tokens(cfg, params, batch["tokens"], compute_dtype)
        if cfg.vlm and "vision_embeds" in batch:
            vis = batch["vision_embeds"].astype(compute_dtype)
            S_vis = vis.shape[1]
            x = jnp.concatenate([vis, x[:, S_vis:]], axis=1)

    if _collect is not None:
        md["collect_state"] = True
    x = policy.constrain(x, "batch", "seq", None)
    x, caches = _run_layers(cfg, params["layers"], x, md, policy, use_scan=use_scan, remat=remat)
    if _collect is not None:
        _collect["caches"] = caches
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = lm_logits(cfg, params, x, policy)

    if cfg.n_experts:  # load-balance aux from a replicated router pass (cheap)
        moe_layers = [p for pos, p in enumerate(params["layers"]) if cfg.period[pos].ffn == "moe"]
        if moe_layers:
            first = jax.tree.map(lambda a: a[0], moe_layers[0])
            aux["moe_aux"] = router_aux_loss(cfg, first["ffn"], x.astype(jnp.float32))
    return logits, aux


def loss_fn(cfg, params, batch, policy, **fw_kwargs):
    logits, aux = forward_train(cfg, params, batch, policy, **fw_kwargs)
    labels = batch["labels"]
    mask = (labels >= 0).astype(jnp.float32)
    labels_c = jnp.maximum(labels, 0)
    logits = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(logits, labels_c[..., None], axis=-1)[..., 0]
    nll = (lse - ll) * mask
    denom = jnp.maximum(mask.sum(), 1.0)
    loss = nll.sum() / denom
    zloss = 1e-4 * jnp.sum(jnp.square(lse) * mask) / denom
    total = loss + zloss + 0.01 * aux["moe_aux"]
    return total, {"loss": loss, "zloss": zloss, "moe_aux": aux["moe_aux"], "ntokens": mask.sum()}


def prefill_forward(cfg, params, batch, policy, *, use_scan=True, flash_chunk=1024,
                    compute_dtype=jnp.bfloat16):
    """Inference prefill: last-token logits + filled decode caches."""
    batch = dict(batch)
    logits, aux, caches = _forward_collect(
        cfg, params, batch, policy, use_scan=use_scan, flash_chunk=flash_chunk,
        compute_dtype=compute_dtype,
    )
    return logits[:, -1:], caches


def _forward_collect(cfg, params, batch, policy, **kw):
    """forward_train with collect_state threaded through (prefill mode)."""
    # Implemented by temporarily flagging metadata; reuse forward_train body via
    # a collect container.
    holder = {}
    logits, aux = forward_train(
        cfg, params, batch, policy, remat=False, _collect=holder, **kw
    )
    return logits, aux, holder.get("caches")


# ----------------------------------------------------------------- decode
def _layer_cache(cfg, spec, B, max_len, cache_dtype, cross_len=0):
    c = {}
    if spec.mixer == "attn":
        T = min(2 * cfg.window, max_len) if spec.attn_kind == "swa" else max_len
        K, dh = cfg.n_kv_heads, cfg.head_dim
        c["mixer"] = {
            "k": jnp.zeros((B, T, K, dh), cache_dtype),
            "v": jnp.zeros((B, T, K, dh), cache_dtype),
            "pos": jnp.full((B, T), -1, jnp.int32),
        }
    elif spec.mixer == "mamba":
        c["mixer"] = init_mamba_cache(cfg, B)
    elif spec.mixer == "mlstm":
        c["mixer"] = init_mlstm_cache(cfg, B)
    elif spec.mixer == "slstm":
        c["mixer"] = init_slstm_cache(cfg, B)
    if cfg.enc_dec:
        K, dh = cfg.n_kv_heads, cfg.head_dim
        c["cross"] = {
            "k_const": jnp.zeros((B, cross_len, K, dh), cache_dtype),
            "v_const": jnp.zeros((B, cross_len, K, dh), cache_dtype),
        }
    return c


def init_cache(cfg, B, max_len, cache_dtype=jnp.bfloat16, cross_len=0):
    """Stacked (scan-layout) decode cache."""
    per_layer = [
        _layer_cache(cfg, cfg.layer_spec(i), B, max_len, cache_dtype, cross_len)
        for i in range(cfg.n_layers)
    ]
    P = len(cfg.period)
    stacked = []
    for pos in range(P):
        group = [per_layer[j * P + pos] for j in range(cfg.n_layers // P)]
        stacked.append(jax.tree.map(lambda *xs: jnp.stack(xs), *group))
    return tuple(stacked)


def serve_forward(cfg, params, cache, batch, policy, compute_dtype=jnp.bfloat16):
    """One decode step. batch: tokens (B,1), lengths (B,) current positions.

    Returns (logits (B,1,V), new_cache).
    """
    tokens, lengths = batch["tokens"], batch["lengths"]
    B = tokens.shape[0]
    if cfg.mrope_sections is not None:
        positions = jnp.broadcast_to(lengths[:, None, None], (B, 1, 3)).astype(jnp.int32)
    else:
        positions = lengths[:, None].astype(jnp.int32)
    md = {
        "positions": positions,
        "lengths": lengths,
        "segment_ids": jnp.ones((B, 1), jnp.int32),
        "causal": True,
    }
    if cfg.enc_dec:
        md["cross_segment_ids"] = batch["cross_segment_ids"]
        md["cross_positions"] = batch["cross_positions"]
    x = embed_tokens(cfg, params, tokens, compute_dtype)
    x = policy.constrain(x, "batch", None, None)
    x, new_cache = _run_layers(cfg, params["layers"], x, md, policy, caches=cache, use_scan=True)
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = lm_logits(cfg, params, x, policy)
    return logits, new_cache
