from repro.models.model import (  # noqa: F401
    init_params,
    init_cache,
    forward_train,
    loss_fn,
    serve_forward,
    stack_for_scan,
)
