from repro.data.packing import pack_documents, pack_stats, quadratic_cost  # noqa: F401
from repro.data.synth import SyntheticPackedDataset, sample_doc_lengths  # noqa: F401
