"""Sequence packing: documents -> fixed-token-budget micro-batch rows.

This is the exact mechanism behind the paper's §2.2 observation: with packing,
every row has N tokens but attention cost is proportional to sum(l_i^2) of the
packed documents, which varies across micro-batches. `pack_stats` exposes
(N, sum l^2) — the features of the Detector's micro-batch time predictor
(Eq. 1).
"""
from __future__ import annotations

import numpy as np


def pack_documents(doc_lengths, seq_len, *, strategy="first_fit"):
    """Greedy first-fit packing of document lengths into rows of <= seq_len.

    Returns a list of rows; each row is a list of document lengths. Documents
    longer than seq_len are split into seq_len chunks first.
    """
    chunks = []
    for l in doc_lengths:
        l = int(l)
        while l > seq_len:
            chunks.append(seq_len)
            l -= seq_len
        if l > 0:
            chunks.append(l)
    if strategy == "first_fit_decreasing":
        chunks = sorted(chunks, reverse=True)
    # Exact first-fit via an implicit max-segment-tree over per-bin free
    # space: descending to the *leftmost* leaf whose subtree max >= l lands
    # on precisely the bin a naive left-to-right scan would pick, in
    # O(log bins) per document instead of O(bins) — the linear rescan
    # dominated workload generation once fleet-scale configs pushed
    # thousands of documents into hundreds of near-full bins.
    rows: list[list[int]] = []
    size = 1
    while size < len(chunks):
        size *= 2
    tree = [0] * (2 * size)  # leaf size+b = free space of rows[b]
    for l in chunks:
        if tree[1] >= l:
            i = 1
            while i < size:
                i *= 2
                if tree[i] < l:
                    i += 1
            rows[i - size].append(l)
            tree[i] -= l
        else:
            b = len(rows)
            rows.append([l])
            i = size + b
            tree[i] = seq_len - l
        while i > 1:
            i //= 2
            a, c = tree[2 * i], tree[2 * i + 1]
            tree[i] = a if a >= c else c
    return rows


def row_to_arrays(row, seq_len, rng, vocab):
    """One packed row -> (tokens, segment_ids, positions, labels)."""
    tokens = np.zeros(seq_len, np.int32)
    seg = np.zeros(seq_len, np.int32)
    pos = np.zeros(seq_len, np.int32)
    off = 0
    for i, l in enumerate(row):
        tokens[off : off + l] = rng.integers(1, vocab, size=l)
        seg[off : off + l] = i + 1
        pos[off : off + l] = np.arange(l)
        off += l
    labels = np.where(seg > 0, np.roll(tokens, -1), -1).astype(np.int32)
    # never predict across a document boundary or into padding
    boundary = np.roll(seg, -1) != seg
    labels[boundary] = -1
    return tokens, seg, pos, labels


def pack_stats(segment_ids: np.ndarray):
    """(tokens N, sum(l_i^2)) per row of a (B, S) segment-id array."""
    out = []
    for row in np.asarray(segment_ids):
        lens = np.bincount(row[row > 0])
        lens = lens[lens > 0]
        out.append((int(lens.sum()), int((lens.astype(np.int64) ** 2).sum())))
    return out


def quadratic_cost(row_lengths) -> int:
    return int(sum(int(l) ** 2 for l in row_lengths))
