"""Deterministic synthetic packed-LM dataset.

Document lengths follow a clipped lognormal — matching the long-tailed
distributions of real corpora (the paper's GitHub-dataset motivation) — so the
per-micro-batch sum(l^2) genuinely fluctuates and the Detector has something
real to filter.
"""
from __future__ import annotations

import numpy as np

from repro.data.packing import pack_documents, row_to_arrays


def sample_doc_lengths(rng, n, seq_len, *, mu=6.2, sigma=1.1, min_len=16):
    lens = rng.lognormal(mean=mu, sigma=sigma, size=n)
    return np.clip(lens, min_len, 4 * seq_len).astype(np.int64)


class SyntheticPackedDataset:
    """Resumable, deterministic iterator of packed batches.

    State is (epoch_seed, cursor) — checkpointable, so training resumes with
    identical data order after a failure (bitwise-reproducible loss curves,
    which the convergence-validation benchmark relies on).
    """

    def __init__(self, cfg, seq_len, global_batch, *, seed=0, mu=6.2, sigma=1.1):
        self.cfg = cfg
        self.seq_len = seq_len
        self.global_batch = global_batch
        self.seed = seed
        self.mu, self.sigma = mu, sigma
        self.cursor = 0

    def state(self):
        return {"seed": self.seed, "cursor": self.cursor}

    def restore(self, state):
        self.seed = state["seed"]
        self.cursor = state["cursor"]

    def batch_at(self, index: int):
        """Batch `index` (stateless — used for resume verification)."""
        rng = np.random.default_rng((self.seed, index))
        n_docs = max(8, int(self.global_batch * self.seq_len / np.exp(self.mu + self.sigma**2 / 2) * 0.9))
        lens = sample_doc_lengths(rng, n_docs, self.seq_len, mu=self.mu, sigma=self.sigma)
        rows = pack_documents(lens, self.seq_len)
        # top up with fresh docs until we can fill the batch
        while len(rows) < self.global_batch:
            extra = sample_doc_lengths(rng, 8, self.seq_len, mu=self.mu, sigma=self.sigma)
            rows.extend(pack_documents(extra, self.seq_len))
        rows = rows[: self.global_batch]
        B, S = self.global_batch, self.seq_len
        tokens = np.zeros((B, S), np.int32)
        seg = np.zeros((B, S), np.int32)
        pos = np.zeros((B, S), np.int32)
        labels = np.full((B, S), -1, np.int32)
        for b, row in enumerate(rows):
            tokens[b], seg[b], pos[b], labels[b] = row_to_arrays(row, S, rng, self.cfg.vocab_size)
        return {
            "tokens": tokens,
            "segment_ids": seg,
            "positions": pos,
            "labels": labels,
        }

    def __iter__(self):
        return self

    def __next__(self):
        b = self.batch_at(self.cursor)
        self.cursor += 1
        return b
