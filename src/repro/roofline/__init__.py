from repro.roofline.hlo import analyze_hlo_text, HloCost  # noqa: F401
from repro.roofline.analysis import roofline_terms, V5E  # noqa: F401
