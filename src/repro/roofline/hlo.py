"""HLO-text cost walker.

XLA's `compiled.cost_analysis()` does NOT multiply `while`-loop body costs by
trip count (verified on this container), and layer-stacked `lax.scan` (plus
flash-attention KV-chunk scans) is the only way to keep 70+ production-size
compiles tractable — so every interesting graph here is while-loop-shaped.
This walker parses `compiled.as_text()` and computes, per device:

  * flops            — dot/conv (2*M*N*K) + elementwise/reduce (1/elem)
  * hbm_bytes        — per executed op: operand bytes + output bytes
                       (fusion = fusion params + outputs), the standard
                       roofline traffic upper bound
  * collective_bytes — ring-model bytes per device, by collective kind

with `while` bodies scaled by trip counts extracted from loop-condition
constants. Validated against cost_analysis() on unrolled graphs in
tests/test_roofline.py.
"""
from __future__ import annotations

import math
import re
from dataclasses import dataclass, field

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "s32": 4, "s16": 2, "s8": 1, "u64": 8, "u32": 4, "u16": 2,
    "u8": 1, "pred": 1, "c64": 8, "c128": 16, "token": 0, "opaque": 0,
    "s4": 1, "u4": 1,
}

_ELEMENTWISE = {
    "add", "subtract", "multiply", "divide", "maximum", "minimum", "abs",
    "negate", "exponential", "log", "tanh", "rsqrt", "sqrt", "power",
    "select", "compare", "and", "or", "xor", "not", "floor", "ceil",
    "sign", "atan2", "remainder", "clamp", "logistic", "cbrt",
    "round-nearest-afz", "round-nearest-even", "exponential-minus-one",
    "log-plus-one", "cosine", "sine", "tan", "erf",
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

# ops charged for HBM traffic (beyond dot/conv/reduce/fusion/collectives)
_TRAFFIC_OPS = {
    "gather", "scatter", "dynamic-slice", "dynamic-update-slice", "concatenate",
    "pad", "sort", "select-and-scatter", "reverse", "cholesky", "fft",
    "triangular-solve", "rng", "rng-bit-generator", "transpose",
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_OP_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*(.*?)\s*([\w\-]+)\((.*)$"
)
_COMP_START_RE = re.compile(r"^\s*(?:ENTRY\s+)?%?([\w\.\-]+)\s*\(")


@dataclass
class Op:
    name: str
    opcode: str
    shapes: list  # list of (dtype, dims) for (possibly tuple) output
    operands: list  # operand names
    attrs: str
    is_root: bool = False
    scope: str = ""  # from metadata op_name (jax name stack)


@dataclass
class HloCost:
    flops: float = 0.0
    matmul_flops: float = 0.0
    hbm_bytes: float = 0.0
    collective_bytes: dict = field(default_factory=dict)
    hbm_by_opcode: dict = field(default_factory=dict)
    hbm_by_scope: dict = field(default_factory=dict)
    licm_credit: float = 0.0  # traffic removed by loop-invariant hoisting
    hoistable: float = 0.0  # this computation's loop-invariant charged bytes
    # ring bytes re-costed at the *pre-promotion* dtype: CPU HLO lowers bf16
    # dots as convert(f32) and SPMD reduces the f32 side; TPU reduces bf16.
    collective_bytes_tpu: dict = field(default_factory=dict)
    # all-reduce ring bytes the TPU while-loop pass sinks out of the loop
    sinkable_collective: float = 0.0
    sunk_collective_credit: float = 0.0
    warnings: list = field(default_factory=list)

    def _charge(self, opcode, nbytes, scope=""):
        self.hbm_bytes += nbytes
        self.hbm_by_opcode[opcode] = self.hbm_by_opcode.get(opcode, 0.0) + nbytes
        if scope:
            self.hbm_by_scope[scope] = self.hbm_by_scope.get(scope, 0.0) + nbytes

    @property
    def total_collective_bytes(self):
        return sum(self.collective_bytes.values())

    @property
    def total_collective_bytes_tpu(self):
        d = self.collective_bytes_tpu or self.collective_bytes
        return sum(d.values())

    def scaled(self, k):
        return HloCost(
            self.flops * k, self.matmul_flops * k, self.hbm_bytes * k,
            {kk: v * k for kk, v in self.collective_bytes.items()},
            {kk: v * k for kk, v in self.hbm_by_opcode.items()},
            {kk: v * k for kk, v in self.hbm_by_scope.items()},
            self.licm_credit * k, self.hoistable * k,
            {kk: v * k for kk, v in self.collective_bytes_tpu.items()},
            self.sinkable_collective * k, self.sunk_collective_credit * k,
            list(self.warnings),
        )

    def add(self, other):
        self.flops += other.flops
        self.matmul_flops += other.matmul_flops
        self.hbm_bytes += other.hbm_bytes
        for k, v in other.collective_bytes.items():
            self.collective_bytes[k] = self.collective_bytes.get(k, 0.0) + v
        for k, v in other.hbm_by_opcode.items():
            self.hbm_by_opcode[k] = self.hbm_by_opcode.get(k, 0.0) + v
        for k, v in other.hbm_by_scope.items():
            self.hbm_by_scope[k] = self.hbm_by_scope.get(k, 0.0) + v
        self.licm_credit += other.licm_credit
        self.hoistable += other.hoistable
        for k, v in other.collective_bytes_tpu.items():
            self.collective_bytes_tpu[k] = self.collective_bytes_tpu.get(k, 0.0) + v
        self.sinkable_collective += other.sinkable_collective
        self.sunk_collective_credit += other.sunk_collective_credit
        self.warnings.extend(other.warnings)

    def top_scopes(self, n=12):
        return sorted(self.hbm_by_scope.items(), key=lambda kv: -kv[1])[:n]

    def as_dict(self):
        return {
            "flops": self.flops,
            "matmul_flops": self.matmul_flops,
            "hbm_bytes": self.hbm_bytes,
            "collective_bytes": dict(self.collective_bytes),
            "total_collective_bytes": self.total_collective_bytes,
            "licm_credit": self.licm_credit,
            "hbm_top_scopes": dict(self.top_scopes()),
            "warnings": self.warnings[:20],
        }


def _parse_shapes(type_str):
    """All (dtype, dims) tensors in a (possibly tuple) type string."""
    out = []
    for m in _SHAPE_RE.finditer(type_str):
        dtype = m.group(1)
        if dtype not in _DTYPE_BYTES:
            continue
        dims = [int(d) for d in m.group(2).split(",") if d] if m.group(2) else []
        out.append((dtype, dims))
    return out


def _nbytes(shapes):
    return sum(_DTYPE_BYTES.get(dt, 4) * math.prod(dims or [1]) for dt, dims in shapes)


def _nelems(shapes):
    return sum(math.prod(dims or [1]) for _, dims in shapes)


def _split_operands(rest):
    """Operand list from 'a, %b, f32[2]{0} %c), attrs...' up to closing paren."""
    depth = 1
    ops, cur = [], []
    i = 0
    while i < len(rest) and depth > 0:
        c = rest[i]
        if c in "([{":
            depth += 1
        elif c in ")]}":
            depth -= 1
            if depth == 0:
                break
        elif c == "," and depth == 1:
            ops.append("".join(cur))
            cur = []
            i += 1
            continue
        cur.append(c)
        i += 1
    if cur:
        ops.append("".join(cur))
    attrs = rest[i + 1:] if i + 1 < len(rest) else ""
    names = []
    for o in ops:
        m = re.search(r"%([\w\.\-]+)\s*$", o.strip())
        names.append(m.group(1) if m else o.strip())
    return names, attrs


def parse_hlo(text):
    """-> dict computation_name -> list[Op]."""
    comps = {}
    current = None
    for raw in text.splitlines():
        line = raw.rstrip()
        if not line or line.lstrip().startswith("//"):
            continue
        if line.endswith("{") and "->" in line and "=" not in line.split("->")[0].split("(")[0]:
            m = _COMP_START_RE.match(line)
            if m:
                current = m.group(1)
                comps[current] = []
                if line.lstrip().startswith("ENTRY"):
                    comps["__entry__"] = comps[current]
                continue
        if line.strip() == "}":
            current = None
            continue
        if current is None:
            continue
        m = _OP_RE.match(line)
        if not m:
            continue
        name, type_str, opcode, rest = m.groups()
        operands, attrs = _split_operands(rest)
        is_root = line.lstrip().startswith("ROOT")
        sm = _SCOPE_RE.search(attrs)
        comps[current].append(Op(name, opcode, _parse_shapes(type_str), operands,
                                 attrs, is_root, _short_scope(sm.group(1)) if sm else ""))
    return comps


_SCOPE_RE = re.compile(r'op_name="([^"]*)"')


def _short_scope(op_name: str) -> str:
    """Compress a jax name-stack path to its informative tail: drop jit()/
    while/body boilerplate, keep the last two semantic segments — but always
    preserve explicit jax.named_scope markers (e.g. attn_core) wherever they
    sit in the path, including under jvp()/transpose()/remat wrappers."""
    for marker in ("attn_core", "mlstm_core", "moe_core"):
        if marker in op_name:
            tail = op_name.split("/")[-1]
            return f"{marker}/{tail}"
    parts = [p for p in op_name.split("/")
             if p and not p.startswith("jit(") and p not in
             ("while", "body", "cond", "closed_call", "checkpoint")]
    return "/".join(parts[-2:]) if parts else op_name[-40:]


def _group_size(attrs, warn):
    m = re.search(r"replica_groups=\{\{([\d,]+)\}", attrs)
    if m:
        return len(m.group(1).split(","))
    m = re.search(r"replica_groups=\[(\d+),(\d+)\]", attrs)
    if m:
        return int(m.group(2))
    warn.append(f"no replica_groups parsed: {attrs[:80]}")
    return 2


def _trip_count(comps, cond_name, warn):
    ops = comps.get(cond_name, [])
    consts = []
    for op in ops:
        if op.opcode == "constant":
            # operands list holds the literal, e.g. ['8']
            for o in op.operands:
                if re.fullmatch(r"\d+", o.strip()):
                    consts.append(int(o.strip()))
        if op.opcode == "fusion":
            # compare may be fused; scan the fused computation for constants
            m = re.search(r"calls=%?([\w\.\-]+)", op.attrs)
            if m:
                for op2 in comps.get(m.group(1), []):
                    if op2.opcode == "constant":
                        for o in op2.operands:
                            if re.fullmatch(r"\d+", o.strip()):
                                consts.append(int(o.strip()))
    if not consts:
        warn.append(f"while trip count not found for cond {cond_name}; assuming 1")
        return 1
    return max(consts)


_RING = {
    "all-gather": lambda out_b, in_b, g: out_b * (g - 1) / g,
    "all-reduce": lambda out_b, in_b, g: 2.0 * out_b * (g - 1) / g,
    "reduce-scatter": lambda out_b, in_b, g: in_b * (g - 1) / g,
    "all-to-all": lambda out_b, in_b, g: out_b * (g - 1) / g,
    "collective-permute": lambda out_b, in_b, g: out_b,
}


def _dot_flops(op, symtab):
    out_elems = _nelems(op.shapes)
    m = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", op.attrs)
    cdims = [int(d) for d in m.group(1).split(",") if d] if m else []
    lhs = symtab.get(op.operands[0])
    if lhs is None or not lhs:
        return 2.0 * out_elems  # unknown operand; degrade gracefully
    ldims = lhs[0][1]
    k = math.prod([ldims[d] for d in cdims]) if cdims else 1
    return 2.0 * out_elems * k


def _conv_flops(op, symtab):
    out_elems = _nelems(op.shapes)
    rhs = symtab.get(op.operands[1]) if len(op.operands) > 1 else None
    if rhs is None or not rhs:
        return 2.0 * out_elems
    kernel_elems = math.prod(rhs[0][1] or [1])
    # / output features: kernel is (spatial..., in, out)-ish; approximate with
    # kernel_elems / max(out_feature_dim) using the smallest kernel dim as out
    return 2.0 * out_elems * kernel_elems / max(min(rhs[0][1] or [1]), 1)


def _fusion_traffic(op, symtab, body_ops):
    """HBM traffic of one fusion: params + outputs, with slice-type access
    patterns charged at slice size:

      * dynamic-update-slice on a loop-carried buffer -> read+write the update
        region only (XLA aliases the buffer in place);
      * dynamic-slice / gather / take of a parameter -> read the slice/rows,
        not the whole table (stacked scan params, saved-activation buffers,
        embedding tables).

    Without these rules the walker over-counted ~10x on real train steps.
    """
    body_syms = {o.name: o.shapes for o in body_ops}
    plist = []
    for o in body_ops:
        if o.opcode == "parameter":
            raw = o.operands[0].strip() if o.operands else ""
            idx = int(raw) if raw.isdigit() else len(plist)
            plist.append((idx, o.name))
    body_params = {name: idx for idx, name in plist}
    sliced = {}  # body param name -> summed slice bytes
    dus_adjust = 0.0
    dus_bufs = set()
    for o in body_ops:
        if o.opcode == "dynamic-update-slice" and len(o.operands) >= 2:
            upd_b = _nbytes(body_syms.get(o.operands[1], []))
            dus_adjust += 2 * upd_b
            if o.operands[0] in body_params:
                dus_bufs.add(o.operands[0])
        elif o.opcode in ("dynamic-slice", "gather") and o.operands:
            src = o.operands[0]
            if src in body_params:
                sliced[src] = sliced.get(src, 0.0) + _nbytes(o.shapes)

    out_b = _nbytes(op.shapes)
    # map fusion operands to body params by parameter index
    traffic = 0.0
    param_names = [n for _, n in sorted(plist)]
    for i, operand in enumerate(op.operands):
        pname = param_names[i] if i < len(param_names) else None
        pb = _nbytes(symtab.get(operand, []))
        if pname in dus_bufs:
            continue  # aliased in-place buffer: charged via dus_adjust
        if pname in sliced:
            traffic += min(sliced[pname], pb)
        else:
            traffic += pb
    # outputs: if the fusion's root is a DUS buffer, the write was counted in
    # dus_adjust; otherwise charge the output size.
    if dus_bufs or dus_adjust:
        root_is_dus = any(o.opcode == "dynamic-update-slice" for o in body_ops)
        if not root_is_dus:
            traffic += out_b
    else:
        traffic += out_b
    return max(traffic + dus_adjust, 0.0)


def _invariant_names(ops):
    """Loop-invariant value names inside a while body.

    A while body takes one tuple parameter and returns a tuple; element i is
    invariant when the root tuple passes GTE(param, i) through unchanged.
    Any op all of whose operands are invariant (or constants) produces an
    invariant value — a LICM-capable backend (TPU XLA) hoists it out of the
    loop, so its traffic must be charged once, not x trip-count. CPU HLO
    leaves e.g. whole-buffer convert/broadcast inside scan bodies, which
    otherwise inflates the memory roofline term ~10x.
    """
    params = {op.name for op in ops if op.opcode == "parameter"}
    gte_index = {}
    for op in ops:
        if op.opcode == "get-tuple-element" and op.operands and op.operands[0] in params:
            m = re.search(r"index=(\d+)", op.attrs)
            if m:
                gte_index[op.name] = int(m.group(1))
    root = next((op for op in ops if op.is_root), None)
    if root is None or root.opcode != "tuple":
        return set()
    invariant_idx = {
        i for i, o in enumerate(root.operands)
        if o in gte_index and gte_index[o] == i
    }
    inv = {n for n, i in gte_index.items() if i in invariant_idx}
    inv |= {op.name for op in ops if op.opcode in ("constant", "iota")}
    known = {op.name for op in ops}
    for op in ops:
        if op.name in inv or op.opcode in ("parameter", "tuple"):
            continue
        if op.opcode.startswith(("all-", "reduce-scatter", "collective")):
            continue  # collectives are never hoisted here
        ok = all((o in inv) or (o not in known) for o in op.operands)
        # operands not in `known` are literals (e.g. constant payloads)
        if ok and op.operands:
            inv.add(op.name)
    return inv


_VMEM_RESIDENT_CAP = 64 * 2**20  # invariant operands up to 64 MB stay in VMEM
_VMEM_BUDGET = 96 * 2**20  # total carried state that can stay resident


def _carried_small(ops):
    """Loop-carried tuple elements small enough to stay VMEM-resident across
    iterations (recurrent state / gradient accumulators — the pattern
    production recurrent kernels keep in SRAM/VMEM). Returns ({gte_name},
    {root_operand_name}) for reads and writes respectively, or empty sets if
    the combined state exceeds the VMEM budget."""
    params = {op.name for op in ops if op.opcode == "parameter"}
    symtab = {op.name: op.shapes for op in ops}
    gte = {}
    for op in ops:
        if op.opcode == "get-tuple-element" and op.operands and op.operands[0] in params:
            m = re.search(r"index=(\d+)", op.attrs)
            if m:
                gte[op.name] = int(m.group(1))
    root = next((op for op in ops if op.is_root), None)
    if root is None or root.opcode != "tuple":
        return set(), set()
    # carried = tuple positions that change across iterations
    carried_idx = {
        i for i, o in enumerate(root.operands)
        if not (o in gte and gte[o] == i)
    }
    small_idx, total = set(), 0
    for name, i in gte.items():
        if i in carried_idx:
            b = _nbytes(symtab.get(name, []))
            if 0 < b <= _VMEM_RESIDENT_CAP:
                small_idx.add(i)
                total += b
    if total > _VMEM_BUDGET:
        return set(), set()
    reads = {name for name, i in gte.items() if i in small_idx}
    writes = set()
    for i, o in enumerate(root.operands):
        if i in small_idx and o in symtab:
            if 0 < _nbytes(symtab.get(o, [])) <= _VMEM_RESIDENT_CAP:
                writes.add(o)
    return reads, writes


def _sinkable_allreduce(ops):
    """All-reduce ops a TPU's WhileLoopAllReduceCodeMotion would sink out of
    the loop: the reduced value flows only into an additive accumulator that
    is carried to the root tuple (the scanned weight-gradient pattern — on
    CPU the reduce executes every iteration; TPU reduces once after the
    loop). Returns {allreduce_op_name} judged sinkable."""
    params = {op.name for op in ops if op.opcode == "parameter"}
    gte_index = {}
    for op in ops:
        if op.opcode == "get-tuple-element" and op.operands and op.operands[0] in params:
            m = re.search(r"index=(\d+)", op.attrs)
            if m:
                gte_index[op.name] = int(m.group(1))
    root = next((op for op in ops if op.is_root), None)
    if root is None or root.opcode != "tuple":
        return set()
    root_pos = {name: i for i, name in enumerate(root.operands)}
    consumers = {}
    for op in ops:
        for o in op.operands:
            consumers.setdefault(o, []).append(op)
    out = set()
    for ar in ops:
        if not ar.opcode.startswith("all-reduce"):
            continue
        # values derived from this all-reduce: itself + its GTEs
        derived = [ar.name] + [
            c.name for c in consumers.get(ar.name, [])
            if c.opcode == "get-tuple-element"
        ]
        ok = bool(derived)
        for d in derived:
            for c in consumers.get(d, []):
                if c.opcode == "get-tuple-element":
                    continue
                adds = c.opcode in ("add", "add_any") or (
                    c.opcode == "fusion" and ("add" in c.name or "accum" in c.name))
                accum = any(o in gte_index for o in c.operands)
                to_root = c.name in root_pos
                if not (adds and accum and to_root):
                    ok = False
                    break
            if not ok:
                break
        if ok:
            out.add(ar.name)
    return out


def _produces_f32_from_bf16(prod, symtab, comps):
    """True if `prod` is a convert(bf16 -> f32), directly or as the visible
    pattern inside its fused computation (CPU bf16-dot promotion)."""
    if prod.opcode == "convert" and prod.operands:
        src = symtab.get(prod.operands[0])
        return bool(src and src[0][0] == "bf16")
    if prod.opcode in ("fusion", "call"):
        m = re.search(r"(?:to_apply|calls)=%?([\w\.\-]+)", prod.attrs)
        body = comps.get(m.group(1), []) if m else []
        body_sym = {o.name: o.shapes for o in body}
        for o in body:
            if o.opcode == "convert" and o.shapes and o.shapes[0][0] == "f32":
                for oo in o.operands:
                    src = body_sym.get(oo)
                    if src and src[0][0] == "bf16":
                        return True
        # bf16 params converted implicitly by a dot with f32 output
        has_bf16_in = any(o.opcode == "parameter" and o.shapes
                          and o.shapes[0][0] == "bf16" for o in body)
        root = next((o for o in body if o.is_root), None)
        if has_bf16_in and root is not None and root.shapes and root.shapes[0][0] == "f32":
            return True
    return False


_GLUE_OPS = {"parameter", "convert", "bitcast", "copy", "reshape", "transpose",
             "constant", "broadcast", "tuple", "get-tuple-element"}


def _is_dtype_glue_fusion(op, comps):
    """True for fusions (or parallel-convert calls — newer XLA lowers the
    promotion as call ops with to_apply=) that only re-type/re-layout data
    between bf16 and f32 — the CPU lowering materializes f32 copies of every
    bf16 dot operand and result; the TPU MXU consumes bf16 directly with f32
    accumulation, so this traffic does not exist on the target."""
    m = re.search(r"(?:to_apply|calls)=%?([\w\.\-]+)", op.attrs)
    body = comps.get(m.group(1), []) if m else []
    if not body or any(o.opcode not in _GLUE_OPS for o in body):
        return False
    dts = {s[0] for o in body for s in o.shapes if s}
    return dts <= {"f32", "bf16", "f16"} and len(dts) >= 2


def _computation_cost(comps, name, memo, warn, body_of_while=False):
    if name in memo:
        return memo[name]
    cost = HloCost()
    ops = comps.get(name, [])
    symtab = {op.name: op.shapes for op in ops}
    op_by_name = {op.name: op for op in ops}
    invariant = _invariant_names(ops) if body_of_while else set()
    sinkable = _sinkable_allreduce(ops) if body_of_while else set()
    carried_r, carried_w = _carried_small(ops) if body_of_while else (set(), set())

    def charge(op, nbytes, opcode=None):
        cost._charge(opcode or op.opcode, nbytes, op.scope)
        credit = 0.0
        if op.name in invariant:
            credit = nbytes
        elif invariant or carried_r:
            # weights-stationary + VMEM-resident carried state: invariant
            # operands and small loop-carried accumulators/states are
            # fetched/stored on-chip across iterations; HBM sees them once.
            credit = sum(
                _nbytes(symtab.get(o, []))
                for o in op.operands
                if (o in invariant and _nbytes(symtab.get(o, [])) <= _VMEM_RESIDENT_CAP)
                or (o in carried_r)
            )
            if op.name in carried_w:
                credit += _nbytes(op.shapes)
        if credit:
            cost.hoistable += min(credit, nbytes)

    for op in ops:
        oc = op.opcode
        if oc in ("parameter", "constant", "get-tuple-element", "tuple", "bitcast",
                  "copy", "after-all", "partition-id", "replica-id", "iota"):
            continue
        if oc == "fusion":
            m = re.search(r"calls=%?([\w\.\-]+)", op.attrs)
            callee = m.group(1) if m else None
            inner = _computation_cost(comps, callee, memo, warn) if callee else HloCost()
            cost.flops += inner.flops
            cost.matmul_flops += inner.matmul_flops
            for k, v in inner.collective_bytes.items():
                cost.collective_bytes[k] = cost.collective_bytes.get(k, 0.0) + v
            if _is_dtype_glue_fusion(op, comps):
                charge(op, 0.0, "dtype_glue")  # fused into the MXU op on TPU
            else:
                charge(op, _fusion_traffic(op, symtab, comps.get(callee, [])), "fusion")
            continue
        if oc == "while":
            mb = re.search(r"body=%?([\w\.\-]+)", op.attrs)
            mc = re.search(r"condition=%?([\w\.\-]+)", op.attrs)
            trips = _trip_count(comps, mc.group(1), warn) if mc else 1
            body = (_computation_cost(comps, mb.group(1), memo, warn,
                                      body_of_while=True) if mb else HloCost())
            total = body.scaled(trips)
            # loop-invariant traffic executes once, not x trips (LICM)
            saved = body.hoistable * (trips - 1)
            total.hbm_bytes -= saved
            total.licm_credit += saved
            total.hoistable = 0.0  # invariance w.r.t. outer loops is unknown
            if saved:
                total.hbm_by_opcode["licm_hoisted"] = (
                    total.hbm_by_opcode.get("licm_hoisted", 0.0) - saved)
            # TPU while-loop all-reduce sinking: reduce once after the loop
            sunk = body.sinkable_collective * (trips - 1)
            if sunk:
                total.collective_bytes_tpu["all-reduce"] = (
                    total.collective_bytes_tpu.get("all-reduce", 0.0) - sunk)
                total.sunk_collective_credit += sunk
            total.sinkable_collective = 0.0
            cost.add(total)
            continue
        if oc in ("call", "custom-call"):
            if _is_dtype_glue_fusion(op, comps):
                charge(op, 0.0, "dtype_glue")  # fused into the MXU op on TPU
                continue
            m = re.search(r"(?:to_apply|calls)=%?([\w\.\-]+)", op.attrs)
            if m:
                cost.add(_computation_cost(comps, m.group(1), memo, warn))
            in_b = sum(_nbytes(symtab.get(o, [])) for o in op.operands)
            charge(op, in_b + _nbytes(op.shapes))
            continue
        if oc == "conditional":
            branches = re.findall(r"(?:branch_computations=\{|true_computation=|false_computation=)%?([\w\.\-]+)", op.attrs)
            if branches:
                costs = [_computation_cost(comps, b, memo, warn) for b in branches]
                best = max(costs, key=lambda c: c.flops)
                cost.add(best)
            continue
        if any(oc.startswith(c) for c in _COLLECTIVES):
            kind = next(c for c in _COLLECTIVES if oc.startswith(c))
            g = _group_size(op.attrs, warn) if kind != "collective-permute" else 2
            out_b = _nbytes(op.shapes)
            in_b = sum(_nbytes(symtab.get(o, [])) for o in op.operands)
            moved = _RING[kind](out_b, in_b, g)
            cost.collective_bytes[kind] = cost.collective_bytes.get(kind, 0.0) + moved
            # TPU dtype: CPU promotes bf16 dots to f32 before the reduce; if
            # the payload provably originated as bf16 (producer is a
            # convert-from-bf16 in this computation), re-cost at 2 bytes.
            moved_tpu = moved
            if op.operands and op.shapes and op.shapes[0][0] == "f32":
                prod = op_by_name.get(op.operands[0])
                if prod is not None and _produces_f32_from_bf16(prod, symtab, comps):
                    moved_tpu = moved * 0.5
            cost.collective_bytes_tpu[kind] = (
                cost.collective_bytes_tpu.get(kind, 0.0) + moved_tpu)
            if op.name in sinkable:
                cost.sinkable_collective += moved_tpu
            charge(op, out_b + in_b, kind)
            continue
        in_b = sum(_nbytes(symtab.get(o, [])) for o in op.operands)
        out_b = _nbytes(op.shapes)
        # HBM traffic is only charged at data-movement boundaries; bare
        # elementwise/convert/broadcast chains are assumed fused on the TPU
        # target (CPU HLO fuses far less aggressively — charging every unfused
        # op measured ~8x over plausible TPU traffic on qwen3-8b/train_4k).
        if oc == "dynamic-slice":
            charge(op, 2 * out_b)  # reads the slice, not the buffer
        elif oc == "dynamic-update-slice":
            upd = _nbytes(symtab.get(op.operands[1], [])) if len(op.operands) > 1 else out_b
            charge(op, 2 * upd)
        elif oc in _TRAFFIC_OPS:
            charge(op, in_b + out_b)
        if oc == "dot":
            f = _dot_flops(op, symtab)
            cost.flops += f
            cost.matmul_flops += f
            # TPU dtype: f32 operands that are CPU-promoted bf16 cost 2 bytes
            db = 0.0
            for o in op.operands:
                ob = _nbytes(symtab.get(o, []))
                prod = op_by_name.get(o)
                if (prod is not None and symtab.get(o) and symtab[o][0][0] == "f32"
                        and (_produces_f32_from_bf16(prod, symtab, comps)
                             or (prod.opcode in ("fusion", "call")
                                 and _is_dtype_glue_fusion(prod, comps)))):
                    ob *= 0.5
                db += ob
            charge(op, db + out_b)
        elif oc == "convolution":
            f = _conv_flops(op, symtab)
            cost.flops += f
            cost.matmul_flops += f
            charge(op, in_b + out_b)
        elif oc in _ELEMENTWISE:
            cost.flops += _nelems(op.shapes)
        elif oc in ("reduce", "reduce-window"):
            cost.flops += sum(_nelems(symtab.get(o, [])) for o in op.operands[: max(1, len(op.operands) // 2)])
            charge(op, in_b + out_b)
    memo[name] = cost
    return cost


def analyze_hlo_text(text) -> HloCost:
    """Per-device cost of the compiled module's entry computation."""
    comps = parse_hlo(text)
    memo = {}
    warn = []
    # find the entry computation
    entry = None
    for line in text.splitlines():
        if line.lstrip().startswith("ENTRY"):
            m = _COMP_START_RE.match(line)
            if m:
                entry = m.group(1)
                break
    if entry is None:
        raise ValueError("no ENTRY computation found")
    cost = _computation_cost(comps, entry, memo, warn)
    cost.warnings = warn + cost.warnings
    return cost
