"""Three-term roofline from the compiled dry-run artifact.

Hardware model (TPU v5e, per chip): 197 TFLOP/s bf16, 819 GB/s HBM,
~50 GB/s/link ICI. Terms are seconds-per-step, per the spec:

  compute   = HLO_FLOPs(device) / peak_FLOPs
  memory    = HLO_bytes(device) / HBM_bw
  collective= ring-bytes(device) / link_bw

MODEL_FLOPS uses 6*N*D (dense) / 6*N_active*D (MoE) for train; for inference
steps the multiplier is 2*N*D (forward only) — recorded per step kind.
"""
from __future__ import annotations

from dataclasses import dataclass


@dataclass(frozen=True)
class Hardware:
    name: str
    peak_flops: float  # bf16 FLOP/s per chip
    hbm_bw: float  # bytes/s per chip
    ici_bw: float  # bytes/s per link
    hbm_bytes: float  # capacity per chip


V5E = Hardware("tpu-v5e", 197e12, 819e9, 50e9, 16 * 1024**3)


def model_flops(cfg, shape, *, include_attention=True):
    """Analytic 'useful' FLOPs per step, per device-cluster (whole job)."""
    n_active = cfg.active_param_count()
    tokens = shape.global_batch * shape.seq_len
    if shape.kind == "train":
        base = 6 * n_active * tokens
    elif shape.kind == "prefill":
        base = 2 * n_active * tokens
    else:  # decode: one token per row
        base = 2 * n_active * shape.global_batch
    if include_attention and shape.kind != "decode":
        # quadratic attention term: 12*L_attn*H*dh*S^2 per row (train fwd+bwd)
        attn_layers = sum(1 for s in cfg.layer_specs() if s.mixer == "attn")
        per_row = 2 * 2 * attn_layers * cfg.n_heads * cfg.head_dim * shape.seq_len**2 / 2
        if shape.kind == "train":
            per_row *= 3  # bwd recompute ~2x fwd
        base += per_row * shape.global_batch
    return base


def attn_kernel_substitution(cost, cfg, shape, n_devices, *, tp=16,
                             passes=3.0, dtype_bytes=2):
    """Re-cost the attention interior under the Pallas packed-flash kernel.

    The pure-jnp flash path materializes (Sq x chunk) score/mask/softmax
    tensors in HBM every KV step (tagged `attn_core` via jax.named_scope and
    measured from the compiled artifact); the Pallas kernel keeps all of that
    in VMEM — its HBM traffic is just q/k/v reads + o writes per pass
    (forward, remat-recompute, backward ~= `passes` total, with backward
    additionally reading o/do and writing dq/dk/dv — folded into passes).

    Returns (new_cost_bytes, removed_bytes, kernel_bytes).
    """
    removed = sum(v for s, v in cost.hbm_by_scope.items() if "attn_core" in s)
    if removed == 0.0:
        return cost.hbm_bytes, 0.0, 0.0
    # per-device q/k/v/o bytes per layer pass
    n_attn = sum(1 for s in cfg.layer_specs() if s.mixer == "attn")
    tokens_dev = shape.global_batch * shape.seq_len / max(n_devices / tp, 1)
    q_o = 2 * tokens_dev * (cfg.q_dim / tp) * dtype_bytes
    k_v = 2 * tokens_dev * (cfg.kv_dim / max(min(tp, cfg.n_kv_heads), 1)) * dtype_bytes
    kernel_bytes = passes * n_attn * (q_o + k_v)
    new_total = cost.hbm_bytes - removed + kernel_bytes
    return new_total, removed, kernel_bytes


def optimized_roofline(cost, n_devices, cfg, shape, *, tp=16, hw: Hardware = V5E,
                       use_kernel=True, tpu_collectives=True):
    """Roofline terms for the OPTIMIZED configuration: the same compiled
    artifact re-costed under (a) the Pallas packed-flash kernel for the
    attention interior (scope-measured substitution) and (b) per-op
    bf16-origin dtype correction of collectives (TPU reduces bf16 where the
    CPU lowering promoted to f32). LICM is already part of the walker and
    applies to baseline and optimized alike."""
    mem_bytes = cost.hbm_bytes
    removed = kernel_bytes = 0.0
    if use_kernel:
        mem_bytes, removed, kernel_bytes = attn_kernel_substitution(
            cost, cfg, shape, n_devices, tp=tp)
    coll = (cost.total_collective_bytes_tpu if tpu_collectives
            else cost.total_collective_bytes)
    t_compute = cost.flops / hw.peak_flops
    t_memory = mem_bytes / hw.hbm_bw
    t_coll = coll / hw.ici_bw
    mf = model_flops(cfg, shape)
    t_star = max(t_compute, t_memory, t_coll)
    return {
        "compute_s": t_compute,
        "memory_s": t_memory,
        "collective_s": t_coll,
        "bound": max((("compute", t_compute), ("memory", t_memory),
                      ("collective", t_coll)), key=lambda kv: kv[1])[0],
        "attn_core_removed_bytes": removed,
        "attn_kernel_bytes": kernel_bytes,
        "roofline_fraction": (mf / n_devices / hw.peak_flops) / max(t_star, 1e-12),
    }


def roofline_terms(cost, n_devices, cfg=None, shape=None, hw: Hardware = V5E):
    """cost: HloCost per device. Returns dict of terms (seconds) + metadata."""
    t_compute = cost.flops / hw.peak_flops
    t_memory = cost.hbm_bytes / hw.hbm_bw
    t_coll = cost.total_collective_bytes / hw.ici_bw
    terms = {
        "compute_s": t_compute,
        "memory_s": t_memory,
        "collective_s": t_coll,
        "bound": max(
            (("compute", t_compute), ("memory", t_memory), ("collective", t_coll)),
            key=lambda kv: kv[1],
        )[0],
        "flops_per_device": cost.flops,
        "matmul_flops_per_device": cost.matmul_flops,
        "hbm_bytes_per_device": cost.hbm_bytes,
        "collective_bytes_per_device": cost.total_collective_bytes,
        "collective_breakdown": dict(cost.collective_bytes),
    }
    if cfg is not None and shape is not None:
        mf = model_flops(cfg, shape)
        terms["model_flops_total"] = mf
        terms["model_flops_per_device"] = mf / n_devices
        terms["useful_flops_ratio"] = (mf / n_devices) / max(cost.flops, 1.0)
        # roofline fraction: useful work / (dominant-term time x peak)
        t_star = max(t_compute, t_memory, t_coll)
        terms["roofline_fraction"] = (mf / n_devices / hw.peak_flops) / max(t_star, 1e-12)
    return terms
