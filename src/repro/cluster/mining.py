"""Coverage-guided adversarial scenario mining over the failure-scenario DSL.

The 18-scenario catalog is hand-authored: sweeps over it only measure
failure patterns somebody already thought of. This module searches the
scenario space itself — seeded, budget-bounded, on the fast engine at the
Fig. 14 scale (256 devices) — for *distinct* worst-case failure timelines:

* **candidates** are literal event timelines — tuples of
  ``(t, kind, target, value)`` — produced by mutating and splicing the
  compiled catalog (perturb times/severities, retarget victims, duplicate
  and drop events, splice event subsequences between scenarios, compose
  whole families);
* every candidate is canonicalized by :func:`repair_timeline`, which turns
  an arbitrary event soup into a timeline that passes
  :meth:`EventTrace.validate <repro.cluster.events.EventTrace.validate>` —
  the same hardening that rejects contradictory hand-written scenarios —
  and bounds the adversary's *failure budget* to the worst hand-authored
  storm's (so the miner finds scheduling/timing attacks, not trivial
  mass kills);
* candidates are **scored** by per-policy session-throughput loss under
  ``resihp`` plus a bonus for *policy-ranking flips* — cases where a
  baseline that ``resihp`` normally beats comes out ahead;
* the archive is keyed by a coarse **timeline feature signature**
  (:func:`signature`): near-identical candidates collapse into one cluster
  and the search keeps the best scorer per cluster while mutating from the
  elite set (MAP-elites style), so the output ranks *distinct* failure
  patterns rather than one pattern rediscovered a hundred times.

Because every candidate is an engine input nobody hand-checked, the mining
loop doubles as a continuous fuzz harness for the scenario/event/engine
stack: ``tests/test_mining.py`` replays mutated candidates through both
execution engines and pins fast/python parity on each.

Determinism contract: :func:`mine` is a pure function of
``(seed, budget, config)`` — mutation RNG streams are derived per
``(seed, generation, slot)``, candidate evaluation is a pure function of
the candidate, and archive updates happen in canonical slot order — so the
mined JSON is byte-identical across runs *and across worker counts* when
the evaluation fans out through ``benchmarks.sweep.pmap``.

The driver is ``tools/mine_scenarios.py``; the checked-in survivors are the
``adversarial_*`` family in :mod:`repro.cluster.scenarios`, regression-
pinned by ``tests/test_adversarial_golden.py``.
"""
from __future__ import annotations

import json
import math
from typing import Callable, Optional, Sequence

import numpy as np

from repro.cluster.events import DEVICE_KINDS, NODE_KINDS, EventTrace
from repro.cluster.registry import ClusterTopology
from repro.cluster.simulator import SimConfig, TrainingSim

__all__ = [
    "MINING_MODEL", "POLICIES", "mining_config", "mining_topology",
    "catalog_seeds", "compile_seed_timelines", "damage", "damage_cap",
    "repair_timeline", "mutate", "signature", "evaluate_candidate",
    "score", "mine", "to_json",
]

# ------------------------------------------------------------- mining scale
# llama2-13b layer costs on the paper's Table-3 "xlarge" parallelism —
# (TP, DP, PP) = (4, 4, 16) = 256 devices, the Fig. 14 scale. Small enough
# that the fast engine scores thousands of candidates per CPU-hour, large
# enough that rack locality and TP-group structure matter.
MINING_MODEL = dict(dp=4, pp=16, tp=4, n_layers=40, n_microbatches=8,
                    seq_len=8192, noise=0.01)

# policy label -> (policy name, policy kwargs). The resihp row charges the
# deterministic PlanOverheadModel so a candidate's score is a pure function
# of its timeline (the same contract benchmarks/sweep.py cells rely on).
POLICIES = {
    "resihp": ("resihp", {"plan_overhead_model": True}),
    "recycle+": ("recycle+", {}),
    "oobleck+": ("oobleck+", {}),
}

FAIL_KINDS = ("fail-stop", "fail-stop-node", "fail-slow", "net-degrade")


def mining_config(seed: int = 0, **overrides) -> SimConfig:
    kw = dict(MINING_MODEL)
    kw.update(overrides)
    return SimConfig(seed=seed, **kw)


def mining_topology(cfg: SimConfig) -> ClusterTopology:
    return ClusterTopology(math.ceil(cfg.n_devices / cfg.devices_per_node),
                           cfg.devices_per_node)


# ------------------------------------------------------------ seed catalog
def catalog_seeds(span: float) -> dict:
    """The hand-authored catalog rescaled to the mining span — the initial
    population and the donor pool for splice/compose operators.

    ``table5_failslow`` (a single event) and ``example_mixed`` (an example
    with literal quickstart device ids) are omitted; the ``adversarial_*``
    family itself is never a seed, so re-mining cannot bootstrap from its
    own previous output."""
    from repro.cluster.scenarios import get
    return {
        "fig9_failslow": get("fig9_failslow", at=0.12 * span),
        "fig10_mixed": get("fig10_mixed", span=span),
        "fig11_mixed": get("fig11_mixed", span=span),
        "fig14_largescale": get("fig14_largescale", span=span),
        "table6_failstop": get("table6_failstop", span=span),
        "rack_storm": get("rack_storm", at=0.15 * span,
                          recover_after=0.5 * span),
        "rack_storm_256": get("rack_storm_256", span=span),
        "flap_then_recover": get("flap_then_recover", at=0.1 * span,
                                 down_time=0.02 * span, up_time=0.08 * span),
        "flapping_stragglers": get("flapping_stragglers", span=span),
        "slow_ramp_mix": get("slow_ramp_mix", span=span),
        "thermal_throttle_fleet": get("thermal_throttle_fleet", span=span),
        "poisson_storm": get("poisson_storm", rate=4.0 / span, t_end=span,
                             mttr=0.25 * span),
        "degraded_rejoins": get("degraded_rejoins", span=span),
        "aging_fleet": get("aging_fleet", span=span),
        "lemon_devices": get("lemon_devices", span=span),
        "infant_mortality": get("infant_mortality", span=span),
    }


def compile_seed_timelines(topo: ClusterTopology, span: float,
                           seed: int = 0) -> dict:
    """name -> (t, kind, target, value) timeline for every catalog seed."""
    out = {}
    for name, scen in catalog_seeds(span).items():
        out[name] = tuple((float(ev.t), ev.kind, int(ev.target),
                           float(ev.value))
                          for ev in scen.compile(topo, seed))
    return out


# --------------------------------------------------------- damage / repair
def damage(timeline: Sequence[tuple], topo: ClusterTopology) -> float:
    """The adversary's spent failure budget: 1.0 per fail-stopped device,
    the lost speed fraction per fail-slow, and the comm-share-weighted loss
    per net-degraded node. Rejoins/restores give nothing back — the budget
    prices injected faults, not their net effect."""
    total = 0.0
    for t, kind, target, value in timeline:
        if kind == "fail-stop":
            total += 1.0
        elif kind == "fail-stop-node":
            total += topo.devices_per_node
        elif kind == "fail-slow":
            total += 1.0 - value
        elif kind == "net-degrade":
            total += 0.3 * topo.devices_per_node * (1.0 - value)
    return total


def damage_cap(topo: ClusterTopology, span: float, seed: int = 0) -> float:
    """The worst hand-authored storm's failure budget at this scale: mined
    candidates may not inject more total damage than the catalog's heaviest
    scenario, so a winner is a worse *pattern*, not just a bigger hammer."""
    return max(damage(tl, topo)
               for tl in compile_seed_timelines(topo, span, seed).values())


def repair_timeline(timeline: Sequence[tuple], topo: ClusterTopology,
                    span: float, *, max_events: int = 64,
                    cap: Optional[float] = None) -> tuple:
    """Canonicalize an arbitrary event soup into a valid timeline.

    Deterministic (no RNG): clamp times into ``[0, span]`` and targets into
    range (mod n — remapping is what lets a mined 256-device pattern replay
    on any topology), clamp values into their legal ranges, sort by the
    Event ordering, then walk the per-device state machine dropping every
    event :meth:`EventTrace.validate` would reject (double kills, rejoins
    of healthy devices, net-restores without a degrade) and every fail
    event past the damage ``cap``. The result always validates; a valid
    in-budget timeline passes through unchanged (bar float rounding to 6
    decimals, which the miner applies everywhere)."""
    n_dev, n_nodes = topo.n_devices, topo.n_nodes
    cleaned = []
    for t, kind, target, value in timeline:
        if kind in DEVICE_KINDS:
            target = int(target) % n_dev
        elif kind in NODE_KINDS:
            target = int(target) % n_nodes
        else:
            continue  # callbacks and unknown kinds are not minable
        t = round(min(max(float(t), 0.0), span), 6)
        value = float(value)
        if kind == "fail-slow":
            value = min(max(value, 0.05), 1.0)
        elif kind == "net-degrade":
            value = min(max(value, 0.05), 1.0)
        elif kind == "rejoin":
            value = value if 0.0 < value < 1.0 else 0.0
        else:
            value = 0.0
        cleaned.append((t, kind, target, round(value, 6)))
    # the Event sort key (t, kind, target, value) — the exact order
    # EventTrace will replay in, so the state walk below sees replay order
    cleaned.sort()
    alive: dict = {}
    degraded: set = set()
    net_down: set = set()
    spent = 0.0
    out = []
    for t, kind, target, value in cleaned:
        if kind == "fail-stop":
            if not alive.get(target, True):
                continue
            if cap is not None and spent + 1.0 > cap + 1e-9:
                continue
            spent += 1.0
            alive[target] = False
        elif kind == "fail-stop-node":
            devs = range(target * topo.devices_per_node,
                         (target + 1) * topo.devices_per_node)
            if all(not alive.get(d, True) for d in devs):
                continue
            cost = float(topo.devices_per_node)
            if cap is not None and spent + cost > cap + 1e-9:
                continue
            spent += cost
            for d in devs:
                alive[d] = False
        elif kind == "fail-slow":
            if not alive.get(target, True):
                continue
            cost = 1.0 - value
            if cap is not None and spent + cost > cap + 1e-9:
                continue
            spent += cost
            degraded.add(target)
        elif kind == "rejoin":
            if alive.get(target, True) and target not in degraded:
                continue
            alive[target] = True
            degraded.discard(target)
            if 0.0 < value < 1.0:
                degraded.add(target)
        elif kind == "net-degrade":
            cost = 0.3 * topo.devices_per_node * (1.0 - value)
            if cap is not None and spent + cost > cap + 1e-9:
                continue
            spent += cost
            net_down.add(target)
        elif kind == "net-restore":
            if target not in net_down:
                continue
            net_down.discard(target)
        out.append((t, kind, target, value))
        if len(out) >= max_events:
            break  # validity is prefix-closed: a truncated tail stays valid
    return tuple(out)


# ------------------------------------------------------ mutation operators
def _pick(rng: np.random.Generator, evs: list) -> int:
    return int(rng.integers(0, len(evs)))


def _rand_event(rng, topo, span) -> tuple:
    kind = FAIL_KINDS[int(rng.integers(0, len(FAIL_KINDS)))]
    t = float(rng.uniform(0.0, span))
    if kind in NODE_KINDS:
        target = int(rng.integers(0, topo.n_nodes))
    else:
        target = int(rng.integers(0, topo.n_devices))
    value = float(rng.uniform(0.05, 0.95)) if kind in ("fail-slow",
                                                       "net-degrade") else 0.0
    return (t, kind, target, value)


def _op_jitter_time(evs, rng, topo, span, pool):
    """Perturb the times of a few events (shift a failure into or out of a
    detection/replanning window)."""
    for _ in range(int(rng.integers(1, 4))):
        i = _pick(rng, evs)
        t, kind, target, value = evs[i]
        evs[i] = (t + float(rng.normal(0.0, 0.08 * span)), kind, target, value)
    return evs


def _op_scale_time(evs, rng, topo, span, pool):
    """Compress or stretch the whole storm (burstiness is an axis the
    hand-authored catalog barely explores)."""
    f = float(np.exp(rng.normal(0.0, 0.35)))
    return [(t * f, kind, target, value) for t, kind, target, value in evs]


def _op_perturb_value(evs, rng, topo, span, pool):
    """Resample a severity / link scale / rejoin return speed."""
    i = _pick(rng, evs)
    t, kind, target, value = evs[i]
    if kind in ("fail-slow", "net-degrade"):
        value = float(rng.uniform(0.05, 0.95))
    elif kind == "rejoin":
        # half the draws return the device degraded, half at full health
        value = float(rng.uniform(0.2, 0.95)) if rng.uniform() < 0.5 else 0.0
    evs[i] = (t, kind, target, value)
    return evs


def _op_retarget(evs, rng, topo, span, pool):
    """Move a few events to new victims."""
    for _ in range(int(rng.integers(1, 4))):
        i = _pick(rng, evs)
        t, kind, target, value = evs[i]
        hi = topo.n_nodes if kind in NODE_KINDS else topo.n_devices
        evs[i] = (t, kind, int(rng.integers(0, hi)), value)
    return evs


def _op_shift_targets(evs, rng, topo, span, pool):
    """Shift every victim id by one offset: the same pattern landing on a
    different set of TP groups / racks (structure-preserving retarget)."""
    off = int(rng.integers(1, topo.n_devices))
    out = []
    for t, kind, target, value in evs:
        mod = topo.n_nodes if kind in NODE_KINDS else topo.n_devices
        out.append((t, kind, (target + off) % mod, value))
    return out


def _op_drop(evs, rng, topo, span, pool):
    """Remove events (minimization pressure: simpler timelines that keep
    the score survive clustering better)."""
    for _ in range(int(rng.integers(1, 4))):
        if len(evs) > 1:
            evs.pop(_pick(rng, evs))
    return evs


def _op_duplicate(evs, rng, topo, span, pool):
    """Repeat an existing event at a jittered time/target (recurrence —
    the repeat-offender pattern)."""
    t, kind, target, value = evs[_pick(rng, evs)]
    t = t + float(rng.normal(0.0, 0.15 * span))
    hi = topo.n_nodes if kind in NODE_KINDS else topo.n_devices
    if rng.uniform() < 0.5:
        target = int(rng.integers(0, hi))
    evs.append((t, kind, target, value))
    return evs


def _op_insert(evs, rng, topo, span, pool):
    """Inject fresh primitive events."""
    for _ in range(int(rng.integers(1, 3))):
        evs.append(_rand_event(rng, topo, span))
    return evs


def _op_splice(evs, rng, topo, span, pool):
    """Splice a time window of another timeline into this one (the
    subsequence-recombination operator: compound failures no single
    generator emits)."""
    donor = pool[int(rng.integers(0, len(pool)))]
    if donor:
        lo = float(rng.uniform(0.0, span))
        hi = lo + float(rng.uniform(0.1, 0.5)) * span
        evs.extend(e for e in donor if lo <= e[0] < hi)
    return evs


def _op_compose(evs, rng, topo, span, pool):
    """Overlay a whole donor timeline (family composition)."""
    evs.extend(pool[int(rng.integers(0, len(pool)))])
    return evs


OPERATORS = (
    _op_jitter_time, _op_scale_time, _op_perturb_value, _op_retarget,
    _op_shift_targets, _op_drop, _op_duplicate, _op_insert, _op_splice,
    _op_compose,
)


def mutate(timeline: Sequence[tuple], rng: np.random.Generator,
           topo: ClusterTopology, span: float, pool: Sequence[tuple], *,
           max_events: int = 64, cap: Optional[float] = None) -> tuple:
    """Apply 1-3 random operators, then repair to a valid in-budget
    timeline. Deterministic given the rng state."""
    evs = list(timeline)
    for _ in range(int(rng.integers(1, 4))):
        op = OPERATORS[int(rng.integers(0, len(OPERATORS)))]
        evs = op(evs, rng, topo, span, pool)
        if not evs:
            evs = [_rand_event(rng, topo, span)]
    return repair_timeline(evs, topo, span, max_events=max_events, cap=cap)


# ------------------------------------------------------- cluster signature
def _bucket(n: float) -> int:
    """Coarse log2 bucket: 0, 1, 2, 2, 3, 3, 3, 3, 4, ..."""
    return int(n).bit_length() if n > 0 else 0


def signature(timeline: Sequence[tuple], topo: ClusterTopology,
              span: float) -> tuple:
    """Coarse feature signature of a timeline — the clustering key.

    Two candidates with the same signature are considered the same failure
    *pattern* (the archive keeps only the worse one); distinct signatures
    are distinct patterns, ranked separately in the mined output. Features:
    log-bucketed event-kind counts, victim spread (devices / nodes),
    a 3-bin temporal histogram of fail events, the mean fail-slow depth,
    and the peak number of concurrently-dead devices."""
    kinds = {k: 0 for k in ("fail-stop", "fail-stop-node", "fail-slow",
                            "net-degrade", "net-restore", "rejoin")}
    devices, nodes = set(), set()
    thirds = [0, 0, 0]
    sev_sum, sev_n = 0.0, 0
    alive: dict = {}
    max_down = down = 0
    for t, kind, target, value in timeline:
        kinds[kind] += 1
        if kind in NODE_KINDS:
            nodes.add(target)
        else:
            devices.add(target)
            nodes.add(topo.node_of(target))
        if kind in FAIL_KINDS:
            thirds[min(int(3.0 * t / max(span, 1e-9)), 2)] += 1
        if kind == "fail-slow":
            sev_sum += value
            sev_n += 1
        if kind == "fail-stop" and alive.get(target, True):
            alive[target] = False
            down += 1
            max_down = max(max_down, down)
        elif kind == "fail-stop-node":
            for d in range(target * topo.devices_per_node,
                           (target + 1) * topo.devices_per_node):
                if alive.get(d, True):
                    alive[d] = False
                    down += 1
            max_down = max(max_down, down)
        elif kind == "rejoin" and not alive.get(target, True):
            alive[target] = True
            down -= 1
    sev_bin = int(4.0 * sev_sum / sev_n) if sev_n else 0  # mean depth, 0-4
    return (
        _bucket(kinds["fail-stop"] + 8 * kinds["fail-stop-node"]),
        _bucket(kinds["fail-slow"]),
        _bucket(kinds["rejoin"]),
        _bucket(kinds["net-degrade"] + kinds["net-restore"]),
        _bucket(len(devices)),
        _bucket(len(nodes)),
        _bucket(thirds[0]), _bucket(thirds[1]), _bucket(thirds[2]),
        sev_bin,
        _bucket(max_down),
    )


# ------------------------------------------------------------- evaluation
def evaluate_candidate(job: tuple) -> dict:
    """Score one candidate timeline: run it under every policy and record
    session throughputs. Pure function of the job tuple (per-candidate
    seeding, deterministic engines) — safe to fan out across processes in
    any order. Shaped for ``benchmarks.sweep.pmap``."""
    timeline, cfg_kw, iters, policy_labels, engine = job
    from repro.cluster.scenarios import TimelineScenario

    cfg = SimConfig(**cfg_kw)
    sessions, aborted, elapsed = {}, {}, {}
    for label in policy_labels:
        name, policy_kw = POLICIES[label]
        sim = TrainingSim(name, cfg, engine=engine, policy_kwargs=policy_kw)
        scen = TimelineScenario(span=1.0, timeline=timeline, permute=False,
                                label="mined")
        sim.apply_scenario(scen)
        sim.run(iters, stop_on_abort=False)
        sessions[label] = sim.session_throughput(skip=2)
        aborted[label] = sim.aborted
        elapsed[label] = float(sim.now)
    return {"session": sessions, "aborted": aborted, "elapsed": elapsed}


def score(result: dict, healthy: dict) -> dict:
    """Rank a candidate: ``resihp`` session-throughput loss vs healthy,
    plus half credit for the margin of any policy-ranking flip (a baseline
    ``resihp`` normally beats finishing ahead of it)."""
    h = max(healthy["session"]["resihp"], 1e-9)
    resi = result["session"]["resihp"]
    loss = 1.0 - resi / h
    rivals = [v for k, v in result["session"].items() if k != "resihp"]
    flip_margin = max(0.0, (max(rivals) - resi) / h) if rivals else 0.0
    return {
        "score": round(loss + 0.5 * flip_margin, 9),
        "resihp_loss": round(loss, 9),
        "flip": bool(rivals) and max(rivals) > resi,
        "flip_margin": round(flip_margin, 9),
    }


# ------------------------------------------------------------- the search
def _serial_map(fn: Callable, items: list) -> list:
    return [fn(x) for x in items]


def mine(*, seed: int = 0, budget: int = 96, iters: int = 30,
         span: Optional[float] = None, cfg: Optional[SimConfig] = None,
         policies: Sequence[str] = ("resihp", "recycle+", "oobleck+"),
         engine: str = "fast", batch: int = 8, elites: int = 8,
         top_k: int = 8, max_events: int = 64,
         pool_map: Optional[Callable] = None) -> dict:
    """Run the coverage-guided search and return the mined report dict.

    ``budget`` counts evaluated candidates (catalog seeds included;
    the healthy baseline run is free). ``pool_map(fn, items)`` fans the
    per-candidate evaluation out (pass ``benchmarks.sweep.pmap`` bound to a
    worker count); the default is the in-process serial reference. The
    report is byte-identical (via :func:`to_json`) for a fixed
    ``(seed, budget, config)`` regardless of ``pool_map``."""
    cfg = cfg or mining_config()
    topo = mining_topology(cfg)
    policies = list(policies)
    pmap_fn = pool_map or _serial_map
    cfg_kw = dict(dp=cfg.dp, pp=cfg.pp, tp=cfg.tp, n_layers=cfg.n_layers,
                  n_microbatches=cfg.n_microbatches, seq_len=cfg.seq_len,
                  noise=cfg.noise, seed=cfg.seed,
                  devices_per_node=cfg.devices_per_node)

    def jobs(timelines):
        return [(tl, cfg_kw, iters, tuple(policies), engine)
                for tl in timelines]

    healthy = evaluate_candidate(((), cfg_kw, iters, tuple(policies), engine))
    if span is None:
        # front-load the storm window into the healthy session: events land
        # in the first 60% of a failure-free run, leaving recovery room that
        # session_throughput can observe (failures only stretch the session,
        # so every event inside this window actually fires)
        span = round(0.6 * healthy["elapsed"]["resihp"], 6)

    seed_tls = compile_seed_timelines(topo, span, seed)
    cap = max(damage(tl, topo) for tl in seed_tls.values())
    names = sorted(seed_tls)
    repaired = {n: repair_timeline(seed_tls[n], topo, span,
                                   max_events=max_events, cap=cap)
                for n in names}

    archive: dict = {}  # signature -> entry (best scorer per cluster)
    evaluated = 0

    def admit(label, timeline, result):
        sig = signature(timeline, topo, span)
        sc = score(result, healthy)
        entry = {
            "label": label,
            "signature": list(sig),
            "timeline": [list(e) for e in timeline],
            "n_events": len(timeline),
            "damage": round(damage(timeline, topo), 6),
            "session_throughput": {k: round(v, 9)
                                   for k, v in result["session"].items()},
            "aborted": result["aborted"],
            **sc,
        }
        best = archive.get(sig)
        if best is None or entry["score"] > best["score"]:
            archive[sig] = entry
        return entry

    # generation 0: the catalog itself (its scores double as the
    # worst-hand-authored baseline the acceptance criteria compare against)
    n_seeds = min(len(names), budget)
    seed_results = pmap_fn(evaluate_candidate,
                           jobs([repaired[n] for n in names[:n_seeds]]))
    catalog = {}
    for name, result in zip(names[:n_seeds], seed_results):
        entry = admit(f"seed:{name}", repaired[name], result)
        catalog[name] = {k: entry[k] for k in
                         ("score", "resihp_loss", "flip",
                          "session_throughput", "n_events", "damage")}
    evaluated += n_seeds

    gen = 0
    while evaluated < budget:
        gen += 1
        n = min(batch, budget - evaluated)
        # objective-diverse elite set: half the slots by combined score,
        # half by raw resihp loss — otherwise one objective's lineages
        # (e.g. wide-flip flap storms) crowd the pool and starve the search
        # for deepest-throughput-loss patterns
        by_score = sorted(archive.values(),
                          key=lambda e: (-e["score"], tuple(e["signature"])))
        by_loss = sorted(archive.values(),
                         key=lambda e: (-e["resihp_loss"],
                                        tuple(e["signature"])))
        elite, seen = [], set()
        for e in [x for pair in zip(by_score, by_loss) for x in pair]:
            sig = tuple(e["signature"])
            if sig not in seen:
                seen.add(sig)
                elite.append(e)
            if len(elite) >= elites:
                break
        donor_pool = [repaired[nm] for nm in names] + \
                     [tuple(tuple(e) for e in el["timeline"]) for el in elite]
        children, labels = [], []
        for i in range(n):
            parent = elite[i % len(elite)]
            rng = np.random.default_rng([seed & 0xFFFFFFFF, gen, i])
            child = mutate(tuple(tuple(e) for e in parent["timeline"]),
                           rng, topo, span, donor_pool,
                           max_events=max_events, cap=cap)
            children.append(child)
            labels.append(f"g{gen}.{i}<-{parent['label']}")
        for label, child, result in zip(
                labels, children, pmap_fn(evaluate_candidate, jobs(children))):
            admit(label, child, result)
        evaluated += n

    # the emitted survivors are *mined* patterns: un-mutated catalog seeds
    # stay in the archive (they steer the elite set and donor pool) and in
    # the ``catalog`` table below, but never rank as adversarial output
    ranked = [e for e in sorted(archive.values(),
                                key=lambda e: (-e["score"],
                                               tuple(e["signature"])))
              if not e["label"].startswith("seed:")]
    worst_name = min(catalog, key=lambda n: (-catalog[n]["score"], n))

    # the checked-in adversarial_* family: three signature-distinct mined
    # patterns covering the search objectives — best combined score, deepest
    # raw resihp session loss, widest policy-ranking flip (each backfilled
    # from the score ranking if it collides with an earlier pick)
    family = []
    fam_sigs = set()

    def pick(key):
        for e in sorted(ranked, key=key):
            if tuple(e["signature"]) not in fam_sigs:
                fam_sigs.add(tuple(e["signature"]))
                family.append(e)
                return

    pick(lambda e: (-e["score"], tuple(e["signature"])))
    pick(lambda e: (-e["resihp_loss"], tuple(e["signature"])))
    pick(lambda e: (-e["flip_margin"], tuple(e["signature"])))
    while len(family) < 3 and len(family) < len(ranked):
        pick(lambda e: (-e["score"], tuple(e["signature"])))

    return {
        "config": {
            "seed": seed, "budget": budget, "iters": iters, "span": span,
            "engine": engine, "policies": policies, "batch": batch,
            "elites": elites, "max_events": max_events,
            "damage_cap": round(cap, 6), "n_devices": cfg.n_devices,
            "model": cfg_kw,
        },
        "healthy": {k: round(v, 9) for k, v in healthy["session"].items()},
        "catalog": catalog,
        "worst_catalog": {"name": worst_name, **catalog[worst_name]},
        "n_archive": len(archive),
        "n_clusters": len(ranked),
        "clusters": [dict(rank=i + 1, **e)
                     for i, e in enumerate(ranked[:top_k])],
        "family": [dict(rank=i + 1, objective=obj, **e)
                   for i, (obj, e) in enumerate(
                       zip(("score", "resihp_loss", "flip_margin"), family))],
    }


def to_json(report: dict) -> str:
    """Canonical serialization: byte-identical for identical reports."""
    return json.dumps(report, indent=1, sort_keys=True)
