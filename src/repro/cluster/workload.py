"""Per-iteration workload generation for the cluster simulator.

Samples document lengths from the same long-tailed distribution as the data
pipeline, packs them, and exposes per-micro-batch (N, sum l_i^2) — the
features of the paper's Eq. 1 predictor. Ground-truth chunk times follow the
same functional form (alpha*N + beta*sum_l2 + gamma) with optional jitter,
which is exactly what a calibrated predictor assumes; model mismatch is
covered by the MAPE benchmarks against the *real* engine.
"""
from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

from repro.data.packing import pack_documents, quadratic_cost
from repro.data.synth import sample_doc_lengths


@dataclass
class MicroBatchWork:
    n_tokens: int
    sum_l2: int


@dataclass
class IterationWorkload:
    """Per replica: list of MicroBatchWork (one per micro-batch)."""

    per_replica: list  # [replica][mb] -> MicroBatchWork
    seq_len: int

    def stats(self, replica: int, mb: int) -> MicroBatchWork:
        reps = self.per_replica
        return reps[replica][mb % len(reps[replica])]

    def totals(self):
        n = sum(w.n_tokens for r in self.per_replica for w in r)
        l2 = sum(w.sum_l2 for r in self.per_replica for w in r)
        return n, l2


@dataclass
class WorkloadGen:
    seq_len: int
    n_replicas: int
    n_microbatches: int
    rows_per_microbatch: int = 1
    seed: int = 0
    mu: float = 6.2
    sigma: float = 1.1
    _it: int = field(default=0)

    def for_iteration(self, iteration: int) -> IterationWorkload:
        rng = np.random.default_rng((self.seed, iteration))
        total_rows = self.n_replicas * self.n_microbatches * self.rows_per_microbatch
        mean_len = np.exp(self.mu + self.sigma**2 / 2)
        n_docs = max(8, int(total_rows * self.seq_len / mean_len))
        rows = pack_documents(
            sample_doc_lengths(rng, n_docs, self.seq_len, mu=self.mu, sigma=self.sigma),
            self.seq_len,
        )
        while len(rows) < total_rows:
            extra = sample_doc_lengths(rng, 16, self.seq_len, mu=self.mu, sigma=self.sigma)
            rows.extend(pack_documents(extra, self.seq_len))
        rows = rows[:total_rows]
        per_replica = []
        idx = 0
        for _ in range(self.n_replicas):
            mbs = []
            for _ in range(self.n_microbatches):
                group = rows[idx: idx + self.rows_per_microbatch]
                idx += self.rows_per_microbatch
                n = sum(sum(r) for r in group)
                l2 = sum(quadratic_cost(r) for r in group)
                mbs.append(MicroBatchWork(n, l2))
            per_replica.append(mbs)
        return IterationWorkload(per_replica, self.seq_len)

    def __next__(self) -> IterationWorkload:
        w = self.for_iteration(self._it)
        self._it += 1
        return w
