"""Failure-event timeline: the compiled form of a FailureScenario.

A scenario compiles to a flat, time-sorted list of :class:`Event` records.
Events are plain data (kind + target + value) so a timeline can be exported,
diffed, replayed and asserted on byte-for-byte; the one exception is the
``callback`` kind which carries an opaque function and exists only to back
the legacy ``TrainingSim.inject_at`` shim.

Event kinds
-----------
``fail-stop``       device ``target`` terminates (speed 0, heartbeats stop)
``fail-stop-node``  every device on node ``target`` terminates
``fail-slow``       device ``target`` degrades to ``value`` x peak speed
``net-degrade``     node ``target`` link contention, bandwidth scale ``value``
``net-restore``     node ``target`` link contention cleared (network
                    component only — dead/slow devices stay dead/slow)
``rejoin``          device ``target`` repaired AND re-announced to the system
                    (the elastic-rejoin model: the scheduler learns the device
                    is back, unlike a silent repair). ``value`` in (0, 1)
                    means the device returns *degraded* to that fraction of
                    peak speed; 0.0 (the default) means full health
``callback``        opaque ``fn(cluster, now)`` — inject_at compatibility
"""
from __future__ import annotations

import json
import math
from dataclasses import dataclass, field
from typing import Callable, Iterable, Optional

KINDS = (
    "fail-stop",
    "fail-stop-node",
    "fail-slow",
    "net-degrade",
    "net-restore",
    "rejoin",
    "callback",
)

DEVICE_KINDS = ("fail-stop", "fail-slow", "rejoin")
NODE_KINDS = ("fail-stop-node", "net-degrade", "net-restore")


class TraceValidationError(ValueError):
    """An event timeline is contradictory or out of range for its topology
    (see :meth:`EventTrace.validate`). Raised instead of letting the
    simulator silently mis-simulate an impossible sequence."""


@dataclass(frozen=True, order=True)
class Event:
    t: float
    kind: str
    target: int = -1
    value: float = 0.0
    scenario: str = ""  # provenance: which scenario emitted this event
    fn: Optional[Callable] = field(default=None, compare=False)

    def __post_init__(self):
        if self.kind not in KINDS:
            raise ValueError(f"unknown event kind {self.kind!r}; one of {KINDS}")

    def as_tuple(self) -> tuple:
        return (float(self.t), self.kind, int(self.target),
                float(self.value), self.scenario)


def encode_rejoin_speed(speed: float) -> float:
    """``Event.value`` encoding for a rejoin's return speed: 0.0 (the Event
    default, emitted by every pre-existing scenario) means full health; a
    value in (0, 1) is a degraded return. A rejoin always brings the device
    back alive — "returns dead" is not a rejoin."""
    return speed if 0.0 < speed < 1.0 else 0.0


def decode_rejoin_speed(value: float) -> float:
    return value if 0.0 < value < 1.0 else 1.0


def apply_event(ev: Event, cluster, now: float, *, on_rejoin=None) -> None:
    """Apply one event to a ClusterState; ``on_rejoin(device)`` lets the
    caller propagate elastic rejoins into system beliefs."""
    if ev.kind == "fail-stop":
        cluster.fail_stop(ev.target, now)
    elif ev.kind == "fail-stop-node":
        cluster.fail_stop_node(ev.target, now)
    elif ev.kind == "fail-slow":
        cluster.fail_slow(ev.target, ev.value, now)
    elif ev.kind == "net-degrade":
        cluster.degrade_network(ev.target, ev.value, now=now)
    elif ev.kind == "net-restore":
        cluster.restore_network(ev.target, now=now)
    elif ev.kind == "rejoin":
        cluster.repair(ev.target, now, speed=decode_rejoin_speed(ev.value))
        if on_rejoin is not None:
            on_rejoin(ev.target)
    elif ev.kind == "callback":
        ev.fn(cluster, now)


class EventTrace:
    """A time-sorted event timeline with export/merge/replay helpers."""

    def __init__(self, events: Iterable[Event] = ()):
        self.events: list[Event] = sorted(events)

    def __iter__(self):
        return iter(self.events)

    def __len__(self):
        return len(self.events)

    def __getitem__(self, i):
        return self.events[i]

    def __eq__(self, other):
        if not isinstance(other, EventTrace):
            return NotImplemented
        return self.events == other.events

    def merge(self, other: "EventTrace") -> "EventTrace":
        return EventTrace([*self.events, *other.events])

    def validate(self, topo) -> "EventTrace":
        """Reject timelines the simulator would silently mis-simulate.

        Checks, per event in time order (``callback`` events are opaque and
        skipped):

        * finite, non-negative times and finite values;
        * device targets in ``[0, n_devices)`` for device-kind events and
          node targets in ``[0, n_nodes)`` for node-kind events;
        * value ranges: fail-slow severity in ``(0, 1]``, rejoin return
          speed encoding in ``[0, 1)`` (see :func:`encode_rejoin_speed`),
          net-degrade link scale in ``(0, 1]``;
        * a consistent per-device lifecycle: no fail-stop/fail-slow of an
          already-dead device (a double kill means two generators disagree
          about who owns the victim), no ``rejoin`` of a device that never
          failed, no ``fail-stop-node`` of a node whose devices are all
          already dead, no ``net-restore`` without an active degrade.

        Returns ``self`` so calls chain; raises
        :class:`TraceValidationError` naming the offending event otherwise.
        Every catalog scenario compiles clean under this check
        (``tests/test_scenarios.py`` pins it); the adversarial miner's
        mutation operators route every candidate through
        :func:`repro.cluster.mining.repair_timeline`, which canonicalizes
        arbitrary event soups into timelines that pass."""
        n_dev, n_nodes = topo.n_devices, topo.n_nodes

        def err(i, ev, msg):
            raise TraceValidationError(
                f"event {i} (t={ev.t}, kind={ev.kind!r}, target={ev.target}, "
                f"value={ev.value}): {msg}")

        alive: dict = {}       # device -> liveness (default True)
        degraded: set = set()  # devices currently running below peak
        net_down: set = set()  # nodes with an active link degrade
        for i, ev in enumerate(self.events):
            if ev.kind == "callback":
                continue
            if not math.isfinite(ev.t) or ev.t < 0.0:
                err(i, ev, "event time must be finite and >= 0")
            if not math.isfinite(ev.value):
                err(i, ev, "event value must be finite")
            if ev.kind in DEVICE_KINDS and not 0 <= ev.target < n_dev:
                err(i, ev, f"device id out of range for a {n_dev}-device "
                           "topology")
            if ev.kind in NODE_KINDS and not 0 <= ev.target < n_nodes:
                err(i, ev, f"node id out of range for a {n_nodes}-node "
                           "topology")
            if ev.kind == "fail-stop":
                if not alive.get(ev.target, True):
                    err(i, ev, "device is already dead (double fail-stop "
                               "without an intervening rejoin)")
                alive[ev.target] = False
            elif ev.kind == "fail-stop-node":
                devs = range(ev.target * topo.devices_per_node,
                             (ev.target + 1) * topo.devices_per_node)
                if all(not alive.get(d, True) for d in devs):
                    err(i, ev, "every device on the node is already dead")
                for d in devs:
                    alive[d] = False
            elif ev.kind == "fail-slow":
                if not 0.0 < ev.value <= 1.0:
                    err(i, ev, "fail-slow severity must be in (0, 1] "
                               "(remaining fraction of peak speed)")
                if not alive.get(ev.target, True):
                    err(i, ev, "fail-slow on a dead device (it has no speed "
                               "to degrade; rejoin it first)")
                degraded.add(ev.target)
            elif ev.kind == "rejoin":
                if not 0.0 <= ev.value < 1.0:
                    err(i, ev, "rejoin value must be the encode_rejoin_speed "
                               "encoding: 0.0 = full health, (0, 1) = "
                               "degraded return")
                if alive.get(ev.target, True) and ev.target not in degraded:
                    err(i, ev, "rejoin before any failure of the device "
                               "(nothing to repair or recover from)")
                alive[ev.target] = True
                degraded.discard(ev.target)
                if 0.0 < ev.value < 1.0:
                    degraded.add(ev.target)  # returned below peak
            elif ev.kind == "net-degrade":
                if not 0.0 < ev.value <= 1.0:
                    err(i, ev, "net-degrade link scale must be in (0, 1] "
                               "(remaining fraction of bandwidth)")
                net_down.add(ev.target)
            elif ev.kind == "net-restore":
                if ev.target not in net_down:
                    err(i, ev, "net-restore without an active net-degrade "
                               "on the node")
                net_down.discard(ev.target)  # restore clears all contention
        return self

    def as_tuples(self) -> list:
        return [ev.as_tuple() for ev in self.events]

    def to_json(self) -> str:
        """Canonical serialization — byte-identical for identical timelines
        (callback events are not serializable by design)."""
        if any(ev.kind == "callback" for ev in self.events):
            raise ValueError("callback events cannot be serialized")
        return json.dumps(self.as_tuples(), separators=(",", ":"))

    @classmethod
    def from_json(cls, text: str) -> "EventTrace":
        return cls(Event(t, kind, target, value, scenario)
                   for t, kind, target, value, scenario in json.loads(text))
