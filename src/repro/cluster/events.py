"""Failure-event timeline: the compiled form of a FailureScenario.

A scenario compiles to a flat, time-sorted list of :class:`Event` records.
Events are plain data (kind + target + value) so a timeline can be exported,
diffed, replayed and asserted on byte-for-byte; the one exception is the
``callback`` kind which carries an opaque function and exists only to back
the legacy ``TrainingSim.inject_at`` shim.

Event kinds
-----------
``fail-stop``       device ``target`` terminates (speed 0, heartbeats stop)
``fail-stop-node``  every device on node ``target`` terminates
``fail-slow``       device ``target`` degrades to ``value`` x peak speed
``net-degrade``     node ``target`` link contention, bandwidth scale ``value``
``net-restore``     node ``target`` link contention cleared (network
                    component only — dead/slow devices stay dead/slow)
``rejoin``          device ``target`` repaired AND re-announced to the system
                    (the elastic-rejoin model: the scheduler learns the device
                    is back, unlike a silent repair). ``value`` in (0, 1)
                    means the device returns *degraded* to that fraction of
                    peak speed; 0.0 (the default) means full health
``callback``        opaque ``fn(cluster, now)`` — inject_at compatibility
"""
from __future__ import annotations

import json
from dataclasses import dataclass, field
from typing import Callable, Iterable, Optional

KINDS = (
    "fail-stop",
    "fail-stop-node",
    "fail-slow",
    "net-degrade",
    "net-restore",
    "rejoin",
    "callback",
)


@dataclass(frozen=True, order=True)
class Event:
    t: float
    kind: str
    target: int = -1
    value: float = 0.0
    scenario: str = ""  # provenance: which scenario emitted this event
    fn: Optional[Callable] = field(default=None, compare=False)

    def __post_init__(self):
        if self.kind not in KINDS:
            raise ValueError(f"unknown event kind {self.kind!r}; one of {KINDS}")

    def as_tuple(self) -> tuple:
        return (float(self.t), self.kind, int(self.target),
                float(self.value), self.scenario)


def encode_rejoin_speed(speed: float) -> float:
    """``Event.value`` encoding for a rejoin's return speed: 0.0 (the Event
    default, emitted by every pre-existing scenario) means full health; a
    value in (0, 1) is a degraded return. A rejoin always brings the device
    back alive — "returns dead" is not a rejoin."""
    return speed if 0.0 < speed < 1.0 else 0.0


def decode_rejoin_speed(value: float) -> float:
    return value if 0.0 < value < 1.0 else 1.0


def apply_event(ev: Event, cluster, now: float, *, on_rejoin=None) -> None:
    """Apply one event to a ClusterState; ``on_rejoin(device)`` lets the
    caller propagate elastic rejoins into system beliefs."""
    if ev.kind == "fail-stop":
        cluster.fail_stop(ev.target, now)
    elif ev.kind == "fail-stop-node":
        cluster.fail_stop_node(ev.target, now)
    elif ev.kind == "fail-slow":
        cluster.fail_slow(ev.target, ev.value, now)
    elif ev.kind == "net-degrade":
        cluster.degrade_network(ev.target, ev.value, now=now)
    elif ev.kind == "net-restore":
        cluster.restore_network(ev.target, now=now)
    elif ev.kind == "rejoin":
        cluster.repair(ev.target, now, speed=decode_rejoin_speed(ev.value))
        if on_rejoin is not None:
            on_rejoin(ev.target)
    elif ev.kind == "callback":
        ev.fn(cluster, now)


class EventTrace:
    """A time-sorted event timeline with export/merge/replay helpers."""

    def __init__(self, events: Iterable[Event] = ()):
        self.events: list[Event] = sorted(events)

    def __iter__(self):
        return iter(self.events)

    def __len__(self):
        return len(self.events)

    def __getitem__(self, i):
        return self.events[i]

    def __eq__(self, other):
        if not isinstance(other, EventTrace):
            return NotImplemented
        return self.events == other.events

    def merge(self, other: "EventTrace") -> "EventTrace":
        return EventTrace([*self.events, *other.events])

    def as_tuples(self) -> list:
        return [ev.as_tuple() for ev in self.events]

    def to_json(self) -> str:
        """Canonical serialization — byte-identical for identical timelines
        (callback events are not serializable by design)."""
        if any(ev.kind == "callback" for ev in self.events):
            raise ValueError("callback events cannot be serialized")
        return json.dumps(self.as_tuples(), separators=(",", ":"))

    @classmethod
    def from_json(cls, text: str) -> "EventTrace":
        return cls(Event(t, kind, target, value, scenario)
                   for t, kind, target, value, scenario in json.loads(text))
