"""Fast execution core for the cluster simulator (the ``engine="fast"`` path).

Semantically exact, asymptotically faster re-implementation of
:class:`repro.core.scheduler.migration.ProgressAwareMigrator` plus a
vectorized chunk-cost table. The reference engine is kept untouched as the
semantic anchor; this module exists purely so that Fig. 14-style sweeps scale
to the 32k/100k-device regime (ROADMAP "Scale" item). Structural wins, none
of which changes observable behaviour:

1. **Targeted dispatch.** The reference engine re-dispatches *every* executor
   after *every* completion batch — O(chunks x executors) work dominated by
   redundant readiness probes (at 256 devices: ~78k dispatch calls per
   iteration for ~1.5k events). Completions can only unblock (a) the executor
   that finished, (b) executors owning a dependent of the finished chunk,
   (c) migration sources/destinations and (d) executors with an explicit
   wake-up — so only those are dispatched. Same starts, same times.
2. **Batched event processing.** Both engines drain every heap entry within
   ``SAME_TIME_EPS`` of the batch head before the policy decides; the
   reference then probes each touched executor through scalar python
   dispatch. Here the dispatch round is *adaptive*: when the touched set is
   large (>= ``VEC_BATCH_MIN`` — symmetric replicas completing in lockstep,
   the t=0 kick-off over every executor, timestamp-collision regimes), the
   whole round flows through array stages over preallocated dense mirrors —
   **build** (the touched set from completers + reverse-dependency owners),
   **ready** (each chunk has at most two dependencies, kept as padded index
   arrays into a dense finish vector where +inf = unfinished, so one masked
   maximum over ``finish[dep] + edge_cost`` computes every candidate's ready
   time at once), **select** (candidate heads advance via a vectorized
   cursor walk; one comparison against ``now`` splits starts from wake-ups;
   durations come from the cost table's batched gather — bit-identical
   divisions) and **commit** (start flags/running slots as scatter-updates).
   Small rounds — the common case under per-device noise, where timestamps
   are almost all distinct — keep the tuned scalar path: python-list state
   with an inlined ready probe. Both paths share *eagerly maintained*
   per-dependency edge costs (placements change only at migration time, so
   the edge-cost terms are refreshed per migration instead of being
   recomputed per readiness probe). Executors holding migrated-in work
   always take the scalar path — the migq scan's W-deferral tie-breaks are
   cheapest to keep exact in python, and migrations are failure-localized.
3. **Incremental Algorithm-1 state.** The reference recomputes the progress
   matrix P from the full ``done`` set on every decide (O(chunks) each, so
   O(chunks^2) per iteration) and scans all stages. Here P is maintained
   incrementally; per-stage min/max are updated in O(1) amortized per F
   completion (counts only ever increment by one, so the stage minimum moves
   by at most one when its last holder leaves), and the decide body runs only
   over stages that can possibly act: the "hot" set (progress gap > delta)
   plus stages with fail-stop executors. Stages outside that set provably
   hit a ``continue`` in the reference loop.
4. **Static-structure cache.** Schedules, the chunk index, dependency and
   reverse-dependency lists (plus their padded-array/CSR forms for the
   batched path) depend only on (schedule, stages, micro-batches, replicas)
   — they are built once and shared across iterations instead of being
   rebuilt from ChunkId dataclasses every ``step()``.

Differences from the reference that are *not* observable through
``TrainingSim``: ``SimResult.idle`` is returned empty (the reference
recomputes every chunk cost at the end of a run just to report idle time;
nothing in the simulator reads it), and the set-iteration order inside the
``detail`` string of an aborted result may differ.

Bit-for-bit parity is enforced by ``tests/test_simulator_golden.py`` (the
fast engine is the default), ``tests/test_engine_parity.py`` (python vs
fast across scenario families and policies, including a ``vec_batch_min=1``
sweep that forces every dispatch round through the array path) and
``tests/test_fastsim_unit.py`` (dispatch fast paths + the
timestamp-collision batching boundary).
"""
from __future__ import annotations

import heapq
import math

import numpy as np

from repro.core.detector.dag_sim import ChunkId
from repro.core.scheduler.migration import (SAME_TIME_EPS, MigrationEvent,
                                            SimResult, _budget_error)
from repro.core.scheduler.plan import NTP_EFFICIENCY
from repro.engine.schedules import make_schedule

_KIND_F, _KIND_B, _KIND_W = 0, 1, 2
_KIND_INDEX = {"F": _KIND_F, "B": _KIND_B, "W": _KIND_W}

#: Touched-executor-set size at which a dispatch round switches from the
#: scalar python path to the vectorized build/ready/select/commit stages.
#: Array dispatch costs ~a dozen numpy calls per round regardless of size,
#: so it only pays past a handful of executors; under per-device noise most
#: completion batches touch 2-4 executors and stay scalar, while the t=0
#: kick-off (every executor) and synchronized/collision regimes (whole
#: replica rows completing in lockstep) go wide. Tests force the array path
#: everywhere with ``vec_batch_min=1``.
VEC_BATCH_MIN = 12


# ===================================================== static schedule graph
class _Struct:
    """Immutable per-(schedule, stages, n_mb, replicas) execution graph,
    shared across iterations: integer-indexed chunks, per-executor orders
    (list + padded matrix forms), dependency/reverse-dependency lists (plus
    two-slot padded index arrays for the batched path) and F -> B/W
    companion links."""

    __slots__ = (
        "n_stages", "n_replicas", "n_chunks", "executors", "e_replica",
        "e_replica_arr", "orders", "order_mat", "order_len", "cids",
        "kind", "mb", "stage", "replica", "home",
        "kind_arr", "mb_arr", "stage_arr", "replica_arr", "home_arr",
        "deps", "rdeps", "comp_b", "comp_w",
        "dep_a", "dep_b", "dep_a_cross", "dep_b_cross",
        "dcost_by_p2p",
    )

    def __init__(self, schedule: str, n_stages: int, n_mb, n_replicas: int):
        self.n_stages = n_stages
        self.n_replicas = n_replicas
        self.executors = [(d, s) for d in range(n_replicas)
                          for s in range(n_stages)]
        self.e_replica = [d for d, _ in self.executors]
        self.e_replica_arr = np.array(self.e_replica, dtype=np.intp)
        eidx = {e: i for i, e in enumerate(self.executors)}

        cids: list = []
        index: dict = {}
        self.orders = [[] for _ in self.executors]
        for d in range(n_replicas):
            sched = make_schedule(schedule, n_stages, n_mb[d], replica=d)
            for (rep, st), order in sched.items():
                lst = self.orders[eidx[(rep, st)]]
                for cid in order:
                    i = index.get(cid)
                    if i is None:
                        i = index[cid] = len(cids)
                        cids.append(cid)
                    lst.append(i)
        n = self.n_chunks = len(cids)
        self.cids = cids
        self.kind = [_KIND_INDEX[c.kind] for c in cids]
        self.mb = [c.mb for c in cids]
        self.stage = [c.stage for c in cids]
        self.replica = [c.replica for c in cids]
        self.home = [eidx[(c.replica, c.stage)] for c in cids]
        # dense coordinate arrays for the batched cost gathers (built once
        # per cached structure, reused by every migrator instance)
        self.kind_arr = np.array(self.kind, dtype=np.intp)
        self.mb_arr = np.array(self.mb, dtype=np.intp)
        self.stage_arr = np.array(self.stage, dtype=np.intp)
        self.replica_arr = np.array(self.replica, dtype=np.intp)
        self.home_arr = np.array(self.home, dtype=np.intp)

        # per-executor order, padded matrix form for the vectorized cursor
        # walk; pad value = the sentinel chunk n (never done, never migrated)
        max_len = max((len(o) for o in self.orders), default=0)
        self.order_mat = np.full((len(self.executors), max(max_len, 1)), n,
                                 dtype=np.intp)
        self.order_len = np.array([len(o) for o in self.orders],
                                  dtype=np.intp)
        for e, o in enumerate(self.orders):
            if o:
                self.order_mat[e, :len(o)] = o

        # deps mirror ProgressAwareMigrator._deps (filtered to known chunks);
        # the static edge flag records whether the dep crosses stages (p2p)
        self.deps = [[] for _ in cids]
        self.rdeps = [[] for _ in cids]
        self.comp_b = [-1] * n
        self.comp_w = [-1] * n
        for i, c in enumerate(cids):
            if c.kind == "F":
                if c.stage > 0:
                    d = index.get(ChunkId("F", c.mb, c.stage - 1, c.replica))
                    if d is not None:
                        self.deps[i].append((d, True))
                b = index.get(ChunkId("B", c.mb, c.stage, c.replica))
                if b is not None:
                    self.comp_b[i] = b
                w = index.get(ChunkId("W", c.mb, c.stage, c.replica))
                if w is not None:
                    self.comp_w[i] = w
            elif c.kind == "B":
                d = index.get(ChunkId("F", c.mb, c.stage, c.replica))
                if d is not None:
                    self.deps[i].append((d, False))
                if c.stage < n_stages - 1:
                    d = index.get(ChunkId("B", c.mb, c.stage + 1, c.replica))
                    if d is not None:
                        self.deps[i].append((d, True))
            else:  # W
                d = index.get(ChunkId("B", c.mb, c.stage, c.replica))
                if d is not None:
                    self.deps[i].append((d, False))
        for i in range(n):
            for d, _ in self.deps[i]:
                self.rdeps[d].append(i)

        # two-slot padded dep index arrays for the vectorized ready stage
        # (every chunk has at most two deps; empty slots point at the
        # sentinel, whose finish is pinned to 0.0)
        self.dep_a = np.full(n + 1, n, dtype=np.intp)
        self.dep_b = np.full(n + 1, n, dtype=np.intp)
        self.dep_a_cross = np.zeros(n + 1, dtype=bool)
        self.dep_b_cross = np.zeros(n + 1, dtype=bool)
        for i, deps in enumerate(self.deps):
            if deps:
                self.dep_a[i], self.dep_a_cross[i] = deps[0]
                if len(deps) > 1:
                    self.dep_b[i], self.dep_b_cross[i] = deps[1]

        # home-placement (dep, edge cost) lists keyed by p2p charge — the
        # build is O(n) python work identical for every migrator sharing
        # this structure and charge, so instances take a shallow copy
        # (_refresh_edges rebinds outer slots, never mutates the inner
        # lists, making the shared inner lists safe)
        self.dcost_by_p2p: dict = {}


_STRUCT_CACHE: dict = {}
_STRUCT_CACHE_MAX = 64


def _struct_for(schedule: str, n_stages: int, n_mb, n_replicas: int) -> _Struct:
    key = (schedule, n_stages, tuple(n_mb), n_replicas)
    s = _STRUCT_CACHE.get(key)
    if s is None:
        if len(_STRUCT_CACHE) >= _STRUCT_CACHE_MAX:
            _STRUCT_CACHE.clear()
        s = _STRUCT_CACHE[key] = _Struct(schedule, n_stages, n_mb, n_replicas)
    return s


# ============================================================ fast migrator
class FastMigrator:
    """Drop-in replacement for ProgressAwareMigrator (same constructor plus
    ``event_budget``/``vec_batch_min`` knobs, same ``run() -> SimResult``),
    returning identical makespans, migrations, statuses and finish times —
    see the module docstring for what is faster and the two non-observable
    differences."""

    def __init__(
        self,
        *,
        n_stages: int,
        n_replicas: int,
        n_microbatches,
        chunk_cost,
        schedule: str = "1f1b",
        dead_executors=(),
        policy: str = "resihp",
        delta: int = 0,
        mem_capacity=None,
        p2p_cost: float = 0.0,
        migrate_edge_cost: float = 0.0,
        max_migrations_per_event: int = 4,
        event_budget=None,
        vec_batch_min=None,
    ):
        self.n_stages = n_stages
        self.n_replicas = n_replicas
        if isinstance(n_microbatches, int):
            n_microbatches = [n_microbatches] * n_replicas
        self.n_mb = list(n_microbatches)
        self.chunk_cost = chunk_cost
        self.policy = policy
        self.delta = delta
        self.mem_capacity = mem_capacity if mem_capacity is not None else n_stages + 2
        self.p2p_cost = p2p_cost
        self.migrate_edge_cost = migrate_edge_cost
        self.dead = set(dead_executors)
        self.max_migrations_per_event = max_migrations_per_event
        self.event_budget = event_budget
        self._vec_min = VEC_BATCH_MIN if vec_batch_min is None else vec_batch_min

        st = self.st = _struct_for(schedule, n_stages, self.n_mb, n_replicas)
        n = st.n_chunks
        self._dead_e = {d * n_stages + s for (d, s) in self.dead
                        if 0 <= s < n_stages and 0 <= d < n_replicas}
        self._dead_stages = sorted({s for (_, s) in self.dead
                                    if 0 <= s < n_stages})

        # dynamic state — python lists are the primary representation for
        # the scalar path (fastest per-element access); the batched path
        # reads/writes dense numpy mirrors that every scalar mutation keeps
        # in sync (a handful of O(1) stores per event)
        self.placement = [-1] * n  # executor idx, -1 = home
        self.exec_of = list(st.home)
        self.finish = [None] * n
        self.started = [False] * n
        self.done = [False] * n
        self.migrated_away = [False] * n
        self.n_done_chunks = 0
        E = len(st.executors)
        self.live = [0] * E
        self.inflight = [0] * E
        self.migq = [[] for _ in range(E)]
        self.cursor = [0] * E
        self.pend_cursor = [0] * E
        self.running = [None] * E
        self.migrations: list = []
        self._rr = 0
        # numpy mirrors (sentinel slot n where the vectorized gathers index
        # through dep/order pads: never done, never migrated, finished at
        # 0.0 so a missing dep contributes exactly the reference's initial
        # t = 0.0 to the ready maximum)
        self.finish_arr = np.full(n + 1, np.inf)
        self.finish_arr[n] = 0.0
        self.done_np = np.zeros(n + 1, dtype=bool)
        self.migrated_np = np.zeros(n + 1, dtype=bool)
        self.cursor_arr = np.zeros(E, dtype=np.intp)
        self.running_arr = np.full(E, -1, dtype=np.intp)
        self._migq_pending = np.zeros(E, dtype=np.intp)
        # mirror-write journals: the scalar path appends plain python ints
        # here instead of paying a numpy scalar store per event (which costs
        # several times a list append); ``_flush_mirrors`` replays them as
        # bulk fancy-index assignments right before a vectorized round reads
        # the arrays
        self._dirty_done: list = []    # chunk ids newly done ...
        self._dirty_fin: list = []     # ... and their finish times
        self._dirty_mig: list = []     # chunk ids newly migrated away
        self._dirty_cur: list = []     # executors whose cursor moved
        self._dirty_run: list = []     # executors whose running slot changed
        # ready-time memo: once every dependency of a chunk has finished its
        # ready time is immutable (finish times never change, and a finished
        # dep can never migrate, so the edge costs are frozen too) — the one
        # exception is the chunk itself migrating before it starts, which
        # refreshes its edge costs, so _refresh_edges invalidates its slot.
        # This turns the per-dispatch migq rescan from O(pending ready
        # loops) into O(pending memo reads).
        self._ready_memo: list = [None] * n
        # earliest pending wake per executor: a dispatch that cannot start
        # anything skips pushing its wake when an earlier-or-equal one is
        # already in the heap — that wake re-evaluates the executor anyway,
        # and re-arms coverage if it still cannot start (every state change
        # that could move readiness earlier re-dispatches the executor via
        # the touched set, so coverage is never lost)
        self._wake_at: list = [None] * E
        self._alive_e_mask = np.ones(E, dtype=bool)
        for e in self._dead_e:
            self._alive_e_mask[e] = False
        self._all_executors = np.arange(E, dtype=np.intp)
        # eager per-dependency edge costs: placements change only inside
        # _migrate, so the cost term of every dep edge is maintained there
        # (_refresh_edges) instead of being recomputed per readiness probe.
        # At home placement all dep edges are intra-replica, so the cost is
        # the p2p charge iff the edge crosses stages. ``dcost`` is the
        # scalar path's list-of-(dep, cost) form; dep_cost_a/b mirror it per
        # slot for the batched ready gather.
        self.dep_cost_a = np.where(st.dep_a_cross, p2p_cost, 0.0)
        self.dep_cost_b = np.where(st.dep_b_cross, p2p_cost, 0.0)
        dcost0 = st.dcost_by_p2p.get(p2p_cost)
        if dcost0 is None:
            dcost0 = st.dcost_by_p2p[p2p_cost] = [
                [(d, p2p_cost if crosses else 0.0)
                 for d, crosses in st.deps[i]]
                for i in range(n)
            ]
        self.dcost = list(dcost0)
        # home-placement durations, one batched gather per instance: almost
        # every start runs at the chunk's home executor, so the scalar
        # dispatch reads a plain list instead of calling the cost closure
        # per start (the batch gather performs the identical float64
        # divisions, so the values are bit-for-bit the closure's). At home,
        # e_replica(home) == replica and home % S == stage.
        batch = getattr(chunk_cost, "batch", None)
        self._dur_home = None
        if batch is not None:
            self._dur_home = batch(st.kind_arr, st.mb_arr, st.stage_arr,
                                   st.replica_arr, st.replica_arr,
                                   st.stage_arr).tolist()
        # Algorithm-1 progress state: P stored column-major as plain int
        # lists (one list per stage) — at realistic DP widths (tens to a few
        # hundred replicas) C-level list.index()/count() beat numpy column
        # reductions, whose per-call overhead dominates on short columns —
        # plus per-stage min/max maintained incrementally and the hot set
        self._Pcols = [[0] * n_replicas for _ in range(n_stages)]
        self._minval = [0] * n_stages
        self._n_at_min = [n_replicas] * n_stages
        self._hot: set = set()
        self._hot_dirty = True  # invalidates the sorted candidate cache
        self._cand_cache: list = []
        self._max_finish = None
        self._pr_finish = [0.0] * n_replicas
        # static per-stage liveness (self.dead never changes during a run):
        # alive replica list (reference iteration order) and dead-row lists
        # for the masked max
        self._alive_rows = [
            [d for d in range(n_replicas) if (d, s) not in self.dead]
            for s in range(n_stages)
        ]
        self._dead_rows = [
            [d for d in range(n_replicas) if (d, s) in self.dead] or None
            for s in range(n_stages)
        ]
        # incrementally maintained first-occurrence argmax over the *alive*
        # rows of each P column (== the reference's masked-argmax tie-break:
        # dead rows masked below any real count, first index wins). Values
        # only ever increment by one, so every attainment of a new maximum —
        # or of a tie at the current maximum by a lower index — is observed
        # right where it happens, making the maintenance O(1) per update.
        # For stages with no dead rows this equals the plain column argmax.
        self._amax = [rows[0] if rows else 0 for rows in self._alive_rows]
        self._amaxval = [0] * n_stages

    # ------------------------------------------------------------- helpers
    def _executor_of(self, i: int) -> int:
        return self.exec_of[i]

    def _edge_cost(self, d: int, c: int) -> float:
        """Reference ``_edge_cost`` over integer indices: 0 between
        co-located chunks, else the p2p charge iff the dependency crosses
        stages plus the migrate-edge charge iff it crosses replicas."""
        ed, ec = self.exec_of[d], self.exec_of[c]
        if ed == ec:
            return 0.0
        st = self.st
        cost = self.p2p_cost if st.stage[d] != st.stage[c] else 0.0
        if st.e_replica[ed] != st.e_replica[ec]:
            cost += self.migrate_edge_cost
        return cost

    def _refresh_edges(self, group):
        """Recompute the maintained edge costs around chunks whose placement
        just changed: their own dep slots plus every dependent's slot that
        points at them. Called only from ``_migrate`` — edge costs are
        placement functions, and placements change nowhere else.
        ``_edge_cost`` is inlined into the slot loop (migration storms hit
        this path tens of thousands of times per session)."""
        st = self.st
        exec_of, stage, e_replica = self.exec_of, st.stage, st.e_replica
        deps, dcost = st.deps, self.dcost
        dep_cost_a, dep_cost_b = self.dep_cost_a, self.dep_cost_b
        p2p, mig_edge = self.p2p_cost, self.migrate_edge_cost
        seen = set(group)
        for g in group:
            seen.update(st.rdeps[g])
        memo = self._ready_memo
        for i in seen:
            memo[i] = None
            ei = exec_of[i]
            ri, si = e_replica[ei], stage[i]
            dl = []
            for slot, (d, _) in enumerate(deps[i]):
                ed = exec_of[d]
                if ed == ei:
                    c = 0.0
                else:
                    c = p2p if stage[d] != si else 0.0
                    if e_replica[ed] != ri:
                        c += mig_edge
                dl.append((d, c))
                if slot == 0:
                    dep_cost_a[i] = c
                else:
                    dep_cost_b[i] = c
            dcost[i] = dl

    def _ready_time(self, i: int):
        """Max over dependencies of finish + (eagerly maintained) edge cost;
        None while any dependency is unfinished. The batched ready stage
        computes the identical expression for whole candidate arrays."""
        t = 0.0
        finish = self.finish
        for d, c in self.dcost[i]:
            f = finish[d]
            if f is None:
                return None
            f = f + c
            if f > t:
                t = f
        return t

    def _inc_progress(self, d: int, i: int):
        """P[d, i] += 1 with O(1) amortized min/max/hot maintenance (values
        only ever increment, so the minimum can only move up by one when its
        last holder leaves)."""
        col = self._Pcols[i]
        old = col[d]
        new = old + 1
        col[d] = new
        dr = self._dead_rows[i]
        if dr is None or d not in dr:
            if new > self._amaxval[i]:
                self._amaxval[i] = new
                self._amax[i] = d
            elif new == self._amaxval[i] and d < self._amax[i]:
                self._amax[i] = d
        if old == self._minval[i]:
            self._n_at_min[i] -= 1
            if self._n_at_min[i] == 0:
                self._minval[i] = new
                self._n_at_min[i] = col.count(new)
        # hot tracks the *alive* gap: for stages with dead rows the masked
        # maximum is what _decide compares anyway, and those stages sit in
        # the static _dead_stages candidate list regardless of hotness
        hot = self._hot
        if self._amaxval[i] - self._minval[i] > self.delta:
            if i not in hot:
                hot.add(i)
                self._hot_dirty = True
        elif i in hot:
            hot.discard(i)
            self._hot_dirty = True

    def _next_pending(self, d: int, i: int):
        """First F chunk of executor (d, i) neither started nor migrated.
        Entries skipped are permanently ineligible, so the scan cursor is
        monotone (the reference rescans from the start every call)."""
        e = d * self.n_stages + i
        order = self.st.orders[e]
        kind, started, migrated = self.st.kind, self.started, self.migrated_away
        k = self.pend_cursor[e]
        while k < len(order):
            c = order[k]
            if kind[c] == _KIND_F and not started[c] and not migrated[c]:
                self.pend_cursor[e] = k
                return c
            k += 1
        self.pend_cursor[e] = k
        return None

    def _mem_feasible(self, e: int) -> bool:
        return (self.live[e] + self.inflight[e]) < self.mem_capacity

    def _migrate(self, i: int, dst: int, now: float, reason: str, touched):
        st = self.st
        group = [i]
        if st.comp_b[i] >= 0:
            group.append(st.comp_b[i])
        if st.comp_w[i] >= 0:
            group.append(st.comp_w[i])
        for g in group:
            if self.started[g]:
                return
        src_e = st.home[i]
        for g in group:
            self.placement[g] = dst
            self.exec_of[g] = dst
            self.migrated_away[g] = True
            self._dirty_mig.append(g)
            self.migq[dst].append(g)
        self._migq_pending[dst] += len(group)
        self.inflight[dst] += 1
        self.migrations.append(MigrationEvent(
            now, st.cids[i], st.executors[src_e], st.executors[dst], reason))
        self._inc_progress(st.replica[i], st.stage[i])  # Alg. 1 'Update P'
        self._refresh_edges(group)
        touched.add(dst)
        touched.add(src_e)

    # -------------------------------------------------------------- policy
    def _decide(self, now: float, touched):
        if self.policy == "none":
            return
        if self.policy == "recycle":
            cand = self._dead_stages  # recycle only ever evicts fail-stops
            if not cand:
                return
        else:
            # hot-set membership changes orders of magnitude less often than
            # _decide runs (once per completion batch), so the sorted
            # candidate list is cached until _inc_progress flips a stage
            if self._hot_dirty:
                self._cand_cache = sorted(
                    self._hot.union(self._dead_stages)
                    if self._dead_stages else self._hot)
                self._hot_dirty = False
            cand = self._cand_cache
            if not cand:
                return
        S, Pcols = self.n_stages, self._Pcols
        n_done = 0
        for i in cand:
            if n_done >= self.max_migrations_per_event:
                break
            alive = self._alive_rows[i]
            if not alive:
                continue
            if self.policy == "recycle":
                dead_rows = self._dead_rows[i]
                for d in (dead_rows or ()):
                    j = self._next_pending(d, i)
                    if j is not None and alive:
                        dst = alive[self._rr % len(alive)] * S + i
                        self._rr += 1
                        self._migrate(j, dst, now, "fail-stop", touched)
                        n_done += 1
                continue
            # replica-column reductions: list.index() of the incrementally
            # maintained minimum returns the first (= lowest-d) extremum,
            # matching the reference tie-break min(key=(val, d)); the alive
            # argmax (the reference's dead-rows-masked max, first index on
            # ties) is maintained incrementally by _inc_progress
            col = Pcols[i]
            d_min = col.index(self._minval[i])
            d_max = self._amax[i]
            src_dead = (d_min, i) in self.dead
            gap = col[d_max] - col[d_min]
            if not src_dead and gap <= self.delta:
                continue
            if d_max == d_min:
                continue
            j = self._next_pending(d_min, i)
            if j is None:
                continue
            dst = d_max * S + i
            if (d_max, i) in self.dead or not self._mem_feasible(dst):
                continue
            self._migrate(j, dst, now, "fail-stop" if src_dead else "fail-slow",
                          touched)
            n_done += 1

    # ------------------------------------------------------------ dispatch
    def _dispatch(self, e: int, now: float, heap, seq: int) -> int:
        if self.running[e] is not None or e in self._dead_e:
            return seq
        st = self.st
        order = st.orders[e]
        done, migrated, finish = self.done, self.migrated_away, self.finish
        dcost = self.dcost
        cur = self.cursor[e]
        own = None
        n_ord = len(order)
        while cur < n_ord:
            c = order[cur]
            if migrated[c] or done[c]:
                cur += 1
                continue
            own = c
            break
        if cur != self.cursor[e]:
            self.cursor[e] = cur
            self._dirty_cur.append(e)
        # ready times inlined (this is the hottest loop in the engine): max
        # over deps of finish + maintained edge cost, None while unfinished;
        # memoized once complete (see _ready_memo invariant)
        memo = self._ready_memo
        own_ready = None
        if own is not None:
            t = memo[own]
            if t is None:
                t = 0.0
                for d, cst in dcost[own]:
                    f = finish[d]
                    if f is None:
                        t = None
                        break
                    f = f + cst
                    if f > t:
                        t = f
                if t is not None:
                    memo[own] = t
            own_ready = t
        mig, mig_ready = None, None
        started = self.started
        q = self.migq[e]
        if q:
            kind = st.kind
            # scan with in-place compaction: done/started entries can never
            # be selected again, so squeeze them out of the scanned prefix
            # (keeps hot destination executors from rescanning a whole
            # session's worth of retired arrivals every dispatch)
            w = 0
            i = 0
            L = len(q)
            while i < L:
                c = q[i]
                i += 1
                if done[c] or started[c]:
                    continue
                q[w] = c
                w += 1
                r = memo[c]
                if r is None:
                    r = 0.0
                    for d, cst in dcost[c]:
                        f = finish[d]
                        if f is None:
                            r = None
                            break
                        f = f + cst
                        if f > r:
                            r = f
                    if r is not None:
                        memo[c] = r
                if r is not None and (mig_ready is None or r < mig_ready):
                    mig, mig_ready = c, r
                    if kind[c] != _KIND_W:
                        break
            if w != i:
                while i < L:
                    q[w] = q[i]
                    w += 1
                    i += 1
                del q[w:]
        cand, ready, from_mig = None, None, False
        own_now = own_ready is not None and own_ready <= now
        mig_now = mig_ready is not None and mig_ready <= now
        if own_now and mig_now:
            mk = 0 if st.kind[mig] == _KIND_B else 1
            ok = 0 if st.kind[own] == _KIND_B else 1
            if (st.mb[mig], mk) < (st.mb[own], ok):
                cand, ready, from_mig = mig, mig_ready, True
            else:
                cand, ready = own, own_ready
        elif own_now:
            cand, ready = own, own_ready
        elif mig_now:
            cand, ready, from_mig = mig, mig_ready, True
        elif own_ready is not None or mig_ready is not None:
            t = min(x for x in (own_ready, mig_ready) if x is not None)
            wa = self._wake_at
            pending = wa[e]
            if pending is None or t < pending:
                wa[e] = t
                heapq.heappush(heap, (t, seq, 1, e, -1))
                return seq + 1
            return seq
        if cand is None:
            return seq
        started[cand] = True
        self.running[e] = cand
        self._dirty_run.append(e)
        if from_mig:
            self._migq_pending[e] -= 1
        dur_home = self._dur_home
        if dur_home is not None and e == st.home[cand]:
            dur = dur_home[cand]
        else:
            dur = self.chunk_cost(st.cids[cand], st.executors[e])
        t_end = max(now, ready) + dur
        heapq.heappush(heap, (t_end, seq, 0, e, cand))
        return seq + 1

    def _chunk_costs(self, cs: list, es: np.ndarray) -> np.ndarray:
        """Durations for chunk/executor index arrays: the cost table's
        batched gather when available (bit-identical divisions), else one
        scalar call per start (arbitrary user cost callables)."""
        st = self.st
        batch = getattr(self.chunk_cost, "batch", None)
        if batch is not None:
            ci = np.fromiter(cs, dtype=np.intp, count=len(cs))
            return batch(st.kind_arr[ci], st.mb_arr[ci], st.stage_arr[ci],
                         st.replica_arr[ci], st.e_replica_arr[es],
                         es % self.n_stages)
        cost = self.chunk_cost
        return np.array([cost(st.cids[c], st.executors[e])
                         for c, e in zip(cs, es.tolist())])

    def _flush_mirrors(self):
        """Replay the scalar path's journaled mutations into the numpy
        mirrors as bulk fancy-index stores. Called exactly once per
        vectorized round, before any array is read; duplicate indices are
        harmless because every journaled value is re-read from the (always
        current) list state at flush time."""
        dd = self._dirty_done
        if dd:
            idx = np.fromiter(dd, dtype=np.intp, count=len(dd))
            self.done_np[idx] = True
            self.finish_arr[idx] = np.fromiter(self._dirty_fin,
                                               dtype=np.float64,
                                               count=len(dd))
            dd.clear()
            self._dirty_fin.clear()
        dm = self._dirty_mig
        if dm:
            self.migrated_np[np.fromiter(dm, dtype=np.intp,
                                         count=len(dm))] = True
            dm.clear()
        dc = self._dirty_cur
        if dc:
            cur = self.cursor
            self.cursor_arr[np.fromiter(dc, dtype=np.intp, count=len(dc))] = \
                np.fromiter((cur[e] for e in dc), dtype=np.intp,
                            count=len(dc))
            dc.clear()
        dr = self._dirty_run
        if dr:
            running = self.running
            self.running_arr[np.fromiter(dr, dtype=np.intp, count=len(dr))] = \
                np.fromiter((-1 if running[e] is None else running[e]
                             for e in dr), dtype=np.intp, count=len(dr))
            dr.clear()

    def _dispatch_arr(self, es: np.ndarray, now: float, heap, seq: int) -> int:
        """Batched ready/select/commit over an ascending executor index
        array: vectorized cursor walk to each executor's next own chunk, one
        fused ready-time computation for all candidates, then a single
        comparison against ``now`` splits starts (batched durations,
        completion pushes) from wake-ups. Mutations are mirrored back into
        the list state so subsequent scalar rounds see them."""
        st = self.st
        self._flush_mirrors()
        elig = (self.running_arr[es] == -1) & self._alive_e_mask[es]
        es = es[elig]
        if es.size == 0:
            return seq
        mq = self._migq_pending[es] > 0
        if mq.any():
            # migrated-in work: exact scalar semantics (rare, localized)
            for e in es[mq].tolist():
                seq = self._dispatch(e, now, heap, seq)
            es = es[~mq]
            if es.size == 0:
                return seq
        n = st.n_chunks
        # cursor walk: advance past done/migrated heads, all executors at
        # once (iterates max skip-run times; skips are rare and bounded)
        cur0 = self.cursor_arr[es]
        cur = cur0
        lens = st.order_len[es]
        valid = cur < lens
        head = np.where(valid, st.order_mat[es, np.where(valid, cur, 0)], n)
        while True:
            adv = self.done_np[head] | self.migrated_np[head]
            if not adv.any():
                break
            cur = cur + adv
            valid = cur < lens
            head = np.where(valid, st.order_mat[es, np.where(valid, cur, 0)], n)
        moved = cur != cur0
        if moved.any():
            self.cursor_arr[es] = cur
            cl = self.cursor
            for e, k in zip(es[moved].tolist(), cur[moved].tolist()):
                cl[e] = k
        # ready: masked maximum over the two dep slots (sentinel deps
        # contribute the reference's initial 0.0; unfinished deps poison the
        # maximum with +inf = "no ready time yet")
        ready = np.maximum(
            self.finish_arr[st.dep_a[head]] + self.dep_cost_a[head],
            self.finish_arr[st.dep_b[head]] + self.dep_cost_b[head])
        known = (head != n) & (ready != np.inf)
        start_m = known & (ready <= now)
        wake_m = known & ~start_m
        if wake_m.any():
            wa = self._wake_at
            for e, t in zip(es[wake_m].tolist(), ready[wake_m].tolist()):
                pending = wa[e]
                if pending is None or t < pending:
                    wa[e] = t
                    heapq.heappush(heap, (t, seq, 1, e, -1))
                    seq += 1
        if start_m.any():
            ees = es[start_m]
            cs = head[start_m].tolist()
            self.running_arr[ees] = head[start_m]
            t_end = np.maximum(ready[start_m], now) + self._chunk_costs(cs, ees)
            started, running = self.started, self.running
            for e, c, t in zip(ees.tolist(), cs, t_end.tolist()):
                started[c] = True
                running[e] = c
                heapq.heappush(heap, (t, seq, 0, e, c))
                seq += 1
        return seq

    def _dispatch_round(self, touched, now: float, heap, seq: int) -> int:
        """One dispatch round over a touched-executor set (in ascending
        executor order on both paths): vectorized stages past
        ``vec_batch_min``, the tuned scalar path below it."""
        if len(touched) >= self._vec_min:
            if isinstance(touched, np.ndarray):
                arr = touched
            else:
                arr = np.fromiter(touched, dtype=np.intp, count=len(touched))
                arr.sort()
            return self._dispatch_arr(arr, now, heap, seq)
        for e2 in (sorted(touched) if len(touched) > 1 else touched):
            seq = self._dispatch(e2, now, heap, seq)
        return seq

    # --------------------------------------------------------------- sim
    def run(self) -> SimResult:
        st = self.st
        if self.policy == "none":
            for (d, s) in self.dead:
                if 0 <= d < self.n_replicas and 0 <= s < self.n_stages \
                        and st.orders[d * self.n_stages + s]:
                    return SimResult(
                        math.inf, "aborted", {}, [], {}, {},
                        detail=f"stage {(d, s)} is fail-stop and no migration policy")
        heap: list = []
        seq = 0
        touched: set = set()
        self._decide(0.0, touched)
        seq = self._dispatch_round(self._all_executors, 0.0, heap, seq)
        guard = 0
        limit = (self.event_budget if self.event_budget is not None
                 else 50 * max(1, st.n_chunks))
        # hot-loop local bindings: every name below is read per event, and
        # attribute lookups are a measurable fraction of the drain at 10k+
        # devices (all the bound objects are mutated in place, never rebound)
        kind, replica, stage, rdeps = st.kind, st.replica, st.stage, st.rdeps
        heappop, heappush = heapq.heappop, heapq.heappush
        running = self.running
        done, finish = self.done, self.finish
        exec_of, placement = self.exec_of, self.placement
        live, inflight = self.live, self.inflight
        pr_finish = self._pr_finish
        dirty_done, dirty_fin = self._dirty_done, self._dirty_fin
        dirty_run, dirty_cur = self._dirty_run, self._dirty_cur
        wake_at = self._wake_at
        orders, dcost, mb = st.orders, self.dcost, st.mb
        cursor, memo, started = self.cursor, self._ready_memo, self.started
        migq, migq_pending = self.migq, self._migq_pending
        migrated = self.migrated_away
        dur_home, home = self._dur_home, st.home
        cids, executors, chunk_cost = st.cids, st.executors, self.chunk_cost
        dead_e, vec_min = self._dead_e, self._vec_min
        n_done_chunks, max_finish = self.n_done_chunks, self._max_finish
        while heap:
            guard += 1
            if guard > limit:
                self.n_done_chunks = n_done_chunks
                self._max_finish = max_finish
                raise _budget_error(heap[0][0], len(heap),
                                    st.n_chunks - n_done_chunks,
                                    st.n_chunks, limit)
            now, _, typ, e, c = heappop(heap)
            lim = now + SAME_TIME_EPS
            any_done = False
            touched = set()
            # commit the same-time batch event by event as it is popped:
            # commits never push to the heap, so interleaving pop and commit
            # is identical to gather-then-replay, minus the batch list
            while True:
                if typ == 0:  # completion
                    running[e] = None
                    dirty_run.append(e)
                    done[c] = True
                    dirty_done.append(c)
                    dirty_fin.append(now)
                    n_done_chunks += 1
                    finish[c] = now
                    if max_finish is None or now > max_finish:
                        max_finish = now
                    d = replica[c]
                    if now > pr_finish[d]:
                        pr_finish[d] = now
                    k = kind[c]
                    if k == _KIND_F:
                        live[e] += 1
                        if placement[c] >= 0:
                            inflight[e] -= 1
                        else:
                            self._inc_progress(d, stage[c])
                    elif k == _KIND_B:
                        live[e] -= 1
                    any_done = True
                    touched.add(e)
                    # only idle dependents can act on the new finish time —
                    # busy ones would no-op in _dispatch, and if their chunk
                    # completes later in this same batch that completion
                    # re-adds them (the reference dispatches everybody; the
                    # outcome is identical, minus the no-op calls)
                    for r in rdeps[c]:
                        e2 = exec_of[r]
                        if running[e2] is None:
                            touched.add(e2)
                else:  # wake
                    wake_at[e] = None
                    if running[e] is None:
                        touched.add(e)
                if heap and heap[0][0] <= lim:
                    _, _, typ, e, c = heappop(heap)
                else:
                    break
            if any_done:
                self._decide(now, touched)
            if len(touched) >= vec_min:
                arr = np.fromiter(touched, dtype=np.intp, count=len(touched))
                arr.sort()
                seq = self._dispatch_arr(arr, now, heap, seq)
                continue
            # ---- inlined scalar _dispatch over the touched executors ----
            # a line-for-line copy of ``_dispatch`` on the hoisted local
            # bindings: the method call plus its ~15 per-call attribute
            # loads were the largest single cost of the drain at 10k+
            # devices. Keep this block in lockstep with ``_dispatch`` (the
            # canonical form — the array path, the initial round and the
            # unit tests all still go through the method); the parity
            # suites pin both against the reference engine. Singleton
            # rounds (the common case at low event-time collision rates)
            # skip the ordering sort outright.
            for e in (sorted(touched) if len(touched) > 1 else touched):
                if running[e] is not None or e in dead_e:
                    continue
                order = orders[e]
                cur = cursor[e]
                own = None
                n_ord = len(order)
                while cur < n_ord:
                    cc = order[cur]
                    if migrated[cc] or done[cc]:
                        cur += 1
                        continue
                    own = cc
                    break
                if cur != cursor[e]:
                    cursor[e] = cur
                    dirty_cur.append(e)
                own_ready = None
                if own is not None:
                    t = memo[own]
                    if t is None:
                        t = 0.0
                        for d, cst in dcost[own]:
                            f = finish[d]
                            if f is None:
                                t = None
                                break
                            f = f + cst
                            if f > t:
                                t = f
                        if t is not None:
                            memo[own] = t
                    own_ready = t
                mig, mig_ready = None, None
                q = migq[e]
                if q:
                    w = 0
                    i = 0
                    L = len(q)
                    while i < L:
                        cc = q[i]
                        i += 1
                        if done[cc] or started[cc]:
                            continue
                        q[w] = cc
                        w += 1
                        r = memo[cc]
                        if r is None:
                            r = 0.0
                            for d, cst in dcost[cc]:
                                f = finish[d]
                                if f is None:
                                    r = None
                                    break
                                f = f + cst
                                if f > r:
                                    r = f
                            if r is not None:
                                memo[cc] = r
                        if r is not None and (mig_ready is None
                                              or r < mig_ready):
                            mig, mig_ready = cc, r
                            if kind[cc] != _KIND_W:
                                break
                    if w != i:
                        while i < L:
                            q[w] = q[i]
                            w += 1
                            i += 1
                        del q[w:]
                own_now = own_ready is not None and own_ready <= now
                mig_now = mig_ready is not None and mig_ready <= now
                from_mig = False
                if own_now and mig_now:
                    mk = 0 if kind[mig] == _KIND_B else 1
                    ok = 0 if kind[own] == _KIND_B else 1
                    if (mb[mig], mk) < (mb[own], ok):
                        cand, ready, from_mig = mig, mig_ready, True
                    else:
                        cand, ready = own, own_ready
                elif own_now:
                    cand, ready = own, own_ready
                elif mig_now:
                    cand, ready, from_mig = mig, mig_ready, True
                else:
                    if own_ready is not None or mig_ready is not None:
                        if own_ready is None:
                            t = mig_ready
                        elif mig_ready is None or own_ready < mig_ready:
                            t = own_ready
                        else:
                            t = mig_ready
                        pending = wake_at[e]
                        if pending is None or t < pending:
                            wake_at[e] = t
                            heappush(heap, (t, seq, 1, e, -1))
                            seq += 1
                    continue
                started[cand] = True
                running[e] = cand
                dirty_run.append(e)
                if from_mig:
                    migq_pending[e] -= 1
                if dur_home is not None and e == home[cand]:
                    dur = dur_home[cand]
                else:
                    dur = chunk_cost(cids[cand], executors[e])
                t_end = (now if now > ready else ready) + dur
                heappush(heap, (t_end, seq, 0, e, cand))
                seq += 1
        self.n_done_chunks = n_done_chunks
        self._max_finish = max_finish

        finish = {st.cids[i]: self.finish[i]
                  for i in range(st.n_chunks) if self.done[i]}
        if self.n_done_chunks != st.n_chunks:
            missing = [st.cids[i] for i in range(st.n_chunks) if not self.done[i]]
            return SimResult(math.inf, "aborted", finish, self.migrations,
                             {}, {},
                             detail=f"{len(missing)} chunks unexecuted, e.g. {missing[:4]}")
        total = self._max_finish if self._max_finish is not None else 0.0
        per_replica = {d: self._pr_finish[d] for d in range(self.n_replicas)}
        return SimResult(total, "ok", finish, self.migrations, {}, per_replica)


# ====================================================== belief plumbing
class StageSpeedCache:
    """Vectorized true-device-state -> per-(replica, stage) group-speed sync
    for the fast engine (one of the per-device python loops the ROADMAP
    flagged for 10k+-device sweeps).

    The reference loop in ``TrainingSim._true_stage_speeds`` is
    ``(st.tp / tp0) * min(speeds[d] for d in st.devices)`` per stage, re-run
    every iteration even though the plan only changes on reconfiguration and
    the cluster only changes when an event fires. Two cache levels:

    * per-plan: the per-stage device-index arrays and ``tp/tp0`` ratios are
      rebuilt only when the plan object changes;
    * per-(plan, cluster version): the full result dict is memoized against
      ``ClusterState.version``, so quiet iterations (no event fired, no
      reconfig) return the previous dict without touching the arrays at all
      — the fastsim cost-table refresh stops re-gathering speeds per stage
      per iteration.

    Each recompute reduces with ``ndarray.min`` over the registry's cached
    effective-speed array — bit-identical floats, since min over float64 and
    the single multiply are the exact operations of the reference
    expression. NTP stages (``StagePlan.shard_fractions``) reduce with an
    elementwise divide + ``ndarray.max`` instead — again the same IEEE
    operations as the reference ``max(f / v for ...)`` loop, so parity stays
    exact on nonuniform-width plans too.

    Alongside the dict, each recompute publishes ``grid`` — the same values
    as a dense (replica, stage) float array when the plan's stage grid is
    rectangular, else ``None`` — which the batched cost table consumes
    directly (``make_cost_table(true_speed_grid=...)``), skipping the
    per-iteration dict-walk rebuild of its executor-speed matrix.
    """

    def __init__(self):
        self._plan = None
        # ((r, s), tp_ratio, device-index array|None, shard-width array|None)
        self._entries: list = []
        self._version = None
        self._result: dict = {}
        self.grid = None  # dense (R, S) mirror of the last result, if rect.
        self._grid_shape = None

    def _rebuild(self, plan, tp0: int):
        self._entries = []
        for r, rep in enumerate(plan.replicas):
            for s, st in enumerate(rep.stages):
                ids = (np.fromiter(st.devices, dtype=np.intp,
                                   count=len(st.devices))
                       if st.devices else None)
                fr = (np.fromiter(st.shard_fractions, dtype=np.float64,
                                  count=len(st.shard_fractions))
                      if st.shard_fractions is not None else None)
                self._entries.append(((r, s), st.tp / tp0, ids, fr))
        n_rep = len(plan.replicas)
        stage_counts = {len(rep.stages) for rep in plan.replicas}
        self._grid_shape = ((n_rep, stage_counts.pop())
                            if len(stage_counts) == 1 else None)
        self._plan = plan
        self._version = None

    def speeds(self, plan, effective, tp0: int, *, version=None) -> dict:
        """``effective``: dense per-device effective-speed vector (device id
        = index); ``version``: the cluster mutation counter (None disables
        result memoization). The returned dict is shared — treat it as
        read-only."""
        if plan is not self._plan:
            self._rebuild(plan, tp0)
        if version is not None and version == self._version:
            return self._result
        vec = np.asarray(effective, dtype=np.float64)
        out = {}
        for key, ratio, ids, fr in self._entries:
            if ids is None:
                out[key] = 0.0
                continue
            g = vec[ids]
            m = g.min()
            if m <= 0:
                out[key] = 0.0
            elif fr is not None:
                worst = float((fr / g).max())
                out[key] = NTP_EFFICIENCY / (tp0 * worst)
            else:
                out[key] = ratio * float(m)
        if self._grid_shape is not None:
            self.grid = np.fromiter(
                out.values(), dtype=np.float64,
                count=len(out)).reshape(self._grid_shape)
        else:
            self.grid = None
        self._version = version
        self._result = out
        return out


class FastHeartbeat:
    """Vectorized drop-in for :class:`~repro.core.detector.heartbeat.
    HeartbeatMonitor` (fast engine only — the reference monitor stays the
    parity anchor on the python engine). This was the last per-device python
    loop on the 10k+-device sweep path: ``TrainingSim._sync_beliefs`` beat
    every alive device individually (``device_beat`` + ``node_beat`` per
    device per iteration) and ``sweep`` walked every ``DeviceHB`` dataclass.

    Here the per-device state is four dense numpy arrays (last-beat time,
    failed flag, node row, registered flag) plus three per-node arrays;
    ``beat_all(alive_mask, now)`` replaces the whole beat loop with masked
    stores and ``sweep`` with a handful of vector comparisons. Semantics are
    kept operation-for-operation (same float divisions, same node-channel
    guard on device beats, same whole-node-failure ordering, ``revive`` /
    ``revive_node`` / ``kill_node`` / ``mark_failed`` identical), so the
    engine-parity suite pins python vs fast byte-for-byte — exactly like
    :class:`StageSpeedCache` for ``_true_stage_speeds``.

    Assumes dense integer device ids and nodes registered in ascending
    device order (what ``TrainingSim`` does), so the ascending ``newly``
    list matches the reference's registration-order walk. Registration is
    init-only: unlike the reference monitor, which can adopt a node
    mid-flight, adding a node after the first beat/sweep would rebuild the
    state arrays and re-report every known death — so it raises instead.
    """

    def __init__(self, interval: float = 1.0, miss_threshold: int = 3):
        self.interval = interval
        self.miss_threshold = miss_threshold
        self.on_failstop = None
        self.failed_devices: set = set()
        self.failed_nodes: set = set()
        self.device_node: dict = {}
        self._node_ids: list = []
        self._node_row: dict = {}
        self._node_devices: dict = {}
        self._arrays = None

    # -------------------------------------------------------- registration
    def register_node(self, node_id: int, device_ids: list):
        if self._arrays is not None:
            raise RuntimeError(
                "FastHeartbeat registration is init-only: adding a node "
                "after beats/sweeps started would wipe heartbeat state and "
                "re-report known deaths (use HeartbeatMonitor for elastic "
                "scale-out)")
        self._node_row[node_id] = len(self._node_ids)
        self._node_ids.append(node_id)
        self._node_devices[node_id] = list(device_ids)
        for d in device_ids:
            self.device_node[d] = node_id

    def _ensure(self):
        if self._arrays is not None:
            return
        n_dev = max(self.device_node, default=-1) + 1
        n_nodes = len(self._node_ids)
        self._dev_last = np.full(n_dev, -1.0)
        self._dev_failed = np.zeros(n_dev, dtype=bool)
        self._dev_row = np.full(n_dev, -1, dtype=np.intp)
        self._registered = np.zeros(n_dev, dtype=bool)
        for d, nid in self.device_node.items():
            self._dev_row[d] = self._node_row[nid]
            self._registered[d] = True
        self._node_last = np.full(n_nodes, -1.0)
        self._node_alive = np.ones(n_nodes, dtype=bool)
        self._node_failed = np.zeros(n_nodes, dtype=bool)
        self._arrays = True

    # -------------------------------------------------------------- ingest
    def beat_all(self, alive, now: float):
        """The whole per-iteration beat loop in two masked stores: every
        alive registered device beats (unless its node channel is down — the
        reference ``device_beat`` guard) and every node hosting an alive
        device refreshes its side-channel keepalive (the reference
        ``node_beat``, which has no such guard)."""
        self._ensure()
        alive = np.asarray(alive, dtype=bool)
        live = alive & self._registered
        rows = self._dev_row[live]
        ok = ~self._node_failed & self._node_alive
        self._dev_last[live & ok[self._dev_row]] = now
        self._node_last[np.unique(rows)] = now

    def device_beat(self, node_id: int, device_id, now: float,
                    progress: int = 0):
        self._ensure()
        if node_id in self.failed_nodes or not self._node_alive[
                self._node_row[node_id]]:
            return
        self._dev_last[device_id] = now

    def node_beat(self, node_id: int, now: float):
        self._ensure()
        self._node_last[self._node_row[node_id]] = now

    def kill_node(self, node_id: int):
        self._ensure()
        self._node_alive[self._node_row[node_id]] = False

    def mark_failed(self, device_id):
        """Out-of-band failure report (validation-as-fail-stop path): the
        next sweep will not re-report the device."""
        self._ensure()
        self._dev_failed[device_id] = True
        self.failed_devices.add(device_id)

    # -------------------------------------------------------------- revive
    def revive(self, device_id, now: float = 0.0):
        self._ensure()
        nid = self.device_node.get(device_id)
        if nid is None:
            return
        row = self._node_row[nid]
        if nid in self.failed_nodes or not self._node_alive[row]:
            self.revive_node(nid, now)
        self._dev_failed[device_id] = False
        self._dev_last[device_id] = now
        self.failed_devices.discard(device_id)

    def revive_node(self, node_id: int, now: float = 0.0):
        self._ensure()
        row = self._node_row[node_id]
        self.failed_nodes.discard(node_id)
        self._node_failed[row] = False
        self._node_alive[row] = True
        self._node_last[row] = now

    # --------------------------------------------------------------- sweep
    def sweep(self, now: float) -> list:
        """Both detection levels vectorized. ``floor(x) >= m`` equals
        ``x >= m`` for integer m and non-negative x, so the reference's
        ``int(...)`` truncation reduces to a float comparison on the very
        same division."""
        self._ensure()
        act = ~self._node_failed
        exp_n = np.where(self._node_last >= 0,
                         (now - self._node_last) / self.interval, np.inf)
        node_dead = act & (~self._node_alive | (exp_n >= self.miss_threshold))
        node_ok = act & ~node_dead
        exp_d = np.where(self._dev_last >= 0,
                         (now - self._dev_last) / self.interval, np.inf)
        rows = self._dev_row
        cand = self._registered & ~self._dev_failed
        newly_mask = cand & (node_dead[rows]
                             | (node_ok[rows] & (exp_d >= self.miss_threshold)))
        ids = np.nonzero(newly_mask)[0]
        self._node_failed |= node_dead
        for r in np.nonzero(node_dead)[0]:
            self.failed_nodes.add(self._node_ids[r])
        self._dev_failed[ids] = True
        newly = [int(d) for d in ids]
        self.failed_devices.update(newly)
        if newly and self.on_failstop is not None:
            self.on_failstop(newly, now)
        return newly

    # --------------------------------------------------------------- stats
    @property
    def n_messages_per_interval(self) -> int:
        return len(self._node_ids)


# ========================================================== cost vectorizer
def make_cost_table(*, alpha, beta, gamma, workload, share, n_layers, mult,
                    jit, true_speed, replica_map=None, true_speed_grid=None):
    """Vectorized chunk-cost function, bit-identical to the scalar closure in
    ``TrainingSim.step`` (``make_cost``).

    The per-(stage, kind, micro-batch) numerators are precomputed once per
    plan/iteration as numpy float64 arrays with the *same association order*
    as the scalar expression — ``((base * K) * jit) / max(speed, 1e-9)`` with
    ``base = (alpha*N + beta*sum_l2) + gamma`` and
    ``K = (share[stage] * n_layers) * mult[kind]`` — so every lookup returns
    the exact float the reference closure computes.  ``replica_map`` mirrors
    the reference: when set, the chunk's replica is remapped and the executor
    speed is looked up under the mapped replica (``_run_independent``).

    Without ``replica_map``, the returned callable also carries a ``batch``
    attribute — the batched-dispatch protocol: given dense chunk coordinate
    arrays (kind, mb, stage, replica) and executor coordinate arrays, it
    returns the duration vector through one padded-table gather and one
    elementwise division (the same IEEE-754 ops as the scalar path, so
    parity stays exact). ``true_speed_grid`` (a dense (replica, stage)
    effective-speed array, e.g. ``StageSpeedCache.grid``) skips the
    executor-speed matrix rebuild from the ``true_speed`` dict.
    """
    mult_arr = np.array([mult["F"], mult["B"], mult["W"]], dtype=np.float64)
    n_stages = max(share) + 1
    share_arr = np.array([share[s] for s in range(n_stages)], dtype=np.float64)
    K = (share_arr * n_layers)[:, None] * mult_arr[None, :]

    tables: dict = {}

    def _table(r: int):
        t = tables.get(r)
        if t is None:
            mbs = workload.per_replica[r]
            n_tok = np.array([w.n_tokens for w in mbs], dtype=np.float64)
            l2 = np.array([w.sum_l2 for w in mbs], dtype=np.float64)
            base = (alpha * n_tok + beta * l2) + gamma
            t = tables[r] = (base[None, None, :] * K[:, :, None]) * jit
        return t

    vmax: dict = {}

    def cost(cid: ChunkId, executor) -> float:
        if replica_map is not None:
            r = replica_map(cid.replica)
            e = (r, executor[1])
        else:
            r = cid.replica
            e = executor
        v = vmax.get(e)
        if v is None:
            v = vmax[e] = max(true_speed.get(e, 1.0), 1e-9)
        t = _table(r)
        return float(t[cid.stage, _KIND_INDEX[cid.kind], cid.mb % t.shape[2]]) / v

    if replica_map is None:
        state: dict = {}

        def batch(kind, mb, stage, replica, e_replica, e_stage):
            T = state.get("T")
            if T is None:
                n_rep = len(workload.per_replica)
                widths = np.array(
                    [len(workload.per_replica[r]) for r in range(n_rep)],
                    dtype=np.intp)
                T = np.zeros((n_rep, n_stages, 3, int(widths.max())))
                for r in range(n_rep):
                    T[r, :, :, :widths[r]] = _table(r)
                if (true_speed_grid is not None
                        and true_speed_grid.shape == (n_rep, n_stages)):
                    vm = np.maximum(true_speed_grid, 1e-9)
                else:
                    vm = np.empty((n_rep, n_stages))
                    for r in range(n_rep):
                        for s in range(n_stages):
                            vm[r, s] = max(true_speed.get((r, s), 1.0), 1e-9)
                state.update(T=T, widths=widths, vm=vm)
            return (state["T"][replica, stage, kind, mb % state["widths"][replica]]
                    / state["vm"][e_replica, e_stage])

        cost.batch = batch

    return cost
