"""Fast execution core for the cluster simulator (the ``engine="fast"`` path).

Semantically exact, asymptotically faster re-implementation of
:class:`repro.core.scheduler.migration.ProgressAwareMigrator` plus a
vectorized chunk-cost table. The reference engine is kept untouched as the
semantic anchor; this module exists purely so that Fig. 14-style sweeps scale
to 1k+ devices (ROADMAP "Scale" item). Three structural wins, none of which
changes observable behaviour:

1. **Targeted dispatch.** The reference engine re-dispatches *every* executor
   after *every* completion batch — O(chunks x executors) work dominated by
   redundant readiness probes (at 256 devices: ~78k dispatch calls per
   iteration for ~1.5k events). Completions can only unblock (a) the executor
   that finished, (b) executors owning a dependent of the finished chunk,
   (c) migration sources/destinations and (d) executors with an explicit
   wake-up — so only those are dispatched. Same starts, same times.
2. **Incremental Algorithm-1 state.** The reference recomputes the progress
   matrix P from the full ``done`` set on every decide (O(chunks) each, so
   O(chunks^2) per iteration) and scans all stages. Here P is maintained
   incrementally; per-stage min/max are updated in O(1) amortized per F
   completion (counts only ever increment by one, so the stage minimum moves
   by at most one when its last holder leaves), and the decide body runs only
   over stages that can possibly act: the "hot" set (progress gap > delta)
   plus stages with fail-stop executors. Stages outside that set provably
   hit a ``continue`` in the reference loop.
3. **Static-structure cache.** Schedules, the chunk index, dependency and
   reverse-dependency lists depend only on (schedule, stages, micro-batches,
   replicas) — they are built once and shared across iterations instead of
   being rebuilt from ChunkId dataclasses every ``step()``.

Differences from the reference that are *not* observable through
``TrainingSim``: ``SimResult.idle`` is returned empty (the reference
recomputes every chunk cost at the end of a run just to report idle time;
nothing in the simulator reads it), and the set-iteration order inside the
``detail`` string of an aborted result may differ.

Bit-for-bit parity is enforced by ``tests/test_simulator_golden.py`` (the
fast engine is the default) and ``tests/test_engine_parity.py`` (python vs
fast across scenario families and policies).
"""
from __future__ import annotations

import heapq
import math

import numpy as np

from repro.core.detector.dag_sim import ChunkId
from repro.core.scheduler.migration import MigrationEvent, SimResult
from repro.core.scheduler.plan import NTP_EFFICIENCY
from repro.engine.schedules import make_schedule

_KIND_F, _KIND_B, _KIND_W = 0, 1, 2
_KIND_INDEX = {"F": _KIND_F, "B": _KIND_B, "W": _KIND_W}


# ===================================================== static schedule graph
class _Struct:
    """Immutable per-(schedule, stages, n_mb, replicas) execution graph,
    shared across iterations: integer-indexed chunks, per-executor orders,
    dependency/reverse-dependency lists and F -> B/W companion links."""

    __slots__ = (
        "n_stages", "n_replicas", "n_chunks", "executors", "e_replica",
        "orders", "cids", "kind", "mb", "stage", "replica", "home",
        "deps", "rdeps", "comp_b", "comp_w",
    )

    def __init__(self, schedule: str, n_stages: int, n_mb, n_replicas: int):
        self.n_stages = n_stages
        self.n_replicas = n_replicas
        self.executors = [(d, s) for d in range(n_replicas)
                          for s in range(n_stages)]
        self.e_replica = [d for d, _ in self.executors]
        eidx = {e: i for i, e in enumerate(self.executors)}

        cids: list = []
        index: dict = {}
        self.orders = [[] for _ in self.executors]
        for d in range(n_replicas):
            sched = make_schedule(schedule, n_stages, n_mb[d], replica=d)
            for (rep, st), order in sched.items():
                lst = self.orders[eidx[(rep, st)]]
                for cid in order:
                    i = index.get(cid)
                    if i is None:
                        i = index[cid] = len(cids)
                        cids.append(cid)
                    lst.append(i)
        self.n_chunks = len(cids)
        self.cids = cids
        self.kind = [_KIND_INDEX[c.kind] for c in cids]
        self.mb = [c.mb for c in cids]
        self.stage = [c.stage for c in cids]
        self.replica = [c.replica for c in cids]
        self.home = [eidx[(c.replica, c.stage)] for c in cids]

        # deps mirror ProgressAwareMigrator._deps (filtered to known chunks);
        # the static edge flag records whether the dep crosses stages (p2p)
        self.deps = [[] for _ in cids]
        self.rdeps = [[] for _ in cids]
        self.comp_b = [-1] * len(cids)
        self.comp_w = [-1] * len(cids)
        for i, c in enumerate(cids):
            if c.kind == "F":
                if c.stage > 0:
                    d = index.get(ChunkId("F", c.mb, c.stage - 1, c.replica))
                    if d is not None:
                        self.deps[i].append((d, True))
                b = index.get(ChunkId("B", c.mb, c.stage, c.replica))
                if b is not None:
                    self.comp_b[i] = b
                w = index.get(ChunkId("W", c.mb, c.stage, c.replica))
                if w is not None:
                    self.comp_w[i] = w
            elif c.kind == "B":
                d = index.get(ChunkId("F", c.mb, c.stage, c.replica))
                if d is not None:
                    self.deps[i].append((d, False))
                if c.stage < n_stages - 1:
                    d = index.get(ChunkId("B", c.mb, c.stage + 1, c.replica))
                    if d is not None:
                        self.deps[i].append((d, True))
            else:  # W
                d = index.get(ChunkId("B", c.mb, c.stage, c.replica))
                if d is not None:
                    self.deps[i].append((d, False))
        for i in range(len(cids)):
            for d, _ in self.deps[i]:
                self.rdeps[d].append(i)


_STRUCT_CACHE: dict = {}
_STRUCT_CACHE_MAX = 64


def _struct_for(schedule: str, n_stages: int, n_mb, n_replicas: int) -> _Struct:
    key = (schedule, n_stages, tuple(n_mb), n_replicas)
    s = _STRUCT_CACHE.get(key)
    if s is None:
        if len(_STRUCT_CACHE) >= _STRUCT_CACHE_MAX:
            _STRUCT_CACHE.clear()
        s = _STRUCT_CACHE[key] = _Struct(schedule, n_stages, n_mb, n_replicas)
    return s


# ============================================================ fast migrator
class FastMigrator:
    """Drop-in replacement for ProgressAwareMigrator (same constructor, same
    ``run() -> SimResult``), returning identical makespans, migrations,
    statuses and finish times — see the module docstring for what is faster
    and the two non-observable differences."""

    def __init__(
        self,
        *,
        n_stages: int,
        n_replicas: int,
        n_microbatches,
        chunk_cost,
        schedule: str = "1f1b",
        dead_executors=(),
        policy: str = "resihp",
        delta: int = 0,
        mem_capacity=None,
        p2p_cost: float = 0.0,
        migrate_edge_cost: float = 0.0,
        max_migrations_per_event: int = 4,
    ):
        self.n_stages = n_stages
        self.n_replicas = n_replicas
        if isinstance(n_microbatches, int):
            n_microbatches = [n_microbatches] * n_replicas
        self.n_mb = list(n_microbatches)
        self.chunk_cost = chunk_cost
        self.policy = policy
        self.delta = delta
        self.mem_capacity = mem_capacity if mem_capacity is not None else n_stages + 2
        self.p2p_cost = p2p_cost
        self.migrate_edge_cost = migrate_edge_cost
        self.dead = set(dead_executors)
        self.max_migrations_per_event = max_migrations_per_event

        st = self.st = _struct_for(schedule, n_stages, self.n_mb, n_replicas)
        n = st.n_chunks
        self._dead_e = {d * n_stages + s for (d, s) in self.dead
                        if 0 <= s < n_stages and 0 <= d < n_replicas}
        self._dead_stages = sorted({s for (_, s) in self.dead
                                    if 0 <= s < n_stages})

        # dynamic state
        self.placement = [-1] * n  # executor idx, -1 = home
        self.finish = [None] * n
        self.started = [False] * n
        self.done = [False] * n
        self.migrated_away = [False] * n
        self.n_done_chunks = 0
        E = len(st.executors)
        self.live = [0] * E
        self.inflight = [0] * E
        self.migq = [[] for _ in range(E)]
        self.cursor = [0] * E
        self.pend_cursor = [0] * E
        self.running = [None] * E
        self.migrations: list = []
        self._rr = 0
        # Algorithm-1 progress state: P[d, i] as a dense int matrix so the
        # decide body reduces whole replica-columns in C (the per-stage
        # min/max python loops were the one O(R) term left per event batch —
        # superlinear once fleet growth rides on DP), plus per-stage min/max
        # and the hot set
        self._P = np.zeros((n_replicas, n_stages), dtype=np.int64)
        self._minval = [0] * n_stages
        self._n_at_min = [n_replicas] * n_stages
        self._maxval = [0] * n_stages
        self._hot: set = set()
        self._max_finish = None
        self._pr_finish = [0.0] * n_replicas
        # static per-stage liveness (self.dead never changes during a run):
        # alive replica list (reference iteration order) and dead-row index
        # arrays for the masked argmax
        self._alive_rows = [
            [d for d in range(n_replicas) if (d, s) not in self.dead]
            for s in range(n_stages)
        ]
        self._dead_rows = [
            np.array([d for d in range(n_replicas) if (d, s) in self.dead],
                     dtype=np.intp)
            if any((d, s) in self.dead for d in range(n_replicas)) else None
            for s in range(n_stages)
        ]

    # ------------------------------------------------------------- helpers
    def _executor_of(self, i: int) -> int:
        p = self.placement[i]
        return p if p >= 0 else self.st.home[i]

    def _ready_time(self, i: int):
        t = 0.0
        finish = self.finish
        for d, crosses_stage in self.st.deps[i]:
            f = finish[d]
            if f is None:
                return None
            ed, ec = self._executor_of(d), self._executor_of(i)
            if ed != ec:
                c = self.p2p_cost if crosses_stage else 0.0
                if self.st.e_replica[ed] != self.st.e_replica[ec]:
                    c += self.migrate_edge_cost
                f = f + c
            if f > t:
                t = f
        return t

    def _inc_progress(self, d: int, i: int):
        """P[d, i] += 1 with O(1) amortized min/max/hot maintenance (values
        only ever increment, so the minimum can only move up by one when its
        last holder leaves)."""
        P = self._P
        old = int(P[d, i])
        P[d, i] = old + 1
        if old + 1 > self._maxval[i]:
            self._maxval[i] = old + 1
        if old == self._minval[i]:
            self._n_at_min[i] -= 1
            if self._n_at_min[i] == 0:
                m = old + 1
                self._minval[i] = m
                self._n_at_min[i] = int((P[:, i] == m).sum())
        if self._maxval[i] - self._minval[i] > self.delta:
            self._hot.add(i)
        else:
            self._hot.discard(i)

    def _next_pending(self, d: int, i: int):
        """First F chunk of executor (d, i) neither started nor migrated.
        Entries skipped are permanently ineligible, so the scan cursor is
        monotone (the reference rescans from the start every call)."""
        e = d * self.n_stages + i
        order = self.st.orders[e]
        kind, started, migrated = self.st.kind, self.started, self.migrated_away
        k = self.pend_cursor[e]
        while k < len(order):
            c = order[k]
            if kind[c] == _KIND_F and not started[c] and not migrated[c]:
                self.pend_cursor[e] = k
                return c
            k += 1
        self.pend_cursor[e] = k
        return None

    def _mem_feasible(self, e: int) -> bool:
        return (self.live[e] + self.inflight[e]) < self.mem_capacity

    def _migrate(self, i: int, dst: int, now: float, reason: str, touched):
        st = self.st
        group = [i]
        if st.comp_b[i] >= 0:
            group.append(st.comp_b[i])
        if st.comp_w[i] >= 0:
            group.append(st.comp_w[i])
        for g in group:
            if self.started[g]:
                return
        src_e = st.home[i]
        for g in group:
            self.placement[g] = dst
            self.migrated_away[g] = True
            self.migq[dst].append(g)
        self.inflight[dst] += 1
        self.migrations.append(MigrationEvent(
            now, st.cids[i], st.executors[src_e], st.executors[dst], reason))
        self._inc_progress(st.replica[i], st.stage[i])  # Alg. 1 'Update P'
        touched.add(dst)
        touched.add(src_e)

    # -------------------------------------------------------------- policy
    def _decide(self, now: float, touched):
        if self.policy == "none":
            return
        if self.policy == "recycle":
            cand = self._dead_stages  # recycle only ever evicts fail-stops
            if not cand:
                return
        elif self._dead_stages:
            cand = sorted(self._hot.union(self._dead_stages))
        elif self._hot:
            cand = sorted(self._hot)
        else:
            return
        S, P = self.n_stages, self._P
        n_done = 0
        for i in cand:
            if n_done >= self.max_migrations_per_event:
                break
            alive = self._alive_rows[i]
            if not alive:
                continue
            if self.policy == "recycle":
                dead_rows = self._dead_rows[i]
                for d in ([] if dead_rows is None else dead_rows.tolist()):
                    j = self._next_pending(d, i)
                    if j is not None and alive:
                        dst = alive[self._rr % len(alive)] * S + i
                        self._rr += 1
                        self._migrate(j, dst, now, "fail-stop", touched)
                        n_done += 1
                continue
            # replica-column reductions: argmin/argmax return the first (=
            # lowest-d) extremum, matching the reference tie-breaks
            # min(key=(val, d)) and max(alive, key=(val, -d)); dead rows are
            # masked below any real count (counts are >= 0) so the masked
            # argmax only ever picks an alive replica
            col = P[:, i]
            d_min = int(col.argmin())
            dead_rows = self._dead_rows[i]
            if dead_rows is None:
                d_max = int(col.argmax())
            else:
                masked = col.copy()
                masked[dead_rows] = -1
                d_max = int(masked.argmax())
            src_dead = (d_min, i) in self.dead
            gap = int(col[d_max]) - int(col[d_min])
            if not src_dead and gap <= self.delta:
                continue
            if d_max == d_min:
                continue
            j = self._next_pending(d_min, i)
            if j is None:
                continue
            dst = d_max * S + i
            if (d_max, i) in self.dead or not self._mem_feasible(dst):
                continue
            self._migrate(j, dst, now, "fail-stop" if src_dead else "fail-slow",
                          touched)
            n_done += 1

    # ------------------------------------------------------------ dispatch
    def _dispatch(self, e: int, now: float, heap, seq: int) -> int:
        if self.running[e] is not None or e in self._dead_e:
            return seq
        st = self.st
        order = st.orders[e]
        done, migrated = self.done, self.migrated_away
        cur = self.cursor[e]
        own = None
        while cur < len(order):
            c = order[cur]
            if migrated[c] or done[c]:
                cur += 1
                continue
            own = c
            break
        self.cursor[e] = cur
        own_ready = self._ready_time(own) if own is not None else None
        mig, mig_ready = None, None
        started = self.started
        for c in self.migq[e]:
            if done[c] or started[c]:
                continue
            r = self._ready_time(c)
            if r is not None and (mig_ready is None or r < mig_ready):
                mig, mig_ready = c, r
                if st.kind[c] != _KIND_W:
                    break
        cand, ready = None, None
        own_now = own_ready is not None and own_ready <= now
        mig_now = mig_ready is not None and mig_ready <= now
        if own_now and mig_now:
            mk = 0 if st.kind[mig] == _KIND_B else 1
            ok = 0 if st.kind[own] == _KIND_B else 1
            if (st.mb[mig], mk) < (st.mb[own], ok):
                cand, ready = mig, mig_ready
            else:
                cand, ready = own, own_ready
        elif own_now:
            cand, ready = own, own_ready
        elif mig_now:
            cand, ready = mig, mig_ready
        elif own_ready is not None or mig_ready is not None:
            t = min(x for x in (own_ready, mig_ready) if x is not None)
            heapq.heappush(heap, (t, seq, 1, e, -1))
            return seq + 1
        if cand is None:
            return seq
        started[cand] = True
        self.running[e] = cand
        dur = self.chunk_cost(st.cids[cand], st.executors[e])
        t_end = max(now, ready) + dur
        heapq.heappush(heap, (t_end, seq, 0, e, cand))
        return seq + 1

    # --------------------------------------------------------------- sim
    def run(self) -> SimResult:
        st = self.st
        if self.policy == "none":
            for (d, s) in self.dead:
                if 0 <= d < self.n_replicas and 0 <= s < self.n_stages \
                        and st.orders[d * self.n_stages + s]:
                    return SimResult(
                        math.inf, "aborted", {}, [], {}, {},
                        detail=f"stage {(d, s)} is fail-stop and no migration policy")
        heap: list = []
        seq = 0
        touched: set = set()
        self._decide(0.0, touched)
        for e in range(len(st.executors)):
            seq = self._dispatch(e, 0.0, heap, seq)
        guard = 0
        limit = 50 * max(1, st.n_chunks)
        kind, replica = st.kind, st.replica
        while heap:
            guard += 1
            if guard > limit:
                raise RuntimeError("migration sim: event budget exceeded (livelock?)")
            now, _, typ, e, c = heapq.heappop(heap)
            batch = [(typ, e, c)]
            while heap and heap[0][0] <= now + 1e-12:
                _, _, typ2, e2, c2 = heapq.heappop(heap)
                batch.append((typ2, e2, c2))
            any_done = False
            touched = set()
            for typ, e, c in batch:
                if typ == 0:  # completion
                    self.running[e] = None
                    self.done[c] = True
                    self.n_done_chunks += 1
                    self.finish[c] = now
                    if self._max_finish is None or now > self._max_finish:
                        self._max_finish = now
                    d = replica[c]
                    if now > self._pr_finish[d]:
                        self._pr_finish[d] = now
                    k = kind[c]
                    if k == _KIND_F:
                        self.live[e] += 1
                        if self.placement[c] >= 0:
                            self.inflight[e] -= 1
                        else:
                            self._inc_progress(d, st.stage[c])
                    elif k == _KIND_B:
                        self.live[e] -= 1
                    any_done = True
                    touched.add(e)
                    for r in st.rdeps[c]:
                        touched.add(self._executor_of(r))
                else:  # wake
                    touched.add(e)
            if any_done:
                self._decide(now, touched)
            for e2 in sorted(touched):
                seq = self._dispatch(e2, now, heap, seq)

        finish = {st.cids[i]: self.finish[i]
                  for i in range(st.n_chunks) if self.done[i]}
        if self.n_done_chunks != st.n_chunks:
            missing = [st.cids[i] for i in range(st.n_chunks) if not self.done[i]]
            return SimResult(math.inf, "aborted", finish, self.migrations,
                             {}, {},
                             detail=f"{len(missing)} chunks unexecuted, e.g. {missing[:4]}")
        total = self._max_finish if self._max_finish is not None else 0.0
        per_replica = {d: self._pr_finish[d] for d in range(self.n_replicas)}
        return SimResult(total, "ok", finish, self.migrations, {}, per_replica)


# ====================================================== belief plumbing
class StageSpeedCache:
    """Vectorized true-device-state -> per-(replica, stage) group-speed sync
    for the fast engine (one of the per-device python loops the ROADMAP
    flagged for 10k+-device sweeps).

    The reference loop in ``TrainingSim._true_stage_speeds`` is
    ``(st.tp / tp0) * min(speeds[d] for d in st.devices)`` per stage, re-run
    every iteration even though the plan only changes on reconfiguration and
    the cluster only changes when an event fires. Two cache levels:

    * per-plan: the per-stage device-index arrays and ``tp/tp0`` ratios are
      rebuilt only when the plan object changes;
    * per-(plan, cluster version): the full result dict is memoized against
      ``ClusterState.version``, so quiet iterations (no event fired, no
      reconfig) return the previous dict without touching the arrays at all
      — the fastsim cost-table refresh stops re-gathering speeds per stage
      per iteration.

    Each recompute reduces with ``ndarray.min`` over the registry's cached
    effective-speed array — bit-identical floats, since min over float64 and
    the single multiply are the exact operations of the reference
    expression. NTP stages (``StagePlan.shard_fractions``) reduce with an
    elementwise divide + ``ndarray.max`` instead — again the same IEEE
    operations as the reference ``max(f / v for ...)`` loop, so parity stays
    exact on nonuniform-width plans too.
    """

    def __init__(self):
        self._plan = None
        # ((r, s), tp_ratio, device-index array|None, shard-width array|None)
        self._entries: list = []
        self._version = None
        self._result: dict = {}

    def _rebuild(self, plan, tp0: int):
        self._entries = []
        for r, rep in enumerate(plan.replicas):
            for s, st in enumerate(rep.stages):
                ids = (np.fromiter(st.devices, dtype=np.intp,
                                   count=len(st.devices))
                       if st.devices else None)
                fr = (np.fromiter(st.shard_fractions, dtype=np.float64,
                                  count=len(st.shard_fractions))
                      if st.shard_fractions is not None else None)
                self._entries.append(((r, s), st.tp / tp0, ids, fr))
        self._plan = plan
        self._version = None

    def speeds(self, plan, effective, tp0: int, *, version=None) -> dict:
        """``effective``: dense per-device effective-speed vector (device id
        = index); ``version``: the cluster mutation counter (None disables
        result memoization). The returned dict is shared — treat it as
        read-only."""
        if plan is not self._plan:
            self._rebuild(plan, tp0)
        if version is not None and version == self._version:
            return self._result
        vec = np.asarray(effective, dtype=np.float64)
        out = {}
        for key, ratio, ids, fr in self._entries:
            if ids is None:
                out[key] = 0.0
                continue
            g = vec[ids]
            m = g.min()
            if m <= 0:
                out[key] = 0.0
            elif fr is not None:
                worst = float((fr / g).max())
                out[key] = NTP_EFFICIENCY / (tp0 * worst)
            else:
                out[key] = ratio * float(m)
        self._version = version
        self._result = out
        return out


class FastHeartbeat:
    """Vectorized drop-in for :class:`~repro.core.detector.heartbeat.
    HeartbeatMonitor` (fast engine only — the reference monitor stays the
    parity anchor on the python engine). This was the last per-device python
    loop on the 10k+-device sweep path: ``TrainingSim._sync_beliefs`` beat
    every alive device individually (``device_beat`` + ``node_beat`` per
    device per iteration) and ``sweep`` walked every ``DeviceHB`` dataclass.

    Here the per-device state is four dense numpy arrays (last-beat time,
    failed flag, node row, registered flag) plus three per-node arrays;
    ``beat_all(alive_mask, now)`` replaces the whole beat loop with masked
    stores and ``sweep`` with a handful of vector comparisons. Semantics are
    kept operation-for-operation (same float divisions, same node-channel
    guard on device beats, same whole-node-failure ordering, ``revive`` /
    ``revive_node`` / ``kill_node`` / ``mark_failed`` identical), so the
    engine-parity suite pins python vs fast byte-for-byte — exactly like
    :class:`StageSpeedCache` for ``_true_stage_speeds``.

    Assumes dense integer device ids and nodes registered in ascending
    device order (what ``TrainingSim`` does), so the ascending ``newly``
    list matches the reference's registration-order walk. Registration is
    init-only: unlike the reference monitor, which can adopt a node
    mid-flight, adding a node after the first beat/sweep would rebuild the
    state arrays and re-report every known death — so it raises instead.
    """

    def __init__(self, interval: float = 1.0, miss_threshold: int = 3):
        self.interval = interval
        self.miss_threshold = miss_threshold
        self.on_failstop = None
        self.failed_devices: set = set()
        self.failed_nodes: set = set()
        self.device_node: dict = {}
        self._node_ids: list = []
        self._node_row: dict = {}
        self._node_devices: dict = {}
        self._arrays = None

    # -------------------------------------------------------- registration
    def register_node(self, node_id: int, device_ids: list):
        if self._arrays is not None:
            raise RuntimeError(
                "FastHeartbeat registration is init-only: adding a node "
                "after beats/sweeps started would wipe heartbeat state and "
                "re-report known deaths (use HeartbeatMonitor for elastic "
                "scale-out)")
        self._node_row[node_id] = len(self._node_ids)
        self._node_ids.append(node_id)
        self._node_devices[node_id] = list(device_ids)
        for d in device_ids:
            self.device_node[d] = node_id

    def _ensure(self):
        if self._arrays is not None:
            return
        n_dev = max(self.device_node, default=-1) + 1
        n_nodes = len(self._node_ids)
        self._dev_last = np.full(n_dev, -1.0)
        self._dev_failed = np.zeros(n_dev, dtype=bool)
        self._dev_row = np.full(n_dev, -1, dtype=np.intp)
        self._registered = np.zeros(n_dev, dtype=bool)
        for d, nid in self.device_node.items():
            self._dev_row[d] = self._node_row[nid]
            self._registered[d] = True
        self._node_last = np.full(n_nodes, -1.0)
        self._node_alive = np.ones(n_nodes, dtype=bool)
        self._node_failed = np.zeros(n_nodes, dtype=bool)
        self._arrays = True

    # -------------------------------------------------------------- ingest
    def beat_all(self, alive, now: float):
        """The whole per-iteration beat loop in two masked stores: every
        alive registered device beats (unless its node channel is down — the
        reference ``device_beat`` guard) and every node hosting an alive
        device refreshes its side-channel keepalive (the reference
        ``node_beat``, which has no such guard)."""
        self._ensure()
        alive = np.asarray(alive, dtype=bool)
        live = alive & self._registered
        rows = self._dev_row[live]
        ok = ~self._node_failed & self._node_alive
        self._dev_last[live & ok[self._dev_row]] = now
        self._node_last[np.unique(rows)] = now

    def device_beat(self, node_id: int, device_id, now: float,
                    progress: int = 0):
        self._ensure()
        if node_id in self.failed_nodes or not self._node_alive[
                self._node_row[node_id]]:
            return
        self._dev_last[device_id] = now

    def node_beat(self, node_id: int, now: float):
        self._ensure()
        self._node_last[self._node_row[node_id]] = now

    def kill_node(self, node_id: int):
        self._ensure()
        self._node_alive[self._node_row[node_id]] = False

    def mark_failed(self, device_id):
        """Out-of-band failure report (validation-as-fail-stop path): the
        next sweep will not re-report the device."""
        self._ensure()
        self._dev_failed[device_id] = True
        self.failed_devices.add(device_id)

    # -------------------------------------------------------------- revive
    def revive(self, device_id, now: float = 0.0):
        self._ensure()
        nid = self.device_node.get(device_id)
        if nid is None:
            return
        row = self._node_row[nid]
        if nid in self.failed_nodes or not self._node_alive[row]:
            self.revive_node(nid, now)
        self._dev_failed[device_id] = False
        self._dev_last[device_id] = now
        self.failed_devices.discard(device_id)

    def revive_node(self, node_id: int, now: float = 0.0):
        self._ensure()
        row = self._node_row[node_id]
        self.failed_nodes.discard(node_id)
        self._node_failed[row] = False
        self._node_alive[row] = True
        self._node_last[row] = now

    # --------------------------------------------------------------- sweep
    def sweep(self, now: float) -> list:
        """Both detection levels vectorized. ``floor(x) >= m`` equals
        ``x >= m`` for integer m and non-negative x, so the reference's
        ``int(...)`` truncation reduces to a float comparison on the very
        same division."""
        self._ensure()
        act = ~self._node_failed
        exp_n = np.where(self._node_last >= 0,
                         (now - self._node_last) / self.interval, np.inf)
        node_dead = act & (~self._node_alive | (exp_n >= self.miss_threshold))
        node_ok = act & ~node_dead
        exp_d = np.where(self._dev_last >= 0,
                         (now - self._dev_last) / self.interval, np.inf)
        rows = self._dev_row
        cand = self._registered & ~self._dev_failed
        newly_mask = cand & (node_dead[rows]
                             | (node_ok[rows] & (exp_d >= self.miss_threshold)))
        ids = np.nonzero(newly_mask)[0]
        self._node_failed |= node_dead
        for r in np.nonzero(node_dead)[0]:
            self.failed_nodes.add(self._node_ids[r])
        self._dev_failed[ids] = True
        newly = [int(d) for d in ids]
        self.failed_devices.update(newly)
        if newly and self.on_failstop is not None:
            self.on_failstop(newly, now)
        return newly

    # --------------------------------------------------------------- stats
    @property
    def n_messages_per_interval(self) -> int:
        return len(self._node_ids)


# ========================================================== cost vectorizer
def make_cost_table(*, alpha, beta, gamma, workload, share, n_layers, mult,
                    jit, true_speed, replica_map=None):
    """Vectorized chunk-cost function, bit-identical to the scalar closure in
    ``TrainingSim.step`` (``make_cost``).

    The per-(stage, kind, micro-batch) numerators are precomputed once per
    plan/iteration as numpy float64 arrays with the *same association order*
    as the scalar expression — ``((base * K) * jit) / max(speed, 1e-9)`` with
    ``base = (alpha*N + beta*sum_l2) + gamma`` and
    ``K = (share[stage] * n_layers) * mult[kind]`` — so every lookup returns
    the exact float the reference closure computes.  ``replica_map`` mirrors
    the reference: when set, the chunk's replica is remapped and the executor
    speed is looked up under the mapped replica (``_run_independent``).
    """
    mult_arr = np.array([mult["F"], mult["B"], mult["W"]], dtype=np.float64)
    n_stages = max(share) + 1
    share_arr = np.array([share[s] for s in range(n_stages)], dtype=np.float64)
    K = (share_arr * n_layers)[:, None] * mult_arr[None, :]

    tables: dict = {}

    def _table(r: int):
        t = tables.get(r)
        if t is None:
            mbs = workload.per_replica[r]
            n_tok = np.array([w.n_tokens for w in mbs], dtype=np.float64)
            l2 = np.array([w.sum_l2 for w in mbs], dtype=np.float64)
            base = (alpha * n_tok + beta * l2) + gamma
            t = tables[r] = (base[None, None, :] * K[:, :, None]) * jit
        return t

    vmax: dict = {}

    def cost(cid: ChunkId, executor) -> float:
        if replica_map is not None:
            r = replica_map(cid.replica)
            e = (r, executor[1])
        else:
            r = cid.replica
            e = executor
        v = vmax.get(e)
        if v is None:
            v = vmax[e] = max(true_speed.get(e, 1.0), 1e-9)
        t = _table(r)
        return float(t[cid.stage, _KIND_INDEX[cid.kind], cid.mb % t.shape[2]]) / v

    return cost
