"""Cluster device registry + failure injection.

Devices carry a normalized throughput p (1.0 = healthy peak) and a liveness
bit; nodes group devices (heartbeat locality + NVLink/ICI domain). Injection
mirrors the paper's §8.1 methodology:

  * fail-stop        — worker terminated (speed 0, heartbeats stop);
  * compute fail-slow — SM-clock-lock analogue: multiply device speed;
  * network fail-slow — bandwidth contention on a node's links: multiplies
    the communication-sensitive share of affected devices' throughput.

Array-native core
-----------------
Ground truth lives in preallocated dense numpy arrays over device ids
``0..n-1`` — ``speed``, ``net_scale``, ``alive``, ``age`` (sim-time the
device last (re)entered service) and ``node_of`` — so the simulator hot path
(validation scans, heartbeat masks, stage-speed reductions) is C-speed at
16k+ devices. The original dict/object API (``cluster.devices[i].alive``
etc.) is kept as a thin **adapter view**: :class:`DeviceView` proxies read
and write the arrays in place, and ``cluster.devices`` behaves like the old
insertion-ordered dict. Contract:

  * every mutation (injection method or adapter-attribute write) bumps
    ``cluster.version`` — consumers key caches on it;
  * ``effective()`` / ``alive_mask()`` return cached **read-only** array
    views, rebuilt lazily after a version bump;
  * ``speeds()`` (the legacy dict form) is likewise rebuilt only after a
    mutation — identical floats, since the array product ``speed *
    net_scale`` is the same IEEE-754 multiply the old per-object property
    performed.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np


@dataclass
class Device:
    """Plain standalone device record (kept for back-compat construction);
    inside :class:`ClusterState` devices are rows of the arrays, surfaced
    through :class:`DeviceView`."""

    id: int
    node: int
    speed: float = 1.0  # normalized compute throughput p_i
    net_scale: float = 1.0  # link-contention multiplier (1.0 = clean links)
    alive: bool = True

    @property
    def effective(self) -> float:
        return self.speed * self.net_scale if self.alive else 0.0


@dataclass(frozen=True)
class ClusterTopology:
    """Physical layout: nodes of ``devices_per_node`` devices, grouped into
    correlated failure domains. A *rack* is a node (the heartbeat/NVLink
    domain the repo always had); ``nodes_per_pdu`` racks share one power
    distribution unit and ``nodes_per_switch`` racks share one leaf switch —
    the two correlation domains fleet reliability reports blame for most
    multi-device incidents (a browned-out PDU elevates every resident
    device's failure rate; a flaky switch degrades every resident link).
    The defaults (PDU == rack, two racks per switch) keep every existing
    two-argument construction byte-compatible."""

    n_nodes: int
    devices_per_node: int = 8
    nodes_per_pdu: int = 1
    nodes_per_switch: int = 2

    def __post_init__(self):
        if self.nodes_per_pdu < 1 or self.nodes_per_switch < 1:
            raise ValueError("nodes_per_pdu / nodes_per_switch must be >= 1")

    @property
    def n_devices(self) -> int:
        return self.n_nodes * self.devices_per_node

    def node_of(self, device_id: int) -> int:
        return device_id // self.devices_per_node

    # ------------------------------------------------------ failure domains
    @property
    def n_pdus(self) -> int:
        return -(-self.n_nodes // self.nodes_per_pdu)

    @property
    def n_switches(self) -> int:
        return -(-self.n_nodes // self.nodes_per_switch)

    def pdu_of(self, device_id: int) -> int:
        return self.node_of(device_id) // self.nodes_per_pdu

    def switch_of(self, device_id: int) -> int:
        return self.node_of(device_id) // self.nodes_per_switch

    def domain_of(self, device_id: int, kind: str = "pdu") -> int:
        """Domain index of a device under ``kind`` ('pdu' | 'switch' |
        'node'/'rack')."""
        if kind == "pdu":
            return self.pdu_of(device_id)
        if kind == "switch":
            return self.switch_of(device_id)
        if kind in ("node", "rack"):
            return self.node_of(device_id)
        raise ValueError(f"unknown domain kind {kind!r}")

    def domain_nodes(self, kind: str, index: int) -> list:
        """Node ids resident in one domain (for ``kind='node'`` the domain
        *is* the node)."""
        per = {"pdu": self.nodes_per_pdu, "switch": self.nodes_per_switch,
               "node": 1, "rack": 1}.get(kind)
        if per is None:
            raise ValueError(f"unknown domain kind {kind!r}")
        lo = index * per
        return [n for n in range(lo, min(lo + per, self.n_nodes))]

    def domain_devices(self, kind: str, index: int) -> list:
        """Device ids resident in one domain, ascending."""
        return [d for n in self.domain_nodes(kind, index)
                for d in range(n * self.devices_per_node,
                               (n + 1) * self.devices_per_node)]


class DeviceView:
    """Write-through adapter over one row of the ClusterState arrays —
    attribute-compatible with the old ``Device`` dataclass."""

    __slots__ = ("_cs", "id")

    def __init__(self, cs: "ClusterState", device_id: int):
        self._cs = cs
        self.id = device_id

    @property
    def node(self) -> int:
        return int(self._cs.node_of[self.id])

    @property
    def speed(self) -> float:
        return float(self._cs._speed[self.id])

    @speed.setter
    def speed(self, v: float):
        self._cs._speed[self.id] = float(v)
        self._cs._touch()

    @property
    def net_scale(self) -> float:
        return float(self._cs._net[self.id])

    @net_scale.setter
    def net_scale(self, v: float):
        self._cs._net[self.id] = float(v)
        self._cs._touch()

    @property
    def alive(self) -> bool:
        return bool(self._cs._alive[self.id])

    @alive.setter
    def alive(self, v: bool):
        self._cs._alive[self.id] = bool(v)
        self._cs._touch()

    @property
    def effective(self) -> float:
        cs = self._cs
        if not cs._alive[self.id]:
            return 0.0
        return float(cs._speed[self.id]) * float(cs._net[self.id])

    def __repr__(self):
        return (f"DeviceView(id={self.id}, node={self.node}, "
                f"speed={self.speed}, net_scale={self.net_scale}, "
                f"alive={self.alive})")


class _DeviceMap:
    """Read-only mapping facade over the arrays: iteration order and key set
    match the old ``{0: Device, 1: Device, ...}`` dict exactly."""

    __slots__ = ("_cs",)

    def __init__(self, cs: "ClusterState"):
        self._cs = cs

    def __getitem__(self, device_id: int) -> DeviceView:
        if not 0 <= device_id < self._cs.n_devices:
            raise KeyError(device_id)
        return DeviceView(self._cs, device_id)

    def __len__(self) -> int:
        return self._cs.n_devices

    def __iter__(self):
        return iter(range(self._cs.n_devices))

    def __contains__(self, device_id) -> bool:
        return isinstance(device_id, (int, np.integer)) \
            and 0 <= device_id < self._cs.n_devices

    def keys(self):
        return range(self._cs.n_devices)

    def values(self):
        cs = self._cs
        return (DeviceView(cs, i) for i in range(cs.n_devices))

    def items(self):
        cs = self._cs
        return ((i, DeviceView(cs, i)) for i in range(cs.n_devices))


class ClusterState:
    """Array-native cluster ground truth (see module docstring)."""

    def __init__(self, topo: ClusterTopology, events=None):
        self.topo = topo
        n = topo.n_devices
        self._speed = np.ones(n, dtype=np.float64)
        self._net = np.ones(n, dtype=np.float64)
        self._alive = np.ones(n, dtype=np.bool_)
        # sim-time each device last (re)entered service (0.0 at birth,
        # stamped by ``repair``) — the per-device age anchor hazard-aware
        # tooling reads as ``now - age``
        self._age = np.zeros(n, dtype=np.float64)
        self.node_of = np.arange(n, dtype=np.intp) // topo.devices_per_node
        self.events = list(events) if events else []  # injection log
        self.devices = _DeviceMap(self)
        self.version = 0  # bumped on every mutation (cache-invalidation key)
        self._eff = None  # cached effective-speed array
        self._speeds_dict = None  # cached legacy dict form
        self._node_members = None  # node -> [device ids], built lazily

    # ------------------------------------------------------------ mutation
    def _touch(self):
        self.version += 1
        self._eff = None
        self._speeds_dict = None

    # ------------------------------------------------------------ queries
    def effective(self) -> np.ndarray:
        """Dense effective-speed vector (``speed * net_scale``, 0.0 when
        dead) over device ids ``0..n-1`` — a cached read-only view, rebuilt
        only after a mutation."""
        if self._eff is None:
            eff = self._speed * self._net
            eff[~self._alive] = 0.0
            eff.flags.writeable = False
            self._eff = eff
        return self._eff

    def speeds(self) -> dict:
        """Legacy dict form ``{device_id: effective}`` — cached slice of the
        effective array, invalidated on mutation."""
        if self._speeds_dict is None:
            self._speeds_dict = dict(enumerate(self.effective().tolist()))
        return self._speeds_dict

    def alive_ids(self) -> list:
        return np.nonzero(self._alive)[0].tolist()

    def alive_mask(self) -> np.ndarray:
        """Dense liveness vector over the device ids ``0..n-1`` for the
        vectorized heartbeat path — one bool per device (read-only view of
        the ground-truth array)."""
        v = self._alive.view()
        v.flags.writeable = False
        return v

    def ages(self, now: float) -> np.ndarray:
        """Per-device service age in seconds at time ``now`` (time since
        birth or last repair)."""
        return np.maximum(now - self._age, 0.0)

    @property
    def n_devices(self) -> int:
        return self.topo.n_devices

    def node_devices(self, node: int) -> list:
        if self._node_members is None:
            members = [[] for _ in range(self.topo.n_nodes)]
            for d, nd in enumerate(self.node_of.tolist()):
                members[nd].append(d)
            self._node_members = members
        return list(self._node_members[node])

    def _node_rows(self, node: int) -> np.ndarray:
        return np.nonzero(self.node_of == node)[0]

    # ---------------------------------------------------------- injection
    def fail_stop(self, device_id: int, now: float = 0.0):
        self._alive[device_id] = False
        self._touch()
        self.events.append((now, "fail-stop", device_id, 0.0))

    def fail_stop_node(self, node: int, now: float = 0.0):
        self._alive[self._node_rows(node)] = False
        self._touch()
        self.events.append((now, "fail-stop-node", node, 0.0))

    def fail_slow(self, device_id: int, factor: float, now: float = 0.0):
        """factor = remaining fraction of peak (0.5 = half speed)."""
        self._speed[device_id] = float(factor)
        self._touch()
        self.events.append((now, "fail-slow", device_id, factor))

    def degrade_network(self, node: int, factor: float, comm_share: float = 0.3,
                        now: float = 0.0):
        """Bandwidth contention on a node: the communication share of each
        device's step time stretches by 1/factor. Tracked separately from
        compute speed so clearing the contention restores exactly this
        component (a co-located compute straggler stays slow)."""
        eff = 1.0 / ((1.0 - comm_share) + comm_share / max(factor, 1e-9))
        rows = self._node_rows(node)
        self._net[rows] = np.minimum(self._net[rows], eff)
        self._touch()
        self.events.append((now, "net-degrade", node, factor))

    def restore_network(self, node: int, now: float = 0.0):
        """Link contention cleared: only the network component recovers —
        dead devices stay dead, compute fail-slows stay slow."""
        self._net[self._node_rows(node)] = 1.0
        self._touch()
        self.events.append((now, "net-restore", node, 1.0))

    def repair(self, device_id: int, now: float = 0.0, speed: float = 1.0):
        """Bring a device back; ``speed < 1.0`` models a degraded return
        (swapped-in older part, partially-recovered thermal state) — the
        case rejoin admission probing exists for."""
        self._alive[device_id] = True
        self._speed[device_id] = float(speed)
        self._net[device_id] = 1.0
        self._age[device_id] = float(now)
        self._touch()
        self.events.append((now, "repair", device_id, float(speed)))
