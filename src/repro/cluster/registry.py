"""Cluster device registry + failure injection.

Devices carry a normalized throughput p (1.0 = healthy peak) and a liveness
bit; nodes group devices (heartbeat locality + NVLink/ICI domain). Injection
mirrors the paper's §8.1 methodology:

  * fail-stop        — worker terminated (speed 0, heartbeats stop);
  * compute fail-slow — SM-clock-lock analogue: multiply device speed;
  * network fail-slow — bandwidth contention on a node's links: multiplies
    the communication-sensitive share of affected devices' throughput.
"""
from __future__ import annotations

from dataclasses import dataclass, field


@dataclass
class Device:
    id: int
    node: int
    speed: float = 1.0  # normalized compute throughput p_i
    net_scale: float = 1.0  # link-contention multiplier (1.0 = clean links)
    alive: bool = True

    @property
    def effective(self) -> float:
        return self.speed * self.net_scale if self.alive else 0.0


@dataclass(frozen=True)
class ClusterTopology:
    n_nodes: int
    devices_per_node: int = 8

    @property
    def n_devices(self) -> int:
        return self.n_nodes * self.devices_per_node

    def node_of(self, device_id: int) -> int:
        return device_id // self.devices_per_node


@dataclass
class ClusterState:
    topo: ClusterTopology
    devices: dict = field(default_factory=dict)
    events: list = field(default_factory=list)  # injection log

    def __post_init__(self):
        if not self.devices:
            self.devices = {
                i: Device(i, self.topo.node_of(i)) for i in range(self.topo.n_devices)
            }

    # ------------------------------------------------------------ queries
    def speeds(self) -> dict:
        return {i: d.effective for i, d in self.devices.items()}

    def alive_ids(self) -> list:
        return [i for i, d in self.devices.items() if d.alive]

    def alive_mask(self):
        """Dense liveness vector over the device ids ``0..n-1`` (insertion
        order) for the vectorized heartbeat path — one bool per device."""
        import numpy as np

        return np.fromiter((d.alive for d in self.devices.values()),
                           dtype=np.bool_, count=len(self.devices))

    def node_devices(self, node: int) -> list:
        return [i for i, d in self.devices.items() if d.node == node]

    # ---------------------------------------------------------- injection
    def fail_stop(self, device_id: int, now: float = 0.0):
        self.devices[device_id].alive = False
        self.events.append((now, "fail-stop", device_id, 0.0))

    def fail_stop_node(self, node: int, now: float = 0.0):
        for d in self.node_devices(node):
            self.devices[d].alive = False
        self.events.append((now, "fail-stop-node", node, 0.0))

    def fail_slow(self, device_id: int, factor: float, now: float = 0.0):
        """factor = remaining fraction of peak (0.5 = half speed)."""
        self.devices[device_id].speed = float(factor)
        self.events.append((now, "fail-slow", device_id, factor))

    def degrade_network(self, node: int, factor: float, comm_share: float = 0.3,
                        now: float = 0.0):
        """Bandwidth contention on a node: the communication share of each
        device's step time stretches by 1/factor. Tracked separately from
        compute speed so clearing the contention restores exactly this
        component (a co-located compute straggler stays slow)."""
        eff = 1.0 / ((1.0 - comm_share) + comm_share / max(factor, 1e-9))
        for d in self.node_devices(node):
            self.devices[d].net_scale = min(self.devices[d].net_scale, eff)
        self.events.append((now, "net-degrade", node, factor))

    def restore_network(self, node: int, now: float = 0.0):
        """Link contention cleared: only the network component recovers —
        dead devices stay dead, compute fail-slows stay slow."""
        for d in self.node_devices(node):
            self.devices[d].net_scale = 1.0
        self.events.append((now, "net-restore", node, 1.0))

    def repair(self, device_id: int, now: float = 0.0, speed: float = 1.0):
        """Bring a device back; ``speed < 1.0`` models a degraded return
        (swapped-in older part, partially-recovered thermal state) — the
        case rejoin admission probing exists for."""
        dev = self.devices[device_id]
        dev.alive, dev.speed, dev.net_scale = True, float(speed), 1.0
        self.events.append((now, "repair", device_id, float(speed)))
