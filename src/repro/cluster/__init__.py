from repro.cluster.registry import ClusterState, ClusterTopology, Device  # noqa: F401
from repro.cluster.workload import WorkloadGen  # noqa: F401
from repro.cluster.simulator import TrainingSim, SimConfig  # noqa: F401
from repro.cluster.events import Event, EventTrace, apply_event  # noqa: F401
from repro.cluster import scenarios  # noqa: F401
