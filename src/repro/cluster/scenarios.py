"""Declarative failure scenarios: a composable DSL that compiles to a seeded,
deterministic :class:`~repro.cluster.events.EventTrace`.

A :class:`FailureScenario` describes *what goes wrong and when* independently
of any simulator instance. ``scenario.compile(topo, seed)`` produces the flat
event timeline; ``TrainingSim.apply_scenario`` feeds it through the single
``apply_events(t)`` hook. Scenarios compose with ``+`` (timelines merge in
time order) and every stochastic generator derives its RNG from
``(seed, scenario-name)`` so a sub-scenario compiles to the same events alone
or inside a composition.

Scenario catalog
----------------
Registered names (``scenarios.get(name, **overrides)``):

======================  ====================================================
name                    models / used by
======================  ====================================================
``fig9_failslow``       one compute fail-slow of tunable severity on a fixed
                        device (paper Fig. 9 weak/medium/severe sweep);
                        ``bench_fig9_failslow``
``fig10_mixed``         alternating fail-stop / medium fail-slow over
                        shuffled distinct devices (paper Fig. 10);
                        ``bench_fig10_mixed``
``fig11_mixed``         the 4-event mixed storm used for the component
                        ablation (paper Fig. 11); ``bench_fig11_ablation``
``fig14_largescale``    256-GPU recurring fail-stop + fail-slow with elastic
                        rejoins on a fixed fractional timeline (paper
                        Fig. 14); ``bench_fig14_largescale``
``table5_failslow``     zero or one fail-slow at a random time/device/
                        severity inside a detection window (paper Table 5
                        false-alarm study); ``bench_table5_false_alarms``
``table6_failstop``     monotonic worker terminations at fixed frequency,
                        capped at half the cluster (paper Table 6);
                        ``bench_table6_failstop``
``example_mixed``       the fixed 6-event mixed storm from
                        ``examples/cluster_failures.py``
``rack_storm``          correlated rack failure: every device of one or more
                        racks fail-stops in a staggered burst, with optional
                        recovery (ByteDance-style correlated infra faults);
                        ``bench_scenarios``
``rack_storm_256``      ``rack_storm`` preset at Fig. 14 scale: two racks
                        lost back-to-back, one rejoining later
``flapping_stragglers`` transient flaps — devices bounce between dead and
                        healthy (NIC resets, thermal throttle-recover
                        cycles) while another straggles; ``bench_scenarios``
``flap_then_recover``   a single device flaps repeatedly then stays healthy
``slow_ramp_mix``       slow-ramp stragglers: several devices degrade
                        gradually (step ramps) to different severities, some
                        recovering — the hardest case for change-point
                        detection; ``bench_scenarios``
``thermal_throttle_fleet`` many mild stragglers at once — a fleet fraction
                        throttles to 0.7–0.9x (thermal/power capping): the
                        shrink-shard (NTP) vs exclusion stress family;
                        ``bench_scenarios``
``poisson_storm``       memoryless background failure process with a
                        fail-stop/fail-slow mix and exponential repair times
                        (MTTF/MTTR fleet model); ``bench_scenarios``
``degraded_rejoins``    devices fail-stop and return *degraded* (reduced
                        speed): the rejoin-admission stress case —
                        lifecycle sweeps in ``bench_scenarios``
``aging_fleet``         per-device Weibull wear-out hazard (old fleet, a
                        lemon tail, imperfect repairs): failures concentrate
                        on the worn/lemon devices and recur —
                        the hazard-aware-policy stress case
                        (``bench_scenarios``)
``lemon_devices``       memoryless per-device hazard dominated by a small
                        lemon tail: a few bad parts fail again and again
                        while the rest of the fleet stays clean
``infant_mortality``    fresh fleet with a decreasing hazard (Weibull
                        k < 1): an early failure burst that quiets down
``pdu_brownout``        a browned-out PDU multiplies every resident
                        device's hazard rate (topology covariates):
                        failures concentrate in one power domain and recur
                        — the domain-aware-policy stress case
                        (``bench_scenarios``)
``switch_degrade``      correlated network degrade: every node under one
                        leaf switch sees link contention together, later
                        restored (flaky uplink); ``bench_scenarios``
``restart_storm``       a fleet fraction fail-stops in one tight burst and
                        mass-rejoins after a downtime, twice — the
                        job-restart regime where checkpoint/restart
                        economics beat live adaptation
                        (``bench_scenarios``)
======================  ====================================================
"""
from __future__ import annotations

import zlib
from dataclasses import dataclass, field
from typing import Callable, Iterable, Optional, Sequence

import numpy as np

from repro.cluster.events import Event, EventTrace, encode_rejoin_speed
from repro.cluster.hazard import HazardConfig, HazardModel, hazard_event_times
from repro.cluster.registry import ClusterTopology

__all__ = [
    "FailureScenario", "Compose", "FailStop", "FailSlow", "TransientFlap",
    "NetworkDegrade", "Rejoin", "MixedFailures", "RandomFailSlow",
    "ThermalThrottleFleet", "PoissonFailures", "CorrelatedRackStorm",
    "CorrelatedSwitchDegrade", "RestartStorm", "TimelineScenario",
    "HazardConfig", "register", "get", "names",
]


# ===================================================================== base
class FailureScenario:
    """Base class: subclasses emit events via :meth:`events`."""

    @property
    def name(self) -> str:
        return type(self).__name__

    def events(self, topo: ClusterTopology, rng: np.random.Generator
               ) -> Iterable[Event]:
        raise NotImplementedError

    def compile(self, topo: ClusterTopology, seed: int = 0) -> EventTrace:
        """Deterministic: same (topo, seed) => byte-identical timeline."""
        return EventTrace(self.events(topo, self._rng(seed)))

    def _rng(self, seed: int) -> np.random.Generator:
        # derive from (seed, name, params): composition does not perturb a
        # child's stream, and two same-class children with different
        # parameters draw independent streams (dataclass repr is stable)
        return np.random.default_rng([int(seed) & 0xFFFFFFFF,
                                      zlib.crc32(self.name.encode()),
                                      zlib.crc32(repr(self).encode())])

    def __add__(self, other: "FailureScenario") -> "Compose":
        return Compose([self, other])

    def _ev(self, t, kind, target=-1, value=0.0) -> Event:
        return Event(float(t), kind, int(target), float(value), self.name)


@dataclass
class Compose(FailureScenario):
    """Merge child timelines in time order; children compile independently."""
    children: Sequence[FailureScenario]

    def compile(self, topo: ClusterTopology, seed: int = 0) -> EventTrace:
        out = EventTrace()
        for c in self.children:
            out = out.merge(c.compile(topo, seed))
        return out

    def events(self, topo, rng):  # pragma: no cover - compile() is overridden
        raise RuntimeError("Compose compiles via children")

    def __add__(self, other: FailureScenario) -> "Compose":
        return Compose([*self.children, other])


# =============================================================== primitives
@dataclass
class FailStop(FailureScenario):
    """Terminate a device, a whole node, or a whole rack at time ``at``."""
    at: float
    device: Optional[int] = None
    node: Optional[int] = None
    rack: Optional[int] = None  # alias for node (rack == heartbeat domain)

    def events(self, topo, rng):
        node = self.node if self.node is not None else self.rack
        if (self.device is None) == (node is None):
            raise ValueError("FailStop needs exactly one of device / node|rack")
        if self.device is not None:
            yield self._ev(self.at, "fail-stop", self.device)
        else:
            yield self._ev(self.at, "fail-stop-node", node)


@dataclass
class FailSlow(FailureScenario):
    """Degrade a device to ``severity`` x peak at ``at``; optionally ramp the
    degradation in steps over ``ramp`` seconds (thermal-throttle model) and
    recover after ``duration`` seconds."""
    device: int
    severity: float
    at: float
    duration: Optional[float] = None
    ramp: float = 0.0
    ramp_steps: int = 4

    def events(self, topo, rng):
        if self.ramp > 0.0 and self.ramp_steps > 1:
            for i in range(1, self.ramp_steps + 1):
                frac = i / self.ramp_steps
                speed = 1.0 + (self.severity - 1.0) * frac
                t = self.at + self.ramp * (i - 1) / self.ramp_steps
                yield self._ev(t, "fail-slow", self.device, speed)
        else:
            yield self._ev(self.at, "fail-slow", self.device, self.severity)
        if self.duration is not None:
            yield self._ev(self.at + self.duration, "rejoin", self.device)


@dataclass
class TransientFlap(FailureScenario):
    """A device bounces: dead for ``down_time``, healthy for ``up_time``,
    ``n_flaps`` times (NIC reset / kernel-driver hiccup model).
    ``recover_speed < 1.0`` makes every bounce-back degraded (the part is
    going bad) — the rejoin-admission stress case."""
    device: int
    at: float
    n_flaps: int = 3
    down_time: float = 4.0
    up_time: float = 10.0
    recover_speed: float = 1.0

    def events(self, topo, rng):
        t = self.at
        v = encode_rejoin_speed(self.recover_speed)
        for _ in range(self.n_flaps):
            yield self._ev(t, "fail-stop", self.device)
            yield self._ev(t + self.down_time, "rejoin", self.device, v)
            t += self.down_time + self.up_time


@dataclass
class NetworkDegrade(FailureScenario):
    """Bandwidth contention on a node's links: communication share of each
    resident device stretches by 1/``link_scale``; after ``duration`` the
    contention clears (network component only — co-located fail-stop/
    fail-slow victims are untouched)."""
    node: int
    link_scale: float
    at: float
    duration: Optional[float] = None

    def events(self, topo, rng):
        yield self._ev(self.at, "net-degrade", self.node, self.link_scale)
        if self.duration is not None:
            yield self._ev(self.at + self.duration, "net-restore", self.node)


@dataclass
class Rejoin(FailureScenario):
    """Repair a device and announce it to the system (elastic rejoin,
    ElasWave-style); ``speed < 1.0`` = the device returns degraded."""
    device: int
    at: float
    speed: float = 1.0

    def events(self, topo, rng):
        yield self._ev(self.at, "rejoin", self.device,
                       encode_rejoin_speed(self.speed))


# ======================================================= stochastic storms
@dataclass
class MixedFailures(FailureScenario):
    """``n_events`` alternating fail-stop / fail-slow hits on shuffled
    distinct devices, evenly spread over ``span`` (Fig. 10/11 storm)."""
    span: float
    n_events: int = 6
    severity: float = 0.45
    start: str = "stop"  # which kind goes first

    def events(self, topo, rng):
        devices = rng.permutation(topo.n_devices)
        first_stop = self.start == "stop"
        for i in range(self.n_events):
            t = self.span * (i + 1) / (self.n_events + 1)
            d = int(devices[i])
            if (i % 2 == 0) == first_stop:
                yield self._ev(t, "fail-stop", d)
            else:
                yield self._ev(t, "fail-slow", d, self.severity)


@dataclass
class MonotonicFailStops(FailureScenario):
    """``n_failures`` permanent worker terminations at fixed frequency over
    ``span``, never beyond half the cluster (Table 6 protocol)."""
    span: float
    n_failures: int

    def events(self, topo, rng):
        devices = rng.permutation(topo.n_devices)
        victims = devices[: min(self.n_failures, topo.n_devices // 2)]
        for i, d in enumerate(victims):
            t = self.span * (i + 1) / (len(victims) + 1)
            yield self._ev(t, "fail-stop", int(d))


@dataclass
class RandomFailSlow(FailureScenario):
    """One fail-slow at a random time inside ``window``, random device,
    severity drawn from ``severities`` (Table 5 injection protocol)."""
    window: tuple
    severities: tuple = (0.3, 0.45, 0.6)

    def events(self, topo, rng):
        lo, hi = self.window
        t = float(rng.uniform(lo, max(hi, lo + 1e-9)))
        d = int(rng.integers(0, topo.n_devices))
        sev = float(rng.choice(list(self.severities)))
        yield self._ev(t, "fail-slow", d, sev)


@dataclass
class ThermalThrottleFleet(FailureScenario):
    """Many *mild* stragglers at once: a ``frac`` share of the fleet throttles
    to a severity drawn from ``severity`` (0.7–0.9 = thermal/power capping,
    not hardware faults), at staggered times inside ``window * span``.

    The stress case for the adaptation axis choice: every affected TP group
    keeps running, so exclusion-style planning either drags the whole group
    to the straggler's rate (k * min p) or throws a barely-degraded device
    away — while shrink-shard (NTP widths ∝ p_i) recovers ~sum(p_i) per
    group. With ``recover_after`` set, devices cool down and return to full
    speed (a second ramp of replans back to uniform widths)."""
    span: float
    frac: float = 0.3
    severity: tuple = (0.7, 0.9)
    window: tuple = (0.08, 0.55)
    recover_after: Optional[float] = None  # seconds of throttling, if any

    def events(self, topo, rng):
        n = max(1, int(round(self.frac * topo.n_devices)))
        devices = rng.permutation(topo.n_devices)[:n]
        lo, hi = self.window
        times = rng.uniform(lo * self.span, hi * self.span, size=n)
        sevs = rng.uniform(self.severity[0], self.severity[1], size=n)
        for i in range(n):
            d = int(devices[i])
            yield self._ev(float(times[i]), "fail-slow", d, float(sevs[i]))
            if self.recover_after is not None:
                yield self._ev(float(times[i]) + self.recover_after,
                               "rejoin", d)


@dataclass
class PoissonFailures(FailureScenario):
    """Memoryless background failure process: exponential inter-arrivals at
    ``rate`` events per second over [``t_start``, ``t_end``), each event
    fail-stop with probability ``mix`` else fail-slow with severity drawn
    uniformly from ``severity``; repaired (elastic rejoin) after an
    exponential repair time of mean ``mttr`` when set.

    Two victim-selection modes:

    * **distinct-device** (``renewal=False``, the default) — victims come
      from a seeded permutation and each device is hit at most once per
      compiled timeline. This matches the paper's §8.1 injection protocol
      (a bounded number of distinct faults per session) but understates
      long sessions, where nothing stops a repaired GPU from failing again.
    * **renewal process** (``renewal=True``) — a device that has completed
      its exponential repair (``mttr``) re-enters the victim pool, so the
      same device can fail, rejoin and fail again, approximating a
      per-device MTTF/MTTR renewal process (the fleet model in the
      ByteDance-scale reliability literature). Without ``mttr`` there are
      no repairs, so the two modes emit identical event kinds.

    Both modes are deterministic for a fixed (topology, seed).

    ``hazard=HazardConfig(...)`` (default **off**: the behaviour above is
    byte-identical to every pre-hazard release) replaces the global-rate
    victim pool with per-device age-dependent hazard processes
    (:class:`~repro.cluster.hazard.HazardModel`): inter-arrival times and
    victim identity both come from the fleet's competing Weibull renewals,
    so failures concentrate on old/lemon/worn devices and — with
    ``renewal=True`` — recur on them. ``rate`` is ignored in hazard mode
    (the per-device scales set the intensity); ``mix``/``severity``/
    ``mttr``/``max_events``/``renewal`` keep their meanings.
    """
    rate: float
    t_end: float
    t_start: float = 0.0
    mix: float = 0.5  # P(fail-stop); 1-mix => fail-slow
    severity: tuple = (0.3, 0.6)
    mttr: Optional[float] = None
    max_events: int = 64
    renewal: bool = False
    hazard: Optional[HazardConfig] = None

    def __repr__(self):
        # the derived-RNG stream key is crc32(repr(self)): with ``hazard``
        # unset the repr must stay byte-identical to the pre-hazard
        # dataclass repr, or every existing PoissonFailures timeline would
        # silently recompile differently across releases. A set ``hazard``
        # appends itself, so distinct hazard configs keep distinct streams.
        s = (f"PoissonFailures(rate={self.rate!r}, t_end={self.t_end!r}, "
             f"t_start={self.t_start!r}, mix={self.mix!r}, "
             f"severity={self.severity!r}, mttr={self.mttr!r}, "
             f"max_events={self.max_events!r}, renewal={self.renewal!r}")
        if self.hazard is not None:
            s += f", hazard={self.hazard!r}"
        return s + ")"

    def events(self, topo, rng):
        if self.hazard is not None:
            yield from self._hazard_events(topo, rng)
            return
        t, emitted = self.t_start, 0
        pool = list(rng.permutation(topo.n_devices))
        down: list = []  # (repair-complete time, device) — renewal mode
        while emitted < self.max_events:
            t += float(rng.exponential(1.0 / max(self.rate, 1e-12)))
            if t >= self.t_end:
                break
            if self.renewal and down:
                # repaired devices rejoin the victim pool (renewal process)
                back = sorted(e for e in down if e[0] <= t)
                if back:
                    down = [e for e in down if e[0] > t]
                    pool.extend(d for _, d in back)
            if not pool:
                if self.renewal and down:
                    continue  # everything is mid-repair; arrival hits nothing
                break  # distinct devices exhausted: no double-kill
            d = int(pool.pop(0))
            if float(rng.uniform()) < self.mix:
                yield self._ev(t, "fail-stop", d)
            else:
                sev = float(rng.uniform(*self.severity))
                yield self._ev(t, "fail-slow", d, sev)
            if self.mttr is not None:
                dt = float(rng.exponential(self.mttr))
                yield self._ev(t + dt, "rejoin", d)
                if self.renewal:
                    down.append((t + dt, d))
            emitted += 1

    def _hazard_events(self, topo, rng):
        """Per-device hazard mode: the fleet's competing Weibull renewal
        processes pick both the times and the victims. Draw order is fixed
        (model init, then event times in firing order, then per-event
        kind/severity), so compilation stays byte-deterministic."""
        model = HazardModel(self.hazard, topo.n_devices, rng, topo=topo)
        fails = hazard_event_times(
            model, rng, t_start=self.t_start, t_end=self.t_end,
            mttr=self.mttr, renewal=self.renewal, max_events=self.max_events)
        for t, d, t_rep in fails:
            if float(rng.uniform()) < self.mix:
                yield self._ev(t, "fail-stop", d)
            else:
                sev = float(rng.uniform(*self.severity))
                yield self._ev(t, "fail-slow", d, sev)
            if t_rep is not None:
                yield self._ev(t_rep, "rejoin", d)


@dataclass
class CorrelatedRackStorm(FailureScenario):
    """Correlated infrastructure fault: every device of ``n_racks`` racks
    (random distinct racks unless ``racks`` pins them) fails in a staggered
    burst — PDU/ToR-switch loss takes out co-located devices together.
    ``kind`` picks fail-stop or fail-slow; ``recover_after`` rejoins the
    whole rack (power restored)."""
    at: float
    n_racks: int = 1
    racks: Optional[Sequence[int]] = None
    kind: str = "fail-stop"
    severity: float = 0.4  # only for kind == "fail-slow"
    stagger: float = 0.5
    recover_after: Optional[float] = None

    def events(self, topo, rng):
        racks = (list(self.racks) if self.racks is not None
                 else [int(r) for r in
                       rng.permutation(topo.n_nodes)[: self.n_racks]])
        for r in racks:
            devs = [d for d in range(topo.n_devices) if topo.node_of(d) == r]
            for j, d in enumerate(devs):
                t = self.at + j * self.stagger
                if self.kind == "fail-stop":
                    yield self._ev(t, "fail-stop", d)
                else:
                    yield self._ev(t, "fail-slow", d, self.severity)
                if self.recover_after is not None:
                    yield self._ev(self.at + self.recover_after + j * self.stagger,
                                   "rejoin", d)


@dataclass
class CorrelatedSwitchDegrade(FailureScenario):
    """Correlated network fault: every node under ``n_switches`` leaf
    switches (random distinct switches unless ``switches`` pins them —
    domain map: ``ClusterTopology.nodes_per_switch``) sees link contention
    together in a staggered onset — the flaky-uplink signature where a
    whole switch domain degrades at once rather than one node at a time.
    ``recover_after`` clears the contention (uplink failed over)."""
    at: float
    n_switches: int = 1
    switches: Optional[Sequence[int]] = None
    link_scale: float = 0.35
    stagger: float = 0.5
    recover_after: Optional[float] = None

    def events(self, topo, rng):
        sws = (list(self.switches) if self.switches is not None
               else [int(s) for s in
                     rng.permutation(topo.n_switches)[: self.n_switches]])
        for s in sws:
            for j, node in enumerate(topo.domain_nodes("switch", s)):
                t = self.at + j * self.stagger
                yield self._ev(t, "net-degrade", node, self.link_scale)
                if self.recover_after is not None:
                    yield self._ev(
                        self.at + self.recover_after + j * self.stagger,
                        "net-restore", node)


@dataclass
class RestartStorm(FailureScenario):
    """Job-restart storm: a seeded ``frac`` fraction of the fleet
    fail-stops in one tight staggered burst (the mass-exit signature of a
    job-level restart or a rolling infra intervention) and mass-rejoins
    ``downtime`` later — optionally repeating every ``period`` seconds for
    ``n_storms`` rounds. The scenario where restart-from-checkpoint
    economics matter: adaptation churns through a cliff of simultaneous
    losses that a checkpoint restore would absorb in one charge."""
    at: float
    frac: float = 0.25
    downtime: float = 10.0
    stagger: float = 0.25
    n_storms: int = 1
    period: float = 60.0

    def events(self, topo, rng):
        for k in range(self.n_storms):
            t0 = self.at + k * self.period
            n = max(1, int(round(self.frac * topo.n_devices)))
            victims = sorted(int(d) for d in
                             rng.permutation(topo.n_devices)[:n])
            for j, d in enumerate(victims):
                yield self._ev(t0 + j * self.stagger, "fail-stop", d)
                yield self._ev(t0 + self.downtime + j * self.stagger,
                               "rejoin", d)


@dataclass
class TimelineScenario(FailureScenario):
    """Fixed fractional timeline scaled by ``span``: entries are
    ``(frac, kind, target[, value])`` with targets as indices into a seeded
    device permutation when ``permute`` (Fig. 14 protocol) or literal device
    ids otherwise."""
    span: float
    timeline: Sequence[tuple]
    permute: bool = True
    label: str = "TimelineScenario"

    @property
    def name(self) -> str:
        return self.label

    def events(self, topo, rng):
        devs = list(rng.permutation(topo.n_devices)) if self.permute else None
        for entry in self.timeline:
            frac, kind = entry[0], entry[1]
            target = int(devs[entry[2]]) if devs is not None else int(entry[2])
            value = float(entry[3]) if len(entry) > 3 else 0.0
            yield self._ev(frac * self.span, kind, target, value)


# ================================================================= registry
_REGISTRY: dict = {}


def register(name: str) -> Callable:
    def deco(factory: Callable) -> Callable:
        _REGISTRY[name] = factory
        return factory
    return deco


def get(name: str, **overrides) -> FailureScenario:
    """Instantiate a named scenario; ``overrides`` go to its factory."""
    if name not in _REGISTRY:
        raise KeyError(f"unknown scenario {name!r}; known: {names()}")
    return _REGISTRY[name](**overrides)


def names() -> list:
    return sorted(_REGISTRY)


# ------------------------------------------------- paper-figure scenarios
@register("fig9_failslow")
def _fig9(device: int = 5, factor: float = 0.42, at: float = 12.0,
          **kw) -> FailureScenario:
    return FailSlow(device=device, severity=factor, at=at, **kw)


@register("fig10_mixed")
def _fig10(span: float = 240.0, n_events: int = 6, severity: float = 0.45,
           ) -> FailureScenario:
    return MixedFailures(span=span, n_events=n_events, severity=severity)


@register("fig11_mixed")
def _fig11(span: float = 200.0, severity: float = 0.45) -> FailureScenario:
    return MixedFailures(span=span, n_events=4, severity=severity)


_FIG14_TIMELINE = (
    (0.10, "fail-stop", 0),
    (0.22, "fail-slow", 1, 0.45),
    (0.34, "fail-stop", 2),
    (0.45, "rejoin", 0),
    (0.55, "fail-slow", 3, 0.3),
    (0.66, "fail-stop", 4),
    (0.75, "rejoin", 2),
    (0.85, "fail-slow", 5, 0.55),
)


@register("fig14_largescale")
def _fig14(span: float = 192.0) -> FailureScenario:
    return TimelineScenario(span=span, timeline=_FIG14_TIMELINE,
                            label="fig14_largescale")


@register("table5_failslow")
def _table5(window: tuple = (30.0, 60.0),
            severities: tuple = (0.3, 0.45, 0.6)) -> FailureScenario:
    return RandomFailSlow(window=window, severities=severities)


@register("table6_failstop")
def _table6(span: float = 320.0, n_failures: int = 8) -> FailureScenario:
    return MonotonicFailStops(span=span, n_failures=n_failures)


_EXAMPLE_TIMELINE = (
    (15.0, "fail-stop", 37),
    (35.0, "fail-slow", 101, 0.45),
    (55.0, "fail-stop", 5),
    (75.0, "fail-slow", 182, 0.3),
    (95.0, "fail-stop", 201),
    (115.0, "fail-slow", 66, 0.5),
)


@register("example_mixed")
def _example(span: float = 1.0) -> FailureScenario:
    # literal device ids, absolute times (span=1): the quickstart storm
    return TimelineScenario(span=span, timeline=_EXAMPLE_TIMELINE,
                            permute=False, label="example_mixed")


# --------------------------------------------- new scenario families (PR 1)
@register("rack_storm")
def _rack_storm(at: float = 20.0, n_racks: int = 1, stagger: float = 0.5,
                recover_after: Optional[float] = None) -> FailureScenario:
    return CorrelatedRackStorm(at=at, n_racks=n_racks, stagger=stagger,
                               recover_after=recover_after)


@register("rack_storm_256")
def _rack_storm_256(span: float = 160.0) -> FailureScenario:
    # two racks lost back-to-back; the first comes back (power restored)
    return (CorrelatedRackStorm(at=0.15 * span, racks=[1], stagger=0.25,
                                recover_after=0.45 * span)
            + CorrelatedRackStorm(at=0.35 * span, racks=[5], stagger=0.25))


@register("flap_then_recover")
def _flap_then_recover(device: int = 5, at: float = 15.0, n_flaps: int = 3,
                       down_time: float = 4.0, up_time: float = 12.0,
                       ) -> FailureScenario:
    return TransientFlap(device=device, at=at, n_flaps=n_flaps,
                         down_time=down_time, up_time=up_time)


@register("flapping_stragglers")
def _flapping_stragglers(span: float = 160.0,
                         devices: Sequence[int] = (3, 12, 7)
                         ) -> FailureScenario:
    # two flappers in different racks plus one persistent mid straggler;
    # `devices` lets small-topology harnesses (engine parity at 8 devices)
    # keep the victims in range now that apply_scenario validates targets
    return Compose([
        TransientFlap(device=devices[0], at=0.10 * span, n_flaps=3,
                      down_time=0.02 * span, up_time=0.08 * span),
        TransientFlap(device=devices[1], at=0.30 * span, n_flaps=2,
                      down_time=0.03 * span, up_time=0.10 * span),
        FailSlow(device=devices[2], severity=0.55, at=0.55 * span),
    ])


@register("slow_ramp_mix")
def _slow_ramp_mix(span: float = 160.0,
                   devices: Sequence[int] = (2, 9, 14)) -> FailureScenario:
    # gradual degradations of different depths; the shallow one recovers
    # (`devices` override: see flapping_stragglers)
    return Compose([
        FailSlow(device=devices[0], severity=0.7, at=0.10 * span,
                 ramp=0.15 * span, ramp_steps=4, duration=0.45 * span),
        FailSlow(device=devices[1], severity=0.45, at=0.35 * span,
                 ramp=0.20 * span, ramp_steps=5),
        FailSlow(device=devices[2], severity=0.3, at=0.65 * span,
                 ramp=0.10 * span, ramp_steps=3),
    ])


@register("degraded_rejoins")
def _degraded_rejoins(span: float = 160.0,
                      recover_speed: float = 0.6) -> FailureScenario:
    # devices die and come back *degraded*: the belief gap rejoin admission
    # closes — without a probe the system schedules them as full-health
    return Compose([
        FailStop(at=0.10 * span, device=2),
        Rejoin(device=2, at=0.30 * span, speed=recover_speed),
        FailStop(at=0.45 * span, device=11),
        Rejoin(device=11, at=0.60 * span, speed=recover_speed),
    ])


@register("thermal_throttle_fleet")
def _thermal_throttle_fleet(span: float = 160.0, frac: float = 0.3,
                            severity: tuple = (0.7, 0.9),
                            recover_after: Optional[float] = None,
                            ) -> FailureScenario:
    # many mild stragglers at once (fleet-wide thermal/power capping): the
    # scenario family where shrink-shard (NTP) should dominate exclusion
    return ThermalThrottleFleet(span=span, frac=frac, severity=severity,
                                recover_after=recover_after)


@register("poisson_storm")
def _poisson_storm(rate: float = 0.05, t_end: float = 160.0, mix: float = 0.5,
                   mttr: Optional[float] = 40.0,
                   renewal: bool = False) -> FailureScenario:
    return PoissonFailures(rate=rate, t_end=t_end, mix=mix, mttr=mttr,
                           renewal=renewal)


# ------------------------------------------- per-device hazard families (PR 4)
@register("aging_fleet")
def _aging_fleet(span: float = 160.0, mix: float = 0.1,
                 max_events: int = 64) -> FailureScenario:
    # worn fleet (Weibull k=3, ages spread over 2 spans) with a lemon tail
    # and imperfect repairs: failures recur on the same few bad devices for
    # the whole span — the hazard-aware quarantine/placement stress case.
    # Mostly fail-slow (mix=0.1, the wear-out signature: thermal throttling
    # and ECC-retirement slowdowns, not crashes), so the fail-stop flap
    # counter is blind to the repeats while the hazard estimator is not.
    return PoissonFailures(
        rate=0.0, t_end=span, mix=mix, mttr=0.06 * span, renewal=True,
        max_events=max_events, severity=(0.25, 0.5),
        hazard=HazardConfig(mttf_s=6.0 * span, shape=3.0,
                            age_spread_s=2.0 * span, lemon_frac=0.08,
                            lemon_factor=10.0, wear_per_repair=1.5))


@register("lemon_devices")
def _lemon_devices(span: float = 160.0, lemon_frac: float = 0.08,
                   max_events: int = 24) -> FailureScenario:
    # memoryless per-device hazard dominated by a small lemon tail: a few
    # bad parts fail over and over while the rest of the fleet stays clean
    return PoissonFailures(
        rate=0.0, t_end=span, mix=0.5, mttr=0.08 * span, renewal=True,
        max_events=max_events,
        hazard=HazardConfig(mttf_s=10.0 * span, shape=1.0,
                            lemon_frac=lemon_frac, lemon_factor=60.0))


@register("infant_mortality")
def _infant_mortality(span: float = 160.0,
                      max_events: int = 16) -> FailureScenario:
    # fresh fleet, decreasing hazard (Weibull k<1): an early burn-in burst
    # that quiets down as survivors age past their infancy
    return PoissonFailures(
        rate=0.0, t_end=span, mix=0.5, mttr=0.10 * span, renewal=True,
        max_events=max_events,
        hazard=HazardConfig(mttf_s=8.0 * span, shape=0.6))


# --------------------------------- correlated-domain families (this PR)
@register("pdu_brownout")
def _pdu_brownout(span: float = 160.0, mix: float = 0.7,
                  max_events: int = 64, bad_frac: float = 0.05,
                  factor: float = 64.0) -> FailureScenario:
    # a seeded PDU domain goes bad (``bad_frac`` is a fraction of domains
    # with an at-least-one guarantee, so small fleets get exactly one hot
    # rack): every resident device's memoryless hazard rate is multiplied
    # by ``factor``, so failures concentrate inside the browned-out rack
    # and — with renewal repairs — recur there. Mostly fail-stop (mix=0.7,
    # the power-domain signature) over a *thin* healthy-fleet background
    # (mttf 16 spans — pooled domain detection lives or dies on the
    # contrast between rack rate and background rate, not on raw counts).
    # The pooled DomainEstimator should bench the rack after two distinct
    # resident failures, before its third device dies; repairs land in
    # ~0.1 spans, long enough for the heartbeat to see every death.
    return PoissonFailures(
        rate=0.0, t_end=span, mix=mix, mttr=0.10 * span, renewal=True,
        max_events=max_events, severity=(0.3, 0.55),
        hazard=HazardConfig(mttf_s=16.0 * span, shape=1.0,
                            bad_domain_frac=bad_frac,
                            bad_domain_factor=factor, domain="pdu"))


@register("switch_degrade")
def _switch_degrade(span: float = 160.0, link_scale: float = 0.35,
                    n_switches: int = 1) -> FailureScenario:
    # a flaky leaf-switch uplink: every node under the switch degrades
    # together in a staggered onset, restored after the failover
    return CorrelatedSwitchDegrade(at=0.15 * span, n_switches=n_switches,
                                   link_scale=link_scale,
                                   stagger=0.01 * span,
                                   recover_after=0.45 * span)


@register("restart_storm")
def _restart_storm(span: float = 160.0, frac: float = 0.25,
                   n_storms: int = 2) -> FailureScenario:
    # two job-restart storms: a quarter of the fleet mass-exits and
    # mass-rejoins after a downtime, then it happens again
    return RestartStorm(at=0.15 * span, frac=frac,
                        downtime=0.06 * span, stagger=0.002 * span,
                        n_storms=n_storms, period=0.30 * span)


# ================================================== mined adversarial family
@dataclass
class AdversarialScenario(FailureScenario):
    """A mined worst-case timeline (``tools/mine_scenarios.py``).

    The timeline is literal ``(t, kind, target, value)`` events discovered by
    the coverage-guided search in :mod:`repro.cluster.mining` at the 256-device
    mining scale. On the mining topology with the mined span it replays
    verbatim; on any other topology (or with ``span`` overridden) the events
    are rescaled in time and routed through
    :func:`repro.cluster.mining.repair_timeline`, which remaps victims
    (device/node ids mod the topology size) and drops whatever the remap made
    contradictory — so the same mined pattern replays, valid, at any scale
    (the engine-parity tests run it on an 8-device config)."""

    timeline: Sequence[tuple]
    mined_span: float
    span: Optional[float] = None
    label: str = "adversarial"

    @property
    def name(self) -> str:
        return self.label

    def events(self, topo: ClusterTopology, rng: np.random.Generator
               ) -> Iterable[Event]:
        from repro.cluster.mining import repair_timeline
        span = self.span if self.span is not None else self.mined_span
        scale = span / self.mined_span
        raw = [(t * scale, kind, target, value)
               for t, kind, target, value in self.timeline]
        for t, kind, target, value in repair_timeline(raw, topo, span):
            yield self._ev(t, kind, target, value)


# Mined by the fixed quick recipe (see tools/mine_scenarios.py QUICK);
# regenerate with `PYTHONPATH=src python tools/mine_scenarios.py --quick`
# and keep in lockstep with results/adversarial_mined.json — the nightly
# --check smoke and tests/test_adversarial_golden.py pin both sides.
# The three members cover the search objectives: best combined score,
# deepest raw resihp session-throughput loss, widest policy-ranking flip.
_ADVERSARIAL_SPAN = 7.36203  # the quick recipe's mining span (seconds)
_ADVERSARIAL = {
    # objective: score | lineage g12.0<-g9.0<-g7.7<-seed:infant_mortality
    # resihp session 18.397508441 (loss 0.7650, flip margin 0.4501)
    "adversarial_1": (
        (0.003112, "fail-slow", 161, 0.506201),
        (0.004003, "fail-stop", 39, 0.0),
        (0.004042, "fail-stop", 73, 0.0),
        (0.016146, "fail-slow", 143, 0.493315),
        (0.018257, "fail-stop", 217, 0.0),
        (0.034974, "fail-slow", 82, 0.363836),
        (0.097138, "fail-stop", 20, 0.0),
        (0.101375, "fail-stop", 130, 0.0),
        (0.101584, "fail-stop", 173, 0.0),
        (0.124755, "rejoin", 161, 0.0),
        (0.142091, "rejoin", 39, 0.0),
        (0.145432, "fail-stop", 1, 0.0),
        (0.156805, "fail-slow", 32, 0.371525),
        (0.165517, "fail-stop", 124, 0.0),
        (0.183462, "rejoin", 173, 0.0),
        (0.209743, "fail-stop", 21, 0.0),
        (0.315755, "fail-stop", 109, 0.0),
        (0.37975, "rejoin", 143, 0.0),
        (0.398323, "fail-slow", 121, 0.551146),
        (0.428592, "fail-stop", 185, 0.0),
        (0.480845, "rejoin", 121, 0.0),
        (0.496868, "fail-stop-node", 9, 0.0),
        (0.497922, "rejoin", 124, 0.0),
        (0.572879, "rejoin", 130, 0.841926),
        (0.696512, "rejoin", 217, 0.0),
        (0.892934, "rejoin", 185, 0.0),
        (1.090436, "rejoin", 20, 0.0),
        (1.125776, "rejoin", 1, 0.0),
        (1.144623, "rejoin", 32, 0.0),
        (1.429767, "rejoin", 109, 0.0),
        (1.924609, "rejoin", 82, 0.0),
        (2.119534, "rejoin", 21, 0.0),
        (2.164811, "fail-stop-node", 27, 0.0),
        (5.190934, "fail-slow", 208, 0.24532),
    ),
    # objective: resihp_loss | lineage g7.7<-seed:infant_mortality
    # resihp session 14.570841462 (loss 0.8139, flip margin 0.1438)
    "adversarial_2": (
        (0.002702, "fail-slow", 114, 0.506201),
        (0.003475, "fail-stop", 248, 0.0),
        (0.003509, "fail-stop", 26, 0.0),
        (0.014018, "fail-slow", 96, 0.493315),
        (0.015851, "fail-stop", 170, 0.0),
        (0.030365, "fail-slow", 35, 0.363836),
        (0.084336, "fail-stop", 229, 0.0),
        (0.088014, "fail-stop", 83, 0.0),
        (0.088196, "fail-stop", 126, 0.0),
        (0.108313, "rejoin", 114, 0.0),
        (0.123364, "rejoin", 248, 0.0),
        (0.126265, "fail-stop", 210, 0.0),
        (0.136139, "fail-slow", 241, 0.371525),
        (0.143703, "fail-stop", 77, 0.0),
        (0.159283, "rejoin", 126, 0.0),
        (0.1821, "fail-stop", 230, 0.0),
        (0.27414, "fail-stop", 62, 0.0),
        (0.329701, "rejoin", 96, 0.0),
        (0.345826, "fail-slow", 74, 0.551146),
        (0.372106, "fail-stop", 138, 0.0),
        (0.417472, "rejoin", 74, 0.0),
        (0.432299, "rejoin", 77, 0.0),
        (0.497377, "rejoin", 83, 0.841926),
        (0.604716, "rejoin", 170, 0.0),
        (0.77525, "rejoin", 138, 0.0),
        (0.946723, "rejoin", 229, 0.0),
        (0.977405, "rejoin", 210, 0.0),
        (0.993768, "rejoin", 241, 0.0),
        (1.241332, "rejoin", 62, 0.0),
        (1.670956, "rejoin", 35, 0.0),
        (1.840191, "rejoin", 230, 0.0),
        (1.879501, "fail-stop-node", 12, 0.0),
        (4.506798, "fail-slow", 161, 0.24532),
        (5.394335, "rejoin", 26, 0.0),
    ),
    # objective: flip_margin | lineage g9.0<-g7.7<-seed:infant_mortality
    # resihp session 24.206351095 (loss 0.6908, flip margin 0.3995)
    "adversarial_3": (
        (0.002702, "fail-slow", 114, 0.506201),
        (0.003475, "fail-stop", 248, 0.0),
        (0.003509, "fail-stop", 26, 0.0),
        (0.014018, "fail-slow", 96, 0.493315),
        (0.015851, "fail-stop", 170, 0.0),
        (0.030365, "fail-slow", 35, 0.363836),
        (0.084336, "fail-stop", 229, 0.0),
        (0.088014, "fail-stop", 83, 0.0),
        (0.088196, "fail-stop", 126, 0.0),
        (0.108313, "rejoin", 114, 0.0),
        (0.123364, "rejoin", 248, 0.0),
        (0.126265, "fail-stop", 210, 0.0),
        (0.136139, "fail-slow", 241, 0.371525),
        (0.143703, "fail-stop", 77, 0.0),
        (0.159283, "rejoin", 126, 0.0),
        (0.1821, "fail-stop", 230, 0.0),
        (0.27414, "fail-stop", 62, 0.0),
        (0.329701, "rejoin", 96, 0.0),
        (0.345826, "fail-slow", 74, 0.551146),
        (0.372106, "fail-stop", 138, 0.0),
        (0.417472, "rejoin", 74, 0.0),
        (0.432299, "rejoin", 77, 0.0),
        (0.497377, "rejoin", 83, 0.841926),
        (0.604716, "rejoin", 170, 0.0),
        (0.77525, "rejoin", 138, 0.0),
        (0.946723, "rejoin", 229, 0.0),
        (0.977405, "rejoin", 210, 0.0),
        (0.993768, "rejoin", 241, 0.0),
        (1.241332, "rejoin", 62, 0.0),
        (1.670956, "rejoin", 35, 0.0),
        (1.840191, "rejoin", 230, 0.0),
        (1.879501, "fail-stop-node", 12, 0.0),
        (4.506798, "fail-slow", 161, 0.24532),
    ),
}


def _register_adversarial(name: str) -> None:
    @register(name)
    def _factory(span: Optional[float] = None) -> FailureScenario:
        return AdversarialScenario(timeline=_ADVERSARIAL[name],
                                   mined_span=_ADVERSARIAL_SPAN,
                                   span=span, label=name)


for _name in sorted(_ADVERSARIAL):
    _register_adversarial(_name)
del _name
