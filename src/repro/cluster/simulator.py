"""Discrete-event cluster training simulator (the §8 evaluation harness).

Executes a training job iteration by iteration at cluster scale with the
*actual* system code in the loop:

  * ground-truth chunk times come from the Eq. 1 functional form with the
    per-iteration packed workload (real packing of lognormal documents) and
    the injected true device speeds;
  * pipeline execution (with cross-DP migration) is simulated by
    ProgressAwareMigrator — the same engine the Scheduler ships;
  * the real Detector consumes the observed iteration-time series and the
    real heartbeat hierarchy; its reports drive the real policy/Scheduler;
  * reconfiguration costs (planning, group rebuild, layer transfer) are
    charged per Fig. 13.

The per-iteration trace (time, throughput, events) reproduces Table 6,
Fig. 9, Fig. 10, Fig. 11 and the Fig. 14 large-scale run.
"""
from __future__ import annotations

import heapq
import math
from dataclasses import dataclass, field
from typing import Callable, Optional

import numpy as np

from repro.cluster.baselines import BasePolicy, PolicyDecision, make_policy
from repro.cluster.events import Event, apply_event
from repro.cluster.fastsim import (FastHeartbeat, FastMigrator,
                                   StageSpeedCache, make_cost_table)
from repro.cluster.hazard import DomainEstimator, HazardEstimator
from repro.cluster.registry import ClusterState, ClusterTopology
from repro.cluster.workload import WorkloadGen
from repro.core.detector.changepoint import CusumDetector, SlopeDriftDetector
from repro.core.detector.credit import CreditModel
from repro.core.detector.detector import Detector
from repro.core.detector.heartbeat import HeartbeatMonitor
from repro.core.detector.lifecycle import LifecycleManager
from repro.core.detector.predictor import MicroBatchTimePredictor
from repro.core.detector.dag_sim import ChunkId
from repro.core.scheduler.migration import ProgressAwareMigrator
from repro.core.scheduler.plan import NTP_EFFICIENCY, initial_plan


@dataclass
class SimConfig:
    dp: int = 2
    pp: int = 4
    tp: int = 4
    n_layers: int = 40
    n_microbatches: int = 8  # per replica
    seq_len: int = 8192
    rows_per_microbatch: int = 1
    schedule: str = "1f1b"
    # ground-truth per-layer chunk-time coefficients (seconds)
    alpha: float = 2.0e-7  # per token per layer
    beta: float = 1.2e-11  # per (token^2) per layer
    gamma: float = 1.0e-4  # fixed per-chunk per-layer overhead
    b_ratio: float = 2.0
    w_ratio: float = 1.0
    noise: float = 0.01  # multiplicative jitter on true chunk times
    p2p_cost: float = 2.0e-4
    migrate_edge_cost: float = 2.0e-3
    devices_per_node: int = 8
    # correlated failure domains (ClusterTopology defaults: PDU == rack,
    # two racks per leaf switch)
    nodes_per_pdu: int = 1
    nodes_per_switch: int = 2
    # detection model
    failstop_stall_s: float = 4.0  # heartbeat loss -> NCCL-timeout analogue
    failslow_detect_iters: int = 2  # paper Fig. 14: detected in 2-3 iterations
    detector_tax: float = 0.013  # per-iteration Detector overhead (Fig. 13)
    seed: int = 0

    @property
    def n_devices(self) -> int:
        return self.dp * self.pp * self.tp

    @property
    def samples_per_iter(self) -> int:
        return self.dp * self.n_microbatches * self.rows_per_microbatch


class BeliefArray(dict):
    """The system's believed per-device speeds: the legacy dict API (the
    policies consume ``{device: speed}``) backed by a dense numpy mirror
    (``.arr``) kept in sync on every write — so the validation pass compares
    belief against ground truth in one masked array comparison instead of an
    O(n) dict walk."""

    def __init__(self, n_devices: int):
        super().__init__((i, 1.0) for i in range(n_devices))
        self.arr = np.ones(n_devices, dtype=np.float64)

    def __setitem__(self, device: int, speed: float):
        dict.__setitem__(self, device, speed)
        self.arr[device] = speed


@dataclass
class IterRecord:
    iteration: int
    t_start: float
    duration: float
    throughput: float  # samples/s
    events: list = field(default_factory=list)


class TrainingSim:
    """Cluster training simulator.

    ``engine`` selects the pipeline-execution core:

    * ``"fast"`` (default) — :class:`repro.cluster.fastsim.FastMigrator` with
      vectorized chunk-cost tables: same results bit-for-bit, orders of
      magnitude faster at scale (see ``BENCH_simcore.json``), opening
      1k+-device sweeps;
    * ``"python"`` — the reference
      :class:`~repro.core.scheduler.migration.ProgressAwareMigrator` event
      loop, kept as the semantic anchor and parity baseline.
    """

    def __init__(self, policy_name: str, cfg: SimConfig, *, layer_costs=None,
                 policy_kwargs=None, detector_kwargs=None, engine: str = "fast"):
        if engine not in ("python", "fast"):
            raise ValueError(f"unknown engine {engine!r}; one of ('python', 'fast')")
        self.engine = engine
        self._migrator_cls = FastMigrator if engine == "fast" else ProgressAwareMigrator
        self.cfg = cfg
        self.layer_costs = list(layer_costs) if layer_costs else [1.0] * cfg.n_layers
        self.topo = ClusterTopology(
            math.ceil(cfg.n_devices / cfg.devices_per_node),
            cfg.devices_per_node, nodes_per_pdu=cfg.nodes_per_pdu,
            nodes_per_switch=cfg.nodes_per_switch)
        self.cluster = ClusterState(self.topo)
        self.plan0 = initial_plan(
            cfg.n_layers, cfg.dp, cfg.pp, cfg.tp,
            microbatches=cfg.n_microbatches, schedule=cfg.schedule)
        pk = dict(policy_kwargs or {})
        if policy_name.lower() == "resihp":
            # the §6.1 node-local-standby contract needs the physical
            # topology; explicit policy_kwargs (incl. node_of=None) win
            pk.setdefault("node_of", self.topo.node_of)
            if pk.get("domains"):
                # domain-aware switch: give the Scheduler the device ->
                # failure-domain map for domain-spread standby offers
                kind = getattr(pk["domains"], "domain", "pdu")
                pk.setdefault(
                    "domain_of",
                    lambda d, _k=kind: self.topo.domain_of(d, _k))
        self.policy: BasePolicy = make_policy(
            policy_name, self.plan0, self.layer_costs, **pk)
        self.gen = WorkloadGen(cfg.seq_len, cfg.dp, cfg.n_microbatches,
                               rows_per_microbatch=cfg.rows_per_microbatch,
                               seed=cfg.seed)
        self.rng = np.random.default_rng(cfg.seed + 1)

        # ---- detection stack (real code) ----
        # the fast engine swaps the reference per-device heartbeat monitor
        # for the vectorized FastHeartbeat (same semantics, parity-pinned);
        # the python engine keeps the reference as the semantic anchor
        hb_cls = FastHeartbeat if engine == "fast" else HeartbeatMonitor
        hb = hb_cls(interval=1.0, miss_threshold=3)
        for n in range(self.topo.n_nodes):
            hb.register_node(n, self.cluster.node_devices(n))
        self._fitted = self._fit_predictor()

        # ---- failure lifecycle (flap quarantine / drift / admission) ----
        # built from the policy's default-off ``lifecycle`` switch; the probe
        # is the ElasWave-style rejoin micro-benchmark (ground-truth lookup,
        # cost charged to simulated time like Greyhound's validation pass)
        lc_cfg = getattr(self.policy, "lifecycle", None)
        # per-device hazard awareness (default-off ``hazard`` switch): the
        # estimator reads the lifecycle's FailureHistory — hazard-keyed
        # quarantine inside the manager, risk scores for the Scheduler
        hz_cfg = getattr(self.policy, "hazard", None)
        self.hazard_estimator: Optional[HazardEstimator] = (
            HazardEstimator(hz_cfg) if hz_cfg else None)
        self.lifecycle: Optional[LifecycleManager] = None
        if lc_cfg:
            self.lifecycle = LifecycleManager(
                cfg=lc_cfg,
                probe_fn=lambda d: self.cluster.devices[d].effective,
                hazard=self.hazard_estimator)
        # pooled domain-level detection (default-off ``domains`` switch):
        # the estimator aggregates the lifecycle's FailureHistory by
        # failure domain — whole-domain quarantine + domain-spread risk.
        # ``domains`` implies ``hazard`` implies ``lifecycle`` (policy
        # __post_init__), so the manager above always exists here.
        dom_cfg = getattr(self.policy, "domains", None)
        self.domain_estimator: Optional[DomainEstimator] = (
            DomainEstimator(dom_cfg) if dom_cfg else None)
        self._domain_members: Optional[dict] = None
        # per-domain time of the last quarantine-supporting evidence: a
        # benched domain stays benched for ``hold_s`` after this (benching
        # works precisely by silencing the evidence stream, so a purely
        # window-functional quarantine would flap)
        self._domain_trips: dict = {}
        if dom_cfg:
            members: dict = {}
            for d in range(self.topo.n_devices):
                members.setdefault(
                    self.topo.domain_of(d, dom_cfg.domain), []).append(d)
            self._domain_members = members
        # unified credit score (default-off ``credit`` switch): one scalar
        # per device behind quarantine, admission and placement. ``credit``
        # implies ``hazard`` implies ``lifecycle`` (policy __post_init__),
        # so the manager and estimator above always exist here; the model
        # attaches to the manager, which rekeys its decision chain on the
        # credit bands.
        cr_cfg = getattr(self.policy, "credit", None)
        self.credit_model: Optional[CreditModel] = None
        if cr_cfg:
            cr_members = None
            if cr_cfg.delta > 0.0:
                cr_members = {}
                for d in range(self.topo.n_devices):
                    cr_members.setdefault(
                        self.topo.domain_of(d, cr_cfg.domain), []).append(d)
            self.credit_model = CreditModel(
                cr_cfg, self.topo.n_devices,
                hazard=self.hazard_estimator, domain_members=cr_members)
            if self.lifecycle is not None:
                self.lifecycle.credit = self.credit_model
            # the planner owns the NTP veto, so it owns the veto counter
            sched = getattr(self.policy, "scheduler", None)
            if sched is not None and hasattr(sched, "credit_stats"):
                sched.credit_stats = self.credit_model.stats
        # validation doubles as a fail-stop path (lifecycle gate): a
        # validation pass reports devices it measured dead instead of
        # leaving them to the heartbeat timeout
        self._validation_failstop = bool(lc_cfg and lc_cfg.validation_failstop)

        dkw = dict(detector_kwargs or {})
        dkw.setdefault("workload_filter", policy_name.lower() == "resihp")
        if lc_cfg:
            dkw.setdefault("suppress_failstop_s", lc_cfg.failstop_suppress_s)
            # the debounce hold is the second hand-tuned lifecycle constant
            # retired into the credit fit (4.0 stays the credit-off default)
            dkw.setdefault("validation_debounce_s",
                           cr_cfg.validation_debounce_s if cr_cfg
                           else lc_cfg.validation_debounce_s)
        # a fitted threshold of 1.0 means the margin is unclearable — no
        # shortfall is < 100% slow — so the whole slope/carry stack would be
        # pure overhead; skip installing it and let the credit gamma term be
        # the only slowness channel
        drift_on = bool(lc_cfg and lc_cfg.drift
                        and not (cr_cfg
                                 and cr_cfg.drift_filter_threshold >= 1.0))
        if drift_on:
            dkw.setdefault("drift_factory", SlopeDriftDetector)
            dkw.setdefault("carry_baseline", True)
            # the hand-tuned 10% validation margin is retired as a fit
            # output under the credit switch (0.10 stays the credit-off
            # default via LifecycleConfig)
            dkw.setdefault("drift_filter_threshold",
                           cr_cfg.drift_filter_threshold if cr_cfg
                           else lc_cfg.drift_filter_threshold)
            dkw.setdefault("workload_scalar_fn", self._workload_scalar)
        self.detector = Detector(
            healthy_time_fn=self._healthy_time,
            validate_fn=self._validate,
            heartbeat=hb,
            changepoint_factory=lambda: CusumDetector(warmup=10),
            **dkw,
        )
        # vectorized belief->stage-speed sync (fast engine only; the python
        # engine keeps the reference per-device loop as the parity anchor)
        self._stage_speed_cache = StageSpeedCache() if engine == "fast" else None
        # cached liveness vector for the vectorized heartbeat path; rebuilt
        # lazily, keyed on the registry's mutation counter (liveness changes
        # flow exclusively through ClusterState mutators, which bump it)
        self._alive_vec = None
        self._alive_version = -1
        # the system's *belief* about device speeds (truth lives in cluster)
        self.known_speeds = BeliefArray(self.topo.n_devices)
        self._belief_dirty = True
        self._decision: Optional[PolicyDecision] = None
        self._failslow_backlog: list = []  # (device, true_speed, detect_at_iter)
        self._probation: set = set()  # devices with an active re-probe chain
        self.trace: list = []
        self.now = 0.0
        self.it = 0
        self.aborted = False
        # min-heap of (Event, seq): scenario timelines merge in O(log n) and
        # pop in the same order the previous sorted-list representation
        # produced (full Event field order, insertion order on exact ties)
        self.pending_events: list = []
        self._event_seq = 0
        self.event_log: list = []  # Events already applied, in firing order

    # ------------------------------------------------------------ predictor
    def _fit_predictor(self) -> MicroBatchTimePredictor:
        """Warm-up profiling: fit Eq. 1 on healthy synthetic chunks."""
        cfg = self.cfg
        pred = MicroBatchTimePredictor(backward_ratio=cfg.b_ratio,
                                       weight_ratio=cfg.w_ratio)
        for i in range(24):
            w = self.gen.for_iteration(10_000 + i)
            mb = w.per_replica[0][0]
            t = (cfg.alpha * mb.n_tokens + cfg.beta * mb.sum_l2 + cfg.gamma)
            pred.observe(mb.n_tokens, mb.sum_l2, t, n_layers=1)
        return pred.fit()

    def _healthy_time(self, workload) -> float:
        """Eq. 2: expected healthy iteration time for this workload under the
        current plan — DAG critical path with predicted chunk times."""
        decision = self._decision
        plan = decision.plan if decision else self.plan0
        share = self._stage_shares(plan)

        def cost(cid: ChunkId, executor=None) -> float:
            mbw = workload.stats(cid.replica, cid.mb)
            return self._fitted.predict(
                mbw.n_tokens, mbw.sum_l2,
                n_layers=share[cid.stage] * len(self.layer_costs),
                kind=cid.kind,
            )

        m = self._migrator_cls(
            n_stages=plan.replicas[0].pp, n_replicas=plan.dp,
            n_microbatches=decision.n_mb if decision else plan.microbatches,
            chunk_cost=cost, schedule=self.cfg.schedule, policy="none",
            p2p_cost=self.cfg.p2p_cost,
        )
        r = m.run()
        return r.makespan if r.status == "ok" else float("inf")

    def _workload_scalar(self, workload) -> float:
        """Cheap Eq. 1 workload proxy (total predicted chunk seconds, no DAG
        critical path): normalizes the drift test's input so per-iteration
        workload swings don't mask a slow ramp's trend."""
        tot = 0.0
        for mbs in workload.per_replica:
            for mb in mbs:
                tot += self._fitted.predict(mb.n_tokens, mb.sum_l2)
        return tot

    def _validate(self, iteration: int) -> list:
        """Validation phase: localize degraded devices (ground-truth lookup —
        Greyhound's micro-benchmark pass; the cost is charged by Detector).
        With the lifecycle's ``validation_failstop`` gate, devices the pass
        measures *dead* are reported too (speed 0.0) — the fail-stop no
        longer waits out the heartbeat window when a validation already ran.

        One masked comparison of the registry's effective-speed array
        against the belief mirror (``known_speeds.arr``) replaces the
        reference O(n) dict walk; ``np.nonzero`` preserves the ascending
        device-id report order, and a dead device's effective speed is
        exactly the 0.0 the reference appended."""
        eff = self.cluster.effective()
        alive = self.cluster.alive_mask()
        known = self.known_speeds.arr
        mask = alive & (eff < 0.97) & (known > eff)
        if self._validation_failstop:
            mask = mask | (~alive & (known > 0.0))
        return [(int(d), float(eff[d])) for d in np.nonzero(mask)[0]]

    # ------------------------------------------------------------- helpers
    def _stage_shares(self, plan, replica: int = 0) -> dict:
        total = sum(self.layer_costs)
        shares = {}
        for s, st in enumerate(plan.replicas[replica].stages):
            shares[s] = sum(self.layer_costs[i] for i in st.layers) / total
        return shares

    def _true_stage_speeds(self, plan) -> dict:
        """Effective speed of each (replica, stage) group under TRUE device
        state: (k/tp0) * min p over the group; 0 if any member is dead. A
        stage running nonuniform shard widths (NTP) instead pays each
        member's width over its speed — NTP_EFFICIENCY / (tp0 * max f_i/p_i)
        — so a well-matched width assignment realizes ~sum(p_i)."""
        tp0 = self.cfg.tp
        if self._stage_speed_cache is not None:
            # fast engine: reduce over the registry's cached effective array,
            # memoized on (plan, cluster version) — quiet iterations skip the
            # recompute entirely
            return self._stage_speed_cache.speeds(
                plan, self.cluster.effective(), tp0,
                version=self.cluster.version)
        speeds = self.cluster.speeds()
        out = {}
        for r, rep in enumerate(plan.replicas):
            for s, st in enumerate(rep.stages):
                if not st.devices:
                    out[(r, s)] = 0.0
                    continue
                vals = [speeds.get(d, 0.0) for d in st.devices]
                if min(vals) <= 0:
                    out[(r, s)] = 0.0
                elif st.shard_fractions is not None:
                    worst = max(f / v for f, v in zip(st.shard_fractions, vals))
                    out[(r, s)] = NTP_EFFICIENCY / (tp0 * worst)
                else:
                    out[(r, s)] = (st.tp / tp0) * min(vals)
        return out

    # ------------------------------------------------------------ schedule
    def apply_scenario(self, scenario, *, seed: Optional[int] = None,
                       validate: bool = True):
        """Compile a FailureScenario (or registry name) against this sim's
        topology and enqueue its event timeline. Returns the compiled trace.

        ``validate`` (default on) rejects contradictory timelines — rejoins
        of never-failed devices, events on out-of-range ids, double kills —
        with a :class:`~repro.cluster.events.TraceValidationError` instead
        of silently mis-simulating them; pass ``validate=False`` to replay
        a deliberately malformed trace."""
        from repro.cluster.scenarios import FailureScenario, get as get_scenario

        if isinstance(scenario, str):
            scenario = get_scenario(scenario)
        assert isinstance(scenario, FailureScenario), scenario
        trace = scenario.compile(
            self.topo, self.cfg.seed if seed is None else seed)
        if validate:
            trace.validate(self.topo)
        for ev in trace:
            self._push_event(ev)
        return trace

    def _push_event(self, ev: Event):
        heapq.heappush(self.pending_events, (ev, self._event_seq))
        self._event_seq += 1

    def inject_at(self, time_s: float, fn: Callable):
        """Legacy shim: fn(cluster, now) applied once simulated time passes
        time_s. Prefer apply_scenario with a declarative FailureScenario."""
        self._push_event(Event(float(time_s), "callback", fn=fn))

    def _on_rejoin(self, device: int):
        """Elastic rejoin: the repaired device announces itself. Without the
        lifecycle subsystem the belief flips to full health (the paper's
        model — wrong when the device comes back degraded); with it, a flap
        quarantine can absorb the rejoin entirely and the admission probe
        seeds the belief with the *measured* speed."""
        if self.lifecycle is not None:
            dec = self.lifecycle.on_rejoin(device, self.now)
            async_probe = (self.credit_model is not None
                           and self.credit_model.cfg.admission)
            if async_probe:
                # asynchronous admission (credit switch): the probe runs on
                # the rejoining device itself — which is idle anyway — and
                # overlaps the replan this very rejoin triggers, so no
                # global time is charged; the measured speed still seeds
                # the belief (the whole point of admission probing)
                if dec.admit and dec.probe_cost_s > 0.0:
                    self.credit_model.stats.async_admissions += 1
            else:
                self.now += dec.probe_cost_s
            if not dec.admit:
                # quarantined: belief stays failed, heartbeat stays muted, no
                # replan — the Scheduler keeps ignoring the flapper
                return
            speed = dec.speed
            if (async_probe and dec.probe_cost_s > 0.0 and speed < 1.0
                    and self.credit_model.cfg.probation_recheck_s > 0.0):
                # a degraded admission starts probation: nothing else ever
                # re-measures a device the planner benched on this stale
                # reading, so a transient throttle would pin it slow forever
                self._schedule_probation(device)
        else:
            speed = 1.0
        # heartbeat-revive bugfix: clear the failed state so the device's
        # *next* fail-stop is detectable (previously never cleared)
        self.detector.heartbeat.revive(device, self.now)
        if self.known_speeds.get(device) != speed:
            self.known_speeds[device] = speed
            self._belief_dirty = True

    def _schedule_probation(self, device: int):
        """Queue a free async re-probe of ``device`` one recheck interval
        out; the re-probe keeps following the device (and rescheduling)
        until belief matches truth or the device fails again. At most one
        chain runs per device."""
        if device in self._probation:
            return
        self._probation.add(device)
        recheck_s = self.credit_model.cfg.probation_recheck_s

        def fn(cluster, now):
            believed = self.known_speeds.get(device, 1.0)
            true = cluster.devices[device].effective
            if believed <= 0.0 or true <= 0.0:
                # failed again / down right now: the next rejoin or
                # validation restarts probation
                self._probation.discard(device)
                return
            if true != believed:
                self.known_speeds[device] = true
                self._belief_dirty = True
                self.credit_model.stats.probation_corrections += 1
                self._push_event(Event(self.now + recheck_s, "callback",
                                       fn=fn))
            else:
                self._probation.discard(device)

        self._push_event(Event(self.now + recheck_s, "callback", fn=fn))

    def apply_events(self, t: float) -> list:
        """The single injection hook: fire every pending event with
        ``event.t <= t`` against the cluster (and system beliefs, for
        rejoins), appending them to ``event_log``."""
        fired = []
        while self.pending_events and self.pending_events[0][0].t <= t:
            ev = heapq.heappop(self.pending_events)[0]
            apply_event(ev, self.cluster, self.now, on_rejoin=self._on_rejoin)
            self.event_log.append(ev)
            fired.append(ev)
        return fired

    def _expected_time(self, workload, decision) -> float:
        """Expected *observed* iteration time under ``decision``: Eq. 2
        critical path with predicted chunk times divided by the decision's
        believed per-stage effective speeds. Unlike ``_healthy_time`` (the
        workload filter's healthy reference) this includes the slowdowns the
        system already knows about — the right scale for carrying the CUSUM
        baseline across a reconfiguration, since the post-reconfig steady
        state is legitimately slower than healthy whenever a mitigated
        degradation remains."""
        plan = decision.plan
        share = self._stage_shares(plan)
        speeds = decision.stage_speeds

        def cost(cid: ChunkId, executor=None) -> float:
            mbw = workload.stats(cid.replica, cid.mb)
            base = self._fitted.predict(
                mbw.n_tokens, mbw.sum_l2,
                n_layers=share[cid.stage] * len(self.layer_costs),
                kind=cid.kind,
            )
            v = speeds.get((cid.replica, cid.stage), 1.0)
            return base / max(v, 1e-9)

        m = self._migrator_cls(
            n_stages=plan.replicas[0].pp, n_replicas=plan.dp,
            n_microbatches=decision.n_mb, chunk_cost=cost,
            schedule=self.cfg.schedule, policy="none",
            p2p_cost=self.cfg.p2p_cost,
        )
        r = m.run()
        return r.makespan if r.status == "ok" else float("inf")

    def _domain_view(self, now: float):
        """Pooled domain-level failure view at ``now``: the set of devices
        resident in quarantined domains, plus per-device pooled risk for
        every elevated (but not necessarily quarantined) domain. The view is
        functional in the lifecycle's histories and ``now`` except for the
        quarantine hold: once a domain trips, it stays benched for
        ``hold_s`` after its last supporting evidence (``_domain_trips``),
        because benching silences the very evidence stream that tripped it
        — without the hold the quarantine flaps, and every flap is a full
        replan with migrations. Both engines run this identically from the
        shared step loop, so the extra state cannot diverge them."""
        est = self.domain_estimator
        cfg = est.cfg
        hist = self.lifecycle.histories
        quarantined: set = set()
        risk: dict = {}
        for dom in sorted(self._domain_members):
            members = self._domain_members[dom]
            hs = [hist[d] for d in members if d in hist]
            held = (dom in self._domain_trips
                    and now < self._domain_trips[dom] + cfg.hold_s)
            if not hs and not held:
                continue
            r = est.risk(hs, now) if hs else 1.0
            if cfg.quarantine and hs and est.should_quarantine(hs, now):
                self._domain_trips[dom] = now
                held = True
            if cfg.quarantine and held:
                quarantined.update(members)
            if cfg.spread and r > 1.0:
                for d in members:
                    risk[d] = r
        return frozenset(quarantined), risk

    def _rebaseline_scale(self, old_decision) -> Optional[float]:
        """Predicted expected-time ratio (new decision / old decision) for
        the ramp-aware baseline carry. Only computed when the Detector will
        use it (lifecycle drift policy on) — two extra Eq. 2 critical-path
        evaluations per reconfiguration; ``None`` otherwise, which makes
        ``rebaseline`` behave exactly as before (full reset)."""
        if (not self.detector.carry_baseline or old_decision is None
                or old_decision.aborted or self._decision.aborted):
            return None
        w = self.gen.for_iteration(self.it)
        h_new = self._expected_time(w, self._decision)
        h_old = self._expected_time(w, old_decision)
        if not (math.isfinite(h_old) and math.isfinite(h_new)) or h_old <= 0:
            return None
        return h_new / h_old

    # ------------------------------------------------------------ stepping
    def _sync_beliefs(self) -> list:
        """Detection: heartbeats catch fail-stop immediately; fail-slow is
        detected via the Detector's series analysis with latency."""
        events = []
        # quarantine releases: probe expired quarantines and readmit (or
        # extend the backoff for devices that are still down)
        if self.lifecycle is not None:
            release_free = (self.credit_model is not None
                            and self.credit_model.cfg.admission)
            for dec in self.lifecycle.poll_releases(self.now):
                if not release_free:
                    # under the credit switch the release probe runs on the
                    # still-benched device concurrently with training, like
                    # the rejoin probe — no global charge
                    self.now += dec.probe_cost_s
                if not dec.admit:
                    continue
                self.detector.heartbeat.revive(dec.device, self.now)
                if self.known_speeds.get(dec.device) != dec.speed:
                    self.known_speeds[dec.device] = dec.speed
                    self._belief_dirty = True
                events.append(("readmitted", (dec.device, dec.speed)))
        # fail-stop: heartbeat sweep (dead devices stopped beating). The fast
        # engine beats the whole fleet in one vectorized call; the python
        # engine keeps the reference per-device loop as the parity anchor.
        if isinstance(self.detector.heartbeat, FastHeartbeat):
            if self._alive_version != self.cluster.version:
                self._alive_vec = self.cluster.alive_mask()
                self._alive_version = self.cluster.version
            self.detector.heartbeat.beat_all(self._alive_vec, self.now)
        else:
            for d, dev in self.cluster.devices.items():
                if dev.alive:
                    node = self.topo.node_of(d)
                    self.detector.heartbeat.device_beat(node, d, self.now, self.it)
                    self.detector.heartbeat.node_beat(node, self.now)
        # dead nodes stop beating entirely
        rep = self.detector.poll_failstop(self.now)
        if rep:
            for d in rep.devices:
                if self.lifecycle is not None:
                    self.lifecycle.record_failstop(d, self.now)
                if self.known_speeds.get(d, 1.0) != 0.0:
                    self.known_speeds[d] = 0.0
                    self._belief_dirty = True
            events.append(("fail-stop-detected", rep.devices))
            # the stall models an NCCL timeout: only a rank inside an
            # active communicator can hang a collective. A death confined
            # to warm standbys (benched rack, hazard-quarantined device) is
            # detected out-of-band by the heartbeat — belief flips above,
            # but training never stalls. Membership gating rides the
            # domains= switch: with it off, every fail-stop charges the
            # stall exactly as before (old sweep cells stay byte-identical).
            stall = True
            if self.domain_estimator is not None:
                active = None
                if self._decision is not None and not self._decision.aborted:
                    active = frozenset(
                        d for r in self._decision.plan.replicas
                        for d in r.devices)
                stall = (active is None
                         or any(d in active for d in rep.devices))
            if stall:
                self.now += self.cfg.failstop_stall_s
        # fail-slow backlog promoted after detect latency
        still = []
        for d, speed, at in self._failslow_backlog:
            if self.it >= at:
                if self.known_speeds.get(d, 1.0) != speed:
                    self.known_speeds[d] = speed
                    self._belief_dirty = True
                    events.append(("fail-slow-detected", (d, speed)))
                    if self.lifecycle is not None:
                        self.lifecycle.record_failslow(d, speed, self.now)
            else:
                still.append((d, speed, at))
        self._failslow_backlog = still
        return events

    def step(self) -> IterRecord:
        cfg = self.cfg
        events = []
        events += [("injection", ev.t) for ev in self.apply_events(self.now)]
        events += self._sync_beliefs()

        if self._belief_dirty or self._decision is None:
            changed = self._decision is not None and self._belief_dirty
            old_decision = self._decision
            excluded = (self.lifecycle.quarantined(self.now)
                        if self.lifecycle is not None else frozenset())
            # per-device hazard view for risk-aware placement ({} -> None:
            # the hazard-blind planner path stays byte-identical)
            risk = (self.lifecycle.risk_scores(self.now)
                    if self.lifecycle is not None else {})
            # unified credit view for placement / restart weighting (None
            # when the switch is off — the credit-blind path stays
            # byte-identical)
            credit_scores = None
            if (self.credit_model is not None
                    and self.credit_model.cfg.planning):
                credit_scores = self.credit_model.scores(
                    self.lifecycle.histories, self.now) or None
                # one scalar means ONE: the raw hazard view is dropped, not
                # merged — risk only reaches placement through the credit
                # score's alpha term. Without this, a zero-signal credit
                # config would still pay the risk view's plan-cache churn.
                risk = {}
            if self.domain_estimator is not None:
                # pooled domain view: a hot domain's residents are excluded
                # wholesale (bench the rack before its third device fails)
                # and carry the pooled risk into placement tie-breaks
                dq, drisk = self._domain_view(self.now)
                if dq:
                    excluded = frozenset(excluded) | dq
                if drisk:
                    risk = dict(risk)
                    for d, rv in drisk.items():
                        if rv > risk.get(d, 1.0):
                            risk[d] = rv
            self._decision = self.policy.decide(self.known_speeds,
                                                changed=changed,
                                                excluded=excluded,
                                                risk=risk or None,
                                                credit=credit_scores)
            if (self._decision.aborted and self.domain_estimator is not None
                    and dq):
                # a bench is advisory, never fatal: if excluding the hot
                # domain leaves no feasible plan (its capacity is needed to
                # cover unrelated concurrent failures), fall back to the
                # per-device exclusion set and keep the session alive
                excluded = frozenset(excluded) - dq
                self._decision = self.policy.decide(self.known_speeds,
                                                    changed=changed,
                                                    excluded=excluded,
                                                    risk=risk or None,
                                                    credit=credit_scores)
                events.append(("bench-waived", tuple(sorted(dq))))
            self._belief_dirty = False
            if self._decision.reconfig_overhead_s:
                self.now += self._decision.reconfig_overhead_s
                events.append(("reconfig", self._decision.reconfig_overhead_s))
                self.detector.rebaseline(self._rebaseline_scale(old_decision))
        decision = self._decision
        if decision.aborted:
            self.aborted = True
            rec = IterRecord(self.it, self.now, math.inf, 0.0,
                             events + [("aborted", decision.detail)])
            self.trace.append(rec)
            return rec

        workload = self.gen.for_iteration(self.it)
        plan = decision.plan
        true_speed = self._true_stage_speeds(plan)
        # dense (replica, stage) mirror of true_speed for the fast engine's
        # batched cost gather; only valid while it matches the dict
        speed_grid = (self._stage_speed_cache.grid
                      if self._stage_speed_cache is not None else None)
        if decision.slowdown_recovery > 0.0:
            # schedule-level mitigation (Adaptra): hides part of a slowdown
            true_speed = {
                e: (v + (1.0 - v) * decision.slowdown_recovery if 0.0 < v < 1.0 else v)
                for e, v in true_speed.items()
            }
            speed_grid = None
        # ZB splits the 1F1B backward into B (activation) + W (weight): the
        # two must sum to the 1F1B backward cost, not add to it
        if decision.schedule.lower().startswith("zb"):
            mult = {"F": 1.0, "B": cfg.b_ratio - cfg.w_ratio, "W": cfg.w_ratio}
        else:
            mult = {"F": 1.0, "B": cfg.b_ratio, "W": cfg.w_ratio}
        jit = float(self.rng.normal(1.0, cfg.noise)) if cfg.noise else 1.0

        def make_cost(share, replica_map=None):
            if self.engine == "fast":
                # vectorized per-(stage, kind, micro-batch) cost arrays,
                # bit-identical to the scalar closure below
                return make_cost_table(
                    alpha=cfg.alpha, beta=cfg.beta, gamma=cfg.gamma,
                    workload=workload, share=share,
                    n_layers=len(self.layer_costs), mult=mult, jit=jit,
                    true_speed=true_speed, replica_map=replica_map,
                    true_speed_grid=speed_grid)

            def cost(cid: ChunkId, executor) -> float:
                r = replica_map(cid.replica) if replica_map else cid.replica
                mbw = workload.stats(r, cid.mb)
                base = (cfg.alpha * mbw.n_tokens + cfg.beta * mbw.sum_l2 + cfg.gamma)
                base *= share[cid.stage] * len(self.layer_costs) * mult[cid.kind]
                e = (r, executor[1]) if replica_map else executor
                v = true_speed.get(e, 1.0)
                return base * jit / max(v, 1e-9)
            return cost

        dead = [e for e, v in true_speed.items() if v <= 0.0]
        if decision.migration_policy == "none":
            # replicas may be heterogeneous (Oobleck templates): simulate each
            # pipeline independently; the iteration ends at the DP sync = max.
            res = self._run_independent(decision, make_cost, dead)
        else:
            share = self._stage_shares(plan)
            m = self._migrator_cls(
                n_stages=plan.replicas[0].pp,
                n_replicas=plan.dp,
                n_microbatches=decision.n_mb,
                chunk_cost=make_cost(share),
                schedule=decision.schedule,
                dead_executors=dead,
                policy=decision.migration_policy,
                delta=decision.delta,
                p2p_cost=cfg.p2p_cost,
                migrate_edge_cost=cfg.migrate_edge_cost,
            )
            res = m.run()
        if res.status != "ok":
            # undetected dead executor stalls the job until detection kicks in
            self.aborted = decision.migration_policy == "none" and bool(
                set(dead) & set(plan.dead_stages or ())
            )
            duration = cfg.failstop_stall_s
            rec = IterRecord(self.it, self.now, duration, 0.0,
                             events + [("stalled", res.detail)])
        else:
            duration = res.makespan * (1.0 + cfg.detector_tax)
            thpt = cfg.samples_per_iter / duration
            rec = IterRecord(self.it, self.now, duration, thpt,
                             events + [("migrations", len(res.migrations))] if res.migrations else events)

        # fail-slow series detection on the observed time (real Detector) —
        # only systems with a fail-slow story run it (vanilla ReCycle/Oobleck
        # have no detector; their belief stays healthy, execution stays slow)
        if self.policy.handles_failslow and not math.isinf(rec.duration):
            drep = self.detector.observe_iteration(self.it, rec.duration, workload, self.now)
            if drep:
                for d, speed in drep.devices:
                    if speed <= 0.0:
                        # validation doubled as the fail-stop path: the pass
                        # measured the device dead, so the belief flips now —
                        # no heartbeat wait, no second NCCL-stall charge (the
                        # monitor is told out-of-band so its sweep stays mute,
                        # and the Detector arms its fail-stop suppression
                        # window exactly as for a heartbeat detection)
                        self.detector.heartbeat.mark_failed(d)
                        self.detector.note_failstop(self.now)
                        if self.lifecycle is not None:
                            self.lifecycle.record_failstop(d, self.now)
                        if self.known_speeds.get(d, 1.0) != 0.0:
                            self.known_speeds[d] = 0.0
                            self._belief_dirty = True
                        rec.events.append(("failstop-via-validation", d))
                    else:
                        self._failslow_backlog.append(
                            (d, speed, self.it + cfg.failslow_detect_iters - 1))
                rec.events.append(("failslow-report", drep.devices))

        self.now += rec.duration if not math.isinf(rec.duration) else 0.0
        self.it += 1
        self.trace.append(rec)
        return rec

    def _run_independent(self, decision, make_cost, dead):
        """Per-replica pipeline simulation for non-migrating policies; the
        iteration ends at the global DP synchronization (max over replicas)."""
        from repro.core.scheduler.migration import SimResult

        plan = decision.plan
        worst, finishes = 0.0, {}
        all_ok = True
        detail = ""
        for r, rep in enumerate(plan.replicas):
            if decision.n_mb[r] <= 0:
                continue
            share = self._stage_shares(plan, r)
            dead_r = [(0, s) for (dr, s) in dead if dr == r and s < rep.pp]
            m = self._migrator_cls(
                n_stages=rep.pp, n_replicas=1,
                n_microbatches=[decision.n_mb[r]],
                chunk_cost=make_cost(share, replica_map=lambda _=None, r=r: r),
                schedule=decision.schedule,
                dead_executors=dead_r,
                policy="none",
                p2p_cost=self.cfg.p2p_cost,
            )
            res_r = m.run()
            if res_r.status != "ok":
                all_ok = False
                detail = res_r.detail
                continue
            worst = max(worst, res_r.makespan)
            finishes[r] = res_r.makespan
        if not all_ok:
            return SimResult(math.inf, "aborted", {}, [], {}, finishes, detail=detail)
        return SimResult(worst, "ok", {}, [], {}, finishes)

    def run(self, n_iters: int, *, stop_on_abort=True) -> list:
        for _ in range(n_iters):
            rec = self.step()
            if self.aborted and stop_on_abort:
                break
        return self.trace

    # ------------------------------------------------------------- metrics
    def avg_throughput(self, *, skip: int = 0) -> float:
        """Execution throughput: samples/s over iteration durations only —
        reconfiguration, stall and probe charges advance ``now`` but are not
        part of any iteration, so this metric ignores them (the paper's
        figure convention)."""
        recs = [r for r in self.trace[skip:] if not math.isinf(r.duration)]
        if not recs:
            return 0.0
        total_t = sum(r.duration for r in recs)
        total_s = sum(r.throughput * r.duration for r in recs)
        return total_s / max(total_t, 1e-9)

    def session_throughput(self, *, skip: int = 0) -> float:
        """End-to-end throughput: samples delivered per second of *elapsed*
        simulated time, reconfiguration / fail-stop-stall / probe charges
        included. This is the metric a reconfiguration storm actually hurts
        — the one the failure-lifecycle and hazard policies optimize."""
        recs = [r for r in self.trace[skip:] if not math.isinf(r.duration)]
        if not recs:
            return 0.0
        t0 = recs[0].t_start
        elapsed = max(self.now - t0, 1e-9)
        total_s = sum(r.throughput * r.duration for r in recs)
        return total_s / elapsed
