"""Per-device hazard models: age-dependent MTTF instead of a global rate.

The paper's §8.1 injection protocol (and everything built on it up to now)
draws failures from a *global* Poisson process: every device is equally
likely to be the next victim, and a device that failed three times is as
likely to fail again as one that never did. Fleet-scale reliability reports
(ByteDance's robust-training infrastructure retrospective, the SPARe/ElasWave
line of work) say otherwise: failure intensity is a *per-device* function of
age, part quality and repair history. This module provides both halves of
that story:

* the **ground-truth side** — :class:`HazardModel`, a seeded per-device
  Weibull renewal process with covariates (initial age, "lemon" parts with a
  shorter characteristic life, wear-out per imperfect repair). It replaces
  the global-rate victim pool inside
  :class:`~repro.cluster.scenarios.PoissonFailures` when
  ``hazard=HazardConfig(...)`` is set (default **off** — the golden trace is
  untouched) and backs the ``aging_fleet`` / ``lemon_devices`` /
  ``infant_mortality`` scenario families;
* the **observational side** — :class:`HazardEstimator`, a Gamma-prior
  empirical rate estimate over a device's
  :class:`~repro.core.detector.lifecycle.FailureHistory`. The system never
  sees the ground-truth model; what it *can* see is each device's detected
  failure count and exposure time, and the estimator turns that into the
  per-device risk scores that drive hazard-keyed quarantine
  (:class:`~repro.core.detector.lifecycle.LifecycleManager`) and risk-aware
  placement (``Scheduler.adapt(device_risk=...)``). The default-off policy
  switch is ``ResiHPPolicy(hazard=HazardPolicyConfig(...))``.

Hazard math
-----------
A device with characteristic life ``lam`` (seconds), Weibull shape ``k`` and
rate multiplier ``m`` (wear) has cumulative hazard ``H(a) = m * (a/lam)**k``
at age ``a``. ``k > 1`` models wear-out (old parts fail more), ``k < 1``
infant mortality (fresh parts fail more), ``k = 1`` is the memoryless
exponential — with no covariates that special case is statistically the
global-rate process the repo always had. Sampling uses the standard
inverse-transform for a conditional renewal: given survival to age ``a`` and
``E ~ Exp(1)``, the next failure age solves ``H(x) - H(a) = E``, i.e.
``x = lam * (E/m + (a/lam)**k) ** (1/k)``. Everything is driven by the
scenario's derived RNG, so the same ``(topology, seed)`` compiles to a
byte-identical timeline like every other scenario.
"""
from __future__ import annotations

import heapq
import math
from dataclasses import dataclass
from typing import Optional

import numpy as np

__all__ = [
    "HazardConfig", "HazardModel", "HazardPolicyConfig", "HazardEstimator",
    "DomainPolicyConfig", "DomainEstimator",
]


# ============================================================ ground truth
@dataclass(frozen=True)
class HazardConfig:
    """Ground-truth fleet hazard parameters (scenario side, default-off).

    ``mttf_s`` is the Weibull characteristic life of a *median* device; a
    ``lemon_frac`` fraction of devices (seeded, anonymous) get
    ``mttf_s / lemon_factor`` instead — the bad-part tail every large fleet
    has. ``age_spread_s`` draws each device's initial age uniformly from
    ``[0, age_spread_s]`` so a wear-out fleet (``shape > 1``) is
    heterogeneous from the first second. ``wear_per_repair`` multiplies a
    device's hazard rate after every repair (imperfect repair: a swapped
    part helps, a reseated cable does not).
    """

    mttf_s: float = 400.0
    shape: float = 1.0  # Weibull k: >1 wear-out, <1 infant mortality
    age_spread_s: float = 0.0
    lemon_frac: float = 0.0
    lemon_factor: float = 8.0
    wear_per_repair: float = 1.0
    # -------- topology covariates (default-off: per-device independence) --
    # A seeded ``bad_domain_frac`` fraction of failure domains (PDUs by
    # default — see ``ClusterTopology.nodes_per_pdu``) go *bad*: every
    # resident device's hazard rate is multiplied by ``bad_domain_factor``.
    # This is the correlated-failure regime fleet retrospectives report —
    # a browned-out PDU takes out devices by rack, not independently.
    bad_domain_frac: float = 0.0
    bad_domain_factor: float = 1.0
    domain: str = "pdu"  # grouping: 'pdu' | 'switch' | 'node'

    def __post_init__(self):
        if self.mttf_s <= 0 or self.shape <= 0:
            raise ValueError("HazardConfig needs mttf_s > 0 and shape > 0")
        if not (0.0 <= self.lemon_frac <= 1.0):
            raise ValueError("lemon_frac must be in [0, 1]")
        if self.lemon_factor < 1.0 or self.wear_per_repair < 1.0:
            raise ValueError("lemon_factor / wear_per_repair must be >= 1")
        if not (0.0 <= self.bad_domain_frac <= 1.0):
            raise ValueError("bad_domain_frac must be in [0, 1]")
        if self.bad_domain_factor < 1.0:
            raise ValueError("bad_domain_factor must be >= 1")
        if self.domain not in ("pdu", "switch", "node", "rack"):
            raise ValueError(f"unknown domain kind {self.domain!r}")

    def __repr__(self):
        # HazardConfig reprs are embedded in scenario reprs, which key the
        # DSL's derived RNG streams: with the domain covariates unset this
        # must reproduce the pre-domain dataclass repr byte-for-byte so
        # existing hazard families (aging_fleet, lemon_devices, ...) keep
        # their compiled timelines.
        s = (f"HazardConfig(mttf_s={self.mttf_s!r}, shape={self.shape!r}, "
             f"age_spread_s={self.age_spread_s!r}, "
             f"lemon_frac={self.lemon_frac!r}, "
             f"lemon_factor={self.lemon_factor!r}, "
             f"wear_per_repair={self.wear_per_repair!r}")
        if self.bad_domain_frac > 0.0:
            s += (f", bad_domain_frac={self.bad_domain_frac!r}, "
                  f"bad_domain_factor={self.bad_domain_factor!r}, "
                  f"domain={self.domain!r}")
        return s + ")"


class HazardModel:
    """Per-device Weibull renewal process over a fleet of ``n_devices``.

    Construction consumes exactly two vectorized draws from ``rng`` (lemon
    assignment, initial ages) — plus one more, gated on
    ``cfg.bad_domain_frac > 0``, for bad-domain assignment — so scenario
    compilation stays deterministic and composition-stable under the DSL's
    derived-RNG contract (covariates off ⇒ identical draw sequence to the
    pre-domain model).

    With the domain covariates on, ``topo`` (a
    :class:`~repro.cluster.registry.ClusterTopology`) supplies the device →
    domain map; a seeded ``bad_domain_frac`` fraction of domains (at least
    one, same guarantee as the lemon tail) multiplies every resident
    device's hazard rate by ``bad_domain_factor``.
    """

    def __init__(self, cfg: HazardConfig, n_devices: int,
                 rng: np.random.Generator, topo=None):
        self.cfg = cfg
        self.n_devices = int(n_devices)
        u = rng.uniform(size=self.n_devices)
        lemons = u < cfg.lemon_frac
        if cfg.lemon_frac > 0.0 and self.n_devices and not lemons.any():
            # a configured lemon tail always exists: without this, small
            # fleets / unlucky seeds draw zero lemons and the family's
            # repeat-offender dynamics silently vanish
            lemons[int(np.argmin(u))] = True
        self.scale = np.where(lemons, cfg.mttf_s / cfg.lemon_factor,
                              cfg.mttf_s)
        self.age0 = (rng.uniform(0.0, cfg.age_spread_s, size=self.n_devices)
                     if cfg.age_spread_s > 0.0 else np.zeros(self.n_devices))
        self.mult = np.ones(self.n_devices)
        self.lemons = lemons
        self.bad_domains = frozenset()
        if cfg.bad_domain_frac > 0.0 and self.n_devices:
            if topo is None:
                raise ValueError(
                    "HazardConfig.bad_domain_frac > 0 needs a ClusterTopology"
                    " for the device -> domain map (pass topo=)")
            dom = np.array([topo.domain_of(d, cfg.domain)
                            for d in range(self.n_devices)], dtype=np.intp)
            n_dom = int(dom.max()) + 1
            v = rng.uniform(size=n_dom)
            bad = v < cfg.bad_domain_frac
            if not bad.any():
                # same always-at-least-one guarantee as the lemon tail
                bad[int(np.argmin(v))] = True
            self.bad_domains = frozenset(np.nonzero(bad)[0].tolist())
            self.mult[bad[dom]] *= cfg.bad_domain_factor

    # --------------------------------------------------------------- query
    def cumulative_hazard(self, device: int, age_s: float) -> float:
        return float(self.mult[device]
                     * (max(age_s, 0.0) / self.scale[device]) ** self.cfg.shape)

    def rate(self, device: int, t: float) -> float:
        """Instantaneous hazard (failures/s) at simulated time ``t``."""
        lam, k = float(self.scale[device]), self.cfg.shape
        a = max(float(self.age0[device]) + t, 1e-12)
        return float(self.mult[device]) * (k / lam) * (a / lam) ** (k - 1.0)

    # ------------------------------------------------------------ sampling
    def sample_next(self, device: int, t: float,
                    rng: np.random.Generator) -> float:
        """Absolute time of the device's next failure, conditioned on it
        being alive (and just repaired / fresh) at time ``t``."""
        e = float(rng.exponential(1.0))
        lam, k = float(self.scale[device]), self.cfg.shape
        m = float(self.mult[device])
        a = float(self.age0[device]) + t
        x = lam * (e / m + (a / lam) ** k) ** (1.0 / k)
        return t + max(x - a, 1e-9)

    def record_repair(self, device: int):
        self.mult[device] *= self.cfg.wear_per_repair


def hazard_event_times(model: HazardModel, rng: np.random.Generator, *,
                       t_start: float, t_end: float, mttr: Optional[float],
                       renewal: bool, max_events: int):
    """Drive the fleet's competing per-device renewal processes into a flat
    ``(t_fail, device, t_repair | None)`` sequence for scenario compilation.

    Each device holds one pending next-failure sample in a min-heap; firing a
    failure optionally samples an exponential repair (``mttr``) and — in
    renewal mode — re-arms the device from its repair time with the wear
    multiplier applied. Deterministic: draws happen in device-id order at
    init and in firing order afterwards.
    """
    heap = []
    for d in range(model.n_devices):
        heapq.heappush(heap, (model.sample_next(d, t_start, rng), d))
    out = []
    while heap and len(out) < max_events:
        t, d = heapq.heappop(heap)
        if t >= t_end:
            break
        t_rep = None
        if mttr is not None:
            t_rep = t + float(rng.exponential(mttr))
            if renewal:
                model.record_repair(d)
                heapq.heappush(heap, (model.sample_next(d, t_rep, rng), d))
        out.append((t, d, t_rep))
    return out


# ========================================================== observational
@dataclass(frozen=True)
class HazardPolicyConfig:
    """Default-off policy switch for the hazard-*aware* system behaviours
    (``ResiHPPolicy(hazard=...)``; ``hazard=True`` for these defaults).
    Requires the failure-lifecycle subsystem (it owns the per-device
    ``FailureHistory`` the estimator reads); enabling ``hazard`` without
    ``lifecycle`` turns the default ``LifecycleConfig`` on too.

    * ``quarantine`` — quarantine entry/backoff keyed on the *estimated*
      per-device risk instead of the raw fail-stop flap counter: a device
      whose risk score (``1 + n_recent/prior_failures``, fail-slows
      included — a part that keeps coming back degraded is as much a lemon
      as one that dies) reaches ``rate_threshold_ratio`` quarantines on
      rejoin, for a duration that scales with how far above threshold it
      sits (capped at the lifecycle's ``backoff_max_s``).
    * ``planning`` — feed the estimated rates into ``Scheduler.adapt`` as
      ``device_risk``: among equal-throughput choices the planner prefers
      low-hazard devices for TP membership and standby pull-in (risk-aware
      placement; ties only, Eq. 4 still decides throughput).
    """

    prior_failures: float = 0.5  # Gamma prior pseudo-events: each in-window
    # failure adds 1/prior_failures to the risk score
    prior_time_s: float = 400.0  # Gamma prior pseudo-exposure (seconds) —
    # only scales the absolute ``rate()`` view; the decision paths use the
    # exposure-free ``risk()`` score, where it cancels
    rate_threshold_ratio: float = 4.0  # risk score at/above => quarantine
    # (with prior_failures=0.5: 2 in-window failures)
    # recency window (validated in __post_init__ together with the priors —
    # a zero prior would divide-by-zero deep in the decide loop otherwise):
    # only failures inside the last ``window_s`` seconds
    # count as evidence (with exposure capped at the window), so a device
    # whose failure burst is *over* decays back below the quarantine
    # threshold instead of being benched on stale history. ``inf`` => all
    # history counts.
    window_s: float = 60.0
    quarantine: bool = True
    planning: bool = True
    # per-device MTTF priors (default None => the fleet-wide prior for
    # everyone, byte-identical to the pre-prior estimator): a tuple of
    # ``(device_id, mttf_s)`` pairs, fit offline by
    # ``tools/fit_credit.py --priors`` from observed sweep histories. A
    # device with a fitted MTTF shorter than ``prior_time_s`` scores
    # proportionally riskier *before* any fresh in-session evidence — the
    # fleet's known lemons start on the back foot.
    priors: Optional[tuple] = None

    def __post_init__(self):
        if self.prior_failures <= 0 or self.prior_time_s <= 0:
            raise ValueError("HazardPolicyConfig priors must be > 0")
        if self.rate_threshold_ratio < 1.0:
            raise ValueError("rate_threshold_ratio must be >= 1 (1.0 "
                             "quarantines every rejoining device)")
        if self.window_s <= 0:
            raise ValueError("window_s must be > 0")
        if self.priors is not None:
            norm = []
            for item in (self.priors.items()
                         if isinstance(self.priors, dict) else self.priors):
                d, mttf = item
                if mttf <= 0:
                    raise ValueError(
                        f"per-device MTTF prior must be > 0 (device {d})")
                norm.append((int(d), float(mttf)))
            # frozen dataclass: normalize to a canonical hashable form
            object.__setattr__(self, "priors", tuple(sorted(norm)))


class HazardEstimator:
    """Posterior-mean per-device failure-rate estimate from observed history:
    ``(prior_failures + n_detected) / (prior_time_s + exposure)`` — the
    Gamma-Exponential conjugate update, shrunk toward the fleet prior so a
    single unlucky failure does not brand a device a lemon."""

    def __init__(self, cfg: HazardPolicyConfig):
        self.cfg = cfg
        # device -> fitted MTTF (empty when no per-device priors are set)
        self._prior_mttf = dict(cfg.priors or ())

    @property
    def prior_rate(self) -> float:
        return self.cfg.prior_failures / self.cfg.prior_time_s

    def _prior_factor(self, history) -> float:
        """Per-device prior multiplier on the risk score: the fleet prior
        exposure over the device's fitted MTTF (1.0 when no prior is set —
        the exposure-free score is untouched)."""
        if not self._prior_mttf or history is None:
            return 1.0
        mttf = self._prior_mttf.get(history.device)
        return self.cfg.prior_time_s / mttf if mttf else 1.0

    def _recent_failures(self, history, now: float) -> int:
        """Failures inside the recency window — fail-stops *and* fail-slows:
        a part that keeps coming back degraded is as much a lemon as one
        that dies."""
        if history is None:
            return 0
        t0 = now - self.cfg.window_s
        return (sum(1 for t in history.fail_stops if t >= t0)
                + sum(1 for t, _ in history.fail_slows if t >= t0))

    def rate(self, history, now: float) -> float:
        """Posterior-mean absolute rate (failures/s), for introspection and
        absolute-threshold consumers: recent events over windowed exposure,
        shrunk by the Gamma prior. The decision paths below do NOT use this
        directly — they use :meth:`risk`, whose same-exposure baseline
        cancels the denominator."""
        exposure = max(min(now, self.cfg.window_s), 0.0)
        return ((self.cfg.prior_failures + self._recent_failures(history, now))
                / (self.cfg.prior_time_s + exposure))

    def risk(self, history, now: float) -> float:
        """Risk score for the planner: the device's posterior rate over the
        same-exposure baseline. The exposure terms cancel algebraically, so
        this is exactly ``1 + n_recent / prior_failures`` — a clean device
        (or one whose burst aged out of the window) scores 1.0, never below,
        and each in-window failure adds ``1/prior_failures``. Exposure-free
        by construction: the score depends only on recent failure count, not
        on when in the session it is evaluated. With per-device MTTF priors
        (``cfg.priors``) the score is further multiplied by
        ``prior_time_s / mttf_device`` — a fitted lemon scores above 1.0
        even before fresh evidence."""
        base = (1.0 + self._recent_failures(history, now)
                / self.cfg.prior_failures)
        return base * self._prior_factor(history)

    def should_quarantine(self, history, now: float) -> bool:
        return self.risk(history, now) >= self.cfg.rate_threshold_ratio

    def backoff_s(self, history, now: float, *, base_s: float,
                  max_s: float, level: int, factor: float) -> float:
        """Risk-keyed quarantine duration: the base backoff scaled by how
        far the device's risk score sits above the quarantine threshold,
        escalated per unserved quarantine level exactly like the flap-counter
        policy, capped at ``max_s``."""
        ratio = self.risk(history, now) / self.cfg.rate_threshold_ratio
        dur = base_s * max(ratio, 1.0) * factor ** max(level - 1, 0)
        return min(dur, max_s)


# ------------------------------------------------- pooled (domain) side
@dataclass(frozen=True)
class DomainPolicyConfig:
    """Default-off policy switch for *domain-level* failure awareness
    (``ResiHPPolicy(domains=...)``; ``domains=True`` for these defaults).
    Implies the hazard estimator (and therefore the lifecycle subsystem):
    the pooled estimator reads the same per-device ``FailureHistory``
    records, aggregated by the topology's domain map.

    * ``domain`` — which correlation domain to pool over ('pdu' | 'switch'
      | 'node'); PDUs are the default because brownouts are the canonical
      correlated killer.
    * ``quarantine`` — when a domain's pooled risk crosses
      ``rate_threshold_ratio`` *and* at least ``min_devices`` distinct
      resident devices failed inside the window, every resident device is
      excluded from placement (the whole rack is benched before its third
      device fails). Purely functional in ``now``: the window sliding past
      the burst readmits the domain with no extra state.
    * ``spread`` — feed the pooled risk to ``Scheduler.adapt`` as
      per-device risk (max-merged with the per-device estimate) so
      equal-throughput placement ties break away from hot domains, and TP
      groups / standbys straddle domains.
    * ``hold_s`` — minimum bench time once a domain trips. Domain evidence
      goes quiet the moment the bench works (a standby device's throttling
      never shows up in iteration time), so a purely window-functional
      quarantine flaps: trip, evidence ages out, readmit, re-detect,
      re-trip — each flip a full replan with migrations. The hold keeps a
      tripped domain benched for ``hold_s`` after its last supporting
      evidence, trading a bounded capacity tax for churn immunity.
    * ``restart`` — a :class:`~repro.checkpoint.RestartCostModel` (or
      ``True`` for its defaults, ``None`` to disable): lets the policy
      charge restart-from-checkpoint instead of live migration whenever the
      modeled restart cost undercuts the live adaptation cost.
    """

    domain: str = "pdu"
    prior_failures: float = 0.5  # same normalization as HazardPolicyConfig
    window_s: float = 60.0
    rate_threshold_ratio: float = 4.0
    min_devices: int = 2  # distinct recent-failing residents to quarantine
    quarantine: bool = True
    spread: bool = True
    hold_s: float = 90.0  # bench dwell after the last supporting evidence
    restart: object = True

    def __post_init__(self):
        if self.domain not in ("pdu", "switch", "node", "rack"):
            raise ValueError(f"unknown domain kind {self.domain!r}")
        if self.prior_failures <= 0:
            raise ValueError("prior_failures must be > 0")
        if self.window_s <= 0:
            raise ValueError("window_s must be > 0")
        if self.rate_threshold_ratio < 1.0:
            raise ValueError("rate_threshold_ratio must be >= 1")
        if self.min_devices < 1:
            raise ValueError("min_devices must be >= 1")
        if self.hold_s < 0:
            raise ValueError("hold_s must be >= 0")


class DomainEstimator:
    """Pooled sibling of :class:`HazardEstimator`: the same exposure-free
    risk score, computed over the union of a domain's resident
    ``FailureHistory`` records — ``1 + pooled_recent / prior_failures``.
    On a single-device domain this reduces *exactly* to the per-device
    estimator's score (same prior, same window, same fail-stop+fail-slow
    evidence), so domain pooling is a strict generalization, not a second
    calibration.

    Quarantine additionally requires ``min_devices`` distinct recent-failing
    residents: two failures on one device are that device's problem (the
    per-device estimator already benches it); two failures on two devices of
    the same rack are the rack's problem — that correlation is the only
    signal this class adds."""

    def __init__(self, cfg: DomainPolicyConfig):
        self.cfg = cfg

    def _recent(self, histories, now: float):
        """Pooled in-window failure count + the distinct devices involved."""
        t0 = now - self.cfg.window_s
        n, devs = 0, set()
        for h in histories:
            if h is None:
                continue
            c = (sum(1 for t in h.fail_stops if t >= t0)
                 + sum(1 for t, _ in h.fail_slows if t >= t0))
            if c:
                n += c
                devs.add(h.device)
        return n, devs

    def risk(self, histories, now: float) -> float:
        n, _ = self._recent(histories, now)
        return 1.0 + n / self.cfg.prior_failures

    def should_quarantine(self, histories, now: float) -> bool:
        n, devs = self._recent(histories, now)
        return (len(devs) >= self.cfg.min_devices
                and 1.0 + n / self.cfg.prior_failures
                >= self.cfg.rate_threshold_ratio)


def expected_failures(model: HazardModel, horizon_s: float) -> float:
    """Fleet-level expected failure count over ``[0, horizon]`` (no repairs):
    sum of per-device cumulative-hazard increments. Used by tests and for
    sizing scenario parameters against a target event budget."""
    tot = 0.0
    for d in range(model.n_devices):
        a0 = float(model.age0[d])
        tot += (model.cumulative_hazard(d, a0 + horizon_s)
                - model.cumulative_hazard(d, a0))
    return tot
