"""Resilience policies: ResiHP and the paper's four baselines (§8.1).

Each policy maps the current failure state (device speeds) to a
PolicyDecision: the plan to execute, per-(replica,stage) effective speeds,
the DP migration mode, per-replica micro-batch counts, and the
reconfiguration overhead to charge. The simulator executes decisions; the
differences between systems are exactly the paper's §3 limitations:

  ReCycle      — fail-stop only. A failed device excludes its *entire* TP
                 group (no selective exclusion); pending work is rerouted to
                 DP peers with no progress awareness (Fig. 3a/6a). No
                 fail-slow reaction. Aborts when a stage loses all replicas.
  Oobleck      — fail-stop only. Switches the affected replica to a
                 precomputed template with fewer stages (layers merged into
                 survivors); high reconfiguration latency; aborts beyond its
                 precomputed fault budget. No fail-slow reaction.
  Greyhound    — fail-slow only. Change-point detection *without* the
                 workload filter (pays validation on every alarm) and
                 mitigates by redistributing micro-batches across DP groups
                 proportionally to replica speed (Fig. 3b: intra-DP pipeline
                 imbalance remains).
  Adaptra      — fail-slow only. PP-schedule adaptation: ZB-H1 with
                 bubble-filling hides part of a slow stage; communication
                 slowdowns are largely overlapped. No DP redistribution.
  strengthened ReCycle/Oobleck — + Greyhound's fail-slow handling (§8.1).
  ResiHP       — full §6 progressive adaptation via the Scheduler.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Optional

from repro.core.scheduler.plan import ParallelPlan, ReplicaPlan, StagePlan, initial_plan
from repro.core.scheduler.scheduler import PlanOverheadModel, Scheduler


@dataclass
class PolicyDecision:
    plan: ParallelPlan
    stage_speeds: dict  # (replica, stage) -> effective speed (1.0 healthy)
    migration_policy: str  # 'resihp' | 'recycle' | 'none'
    n_mb: list  # per replica
    reconfig_overhead_s: float
    aborted: bool = False
    delta: int = 1
    schedule: str = "1f1b"
    detail: str = ""
    # share of a fail-slow slowdown genuinely hidden by the policy's schedule
    # adaptation (Adaptra's async-P2P/bubble-filling) — applied to execution
    slowdown_recovery: float = 0.0

    @property
    def dead_executors(self):
        return self.plan.dead_stages


def _group_speed_conservative(devices, speeds) -> float:
    """Baseline TP-group speed: no selective exclusion — a fail-stop device
    kills the group (0.0); otherwise the group runs at its slowest member."""
    vals = [speeds.get(d, 1.0) for d in devices]
    if any(v <= 0.0 for v in vals):
        return 0.0
    return min(vals)


def _redistribute_mb(total_mb: int, replica_speeds: list) -> list:
    """Greyhound-style: micro-batches proportional to replica speed."""
    s = [max(v, 0.0) for v in replica_speeds]
    tot = sum(s)
    if tot <= 0:
        return [0] * len(s)
    raw = [v / tot * total_mb for v in s]
    out = [int(x) for x in raw]
    # distribute remainder to largest fractional parts, keep >=1 for live reps
    rem = total_mb - sum(out)
    order = sorted(range(len(s)), key=lambda i: raw[i] - out[i], reverse=True)
    for i in range(rem):
        out[order[i % len(order)]] += 1
    for i, v in enumerate(s):
        if v > 0 and out[i] == 0:
            j = max(range(len(out)), key=lambda k: out[k])
            out[j] -= 1
            out[i] += 1
    return out


@dataclass
class BasePolicy:
    plan0: ParallelPlan
    layer_costs: list
    handles_failslow: bool = False
    handles_failstop: bool = False
    name: str = "base"
    reconfig_cost_s: float = 5.0

    def _replica_bottleneck_speed(self, plan, stage_speeds, r) -> float:
        pp = plan.replicas[r].pp
        vals = [stage_speeds.get((r, s), 0.0) for s in range(pp)]
        return min(vals) if vals else 0.0

    def decide(self, speeds, *, changed: bool,
               excluded=frozenset(), risk=None,
               credit=None) -> PolicyDecision:
        """``excluded``: lifecycle-quarantined devices; ``risk``: per-device
        hazard scores from the lifecycle hazard estimator; ``credit``:
        per-device unified credit scores (supersede ``risk`` when present).
        Only policies with a failure-lifecycle story (ResiHP) act on any of
        them — baselines ignore them, mirroring their lack of flap/hazard
        memory (§3 limitations)."""
        raise NotImplementedError


@dataclass
class ReCyclePolicy(BasePolicy):
    name: str = "recycle"
    handles_failstop: bool = True
    failslow_aware: bool = False  # strengthened flag

    def __post_init__(self):
        self.handles_failslow = self.failslow_aware
        if self.failslow_aware:
            self.name = "recycle+"

    def decide(self, speeds, *, changed: bool,
               excluded=frozenset(), risk=None,
               credit=None) -> PolicyDecision:
        plan = self.plan0
        dead, stage_speeds = [], {}
        eff = dict(speeds)
        if not self.failslow_aware:
            pass  # slowdowns still physically apply; no reaction
        for r, rep in enumerate(plan.replicas):
            for s, st in enumerate(rep.stages):
                v = _group_speed_conservative(st.devices, eff)
                stage_speeds[(r, s)] = v
                if v <= 0.0:
                    dead.append((r, s))
        pp = plan.replicas[0].pp
        aborted = any(
            all((r, s) in dead for r in range(plan.dp)) for s in range(pp)
        )
        n_mb = [plan.microbatches] * plan.dp
        if self.failslow_aware:  # strengthened: Greyhound-style redistribution
            rep_speed = [
                min((stage_speeds[(r, s)] for s in range(pp)
                     if stage_speeds[(r, s)] > 0), default=0.0)
                or 1e-3
                for r in range(plan.dp)
            ]
            n_mb = _redistribute_mb(plan.microbatches * plan.dp, rep_speed)
        return PolicyDecision(
            plan=plan.replace(dead_stages=tuple(dead)),
            stage_speeds=stage_speeds,
            migration_policy="recycle",
            n_mb=n_mb,
            reconfig_overhead_s=self.reconfig_cost_s if changed else 0.0,
            aborted=aborted,
            detail="strengthened" if self.failslow_aware else "vanilla",
        )


@dataclass
class OobleckPolicy(BasePolicy):
    name: str = "oobleck"
    handles_failstop: bool = True
    failslow_aware: bool = False
    fault_budget_frac: float = 0.3  # precomputed templates cover this much loss
    reconfig_cost_s: float = 25.0  # template switch + state redistribution

    def __post_init__(self):
        self.handles_failslow = self.failslow_aware
        if self.failslow_aware:
            self.name = "oobleck+"

    def decide(self, speeds, *, changed: bool,
               excluded=frozenset(), risk=None,
               credit=None) -> PolicyDecision:
        plan0 = self.plan0
        pp = plan0.replicas[0].pp
        lost = sum(1 for d in plan0.devices if speeds.get(d, 1.0) <= 0.0)
        aborted = lost > self.fault_budget_frac * len(plan0.devices)

        # rebuild each replica: drop dead-TP-group stages, merge their layers
        new_replicas, stage_speeds = [], {}
        for r, rep in enumerate(plan0.replicas):
            alive_stages = [
                (s, st) for s, st in enumerate(rep.stages)
                if _group_speed_conservative(st.devices, speeds) > 0.0
            ]
            if not alive_stages:
                aborted = True
                new_replicas.append(rep)
                continue
            n_alive = len(alive_stages)
            # template: even contiguous re-split of all layers over survivors
            L = sum(st.n_layers for st in rep.stages)
            per = [L // n_alive + (1 if i < L % n_alive else 0) for i in range(n_alive)]
            off, stages = 0, []
            for i, (s, st) in enumerate(alive_stages):
                stages.append(StagePlan(st.devices, tuple(range(off, off + per[i]))))
                off += per[i]
            new_replicas.append(ReplicaPlan(tuple(stages)))
        # pad replicas to a uniform stage count for bookkeeping: speeds only
        for r, rep in enumerate(new_replicas):
            for s, st in enumerate(rep.stages):
                v = _group_speed_conservative(st.devices, speeds)
                # fewer stages => each stage holds more layers; fold the extra
                # work into the speed so bottleneck math stays comparable
                stage_speeds[(r, s)] = v * (len(rep.stages) / pp)
        plan = plan0.replace(replicas=tuple(new_replicas), dead_stages=())

        n_mb = [plan0.microbatches] * plan0.dp
        rep_speed = [
            self._replica_bottleneck_speed(plan, stage_speeds, r)
            for r in range(plan.dp)
        ]
        # Oobleck rebalances the global batch across heterogeneous pipelines
        n_mb = _redistribute_mb(plan0.microbatches * plan0.dp,
                                [v if v > 0 else 1e-3 for v in rep_speed])
        if not self.failslow_aware:
            # vanilla ignores fail-slow in its templates: redistribution keys
            # only on structure (stage counts), not on measured speeds
            struct_speed = [len(rep.stages) and pp / len(rep.stages) for rep in new_replicas]
            struct_speed = [1.0 / v if v else 0.0 for v in struct_speed]
            n_mb = _redistribute_mb(plan0.microbatches * plan0.dp,
                                    [v if v > 0 else 1e-3 for v in struct_speed])
        return PolicyDecision(
            plan=plan,
            stage_speeds=stage_speeds,
            migration_policy="none",
            n_mb=n_mb,
            reconfig_overhead_s=self.reconfig_cost_s if changed else 0.0,
            aborted=aborted,
            detail="strengthened" if self.failslow_aware else "vanilla",
        )


@dataclass
class GreyhoundPolicy(BasePolicy):
    name: str = "greyhound"
    handles_failslow: bool = True

    def decide(self, speeds, *, changed: bool,
               excluded=frozenset(), risk=None,
               credit=None) -> PolicyDecision:
        plan = self.plan0
        pp = plan.replicas[0].pp
        stage_speeds, dead = {}, []
        for r, rep in enumerate(plan.replicas):
            for s, st in enumerate(rep.stages):
                v = _group_speed_conservative(st.devices, speeds)
                stage_speeds[(r, s)] = v
                if v <= 0:
                    dead.append((r, s))
        aborted = bool(dead)  # no fail-stop story
        rep_speed = [
            min(stage_speeds[(r, s)] for s in range(pp)) for r in range(plan.dp)
        ]
        n_mb = _redistribute_mb(plan.microbatches * plan.dp,
                                [v if v > 0 else 1e-3 for v in rep_speed])
        return PolicyDecision(
            plan=plan.replace(dead_stages=tuple(dead)),
            stage_speeds=stage_speeds,
            migration_policy="none",
            n_mb=n_mb,
            reconfig_overhead_s=(self.reconfig_cost_s if changed else 0.0),
            aborted=aborted,
        )


@dataclass
class AdaptraPolicy(BasePolicy):
    name: str = "adaptra"
    handles_failslow: bool = True
    comm_recovery: float = 0.85  # share of a *network* slowdown hidden by
    # asynchronous P2P + schedule adaptation
    compute_recovery: float = 0.25  # ZB bubble-filling hides a bit of compute

    def decide(self, speeds, *, changed: bool,
               excluded=frozenset(), risk=None,
               credit=None) -> PolicyDecision:
        plan = self.plan0
        stage_speeds, dead = {}, []
        for r, rep in enumerate(plan.replicas):
            for s, st in enumerate(rep.stages):
                v = _group_speed_conservative(st.devices, speeds)
                if v <= 0:
                    dead.append((r, s))
                stage_speeds[(r, s)] = v
        return PolicyDecision(
            plan=plan.replace(dead_stages=tuple(dead)),
            stage_speeds=stage_speeds,
            migration_policy="none",
            n_mb=[plan.microbatches] * plan.dp,
            reconfig_overhead_s=(self.reconfig_cost_s if changed else 0.0),
            aborted=bool(dead),
            schedule="zb",
            slowdown_recovery=self.compute_recovery,
        )


@dataclass
class ResiHPPolicy(BasePolicy):
    name: str = "resihp"
    handles_failslow: bool = True
    handles_failstop: bool = True
    k_min: int = 1
    delta: int = 1
    group_rebuild_s: float = 1.8  # Fig. 13: comm-group reconstruction < 2s
    layer_transfer_s_per_layer: float = 0.35
    # None => charge measured wall-clock planning time (Fig. 13 methodology);
    # a float pins the charge for deterministic replay (golden tests)
    plan_overhead_fixed: Optional[float] = None
    # modeled planning-cost curve (PlanOverheadModel; ``True`` for the
    # checked-in default fit): deterministic *and* scale-aware, unlike the
    # measured charge (nondeterministic) or the fixed pin (a constant).
    # Resolution order: fixed > model > measured.
    plan_overhead_model: Optional[object] = None
    scheduler: Optional[Scheduler] = None
    # ablation switches (Fig. 11)
    enable_selective: bool = True
    enable_repartition: bool = True
    migration_mode: str = "resihp"  # 'resihp' | 'recycle' (progress-unaware)
    # failure-lifecycle policies (flap quarantine / ramp-aware drift / rejoin
    # admission — see repro.core.detector.lifecycle). Default OFF: the paper's
    # one-shot failure model, bit-for-bit the pre-lifecycle behaviour. Pass
    # ``lifecycle=True`` for the default LifecycleConfig or a LifecycleConfig
    # for tuned/ablated policies; TrainingSim builds the manager from it.
    lifecycle: Optional[object] = None
    # per-device hazard awareness (HazardPolicyConfig; ``True`` for defaults;
    # default OFF): hazard-keyed quarantine backoff + risk-aware placement,
    # both fed by the lifecycle's FailureHistory — so enabling ``hazard``
    # turns the default lifecycle on if it was off.
    hazard: Optional[object] = None
    # nonuniform TP shard widths (NTPConfig; ``True`` for defaults; default
    # OFF): a mildly-slow device keeps a proportionally smaller shard
    # instead of being excluded — see tp_reconfig.shrink_shard_candidate.
    ntp: Optional[object] = None
    # correlated-failure-domain awareness (DomainPolicyConfig; ``True`` for
    # defaults; default OFF): pooled domain-level quarantine + domain-spread
    # placement risk + checkpoint/restart economics. Reads the same
    # FailureHistory records as the hazard estimator, so enabling
    # ``domains`` turns the default hazard (and therefore lifecycle)
    # switch on if it was off.
    domains: Optional[object] = None
    # unified device credit (CreditConfig; ``True`` loads the fitted weights
    # from src/repro/configs/credit_fitted.json; default OFF): one learned
    # health scalar replaces the four hand-thresholded signals — quarantine
    # entry/backoff and probe admission key on credit bands, placement
    # tie-breaks take the credit vector (superseding device_risk), NTP
    # shrink-shard retention is credit-gated, and the restart-vs-adapt
    # decision weighs the plan's aggregate credit. The model reads the
    # hazard estimator's windowed risk, so enabling ``credit`` turns the
    # default hazard (and therefore lifecycle) switch on if it was off.
    credit: Optional[object] = None
    # physical topology (device -> node; TrainingSim wires topo.node_of) so
    # the Scheduler honors the §6.1 node-local-standby contract. None =>
    # plan-only use without a topology, whole-pool standby offers.
    node_of: Optional[object] = None
    # device -> failure-domain map (TrainingSim wires topo.pdu_of & co. when
    # ``domains`` is on): lets the Scheduler order standby offers toward
    # less-failed domains. None => legacy offer order, byte-identical.
    domain_of: Optional[object] = None

    def __post_init__(self):
        # the plan whose layers are currently resident on the devices — what
        # a reconfiguration's layer-transfer volume must be diffed against
        self._prev_plan = self.plan0
        if self.lifecycle is True:
            from repro.core.detector.lifecycle import LifecycleConfig

            self.lifecycle = LifecycleConfig()
        if self.domains is True:
            from repro.cluster.hazard import DomainPolicyConfig

            self.domains = DomainPolicyConfig()
        if self.domains:
            import dataclasses as _dc

            if self.domains.restart is True:
                from repro.checkpoint import RestartCostModel

                self.domains = _dc.replace(self.domains,
                                           restart=RestartCostModel())
            if not self.hazard:
                self.hazard = True  # pooled detection rides on the same
                # FailureHistory evidence the per-device estimator keeps
        if self.credit is True:
            from repro.core.detector.credit import fitted_credit_config

            self.credit = fitted_credit_config()
        if self.credit and not self.hazard:
            # the credit model's risk_excess signal is the hazard
            # estimator's windowed score
            self.hazard = True
        if self.hazard is True:
            from repro.cluster.hazard import HazardPolicyConfig

            self.hazard = HazardPolicyConfig()
        if self.hazard and not self.lifecycle:
            from repro.core.detector.lifecycle import LifecycleConfig

            self.lifecycle = LifecycleConfig()
        if self.plan_overhead_model is True:
            self.plan_overhead_model = PlanOverheadModel()
        if self.ntp is True:
            from repro.core.scheduler.tp_reconfig import NTPConfig

            self.ntp = NTPConfig()
        if self.scheduler is None:
            self.scheduler = Scheduler(
                layer_costs=list(self.layer_costs), k_min=self.k_min,
                delta=self.delta,
                enable_selective=self.enable_selective,
                enable_repartition=self.enable_repartition,
                ntp=self.ntp,
                ntp_min_credit=(self.credit.ntp_band if self.credit else 0.0),
                node_of=self.node_of,
                domain_of=self.domain_of,
                # effective speeds are normalized against the healthy plan's
                # widest group even when re-adapting a shrunk plan
                baseline_tp=max(st.tp for rep in self.plan0.replicas
                                for st in rep.stages),
                # with a fixed or modeled planning charge the measured wall
                # clock is never read — keep the hot loop syscall-free so
                # plan-cache hits are truly free
                measure_overhead=(self.plan_overhead_fixed is None
                                  and self.plan_overhead_model is None),
            )

    def decide(self, speeds, *, changed: bool,
               excluded=frozenset(), risk=None,
               credit=None) -> PolicyDecision:
        failed = {d for d, v in speeds.items() if v <= 0.0}
        # quarantine exclusion is owned by Scheduler.adapt (it unions
        # quarantined into failed and records the note); risk flows through
        # to the placement tie-breaks (risk-aware planning, hazard switch)
        # and credit supersedes it (unified-credit switch)
        ad = self.scheduler.adapt(self.plan0, speeds, failed=failed,
                                  quarantined=frozenset(excluded),
                                  device_risk=risk, device_credit=credit)
        overhead = 0.0
        if changed:
            # layer-transfer volume: layers each stage must *fetch* relative
            # to the plan currently executing — not plan0, which overcharged
            # every reconfiguration after the first (consecutive exclusion
            # plans re-paid transfers for layers already in place)
            moved_layers = 0
            for s, (old, new) in enumerate(
                zip(self._prev_plan.replicas[0].stages,
                    ad.plan.replicas[0].stages)
            ):
                moved_layers += len(set(new.layers) - set(old.layers))
            if self.plan_overhead_fixed is not None:
                plan_s = self.plan_overhead_fixed
            elif self.plan_overhead_model is not None:
                plan_s = self.plan_overhead_model.predict(
                    len(self.plan0.devices), len(self.layer_costs))
            else:
                plan_s = ad.plan_overhead_s
            overhead = (
                plan_s
                + self.group_rebuild_s
                + moved_layers * self.layer_transfer_s_per_layer
            )
        notes = list(ad.notes)
        if changed and self.domains is not None \
                and getattr(self.domains, "restart", None) is not None:
            # checkpoint/restart economics: when the modeled cost of
            # restart-from-checkpoint (relaunch + restore read + replayed
            # work) undercuts live adaptation (replan + group rebuild +
            # layer migration), take the restart — state reaches the new
            # plan via the checkpoint restore instead of layer transfers,
            # and the session is charged the restart price. Strictly-below
            # comparison: at equal cost live adaptation wins (no lost
            # iterations to replay outside the model's expectation).
            restart_s = self.domains.restart.restart_cost_s()
            threshold = overhead
            if credit and self.credit is not None \
                    and getattr(self.credit, "restart_weighting", False):
                # aggregate group credit weighs the restart-vs-adapt call: a
                # low-credit plan is likely interrupted again before the
                # restored session repays the restore, so the live-adaptation
                # threshold is discounted by the plan's mean credit
                vals = [credit.get(d, 1.0) for d in ad.plan.devices]
                if vals:
                    threshold = overhead * (sum(vals) / len(vals))
            if restart_s < threshold:
                notes.insert(0, "restart-from-checkpoint: "
                                f"{restart_s:.3f}s < live {threshold:.3f}s")
                overhead = restart_s
        self._prev_plan = ad.plan
        return PolicyDecision(
            plan=ad.plan,
            stage_speeds=ad.stage_speeds,
            migration_policy=self.migration_mode,
            n_mb=[self.plan0.microbatches] * self.plan0.dp,
            reconfig_overhead_s=overhead,
            aborted=ad.restore_required,  # needs checkpoint fallback (Fig. 8b)
            delta=self.delta,
            detail="; ".join(notes[:3]),
        )


def make_policy(name: str, plan0: ParallelPlan, layer_costs, **kw) -> BasePolicy:
    name = name.lower()
    if name == "resihp":
        return ResiHPPolicy(plan0, layer_costs, **kw)
    if name == "recycle":
        return ReCyclePolicy(plan0, layer_costs, **kw)
    if name in ("recycle+", "recycle-strong"):
        return ReCyclePolicy(plan0, layer_costs, failslow_aware=True, **kw)
    if name == "oobleck":
        return OobleckPolicy(plan0, layer_costs, **kw)
    if name in ("oobleck+", "oobleck-strong"):
        return OobleckPolicy(plan0, layer_costs, failslow_aware=True, **kw)
    if name == "greyhound":
        return GreyhoundPolicy(plan0, layer_costs, **kw)
    if name == "adaptra":
        return AdaptraPolicy(plan0, layer_costs, **kw)
    raise ValueError(name)
