"""Sharding-rules engine.

Models declare *logical* axes on every parameter/activation; a ShardingPolicy
maps logical axes onto mesh axes with divisibility-aware fallbacks. This is
how the same model code lowers on a single CPU device (NULL_POLICY), the
(16,16) production pod, the (2,16,16) multi-pod mesh, and arbitrary per-stage
meshes built by the ResiHP Scheduler after a reconfiguration.

Logical axes used across the model zoo:
  batch, seq, dmodel, vocab, heads, kv_heads, head_dim, ffn, expert,
  layers (scan stack), dinner (mamba/xlstm inner), state, conv, dtrank
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Any, Optional

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


@dataclass(frozen=True)
class Annot:
    """A parameter annotated with logical axis names (one per dim)."""

    value: Any  # jnp.ndarray | ShapeDtypeStruct
    axes: tuple[Optional[str], ...]

    def __post_init__(self):
        if hasattr(self.value, "shape"):  # tolerate treedef placeholder objects
            assert len(self.axes) == len(self.value.shape), (self.axes, self.value.shape)


# Registered as a pytree node (axes ride along as aux data) so annotated trees
# pass through jax.eval_shape / jit tracing transparently.
jax.tree_util.register_pytree_node(
    Annot,
    lambda a: ((a.value,), a.axes),
    lambda axes, children: Annot(children[0], axes),
)


def annotate(value, *axes) -> Annot:
    return Annot(value, tuple(axes))


def split_annotations(tree):
    """Split a pytree of Annot into (values_tree, axes_tree)."""
    is_annot = lambda x: isinstance(x, Annot)
    values = jax.tree.map(lambda a: a.value, tree, is_leaf=is_annot)
    axes = jax.tree.map(lambda a: a.axes, tree, is_leaf=is_annot)
    return values, axes


@dataclass(frozen=True)
class ShardingPolicy:
    """Maps logical axes -> mesh axes. None mesh = single-device no-op."""

    mesh: Optional[Mesh] = None
    dp_axes: tuple[str, ...] = ()  # batch / FSDP axes, e.g. ('pod', 'data')
    tp_axis: Optional[str] = None  # tensor-parallel axis, e.g. 'model'
    fsdp: bool = True  # shard params (and opt state) over dp_axes
    seq_parallel: bool = False  # shard activation seq over tp between blocks
    decode_kv_seq_shard: bool = True  # shard KV caches over tp on the seq dim
    expert_parallel: bool = False  # shard experts over tp (vs per-expert TP)
    # batch sharding can be disabled for global_batch < dp (long_500k)
    shard_batch: bool = True
    # joint attention TP decision: 'heads' | 'head_dim' | None. Must be one
    # consistent choice per arch or SPMD falls back to full remat between the
    # q projection and the attention einsums.
    attn_shard: Optional[str] = "heads"

    # ------------------------------------------------------------- sizes
    def axis_size(self, axes) -> int:
        if self.mesh is None:
            return 1
        if axes is None:
            return 1
        if isinstance(axes, str):
            axes = (axes,)
        return int(np.prod([self.mesh.shape[a] for a in axes])) if axes else 1

    @property
    def tp(self) -> int:
        return self.axis_size(self.tp_axis)

    @property
    def dp(self) -> int:
        return self.axis_size(self.dp_axes)

    # ------------------------------------------------------- logical->mesh
    def _mesh_axis_for(self, logical: Optional[str], dim: int, used: set) -> Any:
        """Pick the mesh axis (or axes) for one logical axis, or None."""
        tp, dp = self.tp_axis, self.dp_axes
        if logical is None or self.mesh is None:
            return None

        def tp_free():
            return tp is not None and tp not in used

        def dp_free():
            return bool(dp) and not (set(dp) & used)

        if logical in ("vocab", "ffn", "dinner"):
            if tp_free() and dim % self.tp == 0:
                return tp
        elif logical == "heads":
            if self.attn_shard == "heads" and tp_free() and dim % self.tp == 0:
                return tp
        elif logical == "kv_heads":
            if self.attn_shard == "heads" and tp_free() and dim % self.tp == 0:
                return tp
        elif logical == "head_dim":
            if self.attn_shard == "head_dim" and tp_free() and dim % self.tp == 0:
                return tp
        elif logical == "expert":
            if self.expert_parallel and tp_free() and dim % self.tp == 0:
                return tp
        elif logical == "dmodel":
            # FSDP axis for parameters
            if self.fsdp and dp_free() and dim % self.dp == 0:
                return dp if len(dp) > 1 else dp[0]
        elif logical == "batch":
            if self.shard_batch and dp_free():
                return dp if len(dp) > 1 else dp[0]
        elif logical == "seq":
            if self.seq_parallel and tp_free() and dim % self.tp == 0:
                return tp
        elif logical == "kv_seq":
            if not self.decode_kv_seq_shard:
                return None
            if not self.shard_batch and tp_free() and dp_free() and dim % (self.tp * self.dp) == 0:
                # tiny-batch long-context decode: spread the KV sequence over
                # every mesh axis (flash-decoding-style split)
                return tuple(dp) + (tp,)
            if tp_free() and dim % self.tp == 0:
                return tp
        return None

    def spec_for(self, axes: tuple, shape: tuple) -> P:
        """PartitionSpec for a tensor with the given logical axes."""
        entries, used = [], set()
        # Two passes: high-priority TP targets first so e.g. ('heads','head_dim')
        # puts TP on heads when possible, then head_dim never double-books it.
        order = sorted(
            range(len(axes)),
            key=lambda i: {"vocab": 0, "ffn": 0, "dinner": 0, "heads": 0, "expert": 1,
                           "kv_heads": 1, "head_dim": 2, "batch": 0, "kv_seq": 1,
                           "seq": 3, "dmodel": 4}.get(axes[i], 9),
        )
        picked = {}
        for i in order:
            ax = self._mesh_axis_for(axes[i], shape[i], used)
            if ax is not None:
                picked[i] = ax
                used.update((ax,) if isinstance(ax, str) else ax)
        for i in range(len(axes)):
            entries.append(picked.get(i))
        return P(*entries)

    def sharding_for(self, axes: tuple, shape: tuple):
        if self.mesh is None:
            return None
        return NamedSharding(self.mesh, self.spec_for(axes, shape))

    # --------------------------------------------------------- activations
    def constrain(self, x, *axes):
        """with_sharding_constraint by logical axes (no-op without a mesh)."""
        if self.mesh is None:
            return x
        spec = self.spec_for(tuple(axes), x.shape)
        return jax.lax.with_sharding_constraint(x, NamedSharding(self.mesh, spec))

    def batch_spec(self) -> P:
        if self.mesh is None or not self.dp_axes or not self.shard_batch:
            return P()
        return P(self.dp_axes if len(self.dp_axes) > 1 else self.dp_axes[0])

    # ------------------------------------------------------------ params
    def tree_shardings(self, annot_tree):
        """NamedSharding tree for a pytree of Annot."""
        is_annot = lambda x: isinstance(x, Annot)
        return jax.tree.map(
            lambda a: self.sharding_for(a.axes, a.value.shape), annot_tree, is_leaf=is_annot
        )

    def tree_specs(self, axes_tree, values_tree):
        """PartitionSpec tree given separate axes/values trees."""
        return jax.tree.map(
            lambda ax, v: self.spec_for(ax, v.shape),
            axes_tree,
            values_tree,
            is_leaf=lambda x: isinstance(x, tuple) and all(a is None or isinstance(a, str) for a in x),
        )

    def replace(self, **kw) -> "ShardingPolicy":
        return dataclasses.replace(self, **kw)


NULL_POLICY = ShardingPolicy()


def policy_for_mesh(mesh: Optional[Mesh], **kw) -> ShardingPolicy:
    """Infer dp/tp axes from a mesh's axis names."""
    if mesh is None:
        return NULL_POLICY
    names = mesh.axis_names
    dp = tuple(a for a in names if a in ("pod", "data", "replica", "fsdp"))
    tp = "model" if "model" in names else None
    return ShardingPolicy(mesh=mesh, dp_axes=dp, tp_axis=tp, **kw)
