from repro.parallel.sharding import (  # noqa: F401
    Annot,
    NULL_POLICY,
    ShardingPolicy,
    annotate,
    split_annotations,
)
