"""Hierarchical two-level heartbeat fail-stop detection (paper §5.1, §7).

Intra-node: every worker (device) periodically reports a compact liveness
signal + local training progress to its node-local monitor; the monitor marks
a device failed after `miss_threshold` consecutive missed heartbeats.
Inter-node: a central coordinator tracks only node monitors (a TCP socket per
node in the paper; a registered endpoint here) — so coordinator load scales
with nodes, not devices. A dead node monitor fails the whole node.

The wire is simulated (in-process, clock-driven) but the protocol and state
machines are the real ones; `ClusterSim` advances `now` and calls `beat` for
every live device each interval.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable, Optional


@dataclass
class DeviceHB:
    last_beat: float = -1.0
    last_progress: int = -1
    missed: int = 0
    failed: bool = False


@dataclass
class NodeMonitor:
    """Node-local aggregator: raw device heartbeats stay on the node."""

    node_id: int
    devices: list  # device ids hosted on this node
    interval: float = 1.0
    miss_threshold: int = 3
    state: dict = field(default_factory=dict)
    alive: bool = True

    def __post_init__(self):
        for d in self.devices:
            self.state[d] = DeviceHB()

    def beat(self, device_id, now: float, progress: int = 0):
        hb = self.state[device_id]
        hb.last_beat = now
        hb.last_progress = progress
        hb.missed = 0

    def sweep(self, now: float) -> list:
        """Periodic check; returns newly-failed device ids (the only thing
        forwarded upstream — decisions, not raw beats)."""
        newly = []
        for d, hb in self.state.items():
            if hb.failed:
                continue
            expected = int((now - hb.last_beat) / self.interval) if hb.last_beat >= 0 else 10**9
            hb.missed = max(hb.missed, expected)
            if hb.missed >= self.miss_threshold:
                hb.failed = True
                newly.append(d)
        return newly


@dataclass
class HeartbeatMonitor:
    """Central coordinator over node monitors (level 2)."""

    interval: float = 1.0
    miss_threshold: int = 3
    nodes: dict = field(default_factory=dict)  # node_id -> NodeMonitor
    node_last_seen: dict = field(default_factory=dict)
    failed_devices: set = field(default_factory=set)
    failed_nodes: set = field(default_factory=set)
    device_node: dict = field(default_factory=dict)  # device_id -> node_id
    on_failstop: Optional[Callable] = None  # callback(list[device_id], now)

    def register_node(self, node_id: int, device_ids: list) -> NodeMonitor:
        mon = NodeMonitor(node_id, list(device_ids), self.interval, self.miss_threshold)
        self.nodes[node_id] = mon
        self.node_last_seen[node_id] = -1.0
        for d in device_ids:
            self.device_node[d] = node_id
        return mon

    # -------------------------------------------------------------- ingest
    def device_beat(self, node_id: int, device_id, now: float, progress: int = 0):
        if node_id in self.failed_nodes or not self.nodes[node_id].alive:
            return  # dead node's agent can't relay
        self.nodes[node_id].beat(device_id, now, progress)

    def node_beat(self, node_id: int, now: float):
        """The node agent's own keepalive on the TCP side channel."""
        self.node_last_seen[node_id] = now

    def kill_node(self, node_id: int):
        """Simulate a node crash: its agent stops beating entirely."""
        self.nodes[node_id].alive = False

    def mark_failed(self, device_id):
        """Record a device as failed through an out-of-band channel (a
        validation pass that found it dead — the validation-as-fail-stop
        path). The next sweep will not re-report it, so the NCCL-timeout
        stall is not paid twice for a failure the system already knows."""
        nid = self.device_node.get(device_id)
        if nid is not None:
            hb = self.nodes[nid].state[device_id]
            hb.failed = True
        self.failed_devices.add(device_id)

    # -------------------------------------------------------------- revive
    def revive(self, device_id, now: float = 0.0):
        """A repaired device re-announces itself (elastic rejoin): clear the
        failed state so its *next* fail-stop is detectable again. Without
        this, ``failed_devices`` / ``DeviceHB.failed`` were never cleared and
        a flapping or renewal-process device could silently die a second
        time. The device is credited a fresh beat at ``now`` so it is not
        instantly re-failed before its first post-rejoin heartbeat."""
        nid = self.device_node.get(device_id)
        if nid is None:
            return
        if nid in self.failed_nodes or not self.nodes[nid].alive:
            self.revive_node(nid, now)
        hb = self.nodes[nid].state[device_id]
        hb.failed = False
        hb.missed = 0
        hb.last_beat = now
        self.failed_devices.discard(device_id)

    def revive_node(self, node_id: int, now: float = 0.0):
        """Restore a node agent's side channel (node repaired / rack power
        back). Devices on the node stay individually failed until they are
        revived themselves."""
        self.failed_nodes.discard(node_id)
        mon = self.nodes[node_id]
        mon.alive = True
        self.node_last_seen[node_id] = now

    # --------------------------------------------------------------- sweep
    def sweep(self, now: float) -> list:
        """Run both levels; returns newly failed device ids."""
        newly = []
        for nid, mon in self.nodes.items():
            if nid in self.failed_nodes:
                continue
            last = self.node_last_seen[nid]
            expected = int((now - last) / self.interval) if last >= 0 else 10**9
            if not mon.alive or expected >= self.miss_threshold:
                # socket disconnection: fail the whole node immediately
                self.failed_nodes.add(nid)
                for d in mon.devices:
                    if d not in self.failed_devices:
                        self.failed_devices.add(d)
                        newly.append(d)
                continue
            for d in mon.sweep(now):
                if d not in self.failed_devices:
                    self.failed_devices.add(d)
                    newly.append(d)
        if newly and self.on_failstop is not None:
            self.on_failstop(newly, now)
        return newly

    # ------------------------------------------------------------ stats
    @property
    def n_messages_per_interval(self) -> int:
        """Coordinator-side message load: one per *node*, not per device —
        the scalability claim of §5.1."""
        return len(self.nodes)
