"""DAG-based analytical pipeline simulator (paper §5.2, Eq. 2).

Chunks (F/B/W per micro-batch per stage) are DAG vertices; edges carry data
dependencies (with P2P cost) and resource ordering (zero cost, fixed by the
schedule). Earliest-start times follow

    t_start(v) = max_{u in pred(v)} ( t_start(u) + T_cost(u) + T_edge(u, v) )

and the healthy iteration time is the critical-path length. The same engine
powers (a) the Detector's workload-aware filter, (b) the Scheduler's
progress-aware migration what-ifs (Alg. 1, step 3 'simulated first'), and
(c) the cluster-scale throughput benchmarks.

Executors are (replica, stage) pairs — so cross-replica migrations (Fig. 6)
are just chunks whose executor differs from their home replica.
"""
from __future__ import annotations

import heapq
from dataclasses import dataclass, field
from typing import Callable, Optional


@dataclass(frozen=True)
class ChunkId:
    kind: str  # 'F' | 'B' | 'W'
    mb: int  # micro-batch index
    stage: int
    replica: int = 0

    def __repr__(self):
        return f"{self.kind}{self.mb}@s{self.stage}r{self.replica}"


@dataclass
class Chunk:
    cid: ChunkId
    cost: float  # execution seconds on its executor (already speed-scaled)
    deps: list = field(default_factory=list)  # [(ChunkId, edge_cost)]
    executor: tuple = None  # (replica, stage) it runs on


@dataclass
class PipelineDag:
    chunks: dict  # ChunkId -> Chunk
    exec_order: dict  # executor -> [ChunkId] in schedule order

    def simulate(self):
        """Returns (iteration_time, finish_times dict, per-executor idle)."""
        finish: dict = {}
        start: dict = {}
        n_pending_dep = {}
        dependents: dict = {}
        for cid, ch in self.chunks.items():
            n_pending_dep[cid] = 0
            for dep, _ in ch.deps:
                if dep in self.chunks:
                    n_pending_dep[cid] += 1
                    dependents.setdefault(dep, []).append(cid)
        # per-executor cursor: a chunk is runnable when deps done AND it is
        # the next chunk in its executor's order.
        cursor = {e: 0 for e in self.exec_order}
        exec_free = {e: 0.0 for e in self.exec_order}
        done = set()

        def ready(cid):
            e = self.chunks[cid].executor
            order = self.exec_order[e]
            return n_pending_dep[cid] == 0 and order[cursor[e]] == cid

        heap = []
        seq = 0

        def push_ready():
            nonlocal seq
            for e, order in self.exec_order.items():
                if cursor[e] < len(order):
                    cid = order[cursor[e]]
                    if cid not in done and n_pending_dep[cid] == 0:
                        ch = self.chunks[cid]
                        dep_ready = 0.0
                        for dep, edge in ch.deps:
                            if dep in finish:
                                dep_ready = max(dep_ready, finish[dep] + edge)
                        t0 = max(exec_free[e], dep_ready)
                        heapq.heappush(heap, (t0, seq, cid))
                        seq += 1

        push_ready()
        scheduled = set()
        while heap:
            t0, _, cid = heapq.heappop(heap)
            if cid in done:
                continue
            e = self.chunks[cid].executor
            if not ready(cid) or cid in scheduled:
                continue
            ch = self.chunks[cid]
            dep_ready = 0.0
            for dep, edge in ch.deps:
                dep_ready = max(dep_ready, finish[dep] + edge)
            t0 = max(exec_free[e], dep_ready)
            start[cid] = t0
            finish[cid] = t0 + ch.cost
            exec_free[e] = finish[cid]
            done.add(cid)
            cursor[e] += 1
            for d in dependents.get(cid, []):
                n_pending_dep[d] -= 1
            push_ready()

        if len(done) != len(self.chunks):
            missing = [c for c in self.chunks if c not in done][:8]
            raise RuntimeError(f"pipeline deadlock; unexecuted chunks: {missing}")
        total = max(finish.values()) if finish else 0.0
        busy = {e: sum(self.chunks[c].cost for c in order) for e, order in self.exec_order.items()}
        idle = {e: total - b for e, b in busy.items()}
        return total, finish, idle


def build_pipeline_dag(
    *,
    n_stages: int,
    schedule: dict,  # executor (replica, stage) -> ordered [ChunkId]
    chunk_cost: Callable,  # (ChunkId, executor) -> seconds
    p2p_cost: Callable = lambda u, v: 0.0,  # (src ChunkId, dst ChunkId) -> seconds
    placement: Optional[dict] = None,  # ChunkId -> executor override (migration)
) -> PipelineDag:
    """Standard dependency structure:
    F(m,s) <- F(m,s-1); B(m,s) <- B(m,s+1); B(m,last) <- F(m,last);
    B(m,s) <- F(m,s) (same-stage activation availability); W(m,s) <- B(m,s).
    """
    placement = placement or {}
    chunks = {}
    exec_order = {e: list(order) for e, order in schedule.items()}
    for e, order in exec_order.items():
        for cid in order:
            executor = placement.get(cid, e)
            deps = []
            if cid.kind == "F":
                if cid.stage > 0:
                    deps.append(ChunkId("F", cid.mb, cid.stage - 1, cid.replica))
            elif cid.kind == "B":
                deps.append(ChunkId("F", cid.mb, cid.stage, cid.replica))
                if cid.stage < n_stages - 1:
                    deps.append(ChunkId("B", cid.mb, cid.stage + 1, cid.replica))
            elif cid.kind == "W":
                deps.append(ChunkId("B", cid.mb, cid.stage, cid.replica))
            chunks[cid] = Chunk(cid, chunk_cost(cid, executor), [], executor)
            for d in deps:
                chunks[cid].deps.append((d, 0.0))
    # attach P2P costs (data edges between different stages only)
    for cid, ch in chunks.items():
        ch.deps = [
            (d, p2p_cost(d, cid) if (d in chunks and d.stage != cid.stage) else 0.0)
            for d, _ in ch.deps
            if d in chunks
        ]
    return PipelineDag(chunks, exec_order)


def simulate_pipeline(n_stages, n_microbatches, chunk_cost, *, schedule="1f1b",
                      p2p_cost=0.0, replica=0, with_w=None):
    """Convenience: build a single-replica schedule and simulate it."""
    from repro.engine.schedules import make_schedule

    with_w = schedule.startswith("zb") if with_w is None else with_w
    sched = make_schedule(schedule, n_stages, n_microbatches, replica=replica)
    dag = build_pipeline_dag(
        n_stages=n_stages,
        schedule=sched,
        chunk_cost=chunk_cost,
        p2p_cost=(lambda u, v: p2p_cost) if not callable(p2p_cost) else p2p_cost,
    )
    return dag.simulate()
