"""Micro-batch execution-time predictor (paper Eq. 1).

    T_MB ~= alpha * N + beta * sum_i(l_i^2)

alpha captures the linear (MLP/projection) cost per token, beta the quadratic
self-attention cost under sequence packing with block-diagonal masks. Both are
profiled during a warm-up phase and fit by least squares. The predictor is
per-(stage-shape): a pipeline stage with k layers has its own (alpha, beta)
— equivalently we fit per layer and scale, which is what `per_layer=True`
does so the ResiHP Scheduler can re-use the fit after layer repartition.
"""
from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np


@dataclass
class MicroBatchTimePredictor:
    # chunk-kind multipliers relative to forward (paper §5.2: F/B/W chunks)
    backward_ratio: float = 2.0
    weight_ratio: float = 1.0  # W chunk (ZB schedules); B+W ~= full backward
    alpha: float = 0.0
    beta: float = 0.0
    gamma: float = 0.0  # constant per-micro-batch overhead (launch, norm, etc.)
    fitted: bool = False
    _obs: list = field(default_factory=list)

    def observe(self, n_tokens: int, sum_l2: int, seconds: float, n_layers: int = 1):
        """One warm-up measurement of a forward chunk over `n_layers` layers."""
        self._obs.append((n_tokens / n_layers, sum_l2 / n_layers, seconds / n_layers))

    def fit(self):
        if len(self._obs) < 3:
            raise ValueError(f"need >=3 warm-up observations, have {len(self._obs)}")
        arr = np.asarray(self._obs, dtype=np.float64)
        X = np.stack([arr[:, 0], arr[:, 1], np.ones(len(arr))], axis=1)
        y = arr[:, 2]
        coef, *_ = np.linalg.lstsq(X, y, rcond=None)
        self.alpha, self.beta, self.gamma = map(float, coef)
        # cost terms are physically non-negative; clamp tiny negatives from noise
        self.alpha = max(self.alpha, 0.0)
        self.beta = max(self.beta, 0.0)
        self.gamma = max(self.gamma, 0.0)
        self.fitted = True
        return self

    def predict(self, n_tokens: int, sum_l2: int, *, n_layers: int = 1,
                kind: str = "F", speed: float = 1.0) -> float:
        """Expected healthy chunk time for a (packed) micro-batch."""
        assert self.fitted, "call fit() after warm-up"
        t = (self.alpha * n_tokens + self.beta * sum_l2 + self.gamma) * n_layers
        mult = {"F": 1.0, "B": self.backward_ratio, "W": self.weight_ratio}[kind]
        return t * mult / max(speed, 1e-9)

    def mape(self, samples) -> float:
        """Mean absolute percentage error on (n, sum_l2, n_layers, actual)."""
        errs = []
        for n, l2, nl, actual in samples:
            pred = self.predict(n, l2, n_layers=nl)
            errs.append(abs(pred - actual) / max(abs(actual), 1e-12))
        return float(np.mean(errs))


def synthetic_chunk_time(alpha, beta, gamma, n_tokens, sum_l2, n_layers=1,
                         kind="F", speed=1.0, b_ratio=2.0, w_ratio=1.0,
                         noise=0.0, rng=None):
    """Ground-truth generator used by the cluster simulator: same functional
    form the predictor assumes, plus optional multiplicative jitter."""
    t = (alpha * n_tokens + beta * sum_l2 + gamma) * n_layers
    t *= {"F": 1.0, "B": b_ratio, "W": w_ratio}[kind]
    t /= max(speed, 1e-9)
    if noise and rng is not None:
        t *= float(rng.normal(1.0, noise))
    return t
