from repro.core.detector.predictor import MicroBatchTimePredictor  # noqa: F401
from repro.core.detector.dag_sim import PipelineDag, simulate_pipeline  # noqa: F401
from repro.core.detector.changepoint import BOCPD, CusumDetector, SlopeDriftDetector  # noqa: F401
from repro.core.detector.heartbeat import HeartbeatMonitor  # noqa: F401
from repro.core.detector.detector import Detector, FailureReport  # noqa: F401
from repro.core.detector.lifecycle import (  # noqa: F401
    FailureHistory,
    LifecycleConfig,
    LifecycleManager,
    RejoinDecision,
)
