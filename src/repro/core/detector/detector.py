"""The ResiHP Detector (paper §5): fail-stop via hierarchical heartbeats,
fail-slow via change-point detection on the iteration-time series with a
workload-aware filter.

Fail-slow pipeline per iteration (paper §5.2):
  1. append observed iteration time to the series; run the change-point
     detector (Greyhound-style proxy signal);
  2. on a change point, *analytically* estimate the expected healthy
     iteration time for the current workload (Eq. 1 micro-batch predictor +
     Eq. 2 DAG critical path, both supplied as `healthy_time_fn`);
  3. if observed > (1 + filter_threshold) * predicted  -> run the expensive
     validation phase (`validate_fn`) to localize degraded devices;
     else -> benign workload fluctuation: drop the point from the series and
     skip validation (this is what kills Greyhound's false alarms).

`workload_filter=False` reproduces Greyhound's behaviour (every change point
pays validation) — the Table 5 baseline.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Callable, Optional

from repro.core.detector.changepoint import CusumDetector
from repro.core.detector.heartbeat import HeartbeatMonitor


@dataclass(frozen=True)
class FailureReport:
    kind: str  # 'fail-stop' | 'fail-slow'
    devices: tuple  # device ids; fail-slow entries are (device_id, speed)
    iteration: int
    time: float
    detail: str = ""


@dataclass
class DetectorStats:
    change_points: int = 0
    drift_alarms: int = 0  # change points raised by the slope-drift test
    validations: int = 0
    false_alarms: int = 0
    filtered_benign: int = 0
    suppressed_failstop: int = 0  # alarms explained by a just-detected fail-stop
    missed_filter: int = 0  # filter said benign but a real failure existed
    detections: int = 0
    carried_rebaselines: int = 0  # rebaselines that kept the scaled baseline
    validation_overhead_s: float = 0.0
    filter_overhead_s: float = 0.0

    def as_dict(self):
        return dict(self.__dict__)


@dataclass
class Detector:
    """Owns the fail-stop heartbeat hierarchy and the fail-slow series logic.

    healthy_time_fn(workload) -> predicted healthy iteration seconds.
    validate_fn(iteration) -> list[(device_id, measured_speed)] of degraded
        devices (empty if none). Its cost models Greyhound's validation pass.
    """

    healthy_time_fn: Callable
    validate_fn: Callable
    heartbeat: HeartbeatMonitor = field(default_factory=HeartbeatMonitor)
    workload_filter: bool = True
    filter_threshold: float = 0.25  # the 25% rule
    validation_cost_s: float = 3.0  # paper Table 5: seconds per validation
    filter_cost_s: float = 0.045  # paper Table 5: 34-49 ms per filtered alarm
    changepoint_factory: Callable = CusumDetector
    # failure-lifecycle drift policy (default off = paper behaviour):
    # drift_factory adds a slope/GLR trend test alongside CUSUM so slow ramps
    # fire before completion; carry_baseline keeps the (rescaled) baseline
    # across rebaseline() instead of re-learning from scratch.
    drift_factory: Optional[Callable] = None
    carry_baseline: bool = False
    # cheap per-iteration workload scalar (Eq. 1 sum over micro-batches, no
    # DAG sim): the drift test runs on observed / scalar so workload swings
    # between iterations do not drown a ramp's slope in residual noise
    workload_scalar_fn: Optional[Callable] = None
    # a drift alarm carries trend evidence a workload spike cannot produce,
    # so it validates at a tighter margin than the 25% rule — otherwise the
    # system's own mitigation (progress-aware migration hides most of a slow
    # ramp) keeps the observed time under the 25% gate until long after the
    # ramp completed
    drift_filter_threshold: float = 0.10
    # change points raised this soon after a heartbeat fail-stop report are
    # explained by the known failure (stall + replan transient): skip the
    # redundant validation pass. 0 = off (paper behaviour); the lifecycle
    # policy enables it — a carried baseline has no warm-up window to absorb
    # these transients the way a fresh one accidentally did
    suppress_failstop_s: float = 0.0
    # validation debounce: hold an alarm that passed the filter for this long
    # before paying the validation pass; if a heartbeat fail-stop report
    # arrives in the meantime the alarm was that failure's pre-detection
    # stall and is dropped. Covers the window where a dying device already
    # slows iterations but has not yet missed enough heartbeats. 0 = off.
    validation_debounce_s: float = 0.0
    stats: DetectorStats = field(default_factory=DetectorStats)
    reports: list = field(default_factory=list)

    def __post_init__(self):
        self._cpd = self.changepoint_factory()
        self._drift = self.drift_factory() if self.drift_factory else None
        self._series: list = []
        self._last_failstop_t = -math.inf
        self._pending_val: Optional[tuple] = None  # (iteration, armed_t, obs)

    # ------------------------------------------------------------ fail-stop
    def poll_failstop(self, now: float) -> Optional[FailureReport]:
        newly = self.heartbeat.sweep(now)
        if not newly:
            return None
        rep = FailureReport("fail-stop", tuple(newly), len(self._series), now,
                            detail="heartbeat loss")
        self.reports.append(rep)
        self.stats.detections += 1
        self._last_failstop_t = now
        return rep

    def note_failstop(self, now: float):
        """Record an out-of-band fail-stop detection (a validation pass that
        measured a device dead) so the ``suppress_failstop_s`` window and
        the pending-validation drop arm exactly as they do for
        heartbeat-detected deaths — without this, the stall/replan transient
        of a validation-detected death would charge a second validation and
        count a false alarm."""
        self._last_failstop_t = now

    # ------------------------------------------------------------ fail-slow
    def observe_iteration(self, iteration: int, observed_s: float, workload,
                          now: float = 0.0) -> Optional[FailureReport]:
        """Returns a FailureReport if a fail-slow failure is confirmed."""
        self._series.append(observed_s)
        fired = self._cpd.update(observed_s)
        drift_fired = False
        if self._drift is not None:
            x = observed_s
            if self.workload_scalar_fn is not None:
                x = observed_s / max(self.workload_scalar_fn(workload), 1e-12)
            drift_fired = self._drift.update(x)
        # resolve a debounced alarm AFTER recording this observation, so the
        # series/change-point state never run a point behind on a confirm
        if self._pending_val is not None:
            armed_it, armed_t, armed_obs = self._pending_val
            if self._last_failstop_t >= armed_t:
                # the alarm was the pre-detection stall of a fail-stop the
                # heartbeat hierarchy has since localized: drop it
                self.stats.suppressed_failstop += 1
                self._pending_val = None
            elif now - armed_t >= self.validation_debounce_s:
                self._pending_val = None
                rep = self._run_validation(armed_it, now, armed_obs)
                if rep is not None:
                    return rep
        if drift_fired:
            self.stats.drift_alarms += 1
            fired = True
        if not fired:
            return None
        self.stats.change_points += 1

        if (self.suppress_failstop_s > 0.0
                and now - self._last_failstop_t <= self.suppress_failstop_s):
            # lifecycle: the alarm is explained by a fail-stop the heartbeat
            # hierarchy already localized (stall + replan transient) — a
            # validation pass could only rediscover what is known
            self.stats.suppressed_failstop += 1
            self._discard_last_point(drop_drift=True)
            return None

        if self.workload_filter:
            self.stats.filter_overhead_s += self.filter_cost_s
            predicted = self.healthy_time_fn(workload)
            threshold = (min(self.filter_threshold, self.drift_filter_threshold)
                         if drift_fired else self.filter_threshold)
            if observed_s <= (1.0 + threshold) * predicted:
                # benign workload fluctuation: remove the point, skip
                # validation. The drift window keeps the point — a ramp's
                # early observations are individually benign (that is the
                # point of a ramp) and dropping them would blind the trend
                # test to exactly the failures it exists for.
                self.stats.filtered_benign += 1
                self._discard_last_point(drop_drift=False)
                return None

        if self.validation_debounce_s > 0.0:
            if self._pending_val is None:
                self._pending_val = (iteration, now, observed_s)
            return None

        # validation phase (expensive)
        return self._run_validation(iteration, now, observed_s,
                                    pop_on_false=True)

    def _run_validation(self, iteration: int, now: float, observed_s: float,
                        *, pop_on_false: bool = False
                        ) -> Optional[FailureReport]:
        self.stats.validations += 1
        self.stats.validation_overhead_s += self.validation_cost_s
        degraded = self.validate_fn(iteration)
        if not degraded:
            # a false alarm is removed from the series exactly like a benign
            # point — the change-point state must not keep the contaminated
            # observation either (it previously did: only the series was
            # popped, so spurious alarms perturbed later detection)
            self.stats.false_alarms += 1
            if pop_on_false:
                self._discard_last_point(drop_drift=True)
            else:
                # debounced path: the armed point is buried in the series, so
                # an exact rewind is impossible — but validation just
                # certified every device healthy, which means the accumulated
                # CUSUM/trend evidence is noise; clear it instead
                if hasattr(self._cpd, "clear_evidence"):
                    self._cpd.clear_evidence()
                if self._drift is not None:
                    self._drift.reset()
            return None
        self.stats.detections += 1
        rep = FailureReport("fail-slow", tuple(degraded), iteration, now,
                            detail=f"observed={observed_s:.3f}s")
        self.reports.append(rep)
        return rep

    # -------------------------------------------------------------- control
    def _discard_last_point(self, *, drop_drift: bool):
        """Remove the last observation from the series and the CUSUM state
        (benign/false-alarm points must not contaminate later detection —
        paper §5.2). ``drop_drift`` also removes it from the trend window:
        done for disproved (false-alarm) and fail-stop-explained points, but
        NOT for workload-benign ones, which a slow ramp is made of."""
        self._series.pop()
        if hasattr(self._cpd, "discard_last"):
            self._cpd.discard_last()
        if drop_drift and self._drift is not None:
            self._drift.discard_last()

    def rebaseline(self, scale: Optional[float] = None):
        """Reset the time-series model after a reconfiguration (the healthy
        iteration time changes when the parallel plan changes).

        With the lifecycle drift policy (``carry_baseline=True``) and a
        predicted healthy-time ratio ``scale`` (new plan / old plan), the
        frozen baseline and accumulated evidence are *carried* — rescaled by
        ``scale`` — instead of re-learned: a slow ramp can no longer hide
        inside the fresh warm-up window every reconfiguration used to open.
        """
        if (scale is not None and self.carry_baseline
                and hasattr(self._cpd, "carried")):
            self._cpd = self._cpd.carried(scale)
            if self._drift is not None:
                self._drift.rescale(scale)
            if getattr(self._cpd, "_frozen", False):
                self.stats.carried_rebaselines += 1
        else:
            self._cpd = self.changepoint_factory()
            if self._drift is not None:
                self._drift.reset()
        self._series = []

    @property
    def overhead_s(self) -> float:
        return self.stats.validation_overhead_s + self.stats.filter_overhead_s
