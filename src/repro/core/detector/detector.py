"""The ResiHP Detector (paper §5): fail-stop via hierarchical heartbeats,
fail-slow via change-point detection on the iteration-time series with a
workload-aware filter.

Fail-slow pipeline per iteration (paper §5.2):
  1. append observed iteration time to the series; run the change-point
     detector (Greyhound-style proxy signal);
  2. on a change point, *analytically* estimate the expected healthy
     iteration time for the current workload (Eq. 1 micro-batch predictor +
     Eq. 2 DAG critical path, both supplied as `healthy_time_fn`);
  3. if observed > (1 + filter_threshold) * predicted  -> run the expensive
     validation phase (`validate_fn`) to localize degraded devices;
     else -> benign workload fluctuation: drop the point from the series and
     skip validation (this is what kills Greyhound's false alarms).

`workload_filter=False` reproduces Greyhound's behaviour (every change point
pays validation) — the Table 5 baseline.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable, Optional

from repro.core.detector.changepoint import CusumDetector
from repro.core.detector.heartbeat import HeartbeatMonitor


@dataclass(frozen=True)
class FailureReport:
    kind: str  # 'fail-stop' | 'fail-slow'
    devices: tuple  # device ids; fail-slow entries are (device_id, speed)
    iteration: int
    time: float
    detail: str = ""


@dataclass
class DetectorStats:
    change_points: int = 0
    validations: int = 0
    false_alarms: int = 0
    filtered_benign: int = 0
    missed_filter: int = 0  # filter said benign but a real failure existed
    detections: int = 0
    validation_overhead_s: float = 0.0
    filter_overhead_s: float = 0.0

    def as_dict(self):
        return dict(self.__dict__)


@dataclass
class Detector:
    """Owns the fail-stop heartbeat hierarchy and the fail-slow series logic.

    healthy_time_fn(workload) -> predicted healthy iteration seconds.
    validate_fn(iteration) -> list[(device_id, measured_speed)] of degraded
        devices (empty if none). Its cost models Greyhound's validation pass.
    """

    healthy_time_fn: Callable
    validate_fn: Callable
    heartbeat: HeartbeatMonitor = field(default_factory=HeartbeatMonitor)
    workload_filter: bool = True
    filter_threshold: float = 0.25  # the 25% rule
    validation_cost_s: float = 3.0  # paper Table 5: seconds per validation
    filter_cost_s: float = 0.045  # paper Table 5: 34-49 ms per filtered alarm
    changepoint_factory: Callable = CusumDetector
    stats: DetectorStats = field(default_factory=DetectorStats)
    reports: list = field(default_factory=list)

    def __post_init__(self):
        self._cpd = self.changepoint_factory()
        self._series: list = []

    # ------------------------------------------------------------ fail-stop
    def poll_failstop(self, now: float) -> Optional[FailureReport]:
        newly = self.heartbeat.sweep(now)
        if not newly:
            return None
        rep = FailureReport("fail-stop", tuple(newly), len(self._series), now,
                            detail="heartbeat loss")
        self.reports.append(rep)
        self.stats.detections += 1
        return rep

    # ------------------------------------------------------------ fail-slow
    def observe_iteration(self, iteration: int, observed_s: float, workload,
                          now: float = 0.0) -> Optional[FailureReport]:
        """Returns a FailureReport if a fail-slow failure is confirmed."""
        self._series.append(observed_s)
        if not self._cpd.update(observed_s):
            return None
        self.stats.change_points += 1

        if self.workload_filter:
            self.stats.filter_overhead_s += self.filter_cost_s
            predicted = self.healthy_time_fn(workload)
            if observed_s <= (1.0 + self.filter_threshold) * predicted:
                # benign workload fluctuation: remove the point, skip validation
                self.stats.filtered_benign += 1
                self._series.pop()
                if hasattr(self._cpd, "discard_last"):
                    self._cpd.discard_last()
                return None

        # validation phase (expensive)
        self.stats.validations += 1
        self.stats.validation_overhead_s += self.validation_cost_s
        degraded = self.validate_fn(iteration)
        if not degraded:
            self.stats.false_alarms += 1
            self._series.pop()
            return None
        self.stats.detections += 1
        rep = FailureReport("fail-slow", tuple(degraded), iteration, now,
                            detail=f"observed={observed_s:.3f}s")
        self.reports.append(rep)
        return rep

    # -------------------------------------------------------------- control
    def rebaseline(self):
        """Reset the time-series model after a reconfiguration (the healthy
        iteration time changes when the parallel plan changes)."""
        self._cpd = self.changepoint_factory()
        self._series = []

    @property
    def overhead_s(self) -> float:
        return self.stats.validation_overhead_s + self.stats.filter_overhead_s
