"""Per-device failure-lifecycle tracking: flap quarantine, ramp-aware drift
policy and rejoin admission (the post-§5/§6 gap the PR-1 scenario families
exposed).

ResiHP's Detector/Scheduler loop treats failures as one-shot: every flap of
the same device is re-detected, re-validated and re-planned from scratch; a
device that rejoins is believed healthy (speed 1.0) regardless of its actual
state; and a slowly-ramping straggler hides inside the fresh CUSUM warm-up
window that every reconfiguration opens. Production fleets (ByteDance's
failure-lifecycle reports; ElasWave's re-admission probing) show the fix is
per-device failure *history*. This module provides it:

* **Flap quarantine** — a device whose fail-stop count inside
  ``flap_window_s`` reaches ``flap_threshold`` is quarantined on rejoin with
  exponential backoff (``backoff_base_s * backoff_factor**level``, capped).
  While quarantined the device stays out of the Scheduler's plans (no
  replanning, no reconfiguration charge, no detector rebaseline) and the
  Detector never pays validation for its flaps.
* **Rejoin admission** — an ElasWave-style micro-benchmark probe runs when a
  device rejoins (and when a quarantine expires): the system's belief enters
  at the *measured* speed, not 1.0. A probe that still measures (near-)zero
  extends the quarantine instead of readmitting.
* **Ramp-aware drift** — the config gates the Detector's slope-drift test and
  baseline carry across ``rebaseline()`` (see
  :class:`~repro.core.detector.changepoint.SlopeDriftDetector` and
  ``CusumDetector.carried``); the lifecycle manager only carries the flag,
  the Detector owns the mechanics.
* **Hazard-keyed quarantine** (PR 4) — when the manager is handed a hazard
  estimator (duck-typed: ``risk``/``should_quarantine``/``backoff_s`` over
  a device's :class:`FailureHistory`; see
  :class:`repro.cluster.hazard.HazardEstimator` — injected by the caller so
  this module stays import-clean of the cluster layer), quarantine *entry*
  keys on the estimated per-device failure rate instead of the raw fail-stop
  flap counter — so a part that keeps coming back degraded (fail-slow
  repeats, which the flap counter never sees) is quarantined too — and the
  backoff *duration* scales with how far above the quarantine threshold the
  estimate sits. ``risk_scores()`` exposes the same estimates to the
  Scheduler for risk-aware placement.
* **Validation as a fail-stop path** — ``cfg.validation_failstop`` lets a
  validation pass report dead devices (speed 0) directly: a device that died
  just before a validation micro-benchmark ran no longer waits for the
  heartbeat timeout (and its NCCL-stall charge) to enter system beliefs.
  The simulator owns the mechanics (see ``TrainingSim._validate``).

Lifecycle states per device::

    healthy -> suspect -> quarantined -> probing -> readmitted
       ^         |             |            |          |
       |         +---- rejoin (admitted) ---+----------+
       +------------------- probe measures healthy ----+

``suspect`` marks a device with failure history that is currently believed
degraded or down; ``readmitted`` marks one that returned through a probe.

Everything here is pure policy + bookkeeping (no jax, no simulator imports):
the cluster simulator supplies ``probe_fn`` (the micro-benchmark) and charges
``probe_cost_s`` to simulated time; the default-off switch is
``ResiHPPolicy(lifecycle=...)``.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable, Optional

HEALTHY = "healthy"
SUSPECT = "suspect"
QUARANTINED = "quarantined"
PROBING = "probing"
READMITTED = "readmitted"

STATES = (HEALTHY, SUSPECT, QUARANTINED, PROBING, READMITTED)


@dataclass
class LifecycleConfig:
    """Tunables for the failure-lifecycle policies. Each policy has its own
    gate so ablations can enable them independently."""

    quarantine: bool = True  # flap quarantine with exponential backoff
    drift: bool = True  # slope-drift test + baseline carry across rebaseline
    admission: bool = True  # micro-benchmark probe on rejoin
    # detector-side redundant-validation skipping: change points raised this
    # soon after a heartbeat fail-stop report are explained by the known
    # failure, not worth a validation pass (a carried baseline has no fresh
    # warm-up window to absorb the stall/replan transient)
    failstop_suppress_s: float = 10.0
    # hold a filter-passing alarm this long before paying validation; dropped
    # if a heartbeat fail-stop report lands first (the alarm was the dying
    # device's pre-detection stall). Sized to the heartbeat detection window
    # (interval * miss_threshold) plus margin.
    validation_debounce_s: float = 4.0
    # validation margin for drift alarms (trend evidence justifies a gate
    # tighter than the 25% rule — migration hides most of a ramp's level)
    drift_filter_threshold: float = 0.10
    flap_window_s: float = 200.0  # fail-stops inside this window count as flaps
    flap_threshold: int = 2  # this many recent fail-stops => quarantine
    backoff_base_s: float = 40.0
    backoff_factor: float = 2.0
    backoff_max_s: float = 1200.0
    probe_cost_s: float = 0.5  # micro-benchmark wall time per probe
    readmit_speed_floor: float = 0.05  # probe below this => still failed
    # validation doubles as a fail-stop path: a validation pass that finds a
    # dead device reports it immediately instead of leaving it to time out
    # its heartbeats (and pay the NCCL-stall charge) — ROADMAP open item
    validation_failstop: bool = True


@dataclass
class FailureHistory:
    """Persistent per-device record threaded through detection/scheduling."""

    device: int
    state: str = HEALTHY
    fail_stops: list = field(default_factory=list)  # detection times
    fail_slows: list = field(default_factory=list)  # (time, measured speed)
    rejoins: list = field(default_factory=list)  # admitted-rejoin times
    quarantine_until: float = 0.0
    quarantine_level: int = 0  # backoff exponent (resets on clean readmit)
    last_probe_speed: float = 1.0

    def recent_failstops(self, now: float, window: float) -> int:
        return sum(1 for t in self.fail_stops if now - t <= window)


@dataclass(frozen=True)
class RejoinDecision:
    """Outcome of ``on_rejoin`` / a quarantine-release probe."""

    device: int
    admit: bool
    speed: float = 1.0  # belief speed to enter on admit
    probe_cost_s: float = 0.0  # charged to simulated time by the caller
    state: str = READMITTED
    until: float = 0.0  # quarantine expiry when not admitted


@dataclass
class LifecycleStats:
    quarantines: int = 0
    rejoins_deferred: int = 0  # rejoin events absorbed by an active quarantine
    probes: int = 0
    readmissions: int = 0
    degraded_admissions: int = 0  # probe measured < 1.0 on an admitted rejoin

    def as_dict(self):
        return dict(self.__dict__)


@dataclass
class LifecycleManager:
    """Owns every device's :class:`FailureHistory` and decides quarantine /
    admission. ``probe_fn(device) -> measured speed`` is the micro-benchmark
    (ground-truth lookup in the simulator, mirroring Greyhound's validation
    pass); its cost is returned in each decision for the caller to charge."""

    cfg: LifecycleConfig = field(default_factory=LifecycleConfig)
    probe_fn: Optional[Callable] = None
    # duck-typed hazard estimator (repro.cluster.hazard.HazardEstimator or
    # anything with risk/should_quarantine/backoff_s and a cfg carrying
    # ``quarantine``/``planning`` gates). None => flap-counter policy.
    hazard: Optional[object] = None
    # unified credit model (repro.core.detector.credit.CreditModel, attached
    # by the simulator when the ``credit`` switch is on). When present, the
    # decision chain rekeys on credit bands: quarantine entry is
    # ``credit < quarantine_band`` (strict — band 0 never quarantines),
    # backoff scales with the shortfall below the band, and admission is
    # banded (``credit >= probe_band`` admits directly with no probe at
    # all). None => the hazard / flap-counter chain, byte-identical.
    credit: Optional[object] = None
    histories: dict = field(default_factory=dict)  # device -> FailureHistory
    stats: LifecycleStats = field(default_factory=LifecycleStats)

    def history(self, device: int) -> FailureHistory:
        h = self.histories.get(device)
        if h is None:
            h = self.histories[device] = FailureHistory(device)
        return h

    # ------------------------------------------------------------ recording
    def record_failstop(self, device: int, now: float):
        h = self.history(device)
        h.fail_stops.append(now)
        if h.state != QUARANTINED:
            h.state = SUSPECT

    def record_failslow(self, device: int, speed: float, now: float):
        h = self.history(device)
        h.fail_slows.append((now, float(speed)))
        if h.state != QUARANTINED:
            h.state = SUSPECT

    # ------------------------------------------------------------- rejoins
    def _probe(self, h: FailureHistory) -> float:
        self.stats.probes += 1
        h.last_probe_speed = float(self.probe_fn(h.device)) if self.probe_fn else 1.0
        return h.last_probe_speed

    def _hazard_quarantine(self) -> bool:
        return self.hazard is not None and self.hazard.cfg.quarantine

    def _credit_quarantine(self) -> bool:
        return self.credit is not None and self.credit.cfg.quarantine

    def _should_quarantine(self, h: FailureHistory, now: float) -> bool:
        if self._credit_quarantine():
            # band-keyed entry on the unified scalar: strictly below the
            # quarantine band (band 0.0 therefore never quarantines)
            c = self.credit.credit_of(h, now, self.histories)
            return c < self.credit.cfg.quarantine_band
        if self._hazard_quarantine():
            # hazard-keyed entry: the estimated per-device rate (fail-slows
            # included) crossed the quarantine threshold — not "N fail-stops
            # in a window"
            return self.hazard.should_quarantine(h, now)
        return (h.recent_failstops(now, self.cfg.flap_window_s)
                >= self.cfg.flap_threshold)

    def _enter_quarantine(self, h: FailureHistory, now: float) -> RejoinDecision:
        h.quarantine_level += 1
        if self._credit_quarantine():
            # backoff scales with the shortfall below the quarantine band:
            # a device just under the band sits out ~base_s, a zero-credit
            # part sits out up to (1 + scale*band) times longer per level
            ccfg = self.credit.cfg
            c = self.credit.credit_of(h, now, self.histories)
            shortfall = max(ccfg.quarantine_band - c, 0.0)
            dur = min(
                self.cfg.backoff_base_s
                * (1.0 + ccfg.backoff_scale * shortfall)
                * self.cfg.backoff_factor ** (h.quarantine_level - 1),
                self.cfg.backoff_max_s,
            )
            self.credit.stats.quarantines += 1
        elif self._hazard_quarantine():
            dur = self.hazard.backoff_s(
                h, now, base_s=self.cfg.backoff_base_s,
                max_s=self.cfg.backoff_max_s, level=h.quarantine_level,
                factor=self.cfg.backoff_factor)
        else:
            dur = min(
                self.cfg.backoff_base_s
                * self.cfg.backoff_factor ** (h.quarantine_level - 1),
                self.cfg.backoff_max_s,
            )
        h.quarantine_until = now + dur
        h.state = QUARANTINED
        self.stats.quarantines += 1
        return RejoinDecision(h.device, admit=False, speed=0.0,
                              state=QUARANTINED, until=h.quarantine_until)

    def _admit(self, h: FailureHistory, now: float) -> RejoinDecision:
        cost = 0.0
        if (self.credit is not None and self.credit.cfg.admission
                and self.credit.credit_of(h, now, self.histories)
                >= self.credit.cfg.probe_band):
            # banded direct admission: a device whose whole evidence record
            # sums to near-full credit skips the micro-benchmark entirely —
            # belief enters at 1.0 and no probe time exists to charge
            self.credit.stats.direct_admits += 1
            h.state = READMITTED if h.fail_stops or h.fail_slows else HEALTHY
            h.rejoins.append(now)
            h.quarantine_level = 0
            self.stats.readmissions += 1
            return RejoinDecision(h.device, admit=True, speed=1.0,
                                  probe_cost_s=0.0, state=h.state)
        if self.cfg.admission and self.probe_fn is not None:
            h.state = PROBING
            speed = self._probe(h)
            cost = self.cfg.probe_cost_s
            if speed <= self.cfg.readmit_speed_floor:
                # came back dead (or flapped down again before the probe ran)
                if self.cfg.quarantine:
                    dec = self._enter_quarantine(h, now)
                    return RejoinDecision(h.device, admit=False, speed=0.0,
                                          probe_cost_s=cost, state=QUARANTINED,
                                          until=dec.until)
                return RejoinDecision(h.device, admit=False, speed=0.0,
                                      probe_cost_s=cost, state=SUSPECT)
            if speed < 1.0:
                self.stats.degraded_admissions += 1
        else:
            speed = 1.0  # legacy belief: every rejoin is full-health
        h.state = READMITTED if h.fail_stops or h.fail_slows else HEALTHY
        h.rejoins.append(now)
        h.quarantine_level = 0 if speed >= 1.0 else h.quarantine_level
        self.stats.readmissions += 1
        return RejoinDecision(h.device, admit=True, speed=speed,
                              probe_cost_s=cost, state=h.state)

    def on_rejoin(self, device: int, now: float) -> RejoinDecision:
        """A repaired device announced itself. Decide quarantine vs (probed)
        admission. The caller applies the belief/heartbeat effects and
        charges ``probe_cost_s``."""
        h = self.history(device)
        if h.state == QUARANTINED and now < h.quarantine_until:
            # the flapper bounced back while still serving its quarantine
            self.stats.rejoins_deferred += 1
            return RejoinDecision(device, admit=False, speed=0.0,
                                  state=QUARANTINED, until=h.quarantine_until)
        if self.cfg.quarantine and self._should_quarantine(h, now):
            return self._enter_quarantine(h, now)
        return self._admit(h, now)

    # ---------------------------------------------------------- quarantine
    def is_quarantined(self, device: int, now: float) -> bool:
        h = self.histories.get(device)
        return (h is not None and h.state == QUARANTINED
                and now < h.quarantine_until)

    def quarantined(self, now: float) -> frozenset:
        """Devices the Scheduler must keep out of plans right now."""
        return frozenset(
            d for d, h in self.histories.items()
            if h.state == QUARANTINED and now < h.quarantine_until
        )

    def poll_releases(self, now: float) -> list:
        """Expired quarantines: probe each and either readmit (decision with
        ``admit=True`` and the measured speed) or extend the backoff (the
        device is still down — decision with ``admit=False``). The caller
        charges every decision's ``probe_cost_s``."""
        out = []
        for h in self.histories.values():
            if h.state != QUARANTINED or now < h.quarantine_until:
                continue
            speed = self._probe(h)
            cost = self.cfg.probe_cost_s
            if speed <= self.cfg.readmit_speed_floor:
                dec = self._enter_quarantine(h, now)
                out.append(RejoinDecision(h.device, admit=False, speed=0.0,
                                          probe_cost_s=cost, state=QUARANTINED,
                                          until=dec.until))
                continue
            h.state = READMITTED
            h.rejoins.append(now)
            self.stats.readmissions += 1
            if speed >= 1.0:
                h.quarantine_level = 0  # clean full-speed readmit: backoff resets
            else:
                self.stats.degraded_admissions += 1
            # the release probe always runs (quarantine must know the device
            # is back at all); only with admission on does the measured speed
            # seed the belief — otherwise the legacy full-health assumption
            admit_speed = speed if self.cfg.admission else 1.0
            out.append(RejoinDecision(h.device, admit=True, speed=admit_speed,
                                      probe_cost_s=cost, state=READMITTED))
        return out

    # --------------------------------------------------------------- intro
    def states(self) -> dict:
        return {d: h.state for d, h in self.histories.items()}

    def risk_scores(self, now: float) -> dict:
        """Per-device risk view for the Scheduler (``device_risk``): the
        hazard estimator's rate-over-prior ratio for every device with
        failure history (1.0 = fleet baseline; unknown devices are implied
        baseline). Empty when no estimator is attached or planning is off."""
        if self.hazard is None or not self.hazard.cfg.planning:
            return {}
        return {d: self.hazard.risk(h, now)
                for d, h in self.histories.items()}
