"""Unified per-device credit score: one learned health scalar behind
quarantine, admission and placement (ROADMAP open item 3).

The policy stack grew four parallel, independently hand-thresholded opinions
about each device — the flap counter (:class:`LifecycleManager`), the slope
drift test (with its hand-tuned 10% ``drift_filter_threshold``), the
Gamma-posterior hazard estimate (:class:`HazardEstimator`) and the rejoin
probe — so a device can be simultaneously "suspect" to one signal and
"healthy" to the others, and every new scenario family means re-tuning four
knobs. This module collapses them into a single scalar per device::

    credit = clamp(1 - alpha * risk_excess
                     - beta  * flap_pressure
                     - gamma * drift_excess
                     - delta * domain_elevation,  0, 1)

where every signal is derived from the *existing* evidence stores (the
lifecycle's :class:`FailureHistory` records and the hazard estimator's
windowed risk score — no new bookkeeping):

* ``risk_excess`` — the hazard estimator's exposure-free risk score minus
  its 1.0 baseline (``n_recent / prior_failures``): recent failures of any
  kind, decaying as the window slides past them;
* ``flap_pressure`` — recent fail-stops over the flap threshold (the raw
  flap counter, normalized so pressure 1.0 is the legacy quarantine trip);
* ``drift_excess`` — the worst in-window detected fail-slow shortfall
  (``1 - measured speed``): a device currently running below peak;
* ``domain_elevation`` — in-window failures pooled over the device's
  failure-domain *siblings*: correlated evidence that the neighborhood, not
  the part, is the problem.

All four weights are non-negative, so credit is monotone: any signal
worsening can only lower it. The weights plus the decision band edges are
**fit offline** against sweep outcomes by ``tools/fit_credit.py`` and
checked into ``src/repro/configs/credit_fitted.json`` —
:func:`fitted_credit_config` loads them (falling back to the in-code
defaults when the artifact is absent).

Band semantics (the whole decision surface keys on one scalar):

* ``credit <  quarantine_band``  — quarantine on rejoin, backoff scaled by
  the shortfall below the band (``quarantine_band=0`` never quarantines);
* ``credit <  probe_band``      — admit through the rejoin micro-benchmark;
  under the credit switch the probe runs *asynchronously* (ElasWave-style:
  the probe occupies the still-idle rejoining device, not the training
  job), so the measured speed enters beliefs one probe-latency later and no
  global time is charged;
* ``credit >= probe_band``      — direct admit at full belief, no probe;
* ``credit <  ntp_band``        — the device is vetoed from NTP shrink-shard
  retention (excluded instead): nonuniform widths are for *trustworthy*
  stragglers (thermal capping), not for parts whose history says the
  slowness is a symptom;
* placement — ``Scheduler.adapt(device_credit=...)`` breaks equal-throughput
  ties toward high-credit devices (superseding the raw ``device_risk``
  view), and the restart-vs-adapt decision discounts the live-adaptation
  threshold by the plan's mean credit (a low-credit fleet is likely to be
  interrupted again before a checkpoint restore pays off).

The model is maintained incrementally and array-backed (``.arr`` beside
``BeliefArray``, bumped ``version``) so the fast engine can read the whole
fleet's credit in one gather without per-device Python loops. Default-off:
``ResiHPPolicy(credit=True | CreditConfig(...))``; off is byte-identical to
every pre-credit path.
"""
from __future__ import annotations

import json
from dataclasses import dataclass
from pathlib import Path
from typing import Optional

import numpy as np

__all__ = ["CreditConfig", "CreditModel", "CreditStats",
           "fitted_credit_config", "FITTED_CONFIG_PATH"]

FITTED_CONFIG_PATH = (Path(__file__).resolve().parents[2]
                      / "configs" / "credit_fitted.json")

# the fields tools/fit_credit.py searches over (and the only keys
# credit_fitted.json may carry) — everything else is fixed structure
FIT_FIELDS = ("alpha", "beta", "gamma", "delta", "quarantine_band",
              "probe_band", "ntp_band", "drift_filter_threshold",
              "validation_debounce_s", "window_s")


@dataclass(frozen=True)
class CreditConfig:
    """Credit-model weights, decision bands and signal windows.

    The first block is the fit surface (``tools/fit_credit.py``); defaults
    are the checked-in fitted values' fallback, used when
    ``credit_fitted.json`` is absent. The second block is fixed structure:
    signal windows deliberately shared with the estimators that own the
    evidence (hazard window for risk, flap window for flaps) so one scalar
    summarizes the same facts the legacy thresholds saw.
    """

    # ---- fitted surface --------------------------------------------------
    alpha: float = 0.05   # weight per risk_excess unit (1 unit = 1/prior ev.)
    beta: float = 0.25    # weight per flap_pressure unit (1.0 = legacy trip)
    gamma: float = 0.30   # weight per drift_excess unit (1.0 = dead slow)
    delta: float = 0.05   # weight per domain_elevation unit
    quarantine_band: float = 0.05  # credit strictly below => quarantine
    probe_band: float = 0.85       # credit at/above => direct admit, no probe
    # credit strictly below => vetoed from NTP shrink-shard retention
    # (0.0 disables the veto — every straggler stays shrink-eligible)
    ntp_band: float = 0.75
    # the drift test's validation margin, retired as a hand-tuned constant:
    # under the credit switch this fitted value replaces the lifecycle's
    # literal 0.10 (which remains the credit-off default). 1.0 is a fit
    # outcome with teeth: no shortfall can clear a 100% margin, so the
    # simulator skips installing the drift stack entirely and slowness
    # reaches the planner only through the gamma term
    drift_filter_threshold: float = 0.10
    # the validation debounce, the other hand-tuned lifecycle constant the
    # fit retires: armed slowness validations wait this long before firing.
    # The surface is sharp — storm families want sub-second reaction while
    # ramp families want the full legacy hold — so it is fit, not tuned
    # (4.0 stays the credit-off default via LifecycleConfig)
    validation_debounce_s: float = 4.0
    # risk/domain evidence recency (no-hazard fallback; with an estimator
    # attached its own window governs risk). Fit, not fixed: the window is
    # the veto's memory — how long a domain burst keeps its survivors
    # veto-listed. Too long and a staggered storm's veto outlives the storm
    # (retention denied after devices recovered); too short and a mass
    # simultaneous burst clears before the pivotal shrink decision
    window_s: float = 60.0
    # ---- fixed structure -------------------------------------------------
    flap_window_s: float = 200.0   # matches LifecycleConfig.flap_window_s
    flap_threshold: int = 2        # matches LifecycleConfig.flap_threshold
    drift_window_s: float = 90.0   # fail-slow evidence recency
    prior_failures: float = 0.5    # risk normalization (matches hazard prior)
    domain: str = "pdu"            # sibling pooling for domain_elevation
    backoff_scale: float = 4.0     # backoff multiplier per unit band shortfall
    # probation re-checks: a device admitted at a measured speed below full
    # keeps being re-probed (free, async — same justification as admission)
    # every this-many seconds until belief matches truth. Without it a
    # transiently-throttled rejoiner is benched on a stale measurement
    # forever: nothing ever re-measures a device the planner stopped using
    # (0 disables probation)
    probation_recheck_s: float = 20.0
    # ---- gates -----------------------------------------------------------
    planning: bool = True          # feed credit to Scheduler.adapt placement
    quarantine: bool = True        # band-keyed quarantine entry/backoff
    admission: bool = True         # band-keyed probe/direct admission
    restart_weighting: bool = True  # group credit discounts restart threshold

    def __post_init__(self):
        for name in ("alpha", "beta", "gamma", "delta"):
            if getattr(self, name) < 0.0:
                raise ValueError(f"CreditConfig.{name} must be >= 0 "
                                 "(credit must stay monotone)")
        if not (0.0 <= self.quarantine_band <= self.probe_band <= 1.0):
            raise ValueError("need 0 <= quarantine_band <= probe_band <= 1")
        if not (0.0 <= self.ntp_band <= 1.0):
            raise ValueError("ntp_band must be in [0, 1]")
        if not (0.0 < self.drift_filter_threshold <= 1.0):
            raise ValueError("drift_filter_threshold must be in (0, 1]")
        if self.validation_debounce_s < 0.0:
            raise ValueError("validation_debounce_s must be >= 0")
        if (self.flap_threshold < 1 or self.flap_window_s <= 0
                or self.drift_window_s <= 0 or self.window_s <= 0
                or self.prior_failures <= 0):
            raise ValueError("credit signal windows/priors must be positive")
        if self.domain not in ("pdu", "switch", "node", "rack"):
            raise ValueError(f"unknown domain kind {self.domain!r}")
        if self.backoff_scale < 0:
            raise ValueError("backoff_scale must be >= 0")
        if self.probation_recheck_s < 0.0:
            raise ValueError("probation_recheck_s must be >= 0")


@dataclass
class CreditStats:
    """Credit-path counters, kept *separate* from :class:`LifecycleStats`
    (whose ``as_dict`` feeds every pre-credit sweep cell's JSON — growing it
    would break old-cell byte identity). Surfaced only on credit rows."""

    direct_admits: int = 0      # credit >= probe_band: no probe at all
    async_admissions: int = 0   # probed off the critical path
    quarantines: int = 0        # band-keyed quarantine entries
    ntp_vetoes: int = 0         # low-credit devices the planner barred from
    # shrink-shard retention (Scheduler bumps this on uncached plans)
    probation_corrections: int = 0  # re-probes that moved a stale belief
    # (the device recovered — or degraded further — since admission)

    def as_dict(self):
        return dict(self.__dict__)


class CreditModel:
    """Per-device credit over the lifecycle's :class:`FailureHistory`
    records. Pure bookkeeping — no simulator imports; the caller supplies
    ``now`` and the histories dict it already owns.

    ``arr`` is the dense per-device mirror (1.0 = full credit) and
    ``version`` bumps whenever any score changes — the same array-backed
    contract :class:`BeliefArray` gives the fast engine, so vectorized
    consumers can gate on the version instead of re-reading the dict."""

    def __init__(self, cfg: CreditConfig, n_devices: int, *,
                 hazard: Optional[object] = None,
                 domain_members: Optional[dict] = None):
        self.cfg = cfg
        self.hazard = hazard  # duck-typed HazardEstimator (risk()) or None
        self.n_devices = int(n_devices)
        self.arr = np.ones(self.n_devices, dtype=np.float64)
        self.version = 0
        self.stats = CreditStats()
        self._last: dict = {}
        # device -> tuple of same-domain sibling ids (self excluded)
        self._siblings: dict = {}
        if domain_members:
            for members in domain_members.values():
                for d in members:
                    self._siblings[d] = tuple(m for m in members if m != d)

    # ------------------------------------------------------------- signals
    def _risk_excess(self, h, now: float) -> float:
        if self.hazard is not None:
            return max(self.hazard.risk(h, now) - 1.0, 0.0)
        t0 = now - self.cfg.window_s
        n = (sum(1 for t in h.fail_stops if t >= t0)
             + sum(1 for t, _ in h.fail_slows if t >= t0))
        return n / self.cfg.prior_failures

    def _flap_pressure(self, h, now: float) -> float:
        return (h.recent_failstops(now, self.cfg.flap_window_s)
                / self.cfg.flap_threshold)

    def _drift_excess(self, h, now: float) -> float:
        t0 = now - self.cfg.drift_window_s
        worst = 0.0
        for t, speed in h.fail_slows:
            if t >= t0:
                worst = max(worst, 1.0 - speed)
        return max(worst, 0.0)

    def _domain_elevation(self, device: int, now: float, histories) -> float:
        sibs = self._siblings.get(device)
        if not sibs or histories is None:
            return 0.0
        t0 = now - self.cfg.window_s
        n = 0
        for s in sibs:
            h = histories.get(s)
            if h is None:
                continue
            # fail-STOPS only: elevation models correlated failure bursts
            # (a PDU trip takes out neighbours); pooling slow events here
            # would double-count slowness the gamma term already carries and
            # poison the NTP veto for merely-throttled fleets
            n += sum(1 for t in h.fail_stops if t >= t0)
        return n / self.cfg.prior_failures

    # -------------------------------------------------------------- scores
    def credit_of(self, h, now: float, histories=None) -> float:
        """Credit scalar for one device's history (1.0 = full trust)."""
        cfg = self.cfg
        c = (1.0
             - cfg.alpha * self._risk_excess(h, now)
             - cfg.beta * self._flap_pressure(h, now)
             - cfg.gamma * self._drift_excess(h, now)
             - cfg.delta * self._domain_elevation(h.device, now, histories))
        return min(max(c, 0.0), 1.0)

    def scores(self, histories: dict, now: float) -> dict:
        """Non-unity credit scores for every device with failure history
        (unknown devices are implied full credit — same sparse convention as
        ``risk_scores``), refreshing the dense mirror and bumping
        ``version`` when anything moved."""
        out = {}
        for d, h in histories.items():
            c = self.credit_of(h, now, histories)
            if c != 1.0:
                out[d] = c
        if out != self._last:
            self.arr[:] = 1.0
            for d, c in out.items():
                self.arr[d] = c
            self._last = dict(out)
            self.version += 1
        return out


def fitted_credit_config(path: Optional[Path] = None) -> CreditConfig:
    """The fitted weights (``credit_fitted.json``'s ``fitted`` block) as a
    :class:`CreditConfig`; in-code defaults when the artifact is missing.
    Unknown keys are rejected — the artifact may only carry the fit
    surface, never silently rewire structure."""
    p = Path(path) if path is not None else FITTED_CONFIG_PATH
    if not p.exists():
        return CreditConfig()
    payload = json.loads(p.read_text())
    params = payload.get("fitted", {})
    bad = set(params) - set(FIT_FIELDS)
    if bad:
        raise ValueError(f"credit_fitted.json carries non-fit keys: {sorted(bad)}")
    return CreditConfig(**params)
