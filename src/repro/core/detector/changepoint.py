"""Online change-point detection over the iteration-time series (paper §5.2).

Two detectors, same interface (`update(x) -> bool`):

* `BOCPD` — Bayesian online change-point detection (Adams–MacKay style, the
  paper cites Agudelo-España et al. [1]): Normal-Inverse-Gamma conjugate
  model, Student-t predictive, constant hazard. A change point is flagged
  when the posterior mass of "run length < lag" exceeds a threshold.
* `CusumDetector` — one-sided CUSUM on standardized residuals; cheaper and
  what the large-scale simulator uses per DP group.

Both are pure-python/numpy and O(window) per update, satisfying the paper's
"lightweight enough for online per-iteration detection" requirement.
"""
from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np


@dataclass
class BOCPD:
    hazard: float = 1.0 / 100.0  # P(change at any step)
    max_run: int = 256  # truncate run-length distribution
    lag: int = 3  # declare change when P(run < lag) is high
    threshold: float = 0.5
    # NIG prior (weak): mu0, kappa0, alpha0, beta0
    mu0: float = 0.0
    kappa0: float = 0.1
    alpha0: float = 1.0
    beta0: float = 1.0
    warmup: int = 8

    def __post_init__(self):
        self._warm: list = []
        self._calibrated = False
        self._reset_state()

    def _reset_state(self):
        self._r = np.array([1.0])  # run-length posterior
        self._mu = np.array([self.mu0])
        self._kappa = np.array([self.kappa0])
        self._alpha = np.array([self.alpha0])
        self._beta = np.array([self.beta0])
        self._n = 0

    def _calibrate(self):
        """Scale the NIG prior to the warm-up window: with a fixed beta0 the
        prior variance swamps low-noise series and big shifts look small."""
        arr = np.asarray(self._warm, dtype=np.float64)
        mean = float(arr.mean())
        var = float(max(arr.var(ddof=1), (0.01 * abs(mean)) ** 2, 1e-12))
        self.mu0 = mean
        self.kappa0 = 1.0
        self.alpha0 = 2.0
        self.beta0 = var * self.alpha0  # E[sigma^2] ~= warm-up variance
        self._calibrated = True
        self._reset_state()
        for x in self._warm:  # replay warm-up under the calibrated prior
            self._step(float(x))

    @staticmethod
    def _gammaln(x):
        """Stirling-series log-gamma (avoids a scipy dependency)."""
        x = np.asarray(x, dtype=np.float64)
        # reflection-free: x here is always > 0.5
        coefs = [
            76.18009172947146, -86.50532032941677, 24.01409824083091,
            -1.231739572450155, 0.1208650973866179e-2, -0.5395239384953e-5,
        ]
        y = x
        tmp = x + 5.5
        tmp -= (x + 0.5) * np.log(tmp)
        ser = np.full_like(x, 1.000000000190015)
        for c in coefs:
            y = y + 1.0
            ser = ser + c / y
        return -tmp + np.log(2.5066282746310005 * ser / x)

    def _student_t_logpdf(self, x):
        df = 2.0 * self._alpha
        scale2 = self._beta * (self._kappa + 1.0) / (self._alpha * self._kappa)
        g = self._gammaln
        return (
            g((df + 1.0) / 2.0)
            - g(df / 2.0)
            - 0.5 * np.log(np.pi * df * scale2)
            - (df + 1.0) / 2.0 * np.log1p((x - self._mu) ** 2 / (df * scale2))
        )

    def update(self, x: float) -> bool:
        """Ingest one observation; True iff a change point is detected."""
        if not self._calibrated:
            self._warm.append(float(x))
            if len(self._warm) >= self.warmup:
                self._calibrate()
            return False
        self._step(float(x))
        return float(self._r[: self.lag].sum()) > self.threshold

    def _step(self, x: float):
        self._n += 1
        logpred = self._student_t_logpdf(float(x))
        pred = np.exp(np.clip(logpred, -700, 50))
        growth = self._r * pred * (1.0 - self.hazard)
        cp = float(np.sum(self._r * pred * self.hazard))
        new_r = np.concatenate([[cp], growth])
        new_r /= max(new_r.sum(), 1e-300)

        # posterior updates per hypothesis (prepend the prior for run=0)
        kappa1 = self._kappa + 1.0
        mu1 = (self._kappa * self._mu + x) / kappa1
        alpha1 = self._alpha + 0.5
        beta1 = self._beta + 0.5 * self._kappa * (x - self._mu) ** 2 / kappa1
        self._mu = np.concatenate([[self.mu0], mu1])
        self._kappa = np.concatenate([[self.kappa0], kappa1])
        self._alpha = np.concatenate([[self.alpha0], alpha1])
        self._beta = np.concatenate([[self.beta0], beta1])
        self._r = new_r
        if len(self._r) > self.max_run:
            self._r = self._r[: self.max_run]
            self._r /= self._r.sum()
            self._mu = self._mu[: self.max_run]
            self._kappa = self._kappa[: self.max_run]
            self._alpha = self._alpha[: self.max_run]
            self._beta = self._beta[: self.max_run]

    def reset(self):
        self._warm = []
        self._calibrated = False
        self._reset_state()


@dataclass
class CusumDetector:
    """One-sided CUSUM on standardized deviations from a running baseline.

    Detects sustained *increases* in iteration time (fail-slow direction).
    The baseline (mean/std) freezes once warm so the post-change points do
    not contaminate it.
    """

    k: float = 0.5  # slack, in std units
    h: float = 5.0  # decision threshold, in std units
    warmup: int = 12
    _hist: list = field(default_factory=list)
    _s: float = 0.0
    _mean: float = 0.0
    _std: float = 1.0
    _frozen: bool = False

    def update(self, x: float) -> bool:
        if not self._frozen:
            self._hist.append(float(x))
            if len(self._hist) >= self.warmup:
                arr = np.asarray(self._hist, dtype=np.float64)
                self._mean = float(arr.mean())
                self._std = float(max(arr.std(ddof=1), 1e-9, 0.01 * abs(self._mean)))
                self._frozen = True
            return False
        z = (float(x) - self._mean) / self._std
        self._s = max(0.0, self._s + z - self.k)
        if self._s > self.h:
            self._s = 0.0
            return True
        return False

    def discard_last(self):
        """Remove the last point's contribution (paper: benign change points
        are removed from the series so they don't perturb later detection)."""
        # CUSUM state was already advanced; rewinding one step is enough
        # because benign points are filtered before they can accumulate.
        self._s = max(0.0, self._s)

    def rebaseline(self):
        """Re-learn the healthy baseline (after a reconfiguration)."""
        self._hist = []
        self._s = 0.0
        self._frozen = False
