"""Online change-point detection over the iteration-time series (paper §5.2).

Three detectors, same core interface (`update(x) -> bool`):

* `BOCPD` — Bayesian online change-point detection (Adams–MacKay style, the
  paper cites Agudelo-España et al. [1]): Normal-Inverse-Gamma conjugate
  model, Student-t predictive, constant hazard. A change point is flagged
  when the posterior mass of "run length < lag" exceeds a threshold.
* `CusumDetector` — one-sided CUSUM on standardized residuals; cheaper and
  what the large-scale simulator uses per DP group.
* `SlopeDriftDetector` — windowed least-squares slope test for *creeping*
  degradations (slow ramps): CUSUM needs the cumulative level shift to cross
  its threshold inside one baseline epoch, which repeated rebaselining after
  reconfigurations defeats; a significant positive trend fires even when
  every individual step is below the CUSUM slack. Runs alongside CUSUM when
  the failure-lifecycle drift policy is enabled (see
  ``repro.core.detector.lifecycle``).

All are pure-python/numpy and O(window) per update, satisfying the paper's
"lightweight enough for online per-iteration detection" requirement.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field

import numpy as np


@dataclass
class BOCPD:
    hazard: float = 1.0 / 100.0  # P(change at any step)
    max_run: int = 256  # truncate run-length distribution
    lag: int = 3  # declare change when P(run < lag) is high
    threshold: float = 0.5
    # NIG prior (weak): mu0, kappa0, alpha0, beta0
    mu0: float = 0.0
    kappa0: float = 0.1
    alpha0: float = 1.0
    beta0: float = 1.0
    warmup: int = 8

    def __post_init__(self):
        self._warm: list = []
        self._calibrated = False
        self._reset_state()

    def _reset_state(self):
        self._r = np.array([1.0])  # run-length posterior
        self._mu = np.array([self.mu0])
        self._kappa = np.array([self.kappa0])
        self._alpha = np.array([self.alpha0])
        self._beta = np.array([self.beta0])
        self._n = 0

    def _calibrate(self):
        """Scale the NIG prior to the warm-up window: with a fixed beta0 the
        prior variance swamps low-noise series and big shifts look small."""
        arr = np.asarray(self._warm, dtype=np.float64)
        mean = float(arr.mean())
        var = float(max(arr.var(ddof=1), (0.01 * abs(mean)) ** 2, 1e-12))
        self.mu0 = mean
        self.kappa0 = 1.0
        self.alpha0 = 2.0
        self.beta0 = var * self.alpha0  # E[sigma^2] ~= warm-up variance
        self._calibrated = True
        self._reset_state()
        for x in self._warm:  # replay warm-up under the calibrated prior
            self._step(float(x))

    @staticmethod
    def _gammaln(x):
        """Stirling-series log-gamma (avoids a scipy dependency)."""
        x = np.asarray(x, dtype=np.float64)
        # reflection-free: x here is always > 0.5
        coefs = [
            76.18009172947146, -86.50532032941677, 24.01409824083091,
            -1.231739572450155, 0.1208650973866179e-2, -0.5395239384953e-5,
        ]
        y = x
        tmp = x + 5.5
        tmp -= (x + 0.5) * np.log(tmp)
        ser = np.full_like(x, 1.000000000190015)
        for c in coefs:
            y = y + 1.0
            ser = ser + c / y
        return -tmp + np.log(2.5066282746310005 * ser / x)

    def _student_t_logpdf(self, x):
        df = 2.0 * self._alpha
        scale2 = self._beta * (self._kappa + 1.0) / (self._alpha * self._kappa)
        g = self._gammaln
        return (
            g((df + 1.0) / 2.0)
            - g(df / 2.0)
            - 0.5 * np.log(np.pi * df * scale2)
            - (df + 1.0) / 2.0 * np.log1p((x - self._mu) ** 2 / (df * scale2))
        )

    def update(self, x: float) -> bool:
        """Ingest one observation; True iff a change point is detected."""
        if not self._calibrated:
            self._warm.append(float(x))
            if len(self._warm) >= self.warmup:
                self._calibrate()
            return False
        self._step(float(x))
        return float(self._r[: self.lag].sum()) > self.threshold

    def _step(self, x: float):
        self._n += 1
        logpred = self._student_t_logpdf(float(x))
        pred = np.exp(np.clip(logpred, -700, 50))
        growth = self._r * pred * (1.0 - self.hazard)
        cp = float(np.sum(self._r * pred * self.hazard))
        new_r = np.concatenate([[cp], growth])
        new_r /= max(new_r.sum(), 1e-300)

        # posterior updates per hypothesis (prepend the prior for run=0)
        kappa1 = self._kappa + 1.0
        mu1 = (self._kappa * self._mu + x) / kappa1
        alpha1 = self._alpha + 0.5
        beta1 = self._beta + 0.5 * self._kappa * (x - self._mu) ** 2 / kappa1
        self._mu = np.concatenate([[self.mu0], mu1])
        self._kappa = np.concatenate([[self.kappa0], kappa1])
        self._alpha = np.concatenate([[self.alpha0], alpha1])
        self._beta = np.concatenate([[self.beta0], beta1])
        self._r = new_r
        if len(self._r) > self.max_run:
            self._r = self._r[: self.max_run]
            self._r /= self._r.sum()
            self._mu = self._mu[: self.max_run]
            self._kappa = self._kappa[: self.max_run]
            self._alpha = self._alpha[: self.max_run]
            self._beta = self._beta[: self.max_run]

    def reset(self):
        self._warm = []
        self._calibrated = False
        self._reset_state()


@dataclass
class CusumDetector:
    """One-sided CUSUM on standardized deviations from a running baseline.

    Detects sustained *increases* in iteration time (fail-slow direction).
    The baseline (mean/std) freezes once warm so the post-change points do
    not contaminate it.
    """

    k: float = 0.5  # slack, in std units
    h: float = 5.0  # decision threshold, in std units
    warmup: int = 12
    _hist: list = field(default_factory=list)
    _s: float = 0.0
    _prev_s: float = 0.0  # _s before the last update (discard_last rewind)
    _mean: float = 0.0
    _std: float = 1.0
    _frozen: bool = False

    def update(self, x: float) -> bool:
        if not self._frozen:
            self._hist.append(float(x))
            if len(self._hist) >= self.warmup:
                arr = np.asarray(self._hist, dtype=np.float64)
                self._mean = float(arr.mean())
                self._std = float(max(arr.std(ddof=1), 1e-9, 0.01 * abs(self._mean)))
                self._frozen = True
            return False
        z = (float(x) - self._mean) / self._std
        self._prev_s = self._s
        self._s = max(0.0, self._s + z - self.k)
        if self._s > self.h:
            self._s = 0.0
            return True
        return False

    def discard_last(self):
        """Remove the last point's contribution (paper: benign change points
        are removed from the series so they don't perturb later detection).

        Restores ``_s`` to its value before the last ``update`` — i.e. the
        last z-increment (and, when the point pushed ``_s`` over ``h``, the
        fire-reset to zero) is undone, so a benign workload spike neither
        accumulates toward a spurious change point nor erases legitimately
        accumulated drift evidence. During warm-up the point is dropped from
        the baseline window instead (a companion drift detector can fire
        before CUSUM is frozen)."""
        if not self._frozen:
            if self._hist:
                self._hist.pop()
            return
        self._s = self._prev_s

    def clear_evidence(self):
        """Drop the accumulated evidence but keep the frozen baseline — used
        when a validation pass has just certified the fleet healthy, proving
        whatever ``_s`` had accumulated was noise."""
        self._s = 0.0
        self._prev_s = 0.0

    def carried(self, scale: float) -> "CusumDetector":
        """Baseline carry across a reconfiguration: the healthy iteration
        time changes by a *predictable* ratio (Eq. 1/2 under old vs new
        plan), so instead of re-learning from scratch — which lets a slow
        ramp hide inside every fresh warm-up window — the frozen baseline is
        rescaled by ``scale`` and the accumulated CUSUM evidence is kept
        (``_s`` is in std units, invariant under a common rescale). Falls
        back to a fresh detector if the baseline was never frozen."""
        new = CusumDetector(k=self.k, h=self.h, warmup=self.warmup)
        if self._frozen and scale > 0.0 and math.isfinite(scale):
            new._mean = self._mean * scale
            new._std = self._std * scale
            new._frozen = True
            new._s = self._s
            new._prev_s = self._prev_s
        return new

    def rebaseline(self):
        """Re-learn the healthy baseline (after a reconfiguration)."""
        self._hist = []
        self._s = 0.0
        self._prev_s = 0.0
        self._frozen = False


@dataclass
class SlopeDriftDetector:
    """Windowed least-squares trend test for slow-ramp degradations.

    Fits ``y ~ a + b*t`` over the last ``window`` points and fires when the
    slope is both practically significant (``b`` exceeds ``rel_slope_min`` of
    the window mean per step) and statistically significant (``b / stderr(b)``
    exceeds ``sig``). Complements CUSUM: a ramp spreads its level shift over
    many points, each inside the CUSUM slack, but the trend statistic grows
    with the window. The window is NOT cleared on a fire: while the trend
    persists the detector keeps alarming (each alarm costs only the workload
    filter) so the ramp is re-examined as it deepens — essential because the
    filter releases a validation only once the ramp clears its margin.
    ``rescale`` carries the window across a reconfiguration whose healthy
    time changed by a predicted ratio."""

    window: int = 40
    min_points: int = 12
    sig: float = 4.0  # threshold on the t-like statistic slope/stderr
    rel_slope_min: float = 0.0015  # slope floor, per step, relative to mean
    _pts: list = field(default_factory=list)

    def update(self, x: float) -> bool:
        self._pts.append(float(x))
        if len(self._pts) > self.window:
            self._pts.pop(0)
        n = len(self._pts)
        if n < self.min_points:
            return False
        y = np.asarray(self._pts, dtype=np.float64)
        t = np.arange(n, dtype=np.float64)
        tc = t - t.mean()
        ybar = float(y.mean())
        stt = float((tc * tc).sum())
        b = float((tc * (y - ybar)).sum()) / stt
        if b <= self.rel_slope_min * max(abs(ybar), 1e-12):
            return False
        resid = y - (ybar + b * tc)
        dof = max(n - 2, 1)
        se = math.sqrt(max(float((resid * resid).sum()) / dof, 1e-24) / stt)
        return b / max(se, 1e-12) > self.sig

    def discard_last(self):
        """Drop the last (filtered-benign) point from the trend window."""
        if self._pts:
            self._pts.pop()

    def rescale(self, scale: float):
        """Carry the window across a reconfiguration: every point rescaled by
        the predicted healthy-time ratio, so the trend of the underlying
        degradation survives the plan change."""
        if scale > 0.0 and math.isfinite(scale):
            self._pts = [p * scale for p in self._pts]
        else:
            self._pts = []

    def reset(self):
        self._pts = []
