"""ResiHPController: the two-stage detect -> adapt protocol (paper §4).

Wires the Detector (fail-stop heartbeats + workload-aware fail-slow) to the
Scheduler (progressive TP/PP/DP adaptation). Both the discrete-event cluster
simulator (256-GPU experiments) and the real JAX engine (8-device
integration tests) drive this same controller:

    ctl = ResiHPController(scheduler, detector, plan, speeds)
    ...
    rep = ctl.observe_iteration(it, seconds, workload, now)   # fail-slow path
    rep = ctl.poll(now)                                       # fail-stop path
    if rep: adaptation = ctl.adapt(now)                       # new plan

The controller owns the authoritative device-speed view: fail-stop sets a
device's speed to 0, fail-slow to the measured fraction; adapt() feeds that
into Scheduler.adapt and rebaselines the Detector's time series (the healthy
iteration time changes with the plan).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Optional

from repro.core.detector.detector import Detector, FailureReport
from repro.core.scheduler.plan import ParallelPlan
from repro.core.scheduler.scheduler import AdaptationPlan, Scheduler


@dataclass
class ReconfigEvent:
    time: float
    reports: tuple
    adaptation: AdaptationPlan


@dataclass
class ResiHPController:
    scheduler: Scheduler
    detector: Detector
    plan: ParallelPlan
    speeds: dict  # device_id -> normalized throughput (authoritative view)
    pending: list = field(default_factory=list)
    events: list = field(default_factory=list)
    stage_speeds: dict = field(default_factory=dict)

    def __post_init__(self):
        if not self.stage_speeds:
            self.stage_speeds = {
                (r, s): 1.0
                for r in range(self.plan.dp)
                for s in range(self.plan.replicas[0].pp)
            }
        self.detector.heartbeat.on_failstop = None  # polled, not pushed

    # ------------------------------------------------------------- detect
    def poll(self, now: float) -> Optional[FailureReport]:
        rep = self.detector.poll_failstop(now)
        if rep:
            for d in rep.devices:
                self.speeds[d] = 0.0
            self.pending.append(rep)
        return rep

    def observe_iteration(self, iteration: int, seconds: float, workload,
                          now: float = 0.0) -> Optional[FailureReport]:
        rep = self.detector.observe_iteration(iteration, seconds, workload, now)
        if rep:
            for dev, speed in rep.devices:
                self.speeds[dev] = float(speed)
            self.pending.append(rep)
        return rep

    def inject_rejoin(self, devices, now: float = 0.0):
        """Repaired devices coming back (the Fig. 14 dynamic scenario)."""
        for d in devices:
            self.speeds[d] = 1.0
        self.pending.append(
            FailureReport("rejoin", tuple(devices), -1, now, detail="devices restored")
        )

    # -------------------------------------------------------------- adapt
    def adapt(self, now: float = 0.0) -> Optional[AdaptationPlan]:
        if not self.pending:
            return None
        reports = tuple(self.pending)
        self.pending = []
        failed = {d for d, v in self.speeds.items() if v <= 0.0}
        adaptation = self.scheduler.adapt(self.plan, self.speeds, failed=failed)
        self.plan = adaptation.plan
        self.stage_speeds = adaptation.stage_speeds
        self.detector.rebaseline()
        self.events.append(ReconfigEvent(now, reports, adaptation))
        return adaptation

    # ------------------------------------------------------------ queries
    @property
    def total_detection_overhead_s(self) -> float:
        return self.detector.overhead_s

    @property
    def total_plan_overhead_s(self) -> float:
        return sum(e.adaptation.plan_overhead_s for e in self.events)
