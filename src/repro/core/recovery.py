"""Optimizer-state and parameter recovery during reconfiguration (paper §7,
Fig. 8) — adapted to JAX/XLA semantics.

Torch rebuilds NCCL groups and manually reshards tensors; in JAX the
equivalent is: build the new plan's shardings and `jax.device_put` the live
state into them (XLA emits exactly the point-to-point transfers Fig. 7
optimizes). The three Fig. 8 cases map to:

  (a) a DP replica lost, params DP-replicated -> survivors already hold the
      state; recovery is re-sharding onto the surviving mesh (peer copy).
  (b) every replica of some stage lost -> no live source; fall back to the
      last committed checkpoint (restore_into_plan).
  (c) layer repartition / TP-degree change -> layers (params + optimizer
      state) move between stage groups and reshard; `transfer_plan`
      enumerates the per-layer source->dest copies and byte volumes (the
      Fig. 13 layer-transfer overhead), and `reshard_live` performs the JAX
      transfer for the in-process engine.
"""
from __future__ import annotations

from dataclasses import dataclass, field

import jax

from repro.core.scheduler.plan import ParallelPlan


@dataclass(frozen=True)
class LayerMove:
    layer: int
    src_replica: int  # surviving replica to copy from (-1 = checkpoint)
    src_stage: int
    dst_stage: int
    tp_from: int
    tp_to: int
    bytes: int


@dataclass
class TransferPlan:
    moves: list
    restore_required: bool = False

    @property
    def total_bytes(self) -> int:
        return sum(m.bytes for m in self.moves)

    def seconds(self, bw: float = 25e9) -> float:
        """Wall time estimate over the slow fabric (scatter/gather optimized:
        each byte crosses once — §7)."""
        return self.total_bytes / bw


def layer_state_bytes(cfg, *, opt_multiplier: float = 3.0, dtype_bytes: int = 4) -> list:
    """Approximate per-layer bytes of params + optimizer state."""
    from repro.core.scheduler.repartition import costs_for_arch

    total_params = cfg.param_count() - 2 * cfg.padded_vocab * cfg.d_model
    rel = costs_for_arch(cfg)
    s = sum(rel)
    return [int(total_params * (r / s) * dtype_bytes * opt_multiplier) for r in rel]


def transfer_plan(cfg, old_plan: ParallelPlan, new_plan: ParallelPlan,
                  *, dead_stages=()) -> TransferPlan:
    """Which layers must move (Fig. 8c), and from where (Fig. 8a/b)."""
    dead = set(dead_stages)
    per_layer_bytes = layer_state_bytes(cfg)
    moves, restore = [], False
    old_owner = {}  # layer -> stage (uniform across replicas)
    for s, st in enumerate(old_plan.replicas[0].stages):
        for l in st.layers:
            old_owner[l] = s
    for s, st in enumerate(new_plan.replicas[0].stages):
        for l in st.layers:
            src_stage = old_owner[l]
            tp_from = old_plan.replicas[0].stages[src_stage].tp
            tp_to = st.tp
            if src_stage == s and tp_from == tp_to:
                continue  # stays put
            # pick a surviving replica that still holds this stage's state
            src_replica = -1
            for r in range(old_plan.dp):
                if (r, src_stage) not in dead:
                    src_replica = r
                    break
            if src_replica < 0:
                restore = True
            moves.append(LayerMove(
                l, src_replica, src_stage, s, tp_from, tp_to,
                per_layer_bytes[l] if l < len(per_layer_bytes) else per_layer_bytes[-1],
            ))
    return TransferPlan(moves, restore_required=restore)


# --------------------------------------------------------------- JAX side
def reshard_live(state, shardings):
    """Fig. 8a/c for the in-process engine: place live state into the new
    plan's shardings (XLA performs the P2P moves)."""
    return jax.tree.map(
        lambda x, s: jax.device_put(x, s) if s is not None else x,
        state, shardings,
        is_leaf=lambda x: not isinstance(x, (dict, list, tuple)),
    )


def recover_state(cfg, state, *, old_plan, new_plan, shardings, checkpoint_mgr=None,
                  dead_stages=()):
    """Full Fig. 8 flow. Returns (state, TransferPlan, restored_from_step).

    Live recovery when any replica survives per stage; otherwise restores the
    last committed checkpoint into the new shardings.
    """
    tp = transfer_plan(cfg, old_plan, new_plan, dead_stages=dead_stages)
    if tp.restore_required:
        if checkpoint_mgr is None or not checkpoint_mgr.has_checkpoint():
            raise RuntimeError(
                "all replicas of a stage failed and no checkpoint exists "
                "(Fig. 8b requires persistent state)"
            )
        state, step, _ = checkpoint_mgr.restore_latest(target=state, shardings=shardings)
        return state, tp, step
    return reshard_live(state, shardings), tp, None
