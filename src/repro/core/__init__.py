from repro.core.resihp import ResiHPController  # noqa: F401
