"""ParallelPlan: the Scheduler's output and the execution engine's input.

A plan is (DP replicas) x (PP stages) with a per-stage device set (the TP
group), per-stage layer assignment, and a standby-device pool. Heterogeneous
TP degrees across stages/replicas are first-class (paper §6.1), as is an
uneven layer partition (paper §6.2).

Plans are pure data: the cluster simulator executes them analytically, and
the JAX engine realizes them as per-stage meshes + pjit'd step functions.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional


# Efficiency of a TP group running *nonuniform* shard widths relative to an
# equal-width group of the same aggregate speed: ragged all-reduce segments
# and per-rank kernel-shape divergence cost a few percent (NTP paper,
# arxiv 2504.06095). A system property of the collective implementation, so
# it lives with the plan data and both the planner's estimate and the
# simulator's ground truth default to it.
NTP_EFFICIENCY = 0.92


@dataclass(frozen=True)
class StagePlan:
    devices: tuple  # device ids in this TP group (sorted)
    layers: tuple  # global layer indices assigned to this stage (contiguous)
    # nonuniform TP (NTP): per-device shard widths, aligned with ``devices``.
    # None (the default) = uniform 1/tp shards, the classic Megatron layout.
    shard_fractions: Optional[tuple] = None

    def __post_init__(self):
        fr = self.shard_fractions
        if fr is None:
            return
        if len(fr) != len(self.devices):
            raise ValueError(
                f"shard_fractions needs one width per device: "
                f"{len(fr)} widths for {len(self.devices)} devices")
        if any(f <= 0.0 for f in fr):
            raise ValueError(f"shard_fractions must be positive: {fr}")
        if abs(sum(fr) - 1.0) > 1e-6:
            raise ValueError(f"shard_fractions must sum to 1: sum={sum(fr)!r}")

    @property
    def tp(self) -> int:
        return len(self.devices)

    @property
    def n_layers(self) -> int:
        return len(self.layers)


@dataclass(frozen=True)
class ReplicaPlan:
    stages: tuple  # tuple[StagePlan]

    @property
    def pp(self) -> int:
        return len(self.stages)

    @property
    def devices(self) -> tuple:
        return tuple(d for s in self.stages for d in s.devices)

    def stage_of_layer(self, layer: int) -> int:
        for i, s in enumerate(self.stages):
            if layer in s.layers:
                return i
        raise KeyError(layer)


@dataclass(frozen=True)
class ParallelPlan:
    replicas: tuple  # tuple[ReplicaPlan]
    standby: tuple = ()  # healthy devices kept warm for later swaps (§6.1)
    microbatches: int = 8  # per replica per iteration
    schedule: str = "1f1b"
    # replica -> stage -> dead (all devices failed, workloads must evict)
    dead_stages: tuple = ()  # tuple[(replica, stage)]

    @property
    def dp(self) -> int:
        return len(self.replicas)

    @property
    def devices(self) -> tuple:
        return tuple(d for r in self.replicas for d in r.devices) + tuple(self.standby)

    @property
    def n_layers(self) -> int:
        return sum(s.n_layers for s in self.replicas[0].stages)

    def stage(self, replica: int, stage: int) -> StagePlan:
        return self.replicas[replica].stages[stage]

    def replace(self, **kw) -> "ParallelPlan":
        return dataclasses.replace(self, **kw)

    def with_stage(self, replica: int, stage: int, new_stage: StagePlan) -> "ParallelPlan":
        reps = list(self.replicas)
        stages = list(reps[replica].stages)
        stages[stage] = new_stage
        reps[replica] = ReplicaPlan(tuple(stages))
        return self.replace(replicas=tuple(reps))

    def summary(self) -> str:
        lines = []
        for r, rep in enumerate(self.replicas):
            cells = [
                f"s{i}:tp{s.tp}xL{s.n_layers}"
                + ("w[" + "/".join(f"{f:.2f}" for f in s.shard_fractions) + "]"
                   if s.shard_fractions is not None else "")
                for i, s in enumerate(rep.stages)
            ]
            lines.append(f"dp{r}[" + " ".join(cells) + "]")
        if self.standby:
            lines.append(f"standby={list(self.standby)}")
        return " ".join(lines)


def initial_plan(n_layers: int, dp: int, pp: int, tp: int, *, device_ids=None,
                 microbatches: int = 8, schedule: str = "1f1b") -> ParallelPlan:
    """The fault-free plan: even layer split, uniform TP, rank-ordered devices
    (TP-contiguous so TP groups stay inside a node, like Megatron rank maps)."""
    if device_ids is None:
        device_ids = list(range(dp * pp * tp))
    assert len(device_ids) == dp * pp * tp
    per = [n_layers // pp + (1 if i < n_layers % pp else 0) for i in range(pp)]
    replicas = []
    it = iter(device_ids)
    for _ in range(dp):
        stages, off = [], 0
        for s in range(pp):
            devs = tuple(next(it) for _ in range(tp))
            layers = tuple(range(sum(per[:s]), sum(per[: s + 1])))
            stages.append(StagePlan(devs, layers))
        replicas.append(ReplicaPlan(tuple(stages)))
    return ParallelPlan(tuple(replicas), microbatches=microbatches, schedule=schedule)
