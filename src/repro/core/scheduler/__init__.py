from repro.core.scheduler.plan import ParallelPlan, ReplicaPlan, StagePlan  # noqa: F401
from repro.core.scheduler.tp_reconfig import reconfigure_tp_group, candidate_degrees  # noqa: F401
from repro.core.scheduler.repartition import repartition_layers  # noqa: F401
from repro.core.scheduler.migration import ProgressAwareMigrator  # noqa: F401
from repro.core.scheduler.p2p import p2p_mapping, p2p_cost_bytes  # noqa: F401
from repro.core.scheduler.scheduler import Scheduler  # noqa: F401
