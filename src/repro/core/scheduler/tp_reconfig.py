"""Selective device exclusion within affected TP groups (paper §6.1).

Given the original TP group G, the fail-stop set F_stop, and per-device
normalized throughput p_i (1.0 = healthy peak), the Scheduler:

  1. generates candidate TP degrees  K = {k | k_min <= k <= |G'|, k = 2^q}
     (Eq. 3) where G' = G \\ F_stop and k_min is the memory floor;
  2. for each k, greedily picks the top-k devices by p_i (healthy first,
     fastest fail-slow devices only when needed);
  3. selects S* = argmax_k ( k * min_{i in S_k} p_i )  (Eq. 4) — TP collectives
     synchronize every layer, so a group runs at its slowest member's rate,
     while a larger k scales aggregate compute;
  4. keeps unassigned healthy devices online as node-local standbys.

Risk-aware placement (PR 4, default off): when a per-device hazard view is
supplied (``risk={device: estimated rate / fleet prior}``, from the failure-
lifecycle hazard estimator), equal-throughput choices break toward the
lower-hazard device — Eq. 4 still decides throughput, but among the many
speed-1.0 candidates the greedy ranking stops being arbitrary and prefers
devices that are least likely to force the *next* reconfiguration. With
``risk=None`` the selection is byte-identical to the pre-hazard behaviour.

Nonuniform TP (NTP, default off): when an :class:`NTPConfig` is supplied, a
*shrink-shard* candidate competes with Eq. 4 exclusion — keep the degraded
device but give it a shard proportional to its measured speed (widths
``f_i ∝ p_i``). The group's per-layer time is ``max_i(f_i / p_i)`` (every
rank still synchronizes per layer, but a slow rank now has less work), so
proportional widths make the effective throughput ``efficiency * sum(p_i)``
instead of ``k * min(p_i)`` — the mildly-slow device contributes its actual
speed rather than dragging the whole group down or being thrown away. The
efficiency discount models ragged-collective overhead; it is what keeps a
healthy uniform group from "shrinking" to no benefit (ties and losses keep
the exclusion plan, so ``ntp=None`` callers see byte-identical output).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Optional

from repro.core.scheduler.plan import NTP_EFFICIENCY


@dataclass(frozen=True)
class NTPConfig:
    """Nonuniform-TP planning knobs (arxiv 2504.06095).

    efficiency: planner's estimate of the nonuniform-collective efficiency
        (defaults to the simulator's ground-truth system constant).
    min_fraction: smallest useful shard width — a device whose proportional
        width would land below this is left on standby instead (a 2% sliver
        of the weights is not worth a rank in every collective).
    """

    efficiency: float = NTP_EFFICIENCY
    min_fraction: float = 0.04


@dataclass(frozen=True)
class TPReconfig:
    devices: tuple  # selected subgroup S*, sorted by device id
    tp: int
    effective_throughput: float  # k * min p_i  (in units of one healthy device)
    standby: tuple  # surviving devices left out of S*
    excluded: tuple  # fail-stop devices removed
    # NTP shrink-shard result: per-device widths aligned with ``devices``
    # (None = uniform shards, the classic exclusion outcome)
    shard_fractions: Optional[tuple] = None
    mode: str = "exclude"  # 'exclude' (Eq. 4) | 'shrink' (NTP widths)

    @property
    def group_speed(self) -> float:
        """Throughput per member — ``min p_i`` for uniform shards (the rate
        every member effectively runs at), the mean contribution for NTP."""
        return self.effective_throughput / max(self.tp, 1)


def candidate_degrees(n_survivors: int, k_min: int) -> list:
    """Eq. 3: power-of-two degrees in [k_min, |G'|]."""
    ks, k = [], 1
    while k <= n_survivors:
        if k >= k_min:
            ks.append(k)
        k *= 2
    return ks


def shrink_shard_candidate(survivors, speeds, ntp: NTPConfig,
                           *, k_min: int = 1,
                           veto=frozenset()) -> Optional[TPReconfig]:
    """NTP candidate over the surviving pool: widths ``f_i ∝ p_i`` so the
    group's per-layer time ``max_i(f_i / p_i)`` is flat across members and
    throughput reaches ``efficiency * sum(p_i)``.

    Two constraints shape the widths:

    * devices whose proportional width falls below ``ntp.min_fraction`` are
      dropped to standby (iteratively, slowest first — dropping one raises
      everyone else's share);
    * the memory floor caps any width at ``1/k_min`` (the same HBM bound
      Eq. 3 expresses as a minimum degree); capped excess re-spreads
      proportionally over the uncapped members (water-filling).

    ``veto`` (credit-gated NTP, default empty = legacy behaviour): devices a
    caller's trust model bars from shrink-shard retention — they go to
    standby like a below-min-fraction sliver, so the exclusion candidate is
    the only plan that may keep them. Nonuniform widths are for trustworthy
    stragglers (thermal capping); a device whose *history* says the slowness
    is a symptom should compete as an exclusion, not keep a shard.

    Returns None when no feasible group remains (fewer than ``k_min``
    members, or fewer than 2 — a single-device "group" is plain exclusion).
    """
    kept = sorted((d for d in survivors if d not in veto),
                  key=lambda d: (-speeds.get(d, 1.0), d))
    while kept:
        tot = sum(speeds.get(d, 1.0) for d in kept)
        if speeds.get(kept[-1], 1.0) / tot >= ntp.min_fraction:
            break
        kept.pop()
    if len(kept) < max(k_min, 2):
        return None
    cap = 1.0 / k_min
    p = {d: speeds.get(d, 1.0) for d in kept}
    free = {d: v / sum(p.values()) for d, v in p.items()}
    capped: dict = {}
    while True:
        over = [d for d in free if free[d] > cap + 1e-12]
        if not over:
            break
        for d in over:
            capped[d] = cap
            del free[d]
        rem = 1.0 - cap * len(capped)
        if not free or rem <= 1e-12:
            return None  # memory floor leaves no width to distribute
        tot = sum(p[d] for d in free)
        free = {d: rem * p[d] / tot for d in free}
    widths = {**capped, **free}
    worst = max(widths[d] / p[d] for d in kept)
    thru = ntp.efficiency / worst
    devices = tuple(sorted(kept))
    return TPReconfig(
        devices, len(devices), thru,
        standby=tuple(sorted(set(survivors) - set(kept))),
        excluded=(),
        shard_fractions=tuple(widths[d] for d in devices),
        mode="shrink",
    )


def reconfigure_tp_group(group, speeds, *, k_min: int = 1,
                         failed=(), risk=None,
                         ntp: Optional[NTPConfig] = None,
                         ntp_veto=frozenset()) -> TPReconfig:
    """group: device ids of the original TP group.
    speeds: {device_id: normalized throughput p_i}; fail-stop devices may be
    listed in `failed` or have speed <= 0.
    k_min: memory floor — the minimum TP degree whose shards still fit HBM.
    risk: optional {device_id: hazard score} — equal-speed ties rank
    low-hazard first (None => exact legacy ordering).
    ntp: optional NTPConfig — also score a shrink-shard (nonuniform-width)
    candidate and return it when it strictly beats exclusion (None => exact
    legacy exclusion-only behaviour).
    ntp_veto: devices barred from shrink-shard retention (credit-gated NTP;
    empty => every survivor is shrink-eligible, the legacy behaviour).
    """
    # a device absent from `speeds` is healthy (p = 1.0) everywhere in this
    # module — only an explicit `failed` listing or a speed <= 0 excludes it
    failed = set(failed) | {d for d in group if speeds.get(d, 1.0) <= 0.0}
    survivors = [d for d in group if d not in failed]
    ks = candidate_degrees(len(survivors), k_min)
    if not ks:
        return TPReconfig((), 0, 0.0, tuple(sorted(survivors)), tuple(sorted(failed)))

    # rank by normalized throughput, healthy (1.0) first; with a hazard view,
    # equal-speed ties prefer the lower-risk device (risk-aware placement)
    if risk is None:
        ranked = sorted(survivors, key=lambda d: -speeds.get(d, 1.0))
    else:
        ranked = sorted(survivors,
                        key=lambda d: (-speeds.get(d, 1.0),
                                       risk.get(d, 1.0)))
    best, best_thru = None, -1.0
    for k in ks:
        sk = ranked[:k]
        thru = k * min(speeds.get(d, 1.0) for d in sk)
        # strictly-greater keeps the smallest k on ties -> frees more standbys
        if thru > best_thru:
            best, best_thru = sk, thru
    standby = tuple(sorted(set(survivors) - set(best)))
    exclude = TPReconfig(tuple(sorted(best)), len(best), best_thru, standby,
                         tuple(sorted(failed)))
    if ntp is None:
        return exclude
    shrink = shrink_shard_candidate(survivors, speeds, ntp, k_min=k_min,
                                    veto=ntp_veto)
    # strictly-greater: ties keep exclusion (uniform shards, frees standbys)
    if shrink is None or shrink.effective_throughput <= best_thru:
        return exclude
    return dataclasses.replace(shrink, excluded=tuple(sorted(failed)))


def backfill_from_standby(reconf: TPReconfig, speeds, *, k_min: int = 1,
                          risk=None, ntp: Optional[NTPConfig] = None) -> TPReconfig:
    """Re-run selection over survivors + standbys (used when a later failure
    hits the group again and the node-local standby pool can help — §6.1
    'reuse them for subsequent intra-node failures')."""
    pool = list(reconf.devices) + list(reconf.standby)
    return reconfigure_tp_group(pool, speeds, k_min=k_min, risk=risk, ntp=ntp)
