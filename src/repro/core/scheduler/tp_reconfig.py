"""Selective device exclusion within affected TP groups (paper §6.1).

Given the original TP group G, the fail-stop set F_stop, and per-device
normalized throughput p_i (1.0 = healthy peak), the Scheduler:

  1. generates candidate TP degrees  K = {k | k_min <= k <= |G'|, k = 2^q}
     (Eq. 3) where G' = G \\ F_stop and k_min is the memory floor;
  2. for each k, greedily picks the top-k devices by p_i (healthy first,
     fastest fail-slow devices only when needed);
  3. selects S* = argmax_k ( k * min_{i in S_k} p_i )  (Eq. 4) — TP collectives
     synchronize every layer, so a group runs at its slowest member's rate,
     while a larger k scales aggregate compute;
  4. keeps unassigned healthy devices online as node-local standbys.

Risk-aware placement (PR 4, default off): when a per-device hazard view is
supplied (``risk={device: estimated rate / fleet prior}``, from the failure-
lifecycle hazard estimator), equal-throughput choices break toward the
lower-hazard device — Eq. 4 still decides throughput, but among the many
speed-1.0 candidates the greedy ranking stops being arbitrary and prefers
devices that are least likely to force the *next* reconfiguration. With
``risk=None`` the selection is byte-identical to the pre-hazard behaviour.
"""
from __future__ import annotations

from dataclasses import dataclass


@dataclass(frozen=True)
class TPReconfig:
    devices: tuple  # selected subgroup S*, sorted by device id
    tp: int
    effective_throughput: float  # k * min p_i  (in units of one healthy device)
    standby: tuple  # surviving devices left out of S*
    excluded: tuple  # fail-stop devices removed

    @property
    def group_speed(self) -> float:
        """min p_i — the rate every member effectively runs at."""
        return self.effective_throughput / max(self.tp, 1)


def candidate_degrees(n_survivors: int, k_min: int) -> list:
    """Eq. 3: power-of-two degrees in [k_min, |G'|]."""
    ks, k = [], 1
    while k <= n_survivors:
        if k >= k_min:
            ks.append(k)
        k *= 2
    return ks


def reconfigure_tp_group(group, speeds, *, k_min: int = 1,
                         failed=(), risk=None) -> TPReconfig:
    """group: device ids of the original TP group.
    speeds: {device_id: normalized throughput p_i}; fail-stop devices may be
    listed in `failed` or have speed <= 0.
    k_min: memory floor — the minimum TP degree whose shards still fit HBM.
    risk: optional {device_id: hazard score} — equal-speed ties rank
    low-hazard first (None => exact legacy ordering).
    """
    failed = set(failed) | {d for d in group if speeds.get(d, 0.0) <= 0.0}
    survivors = [d for d in group if d not in failed]
    ks = candidate_degrees(len(survivors), k_min)
    if not ks:
        return TPReconfig((), 0, 0.0, tuple(sorted(survivors)), tuple(sorted(failed)))

    # rank by normalized throughput, healthy (1.0) first; with a hazard view,
    # equal-speed ties prefer the lower-risk device (risk-aware placement)
    if risk is None:
        ranked = sorted(survivors, key=lambda d: -speeds.get(d, 1.0))
    else:
        ranked = sorted(survivors,
                        key=lambda d: (-speeds.get(d, 1.0),
                                       risk.get(d, 1.0)))
    best, best_thru = None, -1.0
    for k in ks:
        sk = ranked[:k]
        thru = k * min(speeds.get(d, 1.0) for d in sk)
        # strictly-greater keeps the smallest k on ties -> frees more standbys
        if thru > best_thru:
            best, best_thru = sk, thru
    standby = tuple(sorted(set(survivors) - set(best)))
    return TPReconfig(tuple(sorted(best)), len(best), best_thru, standby,
                      tuple(sorted(failed)))


def backfill_from_standby(reconf: TPReconfig, speeds, *, k_min: int = 1,
                          risk=None) -> TPReconfig:
    """Re-run selection over survivors + standbys (used when a later failure
    hits the group again and the node-local standby pool can help — §6.1
    'reuse them for subsequent intra-node failures')."""
    pool = list(reconf.devices) + list(reconf.standby)
    return reconfigure_tp_group(pool, speeds, k_min=k_min, risk=risk)
