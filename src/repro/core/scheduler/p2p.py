"""P2P communication between pipeline stages with heterogeneous TP degrees
(paper §7, Fig. 7) — the symmetric mapping rule + a fabric-aware cost model.

Megatron's scatter/gather optimization sends each boundary tensor once over
the slow fabric (split into TP-many chunks, re-gathered over the fast
intra-node fabric) but requires equal sender/receiver TP degrees. After
selective exclusion (§6.1) degrees differ; the symmetric rule generalizes it:

  N = max(tp_send, tp_recv); the boundary tensor is viewed as N equal chunks.
  Sender rank s owns chunks [s*N/tp_send, (s+1)*N/tp_send); receiver rank r
  needs chunks [r*N/tp_recv, (r+1)*N/tp_recv) — wait, receivers re-gather, so
  each receiver rank is *sent* exactly one distinct chunk-group slice and the
  full tensor is reconstructed receiver-side over NVLink/ICI. Every chunk
  crosses the slow fabric exactly once (vs tp_recv times naively).

On TPU the slow/fast split maps to DCN (inter-slice) vs ICI (intra-slice);
in JAX the rule materializes as resharding-on-transfer: the sender's output
sharding over N chunks, `jax.device_put` to the receiver mesh, then an ICI
all-gather — XLA emits exactly the Fig. 7(b) pattern.
"""
from __future__ import annotations

import math
from dataclasses import dataclass


def p2p_mapping(tp_send: int, tp_recv: int):
    """The symmetric mapping rule: -> list of (send_rank, recv_rank, chunk).

    The tensor is split into N = max(tp_send, tp_recv) equal chunks. Chunk c
    lives on sender rank  c * tp_send // N  and is needed first by receiver
    rank  c * tp_recv // N ; each chunk crosses the slow fabric exactly once.
    """
    assert tp_send >= 1 and tp_recv >= 1
    n = max(tp_send, tp_recv)
    assert n % tp_send == 0 and n % tp_recv == 0, (
        "power-of-two TP degrees (Eq. 3) guarantee divisibility"
    )
    return [(c * tp_send // n, c * tp_recv // n, c) for c in range(n)]


@dataclass(frozen=True)
class Fabric:
    slow_bw: float = 25e9  # bytes/s across nodes/slices (IB/DCN)
    fast_bw: float = 300e9  # bytes/s within node/slice (NVLink/ICI)
    latency: float = 10e-6


def p2p_cost_bytes(tensor_bytes: int, tp_send: int, tp_recv: int,
                   *, scatter_gather: bool = True):
    """Slow-fabric bytes for one boundary transfer.

    naive            : each receiver rank pulls the full tensor
    scatter/gather   : each chunk crosses once -> tensor_bytes total
    """
    if not scatter_gather:
        return tensor_bytes * tp_recv
    return tensor_bytes


def p2p_time(tensor_bytes: int, tp_send: int, tp_recv: int, fabric: Fabric = Fabric(),
             *, scatter_gather: bool = True) -> float:
    """Seconds for one stage-boundary transfer under the rule."""
    slow = p2p_cost_bytes(tensor_bytes, tp_send, tp_recv, scatter_gather=scatter_gather)
    t_slow = slow / fabric.slow_bw
    if scatter_gather:
        n = max(tp_send, tp_recv)
        # receiver-side all-gather of (n-1)/n of the tensor over the fast fabric
        t_fast = tensor_bytes * (n - 1) / n / fabric.fast_bw
    else:
        t_fast = 0.0
    return fabric.latency + t_slow + t_fast


def boundary_bytes(cfg, microbatch_tokens: int, dtype_bytes: int = 2) -> int:
    """Activation bytes crossing one PP boundary per micro-batch."""
    return microbatch_tokens * cfg.d_model * dtype_bytes


def chunk_slices(total_dim: int, tp_send: int, tp_recv: int):
    """Index slices of the boundary tensor's model dim for each chunk of the
    symmetric mapping — used by the JAX engine to build device_put shardings."""
    n = max(tp_send, tp_recv)
    assert total_dim % n == 0, (total_dim, n)
    w = total_dim // n
    return [slice(c * w, (c + 1) * w) for c in range(n)]
