"""Layer repartition to alleviate PP imbalance (paper §6.2).

Straggling stages (degraded TP groups after §6.1 reconfiguration) get fewer
layers; the excess is spread over healthy stages. We minimize the pipeline's
steady-state bottleneck  max_s ( work_s / speed_s )  where work_s is the
summed per-layer cost of the stage's layers and speed_s its effective
throughput. Layer assignments stay contiguous (activations flow stage to
stage), so this is optimal contiguous partitioning over heterogeneous stage
speeds — solved exactly by dynamic programming (n_layers <= ~100 and
stages <= 16, so O(S * n^2) is microseconds).

Per-layer costs may differ (hybrid models: a Mamba layer is cheaper than an
attention layer at long context), which is why this takes a cost vector, not
a layer count.
"""
from __future__ import annotations

import math


def repartition_layers(layer_costs, stage_speeds, *, min_layers=1):
    """-> list of per-stage layer-index tuples (contiguous, covers all layers)
    minimizing the bottleneck stage time. Exact DP.

    layer_costs: per-layer execution cost on a healthy stage.
    stage_speeds: per-stage effective throughput (1.0 = healthy); a stage at
        0.5 finishes the same layers in 2x the time. A dead stage (speed 0)
        is not allowed here — evict it from the plan first.
    """
    costs = [float(c) for c in layer_costs]
    speeds = [float(v) for v in stage_speeds]
    n, S = len(costs), len(speeds)
    assert all(v > 0 for v in speeds), "dead stages must be evicted before repartition"
    assert n >= S * min_layers, (n, S, min_layers)

    prefix = [0.0]
    for c in costs:
        prefix.append(prefix[-1] + c)

    def seg(i, j, s):  # time of layers [i, j) on stage s
        return (prefix[j] - prefix[i]) / speeds[s]

    INF = math.inf
    # dp[s][j]: min bottleneck assigning first j layers to stages [0..s]
    dp = [[INF] * (n + 1) for _ in range(S)]
    cut = [[-1] * (n + 1) for _ in range(S)]
    for j in range(min_layers, n + 1):
        dp[0][j] = seg(0, j, 0)
        cut[0][j] = 0
    for s in range(1, S):
        lo_j = (s + 1) * min_layers
        for j in range(lo_j, n + 1):
            best, arg = INF, -1
            # stage s takes layers [i, j): i ranges so every earlier stage
            # keeps >= min_layers and this one too
            for i in range(s * min_layers, j - min_layers + 1):
                prev = dp[s - 1][i]
                # unreachable prefix (min_layers infeasibility). Value check,
                # not `prev is INF`: float identity silently misses equal
                # infinities produced by arithmetic. An inf *cost* with a
                # valid cut is reachable — extreme speed skew can overflow
                # seg() yet the partition itself is still legal.
                if math.isinf(prev) and cut[s - 1][i] < 0:
                    continue
                v = max(prev, seg(i, j, s))
                if arg < 0 or v < best:
                    best, arg = v, i
            dp[s][j], cut[s][j] = best, arg

    # backtrack
    bounds, j = [], n
    for s in range(S - 1, -1, -1):
        i = cut[s][j]
        assert i >= 0, "infeasible partition"
        bounds.append((i, j))
        j = i
    bounds.reverse()
    return [tuple(range(i, j)) for i, j in bounds]


def partition_bottleneck(layer_costs, partition, stage_speeds) -> float:
    """max stage time of a given partition (the pipeline's steady-state rate)."""
    return max(
        sum(layer_costs[i] for i in layers) / max(speed, 1e-9)
        for layers, speed in zip(partition, stage_speeds)
    )


def uniform_costs(n_layers: int, *, embed_extra: float = 0.0, head_extra: float = 0.0):
    """Cost vector for a homogeneous stack; first/last layers optionally carry
    the embedding/LM-head cost."""
    costs = [1.0] * n_layers
    costs[0] += embed_extra
    costs[-1] += head_extra
    return costs


def costs_for_arch(cfg, seq_len: int = 4096) -> list:
    """Per-layer relative FLOPs for an ArchConfig (hybrid-aware)."""
    costs = []
    for spec in cfg.layer_specs():
        d = cfg.d_model
        if spec.mixer == "attn":
            mix = 2 * d * (cfg.q_dim + 2 * cfg.kv_dim) + 2 * cfg.q_dim * d
            span = min(seq_len, cfg.window) if spec.attn_kind == "swa" else seq_len
            mix += 2 * 2 * cfg.n_heads * cfg.head_dim * span  # qk^T + pv per token
        elif spec.mixer == "mamba":
            di = cfg.mamba_d_inner
            mix = 2 * d * 2 * di + 2 * di * d + 6 * di * cfg.mamba_d_state
        else:  # mlstm / slstm
            mix = 8 * d * d
        if spec.ffn == "dense":
            ffn = 6 * d * cfg.d_ff
        elif spec.ffn == "moe":
            ffn = 6 * d * cfg.moe_d_ff * cfg.moe_top_k + 2 * d * cfg.n_experts
        else:
            ffn = 0
        costs.append(float(mix + ffn))
    m = max(costs)
    return [c / m for c in costs]
