"""The ResiHP Scheduler (paper §6): progressive TP -> PP -> DP adaptation.

Given a failure report (from the Detector), the current ParallelPlan, and
per-device normalized throughputs, produce an AdaptationPlan:

  1. TP (§6.1): selective exclusion inside each affected TP group (Eq. 3/4);
     survivors that don't fit the power-of-two subgroup become node-local
     standbys; a group with no feasible subgroup leaves a *dead stage*.
  2. PP (§6.2): uniform layer repartition against per-stage effective speeds.
     Uniform across DP replicas (gradient all-reduce stays layer-aligned), so
     the per-stage speed used is the min across replicas — the global DP
     sync is gated by the slowest replica at that stage.
  3. DP (§6.3): stage-granular progress-aware migration parameters (delta,
     memory capacity) for the online migrator; dead stages are marked for
     fail-stop eviction.

The Scheduler is pure planning — no jax. The engine/cluster-sim executes
plans; `plan_overhead_s` is measured for the Fig. 13 overhead benchmark.
"""
from __future__ import annotations

import dataclasses
import math
import time
from dataclasses import dataclass, field
from typing import Optional

from repro.core.scheduler.plan import ParallelPlan, ReplicaPlan, StagePlan
from repro.core.scheduler.repartition import repartition_layers
from repro.core.scheduler.tp_reconfig import (NTPConfig, TPReconfig,
                                              reconfigure_tp_group)


@dataclass
class AdaptationPlan:
    plan: ParallelPlan
    stage_speeds: dict  # (replica, stage) -> effective speed (healthy tp = 1.0)
    dead_stages: tuple  # ((replica, stage), ...)
    restore_required: bool  # all replicas of some stage are dead (Fig. 8b)
    plan_overhead_s: float
    notes: list = field(default_factory=list)


@dataclass(frozen=True)
class PlanOverheadModel:
    """Modeled planning-cost curve: a power law ``t = exp(intercept) * x**coef``
    in the problem size ``x = n_devices * n_layers``, fit (log-log least
    squares) to the measured ``Scheduler.adapt`` wall times of the Fig. 13
    overhead benchmark.

    Replaces the *measured* wall-clock planning charge
    (``AdaptationPlan.plan_overhead_s``, honest but nondeterministic and
    machine-dependent) with a deterministic prediction at the same scale —
    closing the ROADMAP item without falling back to a blunt constant the
    way ``plan_overhead_fixed`` does. ``bench_fig13_overhead`` refits the
    curve against fresh measurements every run and reports the fit error, so
    drift between the checked-in default and reality is visible nightly.
    """

    coef: float = 1.4165360  # power-law exponent over n_devices * n_layers
    intercept: float = -17.3245871  # log-seconds at x = 1
    fit_mape: float = 0.0227  # of the default fit (results/fig13_overhead.json)

    def predict(self, n_devices: int, n_layers: int) -> float:
        return self.predict_x(float(n_devices) * float(n_layers))

    @classmethod
    def fit(cls, samples) -> "PlanOverheadModel":
        """``samples``: iterable of (n_devices, n_layers, measured_seconds).
        Closed-form least squares on (log x, log t)."""
        pts = [(math.log(max(float(d) * float(layers), 1.0)), math.log(t))
               for d, layers, t in samples if t > 0]
        if len(pts) < 2:
            raise ValueError("PlanOverheadModel.fit needs >= 2 samples")
        n = len(pts)
        mx = sum(x for x, _ in pts) / n
        my = sum(y for _, y in pts) / n
        sxx = sum((x - mx) ** 2 for x, _ in pts)
        sxy = sum((x - mx) * (y - my) for x, y in pts)
        coef = sxy / max(sxx, 1e-18)
        intercept = my - coef * mx
        model = cls(coef=coef, intercept=intercept)
        mape = sum(abs(model.predict_x(math.exp(x)) - math.exp(y))
                   / math.exp(y) for x, y in pts) / n
        return dataclasses.replace(model, fit_mape=mape)

    def predict_x(self, x: float) -> float:
        return math.exp(self.intercept) * max(x, 1.0) ** self.coef


def k_min_for(param_bytes_per_layer: float, n_layers_stage: int,
              hbm_bytes: float, *, state_multiplier: float = 4.0,
              activation_bytes: float = 2e9) -> int:
    """Memory floor for the TP degree of one stage: params+optimizer shards
    plus activation working set must fit per device."""
    need = param_bytes_per_layer * n_layers_stage * state_multiplier
    avail = max(hbm_bytes - activation_bytes, 1.0)
    k = 1
    while need / k > avail:
        k *= 2
    return k


@dataclass
class Scheduler:
    layer_costs: list  # per-layer healthy cost (repartition input)
    k_min: int = 1
    delta: int = 0
    mem_capacity: Optional[int] = None
    min_layers: int = 1
    repartition_rel_threshold: float = 0.05  # skip repartition for tiny gains
    # ablation switches (Fig. 11): progressive adaptation components
    enable_selective: bool = True  # §6.1 selective exclusion (else whole-group)
    enable_repartition: bool = True  # §6.2 layer repartition
    # False => skip the wall-clock measurement entirely (plan_overhead_s is
    # reported as 0.0). Set by ResiHPPolicy when a plan_overhead_model /
    # plan_overhead_fixed makes the measurement dead weight — the modeled hot
    # loop stays syscall-free and plan-cache hits are truly free.
    measure_overhead: bool = True
    # plan cache: ``adapt`` is a pure function of (plan, speeds, failed,
    # quarantined, risk, ntp mode), so repeated reconfigurations under flapping /
    # poisson storms that revisit a failure signature skip the O(S·n²)
    # repartition DP + TP search. 0 disables. Cached AdaptationPlans are
    # shared — treat them as read-only (every in-repo consumer does).
    plan_cache_size: int = 256
    # healthy-baseline TP degree used to normalize per-stage effective
    # speeds. None => derived from the incoming plan's widest group — correct
    # only while that plan still contains a healthy-width group, which is why
    # ResiHPPolicy pins it from plan0 (adapting an already-shrunk plan must
    # not inflate the surviving stages' speeds).
    baseline_tp: Optional[int] = None
    # physical topology view for the §6.1 node-local-standby contract: a
    # callable (device -> node) or an indexable per-device node array
    # (ClusterState.node_of). None => plan-only callers keep the whole-pool
    # legacy behaviour (no topology to filter by).
    node_of: Optional[object] = None
    # correlated-failure-domain view (device -> domain index; a callable or
    # an indexable array, wired from ClusterTopology.pdu_of & co. by the
    # domain-aware policy switch): among the node-local standbys a group may
    # pull in, offers are stably ordered toward domains with *fewer* failed
    # devices, so backfill straddles domains instead of refilling from the
    # rack that is busy dying. None (the default) keeps the legacy offer
    # order — byte-identical planning.
    domain_of: Optional[object] = None
    # nonuniform-TP adaptation axis (NTPConfig; ``True`` for defaults;
    # default OFF = exclusion-only Eq. 3/4, byte-identical legacy planning)
    ntp: Optional[object] = None
    # credit-gated NTP (credit switch only): devices whose credit sits
    # strictly below this band are vetoed from shrink-shard retention —
    # nonuniform widths are for trustworthy stragglers, a low-credit slow
    # device competes as an exclusion instead. 0.0 (the default) disables
    # the veto, so callers without a credit view are untouched.
    ntp_min_credit: float = 0.0
    # counter sink for the credit path (a CreditStats-shaped object): the
    # planner is the only layer that knows when the NTP veto actually bites,
    # so it bumps ``ntp_vetoes`` here on every uncached plan that vetoed
    # someone. None (the default) counts nothing.
    credit_stats: Optional[object] = field(default=None, repr=False,
                                           compare=False)
    _cache: dict = field(default_factory=dict, init=False, repr=False,
                         compare=False)

    def __post_init__(self):
        if self.ntp is True:
            self.ntp = NTPConfig()

    def _signature(self, speeds: dict, failed, quarantined, device_risk,
                   device_credit=None):
        """Frozen (failed, quarantined, risk-bucketed speeds) cache key.
        Healthy (1.0) speeds are elided so the signature scales with the
        failure count, not the fleet; risk/credit scores are bucketed at
        1e-6 — fine enough that a tie-break could only flip between devices
        whose estimated hazards are practically indistinguishable. The NTP
        config is part of the key: the same failure set yields a different
        plan under shrink-shard than under exclusion, and a cached exclusion
        plan must not alias an NTP request (or vice versa)."""
        sig_speeds = tuple(sorted(
            (d, v) for d, v in speeds.items() if v != 1.0))
        sig_risk = (tuple(sorted((d, round(r, 6))
                                 for d, r in device_risk.items()))
                    if device_risk else None)
        sig_credit = (tuple(sorted((d, round(c, 6))
                                   for d, c in device_credit.items()))
                      if device_credit else None)
        return (sig_speeds, frozenset(failed), frozenset(quarantined),
                sig_risk, sig_credit, self.ntp)

    # ------------------------------------------------------------ adaptation
    def adapt(self, plan: ParallelPlan, speeds: dict, *,
              failed=frozenset(), quarantined=frozenset(),
              device_risk=None, device_credit=None) -> AdaptationPlan:
        """speeds: {device_id: p_i}; failed: fail-stop device ids (speed 0);
        quarantined: lifecycle-quarantined devices — excluded from plans (and
        the standby pool) exactly like failed ones, even if a rejoin has made
        them physically alive, so the Scheduler stops replanning around
        flappers until their quarantine expires.
        device_risk: optional {device_id: hazard score} from the lifecycle
        hazard estimator — equal-throughput placement choices (TP membership,
        standby pull-in) prefer low-hazard devices; None (the default) keeps
        selection byte-identical to the hazard-blind planner.
        device_credit: optional {device_id: credit in [0, 1]} from the
        unified credit model — supersedes ``device_risk`` (low credit maps
        to high risk for the same tie-breaks) and, with ``ntp_min_credit``
        set, vetoes low-credit devices from shrink-shard retention."""
        key = entry = None
        if self.plan_cache_size > 0:
            key = self._signature(speeds, failed, quarantined, device_risk,
                                  device_credit)
            entry = self._cache.get(key)
            # the entry pins its plan object, so an `is` match cannot be an
            # id-reuse collision; a different plan under the same signature
            # (rare: only multi-plan callers) simply recomputes
            if entry is not None and entry[0] is plan:
                return entry[1]
        ad = self._adapt_uncached(plan, speeds, failed=failed,
                                  quarantined=quarantined,
                                  device_risk=device_risk,
                                  device_credit=device_credit)
        if key is not None:
            if len(self._cache) >= self.plan_cache_size:
                self._cache.clear()
            self._cache[key] = (plan, ad)
        return ad

    def _adapt_uncached(self, plan: ParallelPlan, speeds: dict, *,
                        failed=frozenset(), quarantined=frozenset(),
                        device_risk=None, device_credit=None) -> AdaptationPlan:
        t0 = time.perf_counter() if self.measure_overhead else 0.0
        ntp_veto = frozenset()
        if device_credit:
            # credit supersedes the raw hazard view: the same placement
            # tie-breaks run on ``2 - credit`` (injective, order-reversing
            # in credit), so low-credit devices rank exactly like
            # high-hazard ones without a second ranking path
            device_risk = {d: 2.0 - c for d, c in device_credit.items()}
            if self.ntp is not None and self.ntp_min_credit > 0.0:
                ntp_veto = frozenset(d for d, c in device_credit.items()
                                     if c < self.ntp_min_credit)
                if ntp_veto and self.credit_stats is not None:
                    self.credit_stats.ntp_vetoes += len(ntp_veto)
        failed = (set(failed) | {d for d, v in speeds.items() if v <= 0.0}
                  | set(quarantined))
        # per-domain failed-device counts for domain-spread standby offers
        # (None when no domain view is wired: legacy offer order)
        dom_fail = None
        if self.domain_of is not None and failed:
            dom_fail = {}
            for d in failed:
                dom = self._domain(d)
                dom_fail[dom] = dom_fail.get(dom, 0) + 1
        notes = []
        if quarantined:
            notes.append(f"quarantined (excluded): {sorted(quarantined)}")
        if device_credit:
            worst = min(device_credit.items(), key=lambda kv: (kv[1], kv[0]))
            notes.append(f"credit-aware placement over {len(device_credit)} "
                         f"scored devices (worst d{worst[0]}: {worst[1]:.2f})")
        elif device_risk:
            worst = max(device_risk.items(), key=lambda kv: (kv[1], kv[0]))
            notes.append(f"risk-aware placement over {len(device_risk)} "
                         f"scored devices (worst d{worst[0]}: {worst[1]:.2f}x)")

        # ---- 1. TP: reconfigure every affected group --------------------
        new_replicas = []
        group_speed: dict = {}
        dead: list = []
        standby_pool = [d for d in plan.standby if d not in failed]
        for r, rep in enumerate(plan.replicas):
            stages = []
            for s, st in enumerate(rep.stages):
                # a stage already running nonuniform widths is always
                # re-planned: if its straggler recovered, the widths should
                # revert to uniform (exclusion wins ties at full health)
                affected = st.shard_fractions is not None or any(
                    d in failed or speeds.get(d, 1.0) < 1.0 for d in st.devices)
                if not affected:
                    stages.append(st)
                    group_speed[(r, s)] = 1.0 * st.tp
                    continue
                if not self.enable_selective and any(d in failed for d in st.devices):
                    # ablation: conservative whole-group exclusion (§3.2)
                    dead.append((r, s))
                    stages.append(StagePlan((), st.layers))
                    group_speed[(r, s)] = 0.0
                    notes.append(f"stage (dp{r},pp{s}) dead: whole-group exclusion")
                    continue
                # pull node-local standbys into the candidate pool (§6.1 —
                # only standbys co-located with the group's node(s) qualify)
                offered = self._local_standbys(st.devices, standby_pool,
                                               dom_fail)
                pool = list(st.devices) + offered
                rec: TPReconfig = reconfigure_tp_group(
                    pool, speeds, k_min=self.k_min, failed=failed,
                    risk=device_risk, ntp=self.ntp, ntp_veto=ntp_veto)
                if rec.tp == 0:
                    dead.append((r, s))
                    stages.append(StagePlan((), st.layers))
                    group_speed[(r, s)] = 0.0
                    notes.append(f"stage (dp{r},pp{s}) dead: no feasible TP subgroup")
                    continue
                # consumed standbys leave the pool; freed devices join it;
                # standbys never offered (other nodes) keep their place
                standby_pool = (
                    [d for d in standby_pool if d not in pool]
                    + [d for d in rec.standby if d not in st.devices]
                    + [d for d in rec.standby if d in st.devices]
                )
                standby_pool = list(dict.fromkeys(standby_pool))
                stages.append(StagePlan(rec.devices, st.layers,
                                        rec.shard_fractions))
                group_speed[(r, s)] = rec.effective_throughput
                if rec.mode == "shrink":
                    widths = "/".join(f"{f:.2f}" for f in rec.shard_fractions)
                    notes.append(
                        f"stage (dp{r},pp{s}) NTP shrink-shard tp={rec.tp} "
                        f"widths=[{widths}] thru={rec.effective_throughput:.2f}"
                    )
                elif rec.tp != st.tp:
                    notes.append(
                        f"stage (dp{r},pp{s}) TP {st.tp}->{rec.tp} "
                        f"thru={rec.effective_throughput:.2f}"
                    )
            new_replicas.append(ReplicaPlan(tuple(stages)))

        # ---- 2. PP: uniform layer repartition ---------------------------
        pp = plan.replicas[0].pp
        # normalize against the *healthy* baseline TP, not the incoming
        # plan's current widths: when adapting an already-shrunk plan the
        # incoming max degree understates healthy capacity and would inflate
        # every surviving stage's effective speed. The fallback scans all
        # replicas for the widest (least-degraded) group.
        tp0 = self.baseline_tp or max(
            st.tp for rep in plan.replicas for st in rep.stages) or 1
        # per-stage effective speed normalized to the healthy group = min
        # across live replicas (the DP sync is gated by the slowest replica)
        stage_speed = []
        for s in range(pp):
            vals = [
                group_speed[(r, s)] / tp0
                for r in range(plan.dp)
                if (r, s) not in dead
            ]
            stage_speed.append(min(vals) if vals else 0.0)

        restore_required = any(v == 0.0 for v in stage_speed)
        if not restore_required and self.enable_repartition:
            old_layers = [st.layers for st in new_replicas[0].stages]
            new_parts = repartition_layers(
                self.layer_costs, stage_speed, min_layers=self.min_layers)
            if self._worth_it(old_layers, new_parts, stage_speed, notes):
                new_replicas = [
                    ReplicaPlan(tuple(
                        StagePlan(st.devices, new_parts[s], st.shard_fractions)
                        for s, st in enumerate(rep.stages)
                    ))
                    for rep in new_replicas
                ]

        new_plan = plan.replace(
            replicas=tuple(new_replicas),
            standby=tuple(sorted(standby_pool)),
            dead_stages=tuple(dead),
        )
        # effective per-(replica,stage) speed for the migrator / simulator
        eff = {
            (r, s): group_speed[(r, s)] / tp0
            for r in range(plan.dp)
            for s in range(pp)
        }
        return AdaptationPlan(
            plan=new_plan,
            stage_speeds=eff,
            dead_stages=tuple(dead),
            restore_required=restore_required,
            plan_overhead_s=(time.perf_counter() - t0
                             if self.measure_overhead else 0.0),
            notes=notes,
        )

    def _node(self, device) -> int:
        nf = self.node_of
        return int(nf(device)) if callable(nf) else int(nf[device])

    def _domain(self, device) -> int:
        df = self.domain_of
        return int(df(device)) if callable(df) else int(df[device])

    def _local_standbys(self, group, standby_pool, dom_fail=None) -> list:
        """§6.1 node-local standby contract: a group may only pull in
        standbys co-located with its node(s). Without a topology view
        (node_of=None, plan-only callers) the whole pool qualifies.
        ``dom_fail`` (per-domain failed counts, domain-aware switch only)
        stably reorders the qualifying offers toward less-failed domains —
        ties, and the no-domain-view path, keep the legacy pool order."""
        if self.node_of is None or not standby_pool:
            offers = list(standby_pool)
        else:
            nodes = {self._node(d) for d in group}
            offers = [d for d in standby_pool if self._node(d) in nodes]
        if dom_fail:
            offers.sort(key=lambda d: dom_fail.get(self._domain(d), 0))
        return offers

    def _worth_it(self, old_parts, new_parts, stage_speed, notes) -> bool:
        from repro.core.scheduler.repartition import partition_bottleneck

        old_b = partition_bottleneck(self.layer_costs, old_parts, stage_speed)
        new_b = partition_bottleneck(self.layer_costs, new_parts, stage_speed)
        if new_b <= old_b * (1.0 - self.repartition_rel_threshold):
            notes.append(f"repartition: bottleneck {old_b:.3f} -> {new_b:.3f}")
            return True
        notes.append(
            f"repartition skipped (gain {1 - new_b / max(old_b, 1e-12):.1%} "
            f"< {self.repartition_rel_threshold:.0%})"
        )
        return False

    # ---------------------------------------------------------- migration
    def migrator_kwargs(self, adaptation: AdaptationPlan, *, n_mb, chunk_base_cost,
                        schedule="1f1b", p2p_cost=0.0, migrate_edge_cost=0.0):
        """Bundle Algorithm-1 parameters for ProgressAwareMigrator. The chunk
        cost divides the healthy cost by the executor's effective speed."""
        speeds = adaptation.stage_speeds
        layer_share = {}
        total = sum(self.layer_costs)
        for s, layers in enumerate(adaptation.plan.replicas[0].stages):
            layer_share[s] = sum(self.layer_costs[i] for i in layers.layers) / total

        def chunk_cost(cid, executor):
            base = chunk_base_cost(cid) * layer_share[cid.stage] * len(self.layer_costs)
            v = speeds.get(executor, 1.0)
            return base / max(v, 1e-9)

        plan = adaptation.plan
        return dict(
            n_stages=plan.replicas[0].pp,
            n_replicas=plan.dp,
            n_microbatches=plan.microbatches,
            chunk_cost=chunk_cost,
            schedule=schedule,
            dead_executors=adaptation.dead_stages,
            policy="resihp",
            delta=self.delta,
            mem_capacity=self.mem_capacity,
            p2p_cost=p2p_cost,
            migrate_edge_cost=migrate_edge_cost,
        )
