"""Progress-aware DP workload migration (paper §6.3, Algorithm 1).

A discrete-event simulator over all DP replicas' pipelines jointly, with an
online migration policy in the loop. Executors are (replica, stage) TP
groups; chunks are F/B/W per micro-batch per stage (the same ChunkId the
Detector's DAG simulator uses). At every completion event the policy runs
Algorithm 1:

  for each stage i:
      P[d][i] = #forward chunks completed by stage i of replica d
      d_min = argmin_d P, d_max = argmax_d P
      if (d_min, i) is fail-stop or P[d_max] - P[d_min] > delta:
          j = NextPending(d_min, i)
          if memory_feasible(j, i, d_max): migrate stage-i of j -> d_max

Migrated chunks keep their data dependencies (with a cross-replica P2P
penalty for the activation/gradient exchange, paper constraint (2)) and run
in the destination executor's *bubbles*: the destination prefers its own
schedule order and picks up migrated work when its next own chunk is not
ready. Memory constraint (3): live activations (F done, B not yet) plus
in-flight migrated forwards must stay under the stage's capacity.

The same engine with different `policy` values implements the baselines:
  'resihp'  — Algorithm 1 (fail-stop eviction + fail-slow balancing);
  'recycle' — ReCycle-style: fail-stop eviction only, round-robin over DP
              peers with no progress awareness (Fig. 6a);
  'none'    — no migration; a dead stage aborts the iteration.
"""
from __future__ import annotations

import heapq
import math
from dataclasses import dataclass, field
from typing import Callable, Optional

from repro.core.detector.dag_sim import ChunkId
from repro.engine.schedules import make_schedule

#: Same-timestamp batching window (seconds): events within this epsilon of
#: the batch head are drained and processed as one step before the policy
#: decides — symmetric replicas complete simultaneously, and deciding
#: mid-batch would see phantom progress gaps. Both engines MUST share this
#: constant (the fast engine imports it): a fast engine batching at a
#: different epsilon would split or merge batches differently at timestamp
#: collisions and silently break bit-for-bit parity.
SAME_TIME_EPS = 1e-12


def _budget_error(now: float, heap_size: int, undone: int, total: int,
                  limit: int) -> RuntimeError:
    """Actionable livelock-guard report, shared by both engines: the bare
    'event budget exceeded' left nothing to debug with."""
    return RuntimeError(
        f"migration sim: event budget exceeded (livelock?): "
        f"t={now:.6g}, heap_size={heap_size}, "
        f"undone_chunks={undone}/{total}, budget={limit}")


@dataclass
class MigrationEvent:
    time: float
    chunk: ChunkId
    src: tuple  # (replica, stage)
    dst: tuple
    reason: str  # 'fail-stop' | 'fail-slow'


@dataclass
class SimResult:
    makespan: float
    status: str  # 'ok' | 'aborted'
    finish: dict
    migrations: list
    idle: dict
    per_replica_finish: dict
    detail: str = ""


class ProgressAwareMigrator:
    """One training iteration across DP replicas with online migration."""

    def __init__(
        self,
        *,
        n_stages: int,
        n_replicas: int,
        n_microbatches,  # int or per-replica list
        chunk_cost: Callable,  # (ChunkId, executor) -> seconds (speed-scaled)
        schedule: str = "1f1b",
        dead_executors=(),  # iterable of (replica, stage) that are fail-stop
        policy: str = "resihp",
        delta: int = 0,  # progress-gap threshold (Alg. 1)
        mem_capacity: Optional[int] = None,  # live activations per stage
        p2p_cost: float = 0.0,  # same-replica inter-stage edge seconds
        migrate_edge_cost: float = 0.0,  # extra cross-replica edge seconds
        max_migrations_per_event: int = 4,
        event_budget: Optional[int] = None,  # livelock guard (default 50x chunks)
    ):
        self.n_stages = n_stages
        self.n_replicas = n_replicas
        if isinstance(n_microbatches, int):
            n_microbatches = [n_microbatches] * n_replicas
        self.n_mb = list(n_microbatches)
        self.chunk_cost = chunk_cost
        self.policy = policy
        self.delta = delta
        self.mem_capacity = mem_capacity if mem_capacity is not None else n_stages + 2
        self.p2p_cost = p2p_cost
        self.migrate_edge_cost = migrate_edge_cost
        self.dead = set(dead_executors)
        self.max_migrations_per_event = max_migrations_per_event
        self.event_budget = event_budget

        # build per-replica schedules
        self.own_order: dict = {}
        self.chunks: set = set()
        self.with_w = schedule.lower().startswith("zb")
        for d in range(self.n_replicas):
            sched = make_schedule(schedule, n_stages, self.n_mb[d], replica=d)
            for (rep, st), order in sched.items():
                self.own_order[(rep, st)] = list(order)
                self.chunks.update(order)

        # dynamic state
        self.placement: dict = {}  # ChunkId -> executor (only if migrated)
        self.finish: dict = {}
        self.started: set = set()
        self.done: set = set()
        self.live: dict = {e: 0 for e in self.own_order}  # F done - B done
        self.inflight_migrated_f: dict = {e: 0 for e in self.own_order}
        self.migq: dict = {e: [] for e in self.own_order}
        self.cursor: dict = {e: 0 for e in self.own_order}
        self.busy_until: dict = {e: 0.0 for e in self.own_order}
        self.running: dict = {e: None for e in self.own_order}
        self.migrations: list = []
        self.migrated_away: set = set()
        self._rr = 0  # round-robin pointer for the recycle policy

    # ------------------------------------------------------------- helpers
    def _deps(self, cid: ChunkId):
        deps = []
        if cid.kind == "F":
            if cid.stage > 0:
                deps.append(ChunkId("F", cid.mb, cid.stage - 1, cid.replica))
        elif cid.kind == "B":
            deps.append(ChunkId("F", cid.mb, cid.stage, cid.replica))
            if cid.stage < self.n_stages - 1:
                deps.append(ChunkId("B", cid.mb, cid.stage + 1, cid.replica))
        else:  # W
            deps.append(ChunkId("B", cid.mb, cid.stage, cid.replica))
        return [d for d in deps if d in self.chunks]

    def _executor_of(self, cid: ChunkId):
        return self.placement.get(cid, (cid.replica, cid.stage))

    def _edge_cost(self, dep: ChunkId, cid: ChunkId) -> float:
        e_dep, e_cid = self._executor_of(dep), self._executor_of(cid)
        if e_dep == e_cid:
            return 0.0
        c = self.p2p_cost if dep.stage != cid.stage else 0.0
        if e_dep[0] != e_cid[0]:  # crosses replicas (migration exchange)
            c += self.migrate_edge_cost
        return c

    def _ready_time(self, cid: ChunkId) -> Optional[float]:
        t = 0.0
        for dep in self._deps(cid):
            if dep not in self.finish:
                return None
            t = max(t, self.finish[dep] + self._edge_cost(dep, cid))
        return t

    def _progress(self):
        """P[d][i] = completed F chunks by stage i of replica d (home) plus
        in-flight migrated-away forwards: Alg. 1 'Update P' credits a
        migration to the straggler immediately so the same gap is not
        re-triggered while the chunk is still queued at the destination."""
        P = [[0] * self.n_stages for _ in range(self.n_replicas)]
        for cid in self.done:
            if cid.kind == "F":
                P[cid.replica][cid.stage] += 1
        for cid in self.migrated_away:
            if cid.kind == "F" and cid not in self.done:
                P[cid.replica][cid.stage] += 1
        return P

    def _next_pending(self, d: int, i: int) -> Optional[ChunkId]:
        for cid in self.own_order[(d, i)]:
            if cid.kind != "F":
                continue
            if cid in self.started or cid in self.migrated_away:
                continue
            return cid
        return None

    def _mem_feasible(self, dst) -> bool:
        return (self.live[dst] + self.inflight_migrated_f[dst]) < self.mem_capacity

    def _migrate(self, cid: ChunkId, dst, now: float, reason: str):
        """Move the F chunk and its same-stage B/W companions to `dst`."""
        group = [cid]
        b = ChunkId("B", cid.mb, cid.stage, cid.replica)
        w = ChunkId("W", cid.mb, cid.stage, cid.replica)
        if b in self.chunks:
            group.append(b)
        if w in self.chunks:
            group.append(w)
        src = (cid.replica, cid.stage)
        for g in group:
            if g in self.started:
                return  # too late
        for g in group:
            self.placement[g] = dst
            self.migrated_away.add(g)
            self.migq[dst].append(g)
        self.inflight_migrated_f[dst] += 1
        self.migrations.append(MigrationEvent(now, cid, src, dst, reason))

    # ------------------------------------------------------------- policy
    def _decide(self, now: float):
        if self.policy == "none":
            return
        P = self._progress()
        n_done = 0
        for i in range(self.n_stages):
            if n_done >= self.max_migrations_per_event:
                break
            alive = [d for d in range(self.n_replicas) if (d, i) not in self.dead]
            if not alive:
                continue
            vals = {d: P[d][i] for d in range(self.n_replicas)}
            d_min = min(vals, key=lambda d: (vals[d], d))
            d_max = max(alive, key=lambda d: (vals[d], -d))
            if self.policy == "recycle":
                # fail-stop eviction only, no progress awareness: round-robin
                for d in range(self.n_replicas):
                    if (d, i) in self.dead:
                        j = self._next_pending(d, i)
                        if j is not None and alive:
                            dst = (alive[self._rr % len(alive)], i)
                            self._rr += 1
                            self._migrate(j, dst, now, "fail-stop")
                            n_done += 1
                continue
            # --- resihp (Algorithm 1) ---
            src_dead = (d_min, i) in self.dead
            gap = vals[d_max] - vals[d_min]
            if not src_dead and gap <= self.delta:
                continue
            if d_max == d_min:
                continue
            j = self._next_pending(d_min, i)
            if j is None:
                continue
            dst = (d_max, i)
            if dst in self.dead or not self._mem_feasible(dst):
                continue
            self._migrate(j, dst, now, "fail-stop" if src_dead else "fail-slow")
            n_done += 1

    # --------------------------------------------------------------- sim
    def _dispatch(self, e, now: float, heap, seq):
        if self.running[e] is not None or e in self.dead:
            return seq
        # own schedule order: head = next not-migrated-away chunk
        own = None
        order = self.own_order[e]
        while self.cursor[e] < len(order):
            c = order[self.cursor[e]]
            if c in self.migrated_away or c in self.done:
                self.cursor[e] += 1
                continue
            own = c
            break
        own_ready = self._ready_time(own) if own is not None else None
        # migrated bubble-fill work: first ready chunk whose deps are done
        mig, mig_ready = None, None
        for c in self.migq[e]:
            if c in self.done or c in self.started:
                continue
            r = self._ready_time(c)
            if r is not None and (mig_ready is None or r < mig_ready):
                # W chunks have no urgency; prefer F/B first
                mig, mig_ready = c, r
                if c.kind != "W":
                    break
        cand, ready = None, None
        own_now = own_ready is not None and own_ready <= now
        mig_now = mig_ready is not None and mig_ready <= now
        if own_now and mig_now:
            # both ready: run the older micro-batch first (migrated chunks
            # come from a straggler, so they are behind — Fig. 6b interleaves
            # them into the destination's schedule, not only its bubbles)
            if (mig.mb, 0 if mig.kind == "B" else 1) < (own.mb, 0 if own.kind == "B" else 1):
                cand, ready = mig, mig_ready
            else:
                cand, ready = own, own_ready
        elif own_now:
            cand, ready = own, own_ready
        elif mig_now:
            cand, ready = mig, mig_ready
        elif own_ready is not None or mig_ready is not None:
            # nothing ready *now*: schedule a wake-up at the earliest ready time
            t = min(x for x in (own_ready, mig_ready) if x is not None)
            heapq.heappush(heap, (t, seq, ("wake", e)))
            return seq + 1
        if cand is None:
            return seq
        self.started.add(cand)
        self.running[e] = cand
        dur = self.chunk_cost(cand, e)
        t_end = max(now, ready) + dur
        self.busy_until[e] = t_end
        heapq.heappush(heap, (t_end, seq, ("done", e, cand)))
        return seq + 1

    def run(self) -> SimResult:
        # quick abort check for 'none' policy with dead executors holding work
        if self.policy == "none":
            for e in self.dead:
                if self.own_order.get(e):
                    return SimResult(math.inf, "aborted", {}, [], {}, {},
                                     detail=f"stage {e} is fail-stop and no migration policy")
        heap: list = []
        seq = 0
        self._decide(0.0)
        for e in self.own_order:
            seq = self._dispatch(e, 0.0, heap, seq)
        guard = 0
        limit = (self.event_budget if self.event_budget is not None
                 else 50 * max(1, len(self.chunks)))
        while heap:
            guard += 1
            if guard > limit:
                raise _budget_error(heap[0][0], len(heap),
                                    len(self.chunks) - len(self.done),
                                    len(self.chunks), limit)
            now, _, ev = heapq.heappop(heap)
            # drain all events at (effectively) the same timestamp before
            # deciding: symmetric replicas complete simultaneously, and
            # deciding mid-batch would see phantom progress gaps.
            batch = [ev]
            while heap and heap[0][0] <= now + SAME_TIME_EPS:
                batch.append(heapq.heappop(heap)[2])
            any_done = False
            for ev in batch:
                if ev[0] == "done":
                    _, e, cid = ev
                    self.running[e] = None
                    self.done.add(cid)
                    self.finish[cid] = now
                    if cid.kind == "F":
                        self.live[e] += 1
                        if self.placement.get(cid) is not None:
                            self.inflight_migrated_f[e] -= 1
                    elif cid.kind == "B":
                        self.live[e] -= 1
                    any_done = True
            if any_done:
                self._decide(now)
            for e2 in self.own_order:
                seq = self._dispatch(e2, now, heap, seq)

        if len(self.done) != len(self.chunks):
            missing = [c for c in self.chunks if c not in self.done]
            # dead executors with unmigrated chunks => aborted iteration
            return SimResult(math.inf, "aborted", self.finish, self.migrations,
                             {}, {}, detail=f"{len(missing)} chunks unexecuted, e.g. {missing[:4]}")
        total = max(self.finish.values()) if self.finish else 0.0
        busy = {e: 0.0 for e in self.own_order}
        for cid in self.done:
            e = self._executor_of(cid)
            busy[e] += self.chunk_cost(cid, e)
        idle = {e: total - b for e, b in busy.items()}
        per_replica = {
            d: max(
                (self.finish[c] for c in self.done if c.replica == d),
                default=0.0,
            )
            for d in range(self.n_replicas)
        }
        return SimResult(total, "ok", self.finish, self.migrations, idle, per_replica)


def simulate_iteration(**kw) -> SimResult:
    return ProgressAwareMigrator(**kw).run()
