"""xlstm-1.3b [ssm] — sLSTM + mLSTM blocks (7:1 mLSTM:sLSTM).

48L d_model=2048 4H (GQA kv=4) d_ff=0 vocab=50304 [arXiv:2405.04517; unverified].
d_ff=0: xLSTM blocks carry their own up/down projections (no separate FFN).
"""
from repro.configs.base import ArchConfig, LayerSpec, register

_m = LayerSpec("mlstm", ffn="none")
_s = LayerSpec("slstm", ffn="none")

CONFIG = register(
    ArchConfig(
        arch_id="xlstm-1.3b",
        family="ssm",
        n_layers=48,
        d_model=2048,
        n_heads=4,
        n_kv_heads=4,
        head_dim=512,
        d_ff=0,
        vocab_size=50304,
        period=(_m, _m, _m, _m, _m, _m, _m, _s),
        shape_skips={},  # linear-time recurrent arch => long_500k runs
    )
)
