"""gemma3-4b [dense] — 5:1 local:global interleave, 128k context.

34L d_model=2560 8H (GQA kv=4) d_ff=10240 vocab=262144 [hf:google/gemma-3-1b-pt
family; unverified]. 34 layers => period of 17 with 3 global layers
(28 local : 6 global ≈ 4.7:1; closest realizable; documented in DESIGN.md).
"""
from repro.configs.base import ArchConfig, LayerSpec, register

_l = LayerSpec("attn", attn_kind="swa", ffn="dense")
_g = LayerSpec("attn", attn_kind="full", ffn="dense")

CONFIG = register(
    ArchConfig(
        arch_id="gemma3-4b",
        family="dense",
        n_layers=34,
        d_model=2560,
        n_heads=8,
        n_kv_heads=4,
        head_dim=256,
        d_ff=10240,
        vocab_size=262144,
        period=(_l, _l, _l, _l, _l, _g, _l, _l, _l, _l, _l, _g, _l, _l, _l, _l, _g),
        window=1024,
        qk_norm=True,
        rope_theta=1000000.0,
        tie_embeddings=True,
        shape_skips={},
    )
)
