"""The paper's own evaluation models (Table 3): LLaMA 2 and Qwen 2.5 variants.

These drive the benchmark suite's faithful reproduction of the paper's
experiments (3D-parallel settings (TP,DP,PP) per Table 3) and are also
selectable via --arch like the assigned architectures.
"""
from repro.configs.base import ArchConfig, LayerSpec, register

_DENSE = (LayerSpec("attn", attn_kind="full", ffn="dense"),)


def _dense(arch_id, n_layers, d_model, n_heads, n_kv_heads, d_ff, vocab, theta=10000.0, qk_norm=False):
    return register(
        ArchConfig(
            arch_id=arch_id,
            family="dense",
            n_layers=n_layers,
            d_model=d_model,
            n_heads=n_heads,
            n_kv_heads=n_kv_heads,
            head_dim=d_model // n_heads,
            d_ff=d_ff,
            vocab_size=vocab,
            period=_DENSE,
            rope_theta=theta,
            qk_norm=qk_norm,
            shape_skips={"long_500k": "pure full-attention arch (per spec)"},
        )
    )


LLAMA2_7B = _dense("llama2-7b", 32, 4096, 32, 32, 11008, 32000)
LLAMA2_13B = _dense("llama2-13b", 40, 5120, 40, 40, 13824, 32000)
LLAMA2_30B = _dense("llama2-30b", 60, 6656, 52, 52, 17920, 32000)
LLAMA2_70B = _dense("llama2-70b", 80, 8192, 64, 8, 28672, 32000)
QWEN25_7B = _dense("qwen2.5-7b", 28, 3584, 28, 4, 18944, 152064, theta=1e6)
QWEN25_14B = _dense("qwen2.5-14b", 48, 5120, 40, 8, 13824, 152064, theta=1e6)
QWEN25_32B = _dense("qwen2.5-32b", 64, 5120, 40, 8, 27648, 152064, theta=1e6)
QWEN25_72B = _dense("qwen2.5-72b", 80, 8192, 64, 8, 29568, 152064, theta=1e6)

# (TP, DP, PP) settings from Table 3, keyed by paper scale name.
PAPER_PARALLELISM = {
    "small": {"tp": 4, "dp": 2, "pp": 2, "gpus": 16},
    "medium": {"tp": 4, "dp": 2, "pp": 4, "gpus": 32},
    "large": {"tp": 4, "dp": 2, "pp": 8, "gpus": 64},
    "xlarge": {"tp": 4, "dp": 4, "pp": 16, "gpus": 256},
}
PAPER_MODELS = {
    "small": ("llama2-7b", "qwen2.5-7b"),
    "medium": ("llama2-13b", "qwen2.5-14b"),
    "large": ("llama2-30b", "qwen2.5-32b"),
    "xlarge": ("llama2-70b", "qwen2.5-72b"),
}
