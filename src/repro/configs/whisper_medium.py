"""whisper-medium [audio] — enc-dec transformer backbone; conv frontend stub.

24L (encoder and decoder each) d_model=1024 16H (MHA kv=16) d_ff=4096
vocab=51865 [arXiv:2212.04356; unverified]. The conv frontend is a stub per
the assignment: input_specs() provides precomputed frame embeddings for the
encoder. Train/prefill shapes drive the encoder at seq_len frames with a
seq_len//4 decoder; decode shapes drive the decoder with a seq_len KV cache
cross-attending seq_len encoder frames. vocab 51865 is padded to 51968 (x256)
for clean TP sharding.
"""
from repro.configs.base import ArchConfig, LayerSpec, register

CONFIG = register(
    ArchConfig(
        arch_id="whisper-medium",
        family="audio",
        n_layers=24,
        d_model=1024,
        n_heads=16,
        n_kv_heads=16,
        head_dim=64,
        d_ff=4096,
        vocab_size=51865,
        period=(LayerSpec("attn", attn_kind="full", ffn="dense"),),
        enc_dec=True,
        n_enc_layers=24,
        dec_ratio=4,
        audio=True,
        rope_theta=10000.0,  # backbone uses rope in lieu of learned-pos (stub-adapted)
        shape_skips={
            "long_500k": "pure full-attention enc-dec arch; sub-quadratic required (per spec)"
        },
    )
)
