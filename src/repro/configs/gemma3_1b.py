"""gemma3-1b [dense] — 5:1 local:global interleave, 128k context.

26L d_model=1152 4H (GQA kv=1) d_ff=6912 vocab=262144 [hf:google/gemma-3-1b-pt].
26 layers are not divisible by 6, so we use a period of 13 with 2 global layers
(22 local : 4 global = 5.5:1, the closest realizable ratio; documented in
DESIGN.md). Sliding window = 512 (gemma3 default).
"""
from repro.configs.base import ArchConfig, LayerSpec, register

_l = LayerSpec("attn", attn_kind="swa", ffn="dense")
_g = LayerSpec("attn", attn_kind="full", ffn="dense")

CONFIG = register(
    ArchConfig(
        arch_id="gemma3-1b",
        family="dense",
        n_layers=26,
        d_model=1152,
        n_heads=4,
        n_kv_heads=1,
        head_dim=256,
        d_ff=6912,
        vocab_size=262144,
        period=(_l, _l, _l, _l, _l, _g, _l, _l, _l, _l, _l, _g, _l),
        window=512,
        qk_norm=True,
        rope_theta=1000000.0,
        tie_embeddings=True,
        # mostly-local attention: per-step decode cost is bounded => runs
        shape_skips={},
    )
)
