"""qwen3-8b [dense] — qk_norm, GQA. 36L d=4096 32H kv=8 d_ff=12288 vocab=151936.

[hf:Qwen/Qwen3-8B; hf]
"""
from repro.configs.base import ArchConfig, LayerSpec, register

CONFIG = register(
    ArchConfig(
        arch_id="qwen3-8b",
        family="dense",
        n_layers=36,
        d_model=4096,
        n_heads=32,
        n_kv_heads=8,
        head_dim=128,
        d_ff=12288,
        vocab_size=151936,
        period=(LayerSpec("attn", attn_kind="full", ffn="dense"),),
        qk_norm=True,
        rope_theta=1000000.0,
        shape_skips={
            "long_500k": "pure full-attention arch; sub-quadratic required (per spec)"
        },
    )
)
