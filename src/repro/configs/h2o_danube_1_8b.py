"""h2o-danube-1.8b [dense] — llama+mistral mix, sliding-window attention.

24L d_model=2560 32H (GQA kv=8) d_ff=6912 vocab=32000 [arXiv:2401.16818; hf].
head_dim = 2560/32 = 80; mistral-style SWA window 4096 on every layer.
"""
from repro.configs.base import ArchConfig, LayerSpec, register

CONFIG = register(
    ArchConfig(
        arch_id="h2o-danube-1.8b",
        family="dense",
        n_layers=24,
        d_model=2560,
        n_heads=32,
        n_kv_heads=8,
        head_dim=80,
        d_ff=6912,
        vocab_size=32000,
        period=(LayerSpec("attn", attn_kind="swa", ffn="dense"),),
        window=4096,
        rope_theta=10000.0,
        # SWA everywhere: decode cost bounded by window => long_500k runs
        shape_skips={},
    )
)
