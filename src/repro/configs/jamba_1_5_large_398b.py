"""jamba-1.5-large-398b [hybrid] — Mamba+attn 1:7 interleave, MoE 16e top-2.

72L d_model=8192 64H (GQA kv=8) d_ff=24576 vocab=65536 [arXiv:2403.19887; hf].
Period of 8: one attention layer per 8 (1:7), MoE on every other layer.
Param-count check: 9 periods x ~44.2B + 1.07B embeddings = ~398B (matches).
"""
from repro.configs.base import ArchConfig, LayerSpec, register

_M = LayerSpec("mamba", ffn="moe")
_m = LayerSpec("mamba", ffn="dense")
_A = LayerSpec("attn", attn_kind="full", ffn="moe")
_a = LayerSpec("attn", attn_kind="full", ffn="dense")

CONFIG = register(
    ArchConfig(
        arch_id="jamba-1.5-large-398b",
        family="hybrid",
        n_layers=72,
        d_model=8192,
        n_heads=64,
        n_kv_heads=8,
        head_dim=128,
        d_ff=24576,
        vocab_size=65536,
        # 1 attn : 7 mamba, MoE every other layer (even positions)
        period=(_M, _m, _M, _a, _M, _m, _M, _m),
        n_experts=16,
        moe_top_k=2,
        moe_d_ff=24576,
        rope_theta=10000.0,
        shape_skips={},  # hybrid (mamba-dominant) => long_500k runs
    )
)
