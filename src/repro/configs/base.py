"""Architecture / shape / run configuration schema.

Every assigned architecture is a frozen `ArchConfig`; the four assigned input
shapes are `ShapeSpec`s. Configs are pure data — no jax imports — so the
scheduler, simulator, and launcher can all consume them without touching
device state.
"""
from __future__ import annotations

import dataclasses
import math
from dataclasses import dataclass, field
from typing import Optional


@dataclass(frozen=True)
class LayerSpec:
    """One layer inside the repeating period of a model."""

    mixer: str  # 'attn' | 'mamba' | 'mlstm' | 'slstm'
    attn_kind: str = "full"  # 'full' | 'swa'  (only for mixer == 'attn')
    ffn: str = "dense"  # 'dense' | 'moe' | 'none'


@dataclass(frozen=True)
class ShapeSpec:
    """One assigned input-shape cell."""

    name: str
    seq_len: int
    global_batch: int
    kind: str  # 'train' | 'prefill' | 'decode'

    @property
    def lowers(self) -> str:
        return "train_step" if self.kind == "train" else "serve_step"


# The four LM shape cells assigned to every architecture.
TRAIN_4K = ShapeSpec("train_4k", 4096, 256, "train")
PREFILL_32K = ShapeSpec("prefill_32k", 32768, 32, "prefill")
DECODE_32K = ShapeSpec("decode_32k", 32768, 128, "decode")
LONG_500K = ShapeSpec("long_500k", 524288, 1, "decode")
ALL_SHAPES = (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)
SHAPES_BY_NAME = {s.name: s for s in ALL_SHAPES}


def _round_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


@dataclass(frozen=True)
class ArchConfig:
    arch_id: str
    family: str  # dense | moe | hybrid | ssm | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    head_dim: int
    d_ff: int
    vocab_size: int
    period: tuple[LayerSpec, ...] = (LayerSpec("attn"),)

    # attention details
    window: int = 4096  # sliding-window width for attn_kind == 'swa'
    qk_norm: bool = False
    rope_theta: float = 10000.0
    mrope_sections: Optional[tuple[int, ...]] = None  # qwen2-vl M-RoPE

    # MoE
    n_experts: int = 0
    moe_top_k: int = 0
    moe_d_ff: int = 0  # per-expert FFN width (d_ff of the expert)
    capacity_factor: float = 1.25

    # Mamba (hybrid / ssm families)
    mamba_d_state: int = 16
    mamba_d_conv: int = 4
    mamba_expand: int = 2

    # xLSTM
    xlstm_conv: int = 4
    mlstm_chunk: int = 256  # chunkwise-parallel block length (perf knob)

    # encoder-decoder (audio family)
    enc_dec: bool = False
    n_enc_layers: int = 0
    # decoder length = seq_len // dec_ratio for train/prefill shapes
    dec_ratio: int = 4

    # modality frontend stubs
    vlm: bool = False  # expects fused vision embeddings + M-RoPE positions
    audio: bool = False  # expects precomputed frame embeddings

    # numerics
    vocab_pad_to: int = 256
    norm_eps: float = 1e-6
    tie_embeddings: bool = False

    # which assigned shapes are skipped (per-spec) and why
    shape_skips: dict = field(default_factory=dict)

    # ---------------------------------------------------------------- derived
    @property
    def padded_vocab(self) -> int:
        return _round_up(self.vocab_size, self.vocab_pad_to)

    @property
    def n_periods(self) -> int:
        assert self.n_layers % len(self.period) == 0, (
            f"{self.arch_id}: n_layers={self.n_layers} not divisible by "
            f"period={len(self.period)}"
        )
        return self.n_layers // len(self.period)

    @property
    def q_dim(self) -> int:
        return self.n_heads * self.head_dim

    @property
    def kv_dim(self) -> int:
        return self.n_kv_heads * self.head_dim

    @property
    def mamba_d_inner(self) -> int:
        return self.mamba_expand * self.d_model

    def layer_spec(self, i: int) -> LayerSpec:
        return self.period[i % len(self.period)]

    def layer_specs(self) -> list[LayerSpec]:
        return [self.layer_spec(i) for i in range(self.n_layers)]

    # -------------------------------------------------------- parameter count
    def _attn_params(self) -> int:
        d, hq, hkv = self.d_model, self.q_dim, self.kv_dim
        return d * hq + 2 * d * hkv + hq * d + (2 * self.head_dim if self.qk_norm else 0)

    def _ffn_params(self, spec: LayerSpec) -> int:
        if spec.ffn == "dense":
            return 3 * self.d_model * self.d_ff  # gated (SwiGLU-style)
        if spec.ffn == "moe":
            per = 3 * self.d_model * self.moe_d_ff
            return self.n_experts * per + self.d_model * self.n_experts  # + router
        return 0

    def _mixer_params(self, spec: LayerSpec) -> int:
        d = self.d_model
        if spec.mixer == "attn":
            return self._attn_params()
        if spec.mixer == "mamba":
            di, n = self.mamba_d_inner, self.mamba_d_state
            # in_proj (2*di), conv, x_proj (dt+2n), dt_proj, out_proj, A, D
            return (
                d * 2 * di
                + di * self.mamba_d_conv
                + di * (math.ceil(d / 16) + 2 * n)
                + di * math.ceil(d / 16)
                + di * d
                + di * n
                + di
            )
        if spec.mixer == "mlstm":
            di = 2 * d
            dh = di // max(self.n_heads, 1)
            # w_m + w_z + conv + block-diag qkv + i/f gates + groupnorm + w_out
            return (
                2 * d * di
                + di * self.xlstm_conv + di
                + 3 * self.n_heads * dh * dh
                + 2 * di * self.n_heads + 2 * self.n_heads
                + di
                + di * d
            )
        if spec.mixer == "slstm":
            dh = self.d_model // max(self.n_heads, 1)
            # w_g (4 gates) + block-diag recurrence + biases + w_out
            return 4 * d * d + 4 * self.n_heads * dh * dh + 4 * self.n_heads * dh + d * d
        raise ValueError(spec.mixer)

    def param_count(self, *, active_only: bool = False) -> int:
        """Total (or MoE-active) parameter count — used for MODEL_FLOPS."""
        total = self.padded_vocab * self.d_model  # embed
        if not self.tie_embeddings:
            total += self.padded_vocab * self.d_model
        norms = 2 * self.d_model  # per layer, + final
        for i in range(self.n_layers):
            spec = self.layer_spec(i)
            total += self._mixer_params(spec) + norms
            if spec.ffn == "moe" and active_only:
                total += 3 * self.d_model * self.moe_d_ff * self.moe_top_k
                total += self.d_model * self.n_experts
            else:
                total += self._ffn_params(spec)
        if self.enc_dec:
            # encoder layers (attn + dense ffn) + cross-attn in decoder
            for _ in range(self.n_enc_layers):
                total += self._attn_params() + 3 * self.d_model * self.d_ff + norms
            total += self.n_layers * self._attn_params()  # cross attention
        total += self.d_model
        return total

    def active_param_count(self) -> int:
        return self.param_count(active_only=True)

    def runnable_shapes(self) -> list[ShapeSpec]:
        return [s for s in ALL_SHAPES if s.name not in self.shape_skips]


# ---------------------------------------------------------------- registry
_REGISTRY: dict[str, ArchConfig] = {}


def register(cfg: ArchConfig) -> ArchConfig:
    assert cfg.arch_id not in _REGISTRY, f"duplicate arch {cfg.arch_id}"
    _REGISTRY[cfg.arch_id] = cfg
    return cfg


def get_arch(arch_id: str) -> ArchConfig:
    # import side-effect registration
    from repro import configs as _  # noqa: F401

    if arch_id not in _REGISTRY:
        raise KeyError(f"unknown arch '{arch_id}'; known: {sorted(_REGISTRY)}")
    return _REGISTRY[arch_id]


def list_archs() -> list[str]:
    from repro import configs as _  # noqa: F401

    return sorted(_REGISTRY)


def reduced(cfg: ArchConfig, **overrides) -> ArchConfig:
    """A small same-family config for CPU smoke tests."""
    shrink = dict(
        n_layers=len(cfg.period) * 2,
        d_model=64,
        n_heads=4,
        n_kv_heads=min(cfg.n_kv_heads, 2) if cfg.n_kv_heads > 1 else 1,
        head_dim=16,
        d_ff=128,
        vocab_size=512,
        window=32,
        n_experts=4 if cfg.n_experts else 0,
        moe_top_k=min(cfg.moe_top_k, 2) if cfg.n_experts else 0,
        moe_d_ff=64 if cfg.n_experts else 0,
        mamba_d_state=8,
        n_enc_layers=2 if cfg.enc_dec else 0,
        vocab_pad_to=64,
        arch_id=cfg.arch_id + "-reduced",
    )
    if cfg.mrope_sections is not None:
        shrink["mrope_sections"] = (2, 3, 3)  # sums to head_dim // 2
    shrink.update(overrides)
    return dataclasses.replace(cfg, **shrink)
