"""grok-1-314b [moe] — 8 experts top-2.

64L d_model=6144 48H (GQA kv=8) d_ff=32768 vocab=131072 [hf:xai-org/grok-1].
Param-count check: 64 x (8x3x6144x32768 MoE + attn) + embeddings ~= 316B.
"""
from repro.configs.base import ArchConfig, LayerSpec, register

CONFIG = register(
    ArchConfig(
        arch_id="grok-1-314b",
        family="moe",
        n_layers=64,
        d_model=6144,
        n_heads=48,
        n_kv_heads=8,
        head_dim=128,
        d_ff=32768,
        vocab_size=131072,
        period=(LayerSpec("attn", attn_kind="full", ffn="moe"),),
        n_experts=8,
        moe_top_k=2,
        moe_d_ff=32768,
        rope_theta=10000.0,
        shape_skips={
            "long_500k": "pure full-attention arch; sub-quadratic required (per spec)"
        },
    )
)
