"""qwen2-vl-7b [vlm] — M-RoPE, dynamic resolution (backbone only).

28L d_model=3584 28H (GQA kv=4) d_ff=18944 vocab=152064 [arXiv:2409.12191; hf].
The vision frontend is a stub per the assignment: input_specs() provides
precomputed patch embeddings merged into the token stream at masked positions,
plus 3-axis M-RoPE position ids (temporal/height/width; sections 16/24/24
halves of head_dim=128).
"""
from repro.configs.base import ArchConfig, LayerSpec, register

CONFIG = register(
    ArchConfig(
        arch_id="qwen2-vl-7b",
        family="vlm",
        n_layers=28,
        d_model=3584,
        n_heads=28,
        n_kv_heads=4,
        head_dim=128,
        d_ff=18944,
        vocab_size=152064,
        period=(LayerSpec("attn", attn_kind="full", ffn="dense"),),
        mrope_sections=(16, 24, 24),
        rope_theta=1000000.0,
        vlm=True,
        shape_skips={
            "long_500k": "pure full-attention arch; sub-quadratic required (per spec)"
        },
    )
)
