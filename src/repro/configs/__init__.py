"""Config registry: importing this package registers all architectures."""
from repro.configs.base import (  # noqa: F401
    ALL_SHAPES,
    SHAPES_BY_NAME,
    ArchConfig,
    LayerSpec,
    ShapeSpec,
    get_arch,
    list_archs,
    reduced,
    register,
)

# Assigned architectures (register on import).
from repro.configs import jamba_1_5_large_398b  # noqa: F401
from repro.configs import xlstm_1_3b  # noqa: F401
from repro.configs import qwen3_8b  # noqa: F401
from repro.configs import gemma3_1b  # noqa: F401
from repro.configs import gemma3_4b  # noqa: F401
from repro.configs import h2o_danube_1_8b  # noqa: F401
from repro.configs import qwen2_vl_7b  # noqa: F401
from repro.configs import whisper_medium  # noqa: F401
from repro.configs import grok_1_314b  # noqa: F401
from repro.configs import qwen3_moe_30b_a3b  # noqa: F401

# Paper's own models (Table 3).
from repro.configs import paper_models  # noqa: F401

ASSIGNED_ARCHS = (
    "jamba-1.5-large-398b",
    "xlstm-1.3b",
    "qwen3-8b",
    "gemma3-1b",
    "gemma3-4b",
    "h2o-danube-1.8b",
    "qwen2-vl-7b",
    "whisper-medium",
    "grok-1-314b",
    "qwen3-moe-30b-a3b",
)
