"""qwen3-moe-30b-a3b [moe] — 128 experts top-8, qk_norm.

48L d_model=2048 32H (GQA kv=4) d_ff=768 (per-expert) vocab=151936
[hf:Qwen/Qwen3-30B-A3B; hf]. Param-count check: 48x128x3x2048x768 ~= 29B total,
~3.3B active (top-8).
"""
from repro.configs.base import ArchConfig, LayerSpec, register

CONFIG = register(
    ArchConfig(
        arch_id="qwen3-moe-30b-a3b",
        family="moe",
        n_layers=48,
        d_model=2048,
        n_heads=32,
        n_kv_heads=4,
        head_dim=128,
        d_ff=768,
        vocab_size=151936,
        period=(LayerSpec("attn", attn_kind="full", ffn="moe"),),
        n_experts=128,
        moe_top_k=8,
        moe_d_ff=768,
        qk_norm=True,
        rope_theta=1000000.0,
        shape_skips={
            "long_500k": "pure full-attention arch; sub-quadratic required (per spec)"
        },
    )
)
