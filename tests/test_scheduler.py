"""Scheduler: Eq. 3/4 TP reconfiguration, §6.2 layer repartition (exact DP vs
brute force), §6.3 migration invariants, end-to-end progressive adaptation."""
import itertools

import pytest
from _ht import given, settings, strategies as st

from repro.core.scheduler.migration import ProgressAwareMigrator
from repro.core.scheduler.plan import initial_plan
from repro.core.scheduler.repartition import (
    partition_bottleneck,
    repartition_layers,
)
from repro.core.scheduler.scheduler import Scheduler
from repro.core.scheduler.tp_reconfig import (
    backfill_from_standby,
    candidate_degrees,
    reconfigure_tp_group,
)


# ------------------------------------------------------------------ Eq. 3/4
def test_candidate_degrees():
    assert candidate_degrees(7, 1) == [1, 2, 4]
    assert candidate_degrees(8, 2) == [2, 4, 8]
    assert candidate_degrees(3, 4) == []


def test_selective_exclusion_failstop():
    rec = reconfigure_tp_group([0, 1, 2, 3], {0: 1.0, 1: 0.0, 2: 1.0, 3: 1.0})
    assert rec.tp == 2 and rec.effective_throughput == 2.0
    assert 1 in rec.excluded and len(rec.standby) == 1


def test_selective_exclusion_drops_slow_member():
    # k=4 with a 0.4-speed member: 4*0.4=1.6 < k=2 healthy: 2.0
    rec = reconfigure_tp_group([0, 1, 2, 3], {0: 1.0, 1: 0.4, 2: 1.0, 3: 1.0})
    assert rec.tp == 2 and 1 not in rec.devices


def test_keeps_fast_failslow_when_it_wins():
    # 0.9-speed member: 4*0.9=3.6 > 2.0 -> keep the whole group
    rec = reconfigure_tp_group([0, 1, 2, 3], {0: 1.0, 1: 0.9, 2: 1.0, 3: 1.0})
    assert rec.tp == 4 and rec.effective_throughput == pytest.approx(3.6)


def test_k_min_memory_floor():
    rec = reconfigure_tp_group([0, 1, 2, 3], {0: 1.0, 1: 0.0, 2: 0.0, 3: 0.0},
                               k_min=2)
    assert rec.tp == 0  # only 1 survivor < k_min -> dead stage


@settings(max_examples=60, deadline=None)
@given(st.lists(st.floats(0.0, 1.0), min_size=2, max_size=9),
       st.integers(1, 2))
def test_eq4_is_argmax(speeds, k_min):
    group = list(range(len(speeds)))
    sp = dict(enumerate(speeds))
    rec = reconfigure_tp_group(group, sp, k_min=k_min)
    survivors = [d for d in group if sp[d] > 0]
    ks = candidate_degrees(len(survivors), k_min)
    if not ks:
        assert rec.tp == 0
        return
    # brute-force Eq. 4 over all subsets of each candidate size
    best = 0.0
    for k in ks:
        for sub in itertools.combinations(survivors, k):
            best = max(best, k * min(sp[d] for d in sub))
    assert rec.effective_throughput == pytest.approx(best)
    assert rec.tp in ks
    assert bin(rec.tp).count("1") == 1  # power of two


def test_backfill_from_standby():
    rec = reconfigure_tp_group([0, 1, 2, 3], {0: 1.0, 1: 0.0, 2: 1.0, 3: 1.0})
    assert rec.standby
    sp = {0: 1.0, 1: 0.0, 2: 0.0, 3: 1.0}  # second failure hits device 2
    rec2 = backfill_from_standby(rec, sp)
    assert rec2.tp == 2 and set(rec2.devices) == {0, 3}


# --------------------------------------------------------------- §6.2 DP
def test_paper_fig5_repartition():
    parts = repartition_layers([1.0] * 12, [1.0, 0.5, 1.0])
    assert [len(p) for p in parts] == [5, 2, 5]


@settings(max_examples=40, deadline=None)
@given(
    n_layers=st.integers(4, 14),
    speeds=st.lists(st.floats(0.25, 1.0), min_size=2, max_size=4),
)
def test_repartition_optimal_vs_bruteforce(n_layers, speeds):
    if n_layers < len(speeds):
        return
    costs = [1.0] * n_layers
    parts = repartition_layers(costs, speeds)
    got = partition_bottleneck(costs, parts, speeds)
    # brute force all contiguous partitions
    S = len(speeds)
    best = float("inf")
    for cuts in itertools.combinations(range(1, n_layers), S - 1):
        bounds = [0, *cuts, n_layers]
        p = [tuple(range(bounds[i], bounds[i + 1])) for i in range(S)]
        best = min(best, partition_bottleneck(costs, p, speeds))
    assert got == pytest.approx(best, rel=1e-9)


def test_repartition_heterogeneous_costs():
    # hybrid-style: attention layers 3x a mamba layer
    costs = [3.0 if i % 4 == 0 else 1.0 for i in range(12)]
    parts = repartition_layers(costs, [1.0, 1.0, 1.0])
    assert partition_bottleneck(costs, parts, [1.0] * 3) <= sum(costs) / 3 + 3.0
    assert [i for p in parts for i in p] == list(range(12))  # contiguous cover


# --------------------------------------------------------- §6.3 invariants
def _cost(cid, e):
    return {"F": 1.0, "B": 2.0, "W": 0.5}[cid.kind]


@settings(max_examples=25, deadline=None)
@given(
    n_stages=st.integers(2, 4),
    n_replicas=st.integers(2, 3),
    n_mb=st.integers(2, 6),
    dead=st.booleans(),
    slow_stage=st.integers(0, 3),
)
def test_migration_completeness(n_stages, n_replicas, n_mb, dead, slow_stage):
    """Every chunk executes exactly once regardless of failures (constraint 1
    of the §6.3 formulation)."""
    slow_stage = slow_stage % n_stages
    cost = lambda cid, e: _cost(cid, e) * (2.0 if e == (0, slow_stage) else 1.0)
    dead_ex = [(1 % n_replicas, (slow_stage + 1) % n_stages)] if dead else []
    m = ProgressAwareMigrator(
        n_stages=n_stages, n_replicas=n_replicas, n_microbatches=n_mb,
        chunk_cost=cost, dead_executors=dead_ex, policy="resihp", delta=1)
    res = m.run()
    assert res.status == "ok"
    assert len(m.done) == len(m.chunks)  # exactly once: done is a set
    # nothing ran on a dead executor
    for cid in m.done:
        assert m._executor_of(cid) not in m.dead


def test_migration_memory_capacity_respected():
    m = ProgressAwareMigrator(
        n_stages=3, n_replicas=2, n_microbatches=8, chunk_cost=_cost,
        dead_executors=[(0, 1)], policy="resihp", mem_capacity=3)
    res = m.run()
    assert res.status == "ok"
    # inflight migrated F count never exceeded capacity (tracked invariantly)
    assert all(v >= 0 for v in m.inflight_migrated_f.values())


def test_healthy_pipeline_no_migrations():
    m = ProgressAwareMigrator(n_stages=4, n_replicas=2, n_microbatches=8,
                              chunk_cost=_cost, policy="resihp", delta=1)
    res = m.run()
    assert res.status == "ok" and len(res.migrations) == 0


def test_failslow_migration_beats_none():
    slow = lambda cid, e: _cost(cid, e) * (3.0 if e == (0, 1) else 1.0)
    r_mig = ProgressAwareMigrator(n_stages=4, n_replicas=2, n_microbatches=8,
                                  chunk_cost=slow, policy="resihp", delta=1).run()
    r_none = ProgressAwareMigrator(n_stages=4, n_replicas=2, n_microbatches=8,
                                   chunk_cost=slow, policy="none").run()
    assert r_mig.makespan < r_none.makespan


def test_deadstage_none_aborts_resihp_survives():
    kw = dict(n_stages=4, n_replicas=2, n_microbatches=6, chunk_cost=_cost,
              dead_executors=[(0, 2)])
    assert ProgressAwareMigrator(policy="none", **kw).run().status == "aborted"
    assert ProgressAwareMigrator(policy="resihp", **kw).run().status == "ok"


# ------------------------------------------------------------- end to end
def test_progressive_adaptation():
    plan = initial_plan(16, dp=2, pp=4, tp=4)
    sch = Scheduler(layer_costs=[1.0] * 16)
    speeds = {d: 1.0 for d in plan.devices}
    speeds[5] = 0.0  # replica 0, stage 1
    ad = sch.adapt(plan, speeds, failed={5})
    # TP: selective exclusion kept 2 of 4 devices
    assert ad.plan.replicas[0].stages[1].tp == 2
    # healthy replica untouched
    assert ad.plan.replicas[1].stages[1].tp == 4
    # PP: straggler stage holds fewer layers
    n1 = ad.plan.replicas[0].stages[1].n_layers
    assert n1 < 4
    assert not ad.restore_required
    assert sum(s.n_layers for s in ad.plan.replicas[0].stages) == 16
    # standby pool retains the leftover healthy device
    assert len(ad.plan.standby) == 1


def test_adaptation_restore_required():
    plan = initial_plan(8, dp=2, pp=2, tp=2)
    sch = Scheduler(layer_costs=[1.0] * 8)
    speeds = {d: 1.0 for d in plan.devices}
    # kill stage 0 of BOTH replicas
    for d in plan.replicas[0].stages[0].devices + plan.replicas[1].stages[0].devices:
        speeds[d] = 0.0
    ad = sch.adapt(plan, speeds)
    assert ad.restore_required


# ------------------------------------------------------------- plan cache
def test_adapt_plan_cache_hits_on_repeated_signature():
    """Repeated reconfigurations under the same failure signature (flapping
    / poisson storms) skip the repartition DP + TP search entirely: the
    cached AdaptationPlan object itself is returned."""
    plan = initial_plan(16, dp=2, pp=4, tp=4)
    sch = Scheduler(layer_costs=[1.0] * 16)
    speeds = {d: 1.0 for d in plan.devices}
    speeds[5] = 0.0
    first = sch.adapt(plan, speeds, failed={5})
    assert sch.adapt(plan, speeds, failed={5}) is first
    # a different signature recomputes...
    speeds[6] = 0.5
    other = sch.adapt(plan, speeds, failed={5})
    assert other is not first
    # ...and both stay cached independently
    del speeds[6]
    speeds[6] = 1.0
    assert sch.adapt(plan, speeds, failed={5}) is first


def test_adapt_plan_cache_keys_on_quarantine_and_risk():
    plan = initial_plan(8, dp=1, pp=2, tp=4)
    sch = Scheduler(layer_costs=[1.0] * 8)
    speeds = {d: 1.0 for d in plan.devices}
    speeds[1] = 0.0
    blind = sch.adapt(plan, speeds)
    aware = sch.adapt(plan, speeds, device_risk={2: 6.0})
    assert aware is not blind
    assert 2 not in aware.plan.replicas[0].stages[0].devices
    quar = sch.adapt(plan, speeds, quarantined=frozenset({2}))
    assert quar is not blind and quar is not aware
    # hits come back per-signature
    assert sch.adapt(plan, speeds) is blind
    assert sch.adapt(plan, speeds, device_risk={2: 6.0}) is aware


def test_adapt_plan_cache_disabled():
    plan = initial_plan(8, dp=1, pp=2, tp=4)
    sch = Scheduler(layer_costs=[1.0] * 8, plan_cache_size=0)
    speeds = {d: 1.0 for d in plan.devices}
    speeds[1] = 0.0
    a, b = sch.adapt(plan, speeds), sch.adapt(plan, speeds)
    assert a is not b
    assert a.plan == b.plan  # adapt stays a pure function either way


def test_adapt_plan_cache_is_per_plan_object():
    """Same failure signature against a different plan must not serve the
    cached adaptation of the first plan."""
    sch = Scheduler(layer_costs=[1.0] * 8)
    plan_a = initial_plan(8, dp=1, pp=2, tp=4)
    plan_b = initial_plan(8, dp=2, pp=2, tp=2)
    speeds = {d: 1.0 for d in plan_a.devices}
    speeds[1] = 0.0
    ad_a = sch.adapt(plan_a, speeds)
    ad_b = sch.adapt(plan_b, speeds)
    assert ad_b is not ad_a
    assert ad_b.plan.replicas[0].stages[0].tp != ad_a.plan.replicas[0].stages[0].tp


def test_measure_overhead_off_reports_zero():
    plan = initial_plan(8, dp=1, pp=2, tp=4)
    sch = Scheduler(layer_costs=[1.0] * 8, measure_overhead=False)
    speeds = {d: 1.0 for d in plan.devices}
    speeds[1] = 0.0
    assert sch.adapt(plan, speeds).plan_overhead_s == 0.0
    timed = Scheduler(layer_costs=[1.0] * 8)
    assert timed.adapt(plan, speeds).plan_overhead_s > 0.0


def test_resihp_policy_wires_measure_overhead():
    """The measured wall clock is dead weight whenever a fixed or modeled
    planning charge is set — the policy's scheduler must skip it."""
    from repro.cluster.baselines import ResiHPPolicy

    plan = initial_plan(8, dp=1, pp=2, tp=4)
    measured = ResiHPPolicy(plan, [1.0] * 8)
    assert measured.scheduler.measure_overhead
    fixed = ResiHPPolicy(plan, [1.0] * 8, plan_overhead_fixed=0.25)
    assert not fixed.scheduler.measure_overhead
    modeled = ResiHPPolicy(plan, [1.0] * 8, plan_overhead_model=True)
    assert not modeled.scheduler.measure_overhead


# --------------------------------------------------- bugfix-batch regressions
def test_missing_speed_defaults_to_healthy():
    """A device absent from `speeds` must be treated as healthy (p=1.0), the
    default the ranking/throughput paths always used — not as failed (the
    0.0 default the exclusion-set build used to apply)."""
    rec = reconfigure_tp_group([0, 1, 2, 3], {1: 0.5})
    assert rec.excluded == ()  # nobody treated as dead
    # Eq. 4 over {1.0, 0.5, 1.0, 1.0}: k=2 healthy pair (2.0) ties k=4
    # (4*0.5) and the smaller k wins the tie
    assert rec.tp == 2 and rec.effective_throughput == pytest.approx(2.0)
    assert 1 not in rec.devices
    # an empty dict now means an all-healthy group, not an all-dead one
    rec = reconfigure_tp_group([0, 1, 2, 3], {})
    assert rec.tp == 4 and rec.effective_throughput == pytest.approx(4.0)


def test_two_step_adaptation_keeps_healthy_baseline_normalization():
    """Adapting an already-shrunk plan must not inflate surviving stages'
    effective speeds: normalization uses the healthy baseline TP, not the
    incoming plan's (possibly degraded) max degree."""
    plan0 = initial_plan(8, dp=1, pp=2, tp=4)
    sch = Scheduler(layer_costs=[1.0] * 8, baseline_tp=4)
    speeds = {d: 1.0 for d in plan0.devices}
    speeds[1] = speeds[3] = 0.0  # stage 0 loses two ranks
    speeds[5] = 0.0  # stage 1 loses one
    step1 = sch.adapt(plan0, speeds, failed={1, 3, 5})
    assert all(st.tp == 2 for st in step1.plan.replicas[0].stages)
    # both stages now run 2 of 4 original ranks = 0.5 of healthy
    assert step1.stage_speeds == {(0, 0): 0.5, (0, 1): 0.5}
    # second failure wave against the *adapted* plan: the surviving stage is
    # still at half capacity (tp0=4), not "full speed" (the tp0=2 bug)
    speeds2 = {d: 1.0 for d in step1.plan.devices}
    step2 = sch.adapt(step1.plan, speeds2)
    assert step2.stage_speeds[(0, 0)] == pytest.approx(0.5)
    assert step2.stage_speeds[(0, 1)] == pytest.approx(0.5)


def test_resihp_policy_pins_baseline_tp_from_plan0():
    from repro.cluster.baselines import ResiHPPolicy

    plan = initial_plan(8, dp=1, pp=2, tp=4)
    pol = ResiHPPolicy(plan, [1.0] * 8)
    assert pol.scheduler.baseline_tp == 4


def test_standby_pull_in_is_node_local():
    """§6.1 contract: a TP group may only pull in standbys co-located with
    its node. A cross-node standby stays in the pool even when the group
    loses a member."""
    plan = initial_plan(8, dp=1, pp=2, tp=4).replace(standby=(8,))
    node_of = lambda d: d // 8  # devices 0-7 on node 0, standby 8 on node 1
    speeds = {d: 1.0 for d in range(9)}
    speeds[1] = 0.0  # stage-0 group loses a member
    topo_aware = Scheduler(layer_costs=[1.0] * 8, node_of=node_of)
    ad = topo_aware.adapt(plan, speeds, failed={1})
    assert 8 not in ad.plan.replicas[0].stages[0].devices
    assert 8 in ad.plan.standby  # unreachable standby kept, not consumed
    assert ad.plan.replicas[0].stages[0].tp == 2
    # without a topology view (plan-only callers) the whole pool is offered
    legacy = Scheduler(layer_costs=[1.0] * 8)
    ad2 = legacy.adapt(plan, speeds, failed={1})
    assert 8 in ad2.plan.replicas[0].stages[0].devices


def test_node_local_standby_is_consumed_on_same_node():
    plan = initial_plan(8, dp=1, pp=2, tp=4).replace(standby=(8,))
    node_of = lambda d: 0  # everything co-located
    speeds = {d: 1.0 for d in range(9)}
    speeds[1] = 0.0
    sch = Scheduler(layer_costs=[1.0] * 8, node_of=node_of)
    ad = sch.adapt(plan, speeds, failed={1})
    assert 8 in ad.plan.replicas[0].stages[0].devices
    assert ad.plan.replicas[0].stages[0].tp == 4


def test_training_sim_wires_node_of_into_scheduler():
    from repro.cluster.simulator import SimConfig, TrainingSim

    cfg = SimConfig(dp=2, pp=2, tp=2, n_layers=8, n_microbatches=4,
                    devices_per_node=4, seed=0)
    sim = TrainingSim("resihp", cfg)
    assert sim.policy.scheduler.node_of == sim.topo.node_of


def test_repartition_exact_fit_extreme_skew():
    """n == S * min_layers leaves exactly one feasible partition; extreme
    (but finite) speed skew must still return it."""
    parts = repartition_layers([1.0] * 3, [1e-9, 1.0, 1e-9], min_layers=1)
    assert parts == [(0,), (1,), (2,)]
    parts = repartition_layers([1.0] * 6, [1e-12, 1.0, 1.0], min_layers=2)
    assert parts == [(0, 1), (2, 3), (4, 5)]


def test_repartition_survives_overflow_to_inf():
    """Denormal speeds overflow seg() to inf: a reachable-but-infinite-cost
    prefix must not be confused with an unreachable one (the old float
    -identity check crashed on the backtrack here)."""
    parts = repartition_layers([1.0] * 3, [5e-324, 1.0, 5e-324], min_layers=1)
    assert parts == [(0,), (1,), (2,)]
    # mixed: some partitions overflow, the finite one must win
    parts = repartition_layers([1.0] * 4, [5e-324, 1.0], min_layers=1)
    assert [i for p in parts for i in p] == list(range(4))
    assert len(parts[0]) == 1  # the overflowing stage takes as little as legal


# --------------------------------------------- backfill_from_standby coverage
def test_backfill_noop_without_standby():
    rec = reconfigure_tp_group([0, 1, 2, 3], {d: 1.0 for d in range(4)})
    assert rec.standby == ()
    again = backfill_from_standby(rec, {d: 1.0 for d in range(4)})
    assert again.devices == rec.devices
    assert again.effective_throughput == rec.effective_throughput


def test_backfill_respects_k_min():
    rec = reconfigure_tp_group([0, 1, 2, 3], {0: 1.0, 1: 0.0, 2: 1.0, 3: 1.0})
    sp = {0: 1.0, 1: 0.0, 2: 0.0, 3: 0.0}  # everything else dies
    rec2 = backfill_from_standby(rec, sp, k_min=2)
    assert rec2.tp == 0  # one survivor < k_min: dead stage, not a tp-1 group


def test_backfill_prefers_low_risk_on_ties():
    rec = reconfigure_tp_group([0, 1, 2, 3], {0: 1.0, 1: 0.0, 2: 1.0, 3: 1.0})
    standby = rec.standby[0]
    sp = {d: (0.0 if d == 2 else 1.0) for d in range(4)}
    # risk breaks the equal-speed tie: the standby is the safe pick
    risky = backfill_from_standby(rec, sp, risk={0: 9.0, standby: 0.1, 3: 5.0})
    assert standby in risky.devices
