"""Architecture registry: exact assigned configs + parameter-count sanity."""
import pytest

from repro.configs import ASSIGNED_ARCHS, SHAPES_BY_NAME, get_arch, list_archs, reduced

EXPECTED = {
    # arch_id: (layers, d_model, heads, kv, d_ff, vocab)
    "jamba-1.5-large-398b": (72, 8192, 64, 8, 24576, 65536),
    "xlstm-1.3b": (48, 2048, 4, 4, 0, 50304),
    "qwen3-8b": (36, 4096, 32, 8, 12288, 151936),
    "gemma3-1b": (26, 1152, 4, 1, 6912, 262144),
    "gemma3-4b": (34, 2560, 8, 4, 10240, 262144),
    "h2o-danube-1.8b": (24, 2560, 32, 8, 6912, 32000),
    "qwen2-vl-7b": (28, 3584, 28, 4, 18944, 152064),
    "whisper-medium": (24, 1024, 16, 16, 4096, 51865),
    "grok-1-314b": (64, 6144, 48, 8, 32768, 131072),
    "qwen3-moe-30b-a3b": (48, 2048, 32, 4, 768, 151936),
}

# nominal sizes from the arch ids; generous tolerances (embedding/glu details)
NOMINAL_B = {
    "jamba-1.5-large-398b": (398, 0.08),
    # xlstm: assigned dims (48L/2048/4H, proj_factor 2) give ~1.9B with the
    # paper's block parameterization; the "1.3b" id is [unverified] upstream
    "xlstm-1.3b": (1.9, 0.2),
    "qwen3-8b": (8.2, 0.15),
    "gemma3-1b": (1.0, 0.45),
    "gemma3-4b": (4.3, 0.3),
    "h2o-danube-1.8b": (1.8, 0.3),
    "qwen2-vl-7b": (7.6, 0.25),
    "whisper-medium": (0.769, 0.45),
    "grok-1-314b": (314, 0.12),
    "qwen3-moe-30b-a3b": (30.5, 0.15),
}


def test_all_assigned_registered():
    for a in ASSIGNED_ARCHS:
        assert a in list_archs()
    assert len(ASSIGNED_ARCHS) == 10


@pytest.mark.parametrize("arch_id", ASSIGNED_ARCHS)
def test_exact_config(arch_id):
    cfg = get_arch(arch_id)
    L, D, H, K, F, V = EXPECTED[arch_id]
    assert cfg.n_layers == L
    assert cfg.d_model == D
    assert cfg.n_heads == H
    assert cfg.n_kv_heads == K
    assert cfg.d_ff == F or (cfg.d_ff == 0 and F == 0)
    assert cfg.vocab_size == V


@pytest.mark.parametrize("arch_id", ASSIGNED_ARCHS)
def test_param_count_nominal(arch_id):
    cfg = get_arch(arch_id)
    nominal, tol = NOMINAL_B[arch_id]
    got = cfg.param_count() / 1e9
    assert abs(got - nominal) / nominal < tol, f"{arch_id}: {got:.2f}B vs {nominal}B"


def test_moe_configs():
    g = get_arch("grok-1-314b")
    assert g.n_experts == 8 and g.moe_top_k == 2
    q = get_arch("qwen3-moe-30b-a3b")
    assert q.n_experts == 128 and q.moe_top_k == 8
    j = get_arch("jamba-1.5-large-398b")
    assert j.n_experts == 16 and j.moe_top_k == 2
    # active params far below total for high-expert-count MoE
    assert q.active_param_count() < 0.25 * q.param_count()


def test_jamba_interleave():
    cfg = get_arch("jamba-1.5-large-398b")
    kinds = [s.mixer for s in cfg.period]
    assert kinds.count("attn") == 1 and kinds.count("mamba") == 7  # 1:7


def test_gemma_local_global():
    # ~5:1 local:global (period length chosen to divide n_layers)
    for arch in ("gemma3-1b", "gemma3-4b"):
        cfg = get_arch(arch)
        kinds = [s.attn_kind for s in cfg.layer_specs()]
        ratio = kinds.count("swa") / max(kinds.count("full"), 1)
        assert 4.0 <= ratio <= 6.0, (arch, ratio)


def test_shape_skips_recorded():
    # pure full-attention archs skip long_500k; sub-quadratic ones run it
    for a in ("qwen3-8b", "qwen2-vl-7b", "grok-1-314b", "qwen3-moe-30b-a3b",
              "whisper-medium"):
        assert "long_500k" in get_arch(a).shape_skips
    for a in ("jamba-1.5-large-398b", "xlstm-1.3b", "gemma3-1b", "gemma3-4b",
              "h2o-danube-1.8b"):
        assert "long_500k" not in get_arch(a).shape_skips


@pytest.mark.parametrize("arch_id", ASSIGNED_ARCHS)
def test_reduced_is_small(arch_id):
    cfg = reduced(get_arch(arch_id))
    assert cfg.param_count() < 5e6
    assert cfg.n_layers % len(cfg.period) == 0


def test_shapes():
    assert SHAPES_BY_NAME["train_4k"].seq_len == 4096
    assert SHAPES_BY_NAME["train_4k"].global_batch == 256
    assert SHAPES_BY_NAME["prefill_32k"].global_batch == 32
    assert SHAPES_BY_NAME["decode_32k"].global_batch == 128
    assert SHAPES_BY_NAME["long_500k"].seq_len == 524288
