"""Detector stack: Eq. 1 predictor, change-point detectors, heartbeat
hierarchy, and the workload-aware filter (the paper's Table 4/5 behaviour)."""
import numpy as np
import pytest

from repro.core.detector.changepoint import (BOCPD, CusumDetector,
                                             SlopeDriftDetector)
from repro.core.detector.detector import Detector
from repro.core.detector.heartbeat import HeartbeatMonitor
from repro.core.detector.predictor import MicroBatchTimePredictor


# ----------------------------------------------------------- Eq.1 predictor
def test_predictor_recovers_coefficients():
    rng = np.random.default_rng(0)
    a, b, g = 2e-7, 1.5e-11, 5e-4
    pred = MicroBatchTimePredictor()
    for _ in range(32):
        n = int(rng.integers(2000, 8192))
        l2 = int(rng.integers(1e6, n * n))
        t = a * n + b * l2 + g
        pred.observe(n, l2, t * float(rng.normal(1.0, 0.01)))
    pred.fit()
    # MAPE on fresh samples ~ the paper's 1.2-1.6% (Table 4, MTP)
    samples = []
    for _ in range(64):
        n = int(rng.integers(2000, 8192))
        l2 = int(rng.integers(1e6, n * n))
        samples.append((n, l2, 1, a * n + b * l2 + g))
    assert pred.mape(samples) < 0.02


def test_predictor_backward_ratio():
    pred = MicroBatchTimePredictor(backward_ratio=2.0, weight_ratio=1.0)
    pred.alpha, pred.beta, pred.gamma, pred.fitted = 1e-6, 0.0, 0.0, True
    f = pred.predict(1000, 0, kind="F")
    assert pred.predict(1000, 0, kind="B") == pytest.approx(2 * f)
    assert pred.predict(1000, 0, kind="W") == pytest.approx(f)
    assert pred.predict(1000, 0, kind="F", speed=0.5) == pytest.approx(2 * f)


# ------------------------------------------------------------- change-point
@pytest.mark.parametrize("factory", [lambda: CusumDetector(warmup=10),
                                     lambda: BOCPD(warmup=10)])
def test_changepoint_detects_level_shift(factory):
    rng = np.random.default_rng(1)
    det = factory()
    fired_before = 0
    for i in range(40):
        if det.update(1.0 + 0.01 * rng.normal()):
            fired_before += 1
    fired_after = 0
    for i in range(15):
        if det.update(1.35 + 0.01 * rng.normal()):
            fired_after += 1
    assert fired_before == 0
    assert fired_after >= 1


def test_cusum_no_false_fire_on_noise():
    rng = np.random.default_rng(2)
    det = CusumDetector(warmup=10)
    fires = sum(det.update(1.0 + 0.02 * rng.normal()) for _ in range(300))
    assert fires == 0


def test_cusum_discard_last_rewinds_state():
    """Regression: discard_last was a no-op (`_s = max(0.0, _s)`), so a
    filtered-benign point either kept its z-increment or — when it fired —
    erased all accumulated evidence. It must restore the pre-point state."""
    det = CusumDetector(warmup=10)
    for _ in range(10):
        det.update(1.0)
    for _ in range(4):  # accumulate genuine drift evidence (below threshold)
        det.update(1.0 + 0.008)
    s_before = det._s
    assert s_before > 0.0
    fired = det.update(2.0)  # a one-off spike pushes it over the threshold
    assert fired and det._s == 0.0  # fire resets
    det.discard_last()
    assert det._s == pytest.approx(s_before)  # evidence restored, not erased


def test_cusum_s_stays_bounded_under_filtered_benign_runs():
    """Property (satellite): an arbitrarily long run of filtered-benign
    points leaves `_s` bounded — each discard_last fully rewinds the point,
    so benign fluctuations can never accumulate toward a spurious change
    point."""
    rng = np.random.default_rng(7)
    det = CusumDetector(warmup=10)
    for _ in range(10):
        det.update(1.0 + 0.01 * rng.normal())
    baseline_s = det._s
    for _ in range(500):
        det.update(1.0 + abs(0.5 * rng.normal()))  # every point suspicious
        det.discard_last()  # ... and every point filtered benign
        assert det._s == pytest.approx(baseline_s)
        assert 0.0 <= det._s <= det.h


def test_cusum_carried_baseline_rescales_and_keeps_evidence():
    det = CusumDetector(warmup=10)
    for _ in range(10):
        det.update(1.0)
    for _ in range(4):
        det.update(1.03)
    carried = det.carried(2.0)
    assert carried._frozen
    assert carried._mean == pytest.approx(2.0 * det._mean)
    assert carried._std == pytest.approx(2.0 * det._std)
    assert carried._s == pytest.approx(det._s)  # std-units: scale-invariant
    fresh = CusumDetector(warmup=10).carried(2.0)  # never frozen -> fresh
    assert not fresh._frozen and fresh._s == 0.0


def test_slope_drift_fires_on_ramp_not_noise():
    rng = np.random.default_rng(11)
    det = SlopeDriftDetector()
    assert not any(det.update(1.0 + 0.01 * rng.normal()) for _ in range(80))
    det.reset()
    fired_at = None
    x = 1.0
    for i in range(60):
        x += 0.004  # ~0.4%/step creep: far below any single-step threshold
        if det.update(x + 0.01 * rng.normal()):
            fired_at = i
            break
    assert fired_at is not None

    det2 = SlopeDriftDetector()
    for _ in range(40):
        det2.update(1.0 + 0.01 * rng.normal())
    det2.rescale(3.0)
    assert all(2.5 < p < 3.5 for p in det2._pts)


# ---------------------------------------------------------------- heartbeat
def test_heartbeat_two_level():
    hb = HeartbeatMonitor(interval=1.0, miss_threshold=3)
    hb.register_node(0, [0, 1, 2, 3])
    hb.register_node(1, [4, 5, 6, 7])
    for t in range(5):
        for d in range(8):
            if d != 5:  # device 5 stops beating at t=0
                hb.device_beat(d // 4, d, float(t), t)
        hb.node_beat(0, float(t))
        hb.node_beat(1, float(t))
    newly = hb.sweep(5.0)
    assert newly == [5]
    assert hb.failed_devices == {5}
    # coordinator load scales with nodes (2), not devices (8)
    assert hb.n_messages_per_interval == 2


def test_heartbeat_node_crash_fails_all_devices():
    hb = HeartbeatMonitor(interval=1.0, miss_threshold=3)
    hb.register_node(0, [0, 1])
    hb.register_node(1, [2, 3])
    for t in range(3):
        for d in range(4):
            hb.device_beat(d // 2, d, float(t))
        hb.node_beat(0, float(t))
        hb.node_beat(1, float(t))
    hb.kill_node(1)  # socket drops
    newly = hb.sweep(3.5)  # node 0 still fresh (last beat t=2)
    assert set(newly) >= {2, 3}
    assert 0 not in hb.failed_devices


# ------------------------------------------- workload-aware fail-slow filter
def _mk_detector(healthy_fn, validate_fn, *, filt=True):
    hb = HeartbeatMonitor()
    return Detector(healthy_time_fn=healthy_fn, validate_fn=validate_fn,
                    heartbeat=hb, workload_filter=filt,
                    changepoint_factory=lambda: CusumDetector(warmup=10))


def test_filter_suppresses_workload_spike():
    """A heavy-workload iteration spikes the series; the filter predicts the
    spike from the workload and skips validation (no false alarm)."""
    calls = []
    det = _mk_detector(lambda w: w, lambda it: calls.append(it) or [])
    rng = np.random.default_rng(3)
    for i in range(30):
        det.observe_iteration(i, 1.0 + 0.01 * rng.normal(), 1.0)
    # workload-driven spike: healthy time genuinely 1.4
    for i in range(30, 36):
        det.observe_iteration(i, 1.42 + 0.01 * rng.normal(), 1.42)
    assert det.stats.change_points >= 1
    assert det.stats.validations == 0
    assert det.stats.filtered_benign >= 1
    assert calls == []


def test_filter_passes_true_failslow():
    det = _mk_detector(lambda w: 1.0, lambda it: [(5, 0.5)])
    rng = np.random.default_rng(4)
    rep = None
    for i in range(30):
        r = det.observe_iteration(i, 1.0 + 0.01 * rng.normal(), 1.0)
    for i in range(30, 40):
        r = det.observe_iteration(i, 1.9 + 0.01 * rng.normal(), 1.0)
        rep = rep or r
    assert rep is not None and rep.kind == "fail-slow"
    assert rep.devices == ((5, 0.5),)
    assert det.stats.false_alarms == 0


def test_no_filter_pays_validation_like_greyhound():
    """Without the filter every change point pays the validation cost, and
    workload spikes become false alarms (Table 5's Greyhound column)."""
    det = _mk_detector(lambda w: w, lambda it: [], filt=False)
    rng = np.random.default_rng(5)
    for i in range(30):
        det.observe_iteration(i, 1.0 + 0.01 * rng.normal(), 1.0)
    for i in range(30, 36):
        det.observe_iteration(i, 1.42 + 0.01 * rng.normal(), 1.42)
    assert det.stats.validations >= 1
    assert det.stats.false_alarms >= 1
    assert det.overhead_s >= det.validation_cost_s


def test_failstop_report_via_heartbeat():
    det = _mk_detector(lambda w: 1.0, lambda it: [])
    det.heartbeat.register_node(0, [0, 1])
    for t in range(3):
        det.heartbeat.device_beat(0, 0, float(t))
        det.heartbeat.node_beat(0, float(t))
    rep = det.poll_failstop(6.0)
    assert rep is not None and rep.kind == "fail-stop" and 1 in rep.devices


def test_false_alarm_discards_changepoint_state():
    """Regression (satellite): the false-alarm branch popped the series but
    left the contaminated point in the change-point detector."""
    det = _mk_detector(lambda w: 1.0, lambda it: [])  # validation finds nothing
    for i in range(12):
        det.observe_iteration(i, 1.0, 1.0)
    s_before = det._cpd._s
    det.observe_iteration(12, 1.9, 1.0)  # spike -> validation -> false alarm
    assert det.stats.false_alarms == 1
    assert det._cpd._s == pytest.approx(s_before)  # state rewound
    assert len(det._series) == 12  # spike removed from the series


def test_heartbeat_revive_makes_second_failstop_detectable():
    """Regression (satellite): failed state was never cleared on rejoin, so
    the same device's second fail-stop was silently undetectable."""
    hb = HeartbeatMonitor(interval=1.0, miss_threshold=3)
    hb.register_node(0, [0, 1])
    for t in range(3):
        for d in (0, 1):
            hb.device_beat(0, d, float(t))
        hb.node_beat(0, float(t))
    # device 1 stops beating -> first fail-stop
    for t in range(3, 7):
        hb.device_beat(0, 0, float(t))
        hb.node_beat(0, float(t))
    assert hb.sweep(7.0) == [1]
    # repaired + revived: beats again, then dies AGAIN
    hb.revive(1, 8.0)
    assert 1 not in hb.failed_devices
    for t in range(8, 11):
        for d in (0, 1):
            hb.device_beat(0, d, float(t))
        hb.node_beat(0, float(t))
    for t in range(11, 16):
        hb.device_beat(0, 0, float(t))
        hb.node_beat(0, float(t))
    assert hb.sweep(15.0) == [1], "second fail-stop must be re-detected"


def test_heartbeat_revive_node_restores_channel():
    hb = HeartbeatMonitor(interval=1.0, miss_threshold=3)
    hb.register_node(0, [0, 1])
    hb.register_node(1, [2, 3])
    for t in range(3):
        for d in range(4):
            hb.device_beat(d // 2, d, float(t))
        hb.node_beat(0, float(t))
        hb.node_beat(1, float(t))
    hb.kill_node(1)
    assert set(hb.sweep(4.0)) == {2, 3}
    hb.revive(2, 5.0)  # device revive on a dead node revives the node too
    assert 1 not in hb.failed_nodes and hb.nodes[1].alive
    assert 2 not in hb.failed_devices
    assert 3 in hb.failed_devices  # its peer stays individually failed


def test_repeat_failstop_detected_twice_in_sim():
    """Regression (satellite): end-to-end — the same device fail-stops,
    rejoins and fail-stops again; both fail-stops must be *detected* (belief
    flips to 0 twice), which the never-cleared heartbeat state prevented."""
    from repro.cluster.scenarios import TransientFlap
    from repro.cluster.simulator import SimConfig, TrainingSim

    cfg = SimConfig(dp=2, pp=4, tp=4, n_layers=40, n_microbatches=8,
                    seq_len=8192, noise=0.01, seed=0)
    sim = TrainingSim("resihp", cfg,
                      policy_kwargs={"plan_overhead_fixed": 0.25})
    sim.apply_scenario(TransientFlap(device=5, at=10.0, n_flaps=2,
                                     down_time=6.0, up_time=15.0))
    sim.run(80, stop_on_abort=False)
    detections = [e[1] for r in sim.trace for e in r.events
                  if e[0] == "fail-stop-detected" and 5 in e[1]]
    assert len(detections) == 2, (
        f"expected both fail-stops of the flapping device detected, "
        f"got {len(detections)}")
