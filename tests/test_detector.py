"""Detector stack: Eq. 1 predictor, change-point detectors, heartbeat
hierarchy, and the workload-aware filter (the paper's Table 4/5 behaviour)."""
import numpy as np
import pytest

from repro.core.detector.changepoint import BOCPD, CusumDetector
from repro.core.detector.detector import Detector
from repro.core.detector.heartbeat import HeartbeatMonitor
from repro.core.detector.predictor import MicroBatchTimePredictor


# ----------------------------------------------------------- Eq.1 predictor
def test_predictor_recovers_coefficients():
    rng = np.random.default_rng(0)
    a, b, g = 2e-7, 1.5e-11, 5e-4
    pred = MicroBatchTimePredictor()
    for _ in range(32):
        n = int(rng.integers(2000, 8192))
        l2 = int(rng.integers(1e6, n * n))
        t = a * n + b * l2 + g
        pred.observe(n, l2, t * float(rng.normal(1.0, 0.01)))
    pred.fit()
    # MAPE on fresh samples ~ the paper's 1.2-1.6% (Table 4, MTP)
    samples = []
    for _ in range(64):
        n = int(rng.integers(2000, 8192))
        l2 = int(rng.integers(1e6, n * n))
        samples.append((n, l2, 1, a * n + b * l2 + g))
    assert pred.mape(samples) < 0.02


def test_predictor_backward_ratio():
    pred = MicroBatchTimePredictor(backward_ratio=2.0, weight_ratio=1.0)
    pred.alpha, pred.beta, pred.gamma, pred.fitted = 1e-6, 0.0, 0.0, True
    f = pred.predict(1000, 0, kind="F")
    assert pred.predict(1000, 0, kind="B") == pytest.approx(2 * f)
    assert pred.predict(1000, 0, kind="W") == pytest.approx(f)
    assert pred.predict(1000, 0, kind="F", speed=0.5) == pytest.approx(2 * f)


# ------------------------------------------------------------- change-point
@pytest.mark.parametrize("factory", [lambda: CusumDetector(warmup=10),
                                     lambda: BOCPD(warmup=10)])
def test_changepoint_detects_level_shift(factory):
    rng = np.random.default_rng(1)
    det = factory()
    fired_before = 0
    for i in range(40):
        if det.update(1.0 + 0.01 * rng.normal()):
            fired_before += 1
    fired_after = 0
    for i in range(15):
        if det.update(1.35 + 0.01 * rng.normal()):
            fired_after += 1
    assert fired_before == 0
    assert fired_after >= 1


def test_cusum_no_false_fire_on_noise():
    rng = np.random.default_rng(2)
    det = CusumDetector(warmup=10)
    fires = sum(det.update(1.0 + 0.02 * rng.normal()) for _ in range(300))
    assert fires == 0


# ---------------------------------------------------------------- heartbeat
def test_heartbeat_two_level():
    hb = HeartbeatMonitor(interval=1.0, miss_threshold=3)
    hb.register_node(0, [0, 1, 2, 3])
    hb.register_node(1, [4, 5, 6, 7])
    for t in range(5):
        for d in range(8):
            if d != 5:  # device 5 stops beating at t=0
                hb.device_beat(d // 4, d, float(t), t)
        hb.node_beat(0, float(t))
        hb.node_beat(1, float(t))
    newly = hb.sweep(5.0)
    assert newly == [5]
    assert hb.failed_devices == {5}
    # coordinator load scales with nodes (2), not devices (8)
    assert hb.n_messages_per_interval == 2


def test_heartbeat_node_crash_fails_all_devices():
    hb = HeartbeatMonitor(interval=1.0, miss_threshold=3)
    hb.register_node(0, [0, 1])
    hb.register_node(1, [2, 3])
    for t in range(3):
        for d in range(4):
            hb.device_beat(d // 2, d, float(t))
        hb.node_beat(0, float(t))
        hb.node_beat(1, float(t))
    hb.kill_node(1)  # socket drops
    newly = hb.sweep(3.5)  # node 0 still fresh (last beat t=2)
    assert set(newly) >= {2, 3}
    assert 0 not in hb.failed_devices


# ------------------------------------------- workload-aware fail-slow filter
def _mk_detector(healthy_fn, validate_fn, *, filt=True):
    hb = HeartbeatMonitor()
    return Detector(healthy_time_fn=healthy_fn, validate_fn=validate_fn,
                    heartbeat=hb, workload_filter=filt,
                    changepoint_factory=lambda: CusumDetector(warmup=10))


def test_filter_suppresses_workload_spike():
    """A heavy-workload iteration spikes the series; the filter predicts the
    spike from the workload and skips validation (no false alarm)."""
    calls = []
    det = _mk_detector(lambda w: w, lambda it: calls.append(it) or [])
    rng = np.random.default_rng(3)
    for i in range(30):
        det.observe_iteration(i, 1.0 + 0.01 * rng.normal(), 1.0)
    # workload-driven spike: healthy time genuinely 1.4
    for i in range(30, 36):
        det.observe_iteration(i, 1.42 + 0.01 * rng.normal(), 1.42)
    assert det.stats.change_points >= 1
    assert det.stats.validations == 0
    assert det.stats.filtered_benign >= 1
    assert calls == []


def test_filter_passes_true_failslow():
    det = _mk_detector(lambda w: 1.0, lambda it: [(5, 0.5)])
    rng = np.random.default_rng(4)
    rep = None
    for i in range(30):
        r = det.observe_iteration(i, 1.0 + 0.01 * rng.normal(), 1.0)
    for i in range(30, 40):
        r = det.observe_iteration(i, 1.9 + 0.01 * rng.normal(), 1.0)
        rep = rep or r
    assert rep is not None and rep.kind == "fail-slow"
    assert rep.devices == ((5, 0.5),)
    assert det.stats.false_alarms == 0


def test_no_filter_pays_validation_like_greyhound():
    """Without the filter every change point pays the validation cost, and
    workload spikes become false alarms (Table 5's Greyhound column)."""
    det = _mk_detector(lambda w: w, lambda it: [], filt=False)
    rng = np.random.default_rng(5)
    for i in range(30):
        det.observe_iteration(i, 1.0 + 0.01 * rng.normal(), 1.0)
    for i in range(30, 36):
        det.observe_iteration(i, 1.42 + 0.01 * rng.normal(), 1.42)
    assert det.stats.validations >= 1
    assert det.stats.false_alarms >= 1
    assert det.overhead_s >= det.validation_cost_s


def test_failstop_report_via_heartbeat():
    det = _mk_detector(lambda w: 1.0, lambda it: [])
    det.heartbeat.register_node(0, [0, 1])
    for t in range(3):
        det.heartbeat.device_beat(0, 0, float(t))
        det.heartbeat.node_beat(0, float(t))
    rep = det.poll_failstop(6.0)
    assert rep is not None and rep.kind == "fail-stop" and 1 in rep.devices
