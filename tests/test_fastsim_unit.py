"""Direct unit tests for the fast engine's event-loop core.

The scenario-level parity suite (``test_engine_parity.py``) pins the two
engines against each other through the full simulator; the tests here go
one level down and exercise the ``FastMigrator`` internals the batched
refactor leans on:

* the shared same-timestamp epsilon (``SAME_TIME_EPS``) at an actual
  collision boundary,
* the livelock budget guard's diagnostic payload,
* ``_ready_time`` cross-replica migrate-edge costing (and the ready-memo
  invalidation that keeps the memoized fast path honest),
* ``_next_pending`` cursor monotonicity,
* a ``vec_batch_min=1`` sweep that forces every dispatch round down the
  vectorized array path and demands bit-for-bit identity with the python
  reference engine.
"""
import pytest

import repro.cluster.fastsim as fastsim
from repro.cluster.fastsim import FastMigrator
from repro.core.scheduler import migration
from repro.core.scheduler.migration import SAME_TIME_EPS, ProgressAwareMigrator


def _cost(cid, e):
    return {"F": 1.0, "B": 2.0, "W": 0.5}[cid.kind]


def _result_tuple(res):
    """Everything observable in a SimResult, exactly."""
    return (res.makespan, res.status, sorted(res.finish.items(), key=str),
            [(m.time, m.chunk, m.src, m.dst, m.reason)
             for m in res.migrations],
            sorted(res.per_replica_finish.items()))


# ------------------------------------------------------ same-time epsilon
def test_same_time_eps_is_the_shared_constant():
    # one constant, defined by the reference engine, imported by the fast
    # engine — not two numbers that happen to agree today
    assert fastsim.SAME_TIME_EPS is migration.SAME_TIME_EPS


@pytest.mark.parametrize("vec_min", [None, 1])
def test_parity_at_timestamp_collision_boundary(vec_min):
    """Zero-noise symmetric replicas put whole waves of completions at
    *identical* timestamps, and a straggler offset below SAME_TIME_EPS keeps
    them inside one drain batch: the batched engine must group and commit
    exactly like the reference."""
    sub_eps = 1.0 + SAME_TIME_EPS / 4  # collides within the epsilon window

    def cost(cid, e):
        base = _cost(cid, e)
        if e == (1, 1):
            base *= sub_eps  # straggler whose events land on the boundary
        if e == (0, 2):
            base *= 3.0  # a real fail-slow so migrations happen too
        return base

    kw = dict(n_stages=4, n_replicas=2, n_microbatches=6, chunk_cost=cost,
              policy="resihp", delta=1, p2p_cost=0.05,
              migrate_edge_cost=0.2)
    ref = ProgressAwareMigrator(**kw).run()
    fast_kw = dict(kw)
    if vec_min is not None:
        fast_kw["vec_batch_min"] = vec_min
    fast = FastMigrator(**fast_kw).run()
    assert _result_tuple(fast) == _result_tuple(ref)
    assert ref.migrations  # the scenario actually migrated


# --------------------------------------------------------- livelock guard
@pytest.mark.parametrize("cls", [ProgressAwareMigrator, FastMigrator])
def test_event_budget_guard_reports_state(cls):
    m = cls(n_stages=3, n_replicas=2, n_microbatches=4, chunk_cost=_cost,
            policy="resihp", event_budget=5)
    with pytest.raises(RuntimeError) as err:
        m.run()
    msg = str(err.value)
    assert "t=" in msg
    assert "heap_size=" in msg
    assert "undone_chunks=" in msg
    assert "budget=5" in msg


# ------------------------------------------- ready-time migrate-edge cost
def test_ready_time_charges_cross_replica_migrate_edge():
    m = FastMigrator(n_stages=2, n_replicas=2, n_microbatches=2,
                     chunk_cost=_cost, policy="resihp",
                     p2p_cost=0.25, migrate_edge_cost=0.75)
    st = m.st
    # an F chunk on stage 1: its single dep is F on stage 0, same replica —
    # a cross-stage edge, so at home it costs exactly the p2p charge
    i = next(j for j in range(st.n_chunks)
             if st.kind[j] == 0 and st.stage[j] == 1 and st.replica[j] == 0
             and st.mb[j] == 0)
    (d, crosses), = st.deps[i]
    assert crosses and st.stage[d] == 0
    assert m._ready_time(i) is None  # dep unfinished -> no ready time yet
    m.finish[d] = 5.0
    assert m._ready_time(i) == pytest.approx(5.0 + 0.25)

    # migrate i to the other replica's stage-1 executor: the dep edge now
    # also crosses replicas, so the migrate-edge charge stacks on the p2p
    dst = 1 * m.n_stages + 1
    m._migrate(i, dst, 0.0, "test", set())
    assert m.exec_of[i] == dst
    assert m._ready_time(i) == pytest.approx(5.0 + 0.25 + 0.75)
    # the ready memo for the moved group was invalidated with the refresh
    assert m._ready_memo[i] is None


# ------------------------------------------------ pending-cursor monotone
def test_next_pending_cursor_is_monotone():
    m = FastMigrator(n_stages=2, n_replicas=2, n_microbatches=4,
                     chunk_cost=_cost, policy="resihp")
    st = m.st
    e = 0  # executor (replica 0, stage 0)
    seen = []
    cursors = [m.pend_cursor[e]]
    for _ in range(m.n_mb[0]):
        j = m._next_pending(0, 0)
        assert j is not None and st.kind[j] == 0
        seen.append(j)
        # consuming the chunk (started or migrated) must advance, never
        # rewind, the scan cursor
        m.started[j] = True
        cursors.append(m.pend_cursor[e])
    assert m._next_pending(0, 0) is None
    cursors.append(m.pend_cursor[e])
    assert cursors == sorted(cursors)
    assert len(set(seen)) == len(seen)  # each F chunk surfaced exactly once
    # micro-batches surface in schedule order
    assert [st.mb[j] for j in seen] == sorted(st.mb[j] for j in seen)


# ------------------------------------------- forced-vector-path parity
@pytest.mark.parametrize("n_mb", [4, [3, 5, 4]])
def test_vec_batch_min_one_forces_array_path_parity(n_mb):
    """With ``vec_batch_min=1`` every dispatch round takes the batched
    build/ready/select/commit path (journal flush included); the result must
    stay bit-for-bit the reference's, including under nonuniform per-replica
    micro-batch counts and a fail-stop."""
    n_replicas = 3 if isinstance(n_mb, list) else 2

    def cost(cid, e):
        return _cost(cid, e) * (2.5 if e == (0, 1) else 1.0)

    kw = dict(n_stages=3, n_replicas=n_replicas, n_microbatches=n_mb,
              chunk_cost=cost, policy="resihp", delta=0,
              dead_executors=[(0, 2)], p2p_cost=0.1, migrate_edge_cost=0.3)
    ref = ProgressAwareMigrator(**kw).run()
    forced = FastMigrator(vec_batch_min=1, **kw).run()
    default = FastMigrator(**kw).run()
    assert _result_tuple(forced) == _result_tuple(ref)
    assert _result_tuple(default) == _result_tuple(ref)
    assert ref.status == "ok" and ref.migrations
