"""Vendored fallback for the tiny slice of `hypothesis` the suite uses.

If the real hypothesis is installed we re-export it verbatim. Otherwise the
shim below provides ``given`` / ``settings`` / ``strategies`` over seeded
numpy draws: each decorated test runs ``max_examples`` deterministic examples
(seed derived from the test's qualified name and the example index), so runs
are reproducible without the dependency.

Usage in test modules::

    from _ht import given, settings, strategies as st
"""
from __future__ import annotations

try:  # pragma: no cover - exercised only where hypothesis exists
    from hypothesis import given, settings, strategies  # noqa: F401

    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

    import functools
    import inspect
    import zlib

    import numpy as np

    DEFAULT_MAX_EXAMPLES = 25

    class SearchStrategy:
        def __init__(self, draw):
            self._draw = draw

        def example(self, rng: np.random.Generator):
            return self._draw(rng)

        def map(self, f):
            return SearchStrategy(lambda rng: f(self._draw(rng)))

        def filter(self, pred, *, tries: int = 100):
            def draw(rng):
                for _ in range(tries):
                    x = self._draw(rng)
                    if pred(x):
                        return x
                raise ValueError("filter predicate never satisfied")
            return SearchStrategy(draw)

    class _Strategies:
        @staticmethod
        def integers(min_value: int, max_value: int) -> SearchStrategy:
            return SearchStrategy(
                lambda rng: int(rng.integers(min_value, max_value + 1)))

        @staticmethod
        def floats(min_value: float, max_value: float) -> SearchStrategy:
            # hypothesis includes the endpoints; make them reachable
            def draw(rng):
                u = float(rng.uniform(min_value, max_value))
                edge = rng.integers(0, 10)
                if edge == 0:
                    return float(min_value)
                if edge == 1:
                    return float(max_value)
                return u
            return SearchStrategy(draw)

        @staticmethod
        def booleans() -> SearchStrategy:
            return SearchStrategy(lambda rng: bool(rng.integers(0, 2)))

        @staticmethod
        def sampled_from(options) -> SearchStrategy:
            opts = list(options)
            return SearchStrategy(
                lambda rng: opts[int(rng.integers(0, len(opts)))])

        @staticmethod
        def lists(elements: SearchStrategy, *, min_size: int = 0,
                  max_size: int = 10) -> SearchStrategy:
            def draw(rng):
                n = int(rng.integers(min_size, max_size + 1))
                return [elements.example(rng) for _ in range(n)]
            return SearchStrategy(draw)

    strategies = _Strategies()

    def settings(*, max_examples: int = DEFAULT_MAX_EXAMPLES, deadline=None,
                 **_ignored):
        def deco(fn):
            fn._ht_max_examples = max_examples
            return fn
        return deco

    def given(*arg_strategies, **kw_strategies):
        def deco(fn):
            params = [p for p in inspect.signature(fn).parameters]
            bound = dict(zip(params, arg_strategies))
            overlap = set(bound) & set(kw_strategies)
            if overlap:
                raise TypeError(f"strategy given twice for {sorted(overlap)}")
            bound.update(kw_strategies)

            @functools.wraps(fn)
            def wrapper():
                n = getattr(wrapper, "_ht_max_examples", DEFAULT_MAX_EXAMPLES)
                base = zlib.crc32(fn.__qualname__.encode())
                for i in range(n):
                    rng = np.random.default_rng([base, i])
                    kwargs = {k: s.example(rng) for k, s in bound.items()}
                    try:
                        fn(**kwargs)
                    except Exception as e:  # noqa: BLE001 - falsify report
                        raise AssertionError(
                            f"falsifying example #{i}: {fn.__name__}"
                            f"({', '.join(f'{k}={v!r}' for k, v in kwargs.items())})"
                        ) from e

            # hide the strategy params from pytest's fixture resolution
            wrapper.__signature__ = inspect.Signature()
            del wrapper.__wrapped__
            return wrapper
        return deco
