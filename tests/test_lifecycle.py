"""Failure-lifecycle subsystem (flap quarantine, ramp-aware drift detection,
rejoin admission) — the ISSUE-3 acceptance criteria plus unit coverage for
the LifecycleManager state machine.

System-level tests run the benchmark-scale config (iterations ~0.8 s of
simulated time, so heartbeat windows and scenario spans line up the way the
bench sweeps use them) with a fixed seed: every assertion is deterministic.
"""
import pytest

from repro.cluster import scenarios
from repro.cluster.scenarios import FailStop, Rejoin, TransientFlap
from repro.cluster.simulator import SimConfig, TrainingSim
from repro.core.detector.lifecycle import (
    HEALTHY,
    QUARANTINED,
    READMITTED,
    SUSPECT,
    LifecycleConfig,
    LifecycleManager,
)

CFG = SimConfig(dp=2, pp=4, tp=4, n_layers=40, n_microbatches=8,
                seq_len=8192, noise=0.01, seed=0)
BASE_KW = {"plan_overhead_fixed": 0.25}


def _run(policy_kwargs, scenario, iters=200):
    sim = TrainingSim("resihp", CFG,
                      policy_kwargs={**BASE_KW, **policy_kwargs})
    sim.apply_scenario(scenario)
    sim.run(iters, stop_on_abort=False)
    return sim


# ------------------------------------------------------------ flap quarantine
def test_flapping_needs_at_least_2x_fewer_validations():
    """Acceptance: with the lifecycle enabled, flapping stragglers cost at
    most half the validation passes of baseline ResiHP (quarantine keeps the
    flappers out of the plan; the debounce drops pre-detection stall alarms),
    while the persistent straggler is still detected."""
    span = 200.0
    base = _run({}, scenarios.get("flapping_stragglers", span=span))
    lc = _run({"lifecycle": True},
              scenarios.get("flapping_stragglers", span=span))
    assert base.detector.stats.validations >= 2
    assert 2 * lc.detector.stats.validations <= base.detector.stats.validations
    # device 7 (the persistent 0.55x straggler) is still caught
    slow = [d for r in lc.detector.reports if r.kind == "fail-slow"
            for d, _ in r.devices]
    assert 7 in slow
    assert lc.lifecycle.stats.quarantines >= 1


def test_quarantine_excludes_flapper_from_plans():
    """While quarantined, a physically-alive device stays out of the plan
    (belief failed, no replanning around it)."""
    flap = TransientFlap(device=3, at=10.0, n_flaps=3, down_time=5.0,
                         up_time=12.0)
    sim = TrainingSim("resihp", CFG, policy_kwargs={
        **BASE_KW, "lifecycle": LifecycleConfig(flap_threshold=2)})
    sim.apply_scenario(flap)
    saw_quarantined_alive = False
    for _ in range(120):
        sim.step()
        if (sim.lifecycle.is_quarantined(3, sim.now)
                and sim.cluster.devices[3].alive):
            saw_quarantined_alive = True
            assert sim.known_speeds[3] == 0.0  # belief stays failed
            assert 3 not in sim._decision.plan.devices
    assert saw_quarantined_alive
    assert sim.lifecycle.stats.quarantines >= 1


# --------------------------------------------------------- ramp-aware drift
def test_slow_ramps_detected_before_ramp_completion():
    """Acceptance: with the lifecycle drift policy, every slow_ramp_mix ramp
    is reported before its ramp completes; baseline ResiHP only catches the
    first ramp long after completion."""
    span = 200.0
    # slow_ramp_mix timeline (see scenarios.py): device -> (at, ramp) * span
    ramps = {2: (0.10 * span, 0.15 * span),
             9: (0.35 * span, 0.20 * span),
             14: (0.65 * span, 0.10 * span)}
    lc = _run({"lifecycle": True}, scenarios.get("slow_ramp_mix", span=span))
    first_report = {}
    for r in lc.detector.reports:
        if r.kind != "fail-slow":
            continue
        for d, _ in r.devices:
            first_report.setdefault(d, r.time)
    for dev, (at, ramp) in ramps.items():
        assert dev in first_report, f"ramping device {dev} never detected"
        assert first_report[dev] < at + ramp, (
            f"device {dev} detected at {first_report[dev]:.1f}s, "
            f"after ramp completion {at + ramp:.1f}s")
    assert lc.detector.stats.drift_alarms >= 1
    assert lc.detector.stats.carried_rebaselines >= 1

    base = _run({}, scenarios.get("slow_ramp_mix", span=span))
    base_first = {}
    for r in base.detector.reports:
        if r.kind == "fail-slow":
            for d, _ in r.devices:
                base_first.setdefault(d, r.time)
    at2, ramp2 = ramps[2]
    assert base_first.get(2, float("inf")) > at2 + ramp2  # the paper gap


# --------------------------------------------------------- rejoin admission
def test_rejoin_admission_enters_belief_at_measured_speed():
    """A device that comes back at 60% speed enters beliefs at 60% with the
    admission probe — and at the wrong 1.0 without it (the paper gap)."""
    scen = FailStop(at=5.0, device=3) + Rejoin(device=3, at=15.0, speed=0.6)
    beliefs = {}
    for label, kw in (("lc", {"lifecycle": True}), ("base", {})):
        sim = TrainingSim("resihp", CFG, policy_kwargs={**BASE_KW, **kw})
        sim.apply_scenario(scen)
        while not any(ev.kind == "rejoin" for ev in sim.event_log):
            sim.step()
        beliefs[label] = sim.known_speeds[3]
        assert sim.cluster.devices[3].effective == pytest.approx(0.6)
    assert beliefs["lc"] == pytest.approx(0.6)
    assert beliefs["base"] == 1.0


def test_admission_probe_charges_time_and_counts():
    sim = TrainingSim("resihp", CFG, policy_kwargs={**BASE_KW,
                                                    "lifecycle": True})
    sim.apply_scenario(FailStop(at=5.0, device=3)
                       + Rejoin(device=3, at=15.0, speed=0.6))
    sim.run(40, stop_on_abort=False)
    assert sim.lifecycle.stats.probes >= 1
    assert sim.lifecycle.stats.degraded_admissions >= 1
    assert sim.lifecycle.histories[3].state == READMITTED


# ------------------------------------------------- LifecycleManager unit
def test_manager_quarantine_backoff_doubles():
    speeds = {5: 0.9}  # comes back degraded: backoff level is retained
    cfg = LifecycleConfig(flap_threshold=2, backoff_base_s=30.0,
                          backoff_factor=2.0, probe_cost_s=0.25)
    mgr = LifecycleManager(cfg=cfg, probe_fn=lambda d: speeds[d])
    mgr.record_failstop(5, 10.0)
    assert mgr.history(5).state == SUSPECT
    dec = mgr.on_rejoin(5, 12.0)
    assert dec.admit and dec.speed == 0.9  # one fail-stop: not yet a flapper
    mgr.record_failstop(5, 20.0)
    dec = mgr.on_rejoin(5, 22.0)  # second recent fail-stop: quarantine
    assert not dec.admit and dec.state == QUARANTINED
    assert dec.until == pytest.approx(22.0 + 30.0)
    # bouncing back mid-quarantine is absorbed, not re-planned
    dec2 = mgr.on_rejoin(5, 30.0)
    assert not dec2.admit
    assert mgr.stats.rejoins_deferred == 1
    assert mgr.quarantined(30.0) == frozenset({5})
    # release probe finds it up (degraded) -> readmitted at measured speed
    assert mgr.poll_releases(40.0) == []  # still serving quarantine
    rel = mgr.poll_releases(53.0)
    assert len(rel) == 1 and rel[0].admit and rel[0].speed == 0.9
    assert mgr.history(5).state == READMITTED
    # a second quarantine doubles the backoff (degraded readmit kept level 1)
    mgr.record_failstop(5, 60.0)
    mgr.record_failstop(5, 70.0)
    dec3 = mgr.on_rejoin(5, 72.0)
    assert not dec3.admit
    assert dec3.until == pytest.approx(72.0 + 60.0)  # level 2: base * factor


def test_manager_clean_readmit_resets_backoff():
    """A full-speed readmission after serving quarantine resets the backoff
    level: a device that flaps again hours later starts at the base backoff,
    not the escalated one."""
    speeds = {5: 1.0}
    cfg = LifecycleConfig(flap_threshold=2, backoff_base_s=30.0,
                          backoff_factor=2.0)
    mgr = LifecycleManager(cfg=cfg, probe_fn=lambda d: speeds[d])
    mgr.record_failstop(5, 10.0)
    mgr.on_rejoin(5, 12.0)
    mgr.record_failstop(5, 20.0)
    assert not mgr.on_rejoin(5, 22.0).admit  # quarantine #1, 30 s
    rel = mgr.poll_releases(53.0)
    assert rel[0].admit and rel[0].speed == 1.0
    assert mgr.history(5).quarantine_level == 0
    # new flap sequence much later: backoff starts over at the base
    mgr.record_failstop(5, 500.0)
    mgr.record_failstop(5, 510.0)
    dec = mgr.on_rejoin(5, 512.0)
    assert not dec.admit
    assert dec.until == pytest.approx(512.0 + 30.0)


def test_manager_release_probe_extends_quarantine_for_dead_device():
    speeds = {5: 0.0}
    cfg = LifecycleConfig(flap_threshold=1, backoff_base_s=10.0)
    mgr = LifecycleManager(cfg=cfg, probe_fn=lambda d: speeds[d])
    mgr.record_failstop(5, 0.0)
    dec = mgr.on_rejoin(5, 1.0)
    assert not dec.admit  # flap_threshold=1: first rejoin quarantines
    rel = mgr.poll_releases(12.0)  # probe measures 0.0 -> still down
    assert len(rel) == 1 and not rel[0].admit
    assert mgr.history(5).state == QUARANTINED
    assert mgr.history(5).quarantine_until > 12.0
    speeds[5] = 0.8
    rel = mgr.poll_releases(40.0)
    assert len(rel) == 1 and rel[0].admit
    assert rel[0].speed == pytest.approx(0.8)


def test_manager_healthy_device_untracked():
    mgr = LifecycleManager(probe_fn=lambda d: 1.0)
    assert mgr.quarantined(0.0) == frozenset()
    assert not mgr.is_quarantined(3, 0.0)
    assert mgr.history(3).state == HEALTHY


# ------------------------------------------------------------- determinism
def test_lifecycle_engine_parity():
    """The lifecycle is engine-independent: python vs fast (which also
    exercises fastsim.StageSpeedCache) must agree bit-for-bit with it on."""
    streams = []
    for engine in ("python", "fast"):
        sim = TrainingSim("resihp", CFG, engine=engine,
                          policy_kwargs={**BASE_KW, "lifecycle": True})
        sim.apply_scenario(scenarios.get("flapping_stragglers", span=100.0))
        sim.run(80, stop_on_abort=False)
        streams.append(([(r.iteration, r.t_start, r.duration, r.throughput)
                         for r in sim.trace],
                        sim.detector.stats.as_dict(),
                        sim.lifecycle.stats.as_dict()))
    assert streams[0] == streams[1]


def test_lifecycle_run_is_deterministic():
    span = 120.0
    runs = [_run({"lifecycle": True},
                 scenarios.get("flapping_stragglers", span=span), iters=80)
            for _ in range(2)]
    a, b = runs
    assert [r.duration for r in a.trace] == [r.duration for r in b.trace]
    assert a.detector.stats.as_dict() == b.detector.stats.as_dict()
    assert a.lifecycle.stats.as_dict() == b.lifecycle.stats.as_dict()
