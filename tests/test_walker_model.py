"""TPU-backend measurement-model tests for the HLO walker: LICM hoisting,
weights-stationary scans, and dtype-glue discounts on real compiled graphs."""
import jax
import jax.numpy as jnp
import pytest

from repro.roofline.hlo import analyze_hlo_text


def _compile(fn, *args):
    return jax.jit(fn).lower(*args).compile()


def test_weights_stationary_scan():
    """A scanned x @ W with loop-invariant W: the walker must charge W's
    bytes ~once, not x trip count (VMEM-resident weight)."""
    x = jax.ShapeDtypeStruct((128, 512), jnp.float32)
    w = jax.ShapeDtypeStruct((512, 512), jnp.float32)  # 1 MB, loop-invariant

    def scanned(x, w):
        def body(c, _):
            return jnp.tanh(c @ w), ()
        out, _ = jax.lax.scan(body, x, None, length=32)
        return out

    compiled = _compile(scanned, x, w)
    cost = analyze_hlo_text(compiled.as_text())
    w_bytes = 512 * 512 * 4
    x_bytes = 128 * 512 * 4
    # x (in+out) charged every step; w charged ~once. Without the
    # stationary credit the dot charge would include 32 * w_bytes.
    assert cost.matmul_flops == pytest.approx(32 * 2 * 128 * 512 * 512, rel=0.05)
    assert cost.hbm_bytes < 32 * (2 * x_bytes) + 4 * w_bytes + 32 * x_bytes
    assert cost.licm_credit >= 25 * w_bytes


def test_unrolled_chain_not_overcredited():
    """No while loop -> no LICM/stationary credits; flops still exact."""
    x = jax.ShapeDtypeStruct((64, 64), jnp.float32)
    w = jax.ShapeDtypeStruct((64, 64), jnp.float32)

    def chain(x, w):
        for _ in range(3):
            x = x @ w
        return x

    cost = analyze_hlo_text(_compile(chain, x, w).as_text())
    assert cost.licm_credit == 0.0
    assert cost.matmul_flops == pytest.approx(3 * 2 * 64**3, rel=0.01)


def test_dtype_glue_discount():
    """bf16 matmul on CPU: promoted to f32 with convert fusions around the
    dot; the walker must not charge the f32 copies (TPU MXU eats bf16)."""
    x = jax.ShapeDtypeStruct((256, 1024), jnp.bfloat16)
    w = jax.ShapeDtypeStruct((1024, 1024), jnp.bfloat16)

    cost = analyze_hlo_text(_compile(lambda x, w: x @ w, x, w).as_text())
    bf16_io = (256 * 1024 + 1024 * 1024 + 256 * 1024) * 2
    # naive CPU accounting would be ~3-4x (f32 copies of all operands)
    assert cost.hbm_bytes <= 2.6 * bf16_io


def test_scan_carried_state_vmem_resident():
    """Small loop-carried state (an accumulator) should not be charged as
    HBM round-trips every iteration."""
    xs = jax.ShapeDtypeStruct((64, 128, 128), jnp.float32)

    def scanned(xs):
        def body(acc, x):
            return acc + x @ x, ()
        out, _ = jax.lax.scan(body, jnp.zeros((128, 128), jnp.float32), xs)
        return out

    cost = analyze_hlo_text(_compile(scanned, xs).as_text())
    state_bytes = 128 * 128 * 4
    xs_bytes = 64 * state_bytes
    # per-step xs slices are real traffic (dot in+out, slice reads ~5x xs);
    # but the accumulator round-trips must be credited, not charged x64
    assert cost.licm_credit >= 50 * 2 * state_bytes
    assert cost.hbm_bytes < xs_bytes * 5 + 10 * state_bytes


def test_scope_attribution():
    """jax.named_scope markers survive into hbm_by_scope."""
    x = jax.ShapeDtypeStruct((128, 128), jnp.float32)

    def f(x):
        with jax.named_scope("attn_core"):
            y = x @ x
        return y + 1.0

    cost = analyze_hlo_text(_compile(f, x).as_text())
    assert any("attn_core" in s for s in cost.hbm_by_scope), cost.hbm_by_scope
