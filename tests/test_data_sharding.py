"""Data pipeline (packing invariants, determinism) + sharding-rules engine."""
import numpy as np
import pytest
from _ht import given, settings, strategies as st

from repro.data.packing import pack_documents, pack_stats, row_to_arrays
from repro.data.synth import SyntheticPackedDataset
from repro.configs import get_arch, reduced
from repro.parallel.sharding import NULL_POLICY, ShardingPolicy


# ------------------------------------------------------------------ packing
@settings(max_examples=50, deadline=None)
@given(st.lists(st.integers(1, 9000), min_size=1, max_size=60),
       st.integers(64, 4096))
def test_packing_conserves_tokens(doc_lengths, seq_len):
    rows = pack_documents(doc_lengths, seq_len)
    assert sum(sum(r) for r in rows) == sum(doc_lengths)
    for r in rows:
        assert sum(r) <= seq_len
        assert all(l >= 1 for l in r)


def test_pack_stats_matches_rows():
    rng = np.random.default_rng(0)
    row = [100, 50, 30]
    tokens, seg, pos, labels = row_to_arrays(row, 256, rng, 1000)
    (n, l2), = pack_stats(seg[None])
    assert n == 180
    assert l2 == 100**2 + 50**2 + 30**2


def test_labels_never_cross_documents():
    rng = np.random.default_rng(0)
    tokens, seg, pos, labels = row_to_arrays([64, 64], 128, rng, 1000)
    assert labels[63] == -1  # document boundary
    assert labels[127] == -1  # row end
    assert (labels[seg == 0] == -1).all()


def test_dataset_deterministic_and_resumable():
    cfg = reduced(get_arch("qwen3-8b"))
    ds1 = SyntheticPackedDataset(cfg, 64, 4, seed=7)
    ds2 = SyntheticPackedDataset(cfg, 64, 4, seed=7)
    b1, b2 = ds1.batch_at(5), ds2.batch_at(5)
    for k in b1:
        np.testing.assert_array_equal(b1[k], b2[k])
    # resume from saved state reproduces the stream
    next(ds1); next(ds1)
    state = ds1.state()
    a = next(ds1)
    ds2.restore(state)
    b = next(ds2)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])


def test_sum_l2_varies_across_batches():
    """The paper's §2.2 premise: packed batches vary in sum(l^2)."""
    cfg = reduced(get_arch("qwen3-8b"))
    ds = SyntheticPackedDataset(cfg, 512, 8, seed=0)
    l2s = []
    for i in range(10):
        b = ds.batch_at(i)
        stats = pack_stats(b["segment_ids"])
        l2s.append(sum(s[1] for s in stats))
    assert max(l2s) / min(l2s) > 1.05


# ----------------------------------------------------------------- sharding
def _mesh2(shape=(2, 2)):
    import jax

    if len(jax.devices()) < shape[0] * shape[1]:
        pytest.skip("needs multiple devices")
    return jax.make_mesh(shape, ("data", "model"))


def test_null_policy_noop():
    import jax.numpy as jnp

    x = jnp.zeros((4, 8))
    assert NULL_POLICY.constrain(x, "batch", "seq") is x
    assert NULL_POLICY.tp == 1 and NULL_POLICY.dp == 1


def test_spec_divisibility_fallback():
    """Logical dims not divisible by the mesh axis stay unsharded."""
    import jax
    from jax.sharding import PartitionSpec as P

    class FakeMesh:
        shape = {"data": 2, "model": 4}
        axis_names = ("data", "model")

    pol = ShardingPolicy(mesh=FakeMesh(), dp_axes=("data",), tp_axis="model")
    # ffn divisible -> model (TP); dmodel -> data (FSDP); indivisible -> None
    assert pol.spec_for(("dmodel", "ffn"), (8, 12)) == P("data", "model")
    assert pol.spec_for(("dmodel", "ffn"), (8, 10)) == P("data", None)
    assert pol.spec_for(("dmodel", "ffn"), (7, 10)) == P(None, None)
    # heads sharding respects attn_shard choice
    assert pol.spec_for(("heads", "head_dim"), (8, 64)) == P("model", None)
    pol2 = pol.replace(attn_shard="head_dim")
    assert pol2.spec_for(("heads", "head_dim"), (8, 64)) == P(None, "model")
    # fsdp: dmodel gets the data axis on params when free
    assert pol.spec_for(("vocab", "dmodel"), (512, 8)) == P("model", "data")


def test_no_axis_double_booking():
    from jax.sharding import PartitionSpec as P

    class FakeMesh:
        shape = {"data": 2, "model": 2}
        axis_names = ("data", "model")

    pol = ShardingPolicy(mesh=FakeMesh(), dp_axes=("data",), tp_axis="model")
    spec = pol.spec_for(("batch", "seq", "heads", "head_dim"), (4, 128, 8, 64))
    used = [e for e in spec if e is not None]
    flat = []
    for e in used:
        flat.extend(e if isinstance(e, tuple) else (e,))
    assert len(flat) == len(set(flat))
