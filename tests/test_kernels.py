"""Pallas packed flash attention vs the pure-jnp oracle (interpret mode):
shape/dtype sweeps, GQA ratios, windows, property-based packing layouts."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _ht import given, settings, strategies as st

from repro.kernels.ops import packed_attention
from repro.kernels.packed_flash_attn import block_metadata, skipped_block_fraction
from repro.kernels.ref import packed_attention_ref

from conftest import make_packed


def _qkv(rng, B, S, H, K, dh, dtype):
    q = jnp.asarray(rng.normal(size=(B, S, H, dh)), dtype)
    k = jnp.asarray(rng.normal(size=(B, S, K, dh)), dtype)
    v = jnp.asarray(rng.normal(size=(B, S, K, dh)), dtype)
    return q, k, v


TOL = {jnp.float32: 2e-5, jnp.bfloat16: 2e-2}


@pytest.mark.parametrize("S,H,K,dh,bq,bk", [
    (128, 4, 4, 32, 64, 64),    # MHA
    (128, 4, 2, 32, 64, 64),    # GQA 2:1
    (256, 8, 1, 16, 128, 128),  # MQA
    (192, 4, 4, 64, 64, 64),    # non-power-of-two block count + padding
    (128, 4, 4, 32, 32, 64),    # bq != bk
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_kernel_matches_ref(rng, S, H, K, dh, bq, bk, dtype):
    B = 2
    q, k, v = _qkv(rng, B, S, H, K, dh, dtype)
    seg, pos = make_packed(rng, B, S)
    seg, pos = jnp.asarray(seg), jnp.asarray(pos)
    out = packed_attention(q, k, v, seg, seg, pos, pos, causal=True,
                           block_q=bq, block_k=bk, interpret=True)
    ref = packed_attention_ref(q, k, v, seg, seg, pos, pos, causal=True)
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref, np.float32),
        atol=TOL[dtype], rtol=TOL[dtype])


@pytest.mark.parametrize("window", [16, 64, None])
def test_kernel_window(rng, window):
    B, S, H, K, dh = 1, 128, 2, 2, 32
    q, k, v = _qkv(rng, B, S, H, K, dh, jnp.float32)
    seg, pos = make_packed(rng, B, S, doc_lens=[S])
    seg, pos = jnp.asarray(seg), jnp.asarray(pos)
    out = packed_attention(q, k, v, seg, seg, pos, pos, causal=True,
                           window=window, block_q=32, block_k=32, interpret=True)
    ref = packed_attention_ref(q, k, v, seg, seg, pos, pos, causal=True,
                               window=window)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5, rtol=2e-5)


def test_kernel_padding_rows_zero(rng):
    """Rows with segment id 0 (padding) must return exactly 0."""
    B, S, H, dh = 1, 64, 2, 16
    q, k, v = _qkv(rng, B, S, H, H, dh, jnp.float32)
    seg = np.zeros((B, S), np.int32)
    seg[:, :40] = 1
    pos = np.arange(S, dtype=np.int32)[None] * (seg > 0)
    out = packed_attention(q, k, v, jnp.asarray(seg), jnp.asarray(seg),
                           jnp.asarray(pos), jnp.asarray(pos),
                           causal=True, block_q=32, block_k=32, interpret=True)
    assert bool(jnp.all(out[:, 40:] == 0))


@settings(max_examples=12, deadline=None)
@given(
    doc_split=st.lists(st.integers(8, 64), min_size=1, max_size=5),
    hk=st.sampled_from([(4, 4), (4, 2), (8, 1)]),
)
def test_kernel_property_random_packing(doc_split, hk):
    H, K = hk
    rng = np.random.default_rng(sum(doc_split))
    S = 128
    q = jnp.asarray(rng.normal(size=(1, S, H, 16)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(1, S, K, 16)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(1, S, K, 16)), jnp.float32)
    seg, pos = make_packed(rng, 1, S, doc_lens=doc_split)
    seg, pos = jnp.asarray(seg), jnp.asarray(pos)
    out = packed_attention(q, k, v, seg, seg, pos, pos, causal=True,
                           block_q=32, block_k=32, interpret=True)
    ref = packed_attention_ref(q, k, v, seg, seg, pos, pos, causal=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=3e-5, rtol=3e-5)


def test_block_skipping_reflects_sum_l2(rng):
    """More, shorter documents => more skipped tiles (the sum l_i^2 effect)."""
    S = 512
    seg1, pos1 = make_packed(rng, 1, S, doc_lens=[S])  # one long doc
    seg4, pos4 = make_packed(rng, 1, S, doc_lens=[S // 4] * 4)
    f1 = skipped_block_fraction(jnp.asarray(seg1), jnp.asarray(pos1), 64, 64)
    f4 = skipped_block_fraction(jnp.asarray(seg4), jnp.asarray(pos4), 64, 64)
    assert f4 > f1
    # 4 equal docs: visible work ~ 4 * (S/4)^2 / S^2 = 1/4 of one-doc's lower
    # triangle; tile-granularity makes it approximate
    assert f4 - f1 > 0.25


def test_block_metadata_never_skips_needed_tiles(rng):
    """Safety: every (q,k) pair visible under the exact mask lies in a tile
    with blk_ok == 1 (skipping is conservative)."""
    S, bq, bk = 128, 32, 32
    seg, pos = make_packed(rng, 1, S)
    segj, posj = jnp.asarray(seg), jnp.asarray(pos)
    meta = np.asarray(block_metadata(segj, segj, posj, posj, bq, bk,
                                     causal=True, window=None))[0]
    mask = (seg[0][:, None] == seg[0][None, :]) & (seg[0][:, None] != 0)
    mask &= pos[0][:, None] >= pos[0][None, :]
    for iq in range(S // bq):
        for ik in range(S // bk):
            tile = mask[iq * bq:(iq + 1) * bq, ik * bk:(ik + 1) * bk]
            if tile.any():
                assert meta[iq, ik] == 1
