"""DAG pipeline simulator (Eq. 2): analytic critical-path checks."""
import pytest
from _ht import given, settings, strategies as st

from repro.core.detector.dag_sim import ChunkId, simulate_pipeline
from repro.engine.schedules import make_schedule, one_f_one_b, zb_h1


def const_cost(f=1.0, b=2.0, w=0.0):
    return lambda cid, e=None: {"F": f, "B": b, "W": w}[cid.kind]


def test_1f1b_analytic_makespan():
    """Equal chunk costs: T = (p - 1 + m) * (tF + tB) for 1F1B (steady state
    has no bubbles; fill+drain cost p-1 rounds)."""
    for p, m in [(2, 4), (4, 8), (4, 16), (8, 8)]:
        total, _, _ = simulate_pipeline(p, m, const_cost(1.0, 2.0))
        assert total == pytest.approx((p - 1 + m) * 3.0), (p, m)


def test_gpipe_worse_than_1f1b_memory_wise_same_time():
    p, m = 4, 8
    t_1f1b, _, _ = simulate_pipeline(p, m, const_cost(), schedule="1f1b")
    t_gpipe, _, _ = simulate_pipeline(p, m, const_cost(), schedule="gpipe")
    assert t_gpipe == pytest.approx(t_1f1b)  # same critical path, equal costs


def test_zb_h1_reduces_bubble():
    """ZB-H1 fills the drain bubble with W chunks: with B split into B+W the
    makespan beats 1F1B with the same total backward work."""
    p, m = 4, 8
    t_1f1b, _, _ = simulate_pipeline(p, m, const_cost(1.0, 2.0, 0.0), schedule="1f1b")
    t_zb, _, _ = simulate_pipeline(p, m, const_cost(1.0, 1.0, 1.0), schedule="zb")
    assert t_zb < t_1f1b


def test_p2p_cost_extends_critical_path():
    t0, _, _ = simulate_pipeline(4, 8, const_cost(), p2p_cost=0.0)
    t1, _, _ = simulate_pipeline(4, 8, const_cost(), p2p_cost=0.1)
    assert t1 > t0


def test_slow_stage_gates_pipeline():
    """One stage 2x slower: steady-state rate set by the slow stage."""
    slow = lambda cid, e: {"F": 1.0, "B": 2.0, "W": 0.0}[cid.kind] * (
        2.0 if cid.stage == 1 else 1.0)
    p, m = 4, 16
    total, _, _ = simulate_pipeline(p, m, slow)
    # slow stage does m*(2+4)=96s of work; makespan >= that
    assert total >= 16 * 6.0
    healthy, _, _ = simulate_pipeline(p, m, const_cost())
    assert total > healthy * 1.7


@settings(max_examples=20, deadline=None)
@given(p=st.integers(2, 6), m=st.integers(1, 12))
def test_schedules_deadlock_free_and_complete(p, m):
    for name in ("1f1b", "gpipe", "zb"):
        total, finish, idle = simulate_pipeline(p, m, const_cost(1.0, 2.0, 0.5),
                                                schedule=name)
        expect = p * m * (3 if name == "zb" else 2)
        assert len(finish) == expect
        assert total > 0


def test_schedule_orders_valid():
    """Every schedule contains each chunk exactly once per stage."""
    for name in ("1f1b", "gpipe", "zb"):
        sched = make_schedule(name, 4, 6)
        for (r, s), order in sched.items():
            fs = [c for c in order if c.kind == "F"]
            bs = [c for c in order if c.kind == "B"]
            assert [c.mb for c in fs] == sorted(c.mb for c in fs)
            assert len(fs) == 6 and len(bs) == 6
            if name == "zb":
                ws = [c for c in order if c.kind == "W"]
                assert len(ws) == 6
