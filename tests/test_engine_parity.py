"""Engine parity: the fast execution core (``engine="fast"``, the default)
must reproduce the reference python engine bit-for-bit.

Runs named scenarios through both engines under four policies that between
them exercise every execution path: ``resihp`` (joint migrating pipeline,
Algorithm 1), ``resihp+ntp`` (nonuniform TP shard widths — the
``StageSpeedCache`` fraction-aware reduction vs the reference python loop),
``recycle+`` (round-robin fail-stop eviction + redistributed micro-batches)
and ``oobleck+`` (heterogeneous per-replica pipelines via
``_run_independent``). The streams are compared exactly — floats included —
because the fast engine's contract is identity, not approximation.

``plan_overhead_fixed`` pins ResiHP's wall-clock-measured planning charge
(Fig. 13 methodology) so ``t_start`` timestamps are machine-independent;
free-text event payloads (abort details) are dropped from the comparison
because their wording may hinge on set-iteration order, not behavior.
"""
import pytest

from repro.cluster import scenarios
from repro.cluster.simulator import SimConfig, TrainingSim

CFG = SimConfig(dp=2, pp=2, tp=2, n_layers=8, n_microbatches=4,
                seq_len=2048, noise=0.01, seed=0)
ITERS = 40
SCENARIOS = {
    "fig10_mixed": dict(span=20.0),
    # in-range victim overrides: the catalog defaults target devices 9-14,
    # out of range on this 8-device config now that apply_scenario
    # validates event targets (previously those events silently never fired)
    "flapping_stragglers": dict(span=25.0, devices=(3, 4, 7)),
    "slow_ramp_mix": dict(span=25.0, devices=(2, 3, 5)),
    # short span so the mild throttles are detected within the 40-iter run
    # and the NTP policy actually executes nonuniform-width plans
    "thermal_throttle_fleet": dict(span=3.0, frac=0.5),
    # the mined adversarial family (tools/mine_scenarios.py): 256-device
    # worst-case timelines remapped onto this 8-device config — engine
    # parity must hold on every checked-in mined scenario, and the short
    # span lands the storm inside the 40-iteration session
    "adversarial_1": dict(span=1.0),
    "adversarial_2": dict(span=1.0),
    "adversarial_3": dict(span=1.0),
    # correlated failure-domain families (PR 9), spans shrunk to land the
    # correlated bursts inside the ~1.3 simulated seconds 40 iterations
    # cover at this scale: a browned-out rack's hazard-driven fail-stop
    # recurrence and an orchestrator restart wave with staggered rejoins
    "pdu_brownout": dict(span=2.0, max_events=8),
    "restart_storm": dict(span=5.0),
    "switch_degrade": dict(span=3.0),
}
POLICIES = {
    "resihp": {"plan_overhead_fixed": 0.25},
    "resihp+ntp": {"plan_overhead_fixed": 0.25, "ntp": True},
    # pooled domain quarantine + hold + domain-spread risk + the abort
    # fallback (bench waived when it would kill the session) all ride the
    # shared step loop — parity must hold with the whole stack on
    "resihp+dom": {"plan_overhead_fixed": 0.25, "domains": True},
    # the unified credit path (band-keyed quarantine/admission, credit-gated
    # NTP veto, credit-aware placement) also rides the shared step loop —
    # parity with the whole credit stack on
    "resihp+credit": {"plan_overhead_fixed": 0.25, "credit": True,
                      "ntp": True},
    "recycle+": {},
    "oobleck+": {},
}
# policy-label suffixes that select a ResiHPPolicy switch, not a policy name
_LABEL_SUFFIXES = ("+ntp", "+dom", "+credit")


def _policy_name(label: str) -> str:
    for suf in _LABEL_SUFFIXES:
        if label.endswith(suf):
            return label[: -len(suf)]
    return label


def _run(engine, scenario, policy):
    sim = TrainingSim(_policy_name(policy), CFG,
                      policy_kwargs=POLICIES[policy], engine=engine)
    sim.apply_scenario(scenarios.get(scenario, **SCENARIOS[scenario]))
    sim.run(ITERS, stop_on_abort=False)
    return sim


def _stream(sim):
    """IterRecord stream with free-text payloads stripped."""
    out = []
    for r in sim.trace:
        events = [
            (e[0], *(x for x in e[1:] if not isinstance(x, str)))
            if isinstance(e, tuple) else e
            for e in r.events
        ]
        out.append((r.iteration, r.t_start, r.duration, r.throughput, events))
    return out


@pytest.mark.parametrize("policy", sorted(POLICIES))
@pytest.mark.parametrize("scenario", sorted(SCENARIOS))
def test_engines_produce_identical_iter_records(scenario, policy):
    a = _run("python", scenario, policy)
    b = _run("fast", scenario, policy)
    assert _stream(a) == _stream(b)
    assert a.aborted == b.aborted
    assert a.avg_throughput(skip=2) == b.avg_throughput(skip=2)
    assert ([ev.as_tuple() for ev in a.event_log]
            == [ev.as_tuple() for ev in b.event_log])


@pytest.mark.parametrize("scenario", ("pdu_brownout", "restart_storm"))
def test_domain_scenarios_parity_on_forced_array_path(scenario):
    """The fast engine's vectorized dispatch normally engages only past
    ``VEC_BATCH_MIN`` chunks per round; forcing ``vec_batch_min=1`` drives
    every round of the correlated-domain scenarios through the array path,
    so the batched kernels (not the tuned scalar fallback) are what parity
    certifies here."""
    import functools

    from repro.cluster.fastsim import FastMigrator

    sims = []
    for forced in (False, True):
        sim = TrainingSim("resihp", CFG, engine="fast",
                          policy_kwargs=POLICIES["resihp+dom"])
        if forced:
            sim._migrator_cls = functools.partial(FastMigrator,
                                                  vec_batch_min=1)
        sim.apply_scenario(scenarios.get(scenario, **SCENARIOS[scenario]))
        sim.run(ITERS, stop_on_abort=False)
        sims.append(sim)
    assert _stream(sims[0]) == _stream(sims[1])
    assert sims[0].aborted == sims[1].aborted


def test_default_engine_is_fast():
    assert TrainingSim("resihp", CFG).engine == "fast"


def test_unknown_engine_rejected():
    with pytest.raises(ValueError):
        TrainingSim("resihp", CFG, engine="warp")
