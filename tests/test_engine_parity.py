"""Engine parity: the fast execution core (``engine="fast"``, the default)
must reproduce the reference python engine bit-for-bit.

Runs named scenarios through both engines under four policies that between
them exercise every execution path: ``resihp`` (joint migrating pipeline,
Algorithm 1), ``resihp+ntp`` (nonuniform TP shard widths — the
``StageSpeedCache`` fraction-aware reduction vs the reference python loop),
``recycle+`` (round-robin fail-stop eviction + redistributed micro-batches)
and ``oobleck+`` (heterogeneous per-replica pipelines via
``_run_independent``). The streams are compared exactly — floats included —
because the fast engine's contract is identity, not approximation.

``plan_overhead_fixed`` pins ResiHP's wall-clock-measured planning charge
(Fig. 13 methodology) so ``t_start`` timestamps are machine-independent;
free-text event payloads (abort details) are dropped from the comparison
because their wording may hinge on set-iteration order, not behavior.
"""
import pytest

from repro.cluster import scenarios
from repro.cluster.simulator import SimConfig, TrainingSim

CFG = SimConfig(dp=2, pp=2, tp=2, n_layers=8, n_microbatches=4,
                seq_len=2048, noise=0.01, seed=0)
ITERS = 40
SCENARIOS = {
    "fig10_mixed": dict(span=20.0),
    # in-range victim overrides: the catalog defaults target devices 9-14,
    # out of range on this 8-device config now that apply_scenario
    # validates event targets (previously those events silently never fired)
    "flapping_stragglers": dict(span=25.0, devices=(3, 4, 7)),
    "slow_ramp_mix": dict(span=25.0, devices=(2, 3, 5)),
    # short span so the mild throttles are detected within the 40-iter run
    # and the NTP policy actually executes nonuniform-width plans
    "thermal_throttle_fleet": dict(span=3.0, frac=0.5),
    # the mined adversarial family (tools/mine_scenarios.py): 256-device
    # worst-case timelines remapped onto this 8-device config — engine
    # parity must hold on every checked-in mined scenario, and the short
    # span lands the storm inside the 40-iteration session
    "adversarial_1": dict(span=1.0),
    "adversarial_2": dict(span=1.0),
    "adversarial_3": dict(span=1.0),
}
POLICIES = {
    "resihp": {"plan_overhead_fixed": 0.25},
    "resihp+ntp": {"plan_overhead_fixed": 0.25, "ntp": True},
    "recycle+": {},
    "oobleck+": {},
}


def _run(engine, scenario, policy):
    name = policy.split("+ntp")[0] if policy.endswith("+ntp") else policy
    sim = TrainingSim(name, CFG, policy_kwargs=POLICIES[policy],
                      engine=engine)
    sim.apply_scenario(scenarios.get(scenario, **SCENARIOS[scenario]))
    sim.run(ITERS, stop_on_abort=False)
    return sim


def _stream(sim):
    """IterRecord stream with free-text payloads stripped."""
    out = []
    for r in sim.trace:
        events = [
            (e[0], *(x for x in e[1:] if not isinstance(x, str)))
            if isinstance(e, tuple) else e
            for e in r.events
        ]
        out.append((r.iteration, r.t_start, r.duration, r.throughput, events))
    return out


@pytest.mark.parametrize("policy", sorted(POLICIES))
@pytest.mark.parametrize("scenario", sorted(SCENARIOS))
def test_engines_produce_identical_iter_records(scenario, policy):
    a = _run("python", scenario, policy)
    b = _run("fast", scenario, policy)
    assert _stream(a) == _stream(b)
    assert a.aborted == b.aborted
    assert a.avg_throughput(skip=2) == b.avg_throughput(skip=2)
    assert ([ev.as_tuple() for ev in a.event_log]
            == [ev.as_tuple() for ev in b.event_log])


def test_default_engine_is_fast():
    assert TrainingSim("resihp", CFG).engine == "fast"


def test_unknown_engine_rejected():
    with pytest.raises(ValueError):
        TrainingSim("resihp", CFG, engine="warp")
