"""Correlated failure domains: the topology domain map, the bad-domain
hazard covariate (and its off-switch invariance + repr/stream-key
contract), checkpoint/restart economics (arithmetic, manifest pricing, and
the restart-iff-cheaper policy pin), domain-spread standby ordering, the
domains-on quiet-fleet invariance, and the ``pdu_brownout`` acceptance row
(domain pooling beats the domain-blind risk-aware planner)."""
import numpy as np
import pytest

from repro.checkpoint import RestartCostModel
from repro.cluster.hazard import (
    DomainPolicyConfig,
    HazardConfig,
    HazardModel,
)
from repro.cluster.registry import ClusterTopology
from repro.cluster.simulator import SimConfig, TrainingSim
from repro.core.scheduler.plan import initial_plan

BENCH_CFG = SimConfig(dp=2, pp=4, tp=4, n_layers=40, n_microbatches=8,
                      seq_len=8192, noise=0.01, seed=0)


# ========================================================= topology domains
def test_topology_domain_map():
    # 8 nodes x 8 devices; 2 nodes per PDU, 4 nodes per leaf switch
    topo = ClusterTopology(8, 8, nodes_per_pdu=2, nodes_per_switch=4)
    assert topo.n_pdus == 4
    assert topo.n_switches == 2
    assert topo.pdu_of(0) == 0 and topo.pdu_of(15) == 0  # nodes 0-1
    assert topo.pdu_of(16) == 1 and topo.pdu_of(63) == 3
    assert topo.switch_of(0) == 0 and topo.switch_of(32) == 1
    # domain_of dispatch + 'rack' as the colloquial alias for node
    assert topo.domain_of(17, "pdu") == topo.pdu_of(17)
    assert topo.domain_of(17, "switch") == topo.switch_of(17)
    assert topo.domain_of(17, "rack") == topo.node_of(17)
    assert topo.domain_devices("pdu", 1) == list(range(16, 32))
    assert topo.domain_nodes("switch", 1) == [4, 5, 6, 7]


def test_topology_ragged_last_domain():
    # 3 nodes, 2 per PDU: PDU 1 holds only the last node
    topo = ClusterTopology(3, 4, nodes_per_pdu=2)
    assert topo.n_pdus == 2
    assert topo.domain_devices("pdu", 1) == list(range(8, 12))


def test_topology_validates_domain_args():
    with pytest.raises(ValueError):
        ClusterTopology(4, 8, nodes_per_pdu=0)
    with pytest.raises(ValueError):
        ClusterTopology(4, 8).domain_of(0, "galaxy")


# ================================================== bad-domain hazard draw
def test_bad_domain_covariate_multiplies_resident_rates():
    topo = ClusterTopology(4, 8)  # 4 PDUs of 8 devices
    cfg = HazardConfig(mttf_s=1000.0, shape=1.0, bad_domain_frac=0.05,
                       bad_domain_factor=64.0, domain="pdu")
    m = HazardModel(cfg, topo.n_devices, np.random.default_rng(0), topo=topo)
    base = HazardModel(HazardConfig(mttf_s=1000.0, shape=1.0),
                       topo.n_devices, np.random.default_rng(0))
    assert m.bad_domains  # at-least-one guarantee even at frac 0.05
    for d in range(topo.n_devices):
        if topo.pdu_of(d) in m.bad_domains:
            assert m.mult[d] == base.mult[d] * 64.0
        else:
            assert m.mult[d] == base.mult[d]


def test_bad_domain_off_is_draw_stream_identical():
    """``bad_domain_frac=0`` must not consume a single extra RNG draw: the
    sampled failure times match the pre-covariate model exactly, topo
    passed or not."""
    topo = ClusterTopology(4, 8)
    cfg_off = HazardConfig(mttf_s=500.0, shape=3.0, age_spread_s=100.0,
                           lemon_frac=0.1, lemon_factor=8.0)

    def draws(model, rng):
        return [model.sample_next(d, 0.0, rng) for d in range(32)]

    rng_a, rng_b = np.random.default_rng(7), np.random.default_rng(7)
    a = draws(HazardModel(cfg_off, 32, rng_a), rng_a)
    b = draws(HazardModel(cfg_off, 32, rng_b, topo=topo), rng_b)
    assert a == b


def test_bad_domain_requires_topology():
    cfg = HazardConfig(mttf_s=100.0, bad_domain_frac=0.2,
                       bad_domain_factor=8.0)
    with pytest.raises(ValueError):
        HazardModel(cfg, 16, np.random.default_rng(0))


def test_hazard_config_repr_contract():
    """The scenario RNG stream key is crc32(repr(scenario)), so the repr is
    load-bearing: with the covariates unset it must be byte-identical to
    the pre-domain dataclass repr (old scenarios keep their streams), and
    setting them must change it (new scenarios get fresh streams)."""
    plain = HazardConfig(mttf_s=100.0, shape=2.0)
    assert repr(plain) == ("HazardConfig(mttf_s=100.0, shape=2.0, "
                           "age_spread_s=0.0, lemon_frac=0.0, "
                           "lemon_factor=8.0, wear_per_repair=1.0)")
    dom = HazardConfig(mttf_s=100.0, shape=2.0, bad_domain_frac=0.2,
                       bad_domain_factor=24.0)
    assert repr(dom) == ("HazardConfig(mttf_s=100.0, shape=2.0, "
                         "age_spread_s=0.0, lemon_frac=0.0, "
                         "lemon_factor=8.0, wear_per_repair=1.0, "
                         "bad_domain_frac=0.2, bad_domain_factor=24.0, "
                         "domain='pdu')")


def test_hazard_config_validates_covariates():
    with pytest.raises(ValueError):
        HazardConfig(mttf_s=100.0, bad_domain_frac=1.5)
    with pytest.raises(ValueError):
        HazardConfig(mttf_s=100.0, bad_domain_frac=0.1,
                     bad_domain_factor=0.0)
    with pytest.raises(ValueError):
        HazardConfig(mttf_s=100.0, bad_domain_frac=0.1, domain="galaxy")


# ================================================== restart-cost economics
def test_restart_cost_model_arithmetic():
    m = RestartCostModel()
    assert m.save_cost_s() == 2.0  # 26 GB / 13 GB/s
    assert m.restore_read_s() == 1.0  # 26 GB / 26 GB/s
    assert m.lost_work_s() == 10.0  # half a 20 s interval
    assert m.restart_cost_s() == 15.0  # 4 + 1 + 10


def test_restart_cost_model_validation():
    with pytest.raises(ValueError):
        RestartCostModel(write_gbps=0.0)
    with pytest.raises(ValueError):
        RestartCostModel(lost_work_frac=1.5)
    with pytest.raises(ValueError):
        RestartCostModel(state_gb=-1.0)


def test_from_manifest_prices_real_checkpoint_bytes(tmp_path):
    """Manifest pricing without jax: a hand-written step directory in the
    exact ``repro.checkpoint`` layout. 1e9 float32 ~ hmm — use 2.5e8
    elements = 1 GB exactly."""
    import json

    def write_step(step, shapes, committed=True, tmp=False):
        name = f"step_{step:09d}" + (".tmp" if tmp else "")
        d = tmp_path / name
        d.mkdir()
        manifest = {
            "n_leaves": len(shapes),
            "leaves": [{"dtype": "float32", "shape": list(s)}
                       for s in shapes],
        }
        (d / "MANIFEST.json").write_text(json.dumps(manifest))
        if committed:
            (d / "COMMIT").write_text("ok")

    write_step(10, [(1000, 250), (500,)])  # 250500 f32 = 1.002 MB
    write_step(20, [(1000, 1000)])  # 4 MB — the latest committed
    write_step(30, [(1,)], committed=False)  # uncommitted: ignored
    write_step(40, [(1,)], committed=True, tmp=True)  # staging: ignored

    m = RestartCostModel.from_manifest(tmp_path)
    assert m.state_gb == pytest.approx(4e6 / 1e9)
    assert RestartCostModel.from_manifest(
        tmp_path, step=10).state_gb == pytest.approx(250500 * 4 / 1e9)
    # overrides reprice the non-measured fields
    assert RestartCostModel.from_manifest(
        tmp_path, relaunch_s=9.0).relaunch_s == 9.0
    with pytest.raises(FileNotFoundError):
        RestartCostModel.from_manifest(tmp_path / "empty")


# ============================================== restart-iff-cheaper policy
def _live_overhead_probe(restart):
    """One fail-stop adaptation under a pinned planning charge; returns the
    decision so the test can read the charged overhead + note."""
    from repro.cluster.baselines import make_policy

    plan0 = initial_plan(16, 2, 2, 2)
    pol = make_policy("resihp", plan0, [1.0] * 16,
                      plan_overhead_fixed=0.25,
                      domains=DomainPolicyConfig(restart=restart))
    speeds = {d: 1.0 for d in plan0.devices}
    pol.decide(speeds, changed=False)  # seat the healthy plan
    speeds[3] = 0.0
    return pol.decide(speeds, changed=True)


def test_restart_chosen_exactly_when_priced_below_live():
    """The pinned boundary: the policy takes restart-from-checkpoint when
    (and only when) the modeled restart price is strictly below the live
    adaptation cost — at exact equality live adaptation wins."""
    live = _live_overhead_probe(None).reconfig_overhead_s
    assert live > 0.0

    def priced(total):
        # relaunch_s carries the whole price: no read, no replay
        return RestartCostModel(state_gb=0.0, relaunch_s=total,
                                lost_work_frac=0.0)

    below = _live_overhead_probe(priced(live - 1e-6))
    assert below.reconfig_overhead_s == pytest.approx(live - 1e-6)
    assert "restart-from-checkpoint" in below.detail

    at = _live_overhead_probe(priced(live))
    assert at.reconfig_overhead_s == live
    assert "restart-from-checkpoint" not in at.detail

    above = _live_overhead_probe(priced(live + 1e-6))
    assert above.reconfig_overhead_s == live
    assert "restart-from-checkpoint" not in above.detail


# ======================================================== scheduler spread
def test_standby_offers_prefer_less_failed_domains():
    from repro.core.scheduler.scheduler import Scheduler

    topo = ClusterTopology(4, 4)  # 4 nodes of 4; PDU == node
    sched = Scheduler(layer_costs=[1.0] * 8,
                      domain_of=lambda d: topo.pdu_of(d))
    group = (0, 1)
    pool = [2, 6, 10, 14]  # one standby per PDU
    # PDU 1 has 2 recent failures, PDU 0 has 1 — offers sort stably toward
    # the quiet domains, legacy (pool) order inside each tier
    offers = sched._local_standbys(group, pool, {1: 2, 0: 1})
    assert offers == [10, 14, 2, 6]
    # no domain pressure (None) — the legacy order, untouched
    assert sched._local_standbys(group, pool, None) == pool


# ==================================================== quiet-fleet invariance
def test_domains_on_quiet_fleet_matches_hazard_only():
    """With no failures there is no domain evidence: the domains switch must
    not perturb a single float of the session (its machinery only engages
    on pooled FailureHistory records)."""
    runs = []
    for pk in ({"plan_overhead_fixed": 0.25, "hazard": True},
               {"plan_overhead_fixed": 0.25, "domains": True}):
        sim = TrainingSim("resihp", BENCH_CFG, policy_kwargs=pk)
        sim.run(30)
        runs.append([(r.iteration, r.t_start, r.duration, r.throughput)
                     for r in sim.trace])
    assert runs[0] == runs[1]


def test_domains_switch_implies_hazard_and_lifecycle():
    sim = TrainingSim("resihp", BENCH_CFG, policy_kwargs={"domains": True})
    assert sim.domain_estimator is not None
    assert sim.hazard_estimator is not None
    assert sim.lifecycle is not None
    # and the restart default materializes as a priced model
    assert sim.policy.domains.restart.restart_cost_s() == 15.0


def test_domain_quarantine_fires_in_sim_before_third_device():
    """End-to-end: under ``pdu_brownout`` the browned-out rack is benched
    after two distinct resident failures — the quarantine set the decision
    path sees contains the whole rack while at most two of its devices
    have ever failed."""
    sim = TrainingSim("resihp", BENCH_CFG,
                      policy_kwargs={"plan_overhead_model": True,
                                     "domains": True})
    from repro.cluster import scenarios

    sim.apply_scenario(scenarios.get("pdu_brownout", span=128.0))
    tripped = None
    for _ in range(160):
        sim.step()
        if sim.aborted:
            break
        dq, _ = sim._domain_view(sim.now)
        if dq:
            failed_residents = {
                d for d in dq
                if d in sim.lifecycle.histories
                and (sim.lifecycle.histories[d].fail_stops
                     or sim.lifecycle.histories[d].fail_slows)}
            tripped = (len(dq), len(failed_residents))
            break
    assert tripped is not None, "domain quarantine never fired"
    n_benched, n_failed = tripped
    assert n_benched == 8  # the whole rack
    assert n_failed <= 2  # ...before its third device failed


# ==================================================== the acceptance bench row
def test_domain_pooling_beats_domain_blind_on_pdu_brownout():
    """The acceptance row: on the browned-out-rack family, pooled domain
    awareness (bench the rack on correlated evidence, hold it out, spread
    placement away from it) must beat the per-device hazard planner on
    session throughput — the domain-blind planner re-learns each resident's
    badness one failure at a time, in the exact configuration
    ``bench_scenarios`` runs."""
    from benchmarks.bench_scenarios import run as bench_run

    dom = bench_run("llama2-13b", "pdu_brownout", "resihp+dom", iters=160)
    hz = bench_run("llama2-13b", "pdu_brownout", "resihp+hz", iters=160)
    assert not dom["aborted"] and not hz["aborted"]
    assert dom["session_throughput"] > hz["session_throughput"]
