"""Sweep orchestrator: the parallel scenario x policy x seed grid must be
byte-identical to the serial reference path, invariant to worker count
(per-cell seed isolation), and keyed deterministically.

The quick grid here is 3 scenarios x 2 policies at small scale — enough to
exercise fan-out, result collection and the canonical-order merge without
slowing tier-1."""
import json

import pytest

from benchmarks.sweep import Cell, build_grid, run_cell, sweep

GRID_KW = dict(
    models=["llama2-13b"],
    scenarios=["rack_storm", "flapping_stragglers", "slow_ramp_mix"],
    policies=["resihp", "recycle+"],
    iters=20,
    hazard_iters=20,
)


@pytest.fixture(scope="module")
def cells():
    return build_grid(**GRID_KW)


@pytest.fixture(scope="module")
def serial(cells):
    return sweep(cells, workers=1)


def _dumps(out) -> str:
    return json.dumps(out, indent=2, default=str)


def test_grid_is_canonical_order(cells):
    assert len(cells) == 3 * 2
    assert [c.scenario for c in cells[:2]] == ["rack_storm", "rack_storm"]
    assert [c.policy for c in cells[:2]] == ["resihp", "recycle+"]


def test_parallel_equals_serial_byte_for_byte(cells, serial):
    parallel = sweep(cells, workers=2)
    assert _dumps(parallel) == _dumps(serial)


def test_worker_count_does_not_change_results(cells, serial):
    more = sweep(cells, workers=3)
    assert _dumps(more) == _dumps(serial)


def test_cell_is_a_pure_function_of_its_coordinates():
    """Seed isolation: re-running a cell reproduces it exactly, and the seed
    coordinate actually changes the outcome (distinct streams per seed)."""
    c0 = Cell("llama2-13b", "poisson_storm", "resihp", seed=0, iters=20)
    c1 = Cell("llama2-13b", "poisson_storm", "resihp", seed=1, iters=20)
    a, b = run_cell(c0), run_cell(c0)
    assert _dumps(a) == _dumps(b)
    assert _dumps(run_cell(c1)) != _dumps(a)


def test_multi_seed_grid_adds_seed_key_level():
    cells = build_grid(models=["llama2-13b"], scenarios=["rack_storm"],
                       policies=["resihp"], seeds=(0, 1), iters=20)
    out = sweep(cells, workers=1)
    assert sorted(out) == ["llama2-13b/rack_storm/s0",
                           "llama2-13b/rack_storm/s1"]


def test_default_output_is_compact_and_full_keeps_events():
    c = Cell("llama2-13b", "rack_storm", "resihp", seed=0, iters=20)
    compact = run_cell(c)
    assert "events" not in compact and compact["n_events"] > 0
    full = run_cell(c, full=True)
    assert len(full["events"]) == full["n_events"]


MULTI_SCALE_KW = dict(
    models=["llama2-13b"],
    scenarios=["rack_storm"],
    policies=["resihp"],
    iters=20,
    hazard_iters=20,
    scales=(None, "1k"),
)


def test_multi_scale_grid_adds_scale_key_level_and_changes_results():
    cells = build_grid(**MULTI_SCALE_KW)
    assert [c.scale for c in cells] == [None, "1k"]
    out = sweep(cells, workers=1)
    assert sorted(out) == ["llama2-13b/rack_storm@1k",
                           "llama2-13b/rack_storm@native"]
    # the scale override must actually reach the simulator: a 1k-device
    # preset cannot reproduce the native-preset run byte-for-byte
    assert (_dumps(out["llama2-13b/rack_storm@1k"])
            != _dumps(out["llama2-13b/rack_storm@native"]))


def test_single_scale_sweep_keeps_historical_keys(cells, serial):
    """scales=(None,) (the default) must not grow an @scale key level —
    pre-axis artifacts and their consumers stay byte-compatible."""
    explicit = sweep(build_grid(**GRID_KW, scales=(None,)), workers=1)
    assert _dumps(explicit) == _dumps(serial)
    assert all("@" not in k for k in explicit)


def test_multi_scale_merge_is_worker_count_invariant():
    cells = build_grid(**MULTI_SCALE_KW)
    serial_out = sweep(cells, workers=1)
    assert _dumps(sweep(cells, workers=2)) == _dumps(serial_out)


def test_new_grid_axes_leave_old_cells_byte_identical():
    """Growing the sweep grid (PR 9 added three scenario families and the
    resihp+dom policy column) must not perturb a single byte of the cells
    that existed before: recompute pre-existing cells at the checked-in
    artifact's coordinates and compare against results/scenarios_sweep.json
    exactly. A diff here means a new registration leaked into an old cell's
    RNG stream or decision path."""
    from pathlib import Path

    from benchmarks.bench_scenarios import run

    artifact = Path(__file__).parent.parent / "results/scenarios_sweep.json"
    checked_in = json.loads(artifact.read_text())
    # one plain cell and one with the full lifecycle+hazard stack on — the
    # two paths a domain-layer leak could plausibly touch
    for policy in ("resihp", "resihp+hz"):
        fresh = run("llama2-13b", "rack_storm", policy, iters=160)
        pinned = checked_in["llama2-13b/rack_storm"][policy]
        assert _dumps(json.loads(_dumps(fresh))) == _dumps(pinned), policy
