"""Adversarial scenario miner: property-based fuzz over the mutation /
composition operators, repair canonicalization, mining determinism and
worker-count invariance, and the checked-in mined-family contract.

The fuzz pass doubles as the continuous fuzz harness for the scenario /
event / engine stack: every mutated timeline is an engine input nobody
hand-wrote, and each one must compile to a valid ``EventTrace``, replay
deterministically, and execute with bit-for-bit fast/python engine parity.
"""
import json
from functools import partial
from pathlib import Path

import numpy as np
import pytest

from _ht import given, settings, strategies as st
from benchmarks.sweep import pmap
from repro.cluster import mining, scenarios
from repro.cluster.events import Event, EventTrace
from repro.cluster.registry import ClusterTopology
from repro.cluster.simulator import SimConfig, TrainingSim

TOPO = ClusterTopology(4, 4)  # 16 devices: the fuzz scale
SPAN = 10.0
MAX_EVENTS = 48

# the catalog pool the mutators splice/compose from, compiled once
SEED_TLS = mining.compile_seed_timelines(TOPO, SPAN, seed=0)
POOL = [SEED_TLS[name] for name in sorted(SEED_TLS)]
CAP = max(mining.damage(tl, TOPO) for tl in POOL)

TINY = SimConfig(dp=2, pp=2, tp=2, n_layers=8, n_microbatches=4,
                 seq_len=2048, noise=0.01, seed=0)

ARTIFACT = Path(__file__).parent.parent / "results" / "adversarial_mined.json"


def _mutant(seed: int) -> tuple:
    """One deterministic fuzz candidate: a mutated/composed catalog timeline."""
    rng = np.random.default_rng([0xAD5E, seed])
    parent = POOL[int(rng.integers(0, len(POOL)))]
    return mining.mutate(parent, rng, TOPO, SPAN, POOL,
                         max_events=MAX_EVENTS, cap=CAP)


def _trace(timeline) -> EventTrace:
    return EventTrace(Event(t, kind, target, value, "mined")
                      for t, kind, target, value in timeline)


# ------------------------------------------------------ property-based fuzz
@settings(max_examples=200)
@given(st.integers(0, 2**31 - 1))
def test_fuzz_mutants_compile_to_valid_traces(seed):
    """Any mutated/composed candidate is a valid EventTrace within the
    miner's event-count and damage budgets."""
    child = _mutant(seed)
    _trace(child).validate(TOPO)
    assert len(child) <= MAX_EVENTS
    assert mining.damage(child, TOPO) <= CAP + 1e-6
    for t, kind, target, value in child:
        assert 0.0 <= t <= SPAN


@settings(max_examples=50)
@given(st.integers(0, 2**31 - 1))
def test_fuzz_mutation_is_deterministic(seed):
    """Same rng seed => byte-identical mutant, and its trace serializes
    canonically."""
    a, b = _mutant(seed), _mutant(seed)
    assert a == b
    assert _trace(a).to_json() == _trace(b).to_json()


@settings(max_examples=50)
@given(st.integers(0, 2**31 - 1))
def test_fuzz_repair_is_idempotent_on_raw_soup(seed):
    """repair_timeline canonicalizes arbitrary event soups — including
    out-of-range targets, negative times and contradictory sequences — into
    valid timelines, and is a fixed point on its own output."""
    rng = np.random.default_rng([0x50FA, seed])
    kinds = ("fail-stop", "fail-stop-node", "fail-slow", "net-degrade",
             "net-restore", "rejoin")
    soup = [(float(rng.uniform(-2.0, SPAN + 5.0)),
             kinds[int(rng.integers(0, len(kinds)))],
             int(rng.integers(-5, 3 * TOPO.n_devices)),
             float(rng.uniform(-0.5, 1.5)))
            for _ in range(int(rng.integers(0, 40)))]
    repaired = mining.repair_timeline(soup, TOPO, SPAN,
                                      max_events=MAX_EVENTS, cap=CAP)
    _trace(repaired).validate(TOPO)
    again = mining.repair_timeline(repaired, TOPO, SPAN,
                                   max_events=MAX_EVENTS, cap=CAP)
    assert again == repaired


@settings(max_examples=4)
@given(st.integers(0, 2**31 - 1))
def test_fuzz_mutants_run_with_engine_parity(seed):
    """Mutated timelines execute bit-for-bit identically on the fast and
    python engines (nobody hand-checked these inputs — that's the point).

    The candidate must be repaired against the *simulator's* topology —
    an earlier version of this test repaired at a 2x4 topology while the
    sim ran 1x8, and apply_scenario's validation rejected the mismatch
    (a node-kill covers different devices), which is exactly the loud
    failure the hardening satellite is for."""
    topo8 = mining.mining_topology(TINY)  # 1 node x 8 devices
    rng = np.random.default_rng([0x9A41, seed])
    pool8 = [mining.repair_timeline(tl, topo8, 1.0) for tl in POOL]
    child = mining.mutate(pool8[int(rng.integers(0, len(pool8)))],
                          rng, topo8, 1.0, pool8, max_events=24)
    streams = {}
    for engine in ("python", "fast"):
        sim = TrainingSim("resihp", TINY, engine=engine,
                          policy_kwargs={"plan_overhead_fixed": 0.25})
        sim.apply_scenario(scenarios.TimelineScenario(
            span=1.0, timeline=child, permute=False, label="mined"))
        sim.run(12, stop_on_abort=False)
        streams[engine] = [(r.iteration, r.t_start, r.duration, r.throughput)
                           for r in sim.trace]
    assert streams["python"] == streams["fast"]


# ------------------------------------------- named shrunk regression cases
# Minimal raw timelines distilled from fuzz findings during development:
# each is the smallest soup exercising one repair rule the mutators can
# violate. name -> (raw soup, expected repaired timeline).
REGRESSION_CASES = {
    # a rejoin with no prior failure must vanish, not replay
    "orphan_rejoin": (
        [(1.0, "rejoin", 3, 0.0)],
        ()),
    # second kill of a dead device is dropped; its rejoin still replays
    "double_fail_stop": (
        [(1.0, "fail-stop", 2, 0.0), (2.0, "fail-stop", 2, 0.0),
         (3.0, "rejoin", 2, 0.0)],
        ((1.0, "fail-stop", 2, 0.0), (3.0, "rejoin", 2, 0.0))),
    # a dead device has no speed to degrade
    "fail_slow_on_dead": (
        [(1.0, "fail-stop", 5, 0.0), (2.0, "fail-slow", 5, 0.5)],
        ((1.0, "fail-stop", 5, 0.0),)),
    # net-restore without an active degrade is contradictory
    "orphan_net_restore": (
        [(4.0, "net-restore", 1, 0.0)],
        ()),
    # killing a node whose devices are all dead is a no-op storm artifact
    "node_kill_after_all_dead": (
        [(1.0, "fail-stop", 0, 0.0), (1.0, "fail-stop", 1, 0.0),
         (1.0, "fail-stop", 2, 0.0), (1.0, "fail-stop", 3, 0.0),
         (2.0, "fail-stop-node", 0, 0.0)],
        ((1.0, "fail-stop", 0, 0.0), (1.0, "fail-stop", 1, 0.0),
         (1.0, "fail-stop", 2, 0.0), (1.0, "fail-stop", 3, 0.0))),
    # out-of-range victims remap (mod topology) instead of exploding;
    # negative / past-span times clamp into the window
    "out_of_range_and_clamped": (
        [(-3.0, "fail-stop", 18, 0.0), (99.0, "fail-slow", -1, 2.0)],
        ((0.0, "fail-stop", 2, 0.0), (10.0, "fail-slow", 15, 1.0))),
    # a degraded-return rejoin leaves the device below peak, so a second
    # rejoin (full-health) is a recovery, not an orphan
    "degraded_return_then_full_rejoin": (
        [(1.0, "fail-stop", 7, 0.0), (2.0, "rejoin", 7, 0.5),
         (3.0, "rejoin", 7, 0.0)],
        ((1.0, "fail-stop", 7, 0.0), (2.0, "rejoin", 7, 0.5),
         (3.0, "rejoin", 7, 0.0))),
}


@pytest.mark.parametrize("name", sorted(REGRESSION_CASES))
def test_repair_regression_case(name):
    raw, expected = REGRESSION_CASES[name]
    repaired = mining.repair_timeline(raw, TOPO, SPAN)
    assert repaired == expected
    _trace(repaired).validate(TOPO)


# ------------------------------------------------- signature / clustering
def test_signature_distinguishes_pattern_shape():
    kill = ((1.0, "fail-stop", 0, 0.0),)
    storm = tuple((1.0 + 0.1 * i, "fail-stop", i, 0.0) for i in range(8))
    slow = ((1.0, "fail-slow", 0, 0.5),)
    sigs = {mining.signature(tl, TOPO, SPAN) for tl in (kill, storm, slow)}
    assert len(sigs) == 3


def test_signature_collapses_near_identical_candidates():
    a = ((1.0, "fail-stop", 3, 0.0), (2.0, "rejoin", 3, 0.0))
    b = ((1.1, "fail-stop", 5, 0.0), (2.2, "rejoin", 5, 0.0))
    assert mining.signature(a, TOPO, SPAN) == mining.signature(b, TOPO, SPAN)


# ------------------------------------------------ mine(): determinism
MINE_KW = dict(seed=0, budget=10, iters=6, cfg=TINY, batch=3, elites=3)


@pytest.fixture(scope="module")
def tiny_report():
    return mining.mine(**MINE_KW)


def test_mine_same_seed_budget_is_byte_identical(tiny_report):
    again = mining.mine(**MINE_KW)
    assert mining.to_json(again) == mining.to_json(tiny_report)


def test_mine_seed_changes_the_search(tiny_report):
    other = mining.mine(**{**MINE_KW, "seed": 1})
    assert mining.to_json(other) != mining.to_json(tiny_report)


def test_mine_worker_count_invariance(tiny_report):
    """Fanning candidate evaluation through the benchmarks/sweep.py process
    pool must not change a byte of the report."""
    pooled = mining.mine(**MINE_KW, pool_map=partial(pmap, workers=2))
    assert mining.to_json(pooled) == mining.to_json(tiny_report)


def test_mine_report_shape(tiny_report):
    assert tiny_report["config"]["budget"] == 10
    assert tiny_report["worst_catalog"]["name"] in tiny_report["catalog"]
    for c in tiny_report["clusters"]:
        assert not c["label"].startswith("seed:")  # survivors are mined
        _trace([tuple(e) for e in c["timeline"]]).validate(
            mining.mining_topology(TINY))
    sigs = [tuple(c["signature"]) for c in tiny_report["clusters"]]
    assert len(sigs) == len(set(sigs))  # clusters are signature-distinct


# ------------------------------------- the checked-in mined-family contract
@pytest.fixture(scope="module")
def artifact():
    assert ARTIFACT.exists(), "run: python tools/mine_scenarios.py --quick"
    return json.loads(ARTIFACT.read_text())


def test_artifact_family_matches_registered_scenarios(artifact):
    """results/adversarial_mined.json and the adversarial_* registrations in
    scenarios.py are two views of the same mined timelines."""
    topo = mining.mining_topology(mining.mining_config())
    assert len(artifact["family"]) == 3
    for entry in artifact["family"]:
        name = f"adversarial_{entry['rank']}"
        compiled = scenarios.get(name).compile(topo, 0)
        got = [[ev.t, ev.kind, ev.target, ev.value] for ev in compiled]
        assert got == entry["timeline"], name
        compiled.validate(topo)


def test_artifact_meets_acceptance_bar(artifact):
    """>= 3 signature-distinct mined clusters, and at least one family
    member degrades resihp session throughput more than the worst
    hand-authored catalog scenario at the same scale."""
    assert artifact["n_clusters"] >= 3
    sigs = {tuple(e["signature"]) for e in artifact["family"]}
    assert len(sigs) == 3
    worst = artifact["worst_catalog"]["session_throughput"]["resihp"]
    mined = min(e["session_throughput"]["resihp"]
                for e in artifact["family"])
    assert mined < worst
    assert artifact["config"]["seed"] == 0  # the fixed quick recipe


def test_adversarial_scenarios_replay_on_any_topology():
    """The mined 256-device patterns remap + repair onto small topologies
    (the engine-parity configs) and still validate."""
    for name in ("adversarial_1", "adversarial_2", "adversarial_3"):
        for topo in (ClusterTopology(2, 4), ClusterTopology(8, 8)):
            scenarios.get(name, span=1.0).compile(topo, 0).validate(topo)
