"""Per-architecture smoke tests: reduced same-family config, one forward /
train step on CPU, asserting output shapes + finiteness (assignment item f)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ASSIGNED_ARCHS, get_arch, reduced
from repro.data.synth import SyntheticPackedDataset
from repro.models.model import (
    forward_train,
    init_cache,
    loss_fn,
    prefill_forward,
    serve_forward,
    stacked_init,
)
from repro.parallel.sharding import NULL_POLICY, split_annotations
from repro.train.optimizer import make_optimizer
from repro.train.train_step import build_train_step, init_train_state

B, S = 2, 64


def _smoke_cfg(arch_id, **overrides):
    """Reduced config with the fewest layers that still covers every distinct
    LayerSpec in the arch's period (gemma's 5:1 swa:full pattern would
    otherwise force 26-34 reduced layers and minutes of XLA compile). The
    period is truncated to that prefix so stack_for_scan's n_layers % P == 0
    invariant holds."""
    arch = get_arch(arch_id)
    seen, prefix = set(), 0
    for i, spec in enumerate(arch.period):
        if spec not in seen:
            seen.add(spec)
            prefix = i + 1
    if prefix < len(arch.period):
        overrides.setdefault("period", arch.period[:prefix])
    overrides.setdefault("n_layers", max(2, prefix))
    return reduced(arch, **overrides)


def _batch(cfg, seed=0):
    ds = SyntheticPackedDataset(cfg, S, B, seed=seed)
    batch = {k: jnp.asarray(v) for k, v in ds.batch_at(0).items()}
    if cfg.enc_dec:
        Sd = max(S // cfg.dec_ratio, 16)
        rng = np.random.default_rng(seed)
        batch = {
            "frame_embeds": jnp.asarray(
                rng.normal(size=(B, S, cfg.d_model)).astype(np.float32)),
            "enc_segment_ids": jnp.ones((B, S), jnp.int32),
            "enc_positions": jnp.tile(jnp.arange(S, dtype=jnp.int32), (B, 1)),
            "dec_tokens": jnp.asarray(
                rng.integers(1, cfg.vocab_size, size=(B, Sd)).astype(np.int32)),
            "dec_segment_ids": jnp.ones((B, Sd), jnp.int32),
            "dec_positions": jnp.tile(jnp.arange(Sd, dtype=jnp.int32), (B, 1)),
            "labels": jnp.asarray(
                rng.integers(0, cfg.vocab_size, size=(B, Sd)).astype(np.int32)),
        }
    elif cfg.vlm:
        batch["vision_embeds"] = jnp.zeros((B, S // 4, cfg.d_model), jnp.bfloat16)
        batch["positions"] = jnp.tile(
            batch["positions"][..., None], (1, 1, 3)).astype(jnp.int32)
    return batch


@pytest.mark.slow
@pytest.mark.parametrize("arch_id", ASSIGNED_ARCHS)
def test_forward_shapes_finite(arch_id):
    cfg = _smoke_cfg(arch_id)
    params, _ = split_annotations(stacked_init(jax.random.PRNGKey(0), cfg))
    batch = _batch(cfg)
    logits, aux = forward_train(cfg, params, batch, NULL_POLICY, remat=False,
                                flash_chunk=32)
    S_out = batch["dec_tokens"].shape[1] if cfg.enc_dec else S
    assert logits.shape == (B, S_out, cfg.padded_vocab)
    assert bool(jnp.isfinite(logits.astype(jnp.float32)).all())


@pytest.mark.slow
@pytest.mark.parametrize("arch_id", ASSIGNED_ARCHS)
def test_train_step_no_nan(arch_id):
    cfg = _smoke_cfg(arch_id)
    opt = make_optimizer("adamw", lr=1e-3)
    state, _ = init_train_state(jax.random.PRNGKey(0), cfg, opt)
    step = build_train_step(cfg, NULL_POLICY, opt, microbatches=1, remat=False,
                            flash_chunk=32)
    batch = _batch(cfg)
    state, metrics = jax.jit(step)(state, batch)
    assert bool(jnp.isfinite(metrics["loss"]))
    assert float(metrics["grad_norm"]) > 0


@pytest.mark.slow
@pytest.mark.parametrize("arch_id", ["qwen3-8b", "jamba-1.5-large-398b",
                                     "xlstm-1.3b", "gemma3-1b",
                                     "whisper-medium", "qwen3-moe-30b-a3b"])
def test_decode_step(arch_id):
    cfg = _smoke_cfg(arch_id)
    params, _ = split_annotations(stacked_init(jax.random.PRNGKey(0), cfg))
    params = jax.tree.map(lambda a: a.astype(jnp.bfloat16)
                          if a.dtype == jnp.float32 else a, params)
    max_len = 64
    cache = init_cache(cfg, B, max_len, cross_len=S if cfg.enc_dec else 0)
    batch = {
        "tokens": jnp.ones((B, 1), jnp.int32),
        "lengths": jnp.asarray([3, 7], jnp.int32),
    }
    if cfg.enc_dec:
        batch["cross_segment_ids"] = jnp.ones((B, S), jnp.int32)
        batch["cross_positions"] = jnp.tile(jnp.arange(S, dtype=jnp.int32), (B, 1))
    logits, new_cache = serve_forward(cfg, params, cache, batch, NULL_POLICY)
    assert logits.shape == (B, 1, cfg.padded_vocab)
    assert bool(jnp.isfinite(logits.astype(jnp.float32)).all())
    # cache was updated (some leaf changed)
    changed = any(
        bool(jnp.any(a != b))
        for a, b in zip(jax.tree.leaves(cache), jax.tree.leaves(new_cache))
    )
    assert changed


def test_train_step_sentinel_fast():
    """Tier-1 sentinel: one tiny dense arch through a full train step, so the
    model/train path keeps coverage when -m 'not slow' skips the arch sweep."""
    cfg = _smoke_cfg("qwen3-8b")
    opt = make_optimizer("adamw", lr=1e-3)
    state, _ = init_train_state(jax.random.PRNGKey(0), cfg, opt)
    step = build_train_step(cfg, NULL_POLICY, opt, microbatches=1, remat=False,
                            flash_chunk=32)
    state, metrics = jax.jit(step)(state, _batch(cfg))
    assert bool(jnp.isfinite(metrics["loss"]))
    assert float(metrics["grad_norm"]) > 0


def test_prefill_then_decode_consistency():
    """Greedy decode after prefill matches teacher-forced argmax next token."""
    cfg = reduced(get_arch("qwen3-8b"))
    params, _ = split_annotations(stacked_init(jax.random.PRNGKey(1), cfg))
    rng = np.random.default_rng(0)
    T = 16
    tokens = jnp.asarray(rng.integers(1, cfg.vocab_size, size=(1, T)), jnp.int32)
    batch = {
        "tokens": tokens,
        "segment_ids": jnp.ones((1, T), jnp.int32),
        "positions": jnp.arange(T, dtype=jnp.int32)[None],
    }
    logits_full, _ = forward_train(cfg, params, batch, NULL_POLICY, remat=False,
                                   flash_chunk=T, compute_dtype=jnp.float32)
    last_logits, caches = prefill_forward(cfg, params, batch, NULL_POLICY,
                                          flash_chunk=T,
                                          compute_dtype=jnp.float32)
    np.testing.assert_allclose(
        np.asarray(last_logits[0, 0]), np.asarray(logits_full[0, -1]),
        rtol=2e-4, atol=2e-4)
