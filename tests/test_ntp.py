"""Nonuniform TP shard widths (NTP, arxiv 2504.06095): planning invariants,
the shrink-shard vs exclusion decision rule, execution parity across engines,
default-off behavior, plan-cache mode separation, and the acceptance win on
the many-mild-stragglers scenario family.
"""
import pytest

from repro.cluster import scenarios
from repro.cluster.simulator import SimConfig, TrainingSim
from repro.core.scheduler.plan import NTP_EFFICIENCY, StagePlan, initial_plan
from repro.core.scheduler.scheduler import Scheduler
from repro.core.scheduler.tp_reconfig import (
    NTPConfig,
    backfill_from_standby,
    reconfigure_tp_group,
    shrink_shard_candidate,
)


# ------------------------------------------------------- StagePlan invariants
def test_shard_fractions_default_none():
    st = StagePlan((0, 1, 2, 3), (0, 1))
    assert st.shard_fractions is None


def test_shard_fractions_must_match_devices():
    with pytest.raises(ValueError, match="one width per device"):
        StagePlan((0, 1, 2), (0,), shard_fractions=(0.5, 0.5))


def test_shard_fractions_must_be_positive():
    with pytest.raises(ValueError, match="positive"):
        StagePlan((0, 1), (0,), shard_fractions=(1.0, 0.0))


def test_shard_fractions_must_sum_to_one():
    with pytest.raises(ValueError, match="sum to 1"):
        StagePlan((0, 1), (0,), shard_fractions=(0.7, 0.7))
    # float roundoff within tolerance is fine
    StagePlan((0, 1, 2), (0,), shard_fractions=(1 / 3, 1 / 3, 1 / 3))


def test_summary_marks_nonuniform_widths():
    plan = initial_plan(8, 1, 2, 2)
    plan = plan.with_stage(0, 0, StagePlan((0, 1), (0, 1, 2, 3),
                                           shard_fractions=(0.6, 0.4)))
    assert "w[0.60/0.40]" in plan.summary()


# ------------------------------------------------- shrink-shard decision rule
def test_shrink_widths_proportional_to_speed():
    sp = {0: 1.0, 1: 1.0, 2: 1.0, 3: 0.8}
    rec = shrink_shard_candidate([0, 1, 2, 3], sp, NTPConfig())
    # widths ∝ p_i  =>  f_i / p_i flat  =>  thru = efficiency * sum(p_i)
    assert rec.mode == "shrink"
    assert rec.effective_throughput == pytest.approx(NTP_EFFICIENCY * 3.8)
    ratios = [f / sp[d] for d, f in zip(rec.devices, rec.shard_fractions)]
    assert max(ratios) == pytest.approx(min(ratios))
    assert sum(rec.shard_fractions) == pytest.approx(1.0)


def test_shrink_beats_exclusion_on_mild_straggler():
    # exclusion on a 4-group with one 0.8 member: max(4*0.8, 2*1.0) = 3.2;
    # shrink keeps all four at efficiency * 3.8 = 3.496
    sp = {0: 1.0, 1: 1.0, 2: 1.0, 3: 0.8}
    rec = reconfigure_tp_group([0, 1, 2, 3], sp, ntp=NTPConfig())
    assert rec.mode == "shrink" and rec.tp == 4
    assert rec.effective_throughput == pytest.approx(NTP_EFFICIENCY * 3.8)
    # without the ntp switch the same call is the legacy exclusion result
    legacy = reconfigure_tp_group([0, 1, 2, 3], sp)
    assert legacy.mode == "exclude"
    assert legacy.effective_throughput == pytest.approx(3.2)


def test_exclusion_wins_on_severe_straggler():
    # a 0.2 member: shrink = 0.92 * 3.2 = 2.944 < exclusion 2*1.0... no:
    # exclusion best is k=2 -> 2.0? k=4*0.2=0.8; but shrink keeps the slow
    # device's sum: 0.92*3.2 = 2.944 > 2.0, so to see exclusion win we need
    # a healthy group where the discount is pure loss
    sp = {d: 1.0 for d in range(4)}
    rec = reconfigure_tp_group(list(range(4)), sp, ntp=NTPConfig())
    assert rec.mode == "exclude" and rec.shard_fractions is None
    assert rec.effective_throughput == pytest.approx(4.0)


def test_shrink_respects_k_min_memory_floor():
    # k_min=2 caps any width at 1/2; excess water-fills onto the others
    sp = {0: 1.0, 1: 0.05, 2: 0.05}
    ntp = NTPConfig(min_fraction=0.01)
    rec = shrink_shard_candidate([0, 1, 2], sp, ntp, k_min=2)
    assert rec is not None
    assert max(rec.shard_fractions) <= 0.5 + 1e-9
    assert sum(rec.shard_fractions) == pytest.approx(1.0)
    # unconstrained, device 0 would have taken 1.0/1.1 ≈ 0.91 of the model


def test_shrink_drops_sliver_devices_to_standby():
    # a 0.02-speed device would earn a ~0.7% shard: below min_fraction it
    # goes to standby instead of occupying a rank in every collective
    sp = {0: 1.0, 1: 1.0, 2: 1.0, 3: 0.02}
    rec = shrink_shard_candidate([0, 1, 2, 3], sp, NTPConfig(min_fraction=0.04))
    assert rec.devices == (0, 1, 2)
    assert rec.standby == (3,)


def test_shrink_infeasible_below_two_members():
    assert shrink_shard_candidate([0], {0: 0.9}, NTPConfig()) is None


def test_backfill_carries_ntp_mode():
    # first failure leaves a standby; the second hit re-selects over the
    # pool — with ntp the backfilled group takes nonuniform widths
    rec = reconfigure_tp_group([0, 1, 2, 3], {0: 1.0, 1: 0.0, 2: 1.0, 3: 1.0})
    assert rec.standby
    sp = {0: 1.0, 1: 0.0, 2: 0.75, 3: 1.0}
    rec2 = backfill_from_standby(rec, sp, ntp=NTPConfig())
    assert rec2.mode == "shrink"
    assert set(rec2.devices) == {0, 2, 3}
    assert rec2.effective_throughput == pytest.approx(NTP_EFFICIENCY * 2.75)
    # exclusion-only backfill on the same pool keeps uniform shards
    rec3 = backfill_from_standby(rec, sp)
    assert rec3.mode == "exclude" and rec3.shard_fractions is None


# --------------------------------------------------------- Scheduler wiring
def test_adapt_emits_ntp_plan_and_notes():
    plan = initial_plan(8, dp=1, pp=2, tp=4)
    sch = Scheduler(layer_costs=[1.0] * 8, ntp=True)
    speeds = {d: 1.0 for d in plan.devices}
    speeds[3] = 0.8
    ad = sch.adapt(plan, speeds)
    st = ad.plan.replicas[0].stages[0]
    assert st.shard_fractions is not None and len(st.shard_fractions) == 4
    # the NTP group throughput (not k*min) feeds the stage-speed view
    assert ad.stage_speeds[(0, 0)] == pytest.approx(NTP_EFFICIENCY * 3.8 / 4)
    assert any("shrink-shard" in n for n in ad.notes)


def test_adapt_ntp_off_is_byte_identical_legacy():
    plan = initial_plan(8, dp=1, pp=2, tp=4)
    speeds = {d: 1.0 for d in plan.devices}
    speeds[3] = 0.8
    legacy = Scheduler(layer_costs=[1.0] * 8).adapt(plan, speeds)
    off = Scheduler(layer_costs=[1.0] * 8, ntp=None).adapt(plan, speeds)
    assert off.plan == legacy.plan
    assert off.stage_speeds == legacy.stage_speeds
    assert all(st.shard_fractions is None
               for rep in off.plan.replicas for st in rep.stages)


def test_repartition_preserves_shard_fractions():
    # a shrunk stage that also gets a new layer split must keep its widths
    plan = initial_plan(16, dp=1, pp=2, tp=4)
    sch = Scheduler(layer_costs=[1.0] * 16, ntp=True,
                    repartition_rel_threshold=0.0)
    speeds = {d: 1.0 for d in plan.devices}
    speeds[3] = 0.7  # stage 0 shrinks AND deserves fewer layers
    ad = sch.adapt(plan, speeds)
    st = ad.plan.replicas[0].stages[0]
    assert st.shard_fractions is not None
    assert st.n_layers < 8  # repartition moved layers off the slow stage


def test_ntp_stage_reverts_to_uniform_on_recovery():
    plan = initial_plan(8, dp=1, pp=2, tp=4)
    sch = Scheduler(layer_costs=[1.0] * 8, ntp=True)
    speeds = {d: 1.0 for d in plan.devices}
    speeds[3] = 0.8
    shrunk = sch.adapt(plan, speeds).plan
    assert shrunk.replicas[0].stages[0].shard_fractions is not None
    healed = sch.adapt(shrunk, {d: 1.0 for d in plan.devices}).plan
    assert healed.replicas[0].stages[0].shard_fractions is None


def test_plan_cache_distinguishes_ntp_mode():
    """Satellite: the cache signature must separate exclude-mode from
    shrink-shard-mode results for the *same* failure set — a scheduler whose
    ntp config changes between calls must not serve the other mode's plan."""
    plan = initial_plan(8, dp=1, pp=2, tp=4)
    sch = Scheduler(layer_costs=[1.0] * 8)
    speeds = {d: 1.0 for d in plan.devices}
    speeds[3] = 0.8
    excl = sch.adapt(plan, speeds)
    assert excl.plan.replicas[0].stages[0].shard_fractions is None
    sch.ntp = NTPConfig()
    ntp = sch.adapt(plan, speeds)
    assert ntp is not excl
    assert ntp.plan.replicas[0].stages[0].shard_fractions is not None
    # both entries stay cached under their own mode key
    sch.ntp = None
    assert sch.adapt(plan, speeds) is excl
    sch.ntp = NTPConfig()
    assert sch.adapt(plan, speeds) is ntp


# -------------------------------------------------------- execution parity
CFG = SimConfig(dp=2, pp=2, tp=2, n_layers=8, n_microbatches=4,
                seq_len=2048, noise=0.01, seed=0)


def _run(engine, *, ntp):
    kw = {"plan_overhead_fixed": 0.25}
    if ntp:
        kw["ntp"] = True
    sim = TrainingSim("resihp", CFG, policy_kwargs=kw, engine=engine)
    # short span: this config's iterations are ~0.08s, so the throttle
    # events must land early enough for detection within the 40-iter run
    sim.apply_scenario(scenarios.get("thermal_throttle_fleet", span=3.0,
                                     frac=0.5))
    sim.run(40, stop_on_abort=False)
    return sim


def test_fast_python_parity_on_ntp_plans():
    a, b = _run("python", ntp=True), _run("fast", ntp=True)
    sa = [(r.iteration, r.t_start, r.duration, r.throughput) for r in a.trace]
    sb = [(r.iteration, r.t_start, r.duration, r.throughput) for r in b.trace]
    assert sa == sb  # exact floats — the fast engine's contract is identity
    assert a.avg_throughput(skip=2) == b.avg_throughput(skip=2)
    # the run actually exercised nonuniform widths (else this test is hollow)
    assert any(st.shard_fractions is not None
               for sim in (a, b)
               for rep in sim._decision.plan.replicas for st in rep.stages)


def test_ntp_default_off_in_sim():
    # without the switch nothing in the pipeline produces shard fractions —
    # the golden regression (test_simulator_golden) pins the full behavior
    sim = _run("fast", ntp=False)
    assert sim.policy.ntp is None and sim.policy.scheduler.ntp is None
    assert all(st.shard_fractions is None
               for rep in sim._decision.plan.replicas for st in rep.stages)


# ------------------------------------------------------------ acceptance win
def test_ntp_beats_exclusion_on_thermal_throttle_fleet():
    """The adaptation-axis acceptance: on the many-mild-stragglers family,
    shrink-shard (efficiency * sum p) must beat exclusion-only planning
    (k * min p) on both per-iteration and elapsed-time throughput — the same
    comparison the nightly ``resihp+ntp`` quick row surfaces."""
    from benchmarks.bench_scenarios import run

    base = run("llama2-13b", "thermal_throttle_fleet", "resihp", iters=80)
    ntp = run("llama2-13b", "thermal_throttle_fleet", "resihp+ntp", iters=80)
    assert not base["aborted"] and not ntp["aborted"]
    assert ntp["throughput"] > base["throughput"]
    assert ntp["session_throughput"] > base["session_throughput"]
