"""Heterogeneous-TP P2P mapping (§7, Fig. 7): coverage, single-crossing,
byte accounting."""
import pytest
from _ht import given, settings, strategies as st

from repro.core.scheduler.p2p import (
    chunk_slices,
    p2p_cost_bytes,
    p2p_mapping,
    p2p_time,
)

POW2 = [1, 2, 4, 8]


@settings(max_examples=30, deadline=None)
@given(ts=st.sampled_from(POW2), tr=st.sampled_from(POW2))
def test_mapping_covers_every_chunk_once(ts, tr):
    mapping = p2p_mapping(ts, tr)
    n = max(ts, tr)
    chunks = [c for _, _, c in mapping]
    assert sorted(chunks) == list(range(n))  # each chunk crosses exactly once
    for s, r, c in mapping:
        assert 0 <= s < ts and 0 <= r < tr
        # chunk c lives in sender rank c*ts//n and lands on receiver c*tr//n
        assert s == c * ts // n and r == c * tr // n


def test_mapping_balanced():
    """Each sender ships n/ts chunks; each receiver gets n/tr chunks."""
    for ts, tr in [(4, 2), (2, 4), (8, 1), (4, 4)]:
        mapping = p2p_mapping(ts, tr)
        n = max(ts, tr)
        from collections import Counter
        sc = Counter(s for s, _, _ in mapping)
        rc = Counter(r for _, r, _ in mapping)
        assert all(v == n // ts for v in sc.values())
        assert all(v == n // tr for v in rc.values())


def test_scatter_gather_saves_bytes():
    """Fig. 7: naive resends the tensor tp_recv times; the rule sends once."""
    t = 10 * 2**20
    assert p2p_cost_bytes(t, 4, 4, scatter_gather=False) == 4 * t
    assert p2p_cost_bytes(t, 4, 4, scatter_gather=True) == t
    assert p2p_cost_bytes(t, 2, 4, scatter_gather=True) == t  # hetero degrees too


def test_p2p_time_monotone_in_bytes():
    assert p2p_time(2**20, 4, 2) < p2p_time(2**24, 4, 2)
    # scatter/gather beats naive for any multi-rank receiver
    assert p2p_time(2**24, 4, 4, scatter_gather=True) < p2p_time(
        2**24, 4, 4, scatter_gather=False)


def test_chunk_slices_partition_dim():
    slices = chunk_slices(1024, 4, 2)
    assert len(slices) == 4
    covered = set()
    for sl in slices:
        covered |= set(range(sl.start, sl.stop))
    assert covered == set(range(1024))


def test_non_pow2_rejected():
    with pytest.raises(AssertionError):
        p2p_mapping(3, 2)
