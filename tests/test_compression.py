"""int8 + error-feedback gradient compression (pod-axis DCN reduce)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _ht import given, settings, strategies as st

from repro.train.compression import (
    Int8Compressor,
    compress_tree,
    init_feedback,
)


def test_roundtrip_error_bounded():
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(1000,)).astype(np.float32))
    comp = Int8Compressor(block=128)
    q, s, meta = comp.compress(x)
    deq = comp.decompress(q, s, meta)
    # per-block max-scaled int8: error <= scale/2 = max|block|/254
    blocks = np.asarray(x[:1000 // 128 * 128]).reshape(-1, 128)
    bound = np.abs(blocks).max(axis=1) / 254.0 + 1e-7
    err = np.abs(np.asarray(deq)[:blocks.size].reshape(-1, 128) - blocks)
    assert (err <= bound[:, None] + 1e-6).all()


def test_compression_ratio():
    comp = Int8Compressor(block=256)
    x = jnp.zeros((4096, 512), jnp.float32)
    assert comp.ratio(x) > 3.9  # ~4x for f32 payloads


@settings(max_examples=20, deadline=None)
@given(n=st.integers(1, 2000), block=st.sampled_from([64, 128, 256]))
def test_roundtrip_any_shape(n, block):
    rng = np.random.default_rng(n)
    x = jnp.asarray(rng.normal(size=(n,)).astype(np.float32)) * 10
    comp = Int8Compressor(block=block)
    q, s, meta = comp.compress(x)
    deq = comp.decompress(q, s, meta)
    assert deq.shape == x.shape
    assert float(jnp.abs(deq - x).max()) <= float(jnp.abs(x).max()) / 100.0


def test_error_feedback_converges():
    """With error feedback, the *accumulated* compressed sum tracks the true
    gradient sum (the residual never grows unboundedly)."""
    rng = np.random.default_rng(1)
    comp = Int8Compressor(block=64)
    true_sum = np.zeros(256, np.float32)
    sent_sum = np.zeros(256, np.float32)
    residual = jnp.zeros(256, jnp.float32)
    for step in range(50):
        g = jnp.asarray(rng.normal(size=(256,)).astype(np.float32))
        true_sum += np.asarray(g)
        deq, residual = comp.roundtrip_with_feedback(g, residual)
        sent_sum += np.asarray(deq)
    # everything not yet sent lives in the residual
    np.testing.assert_allclose(sent_sum + np.asarray(residual), true_sum,
                               rtol=1e-4, atol=1e-3)
    assert float(jnp.abs(residual).max()) < 1.0  # bounded


def test_compress_tree():
    params = {"w": jnp.ones((64, 32)), "b": jnp.full((7,), 0.5)}
    res = init_feedback(params)
    comp = Int8Compressor(block=32)
    deq, new_res = compress_tree(comp, params, res)
    assert jax.tree.structure(deq) == jax.tree.structure(params)
    np.testing.assert_allclose(np.asarray(deq["w"]), 1.0, rtol=0.02)


def test_jittable():
    comp = Int8Compressor(block=64)
    f = jax.jit(lambda g, r: comp.roundtrip_with_feedback(g, r))
    g = jnp.ones((128,), jnp.float32)
    deq, r = f(g, jnp.zeros((128,), jnp.float32))
    assert bool(jnp.isfinite(deq).all())
