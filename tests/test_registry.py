"""Array-native ClusterState: the ground truth lives in dense numpy arrays,
the legacy dict/object API is a write-through adapter view, and every
mutation bumps ``version`` / invalidates the cached slices — the contract
the simulator hot path (validation scan, heartbeat mask, stage-speed cache)
keys on."""
import numpy as np
import pytest

from repro.cluster.registry import ClusterState, ClusterTopology, DeviceView


@pytest.fixture
def cluster():
    return ClusterState(ClusterTopology(2, devices_per_node=4))


def test_adapter_view_reads_arrays(cluster):
    d = cluster.devices[3]
    assert isinstance(d, DeviceView)
    assert d.id == 3 and d.node == 0
    assert d.alive and d.speed == 1.0 and d.net_scale == 1.0
    assert d.effective == 1.0
    assert cluster.devices[4].node == 1


def test_adapter_view_writes_through_and_bumps_version(cluster):
    v0 = cluster.version
    cluster.devices[2].speed = 0.25
    assert cluster.version > v0
    assert cluster.effective()[2] == 0.25
    assert cluster.speeds()[2] == 0.25
    cluster.devices[2].alive = False
    assert cluster.effective()[2] == 0.0


def test_device_map_is_dict_shaped(cluster):
    n = cluster.topo.n_devices
    assert len(cluster.devices) == n
    assert list(cluster.devices) == list(range(n))
    assert list(cluster.devices.keys()) == list(range(n))
    assert [i for i, _ in cluster.devices.items()] == list(range(n))
    assert all(d.alive for d in cluster.devices.values())
    assert 0 in cluster.devices and n not in cluster.devices
    with pytest.raises(KeyError):
        cluster.devices[n]


def test_cached_slices_invalidate_on_every_mutator(cluster):
    """speeds()/effective() are rebuilt lazily after each injection method —
    stale reads would mean the simulator plans against dead state."""
    assert cluster.speeds() is cluster.speeds()  # cached between mutations
    cluster.fail_stop(1)
    assert cluster.speeds()[1] == 0.0
    cluster.fail_slow(2, 0.5)
    assert cluster.speeds()[2] == 0.5
    cluster.degrade_network(0, 0.25)
    eff = 1.0 / (0.7 + 0.3 / 0.25)
    assert cluster.speeds()[0] == pytest.approx(eff)
    assert cluster.speeds()[1] == 0.0  # dead stays dead through net events
    cluster.restore_network(0)
    assert cluster.speeds()[0] == 1.0
    assert cluster.speeds()[2] == 0.5  # compute straggler stays slow
    cluster.repair(1, now=7.0)
    assert cluster.speeds()[1] == 1.0


def test_effective_and_alive_mask_are_read_only_views(cluster):
    eff = cluster.effective()
    mask = cluster.alive_mask()
    for arr in (eff, mask):
        with pytest.raises(ValueError):
            arr[0] = 0
    cluster.fail_stop(0)
    assert not cluster.alive_mask()[0] and cluster.effective()[0] == 0.0


def test_effective_matches_device_property_bit_for_bit(cluster):
    cluster.fail_slow(1, 1.0 / 3.0)
    cluster.degrade_network(0, 1.0 / 7.0)
    eff = cluster.effective()
    for i, dev in cluster.devices.items():
        assert eff[i] == dev.effective  # exact float equality


def test_node_bookkeeping(cluster):
    assert cluster.node_devices(0) == [0, 1, 2, 3]
    assert cluster.node_devices(1) == [4, 5, 6, 7]
    assert list(cluster.node_of) == [0, 0, 0, 0, 1, 1, 1, 1]
    cluster.fail_stop_node(1)
    assert cluster.alive_ids() == [0, 1, 2, 3]


def test_injection_log_format_unchanged(cluster):
    cluster.fail_stop(1, now=1.0)
    cluster.fail_slow(2, 0.5, now=2.0)
    cluster.repair(1, now=3.0, speed=0.9)
    assert cluster.events == [
        (1.0, "fail-stop", 1, 0.0),
        (2.0, "fail-slow", 2, 0.5),
        (3.0, "repair", 1, 0.9),
    ]


def test_age_tracks_last_service_entry(cluster):
    assert list(cluster.ages(10.0)) == [10.0] * 8
    cluster.fail_stop(3, now=4.0)
    cluster.repair(3, now=6.0)
    ages = cluster.ages(10.0)
    assert ages[3] == 4.0 and ages[0] == 10.0
