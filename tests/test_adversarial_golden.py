"""Golden regression pins for the mined adversarial_* scenario family.

The top-3 mined worst cases (see ``tools/mine_scenarios.py`` and
``results/adversarial_mined.json``) become permanent tier-1 guardrails:
per-policy session throughput at the 256-device mining scale, fixed seed,
pinned exactly — the same mechanism as ``test_simulator_golden.py``. A
policy or engine change that regresses (or silently "improves") behavior on
the worst found failure patterns shows up as a diff here.

Regenerate (after an *intentional* behavior change) with:

    PYTHONPATH=src:tests python -c "import test_adversarial_golden as g; g.regenerate()"

and re-run ``python tools/mine_scenarios.py --quick`` so the artifact keeps
matching (tests/test_mining.py pins the two against each other).
"""
import json
from pathlib import Path

import pytest

from repro.cluster import mining, scenarios
from repro.cluster.simulator import TrainingSim

GOLDEN_PATH = Path(__file__).parent / "golden" / "adversarial_golden.json"
ARTIFACT = Path(__file__).parent.parent / "results" / "adversarial_mined.json"

NAMES = ("adversarial_1", "adversarial_2", "adversarial_3")
ITERS = 30  # the mining recipe's session length


def _run(name: str) -> dict:
    cfg = mining.mining_config()
    out = {}
    for label in sorted(mining.POLICIES):
        policy, policy_kw = mining.POLICIES[label]
        sim = TrainingSim(policy, cfg, engine="fast", policy_kwargs=policy_kw)
        sim.apply_scenario(scenarios.get(name))
        sim.run(ITERS, stop_on_abort=False)
        out[label] = {
            "session_throughput": sim.session_throughput(skip=2),
            "avg_throughput": sim.avg_throughput(skip=2),
            "aborted": sim.aborted,
            "n_fired": len(sim.event_log),
        }
    return out


def _observed() -> dict:
    return {name: _run(name) for name in NAMES}


def regenerate():
    GOLDEN_PATH.parent.mkdir(parents=True, exist_ok=True)
    GOLDEN_PATH.write_text(json.dumps(_observed(), indent=1))
    print(f"wrote {GOLDEN_PATH}")


@pytest.fixture(scope="module")
def golden():
    assert GOLDEN_PATH.exists(), "golden missing - run regenerate()"
    return json.loads(GOLDEN_PATH.read_text())


@pytest.fixture(scope="module")
def observed():
    return json.loads(json.dumps(_observed()))


@pytest.mark.parametrize("name", NAMES)
def test_per_policy_session_throughput_matches_golden(name, golden, observed):
    for label, pinned in golden[name].items():
        got = observed[name][label]
        assert got["aborted"] == pinned["aborted"], (name, label)
        assert got["n_fired"] == pinned["n_fired"], (name, label)
        assert got["session_throughput"] == pytest.approx(
            pinned["session_throughput"], rel=1e-9), (name, label)
        assert got["avg_throughput"] == pytest.approx(
            pinned["avg_throughput"], rel=1e-9), (name, label)


def test_golden_agrees_with_mined_artifact(golden):
    """The golden pins and results/adversarial_mined.json describe the same
    runs: the artifact's recorded per-policy sessions match the pins."""
    report = json.loads(ARTIFACT.read_text())
    assert report["config"]["iters"] == ITERS
    for entry in report["family"]:
        name = f"adversarial_{entry['rank']}"
        for label, sess in entry["session_throughput"].items():
            assert golden[name][label]["session_throughput"] == pytest.approx(
                sess, rel=1e-9), (name, label)


def test_family_worst_case_beats_hand_authored_catalog(golden):
    """The acceptance bar, pinned: at the mining scale at least one mined
    scenario degrades resihp session throughput below every hand-authored
    catalog scenario's worst (recorded in the artifact's catalog table)."""
    report = json.loads(ARTIFACT.read_text())
    worst_catalog = min(
        c["session_throughput"]["resihp"] for c in report["catalog"].values())
    worst_mined = min(
        golden[n]["resihp"]["session_throughput"] for n in NAMES)
    assert worst_mined < worst_catalog
