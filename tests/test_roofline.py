"""HLO walker validation: against XLA cost_analysis on unrolled graphs, and
while-loop trip-count scaling on scanned graphs."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.roofline.analysis import V5E, model_flops, roofline_terms
from repro.roofline.hlo import analyze_hlo_text


def _compile(fn, *args):
    lowered = jax.jit(fn).lower(*args)
    return lowered.compile()


def _xla_flops(compiled) -> float:
    # cost_analysis() returns a dict on newer jax, [dict] on older versions
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0]
    return float(ca["flops"])


def test_walker_matmul_flops_match_cost_analysis():
    A = jax.ShapeDtypeStruct((256, 512), jnp.float32)
    B = jax.ShapeDtypeStruct((512, 128), jnp.float32)
    compiled = _compile(lambda a, b: a @ b, A, B)
    cost = analyze_hlo_text(compiled.as_text())
    expect = 2 * 256 * 512 * 128
    assert cost.matmul_flops == pytest.approx(expect, rel=0.01)
    assert cost.flops == pytest.approx(_xla_flops(compiled), rel=0.05)


def test_walker_unrolled_chain_matches_cost_analysis():
    x = jax.ShapeDtypeStruct((128, 256), jnp.float32)
    w = jax.ShapeDtypeStruct((256, 256), jnp.float32)

    def chain(x, w):
        for _ in range(4):
            x = jnp.tanh(x @ w)
        return x

    compiled = _compile(chain, x, w)
    cost = analyze_hlo_text(compiled.as_text())
    assert cost.flops == pytest.approx(_xla_flops(compiled), rel=0.1)
    assert cost.matmul_flops == pytest.approx(4 * 2 * 128 * 256 * 256, rel=0.01)


def test_walker_scales_while_loops():
    """XLA cost_analysis does NOT multiply while bodies by trip count; the
    walker must. A scanned 8-step matmul chain should cost ~8x one step."""
    x = jax.ShapeDtypeStruct((128, 256), jnp.float32)
    w = jax.ShapeDtypeStruct((8, 256, 256), jnp.float32)

    def scanned(x, w):
        def body(c, wi):
            return jnp.tanh(c @ wi), ()
        out, _ = jax.lax.scan(body, x, w)
        return out

    compiled = _compile(scanned, x, w)
    cost = analyze_hlo_text(compiled.as_text())
    per_step = 2 * 128 * 256 * 256
    assert cost.matmul_flops == pytest.approx(8 * per_step, rel=0.05)
    # and confirm XLA itself undercounts (the reason the walker exists)
    assert _xla_flops(compiled) < 0.5 * cost.matmul_flops


def test_walker_collective_bytes():
    if len(jax.devices()) < 2:
        pytest.skip("needs >1 device for real collectives")


def test_walker_psum_spmd():
    """Collective bytes via an SPMD all-reduce (single-device fallback: the
    graph may omit the collective, so only assert when present)."""
    mesh = jax.make_mesh((1,), ("d",))
    x = jax.ShapeDtypeStruct((128, 128), jnp.float32)
    compiled = jax.jit(lambda a: a * 2).lower(x).compile()
    cost = analyze_hlo_text(compiled.as_text())
    assert cost.total_collective_bytes == 0.0


def test_model_flops_dense_vs_moe():
    from repro.configs import SHAPES_BY_NAME, get_arch

    shape = SHAPES_BY_NAME["train_4k"]
    dense = get_arch("qwen3-8b")
    moe = get_arch("qwen3-moe-30b-a3b")
    fd = model_flops(dense, shape, include_attention=False)
    fm = model_flops(moe, shape, include_attention=False)
    tokens = shape.global_batch * shape.seq_len
    assert fd == pytest.approx(6 * dense.param_count() * tokens, rel=1e-6)
    # MoE uses ACTIVE params
    assert fm == pytest.approx(6 * moe.active_param_count() * tokens, rel=1e-6)
    assert fm < 6 * moe.param_count() * tokens * 0.5


def test_roofline_terms_structure():
    from repro.roofline.hlo import HloCost

    cost = HloCost(flops=1e12, matmul_flops=9e11, hbm_bytes=1e9,
                   collective_bytes={"all-reduce": 5e8})
    terms = roofline_terms(cost, 256)
    assert terms["compute_s"] == pytest.approx(1e12 / V5E.peak_flops)
    assert terms["memory_s"] == pytest.approx(1e9 / V5E.hbm_bw)
    assert terms["collective_s"] == pytest.approx(5e8 / V5E.ici_bw)
    assert terms["bound"] == "collective"
