"""Cluster simulator: paper §8 qualitative claims hold in the sim harness."""
import math

import pytest

from repro.cluster.simulator import SimConfig, TrainingSim

CFG = SimConfig(dp=2, pp=4, tp=4, n_layers=40, n_microbatches=8,
                seq_len=8192, noise=0.01)


def _run(policy, injections=(), iters=100, cfg=CFG, **kw):
    sim = TrainingSim(policy, cfg, **kw)
    for t, fn in injections:
        sim.inject_at(t, fn)
    sim.run(iters)
    return sim


def test_healthy_policies_equal():
    ths = {p: _run(p, iters=25).avg_throughput(skip=2)
           for p in ("resihp", "recycle", "oobleck", "greyhound")}
    base = ths["resihp"]
    for p, v in ths.items():
        assert abs(v - base) / base < 0.05, (p, v, base)


def test_failstop_ordering_matches_table6():
    inj = [(10.0, lambda c, now: c.fail_stop(5, now))]
    r = {p: _run(p, inj).avg_throughput(skip=2)
         for p in ("resihp", "recycle", "oobleck")}
    assert r["resihp"] > r["recycle"]
    assert r["resihp"] >= r["oobleck"] * 0.98  # oobleck is the closer baseline
    g = _run("greyhound", inj)
    assert g.aborted  # no fail-stop story


def test_failslow_ordering_matches_fig9():
    inj = [(10.0, lambda c, now: c.fail_slow(5, 0.30, now))]
    r = {p: _run(p, inj).avg_throughput(skip=2)
         for p in ("resihp", "greyhound", "adaptra", "recycle")}
    assert r["resihp"] > r["greyhound"] > r["recycle"]
    assert r["resihp"] > r["adaptra"]
    # unmitigated drop is severe; resihp recovers most of it
    healthy = _run("resihp", iters=25).avg_throughput(skip=2)
    assert r["recycle"] < 0.6 * healthy
    assert r["resihp"] > 0.8 * healthy


def test_mixed_strengthened_recycle_negligible_gain():
    """Fig. 10's key observation: strengthened ReCycle ~ vanilla ReCycle in
    mixed scenarios (it reassigns crashed-peer work onto degraded devices)."""
    inj = [
        (10.0, lambda c, now: c.fail_stop(5, now)),
        (40.0, lambda c, now: c.fail_slow(20, 0.45, now)),
    ]
    r_van = _run("recycle", inj, 140).avg_throughput(skip=2)
    r_str = _run("recycle+", inj, 140).avg_throughput(skip=2)
    r_resi = _run("resihp", inj, 140).avg_throughput(skip=2)
    assert abs(r_str - r_van) / r_van < 0.25  # negligible-to-modest gain
    assert r_resi > 1.5 * r_str  # paper: 1.22-4.32x over strengthened ReCycle


def test_detector_false_alarms_resihp_vs_greyhound():
    """Table 5: the workload filter kills false alarms; Greyhound pays
    validation on workload-induced change points."""
    resi = _run("resihp", iters=80)
    grey = _run("greyhound", iters=80)
    assert resi.detector.stats.false_alarms <= grey.detector.stats.false_alarms
    assert resi.detector.stats.validations <= grey.detector.stats.validations
    if grey.detector.stats.false_alarms:
        assert resi.detector.overhead_s < grey.detector.overhead_s


def test_failslow_detected_within_iters():
    sim = TrainingSim("resihp", CFG)
    sim.inject_at(10.0, lambda c, now: c.fail_slow(5, 0.4, now))
    sim.run(80)
    reports = [r for r in sim.detector.reports if r.kind == "fail-slow"]
    assert reports, "fail-slow never detected"
    # detected within a handful of iterations of the injection
    inj_iter = next(i for i, rec in enumerate(sim.trace)
                    if any(e[0] == "injection" for e in rec.events))
    assert reports[0].iteration - inj_iter <= 25


def test_rejoin_restores_throughput():
    cfg = CFG
    sim = TrainingSim("resihp", cfg)
    sim.inject_at(10.0, lambda c, now: c.fail_stop(5, now))
    sim.run(60)
    th_degraded = sim.avg_throughput(skip=40)
    sim.cluster.repair(5)
    sim.known_speeds[5] = 1.0
    sim._belief_dirty = True
    sim.run(60)
    th_restored = sim.avg_throughput(skip=len(sim.trace) - 20)
    assert th_restored > th_degraded


def test_aborted_run_reports_infinite_iteration():
    sim = _run("greyhound", [(10.0, lambda c, now: c.fail_stop(5, now))], 40)
    assert sim.aborted
    assert math.isinf(sim.trace[-1].duration)


def test_layer_transfer_charged_against_previous_plan():
    """Consecutive exclusion plans must pay only the *incremental* layer
    movement: the second reconfiguration diffs against the plan currently
    executing, not plan0 (which re-paid transfers for layers already in
    place) — and a recovery back to the plan0 layout pays to move the
    layers back instead of being charged zero."""
    from repro.cluster.baselines import ResiHPPolicy
    from repro.core.scheduler.plan import initial_plan

    plan0 = initial_plan(16, dp=2, pp=4, tp=4)
    pol = ResiHPPolicy(plan0, [1.0] * 16, plan_overhead_fixed=0.0,
                       group_rebuild_s=0.0, layer_transfer_s_per_layer=1.0)
    healthy = {d: 1.0 for d in plan0.devices}

    # failure in (replica 0, stage 1): repartition shrinks the stage
    speeds = dict(healthy)
    speeds[5] = 0.0
    first = pol.decide(speeds, changed=True)
    assert first.reconfig_overhead_s > 0.0
    moved_first = first.reconfig_overhead_s

    # identical failure state re-planned: same plan, nothing left to move
    again = pol.decide(speeds, changed=True)
    assert again.plan == first.plan
    assert again.reconfig_overhead_s == 0.0  # plan0-diff would re-pay here

    # recovery to the plan0 layout: the layers must move *back*, so the
    # charge equals the first move's volume (plan0-diff would charge 0.0)
    back = pol.decide(healthy, changed=True)
    assert back.plan.replicas[0].stages == plan0.replicas[0].stages
    assert back.reconfig_overhead_s == moved_first
