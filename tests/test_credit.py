"""Unified device credit score (``ResiHPPolicy(credit=...)``): model unit
contracts (monotonicity, clamping, config validation), the fitted-artifact
loader, per-device MTTF hazard priors, credit-off inertness, the offline
fit's determinism / worker-count invariance, and the multi-scale axis of
``benchmarks.bench_scenarios``.

The acceptance pins (fitted credit vs the best hand-tuned policy column per
family) live at the bottom and read the checked-in
``src/repro/configs/credit_fitted.json`` — regenerate it with
``PYTHONPATH=src python tools/fit_credit.py`` after touching the credit
path.
"""
import importlib.util
import json
import sys
from pathlib import Path

import pytest

from repro.cluster import scenarios
from repro.cluster.hazard import HazardEstimator, HazardPolicyConfig
from repro.cluster.simulator import SimConfig, TrainingSim
from repro.core.detector.credit import (FIT_FIELDS, FITTED_CONFIG_PATH,
                                        CreditConfig, CreditModel,
                                        fitted_credit_config)
from repro.core.detector.lifecycle import FailureHistory

REPO = Path(__file__).parent.parent
TINY = SimConfig(dp=2, pp=2, tp=2, n_layers=8, n_microbatches=4,
                 seq_len=2048, noise=0.01, seed=0)


def _load_fit_credit():
    """tools/ is not a package: import the fit driver by path. Registered in
    sys.modules so the process pool's pickle round-trip (fork start method)
    resolves ``fit_credit.eval_cell`` in the workers."""
    if "fit_credit" in sys.modules:
        return sys.modules["fit_credit"]
    spec = importlib.util.spec_from_file_location(
        "fit_credit", REPO / "tools" / "fit_credit.py")
    mod = importlib.util.module_from_spec(spec)
    sys.modules["fit_credit"] = mod
    spec.loader.exec_module(mod)
    return mod


def _hist(device=0, stops=(), slows=()):
    return FailureHistory(device=device, fail_stops=list(stops),
                          fail_slows=list(slows))


# ------------------------------------------------------------ credit model
def test_clean_history_scores_full_credit():
    m = CreditModel(CreditConfig(), 4)
    assert m.credit_of(_hist(), now=100.0) == 1.0


def test_credit_clamps_to_zero_under_heavy_evidence():
    # 10 in-window failures at alpha=0.2: risk_excess = 10/0.5 = 20 =>
    # the raw score is deeply negative and must clamp at exactly 0.0
    cfg = CreditConfig(alpha=0.2, beta=0.0, gamma=0.0, delta=0.0)
    m = CreditModel(cfg, 4)
    h = _hist(stops=[99.0] * 10)
    assert m.credit_of(h, now=100.0) == 0.0


def test_flap_pressure_is_monotone_and_exact():
    # beta alone: each recent fail-stop costs beta/flap_threshold
    cfg = CreditConfig(alpha=0.0, beta=0.25, gamma=0.0, delta=0.0)
    m = CreditModel(cfg, 4)
    now = 300.0
    prev = 1.0
    for n in (1, 2, 3):
        c = m.credit_of(_hist(stops=[now - 1.0] * n), now)
        assert c == pytest.approx(1.0 - 0.25 * n / cfg.flap_threshold)
        assert c < prev
        prev = c
    # a flap outside the window is forgiven
    old = m.credit_of(_hist(stops=[now - cfg.flap_window_s - 1.0]), now)
    assert old == 1.0


def test_drift_excess_tracks_worst_in_window_slow():
    cfg = CreditConfig(alpha=0.0, beta=0.0, gamma=0.5, delta=0.0)
    m = CreditModel(cfg, 4)
    now = 200.0
    mild = m.credit_of(_hist(slows=[(now - 1.0, 0.9)]), now)
    deep = m.credit_of(_hist(slows=[(now - 1.0, 0.9), (now - 5.0, 0.4)]), now)
    assert mild == pytest.approx(1.0 - 0.5 * 0.1)
    assert deep == pytest.approx(1.0 - 0.5 * 0.6)
    assert deep < mild
    # recovered: the slow aged out of the drift window
    aged = m.credit_of(
        _hist(slows=[(now - cfg.drift_window_s - 1.0, 0.4)]), now)
    assert aged == 1.0


def test_risk_excess_uses_hazard_estimator_when_attached():
    est = HazardEstimator(HazardPolicyConfig())
    cfg = CreditConfig(alpha=0.1, beta=0.0, gamma=0.0, delta=0.0)
    m = CreditModel(cfg, 4, hazard=est)
    h = _hist(stops=[99.0])
    # risk = 1 + 1/0.5 = 3.0 => excess 2.0
    assert m.credit_of(h, now=100.0) == pytest.approx(1.0 - 0.1 * 2.0)


def test_domain_elevation_pools_sibling_failures_only():
    cfg = CreditConfig(alpha=0.0, beta=0.0, gamma=0.0, delta=0.1)
    m = CreditModel(cfg, 4, domain_members={"pdu0": [0, 1], "pdu1": [2, 3]})
    now = 100.0
    hs = {0: _hist(0), 1: _hist(1, stops=[99.0]), 2: _hist(2)}
    # device 0's sibling (1) failed once in-window: elevation 1/0.5 = 2
    assert m.credit_of(hs[0], now, hs) == pytest.approx(1.0 - 0.1 * 2.0)
    # device 2 is in the other domain: untouched
    assert m.credit_of(hs[2], now, hs) == 1.0
    # the failing device itself is not its own sibling
    assert m.credit_of(hs[1], now, hs) == 1.0


def test_scores_sparse_dict_array_mirror_and_versioning():
    m = CreditModel(CreditConfig(alpha=0.0, beta=0.25, gamma=0.0,
                                 delta=0.0), 4)
    hs = {d: _hist(d) for d in range(4)}
    assert m.scores(hs, now=10.0) == {}
    assert m.version == 0  # nothing moved: no bump
    hs[2].fail_stops.append(9.0)
    out = m.scores(hs, now=10.0)
    assert set(out) == {2} and 0.0 < out[2] < 1.0
    assert m.version == 1
    assert m.arr[2] == out[2] and all(m.arr[d] == 1.0 for d in (0, 1, 3))
    m.scores(hs, now=10.0)  # unchanged scores: version stable
    assert m.version == 1


@pytest.mark.parametrize("bad", [
    dict(alpha=-0.1),
    dict(beta=-1.0),
    dict(quarantine_band=0.9, probe_band=0.5),
    dict(quarantine_band=-0.1),
    dict(ntp_band=1.5),
    dict(drift_filter_threshold=0.0),
    dict(drift_filter_threshold=1.1),
    dict(flap_threshold=0),
    dict(prior_failures=0.0),
    dict(domain="blast_radius"),
    dict(backoff_scale=-1.0),
    dict(validation_debounce_s=-1.0),
])
def test_credit_config_validation(bad):
    with pytest.raises(ValueError):
        CreditConfig(**bad)


# ---------------------------------------------------------- fitted loader
def test_fitted_config_falls_back_to_defaults_when_absent(tmp_path):
    assert fitted_credit_config(tmp_path / "nope.json") == CreditConfig()


def test_fitted_config_loads_fit_surface(tmp_path):
    p = tmp_path / "credit_fitted.json"
    p.write_text(json.dumps({"fitted": {"alpha": 0.1, "ntp_band": 0.6}}))
    cfg = fitted_credit_config(p)
    assert cfg.alpha == 0.1 and cfg.ntp_band == 0.6
    assert cfg.beta == CreditConfig().beta  # unlisted fields keep defaults


def test_fitted_config_rejects_non_fit_keys(tmp_path):
    p = tmp_path / "credit_fitted.json"
    p.write_text(json.dumps({"fitted": {"alpha": 0.1, "planning": False}}))
    with pytest.raises(ValueError, match="non-fit keys"):
        fitted_credit_config(p)


# ------------------------------------------------------------ hazard priors
def test_per_device_mttf_priors_scale_risk():
    cfg = HazardPolicyConfig(priors={3: 200.0})
    est = HazardEstimator(cfg)
    # fitted lemon: clean history already scores prior_time_s/mttf = 2x
    assert est.risk(_hist(3), 10.0) == pytest.approx(400.0 / 200.0)
    # no prior for this device: untouched
    assert est.risk(_hist(5), 10.0) == 1.0
    # evidence multiplies on top of the prior factor
    assert est.risk(_hist(3, stops=[9.0]), 10.0) == pytest.approx(3.0 * 2.0)


def test_priors_normalize_to_sorted_tuple_and_validate():
    cfg = HazardPolicyConfig(priors=[(5, 100), (2, 300.5)])
    assert cfg.priors == ((2, 300.5), (5, 100.0))
    with pytest.raises(ValueError):
        HazardPolicyConfig(priors={1: 0.0})


def test_none_priors_keep_legacy_risk():
    est = HazardEstimator(HazardPolicyConfig())
    assert est.risk(_hist(0, stops=[9.0]), 10.0) == 3.0


# -------------------------------------------------------- policy plumbing
def test_credit_switch_defaults_off_and_implies_hazard():
    assert TrainingSim("resihp", TINY).policy.credit is None
    p = TrainingSim("resihp", TINY, policy_kwargs={"credit": True}).policy
    assert isinstance(p.credit, CreditConfig)
    assert p.hazard is not None and p.lifecycle is not None
    assert p.scheduler.ntp_min_credit == p.credit.ntp_band


def test_credit_off_sim_is_inert():
    """``credit=None`` must not even construct the model — the credit-blind
    path is the byte-identity contract the goldens pin."""
    sim = TrainingSim("resihp", TINY)
    assert sim.credit_model is None
    sim2 = TrainingSim("resihp", TINY, policy_kwargs={"lifecycle": True})
    assert sim2.credit_model is None and sim2.lifecycle.credit is None


def test_credit_dft_one_retires_drift_stack():
    """A fitted threshold of 1.0 is unclearable, so the simulator must not
    install the slope/carry drift machinery at all — its bookkeeping alone
    taxes storm families even when every alarm is filtered."""
    on = TrainingSim("resihp", TINY,
                     policy_kwargs={"credit": CreditConfig()})
    assert on.detector._drift is not None  # sub-1.0 threshold keeps it
    cr = CreditConfig(drift_filter_threshold=1.0)
    off = TrainingSim("resihp", TINY, policy_kwargs={"credit": cr})
    assert off.detector._drift is None
    # credit-off lifecycle keeps its stack regardless (identity contract)
    lc = TrainingSim("resihp", TINY, policy_kwargs={"lifecycle": True})
    assert lc.detector._drift is not None


def test_credit_debounce_rides_the_fit_surface():
    """``validation_debounce_s`` is the second retired constant: the credit
    value must reach the detector, and the credit-off default must stay the
    lifecycle's hand-tuned 4.0."""
    cr = CreditConfig(validation_debounce_s=1.5)
    sim = TrainingSim("resihp", TINY, policy_kwargs={"credit": cr})
    assert sim.detector.validation_debounce_s == 1.5
    lc = TrainingSim("resihp", TINY, policy_kwargs={"lifecycle": True})
    assert lc.detector.validation_debounce_s == 4.0


def test_credit_sim_smoke_runs_and_counts():
    sim = TrainingSim("resihp", TINY,
                      policy_kwargs={"credit": True, "ntp": True,
                                     "plan_overhead_fixed": 0.25})
    assert sim.credit_model is not None
    assert sim.lifecycle.credit is sim.credit_model
    assert sim.policy.scheduler.credit_stats is sim.credit_model.stats
    # short span so the flap cycle lands inside the ~1.5 simulated seconds
    # 40 iterations cover at this scale
    sim.apply_scenario(scenarios.get("flapping_stragglers", span=3.0,
                                     devices=(3, 4, 7)))
    sim.run(40, stop_on_abort=False)
    st = sim.credit_model.stats.as_dict()
    assert set(st) == {"direct_admits", "async_admissions", "quarantines",
                       "ntp_vetoes", "probation_corrections"}
    assert all(v >= 0 for v in st.values())
    # flapping devices rejoin repeatedly: some admission path must have fired
    assert st["direct_admits"] + st["async_admissions"] > 0
    assert sim.lifecycle.stats.readmissions > 0


# ------------------------------------------------------------ fit driver
def _tiny_fit_setup(monkeypatch, fc):
    """Shrink the fit to seconds: 2 families, 1 baseline column, a surface
    with two non-default candidates, the 16-device model, 6 iterations."""
    import benchmarks.bench_scenarios as bs

    monkeypatch.setattr(fc, "SWEEP", {
        "flapping_stragglers": bs.SWEEP["flapping_stragglers"],
        "slow_ramp_mix": bs.SWEEP["slow_ramp_mix"],
    })
    monkeypatch.setattr(fc, "CREDIT_BASELINES", ("resihp",))
    monkeypatch.setattr(fc, "MODEL", "llama2-7b")
    defaults = {f: getattr(CreditConfig(), f) for f in FIT_FIELDS}
    space = {f: (v,) for f, v in defaults.items()}
    space["beta"] = (defaults["beta"], 0.5)
    space["gamma"] = (defaults["gamma"], 0.0)
    monkeypatch.setattr(fc, "SPACE", space)
    monkeypatch.setattr(fc, "SEEDS", ({},))


def test_fit_is_deterministic_and_worker_invariant(monkeypatch):
    fc = _load_fit_credit()
    _tiny_fit_setup(monkeypatch, fc)
    a = fc.fit(iters=6, rounds=1, workers=1)
    b = fc.fit(iters=6, rounds=1, workers=1)
    assert a == b
    c = fc.fit(iters=6, rounds=1, workers=2)
    assert a == c  # worker count never changes the output bytes
    assert json.dumps(a, sort_keys=True) == json.dumps(c, sort_keys=True)
    assert tuple(sorted(a["fitted"])) == tuple(sorted(FIT_FIELDS))
    assert a["history"][0]["note"] == "seed 0"
    assert a["history"][0]["accepted"] is True


def test_fit_objective_shape():
    fc = _load_fit_credit()
    # parity scores 1.0/family; wins cap at CAP; losses cost LOSS_MULT-fold
    assert fc.objective([1.0, 1.0]) == pytest.approx(2.0)
    assert fc.objective([1.5]) == pytest.approx(1.0 + fc.CAP)
    assert fc.objective([0.99]) == pytest.approx(1.0 - fc.LOSS_MULT * 0.01)


def test_fit_check_flags_drift():
    fc = _load_fit_credit()
    report = {"fitted": {"alpha": 0.1}, "objective": 15.0}
    pinned = {"fitted": {"alpha": 0.1},
              "quick": {"fitted": {"alpha": 0.2}, "objective": 15.0}}
    errors = fc.check(report, pinned)
    assert any("drifted" in e for e in errors)
    assert fc.check(report, {}) == ["pinned credit_fitted.json has no "
                                    "'quick' block"]
    ok = {"fitted": {"alpha": 0.1},
          "quick": {"fitted": {"alpha": 0.1}, "objective": 15.0}}
    assert fc.check(report, ok) == []


# ------------------------------------------------------- multi-scale sweep
def test_bench_scenarios_scales_axis(monkeypatch):
    import benchmarks.bench_scenarios as bs

    captured = {}
    monkeypatch.setattr(bs, "write_result",
                        lambda name, payload: captured.update({name: payload}))
    monkeypatch.setattr(bs, "SWEEP",
                        {"flapping_stragglers": bs.SWEEP["flapping_stragglers"]})
    monkeypatch.setattr(bs, "POLICIES", {"resihp": ("resihp", {})})
    rows = bs.main(quick=True, scales=[None, "small"], iters=6)
    keys = set(captured["scenarios_sweep"])
    assert keys == {"llama2-13b/flapping_stragglers@native",
                    "llama2-13b/flapping_stragglers@small"}
    assert all(r[0].startswith("scenarios/llama2-13b/") for r in rows)
    # a single-scale grid keeps the pre-axis key shape (no @ level)
    captured.clear()
    bs.main(quick=True, scales=["small"], iters=6)
    assert set(captured["scenarios_sweep"]) == {"llama2-13b/flapping_stragglers"}


def test_bench_scenarios_rejects_unknown_scale(monkeypatch):
    import benchmarks.bench_scenarios as bs

    with pytest.raises(AssertionError):
        bs.main(quick=True, scales=["galactic"], iters=6)


# -------------------------------------------------------- acceptance pins
# The fitted surface must make one scalar competitive with per-family
# hand-tuning: >= the best hand-tuned resihp column on EVERY family, and
# strictly better on at least two. Regenerate the artifact with
# ``PYTHONPATH=src python tools/fit_credit.py`` (slow) if these fail after
# an intentional credit-path change.
def _artifact():
    assert FITTED_CONFIG_PATH.exists(), \
        "run: PYTHONPATH=src python tools/fit_credit.py"
    return json.loads(FITTED_CONFIG_PATH.read_text())


def test_fitted_artifact_shape():
    art = _artifact()
    assert set(art["fitted"]) <= set(FIT_FIELDS)
    assert set(art["ratios"]) == set(art["baselines"]) == set(art["sessions"])
    assert art["quick"]["recipe"]["iters"] == 40
    assert art["provenance"]["tool"] == "tools/fit_credit.py"
    # the runtime loader accepts the checked-in surface
    cfg = fitted_credit_config()
    for f, v in art["fitted"].items():
        assert getattr(cfg, f) == v


# The catalog's adversarially-mined mirror pairs were *constructed* so the
# same instantaneous evidence demands opposite actions — adversarial_1's
# permanent throttle and adversarial_2/3's transient storms share a probe
# signature (plan fraction, measured speed and storm prefix identical up to
# the probe), and thermal_throttle_fleet vs slow_ramp_mix pull the
# validation debounce in opposite directions — so one fitted config cannot
# dominate both sides of a pair. These families may sit below their best
# hand-tuned column, but never by more than the measured bound; any
# mechanism that closes one shows up here as a win.
RESIDUAL_FAMILIES = frozenset(
    {"adversarial_2", "slow_ramp_mix", "thermal_throttle_fleet"})
RESIDUAL_FLOOR = 0.99


def test_fitted_credit_dominates_hand_tuned_columns():
    art = _artifact()
    ratios = art["ratios"]
    losses = {sc: r for sc, r in ratios.items() if r < 1.0 - 1e-9}
    assert set(losses) <= RESIDUAL_FAMILIES, (
        f"fitted credit loses outside the pinned residual set: "
        f"{ {sc: r for sc, r in losses.items() if sc not in RESIDUAL_FAMILIES} }")
    assert all(r >= RESIDUAL_FLOOR for r in losses.values()), (
        f"a pinned residual fell below the {RESIDUAL_FLOOR} floor: {losses}")
    wins = {sc: r for sc, r in ratios.items() if r > 1.0 + 5e-4}
    assert len(wins) >= 10, f"need >= 10 strict wins, got {len(wins)}: {wins}"
    # mixed-signal families (probe + flap + domain evidence interacting)
    # must be strict wins, not near-ties — the scalar's reason to exist
    for sc in ("degraded_rejoins", "rack_storm", "flapping_stragglers",
               "aging_fleet", "adversarial_3"):
        assert ratios[sc] > 1.005, f"{sc} should win by > 0.5%: {ratios[sc]}"


@pytest.mark.slow
def test_fitted_sessions_reproduce_exactly():
    """Re-run one fit cell (the best-ratio family) with the checked-in
    surface at the full recipe and pin exact equality against the artifact's
    unrounded session value — the whole chain (config load, sim, fit
    bookkeeping) is deterministic end to end."""
    fc = _load_fit_credit()
    art = _artifact()
    sc = max(art["ratios"], key=lambda k: (art["ratios"][k], k))
    params = tuple(sorted(art["fitted"].items()))
    iters = art["provenance"]["recipe"]["iters"]
    got = fc.eval_cell((sc, params), iters=iters, engine="fast")
    assert got == art["sessions"][sc]
