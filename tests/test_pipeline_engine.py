"""Pipeline engine integration: exactness vs the single-device reference,
fault injection -> reconfigure -> resume (loss continuity), migration
identity, and checkpoint-restart determinism."""
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_arch, reduced
from repro.core.detector.detector import FailureReport
from repro.core.scheduler.plan import initial_plan
from repro.core.scheduler.repartition import costs_for_arch
from repro.core.scheduler.scheduler import Scheduler
from repro.data.synth import SyntheticPackedDataset
from repro.engine.pipeline import PipelineEngine
from repro.models.model import loss_fn, stacked_init
from repro.parallel.sharding import NULL_POLICY, split_annotations
from repro.train.optimizer import make_optimizer

# every test here compiles multi-stage jax pipelines (12-33 s apiece); the
# tier-1 suite covers the same scheduler/migration logic through the numpy
# simulator and golden tests
pytestmark = pytest.mark.slow

CFG = reduced(get_arch("qwen3-8b"), n_layers=4)


def _batch(i=0, B=8, S=64):
    ds = SyntheticPackedDataset(CFG, S, B, seed=3)
    return {k: jnp.asarray(v) for k, v in ds.batch_at(i).items()}


def test_pipeline_matches_reference_loss():
    batch = _batch()
    params, _ = split_annotations(stacked_init(jax.random.PRNGKey(0), CFG))
    _, aux = loss_fn(CFG, params, batch, NULL_POLICY, use_scan=False, remat=False)
    eng = PipelineEngine(CFG, initial_plan(4, dp=2, pp=2, tp=1, microbatches=2),
                         optimizer=None, seed=0)
    loss, _ = eng.run_iteration(batch)
    assert abs(loss - float(aux["loss"])) < 2e-3


def test_migration_placement_identity():
    """Executing a micro-batch's stage on a peer replica (Fig. 6b) is
    mathematically identical — replicas are synchronized."""
    from repro.core.detector.dag_sim import ChunkId

    batch = _batch()
    plan = initial_plan(4, dp=2, pp=2, tp=1, microbatches=2)
    eng = PipelineEngine(CFG, plan, optimizer=None, seed=0)
    base, _ = eng.run_iteration(batch)
    placement = {
        ChunkId("F", 0, 1, 0): (1, 1),
        ChunkId("B", 0, 1, 0): (1, 1),
    }
    mig, _ = eng.run_iteration(batch, placement=placement)
    assert abs(base - mig) < 1e-5


def test_failstop_reconfigure_resume_loss_continuity():
    """Kill a device mid-training; Scheduler re-plans (TP exclusion +
    repartition); engine reshards; loss stays continuous (Fig. 12)."""
    opt = make_optimizer("adamw", lr=5e-3)
    plan = initial_plan(4, dp=2, pp=2, tp=2, microbatches=2)
    eng = PipelineEngine(CFG, plan, optimizer=opt, seed=0)
    losses = []
    for i in range(4):
        loss, _ = eng.run_iteration(_batch(i))
        losses.append(loss)
    # fail-stop device 5 (replica 1, stage 0)
    sch = Scheduler(layer_costs=costs_for_arch(CFG, 64))
    speeds = {d: 1.0 for d in plan.devices}
    speeds[5] = 0.0
    ad = sch.adapt(plan, speeds, failed={5})
    assert ad.plan.replicas[1].stages[0].tp == 1  # selective exclusion
    eng.apply_plan(ad.plan)
    for i in range(4, 8):
        loss, _ = eng.run_iteration(_batch(i))
        losses.append(loss)
    # continuity: the post-reconfig loss doesn't jump (same params, math)
    assert abs(losses[4] - losses[3]) < 0.15
    assert all(np.isfinite(losses))


def test_fault_tolerant_training_subprocess_8dev():
    """Full driver on 8 emulated host devices: inject a fail-stop, verify
    reconfiguration + completion (the multi-device integration test)."""
    code = (
        "import repro.launch.train as T; "
        "r = T.main(['--arch','qwen3-8b','--reduced','--mode','pipeline',"
        "'--dp','2','--pp','2','--tp','2','--steps','6','--seq-len','64',"
        "'--batch','8','--inject-failstop','3:5']); "
        "import numpy as np; assert np.isfinite(r['losses']).all(); "
        "assert r['reconfigs'] == [3], r['reconfigs']"
    )
    env = {"REPRO_HOST_DEVICES": "8", "PYTHONPATH": "src"}
    import os

    full_env = dict(os.environ)
    full_env.update(env)
    proc = subprocess.run([sys.executable, "-c", code], cwd="/root/repo",
                          env=full_env, capture_output=True, text=True,
                          timeout=900)
    assert proc.returncode == 0, proc.stderr[-2000:]


def test_checkpoint_restart_determinism(tmp_path):
    """Train 6 steps straight vs 3 steps + restart + 3 steps: identical
    final loss (resumable data pipeline + exact state restore)."""
    import os
    import subprocess
    import sys

    def run(steps, resume):
        code = (
            "import repro.launch.train as T; import json; "
            f"r = T.main(['--arch','qwen3-8b','--reduced','--mode','spmd',"
            f"'--steps','{steps}','--seq-len','64','--batch','4',"
            f"'--ckpt-dir','{tmp_path}','--ckpt-interval','3'"
            + (",'--resume'" if resume else "")
            + "]); print('FINAL', r['losses'][-1])"
        )
        env = dict(os.environ)
        env["PYTHONPATH"] = "src"
        p = subprocess.run([sys.executable, "-c", code], cwd="/root/repo",
                           env=env, capture_output=True, text=True, timeout=600)
        assert p.returncode == 0, p.stderr[-2000:]
        return float(p.stdout.strip().split("FINAL")[-1])

    loss_straight = run(6, resume=False)
    import shutil

    shutil.rmtree(tmp_path)
    run(3, resume=False)  # writes ckpt at step 3
    loss_restart = run(6, resume=True)  # resumes from 3
    assert loss_restart == pytest.approx(loss_straight, abs=1e-5)
